//! Serving front-line tests: policy admission ordering under a binding
//! byte budget (best-fit packs at least as many jobs as first-fit,
//! which beats round-robin's head-of-line blocking), the paper's
//! capacity claim surfaced at the queue (ours admits more jobs than
//! baseline under the same budget and trace), and the determinism
//! contract — every job a front line completes is bit-identical to a
//! serial `Trainer` twin, under every policy and thread count.

use std::collections::BTreeMap;

use ambp::coordinator::engine::predict;
use ambp::coordinator::{
    frontline, Engine, FrontCfg, FrontReport, Policy, TrafficCfg,
    TrafficJob, TrainCfg, Trainer, traffic,
};
use ambp::runtime::native::pool::with_threads;
use ambp::runtime::{Artifact, Runtime};

const OURS: &str = "vitt_loraqv_regelu2_msln";
const BASELINE: &str = "vitt_loraqv_gelu_ln";

fn rt() -> Runtime {
    Runtime::cpu().expect("native runtime")
}

fn base_cfg() -> TrainCfg {
    TrainCfg {
        steps: 0,
        lr: 2e-3,
        log_every: 0,
        eval_batches: 2,
        seed: 0,
        ..TrainCfg::default()
    }
}

/// The exact per-job cfg the front line derives from a trace entry.
fn job_cfg(steps: usize, seed: u64) -> TrainCfg {
    TrainCfg { steps, seed, ..base_cfg() }
}

fn job(arrival: u64, preset: &str, steps: usize, seed: u64,
       priority: i64) -> TrafficJob {
    TrafficJob {
        arrival,
        preset: preset.to_string(),
        steps,
        seed,
        priority,
    }
}

fn front(policy: Policy, budget: u64, ticks: u64) -> FrontCfg {
    FrontCfg {
        policy,
        budget,
        base_cfg: base_cfg(),
        max_ticks: ticks,
        spool: None,
        preempt: false,
        fuse: false,
    }
}

/// Fresh per-test spool directory under the OS temp dir.
fn spool_dir(label: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ambp_frontline_test_{}_{label}", std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn arts_for(rt: &Runtime, presets: &[&str]) -> BTreeMap<String, Artifact> {
    presets
        .iter()
        .map(|p| (p.to_string(), Artifact::synth(rt, p).unwrap()))
        .collect()
}

/// (base bytes, marginal bytes) the memmodel predicts for one job of
/// `preset` — the same numbers the front line fit-checks against.
fn costs(arts: &BTreeMap<String, Artifact>, preset: &str) -> (u64, u64) {
    let art = &arts[preset];
    (art.frozen_base().nbytes(), predict(art, &job_cfg(2, 0)).marginal())
}

#[test]
fn first_fit_skips_head_of_line_blocker() {
    // tick 0: a cheap job is admitted. tick 1: an expensive job that
    // cannot fit next to it arrives *ahead of* a cheap one that can.
    // Round-robin's FIFO head blocks the queue; first-fit and best-fit
    // admit the cheap job past it.
    let rt = rt();
    let arts = arts_for(&rt, &[OURS, BASELINE]);
    let (bc, cc) = costs(&arts, OURS);
    let (be, ce) = costs(&arts, BASELINE);
    let budget = bc + be + ce + cc / 2;
    // scenario preconditions, in terms of the memmodel's own numbers
    assert!(cc < ce, "ours marginal {cc} must undercut baseline {ce}");
    assert!(bc + cc <= budget, "j0 must fit an empty fleet");
    assert!(be + ce <= budget, "j1 must pass the arrival floor");
    assert!(bc + cc + be + ce > budget, "j1 must not fit beside j0");
    assert!(bc + 2 * cc <= budget, "j2 must fit beside j0");

    let trace = [
        job(0, OURS, 2, 3, 0),
        job(1, BASELINE, 2, 5, 0),
        job(1, OURS, 2, 7, 0),
    ];
    let admitted = |policy: Policy| {
        frontline::serve(&arts, &trace, &front(policy, budget, 2))
            .unwrap()
            .metrics
            .admitted
    };
    let rr = admitted(Policy::RoundRobin);
    let ff = admitted(Policy::FirstFit);
    let bf = admitted(Policy::BestFit);
    assert_eq!(rr, 1, "round-robin blocks on the expensive head");
    assert_eq!(ff, 2, "first-fit admits the cheap job past it");
    assert_eq!(bf, 2, "best-fit admits the cheap job past it");
}

#[test]
fn best_fit_packs_more_jobs_than_first_fit() {
    // all three jobs arrive at once; the budget holds either the one
    // expensive job or both cheap ones, never a mix. First-fit burns
    // the budget on the expensive arrival at the queue front; best-fit
    // takes the cheapest jobs first and admits two.
    let rt = rt();
    let arts = arts_for(&rt, &[OURS, BASELINE]);
    let (bc, cc) = costs(&arts, OURS);
    let (be, ce) = costs(&arts, BASELINE);
    let budget = (be + ce).max(bc + 2 * cc);
    assert!(bc + cc < be + ce, "cheap job must cost less than expensive");
    assert!(bc + 2 * cc <= budget, "both cheap jobs must fit together");
    assert!(be + ce <= budget, "the expensive job must fit alone");
    assert!(be + ce + bc + cc > budget,
            "expensive + cheap must overflow the budget");

    let trace = [
        job(0, BASELINE, 2, 3, 0),
        job(0, OURS, 2, 5, 0),
        job(0, OURS, 2, 7, 0),
    ];
    let admitted = |policy: Policy| {
        frontline::serve(&arts, &trace, &front(policy, budget, 1))
            .unwrap()
            .metrics
            .admitted
    };
    assert_eq!(admitted(Policy::RoundRobin), 1);
    assert_eq!(admitted(Policy::FirstFit), 1);
    assert_eq!(admitted(Policy::BestFit), 2);
}

#[test]
fn ours_admits_more_jobs_than_baseline_same_budget_and_trace() {
    // identical traffic shape, identical budget; the only difference
    // is the preset group. The budget holds three of ours' sessions —
    // and strictly fewer of baseline's, because its marginal is larger
    // (the paper's capacity claim, surfaced at the admission queue).
    let rt = rt();
    let arts = arts_for(&rt, &[OURS, BASELINE]);
    let (bc, cc) = costs(&arts, OURS);
    let (be, ce) = costs(&arts, BASELINE);
    assert!(cc < ce, "ours marginal {cc} must undercut baseline {ce}");
    assert_eq!(bc, be, "same arch: frozen bases must match in size");
    let budget = bc.max(be) + 3 * cc;

    let count = |preset: &str| {
        let trace = [
            job(0, preset, 2, 3, 0),
            job(0, preset, 2, 5, 0),
            job(0, preset, 2, 7, 0),
        ];
        frontline::serve(&arts, &trace,
                         &front(Policy::FirstFit, budget, 1))
            .unwrap()
            .metrics
            .admitted
    };
    let ours = count(OURS);
    let baseline = count(BASELINE);
    assert_eq!(ours, 3, "budget was sized for three of ours");
    assert!(baseline < ours,
            "baseline admitted {baseline}, ours {ours} — \
             same budget must hold strictly fewer baseline jobs");
}

/// Per-step (loss bits, metric bits, activation bytes) signatures.
fn row_sigs(rep: &FrontReport) -> BTreeMap<String, Vec<(u32, u32, u64)>> {
    rep.reports
        .iter()
        .map(|r| {
            let tr = r.train().expect("completed");
            let rows = tr
                .rows
                .iter()
                .map(|w| {
                    (w.loss.to_bits(), w.metric.to_bits(),
                     w.activation_bytes)
                })
                .collect();
            (r.name.clone(), rows)
        })
        .collect()
}

fn seeded_trace() -> Vec<TrafficJob> {
    traffic::generate(&TrafficCfg {
        seed: 11,
        jobs: 5,
        presets: vec![OURS.to_string()],
        ..TrafficCfg::default()
    })
    .unwrap()
}

#[test]
fn completed_jobs_bit_identical_to_serial_twins_under_every_policy() {
    // a binding budget (two concurrent sessions) forces real queueing,
    // and the trace carries mixed priorities — none of which may leak
    // into training: every completed job must match a serial Trainer
    // twin bit-for-bit, whatever the policy interleaving did.
    let rt = rt();
    let arts = arts_for(&rt, &[OURS]);
    let (b, c) = costs(&arts, OURS);
    let budget = b + 2 * c;
    let trace = seeded_trace();

    let twins: BTreeMap<String, Vec<(u32, u32, u64)>> = trace
        .iter()
        .enumerate()
        .map(|(i, j)| {
            let mut t = Trainer::new(&arts[OURS],
                                     job_cfg(j.steps, j.seed))
                .unwrap();
            let rows = t
                .train()
                .unwrap()
                .rows
                .iter()
                .map(|w| {
                    (w.loss.to_bits(), w.metric.to_bits(),
                     w.activation_bytes)
                })
                .collect();
            (format!("j{i}"), rows)
        })
        .collect();

    for policy in [Policy::RoundRobin, Policy::FirstFit, Policy::BestFit]
    {
        let rep = frontline::serve(&arts, &trace,
                                   &front(policy, budget, 0))
            .unwrap();
        assert_eq!(rep.metrics.admitted, trace.len(),
                   "{policy:?}: drained run admits everything");
        assert_eq!(rep.metrics.completed, trace.len(), "{policy:?}");
        assert_eq!(rep.metrics.rejected, 0, "{policy:?}");
        assert_eq!(row_sigs(&rep), twins,
                   "{policy:?}: completed jobs must be bit-identical \
                    to serial twins");
    }
}

#[test]
fn preemption_that_would_strand_the_victim_is_requeued_not_an_error() {
    // KNOWN.md regression. Resident: one baseline job filling a budget
    // of exactly (both bases + ours' marginal). Arrival: a
    // higher-priority ours job on a *different* frozen base. Evicting
    // the baseline victim admits the new base — which never leaves
    // residency — after which the victim could never refit
    // (bases + its marginal > budget): the old behavior evicted
    // anyway, and once the high-priority job drained, the engine's
    // scheduling-deadlock detector failed the entire run. The front
    // line must instead leave the arrival queued until the resident
    // job retires, then admit it normally — everyone completes.
    let rt = rt();
    let arts = arts_for(&rt, &[OURS, BASELINE]);
    let (bo, co) = costs(&arts, OURS);
    let (bb, cb) = costs(&arts, BASELINE);
    assert!(!std::sync::Arc::ptr_eq(&arts[OURS].frozen_base(),
                                    &arts[BASELINE].frozen_base()),
            "distinct presets must carry distinct frozen bases");
    // scenario preconditions, in the memmodel's own numbers
    assert!(co < cb, "ours marginal {co} must undercut baseline {cb}");
    assert!(cb <= bo + co,
            "baseline marginal {cb} must not outweigh ours' whole \
             session {bo}+{co}");
    let budget = bb + bo + co;
    assert!(bb + cb <= budget, "the baseline job must fit alone");
    assert!(bo + co <= budget, "ours must pass the arrival floor");
    assert!(bb + cb + bo + co > budget,
            "ours must not fit beside the baseline job");

    // the engine probe sees the strand coming — and only for the
    // base-adding job, not for a same-base preemption
    {
        let spool = spool_dir("strand_probe");
        let mut engine = Engine::new(budget);
        engine.set_spool(spool.clone());
        engine.enable_preempt().unwrap();
        engine
            .admit_prio("j0", &arts[BASELINE], job_cfg(2, 3), 0)
            .unwrap();
        assert!(engine.preempt_would_strand(&arts[OURS],
                                            &job_cfg(2, 5), 10),
                "evicting the victim for a new-base job leaves it \
                 unable to ever refit");
        assert!(!engine.preempt_would_strand(&arts[BASELINE],
                                             &job_cfg(2, 5), 10),
                "a same-base preemption keeps the victim refittable");
        let _ = std::fs::remove_dir_all(&spool);
    }

    let trace = [job(0, BASELINE, 2, 3, 0), job(1, OURS, 2, 5, 10)];
    let spool = spool_dir("strand_serve");
    let mut cfg = front(Policy::FirstFit, budget, 0);
    cfg.spool = Some(spool.clone());
    cfg.preempt = true;
    let rep = frontline::serve(&arts, &trace, &cfg).expect(
        "a stranding preemption must requeue the arrival, not fail \
         the run",
    );
    let m = &rep.metrics;
    assert_eq!(m.preemptions, 0, "no doomed eviction may happen");
    assert_eq!(m.sessions[0].outcome, "completed",
               "the resident job must run to completion undisturbed");
    assert_eq!(m.sessions[0].steps, 2);
    // budget = bases + ours' marginal: after the retire, the arrival
    // fits exactly and completes
    assert_eq!(m.sessions[1].outcome, "completed",
               "the requeued job must be admitted once the victim \
                retires");
    assert_eq!(m.completed, 2);
    let _ = std::fs::remove_dir_all(&spool);
}

#[test]
fn fused_front_line_bit_identical_with_fused_passes_recorded() {
    // same binding-budget trace as the serial-twin test, but with
    // cross-tenant fusion on: per-job results must still match the
    // serial Trainer twins bit-for-bit, and the fleet metrics must
    // show that gangs actually formed (fused passes > 0, occupancy
    // recorded at ≥ 2-way)
    let rt = rt();
    let arts = arts_for(&rt, &[OURS]);
    let (b, c) = costs(&arts, OURS);
    let budget = b + 2 * c;
    let trace = seeded_trace();

    let twins: BTreeMap<String, Vec<(u32, u32, u64)>> = trace
        .iter()
        .enumerate()
        .map(|(i, j)| {
            let mut t = Trainer::new(&arts[OURS],
                                     job_cfg(j.steps, j.seed))
                .unwrap();
            let rows = t
                .train()
                .unwrap()
                .rows
                .iter()
                .map(|w| {
                    (w.loss.to_bits(), w.metric.to_bits(),
                     w.activation_bytes)
                })
                .collect();
            (format!("j{i}"), rows)
        })
        .collect();

    let mut cfg = front(Policy::BestFit, budget, 0);
    cfg.fuse = true;
    let rep = frontline::serve(&arts, &trace, &cfg).unwrap();
    assert_eq!(rep.metrics.completed, trace.len());
    assert_eq!(row_sigs(&rep), twins,
               "fused jobs must be bit-identical to serial twins");
    assert!(rep.metrics.fused_passes > 0,
            "two concurrent same-preset sessions must have fused");
    assert!(rep.metrics
                .gang_occupancy
                .iter()
                .any(|&(n, count)| n >= 2 && count > 0),
            "occupancy histogram must record a ≥2-way gang: {:?}",
            rep.metrics.gang_occupancy);
}

#[test]
fn virtual_time_metrics_identical_across_thread_counts() {
    // wall-clock latency is measurement only; everything derived from
    // virtual time must not notice the worker pool size
    let run = || {
        let rt = rt();
        let arts = arts_for(&rt, &[OURS]);
        let (b, c) = costs(&arts, OURS);
        let rep = frontline::serve(&arts, &seeded_trace(),
                                   &front(Policy::BestFit, b + 2 * c, 0))
            .unwrap();
        let sessions: Vec<_> = rep
            .metrics
            .sessions
            .iter()
            .map(|s| {
                (s.name.clone(), s.arrival, s.admit, s.finish,
                 s.steps, s.predicted_marginal_bytes,
                 s.peak_activation_bytes, s.outcome.clone())
            })
            .collect();
        let m = &rep.metrics;
        ((m.ticks, m.admitted, m.completed, m.rejected,
          m.quarantined, m.preemptions),
         (m.queue_wait_ticks.p50, m.queue_wait_ticks.p90,
          m.queue_wait_ticks.p99),
         sessions,
         row_sigs(&rep))
    };
    let one = with_threads(1, run);
    let four = with_threads(4, run);
    assert_eq!(one, four,
               "virtual-time fleet metrics must be thread-invariant");
}
