//! Durable-state test suite: the crash/corruption/bit-identity pins
//! for the statefile format and suspend/resume.
//!
//! * Format pin: the committed fixture `tests/fixtures/statefile_v1.state`
//!   must equal the Rust writer's output byte-for-byte — any layout
//!   change fails here until `FORMAT_VERSION` is bumped and the
//!   fixture regenerated (`cargo test -- --ignored regenerate_fixture`
//!   or `python3 tests/fixtures/gen_statefile_v1.py`).
//! * Corruption robustness: every single-bit flip and every truncation
//!   of a statefile yields a typed `StateError` naming the damaged
//!   region — never a panic, never a silent load.
//! * Bit identity: suspend at step k + resume equals an uninterrupted
//!   run byte-for-byte (per-step loss/metric/activation signatures and
//!   final trainables) across presets and worker-thread counts,
//!   including resuming under a different thread count than the
//!   suspend ran with.

use std::path::{Path, PathBuf};

use ambp::coordinator::checkpoint::Checkpoint;
use ambp::coordinator::statefile::{
    self, StateError, StateFile, Writer, FORMAT_VERSION, MAGIC,
};
use ambp::coordinator::{Session, StepOutcome, TrainCfg};
use ambp::runtime::native::pool::with_threads;
use ambp::runtime::{Artifact, Runtime, Tensor};

const FIXTURE: &str = "tests/fixtures/statefile_v1.state";

fn rt() -> Runtime {
    Runtime::cpu().expect("native runtime")
}

fn cfg(steps: usize, seed: u64) -> TrainCfg {
    TrainCfg {
        steps,
        lr: 2e-3,
        log_every: 0,
        eval_batches: 2,
        seed,
        ..TrainCfg::default()
    }
}

/// Scratch path under the OS temp dir, unique per label (tests run in
/// one process; labels keep parallel test threads apart).
fn scratch(label: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ambp_statefile_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(label)
}

/// `unwrap_err` without a `Debug` bound on the success type
/// (`Session` and `Checkpoint` don't implement it).
fn err_of<T, E>(r: Result<T, E>, what: &str) -> E {
    match r {
        Err(e) => e,
        Ok(_) => panic!("{what} unexpectedly succeeded"),
    }
}

/// The exact sections `gen_statefile_v1.py` writes — keep in sync.
fn fixture_writer() -> Writer {
    let mut w = Writer::new();
    w.add("fixture.meta", b"ambp statefile fixture v1\n".to_vec());
    let mut data = Vec::new();
    for v in [1.0f32, 2.0, -3.5, 4.25] {
        data.extend_from_slice(&v.to_le_bytes());
    }
    w.add("fixture.data", data);
    w
}

// ---------------------------------------------------------------------
// Format pin
// ---------------------------------------------------------------------

#[test]
fn format_is_pinned_by_fixture() {
    assert_eq!(MAGIC, *b"AMBPSTF\0");
    assert_eq!(FORMAT_VERSION, 1);
    let want = std::fs::read(FIXTURE)
        .expect("fixture missing — run tests from the rust/ package root");
    let got = fixture_writer().finish();
    assert_eq!(
        got, want,
        "the on-disk statefile layout changed without a fixture \
         update: bump FORMAT_VERSION in src/coordinator/statefile.rs, \
         then regenerate tests/fixtures/statefile_v1.state (cargo test \
         -- --ignored regenerate_fixture, and keep \
         tests/fixtures/gen_statefile_v1.py in sync)"
    );
}

#[test]
fn fixture_parses_and_sections_read_zero_copy() {
    let buf = std::fs::read(FIXTURE).unwrap();
    let sf = StateFile::parse(&buf).unwrap();
    assert_eq!(sf.names(), vec!["fixture.meta", "fixture.data"]);
    assert_eq!(sf.section("fixture.meta").unwrap(),
               b"ambp statefile fixture v1\n");
    let data = sf.section("fixture.data").unwrap();
    // payloads are 64-byte aligned within the file
    let off = data.as_ptr() as usize - buf.as_ptr() as usize;
    assert_eq!(off % 64, 0, "payload not 64-byte aligned");
    let vals: Vec<f32> = data
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    assert_eq!(vals, vec![1.0, 2.0, -3.5, 4.25]);
    assert!(matches!(sf.section("nope"),
                     Err(StateError::MissingSection { .. })));
}

/// Rewrites the fixture from the Rust writer. Run only after an
/// intentional format change (with a FORMAT_VERSION bump):
/// `cargo test --test statefile -- --ignored regenerate_fixture`
#[test]
#[ignore]
fn regenerate_fixture() {
    fixture_writer().write(Path::new(FIXTURE)).unwrap();
}

// ---------------------------------------------------------------------
// Corruption robustness
// ---------------------------------------------------------------------

#[test]
fn every_single_bit_flip_is_a_typed_error() {
    let clean = std::fs::read(FIXTURE).unwrap();
    assert!(StateFile::parse(&clean).is_ok());
    // fixture geometry (asserted so region attribution stays honest)
    let meta_payload = 128..154usize;
    let data_payload = 192..208usize;
    assert_eq!(clean.len(), data_payload.end);
    for byte in 0..clean.len() {
        for bit in 0..8 {
            let mut buf = clean.clone();
            buf[byte] ^= 1 << bit;
            let err = match StateFile::parse(&buf) {
                Err(e) => e,
                Ok(_) => panic!(
                    "flip of byte {byte} bit {bit} loaded silently"
                ),
            };
            match byte {
                0..=7 => assert!(
                    matches!(err, StateError::BadMagic { .. }),
                    "byte {byte}: {err}"
                ),
                8..=11 => assert!(
                    matches!(err,
                             StateError::UnsupportedVersion { .. }),
                    "byte {byte}: {err}"
                ),
                16..=23 => assert!(
                    matches!(&err,
                             StateError::Truncated { section, .. }
                                 if section == "file"),
                    "byte {byte}: {err}"
                ),
                24..=31 => assert!(
                    matches!(&err,
                             StateError::ChecksumMismatch { section, .. }
                                 if section == "index"),
                    "byte {byte}: {err}"
                ),
                b if meta_payload.contains(&b) => assert!(
                    matches!(&err,
                             StateError::ChecksumMismatch { section, .. }
                                 if section == "fixture.meta"),
                    "byte {byte}: {err}"
                ),
                b if data_payload.contains(&b) => assert!(
                    matches!(&err,
                             StateError::ChecksumMismatch { section, .. }
                                 if section == "fixture.data"),
                    "byte {byte}: {err}"
                ),
                // section count, index entries, string table, padding:
                // always detected, attribution varies with the flip
                _ => {}
            }
        }
    }
}

#[test]
fn every_truncation_and_any_extension_is_typed() {
    let clean = std::fs::read(FIXTURE).unwrap();
    for cut in 0..clean.len() {
        let buf = &clean[..cut];
        let err = match StateFile::parse(buf) {
            Err(e) => e,
            Ok(_) => panic!("truncation to {cut} bytes loaded silently"),
        };
        if cut < 32 {
            assert!(
                matches!(&err, StateError::Truncated { section, .. }
                             if section == "header"),
                "cut {cut}: {err}"
            );
        } else {
            assert!(
                matches!(&err, StateError::Truncated { section, .. }
                             if section == "file"),
                "cut {cut}: {err}"
            );
        }
    }
    let mut extended = clean.clone();
    extended.push(0);
    assert!(matches!(
        StateFile::parse(&extended),
        Err(StateError::Truncated { ref section, .. })
            if section == "file"
    ));
}

#[test]
fn future_version_is_refused_before_checksum() {
    // a well-formed file from a hypothetical v2 writer: version bumped,
    // checksum recomputed so only the version check can refuse it
    let mut buf = fixture_writer().finish();
    buf[8..12].copy_from_slice(&2u32.to_le_bytes());
    let mut h = ambp::util::hash::Fnv64::new();
    h.update(&buf[0..24]);
    h.update(&buf[32..]);
    let sum = h.finish();
    buf[24..32].copy_from_slice(&sum.to_le_bytes());
    assert_eq!(
        StateFile::parse(&buf).unwrap_err(),
        StateError::UnsupportedVersion { found: 2, supported: 1 }
    );
}

#[test]
fn corrupted_session_statefile_never_resumes() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let mut s = Session::new(&art, cfg(4, 1)).unwrap();
    s.step().unwrap();
    let path = scratch("corrupt_session.state");
    statefile::save_session(&path, "victim", 0, &s.into_state())
        .unwrap();
    let clean = std::fs::read(&path).unwrap();
    assert!(statefile::load_session(&path).is_ok());
    // bit-flip a sweep of offsets across the whole file (headers,
    // index, tensor payloads): load must fail typed, never panic
    for byte in (0..clean.len()).step_by(97) {
        let mut buf = clean.clone();
        buf[byte] ^= 0x10;
        std::fs::write(&path, &buf).unwrap();
        let err = err_of(statefile::load_session(&path),
                         "loading a corrupt session statefile");
        assert!(err.is::<StateError>(),
                "byte {byte}: untyped error {err}");
    }
    // truncations too
    for cut in [0, 1, 31, 32, clean.len() / 2, clean.len() - 1] {
        std::fs::write(&path, &clean[..cut]).unwrap();
        assert!(statefile::load_session(&path).is_err(),
                "truncation to {cut} bytes loaded");
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn resume_against_the_wrong_artifact_is_refused() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let other = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let mut s = Session::new(&art, cfg(4, 1)).unwrap();
    s.step().unwrap();
    let state = s.into_state();
    // preset mismatch caught before any tensor is touched
    let err = err_of(Session::resume(&other, state.clone()),
                     "cross-preset resume");
    assert!(err.to_string().contains("preset"), "{err}");
    // same preset, different frozen weights: the fingerprint refuses
    let mut tampered = state.clone();
    tampered.base_fingerprint ^= 1;
    let err = err_of(Session::resume(&art, tampered),
                     "wrong-fingerprint resume");
    assert!(err.to_string().contains("fingerprint"), "{err}");
    // and the untampered state still resumes
    assert!(Session::resume(&art, state).is_ok());
}

// ---------------------------------------------------------------------
// Bit identity: suspend + resume == uninterrupted
// ---------------------------------------------------------------------

/// (loss bits, metric bits, activation bytes) per step.
type StepSig = (u32, u32, u64);

fn sig(s: &ambp::coordinator::StepStats) -> StepSig {
    (s.loss.to_bits(), s.metric.to_bits(), s.activation_bytes)
}

fn run_uninterrupted(art: &Artifact,
                     c: &TrainCfg) -> (Vec<StepSig>, Vec<Tensor>) {
    let mut s = Session::new(art, c.clone()).unwrap();
    let mut rows = Vec::new();
    while let StepOutcome::Stepped(st) = s.step().unwrap() {
        rows.push(sig(&st));
    }
    (rows, s.params())
}

/// Step to k, spool to disk, reload, resume to completion — the rows
/// span the whole run, pre- and post-suspend.
fn run_with_suspend(art: &Artifact, c: &TrainCfg, k: usize,
                    path: &Path) -> (Vec<StepSig>, Vec<Tensor>) {
    let mut s = Session::new(art, c.clone()).unwrap();
    let mut rows = Vec::new();
    for _ in 0..k {
        match s.step().unwrap() {
            StepOutcome::Stepped(st) => rows.push(sig(&st)),
            StepOutcome::Exhausted => panic!("suspend point beyond run"),
        }
    }
    let handle =
        statefile::save_session(path, "t", 0, &s.into_state()).unwrap();
    assert_eq!(handle.steps_done, k);
    assert_eq!(handle.steps_total, c.steps);
    // the envelope peek agrees with the full load
    let peeked = statefile::peek_session(path).unwrap();
    assert_eq!(peeked.steps_done, k);
    assert_eq!(peeked.preset, art.manifest.preset);
    let saved = statefile::load_session(path).unwrap();
    assert_eq!(saved.state.rows.len(), k);
    let mut s2 = Session::resume(art, saved.state).unwrap();
    assert_eq!(s2.steps_done(), k);
    while let StepOutcome::Stepped(st) = s2.step().unwrap() {
        rows.push(sig(&st));
    }
    std::fs::remove_file(path).unwrap();
    (rows, s2.params())
}

fn assert_params_eq(a: &[Tensor], b: &[Tensor], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: arity");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.data, y.data, "{label}: param {i} differs");
    }
}

fn suspend_resume_grid(threads_label: &str) {
    let rt = rt();
    for preset in ["vitt_loraqv_regelu2_msln",
                   "vitt_loraqv_gelu_ln_mesa",
                   "vitt_loraqv_gelu_ln_ckpt",
                   "llama_loraall_silu_rms_swiglu"] {
        let art = Artifact::synth(&rt, preset).unwrap();
        let c = cfg(5, 3);
        let (want_rows, want_params) = run_uninterrupted(&art, &c);
        assert_eq!(want_rows.len(), 5);
        let path =
            scratch(&format!("grid_{threads_label}_{preset}.state"));
        let (got_rows, got_params) =
            run_with_suspend(&art, &c, 2, &path);
        assert_eq!(got_rows, want_rows,
                   "{preset} [{threads_label}]: per-step signatures \
                    diverged across suspend/resume");
        assert_params_eq(&got_params, &want_params,
                         &format!("{preset} [{threads_label}]"));
    }
}

#[test]
fn suspend_resume_bit_identical_1_thread() {
    with_threads(1, || suspend_resume_grid("t1"));
}

#[test]
fn suspend_resume_bit_identical_4_threads() {
    with_threads(4, || suspend_resume_grid("t4"));
}

#[test]
fn resume_under_a_different_thread_count_still_matches() {
    // the kernels are bit-identical across worker counts, so a session
    // suspended under 1 thread and resumed under 4 must equal the
    // uninterrupted single-thread run
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let c = cfg(5, 11);
    let (want_rows, want_params) =
        with_threads(1, || run_uninterrupted(&art, &c));
    let path = scratch("cross_thread.state");
    let mut rows = Vec::new();
    with_threads(1, || {
        let mut s = Session::new(&art, c.clone()).unwrap();
        for _ in 0..2 {
            match s.step().unwrap() {
                StepOutcome::Stepped(st) => rows.push(sig(&st)),
                StepOutcome::Exhausted => panic!(),
            }
        }
        statefile::save_session(&path, "x", 0, &s.into_state())
            .unwrap();
    });
    let got_params = with_threads(4, || {
        let saved = statefile::load_session(&path).unwrap();
        let mut s = Session::resume(&art, saved.state).unwrap();
        while let StepOutcome::Stepped(st) = s.step().unwrap() {
            rows.push(sig(&st));
        }
        s.params()
    });
    std::fs::remove_file(&path).unwrap();
    assert_eq!(rows, want_rows, "cross-thread resume diverged");
    assert_params_eq(&got_params, &want_params, "cross-thread");
}

// ---------------------------------------------------------------------
// Checkpoint + artifact containers on the same format
// ---------------------------------------------------------------------

#[test]
fn checkpoint_on_statefile_roundtrips_and_detects_corruption() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let params = art.load_params().unwrap();
    let ck = Checkpoint::from_params(&art.manifest, &params);
    let dir = scratch("ckpt_dir");
    std::fs::create_dir_all(&dir).unwrap();
    ck.save(&dir).unwrap();
    // single statefile, no legacy two-file format
    assert!(dir.join("ckpt.state").is_file());
    assert!(!dir.join("ckpt.json").exists());
    assert!(!dir.join("ckpt.bin").exists());
    let ck2 = Checkpoint::load(&dir).unwrap();
    assert_eq!(ck2.tensors.len(), params.len());
    for (info, p) in art.manifest.params.iter().zip(&params) {
        let t = &ck2.tensors[&info.name];
        assert_eq!(t.shape, p.shape, "{}", info.name);
        assert_eq!(t.data, p.data, "{}", info.name);
    }
    // restore round-trips through a manifest-ordered vector
    let mut restored = art.load_params().unwrap();
    let n = ck2.restore(&art.manifest, &mut restored).unwrap();
    assert_eq!(n, params.len());
    // corruption in the tensor payload is a typed refusal
    let file = dir.join("ckpt.state");
    let mut buf = std::fs::read(&file).unwrap();
    let mid = buf.len() / 2;
    buf[mid] ^= 0x40;
    std::fs::write(&file, &buf).unwrap();
    let err = err_of(Checkpoint::load(&dir),
                     "loading a corrupt checkpoint");
    assert!(err.is::<StateError>(), "untyped error: {err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn artifact_statefile_reconstructs_the_same_model() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let path = scratch("artifact.state");
    statefile::save_artifact(&path, &art).unwrap();
    let art2 = statefile::load_artifact(&rt, &path).unwrap();
    assert_eq!(art2.manifest.preset, art.manifest.preset);
    assert_eq!(art2.manifest.params.len(), art.manifest.params.len());
    assert_eq!(art2.manifest.residual_bytes_total,
               art.manifest.residual_bytes_total);
    assert_eq!(art2.frozen_base().fingerprint(),
               art.frozen_base().fingerprint(),
               "frozen-base fingerprint changed across the container");
    assert_params_eq(&art2.load_params().unwrap(),
                     &art.load_params().unwrap(), "artifact params");
    // the reconstructed artifact trains bit-identically
    let c = cfg(2, 5);
    let (rows_a, params_a) = run_uninterrupted(&art, &c);
    let (rows_b, params_b) = run_uninterrupted(&art2, &c);
    assert_eq!(rows_a, rows_b, "reloaded artifact steps diverged");
    assert_params_eq(&params_a, &params_b, "reloaded artifact");
    // a session suspended on the original resumes on the reloaded
    // artifact — the fingerprint proves the bases are the same bytes
    let spath = scratch("artifact_session.state");
    let (rows_c, params_c) = {
        let mut s = Session::new(&art, c.clone()).unwrap();
        let mut rows = vec![match s.step().unwrap() {
            StepOutcome::Stepped(st) => sig(&st),
            StepOutcome::Exhausted => panic!(),
        }];
        statefile::save_session(&spath, "m", 0, &s.into_state())
            .unwrap();
        let saved = statefile::load_session(&spath).unwrap();
        let mut s2 = Session::resume(&art2, saved.state).unwrap();
        while let StepOutcome::Stepped(st) = s2.step().unwrap() {
            rows.push(sig(&st));
        }
        (rows, s2.params())
    };
    std::fs::remove_file(&spath).unwrap();
    assert_eq!(rows_c, rows_a, "cross-container resume diverged");
    assert_params_eq(&params_c, &params_a, "cross-container resume");
    std::fs::remove_file(&path).unwrap();
}
