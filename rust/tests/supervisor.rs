//! Fleet-supervision tests: deterministic fault injection through
//! every `util::faultpoint` site, the quarantine/retry policy table,
//! salvaging warm restarts over a partially corrupt spool, and the
//! strict-mode fail-fast escape hatch.
//!
//! The acceptance bar (ISSUE 7): inject each fault kind into one
//! tenant of a three-tenant fleet and the fleet still completes —
//! transient I/O faults are retried from the last good state,
//! terminal faults quarantine exactly the faulted tenant, and the
//! untouched tenants finish bit-identical to an undisturbed serial
//! run. Every test holds `faultpoint::exclusive()` so armed plans
//! never leak across `cargo test`'s in-binary parallelism.

use ambp::coordinator::engine::{predict, Engine};
use ambp::coordinator::{
    statefile, Session, StepOutcome, TrainCfg, Trainer,
};
use ambp::coordinator::supervisor::{self, FaultKind};
use ambp::runtime::{Artifact, Runtime, Tensor};
use ambp::util::faultpoint;
use ambp::util::json::Json;

fn rt() -> Runtime {
    Runtime::cpu().expect("native runtime")
}

fn cfg(steps: usize, seed: u64) -> TrainCfg {
    TrainCfg {
        steps,
        lr: 2e-3,
        log_every: 0,
        eval_batches: 2,
        seed,
        ..TrainCfg::default()
    }
}

/// Fresh per-test spool directory under the OS temp dir.
fn spool_dir(label: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ambp_supervisor_test_{}_{label}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// (loss bits, metric bits) per step.
type StepSig = (u32, u32);

/// Serial twin of one job through the classic `Trainer` path.
fn serial_run(art: &Artifact, c: &TrainCfg) -> (Vec<StepSig>, Vec<Tensor>) {
    let mut t = Trainer::new(art, c.clone()).unwrap();
    let rep = t.train().unwrap();
    let rows = rep
        .rows
        .iter()
        .map(|r| (r.loss.to_bits(), r.metric.to_bits()))
        .collect();
    (rows, t.params.clone())
}

fn row_sigs(rows: &[ambp::coordinator::metrics::StepRow]) -> Vec<StepSig> {
    rows.iter()
        .map(|r| (r.loss.to_bits(), r.metric.to_bits()))
        .collect()
}

fn assert_params_eq(a: &[Tensor], b: &[Tensor], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: arity");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.data, y.data, "{label}: param {i} differs");
    }
}

/// Save a fresh session's state after `pre_steps` steps, for spool
/// scan / resume tests.
fn save_state(art: &Artifact, path: &std::path::Path, name: &str,
              c: TrainCfg, pre_steps: usize) {
    let mut s = Session::new(art, c).unwrap();
    for _ in 0..pre_steps {
        assert!(matches!(s.step().unwrap(), StepOutcome::Stepped(_)));
    }
    statefile::save_session(path, name, 0, &s.into_state()).unwrap();
}

/// The tentpole acceptance grid: each fault kind at each in-step site,
/// injected into tenant s1 of a three-tenant fleet. The fleet always
/// completes; io is retried transparently, panic/nan quarantine s1;
/// s0/s2 are bit-identical to their undisturbed serial twins in every
/// cell.
#[test]
fn fault_grid_step_sites_isolate_one_tenant() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let cfgs = [cfg(4, 3), cfg(4, 9), cfg(4, 7)];
    let serial: Vec<_> = cfgs.iter().map(|c| serial_run(&art, c)).collect();

    for site in ["step.loss", "step.compute"] {
        for kind in ["panic", "io", "nan"] {
            faultpoint::clear();
            faultpoint::arm(&format!("s1/{site}:1:{kind}")).unwrap();
            let label = format!("{site}:{kind}");
            let spool = spool_dir(&label.replace([':', '.'], "_"));
            let mut engine = Engine::unbounded();
            engine.set_spool(spool.clone());
            for (i, c) in cfgs.iter().enumerate() {
                engine.admit(&format!("s{i}"), &art, c.clone()).unwrap();
            }
            let reports = engine.run().unwrap();
            assert_eq!(reports.len(), 3, "{label}: fleet size");

            // the undisturbed tenants always finish bit-identically
            for i in [0usize, 2] {
                let name = format!("s{i}");
                let r = reports
                    .iter()
                    .find(|r| r.name == name)
                    .unwrap_or_else(|| panic!("{label}: {name} missing"));
                let rep = r.train().unwrap_or_else(|| {
                    panic!("{label}: {name} should have completed")
                });
                assert_eq!(row_sigs(&rep.rows), serial[i].0,
                           "{label}: {name} rows diverged");
                assert_params_eq(&engine.session(&name).unwrap()
                                     .params(),
                                 &serial[i].1, &format!("{label}/{name}"));
            }

            let s1 = reports.iter().find(|r| r.name == "s1").unwrap();
            if kind == "io" {
                // transient: one retry from the last good state, then
                // a bit-identical finish — no quarantine anywhere
                let rep = s1.train().unwrap_or_else(|| {
                    panic!("{label}: io must be retried, not terminal")
                });
                assert_eq!(row_sigs(&rep.rows), serial[1].0,
                           "{label}: s1 rows diverged after retry");
                assert_params_eq(&engine.session("s1").unwrap()
                                     .params(),
                                 &serial[1].1, &format!("{label}/s1"));
                assert!(!supervisor::quarantine_state_path(&spool, "s1")
                            .exists(),
                        "{label}: spurious quarantine");
            } else {
                // terminal: s1 quarantined at the faulting step with
                // its last good state spooled + a diagnostic report
                let rec = s1.fault().unwrap_or_else(|| {
                    panic!("{label}: s1 should be quarantined")
                });
                let want = if kind == "panic" {
                    FaultKind::Panic
                } else {
                    FaultKind::Numeric
                };
                assert_eq!(rec.kind, want, "{label}: kind");
                assert_eq!(rec.step, 1, "{label}: faulting step");
                assert!(!engine.contains("s1"),
                        "{label}: quarantined tenant still resident");
                let qstate = supervisor::quarantine_state_path(&spool, "s1");
                assert_eq!(rec.state_path.as_deref(), Some(&*qstate));
                let saved = statefile::load_session(&qstate).unwrap();
                assert_eq!(saved.name, "s1");
                assert_eq!(saved.state.step, 1,
                           "{label}: quarantined state must be the \
                            last good step");
                let report = std::fs::read_to_string(
                    supervisor::quarantine_report_path(&spool, "s1"),
                )
                .unwrap();
                let j = Json::parse(&report).unwrap();
                assert_eq!(j.get("fault").unwrap().as_str().unwrap(),
                           want.as_str(), "{label}");
                assert_eq!(j.get("step").unwrap().as_usize().unwrap(), 1);
                assert_eq!(j.get("name").unwrap().as_str().unwrap(), "s1");
                assert_eq!(j.get("preset").unwrap().as_str().unwrap(),
                           "vitt_loraqv_regelu2_msln");
                if kind == "nan" {
                    let what = if site == "step.loss" {
                        "non-finite loss"
                    } else {
                        "non-finite gradient norm"
                    };
                    assert!(rec.detail.contains(what),
                            "{label}: detail {:?} should name the \
                             non-finite quantity", rec.detail);
                }
            }
            let _ = std::fs::remove_dir_all(&spool);
        }
    }
}

#[test]
fn io_retry_exhaustion_quarantines_with_retry_count() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    faultpoint::arm("s1/step.compute:0:io:*").unwrap();
    let spool = spool_dir("retry_exhaustion");
    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.set_max_retries(1);
    for (i, c) in [cfg(3, 3), cfg(3, 9), cfg(3, 7)].iter().enumerate() {
        engine.admit(&format!("s{i}"), &art, c.clone()).unwrap();
    }
    let reports = engine.run().unwrap();
    let rec = reports
        .iter()
        .find(|r| r.name == "s1")
        .unwrap()
        .fault()
        .expect("persistent io must exhaust retries and quarantine");
    assert_eq!(rec.kind, FaultKind::Io);
    assert_eq!(rec.retries, 1, "retries spent must equal max_retries");
    assert_eq!(rec.step, 0, "never completed a step");
    assert!(rec.detail.contains("injected fault: io"), "{}", rec.detail);
    // the quarantined state is loadable and sits at the last good step
    let saved = statefile::load_session(
        &supervisor::quarantine_state_path(&spool, "s1"),
    )
    .unwrap();
    assert_eq!(saved.state.step, 0);
    // the other two tenants completed normally
    for name in ["s0", "s2"] {
        assert!(reports.iter().find(|r| r.name == name).unwrap()
                    .train().is_some(), "{name} should complete");
    }
    let _ = std::fs::remove_dir_all(&spool);
}

#[test]
fn strict_mode_fail_fasts_on_injected_fault() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    faultpoint::arm("step.loss:0:io").unwrap();
    let spool = spool_dir("strict");
    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.set_strict(true);
    engine.admit("s0", &art, cfg(3, 3)).unwrap();
    engine.admit("s1", &art, cfg(3, 9)).unwrap();
    let err = format!("{:?}", engine.run().unwrap_err());
    assert!(err.contains("injected fault: io"), "{err}");
    // fail-fast means no supervision artifacts: no quarantine files
    let leftovers: Vec<_> = std::fs::read_dir(&spool)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| supervisor::is_quarantine(&e.path())
                    || e.path().extension().map(|x| x == "json")
                        .unwrap_or(false))
        .collect();
    assert!(leftovers.is_empty(), "{leftovers:?}");
    let _ = std::fs::remove_dir_all(&spool);
}

/// Salvaging warm-restart: `scan_spool` retries transient read faults,
/// quarantines files that stay unreadable (typed `StateError` naming
/// the damaged section in the report), and never re-lists a
/// quarantined file.
#[test]
fn scan_spool_salvages_around_corrupt_statefiles() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let spool = spool_dir("scan");
    for (i, name) in ["a", "b", "c"].iter().enumerate() {
        save_state(&art, &spool.join(format!("{name}.state")), name,
                   cfg(3, i as u64), 1);
    }

    // one transient read fault: retried, every file healthy
    faultpoint::arm("spool.read:0:io").unwrap();
    let scan = supervisor::scan_spool(&spool, 2, false).unwrap();
    assert_eq!(scan.healthy.len(), 3);
    assert!(scan.quarantined.is_empty());

    // persistent read faults exhaust the 3 attempts on the first file
    // (sorted order: a.state) and quarantine exactly it
    faultpoint::clear();
    faultpoint::arm("spool.read:0:io:3").unwrap();
    let scan = supervisor::scan_spool(&spool, 2, false).unwrap();
    assert_eq!(scan.healthy.len(), 2);
    assert_eq!(scan.quarantined.len(), 1);
    let rec = &scan.quarantined[0];
    assert_eq!(rec.name, "a");
    assert_eq!(rec.kind, FaultKind::Io);
    assert_eq!(rec.retries, 2);
    assert!(spool.join("a.state.quarantine").is_file());
    assert!(!spool.join("a.state").exists());

    // a flipped byte fails the checksum: a typed StateError quarantine
    // whose detail names the damaged section, under strict an Err
    faultpoint::clear();
    faultpoint::arm("spool.read:0:nan").unwrap();
    assert!(supervisor::scan_spool(&spool, 2, true).is_err(),
            "strict scan must fail on the corrupt file");
    faultpoint::clear();
    faultpoint::arm("spool.read:0:nan").unwrap();
    let scan = supervisor::scan_spool(&spool, 2, false).unwrap();
    assert_eq!(scan.healthy.len(), 1);
    assert_eq!(scan.quarantined.len(), 1);
    let rec = &scan.quarantined[0];
    assert_eq!(rec.name, "b");
    assert_eq!(rec.kind, FaultKind::State);
    assert!(rec.detail.contains("checksum"),
            "detail should carry the typed StateError: {}", rec.detail);
    let report = std::fs::read_to_string(
        supervisor::quarantine_report_path(&spool, "b"),
    )
    .unwrap();
    assert_eq!(Json::parse(&report).unwrap().get("fault").unwrap()
                   .as_str().unwrap(),
               "state");

    // a panic while parsing is caught and quarantined like the rest
    faultpoint::clear();
    faultpoint::arm("spool.read:0:panic").unwrap();
    let scan = supervisor::scan_spool(&spool, 2, false).unwrap();
    assert!(scan.healthy.is_empty());
    assert_eq!(scan.quarantined[0].name, "c");
    assert_eq!(scan.quarantined[0].kind, FaultKind::Panic);

    // quarantined files are invisible to a clean rescan
    faultpoint::clear();
    let scan = supervisor::scan_spool(&spool, 2, false).unwrap();
    assert!(scan.healthy.is_empty());
    assert!(scan.quarantined.is_empty());
    let _ = std::fs::remove_dir_all(&spool);
}

#[test]
fn suspend_write_fault_retries_then_restores_in_place() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let c = cfg(4, 3);
    let (serial_rows, serial_params) = serial_run(&art, &c);
    let spool = spool_dir("suspend_faults");
    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.admit("s0", &art, c.clone()).unwrap();

    // transient write fault: with_io_retry absorbs it, the suspend
    // lands on disk as usual
    faultpoint::arm("spool.write:0:io").unwrap();
    let h = engine.suspend("s0").unwrap();
    assert!(h.path.is_file());
    assert_eq!(engine.suspended_names(), vec!["s0".to_string()]);
    faultpoint::clear();
    engine.resume_file(&art, &h.path).unwrap();

    // persistent write panic: the suspend fails, but the session is
    // rebuilt in place — no work lost, admission unchanged
    faultpoint::arm("spool.write:0:panic:*").unwrap();
    let err = format!("{:?}", engine.suspend("s0").unwrap_err());
    assert!(err.contains("restored in place"), "{err}");
    assert!(engine.contains("s0"),
            "failed suspend must not lose the session");
    assert_eq!(engine.len(), 1);
    assert!(engine.suspended_names().is_empty());
    faultpoint::clear();

    // after all that turbulence the run is still bit-identical
    let reports = engine.run().unwrap();
    let rep = reports[0].train().expect("completed");
    assert_eq!(row_sigs(&rep.rows), serial_rows,
               "rows diverged after suspend faults");
    assert_params_eq(&engine.session("s0").unwrap().params(),
                     &serial_params, "s0");
    let _ = std::fs::remove_dir_all(&spool);
}

#[test]
fn corrupt_suspend_image_quarantines_at_resume_time() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let spool = spool_dir("corrupt_image");
    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.admit("s0", &art, cfg(4, 3)).unwrap();
    // the write "succeeds" but one byte of the image is flipped — the
    // damage is only detectable by the reader's checksums
    faultpoint::arm("spool.write:0:nan").unwrap();
    let h = engine.suspend("s0").unwrap();
    assert!(h.path.is_file());
    faultpoint::clear();
    // the resume path detects the corruption, quarantines the file,
    // and the fleet run still returns Ok
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 1);
    let rec = reports[0].fault().expect("corrupt image must quarantine");
    assert_eq!(rec.kind, FaultKind::State);
    assert!(spool.join("s0.state.quarantine").is_file());
    assert!(!spool.join("s0.state").exists(),
            "the corrupt original must be renamed away");
    assert!(rec.detail.contains("checksum"), "{}", rec.detail);
    let _ = std::fs::remove_dir_all(&spool);
}

/// Satellite: a failed eviction during preemptive admission degrades to
/// a rejected admission — no panic, victims stay resident (replaces the
/// old `.expect("victim still resident")`).
#[test]
fn failed_eviction_degrades_to_rejected_admission() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let cfgs = [cfg(3, 3), cfg(3, 9), cfg(3, 7)];
    let serial: Vec<_> = cfgs.iter().map(|c| serial_run(&art, c)).collect();
    let adm = predict(&art, &cfgs[0]);
    let base = art.frozen_base().nbytes();
    let budget = base + 2 * adm.marginal() + adm.marginal() / 2;
    let spool = spool_dir("failed_eviction");
    let mut engine = Engine::new(budget);
    engine.set_spool(spool.clone());
    engine.enable_preempt().unwrap();
    engine.admit_prio("s0", &art, cfgs[0].clone(), 0).unwrap();
    engine.admit_prio("s1", &art, cfgs[1].clone(), 5).unwrap();
    // every spool write panics: the eviction of s0 cannot land
    faultpoint::arm("spool.write:0:panic:*").unwrap();
    let err = engine
        .admit_prio("hi", &art, cfgs[2].clone(), 10)
        .unwrap_err()
        .to_string();
    assert!(err.contains("budget"), "{err}");
    assert!(engine.contains("s0"), "victim must stay resident");
    assert!(engine.contains("s1"));
    assert!(!engine.contains("hi"));
    assert!(engine.suspended_names().is_empty());
    faultpoint::clear();
    // the survivors still finish bit-identically
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 2);
    for (i, name) in ["s0", "s1"].iter().enumerate() {
        let r = reports.iter().find(|r| r.name == *name).unwrap();
        assert_eq!(row_sigs(&r.train().unwrap().rows), serial[i].0,
                   "{name}");
        assert_params_eq(&engine.session(name).unwrap().params(),
                         &serial[i].1, name);
    }
    let _ = std::fs::remove_dir_all(&spool);
}

/// Satellite: the scheduling-deadlock error names the spooled sessions,
/// leaves their statefiles intact, and the same spool dir re-serves
/// under a bigger budget.
#[test]
fn scheduling_deadlock_leaves_spool_reservable() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let c = cfg(3, 3);
    let adm = predict(&art, &c);
    let base = art.frozen_base().nbytes();
    let done_cost = adm.opt_bytes + adm.trainable_bytes
        + adm.flat_copy_bytes;
    // fits one live session; even a *finished* resident session plus a
    // second marginal overflows — the spooled job can never come back
    let budget = base + adm.marginal() + done_cost / 2;
    let spool = spool_dir("deadlock");
    let stuck = spool.join("s1.state");
    save_state(&art, &stuck, "s1", cfg(3, 9), 1);
    let mut engine = Engine::new(budget);
    engine.set_spool(spool.clone());
    engine.admit("s0", &art, c).unwrap();
    assert!(!engine.spool_in(&art, &stuck).unwrap(),
            "s1 must queue, not resume");
    let err = loop {
        match engine.round() {
            Ok(_) => {}
            Err(e) => break e.to_string(),
        }
    };
    assert!(err.contains("scheduling deadlock"), "{err}");
    assert!(err.contains("s1"), "deadlock error must name the spooled \
                                 session: {err}");
    // the statefile is intact — not consumed, not quarantined
    assert!(stuck.is_file());
    let h = statefile::peek_session(&stuck).unwrap();
    assert_eq!(h.name, "s1");
    assert_eq!(h.steps_done, 1);
    // a bigger budget finishes the stranded work from the same spool
    let mut engine2 = Engine::unbounded();
    engine2.set_spool(spool.clone());
    assert!(engine2.spool_in(&art, &stuck).unwrap());
    let reports = engine2.run().unwrap();
    let rep = reports
        .iter()
        .find(|r| r.name == "s1")
        .unwrap()
        .train()
        .expect("completed");
    assert_eq!(rep.steps, 3);
    let _ = std::fs::remove_dir_all(&spool);
}

/// Satellite: a resumed run's `--metrics` JSONL sink keeps the full
/// step history — restored rows are re-written, replayed steps appear
/// exactly once, and the file matches an uninterrupted twin's.
#[test]
fn resumed_metrics_sink_keeps_full_history() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let dir = spool_dir("metrics_history");
    let twin_path = dir.join("twin.jsonl");
    let resumed_path = dir.join("resumed.jsonl");
    let mk = |p: &std::path::Path| TrainCfg {
        metrics_jsonl: Some(p.to_path_buf()),
        ..cfg(4, 3)
    };
    // uninterrupted twin
    let mut twin = Session::new(&art, mk(&twin_path)).unwrap();
    while let StepOutcome::Stepped(_) = twin.step().unwrap() {}
    twin.finish().unwrap();
    // interrupted at step 2, saved, resumed, finished
    let state = dir.join("s.state");
    save_state(&art, &state, "s0", mk(&resumed_path), 2);
    let saved = statefile::load_session(&state).unwrap();
    let mut resumed = Session::resume(&art, saved.state).unwrap();
    while let StepOutcome::Stepped(_) = resumed.step().unwrap() {}
    resumed.finish().unwrap();
    let read_steps = |p: &std::path::Path| -> Vec<(usize, f64)> {
        std::fs::read_to_string(p)
            .unwrap()
            .lines()
            .map(|l| {
                let j = Json::parse(l).unwrap();
                (j.get("step").unwrap().as_usize().unwrap(),
                 j.get("loss").unwrap().as_f64().unwrap())
            })
            .collect()
    };
    let twin_rows = read_steps(&twin_path);
    let resumed_rows = read_steps(&resumed_path);
    assert_eq!(twin_rows.len(), 4);
    assert_eq!(
        resumed_rows, twin_rows,
        "a resumed sink must carry the full history, not a truncated \
         tail"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: admission rejects a duplicate session name outright —
/// resident or suspended — instead of spawning a shadowing tenant.
#[test]
fn duplicate_session_names_are_rejected() {
    let _g = faultpoint::exclusive();
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let spool = spool_dir("dup_names");
    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.admit("s0", &art, cfg(3, 3)).unwrap();
    let err = engine.admit("s0", &art, cfg(3, 9)).unwrap_err().to_string();
    assert!(err.contains("already resident or suspended"), "{err}");
    // the name stays taken while the session sits in the spool
    engine.suspend("s0").unwrap();
    let err = engine.admit("s0", &art, cfg(3, 9)).unwrap_err().to_string();
    assert!(err.contains("already resident or suspended"), "{err}");
    let _ = std::fs::remove_dir_all(&spool);
}
