#!/usr/bin/env python3
"""Regenerate statefile_v1.state, the byte-exact pin of statefile
FORMAT_VERSION 1.

This is an independent reimplementation of `Writer::finish` in
`src/coordinator/statefile.rs` — the test
`format_is_pinned_by_fixture` in `tests/statefile.rs` compares the
Rust writer's output byte-for-byte against the file this script
produces (the `#[ignore]`d test `regenerate_fixture` writes the same
bytes from the Rust side). If the two ever disagree, either the format
changed (bump FORMAT_VERSION, update both writers, regenerate) or one
writer has a bug.
"""

import os
import struct

MAGIC = b"AMBPSTF\0"
FORMAT_VERSION = 1
HEADER_LEN = 32
INDEX_ENTRY_LEN = 32

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3


def fnv1a64(data, h=FNV_OFFSET):
    for b in data:
        h ^= b
        h = (h * FNV_PRIME) & 0xFFFFFFFFFFFFFFFF
    return h


def align64(x):
    return (x + 63) & ~63


def finish(sections):
    """Mirror of Writer::finish: header, index, string table, 64-byte
    aligned payloads, per-payload FNV-1a 64 checksums, whole-file
    checksum over bytes[0..24] ++ bytes[32..len]."""
    n = len(sections)
    strtab_off = HEADER_LEN + n * INDEX_ENTRY_LEN
    strtab = b""
    name_pos = []
    for name, _ in sections:
        name_pos.append((strtab_off + len(strtab), len(name)))
        strtab += name.encode()
    cur = strtab_off + len(strtab)
    payload_pos = []
    for _, data in sections:
        off = align64(cur)
        payload_pos.append((off, len(data)))
        cur = off + len(data)
    file_len = cur

    buf = bytearray(file_len)
    buf[0:8] = MAGIC
    buf[8:12] = struct.pack("<I", FORMAT_VERSION)
    buf[12:16] = struct.pack("<I", n)
    buf[16:24] = struct.pack("<Q", file_len)
    # buf[24:32] = file checksum, written last
    for i, (name, data) in enumerate(sections):
        noff, nlen = name_pos[i]
        off, ln = payload_pos[i]
        e = HEADER_LEN + i * INDEX_ENTRY_LEN
        buf[e : e + 4] = struct.pack("<I", noff)
        buf[e + 4 : e + 8] = struct.pack("<I", nlen)
        buf[e + 8 : e + 16] = struct.pack("<Q", off)
        buf[e + 16 : e + 24] = struct.pack("<Q", ln)
        buf[e + 24 : e + 32] = struct.pack("<Q", fnv1a64(data))
        buf[off : off + ln] = data
    buf[strtab_off : strtab_off + len(strtab)] = strtab
    checksum = fnv1a64(bytes(buf[HEADER_LEN:]), fnv1a64(bytes(buf[0:24])))
    buf[24:32] = struct.pack("<Q", checksum)
    return bytes(buf)


def main():
    # Keep in sync with fixture_writer() in tests/statefile.rs.
    sections = [
        ("fixture.meta", b"ambp statefile fixture v1\n"),
        ("fixture.data", struct.pack("<4f", 1.0, 2.0, -3.5, 4.25)),
    ]
    out = finish(sections)
    path = os.path.join(os.path.dirname(__file__), "statefile_v1.state")
    with open(path, "wb") as f:
        f.write(out)
    print(f"wrote {len(out)} bytes to {path}")


if __name__ == "__main__":
    main()
