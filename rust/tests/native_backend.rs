//! Native-backend end-to-end tests: synthesized artifacts, gradient
//! correctness against finite differences, the measured-memory ordering
//! of the paper, and the TrainCfg-driven smoke train step of the
//! acceptance criteria. No files, no network, no XLA.

use ambp::coordinator::{TrainCfg, Trainer};
use ambp::runtime::native::spec::sample_batch;
use ambp::runtime::native::{
    Act, Arch, Model, NativeExec, NetCfg, Norm, Tuning,
};
use ambp::runtime::{Artifact, Runtime, Tensor};

fn rt() -> Runtime {
    Runtime::cpu().expect("native runtime")
}

fn tiny_cfg(arch: Arch, tuning: Tuning, act: Act, norm: Norm) -> NetCfg {
    NetCfg {
        arch,
        dim: 16,
        depth: 2,
        n_heads: 2,
        n_tokens: 6,
        batch: 2,
        n_classes: 3,
        vocab: 11,
        mlp_ratio: 2.0,
        lora_rank: 3,
        patch_dim: 8,
        tuning,
        act,
        norm,
        swiglu: false,
        ckpt: false,
        mesa: false,
    }
}

/// Directional-derivative gradcheck at the default 2e-2 tolerance.
fn gradcheck(cfg: NetCfg, label: &str) {
    gradcheck_tol(cfg, label, 2e-2)
}

/// Directional-derivative gradcheck: perturb all trainable params along
/// the (normalized) analytic gradient direction; the finite-difference
/// slope must equal the gradient norm within `tol` (relative).
fn gradcheck_tol(cfg: NetCfg, label: &str, tol: f64) {
    let model = Model::build(cfg.clone()).expect("build");
    let mut params = model.init_params(7);
    let (x, y) = sample_batch(&cfg, 0, 3);
    let (loss0, _metric, res) =
        model.forward(&params, &x, &y).expect("fwd");
    assert!(loss0.is_finite(), "{label}: non-finite loss");
    let grads = model.backward(&params, &res, &x, &y).expect("bwd");
    let tidx: Vec<usize> = model
        .infos
        .iter()
        .enumerate()
        .filter(|(_, p)| p.trainable)
        .map(|(i, _)| i)
        .collect();
    assert_eq!(grads.len(), tidx.len(), "{label}: grad arity");
    let gnorm = {
        let s: f64 = grads
            .iter()
            .flat_map(|g| g.as_f32().iter())
            .map(|v| (*v as f64).powi(2))
            .sum();
        s.sqrt()
    };
    assert!(gnorm.is_finite() && gnorm > 1e-6, "{label}: gnorm {gnorm}");
    // ε·‖g‖ ≈ 2e-3 keeps the loss perturbation well above f32 forward
    // noise while the ε² truncation term stays ~1e-3 relative (verified
    // against the f64 reference implementation).
    let eps = 2e-3 / gnorm;
    let loss_at = |params: &[Tensor]| -> f64 {
        model.forward(params, &x, &y).expect("fwd").0 as f64
    };
    let mut shifted = |sign: f64| -> f64 {
        for (g, &pi) in grads.iter().zip(&tidx) {
            let gv = g.as_f32();
            let pv = params[pi].as_f32_mut();
            for (p, &gg) in pv.iter_mut().zip(gv) {
                *p += (sign * eps * gg as f64 / gnorm) as f32;
            }
        }
        let l = loss_at(&params);
        for (g, &pi) in grads.iter().zip(&tidx) {
            let gv = g.as_f32();
            let pv = params[pi].as_f32_mut();
            for (p, &gg) in pv.iter_mut().zip(gv) {
                *p -= (sign * eps * gg as f64 / gnorm) as f32;
            }
        }
        l
    };
    let lp = shifted(1.0);
    let lm = shifted(-1.0);
    let fd = (lp - lm) / (2.0 * eps);
    let rel = (fd - gnorm).abs() / gnorm;
    assert!(
        rel < tol,
        "{label}: directional fd {fd} vs |g| {gnorm} (rel {rel}, \
         tol {tol})"
    );
}

#[test]
fn gradcheck_vit_full_gelu_ln() {
    gradcheck(tiny_cfg(Arch::Vit, Tuning::Full, Act::Gelu, Norm::Ln),
              "vit full gelu ln");
}

#[test]
fn gradcheck_vit_loraqv_gelu_msln() {
    gradcheck(tiny_cfg(Arch::Vit, Tuning::LoraQv, Act::Gelu, Norm::MsLn),
              "vit loraqv gelu msln");
}

#[test]
fn gradcheck_vit_lorafa_gelu_ln() {
    gradcheck(tiny_cfg(Arch::Vit, Tuning::LoraFaQv, Act::Gelu, Norm::Ln),
              "vit lorafa gelu ln");
}

#[test]
fn gradcheck_llama_full_silu_rms() {
    gradcheck(tiny_cfg(Arch::Llama, Tuning::Full, Act::Silu, Norm::Rms),
              "llama full silu rms");
}

#[test]
fn gradcheck_llama_loraall_silu_msrms() {
    gradcheck(
        tiny_cfg(Arch::Llama, Tuning::LoraAll, Act::Silu, Norm::MsRms),
        "llama loraall silu msrms",
    );
}

#[test]
fn gradcheck_roberta_loraall_gelu_ln() {
    gradcheck(
        tiny_cfg(Arch::Roberta, Tuning::LoraAll, Act::Gelu, Norm::Ln),
        "roberta loraall gelu ln",
    );
}

#[test]
fn gradcheck_vit_loraqv_relu_ln() {
    // ReLU's 1-bit-coded backward is exact, so the finite-difference
    // identity holds like for the full-precision saves
    gradcheck(tiny_cfg(Arch::Vit, Tuning::LoraQv, Act::Relu, Norm::Ln),
              "vit loraqv relu ln");
}

#[test]
fn gradcheck_llama_swiglu_rope_full() {
    let mut cfg =
        tiny_cfg(Arch::Llama, Tuning::Full, Act::Silu, Norm::Rms);
    cfg.swiglu = true;
    gradcheck(cfg, "llama full silu rms swiglu+rope");
}

#[test]
fn gradcheck_llama_swiglu_rope_loraall_msrms() {
    let mut cfg =
        tiny_cfg(Arch::Llama, Tuning::LoraAll, Act::Silu, Norm::MsRms);
    cfg.swiglu = true;
    gradcheck(cfg, "llama loraall silu msrms swiglu+rope");
}

#[test]
fn gradcheck_ckpt_recompute_path() {
    // checkpointing must be gradient-invisible: store-input/recompute
    // reproduces the exact same backward
    let mut cfg = tiny_cfg(Arch::Vit, Tuning::Full, Act::Gelu, Norm::Ln);
    cfg.ckpt = true;
    gradcheck(cfg, "vit full gelu ln ckpt");
    let mut cfg =
        tiny_cfg(Arch::Llama, Tuning::LoraAll, Act::Silu, Norm::MsRms);
    cfg.swiglu = true;
    cfg.ckpt = true;
    gradcheck(cfg, "llama loraall swiglu ckpt");
}

#[test]
fn ckpt_grads_match_unckpt_bitwise() {
    // same params, same batch: the checkpointed model's gradients must
    // be BIT-identical to the plain model's (recompute determinism)
    let cfg = tiny_cfg(Arch::Vit, Tuning::LoraQv, Act::ReGelu2,
                       Norm::MsLn);
    let mut ck = cfg.clone();
    ck.ckpt = true;
    let plain = Model::build(cfg.clone()).unwrap();
    let ckpt = Model::build(ck).unwrap();
    let params = plain.init_params(3);
    let (x, y) = sample_batch(&cfg, 0, 1);
    let (l1, _, r1) = plain.forward(&params, &x, &y).unwrap();
    let (l2, _, r2) = ckpt.forward(&params, &x, &y).unwrap();
    assert_eq!(l1, l2, "ckpt changed the forward loss");
    assert!(r2.len() < r1.len(), "ckpt must store fewer residuals");
    let g1 = plain.backward(&params, &r1, &x, &y).unwrap();
    let g2 = ckpt.backward(&params, &r2, &x, &y).unwrap();
    assert_eq!(g1.len(), g2.len());
    for (a, b) in g1.iter().zip(&g2) {
        assert_eq!(a.data, b.data, "ckpt gradients deviate");
    }
}

#[test]
fn gradcheck_mesa_quantized_saves() {
    // Under `_mesa` the backward runs from int8-dequantized x̂ /
    // pre-activations, so the analytic gradient deviates from the true
    // gradient by the quantization error. Analytic bound: each
    // dequantized element is off by ≤ scale/2 = amax/254, i.e. ≤ κ/254
    // of the group's rms with κ = amax/rms (≲ 8 for normalized saves)
    // → ≲ 3% relative per quantized residual; the depth-2 models here
    // hold ~5 quantized residuals, RSS ≈ 7%. The directional check
    // adds its 2e-2 finite-difference budget and up to a ~2× projection
    // factor, so 1.2e-1 covers the bound while still failing on any
    // structural bwd bug (those miss at O(1), not O(1/254)).
    let mut cfg = tiny_cfg(Arch::Vit, Tuning::Full, Act::Gelu, Norm::Ln);
    cfg.mesa = true;
    gradcheck_tol(cfg, "vit full gelu ln mesa", 1.2e-1);
    let mut cfg =
        tiny_cfg(Arch::Llama, Tuning::LoraAll, Act::Silu, Norm::MsRms);
    cfg.mesa = true;
    gradcheck_tol(cfg, "llama loraall silu msrms mesa", 1.2e-1);
}

#[test]
fn gradcheck_mesa_composes_with_swiglu_and_ckpt() {
    // the quantized inner tape must survive the recompute path and the
    // gated MLP — same analytic tolerance as above
    let mut cfg =
        tiny_cfg(Arch::Llama, Tuning::Full, Act::Silu, Norm::Rms);
    cfg.swiglu = true;
    cfg.mesa = true;
    cfg.ckpt = true;
    gradcheck_tol(cfg, "llama full silu rms swiglu ckpt mesa", 1.2e-1);
}

#[test]
fn approx_bwd_runs_and_is_finite() {
    // ReGELU2/ReSiLU2: bwd is *approximate* (2-bit codes), so no
    // finite-difference identity — check structure and finiteness.
    for (cfg, label) in [
        (tiny_cfg(Arch::Vit, Tuning::LoraQv, Act::ReGelu2, Norm::MsLn),
         "vit regelu2"),
        (tiny_cfg(Arch::Llama, Tuning::LoraAll, Act::ReSilu2,
                  Norm::MsRms),
         "llama resilu2"),
    ] {
        let model = Model::build(cfg.clone()).expect("build");
        let params = model.init_params(7);
        let (x, y) = sample_batch(&cfg, 0, 3);
        let (loss, _m, res) =
            model.forward(&params, &x, &y).expect("fwd");
        assert!(loss.is_finite(), "{label}");
        let grads = model.backward(&params, &res, &x, &y).expect("bwd");
        for g in &grads {
            assert!(g.as_f32().iter().all(|v| v.is_finite()), "{label}");
        }
    }
}

#[test]
fn smoke_train_step_acceptance() {
    // The acceptance criterion: a TrainCfg-driven train on the native
    // backend produces finite loss and nonzero peak_activation_bytes.
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let mut t = Trainer::new(
        &art,
        TrainCfg {
            steps: 3,
            lr: 1e-3,
            log_every: 0,
            eval_batches: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let rep = t.train().unwrap();
    assert_eq!(rep.rows.len(), 3);
    assert!(rep.final_loss.is_finite());
    assert!(rep.eval_loss.is_finite());
    assert!(rep.peak_activation_bytes > 0);
    assert_eq!(
        rep.rows[0].activation_bytes,
        art.manifest.residual_bytes_total
    );
    assert!(rep.peak_activation_bytes
                >= art.manifest.residual_bytes_total);
    assert!(!rep.by_kind.is_empty());
}

#[test]
fn measured_memory_ckpt_lt_ours_lt_baseline() {
    // the Figure 1 ordering, *measured* at the residual ABI on the
    // native backend (ckpt was previously memmodel-only)
    use ambp::coordinator::memory::MemoryTracker;
    let rt = rt();
    let measured = |preset: &str| -> (u64, u64) {
        let art = Artifact::synth(&rt, preset).unwrap();
        let params = art.load_params().unwrap();
        let cfg =
            ambp::runtime::native::spec::parse_preset(preset).unwrap();
        let (x, y) = sample_batch(&cfg, 2, 7);
        let out = art.run_fwd(&params, &x, &y).unwrap();
        let mut tracker = MemoryTracker::new();
        tracker.observe_residuals(&art.manifest, &out.residuals);
        let ckpt_bytes = tracker.bytes_of_kind("ckpt_input");
        art.recycle(out.residuals);
        (tracker.last_residual_bytes, ckpt_bytes)
    };
    let (base, _) = measured("vitt_loraqv_gelu_ln");
    let (ours, _) = measured("vitt_loraqv_regelu2_msln");
    let (ckpt, ckpt_inputs) = measured("vitt_loraqv_gelu_ln_ckpt");
    assert!(ckpt < ours, "ckpt {ckpt} !< ours {ours}");
    assert!(ours < base, "ours {ours} !< base {base}");
    // and the checkpointed set is dominated by the block inputs
    assert!(ckpt_inputs * 2 > ckpt,
            "ckpt_input {ckpt_inputs} not dominant in {ckpt}");
}

#[test]
fn measured_memory_ours_lt_mesa_lt_baseline() {
    // the Table 1/7 ranking, *measured* at the residual ABI: int8
    // nonlinear saves (mesa) beat fp32, and 2-bit codes + shared x̂
    // (ours) beat int8 — previously only the analytical model could
    // state this ordering
    use ambp::coordinator::memory::MemoryTracker;
    let rt = rt();
    let measured = |preset: &str| -> u64 {
        let art = Artifact::synth(&rt, preset).unwrap();
        let params = art.load_params().unwrap();
        let cfg =
            ambp::runtime::native::spec::parse_preset(preset).unwrap();
        let (x, y) = sample_batch(&cfg, 2, 7);
        let out = art.run_fwd(&params, &x, &y).unwrap();
        let mut tracker = MemoryTracker::new();
        tracker.observe_residuals(&art.manifest, &out.residuals);
        art.recycle(out.residuals);
        tracker.last_residual_bytes
    };
    let base = measured("vitt_loraqv_gelu_ln");
    let mesa = measured("vitt_loraqv_gelu_ln_mesa");
    let ours = measured("vitt_loraqv_regelu2_msln");
    assert!(mesa < base, "mesa {mesa} !< base {base}");
    assert!(ours < mesa, "ours {ours} !< mesa {mesa}");
}

#[test]
fn mesa_acceptance_preset_end_to_end() {
    // the acceptance combination: our 2-bit act + memory-sharing norm,
    // with the remaining nonlinear saves int8-quantized — synthesized
    // natively, manifest int8 slots, measured bytes exactly equal to
    // the derived manifest
    use ambp::runtime::DType;
    let rt = rt();
    let art =
        Artifact::synth(&rt, "llama_loraqv_regelu2_msln_mesa").unwrap();
    let m = &art.manifest;
    assert!(m.mesa);
    // all norms are memory-sharing here, so every quantized slot is a
    // shared x̂ (the 2-bit act codes stay sub-byte, never int8)
    let q8: Vec<_> = m
        .residuals
        .iter()
        .filter(|r| r.dtype == DType::I8)
        .collect();
    assert_eq!(q8.len(), 2 * m.depth + 1);
    for r in &q8 {
        assert_eq!(r.kind, "norm_shared");
        let g = *r.shape.last().unwrap() - 4;
        assert_eq!(g, m.dim);
        assert!((r.bits_per_elem - (8.0 + 32.0 / g as f64)).abs()
                    < 1e-9);
    }
    // a fresh (non-dry-run) batch: measured residual bytes must match
    // the schema-derived manifest byte-for-byte
    let params = art.load_params().unwrap();
    let cfg = ambp::runtime::native::spec::parse_preset(
        "llama_loraqv_regelu2_msln_mesa").unwrap();
    let (x, y) = sample_batch(&cfg, 9, 4);
    let out = art.run_fwd(&params, &x, &y).unwrap();
    let measured: u64 =
        out.residuals.iter().map(|t| t.nbytes() as u64).sum();
    assert_eq!(measured, m.residual_bytes_total);
    let grads = art.run_bwd(&params, &out.residuals, &x, &y).unwrap();
    assert_eq!(grads.len(), m.trainable_indices().len());
    for g in &grads {
        assert!(g.as_f32().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn ckpt_training_works_end_to_end() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_gelu_ln_ckpt").unwrap();
    let mut t = Trainer::new(
        &art,
        TrainCfg {
            steps: 3,
            lr: 1e-3,
            log_every: 0,
            eval_batches: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let rep = t.train().unwrap();
    assert!(rep.final_loss.is_finite());
    assert_eq!(
        rep.rows[0].activation_bytes,
        art.manifest.residual_bytes_total
    );
    assert!(rep.by_kind.iter().any(|(k, _)| k == "ckpt_input"));
}

#[test]
fn residuals_match_manifest_abi() {
    let rt = rt();
    for preset in ["vitt_loraqv_gelu_ln", "vitt_loraqv_regelu2_msln",
                   "vitt_loraqv_relu_ln", "vitt_loraqv_gelu_ln_ckpt",
                   "llama_loraall_resilu2_msrms",
                   "llama_loraall_silu_rms_swiglu",
                   "llama_loraall_resilu2_msrms_swiglu_ckpt",
                   "roberta_loraall_gelu_ln"] {
        let art = Artifact::synth(&rt, preset).unwrap();
        let params = art.load_params().unwrap();
        let (x, y) = {
            // fresh batch ≠ the dry-run batch: shapes must still match
            let cfg = ambp::runtime::native::spec::parse_preset(preset)
                .unwrap();
            sample_batch(&cfg, 5, 9)
        };
        let out = art.run_fwd(&params, &x, &y).unwrap();
        assert_eq!(out.residuals.len(), art.manifest.residuals.len());
        let mut total = 0u64;
        for (t, info) in
            out.residuals.iter().zip(&art.manifest.residuals)
        {
            assert_eq!(t.shape, info.shape, "{preset}: {}", info.name);
            assert_eq!(t.dtype, info.dtype, "{preset}: {}", info.name);
            assert_eq!(t.nbytes() as u64, info.bytes);
            total += info.bytes;
        }
        assert_eq!(total, art.manifest.residual_bytes_total);
        let grads =
            art.run_bwd(&params, &out.residuals, &x, &y).unwrap();
        assert_eq!(grads.len(),
                   art.manifest.trainable_indices().len());
    }
}

#[test]
fn selfcheck_matches_fresh_forward() {
    // The synth manifest's selfcheck came from a dry run with the same
    // deterministic batch — an independent fwd/bwd must reproduce it.
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let params = art.load_params().unwrap();
    let cfg =
        ambp::runtime::native::spec::parse_preset("vitt_loraqv_gelu_ln")
            .unwrap();
    let (x, y) = sample_batch(&cfg, 0, 0);
    let out = art.run_fwd(&params, &x, &y).unwrap();
    let sc = &art.manifest.selfcheck;
    assert!((out.loss as f64 - sc.loss).abs() < 1e-5 * sc.loss.max(1.0));
    assert!((out.metric as f64 - sc.metric).abs() < 1e-6);
    let grads = art.run_bwd(&params, &out.residuals, &x, &y).unwrap();
    assert_eq!(grads.len(), sc.grad_l2.len());
    for (g, want) in grads.iter().zip(&sc.grad_l2) {
        assert!((g.l2() - want).abs() < 1e-4 * want.max(1.0));
    }
}

#[test]
fn training_reduces_loss_on_native_backend() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let mut t = Trainer::new(
        &art,
        TrainCfg {
            steps: 20,
            lr: 1e-2,
            log_every: 0,
            eval_batches: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let rep = t.train().unwrap();
    let first: f32 =
        rep.rows[..3].iter().map(|r| r.loss).sum::<f32>() / 3.0;
    let last: f32 = rep.rows[rep.rows.len() - 3..]
        .iter()
        .map(|r| r.loss)
        .sum::<f32>()
        / 3.0;
    assert!(
        last < first,
        "loss did not decrease: {first:.4} → {last:.4}"
    );
}

#[test]
fn frozen_params_stay_frozen() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let before = art.load_params().unwrap();
    let mut t = Trainer::new(
        &art,
        TrainCfg {
            steps: 2,
            lr: 1e-2,
            log_every: 0,
            eval_batches: 1,
            ..Default::default()
        },
    )
    .unwrap();
    t.train().unwrap();
    let tidx = art.manifest.trainable_indices();
    let mut trained_moved = false;
    for (i, (b, a)) in before.iter().zip(&t.params).enumerate() {
        let same = b.as_f32() == a.as_f32();
        if tidx.contains(&i) {
            trained_moved |= !same;
        } else {
            assert!(same, "frozen param {} changed",
                    art.manifest.params[i].name);
        }
    }
    assert!(trained_moved, "no trainable parameter moved");
}

#[test]
fn lora_starts_at_base_model() {
    // lora_b = 0 at init ⇒ the LoRA variant's forward equals the same
    // preset with tuning=frozen (identical base init)
    let rt = rt();
    let lora = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let frozen = Artifact::synth(&rt, "vitt_frozen_gelu_ln").unwrap();
    let cfg = ambp::runtime::native::spec::parse_preset(
        "vitt_loraqv_gelu_ln").unwrap();
    let (x, y) = sample_batch(&cfg, 1, 4);
    let lo = lora
        .run_fwd(&lora.load_params().unwrap(), &x, &y)
        .unwrap();
    let fo = frozen
        .run_fwd(&frozen.load_params().unwrap(), &x, &y)
        .unwrap();
    assert!((lo.loss - fo.loss).abs() < 1e-6,
            "lora init deviates from base: {} vs {}", lo.loss, fo.loss);
}

#[test]
fn executor_direct_use() {
    // The Backend/Executor split is public API: drive a model without
    // the Artifact facade.
    let cfg = tiny_cfg(Arch::Vit, Tuning::Frozen, Act::Gelu, Norm::Ln);
    let model = Model::build(cfg.clone()).unwrap();
    let params = model.init_params(1);
    let exec = NativeExec::new(model);
    let (x, y) = sample_batch(&cfg, 0, 0);
    use ambp::runtime::Executor;
    let out = exec.run_fwd(&params, &x, &y).unwrap();
    assert!(out.loss.is_finite());
    let grads = exec.run_bwd(&params, &out.residuals, &x, &y).unwrap();
    // frozen vit: only the head trains (W + b)
    assert_eq!(grads.len(), 2);
}

/// One full train-step gradient set (fwd + bwd) for a preset-sized
/// model, used by the thread-count determinism test.
fn full_step_grads(model: &Model, params: &[Tensor], x: &Tensor,
                   y: &Tensor) -> Vec<Tensor> {
    let (_loss, _metric, res) =
        model.forward(params, x, y).expect("fwd");
    model.backward(params, &res, x, y).expect("bwd")
}

#[test]
fn train_step_grads_bit_identical_across_thread_counts() {
    // The pool's determinism contract, end to end: the full train-step
    // gradient set must be BIT-identical whether the kernels partition
    // for 1 worker or for 8 (`with_threads` forces the same logical
    // partition `AMBP_THREADS=1` / `AMBP_THREADS=8` would produce — the
    // env var itself is process-global, so the override is how one
    // process can compare both).
    use ambp::runtime::native::pool::with_threads;
    // preset-sized dims (rows=512, hidden=256) so the partition really
    // differs between 1 and 8 logical threads
    let cfg = ambp::runtime::native::spec::parse_preset(
        "vitt_full_gelu_ln").unwrap();
    let model = Model::build(cfg.clone()).unwrap();
    let params = model.init_params(11);
    let (x, y) = sample_batch(&cfg, 0, 2);
    let g1 = with_threads(1, || full_step_grads(&model, &params, &x, &y));
    let g8 = with_threads(8, || full_step_grads(&model, &params, &x, &y));
    assert_eq!(g1.len(), g8.len());
    for (a, b) in g1.iter().zip(&g8) {
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.data, b.data,
                   "gradient bits differ between thread counts");
    }
}

#[test]
fn swiglu_grads_bit_identical_across_thread_counts() {
    // the determinism contract must survive the new layer dispatch,
    // RoPE rotation, and the gate-multiply kernels
    use ambp::runtime::native::pool::with_threads;
    let cfg = ambp::runtime::native::spec::parse_preset(
        "llama_loraall_silu_rms_swiglu").unwrap();
    let model = Model::build(cfg.clone()).unwrap();
    let params = model.init_params(17);
    let (x, y) = sample_batch(&cfg, 0, 2);
    let g1 = with_threads(1, || full_step_grads(&model, &params, &x, &y));
    let g8 = with_threads(8, || full_step_grads(&model, &params, &x, &y));
    for (a, b) in g1.iter().zip(&g8) {
        assert_eq!(a.data, b.data,
                   "swiglu gradient bits differ between thread counts");
    }
}

#[test]
fn arena_reuse_steady_state() {
    // The step-scoped arena acceptance criterion: after warmup, a train
    // step takes every activation/residual buffer from the free list —
    // the miss counter must not move, and hits must keep accruing.
    use ambp::runtime::Executor;
    let cfg = tiny_cfg(Arch::Vit, Tuning::LoraQv, Act::ReGelu2,
                       Norm::MsLn);
    let model = Model::build(cfg.clone()).unwrap();
    let params = model.init_params(5);
    let exec = NativeExec::new(model);
    let (x, y) = sample_batch(&cfg, 0, 3);
    let step = |exec: &NativeExec| {
        let out = exec.run_fwd(&params, &x, &y).unwrap();
        let grads =
            exec.run_bwd(&params, &out.residuals, &x, &y).unwrap();
        // the trainer returns both residuals AND gradient tensors
        exec.recycle(out.residuals);
        exec.recycle(grads);
    };
    for _ in 0..2 {
        step(&exec); // warmup: populate the free lists
    }
    let warm = exec.arena_stats();
    assert!(warm.misses > 0, "warmup must have allocated something");
    for _ in 0..3 {
        step(&exec);
    }
    let steady = exec.arena_stats();
    assert_eq!(
        steady.misses, warm.misses,
        "steady-state step allocated fresh activation buffers"
    );
    assert!(steady.hits > warm.hits,
            "steady-state step did not reuse arena buffers");
}

#[test]
fn arena_reuse_steady_state_under_ckpt() {
    // the recompute path must also draw its regenerated residuals from
    // the free lists once warm — checkpointing trades time, not allocs
    use ambp::runtime::Executor;
    let mut cfg = tiny_cfg(Arch::Llama, Tuning::LoraAll, Act::ReSilu2,
                           Norm::MsRms);
    cfg.swiglu = true;
    cfg.ckpt = true;
    let model = Model::build(cfg.clone()).unwrap();
    let params = model.init_params(5);
    let exec = NativeExec::new(model);
    let (x, y) = sample_batch(&cfg, 0, 3);
    let step = |exec: &NativeExec| {
        let out = exec.run_fwd(&params, &x, &y).unwrap();
        let grads =
            exec.run_bwd(&params, &out.residuals, &x, &y).unwrap();
        exec.recycle(out.residuals);
        exec.recycle(grads);
    };
    for _ in 0..2 {
        step(&exec);
    }
    let warm = exec.arena_stats();
    for _ in 0..3 {
        step(&exec);
    }
    let steady = exec.arena_stats();
    assert_eq!(steady.misses, warm.misses,
               "ckpt recompute allocated fresh buffers in steady state");
    assert!(steady.hits > warm.hits);
}

#[test]
fn arena_reuse_steady_state_under_mesa() {
    // the quantize-on-push / dequantize-on-pop codec draws its packed
    // payloads and f32 scratch from the arena and must release every
    // dequantized view — a forgotten ResF32::release shows up here as
    // steady-state misses
    use ambp::runtime::Executor;
    let mut cfg = tiny_cfg(Arch::Vit, Tuning::LoraQv, Act::Gelu,
                           Norm::MsLn);
    cfg.mesa = true;
    let model = Model::build(cfg.clone()).unwrap();
    let params = model.init_params(5);
    let exec = NativeExec::new(model);
    let (x, y) = sample_batch(&cfg, 0, 3);
    let step = |exec: &NativeExec| {
        let out = exec.run_fwd(&params, &x, &y).unwrap();
        let grads =
            exec.run_bwd(&params, &out.residuals, &x, &y).unwrap();
        exec.recycle(out.residuals);
        exec.recycle(grads);
    };
    for _ in 0..2 {
        step(&exec);
    }
    let warm = exec.arena_stats();
    for _ in 0..3 {
        step(&exec);
    }
    let steady = exec.arena_stats();
    assert_eq!(steady.misses, warm.misses,
               "mesa codec allocated fresh buffers in steady state");
    assert!(steady.hits > warm.hits);
}

#[test]
fn mesa_grads_bit_identical_across_thread_counts() {
    // the pool determinism contract must survive the int8 group
    // quantize/dequantize kernels (groups never straddle partitions)
    use ambp::runtime::native::pool::with_threads;
    let cfg = ambp::runtime::native::spec::parse_preset(
        "vitt_loraqv_gelu_msln_mesa").unwrap();
    let model = Model::build(cfg.clone()).unwrap();
    let params = model.init_params(13);
    let (x, y) = sample_batch(&cfg, 0, 2);
    let g1 = with_threads(1, || full_step_grads(&model, &params, &x, &y));
    let g8 = with_threads(8, || full_step_grads(&model, &params, &x, &y));
    assert_eq!(g1.len(), g8.len());
    for (a, b) in g1.iter().zip(&g8) {
        assert_eq!(a.data, b.data,
                   "mesa gradient bits differ between thread counts");
    }
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_backend_requires_feature() {
    let err = match Runtime::from_name("pjrt") {
        Ok(_) => panic!("pjrt must be unavailable without the feature"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("pjrt"), "{err}");
    assert!(Runtime::from_name("nope").is_err());
}
