//! The tape-schema grid tests: for **every** `{arch} × {tuning} ×
//! {act} × {norm} [× swiglu][× ckpt][× mesa]` combination, the residual
//! list an actual forward pass emits must match the tape schema the
//! composition derived at build time — byte for byte — and the backward
//! pass must consume the tape exactly (the reader errors on any
//! leftover or out-of-order slot). This generalizes the old hand-picked
//! `residuals_match_manifest_abi` to the full grid, which is what pins
//! "the ABI is derived from the composition" as an invariant rather
//! than a convention.
//!
//! The mesa plane additionally pins the quantization *saving*: for
//! every combination, the `_mesa` tape must be strictly smaller than
//! its fp32 twin (int8 codes + per-group scale < 4 bytes/elem).
//!
//! Also cross-checks the analytical memmodel (Tape mode) against the
//! derived schema for the SwiGLU LLaMA block — including the mesa axis,
//! where the memmodel's `rows·(cols+4)` int8 accounting must agree with
//! the native int8 slots byte-for-byte.

use ambp::memmodel::ops::{self, MemCfg, Mode};
use ambp::runtime::native::spec::{parse_preset, sample_batch,
                                  schema_residuals};
use ambp::runtime::native::{Act, Arch, Model, NetCfg, Norm, Tuning};

const ARCHS: [Arch; 3] = [Arch::Vit, Arch::Llama, Arch::Roberta];
const TUNINGS: [Tuning; 6] = [
    Tuning::Full,
    Tuning::Frozen,
    Tuning::LoraQv,
    Tuning::LoraAll,
    Tuning::LoraFaQv,
    Tuning::LoraFaAll,
];
const ACTS: [Act; 5] =
    [Act::Gelu, Act::ReGelu2, Act::Silu, Act::ReSilu2, Act::Relu];
const NORMS: [Norm; 4] = [Norm::Ln, Norm::MsLn, Norm::Rms, Norm::MsRms];

fn tiny(arch: Arch, tuning: Tuning, act: Act, norm: Norm, swiglu: bool,
        ckpt: bool, mesa: bool) -> NetCfg {
    NetCfg {
        arch,
        dim: 16,
        depth: 2,
        n_heads: 2,
        n_tokens: 6,
        batch: 2,
        n_classes: 3,
        vocab: 11,
        mlp_ratio: 2.0,
        lora_rank: 3,
        patch_dim: 8,
        tuning,
        act,
        norm,
        swiglu,
        ckpt,
        mesa,
    }
}

/// One fwd (+ optional bwd), asserting the emitted residuals match the
/// derived schema byte-for-byte — and, with `bwd`, that the backward
/// consumes the tape exactly (the reader errors on any leftover or
/// out-of-order slot). Returns the tape's total stored bytes.
fn assert_tape_matches_schema(cfg: &NetCfg, label: &str,
                              bwd: bool) -> u64 {
    let model = Model::build(cfg.clone())
        .unwrap_or_else(|e| panic!("{label}: build: {e}"));
    let infos = schema_residuals(&model);
    let params = model.init_params(1);
    let (x, y) = sample_batch(cfg, 1, 2);
    let (loss, _metric, res) = model
        .forward(&params, &x, &y)
        .unwrap_or_else(|e| panic!("{label}: fwd: {e}"));
    assert!(loss.is_finite(), "{label}: non-finite loss");
    assert_eq!(res.len(), infos.len(), "{label}: residual arity");
    let mut total = 0u64;
    for (t, info) in res.iter().zip(&infos) {
        assert_eq!(t.shape, info.shape, "{label}: {}", info.name);
        assert_eq!(t.dtype, info.dtype, "{label}: {}", info.name);
        assert_eq!(t.nbytes() as u64, info.bytes, "{label}: {}",
                   info.name);
        total += info.bytes;
    }
    assert!(total > 0, "{label}: empty tape");
    if !bwd {
        return total;
    }
    let grads = model
        .backward(&params, &res, &x, &y)
        .unwrap_or_else(|e| panic!("{label}: bwd: {e}"));
    let n_train =
        model.infos.iter().filter(|p| p.trainable).count();
    assert_eq!(grads.len(), n_train, "{label}: grad arity");
    total
}

#[test]
fn tape_matches_schema_full_tiny_grid() {
    let mut combos = 0usize;
    for arch in ARCHS {
        for tuning in TUNINGS {
            for act in ACTS {
                for norm in NORMS {
                    for ckpt in [false, true] {
                        let swiglus: &[bool] = if arch == Arch::Llama {
                            &[false, true]
                        } else {
                            &[false]
                        };
                        for &swiglu in swiglus {
                            let label = format!(
                                "{arch:?}/{tuning:?}/{act:?}/{norm:?}\
                                 /swiglu={swiglu}/ckpt={ckpt}"
                            );
                            let base = tiny(arch, tuning, act, norm,
                                            swiglu, ckpt, false);
                            let fp32_bytes = assert_tape_matches_schema(
                                &base, &label, true);
                            let mesa = tiny(arch, tuning, act, norm,
                                            swiglu, ckpt, true);
                            let mesa_bytes = assert_tape_matches_schema(
                                &mesa, &format!("{label}/mesa"), true);
                            // int8 saves must shrink the tape on EVERY
                            // combination (each has at least its norms)
                            assert!(
                                mesa_bytes < fp32_bytes,
                                "{label}: mesa {mesa_bytes} !< fp32 \
                                 {fp32_bytes}"
                            );
                            combos += 2;
                        }
                    }
                }
            }
        }
    }
    // 3 archs × 6 tunings × 5 acts × 4 norms × 2 ckpt, plus the llama
    // swiglu plane — each doubled by the mesa axis
    assert_eq!(combos, (3 * 6 * 5 * 4 * 2 + 6 * 5 * 4 * 2) * 2);
}

#[test]
fn preset_grid_residuals_match_manifest() {
    // every parseable preset string: the actual fwd output must match
    // the schema-derived manifest residual section byte-for-byte, and
    // the _mesa twin of every preset must store strictly fewer bytes
    let models = ["vitt", "llama", "roberta"];
    let tunings =
        ["full", "frozen", "loraqv", "loraall", "lorafaqv", "lorafaall"];
    let acts = ["gelu", "regelu2", "silu", "resilu2", "relu"];
    let norms = ["ln", "msln", "rms", "msrms"];
    let mut checked = 0usize;
    for m in models {
        for t in tunings {
            for a in acts {
                for n in norms {
                    let mut variants =
                        vec![format!("{m}_{t}_{a}_{n}"),
                             format!("{m}_{t}_{a}_{n}_ckpt")];
                    if m == "llama" {
                        variants.push(format!("{m}_{t}_{a}_{n}_swiglu"));
                        variants.push(
                            format!("{m}_{t}_{a}_{n}_swiglu_ckpt"));
                    }
                    for preset in variants {
                        let cfg = parse_preset(&preset)
                            .unwrap_or_else(|e| {
                                panic!("{preset}: parse: {e}")
                            });
                        // fwd-only at preset dims: the tiny grid above
                        // already runs bwd for every combination
                        let fp32_bytes = assert_tape_matches_schema(
                            &cfg, &preset, false);
                        let mesa_preset = format!("{preset}_mesa");
                        let mesa_cfg = parse_preset(&mesa_preset)
                            .unwrap_or_else(|e| {
                                panic!("{mesa_preset}: parse: {e}")
                            });
                        let mesa_bytes = assert_tape_matches_schema(
                            &mesa_cfg, &mesa_preset, false);
                        assert!(
                            mesa_bytes < fp32_bytes,
                            "{mesa_preset}: {mesa_bytes} !< \
                             {fp32_bytes}"
                        );
                        checked += 2;
                    }
                }
            }
        }
    }
    assert_eq!(checked, (3 * 6 * 5 * 4 * 2 + 6 * 5 * 4 * 2) * 2);
}

#[test]
fn memmodel_tape_mode_matches_swiglu_block_bytes() {
    // the analytical model's llama block (always gated) vs the native
    // tape, per block0, at identical dims — Tape mode must agree
    // exactly, int8 mesa accounting included
    for (preset, tuning, act, norm, mesa) in [
        ("llama_loraall_silu_rms_swiglu", ops::Tuning::LoraAll,
         ops::ActKind::Silu, ops::NormKind::Rms, false),
        ("llama_loraall_resilu2_msrms_swiglu", ops::Tuning::LoraAll,
         ops::ActKind::ReSilu2, ops::NormKind::MsRms, false),
        ("llama_loraall_silu_rms_swiglu_mesa", ops::Tuning::LoraAll,
         ops::ActKind::Silu, ops::NormKind::Rms, true),
        // the acceptance combination: our 2-bit act + shared norm,
        // with the remaining nonlinear saves int8-quantized
        ("llama_loraqv_regelu2_msln_swiglu_mesa", ops::Tuning::LoraQv,
         ops::ActKind::ReGelu2, ops::NormKind::MsLn, true),
    ] {
        let cfg = parse_preset(preset).unwrap();
        let model = Model::build(cfg.clone()).unwrap();
        let native_block0: u64 = schema_residuals(&model)
            .iter()
            .filter(|r| r.module.starts_with("block0."))
            .map(|r| r.bytes)
            .sum();
        let mem = MemCfg {
            arch: ops::Arch::Llama,
            dim: cfg.dim,
            depth: cfg.depth,
            n_heads: cfg.n_heads,
            mlp_ratio: cfg.mlp_ratio,
            n_tokens: cfg.n_tokens,
            patch_dim: 0,
            n_classes: 0,
            vocab: cfg.vocab,
            lora_rank: cfg.lora_rank,
            batch: cfg.batch,
            tuning,
            act,
            norm,
            mode: Mode::Tape,
            ckpt: false,
            mesa,
        };
        let analytic: u64 = ambp::memmodel::ops::block_entries(&mem, 0)
            .iter()
            .map(|e| e.bytes)
            .sum();
        assert_eq!(native_block0, analytic,
                   "{preset}: native {native_block0} vs memmodel \
                    {analytic}");
    }
}
