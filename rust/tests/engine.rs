//! Multi-tenant engine tests: session isolation and determinism (K
//! interleaved sessions on one shared frozen base are bit-identical to
//! the same K jobs run serially), parameter-byte accounting (the base
//! is stored once — adding a session grows resident bytes by only its
//! trainable slice), budgeted admission control (an over-budget job is
//! rejected with the memmodel's predicted bytes in the error), and the
//! fleet-capacity ordering: `*_regelu2_msln` / `*_mesa` presets admit
//! strictly more sessions than baseline under the same byte budget,
//! cross-checked against measured residual bytes.

use std::sync::Arc;

use ambp::coordinator::engine::{fleet_capacity, predict, Engine, JobSpec};
use ambp::coordinator::{Session, StepOutcome, TrainCfg, Trainer};
use ambp::runtime::native::pool::with_threads;
use ambp::runtime::native::spec::sample_batch;
use ambp::runtime::{Artifact, Runtime, Tensor};

fn rt() -> Runtime {
    Runtime::cpu().expect("native runtime")
}

fn cfg(steps: usize, seed: u64) -> TrainCfg {
    TrainCfg {
        steps,
        lr: 2e-3,
        log_every: 0,
        eval_batches: 2,
        seed,
        ..TrainCfg::default()
    }
}

fn assert_params_eq(a: &[Tensor], b: &[Tensor], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: arity");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.data, y.data, "{label}: param {i} differs");
    }
}

#[test]
fn split_abi_matches_flat_abi_bitwise() {
    // the tentpole's zero-copy split view must be numerically invisible:
    // same loss, residual stream, and gradients as the flat path
    let rt = rt();
    for preset in ["vitt_loraqv_regelu2_msln",
                   "llama_loraall_silu_rms_swiglu",
                   "vitt_loraqv_gelu_ln_mesa",
                   "vitt_loraqv_gelu_ln_ckpt"] {
        let art = Artifact::synth(&rt, preset).unwrap();
        let full = art.load_params().unwrap();
        let pcfg =
            ambp::runtime::native::spec::parse_preset(preset).unwrap();
        let (x, y) = sample_batch(&pcfg, 3, 5);
        let flat = art.run_fwd(&full, &x, &y).unwrap();
        let base = art.frozen_base();
        let trainable = art.trainable_init();
        let split = art.run_fwd_split(&base, &trainable, &x, &y).unwrap();
        assert_eq!(flat.loss.to_bits(), split.loss.to_bits(), "{preset}");
        assert_eq!(flat.residuals.len(), split.residuals.len());
        for (a, b) in flat.residuals.iter().zip(&split.residuals) {
            assert_eq!(a.data, b.data, "{preset}: residual differs");
        }
        let gf = art.run_bwd(&full, &flat.residuals, &x, &y).unwrap();
        let gs = art
            .run_bwd_split(&base, &trainable, &split.residuals, &x, &y)
            .unwrap();
        assert_params_eq(&gf, &gs, preset);
    }
}

/// (loss bits, metric bits, activation bytes) of one step.
type StepSig = (u32, u32, u64);
/// Per-step signatures + final params of one serial job.
type RunSig = (Vec<StepSig>, Vec<Tensor>);

/// Run K jobs serially through the classic `Trainer` path; return
/// (per-step rows, final params) per job.
fn serial_runs(art: &Artifact, cfgs: &[TrainCfg]) -> Vec<RunSig> {
    cfgs.iter()
        .map(|c| {
            let mut t = Trainer::new(art, c.clone()).unwrap();
            let rep = t.train().unwrap();
            let rows = rep
                .rows
                .iter()
                .map(|r| {
                    (r.loss.to_bits(), r.metric.to_bits(),
                     r.activation_bytes)
                })
                .collect();
            (rows, t.params.clone())
        })
        .collect()
}

fn interleaved_matches_serial() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let cfgs = [cfg(4, 3), cfg(6, 9)]; // uneven budgets: s0 drains first
    let serial = serial_runs(&art, &cfgs);

    let mut engine = Engine::unbounded();
    for (i, c) in cfgs.iter().enumerate() {
        engine.admit(&format!("s{i}"), &art, c.clone()).unwrap();
    }
    // the two sessions really share one frozen base object
    assert!(Arc::ptr_eq(engine.session("s0").unwrap().base(),
                        engine.session("s1").unwrap().base()));
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 2);
    for (i, (r, (rows, params))) in
        reports.iter().zip(&serial).enumerate()
    {
        let rep = r.train().expect("completed");
        assert_eq!(rep.steps, cfgs[i].steps, "s{i}: steps");
        let got: Vec<StepSig> = rep
            .rows
            .iter()
            .map(|row| {
                (row.loss.to_bits(), row.metric.to_bits(),
                 row.activation_bytes)
            })
            .collect();
        assert_eq!(&got, rows, "s{i}: per-step rows diverged");
        assert_params_eq(&engine.session(&format!("s{i}")).unwrap()
                             .params(),
                         params, &format!("s{i}"));
    }
}

#[test]
fn interleaved_sessions_bit_identical_to_serial_1_thread() {
    with_threads(1, interleaved_matches_serial);
}

#[test]
fn interleaved_sessions_bit_identical_to_serial_4_threads() {
    with_threads(4, interleaved_matches_serial);
}

#[test]
fn mixed_preset_fleet_is_isolated() {
    // two bases (vit + llama) in one engine: sessions must still match
    // their serial twins bit-for-bit
    let rt = rt();
    let vit = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let llama = Artifact::synth(&rt, "llama_loraall_silu_rms").unwrap();
    let vc = cfg(3, 1);
    let lc = cfg(3, 2);
    let vit_serial = serial_runs(&vit, std::slice::from_ref(&vc));
    let llama_serial = serial_runs(&llama, std::slice::from_ref(&lc));

    let mut engine = Engine::unbounded();
    engine.admit("vit", &vit, vc).unwrap();
    engine.admit("llama", &llama, lc).unwrap();
    let reports = engine.run().unwrap();
    assert_eq!(reports[0].preset, "vitt_loraqv_gelu_ln");
    assert_params_eq(&engine.session("vit").unwrap().params(),
                     &vit_serial[0].1, "vit");
    assert_params_eq(&engine.session("llama").unwrap().params(),
                     &llama_serial[0].1, "llama");
    // and the per-step losses match too
    let got: Vec<u32> = reports[1]
        .train()
        .expect("completed")
        .rows
        .iter()
        .map(|r| r.loss.to_bits())
        .collect();
    let want: Vec<u32> =
        llama_serial[0].0.iter().map(|r| r.0).collect();
    assert_eq!(got, want, "llama losses diverged");
}

#[test]
fn shared_base_stored_once_param_accounting() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let full_bytes: u64 = art
        .load_params()
        .unwrap()
        .iter()
        .map(|t| t.nbytes() as u64)
        .sum();
    let mut engine = Engine::unbounded();
    engine.admit("a", &art, cfg(1, 0)).unwrap();
    // one session: resident = base (once) + its trainables = all params
    let r1 = engine.resident_param_bytes();
    assert_eq!(r1, full_bytes);
    engine.admit("b", &art, cfg(1, 1)).unwrap();
    let r2 = engine.resident_param_bytes();
    // the second session costs only its trainable slice — the frozen
    // base did not duplicate
    let trainable = engine.session("b").unwrap().trainable_bytes();
    assert_eq!(r2 - r1, trainable);
    assert!(trainable < full_bytes / 10,
            "lora trainables should be a small fraction: {trainable} \
             of {full_bytes}");
    engine.admit("c", &art, cfg(1, 2)).unwrap();
    assert_eq!(engine.resident_param_bytes() - r2,
               engine.session("c").unwrap().trainable_bytes());
}

#[test]
fn over_budget_job_rejected_with_predicted_bytes() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let c = cfg(2, 0);
    let adm = predict(&art, &c);
    assert!(adm.tape_bytes >= art.manifest.residual_bytes_total);
    let base = art.frozen_base().nbytes();
    // budget fits exactly one session, not two
    let budget = base + adm.marginal() + adm.marginal() / 2;
    let mut engine = Engine::new(budget);
    engine.admit("a", &art, c.clone()).unwrap();
    let err = engine.admit("b", &art, c).unwrap_err().to_string();
    assert!(err.contains(&adm.marginal().to_string()),
            "error must carry the predicted marginal bytes: {err}");
    assert!(err.contains(&adm.tape_bytes.to_string()),
            "error must carry the predicted tape bytes: {err}");
    assert!(err.contains("budget"), "{err}");
    // the admitted session still runs to completion
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 1);
    assert!(reports[0].train().expect("completed").final_loss
                .is_finite());
}

#[test]
fn fleet_capacity_ours_and_mesa_beat_baseline() {
    let rt = rt();
    let probe_cfg = TrainCfg {
        steps: 1,
        log_every: 0,
        eval_batches: 0,
        ..TrainCfg::default()
    };
    let baseline = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let m0 = predict(&baseline, &probe_cfg).marginal();
    let b0 = baseline.frozen_base().nbytes();
    // a budget that fits exactly 10 baseline sessions
    let budget = b0 + 10 * m0;
    let presets: Vec<String> = ["vitt_loraqv_gelu_ln",
                                "vitt_loraqv_gelu_ln_mesa",
                                "vitt_loraqv_regelu2_msln"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let rows =
        fleet_capacity(&rt, budget, &presets, &probe_cfg, true).unwrap();
    assert_eq!(rows[0].admitted, 10, "baseline sessions-per-budget");
    // the acceptance ordering: both paper variants admit strictly more
    // tenants than baseline under the same budget (the margin is large:
    // their tapes are ~55% of baseline's)
    assert!(rows[1].admitted > rows[0].admitted,
            "mesa {} !> baseline {}", rows[1].admitted,
            rows[0].admitted);
    assert!(rows[2].admitted > rows[0].admitted,
            "ours {} !> baseline {}", rows[2].admitted,
            rows[0].admitted);
    // ours vs mesa: the byte margin is real but thin (~1.5% of the
    // marginal at vitt dims), so assert it at byte granularity where it
    // is deterministic, and only weakly on the floor-divided counts
    assert!(rows[2].admission.marginal() < rows[1].admission.marginal(),
            "ours marginal {} !< mesa marginal {}",
            rows[2].admission.marginal(), rows[1].admission.marginal());
    assert!(rows[1].admission.marginal() < rows[0].admission.marginal(),
            "mesa marginal {} !< baseline marginal {}",
            rows[1].admission.marginal(), rows[0].admission.marginal());
    assert!(rows[2].admitted >= rows[1].admitted,
            "ours {} < mesa {}", rows[2].admitted, rows[1].admitted);
    // cross-check against measured peaks: the probe step's measured
    // residual bytes equal the schema-derived manifest total, and the
    // prediction admission gates on is never below what was measured
    for (row, preset) in rows.iter().zip(&presets) {
        let art = Artifact::synth(&rt, preset).unwrap();
        let measured = row.measured_tape.expect("probe ran");
        assert_eq!(measured, art.manifest.residual_bytes_total,
                   "{preset}: measured vs manifest");
        assert!(row.admission.tape_bytes >= measured,
                "{preset}: predicted tape below measured");
    }
}

#[test]
fn session_eval_is_non_destructive_and_reuses_producer() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    // twin A steps straight through; twin B evaluates between steps
    let mut a = Session::new(&art, cfg(3, 7)).unwrap();
    let mut b = Session::new(&art, cfg(3, 7)).unwrap();
    let mut a_losses = Vec::new();
    for _ in 0..3 {
        match a.step().unwrap() {
            StepOutcome::Stepped(s) => a_losses.push(s.loss.to_bits()),
            StepOutcome::Exhausted => panic!("budget too small"),
        }
    }
    let mut b_losses = Vec::new();
    let e1 = b.evaluate(50_000, 2).unwrap();
    for _ in 0..3 {
        match b.step().unwrap() {
            StepOutcome::Stepped(s) => b_losses.push(s.loss.to_bits()),
            StepOutcome::Exhausted => panic!("budget too small"),
        }
        assert_eq!(b.evaluate(50_000, 2).unwrap().0.to_bits(),
                   b.evaluate(50_000, 2).unwrap().0.to_bits(),
                   "eval must be deterministic");
    }
    assert_eq!(a_losses, b_losses,
               "mid-run evaluation perturbed the training stream");
    assert_eq!(b.steps_done(), 3);
    let e2 = b.evaluate(50_000, 2).unwrap();
    // same held-out indices, trained params → loss moved, eval did not
    // advance the step counter
    assert_eq!(b.steps_done(), 3);
    assert!(e1.0.is_finite() && e2.0.is_finite());
    // exhausted sessions say so
    assert!(matches!(b.step().unwrap(), StepOutcome::Exhausted));
}

#[test]
fn job_spec_grammar() {
    let base = cfg(20, 5);
    let j = JobSpec::parse("vitt_loraqv_gelu_ln", &base, 2).unwrap();
    assert_eq!(j.preset, "vitt_loraqv_gelu_ln");
    assert_eq!(j.cfg.steps, 20);
    assert_eq!(j.cfg.seed, 7); // base seed + job index
    let j = JobSpec::parse("llama_loraall_silu_rms:12", &base, 0)
        .unwrap();
    assert_eq!(j.cfg.steps, 12);
    assert_eq!(j.cfg.seed, 5);
    let j = JobSpec::parse("p_full_gelu_ln:3:99", &base, 1).unwrap();
    assert_eq!(j.cfg.steps, 3);
    assert_eq!(j.cfg.seed, 99);
    assert_eq!(j.priority, 0);
    // 4th field: scheduling priority (may be negative)
    let j = JobSpec::parse("p_full_gelu_ln:3:99:-2", &base, 1).unwrap();
    assert_eq!(j.cfg.steps, 3);
    assert_eq!(j.cfg.seed, 99);
    assert_eq!(j.priority, -2);
    assert!(JobSpec::parse("p:3:9:1:extra", &base, 0).is_err());
    assert!(JobSpec::parse("p:3:9:extra", &base, 0).is_err());
    assert!(JobSpec::parse("p:notanumber", &base, 0).is_err());
}

#[test]
fn trainer_facade_unchanged_after_session_refactor() {
    // the classic single-job path still trains, reduces loss, tracks
    // memory, and leaves updated params on the trainer
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let before = art.load_params().unwrap();
    let mut t = Trainer::new(&art, cfg(8, 0)).unwrap();
    let rep = t.train().unwrap();
    assert_eq!(rep.rows.len(), 8);
    assert_eq!(rep.rows[0].activation_bytes,
               art.manifest.residual_bytes_total);
    assert!(rep.peak_activation_bytes
                >= art.manifest.residual_bytes_total);
    let tidx = art.manifest.trainable_indices();
    let mut moved = false;
    for (i, (a, b)) in before.iter().zip(&t.params).enumerate() {
        if tidx.contains(&i) {
            moved |= a.data != b.data;
        } else {
            assert_eq!(a.data, b.data, "frozen param {i} changed");
        }
    }
    assert!(moved, "no trainable parameter moved");
}

/// Fresh per-test spool directory under the OS temp dir.
fn spool_dir(label: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "ambp_engine_test_{}_{label}", std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn preemption_admits_what_strict_rejects_and_stays_bit_identical() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let cfgs = [cfg(4, 3), cfg(6, 9), cfg(5, 7)];
    let serial = serial_runs(&art, &cfgs);
    let adm = predict(&art, &cfgs[0]);
    let base = art.frozen_base().nbytes();
    // fits two live sessions, not three
    let budget = base + 2 * adm.marginal() + adm.marginal() / 2;

    // strict admission provably rejects the third job at this budget
    {
        let mut strict = Engine::new(budget);
        strict.admit("s0", &art, cfgs[0].clone()).unwrap();
        strict.admit("s1", &art, cfgs[1].clone()).unwrap();
        let err = strict
            .admit("hi", &art, cfgs[2].clone())
            .unwrap_err()
            .to_string();
        assert!(err.contains("budget"), "{err}");
    }

    // the preemptive engine instead evicts the lowest-priority tenant
    let spool = spool_dir("preempt");
    let mut engine = Engine::new(budget);
    engine.set_spool(spool.clone());
    engine.enable_preempt().unwrap();
    engine.admit_prio("s0", &art, cfgs[0].clone(), 0).unwrap();
    engine.admit_prio("s1", &art, cfgs[1].clone(), 5).unwrap();
    engine.admit_prio("hi", &art, cfgs[2].clone(), 10).unwrap();
    // exactly one eviction: s0 (priority 0 < 5 < 10), spooled to disk
    assert_eq!(engine.suspended_names(), vec!["s0".to_string()]);
    assert!(!engine.contains("s0"));
    assert!(engine.contains("s1"));
    assert!(engine.contains("hi"));
    assert!(spool.join("s0.state").is_file());
    assert!(engine.predicted_bytes() <= budget);

    // the rounds drain s1 + hi, then pull s0 back from the spool and
    // finish it; nothing stays suspended and the spool file is consumed
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 3);
    assert!(engine.suspended_names().is_empty());
    assert!(!spool.join("s0.state").exists(),
            "resume must consume the spool file");

    // every job — the preempted one included — matches its serial twin
    // bit-for-bit, preemption round trip and all
    for (i, name) in ["s0", "s1", "hi"].iter().enumerate() {
        let r = reports
            .iter()
            .find(|r| r.name == *name)
            .unwrap_or_else(|| panic!("{name}: no report"));
        let rep = r.train().expect("completed");
        assert_eq!(rep.steps, cfgs[i].steps, "{name}: steps");
        let got: Vec<StepSig> = rep
            .rows
            .iter()
            .map(|row| {
                (row.loss.to_bits(), row.metric.to_bits(),
                 row.activation_bytes)
            })
            .collect();
        assert_eq!(got, serial[i].0, "{name}: per-step rows diverged");
        assert_params_eq(&engine.session(name).unwrap().params(),
                         &serial[i].1, name);
    }
    let _ = std::fs::remove_dir_all(&spool);
}

#[test]
fn suspend_resume_keeps_the_base_stored_once() {
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let spool = spool_dir("stored_once");
    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.admit("s0", &art, cfg(4, 3)).unwrap();
    engine.admit("s1", &art, cfg(4, 9)).unwrap();
    let base = engine.base_bytes();
    assert_eq!(base, art.frozen_base().nbytes());
    let resident = engine.resident_param_bytes();
    let victim_bytes =
        engine.session("s0").unwrap().resident_param_bytes();
    assert!(victim_bytes > 0);
    let h = engine.suspend("s0").unwrap();
    assert_eq!(h.name, "s0");
    assert_eq!(h.path, spool.join("s0.state"));
    assert_eq!(h.steps_done, 0);
    assert_eq!(h.steps_total, 4);
    // suspending sheds exactly the tenant's private parameter bytes;
    // the shared frozen base stays resident (stored once) for s1
    assert_eq!(engine.base_bytes(), base);
    assert_eq!(engine.resident_param_bytes(), resident - victim_bytes);
    assert_eq!(engine.suspended_names(), vec!["s0".to_string()]);
    // resume restores the same residency against the same base object
    engine.resume_file(&art, &h.path).unwrap();
    assert_eq!(engine.base_bytes(), base);
    assert_eq!(engine.resident_param_bytes(), resident);
    assert!(engine.suspended_names().is_empty());
    assert!(!h.path.exists(), "resume must consume the spool file");
    assert!(Arc::ptr_eq(engine.session("s0").unwrap().base(),
                        engine.session("s1").unwrap().base()),
            "resumed session must rejoin the shared base");
    // a finished session holds no resumable work: suspend refuses
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 2);
    let err = engine.suspend("s1").unwrap_err().to_string();
    assert!(err.contains("finished"), "{err}");
    // and suspending a name that is not resident says so
    let err = engine.suspend("nobody").unwrap_err().to_string();
    assert!(err.contains("nobody"), "{err}");
    let _ = std::fs::remove_dir_all(&spool);
}

// ===== cross-tenant fused execution ==============================

/// Run K jobs through a fused engine and demand bit-identity with
/// their serial `Trainer` twins, plus conservation of physical passes:
/// every session-microbatch ran exactly once, fused or serial.
fn fused_matches_serial(preset: &str) {
    let rt = rt();
    let art = Artifact::synth(&rt, preset).unwrap();
    // uneven budgets: the gang shrinks 3-way → 2-way → singleton
    let cfgs = [cfg(4, 3), cfg(6, 9), cfg(5, 7)];
    let serial = serial_runs(&art, &cfgs);

    let mut engine = Engine::unbounded();
    engine.set_fuse(true);
    for (i, c) in cfgs.iter().enumerate() {
        engine.admit(&format!("s{i}"), &art, c.clone()).unwrap();
    }
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 3, "{preset}");

    let fs = engine.fusion_stats();
    assert!(fs.fused_passes > 0,
            "{preset}: concurrent same-base sessions never fused");
    assert_eq!(fs.fused_passes,
               fs.occupancy.values().sum::<u64>(), "{preset}");
    // conservation: Σ occupancy·count + serial = total microbatches
    let micro: u64 = fs
        .occupancy
        .iter()
        .map(|(&n, &c)| n as u64 * c)
        .sum::<u64>()
        + fs.serial_passes;
    let want: u64 = cfgs.iter().map(|c| c.steps as u64).sum();
    assert_eq!(micro, want, "{preset}: pass accounting leaked");

    for (i, (rows, params)) in serial.iter().enumerate() {
        let name = format!("s{i}");
        let r = reports
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("{preset}: {name} missing"));
        let rep = r.train().expect("completed");
        assert_eq!(rep.steps, cfgs[i].steps, "{preset}/{name}");
        let got: Vec<StepSig> = rep
            .rows
            .iter()
            .map(|row| {
                (row.loss.to_bits(), row.metric.to_bits(),
                 row.activation_bytes)
            })
            .collect();
        assert_eq!(&got, rows,
                   "{preset}/{name}: fused rows diverged from serial");
        assert_params_eq(&engine.session(&name).unwrap().params(),
                         params, &format!("{preset}/{name}"));
    }
}

#[test]
fn fused_gang_bit_identical_to_serial_1_thread() {
    with_threads(1, || fused_matches_serial("vitt_loraqv_regelu2_msln"));
}

#[test]
fn fused_gang_bit_identical_to_serial_4_threads() {
    with_threads(4, || fused_matches_serial("vitt_loraqv_regelu2_msln"));
}

#[test]
fn fused_gang_bit_identical_across_presets() {
    // every residual-ABI flavor: int8 mesa saves, swiglu's gated MLP,
    // activation checkpointing's recompute path
    for preset in ["vitt_loraqv_gelu_ln_mesa",
                   "llama_loraall_silu_rms_swiglu",
                   "vitt_loraqv_gelu_ln_ckpt"] {
        fused_matches_serial(preset);
    }
}

#[test]
fn mixed_key_fleet_splits_into_per_base_gangs() {
    // interleaved admission across two frozen bases: fusion must gang
    // by base, never across, and everyone still matches their twin
    let rt = rt();
    let vit = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let llama = Artifact::synth(&rt, "llama_loraall_silu_rms").unwrap();
    let vcfgs = [cfg(3, 1), cfg(3, 2)];
    let lcfgs = [cfg(3, 4), cfg(3, 5)];
    let vit_serial = serial_runs(&vit, &vcfgs);
    let llama_serial = serial_runs(&llama, &lcfgs);

    let mut engine = Engine::unbounded();
    engine.set_fuse(true);
    engine.admit("v0", &vit, vcfgs[0].clone()).unwrap();
    engine.admit("l0", &llama, lcfgs[0].clone()).unwrap();
    engine.admit("v1", &vit, vcfgs[1].clone()).unwrap();
    engine.admit("l1", &llama, lcfgs[1].clone()).unwrap();
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 4);

    let fs = engine.fusion_stats();
    // two 2-way gangs per round for 3 rounds; never a cross-base 4-way
    assert_eq!(fs.occupancy.keys().copied().collect::<Vec<_>>(),
               vec![2], "gangs crossed a frozen-base boundary");
    assert_eq!(fs.occupancy[&2], 6);
    assert_eq!(fs.serial_passes, 0);

    for (name, serial) in [("v0", &vit_serial[0]), ("v1", &vit_serial[1]),
                           ("l0", &llama_serial[0]),
                           ("l1", &llama_serial[1])] {
        let r = reports.iter().find(|r| r.name == name).unwrap();
        let got: Vec<StepSig> = r
            .train()
            .expect("completed")
            .rows
            .iter()
            .map(|row| {
                (row.loss.to_bits(), row.metric.to_bits(),
                 row.activation_bytes)
            })
            .collect();
        assert_eq!(got, serial.0, "{name}: rows diverged");
        assert_params_eq(&engine.session(name).unwrap().params(),
                         &serial.1, name);
    }
}

#[test]
fn grad_accum_mismatch_splits_the_gang() {
    // same frozen base, different grad-accum phase: the fusion key
    // must separate them (their microbatch cadences disagree), so both
    // ride singleton gangs through the serial path — and still match
    // their twins
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let a = cfg(3, 4);
    let mut b = cfg(3, 5);
    b.grad_accum = 2;
    let serial = serial_runs(&art, &[a.clone(), b.clone()]);

    let mut engine = Engine::unbounded();
    engine.set_fuse(true);
    engine.admit("s0", &art, a).unwrap();
    engine.admit("s1", &art, b).unwrap();
    let reports = engine.run().unwrap();
    let fs = engine.fusion_stats();
    assert_eq!(fs.fused_passes, 0,
               "mismatched grad-accum must never fuse");
    // 3 steps × 1 micro + 3 steps × 2 micros
    assert_eq!(fs.serial_passes, 9);
    for (i, (rows, params)) in serial.iter().enumerate() {
        let name = format!("s{i}");
        let r = reports.iter().find(|r| r.name == name).unwrap();
        let got: Vec<StepSig> = r
            .train()
            .expect("completed")
            .rows
            .iter()
            .map(|row| {
                (row.loss.to_bits(), row.metric.to_bits(),
                 row.activation_bytes)
            })
            .collect();
        assert_eq!(&got, rows, "{name}: rows diverged");
        assert_params_eq(&engine.session(&name).unwrap().params(),
                         params, &name);
    }
}

#[test]
fn mid_run_suspend_breaks_gang_survivors_bit_identical() {
    // two 3-way fused rounds, then s1 is evicted mid-run: the gang
    // must shrink to the survivors (who keep fusing 2-way) and, once
    // s1 resumes, regrow — with every session, round-tripped or not,
    // bit-identical to its serial twin
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let cfgs = [cfg(5, 3), cfg(5, 9), cfg(5, 7)];
    let serial = serial_runs(&art, &cfgs);
    let spool = spool_dir("fuse_suspend");

    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.set_fuse(true);
    for (i, c) in cfgs.iter().enumerate() {
        engine.admit(&format!("s{i}"), &art, c.clone()).unwrap();
    }
    assert_eq!(engine.round().unwrap(), 3);
    assert_eq!(engine.round().unwrap(), 3);
    engine.suspend("s1").unwrap();
    assert_eq!(engine.suspended_names(), vec!["s1".to_string()]);
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 3);
    assert!(engine.suspended_names().is_empty(),
            "unbounded engine must resume the evictee");

    let fs = engine.fusion_stats();
    assert!(fs.occupancy.contains_key(&3), "full gang never formed");
    assert!(fs.occupancy.contains_key(&2),
            "survivors should have fused 2-way while s1 was out: {:?}",
            fs.occupancy);

    for (i, (rows, params)) in serial.iter().enumerate() {
        let name = format!("s{i}");
        let r = reports.iter().find(|r| r.name == name).unwrap();
        let got: Vec<StepSig> = r
            .train()
            .expect("completed")
            .rows
            .iter()
            .map(|row| {
                (row.loss.to_bits(), row.metric.to_bits(),
                 row.activation_bytes)
            })
            .collect();
        assert_eq!(&got, rows, "{name}: rows diverged");
        assert_params_eq(&engine.session(&name).unwrap().params(),
                         params, &name);
    }
    let _ = std::fs::remove_dir_all(&spool);
}

#[test]
fn fault_in_gang_member_quarantines_only_that_member() {
    use ambp::coordinator::supervisor::FaultKind;
    use ambp::util::faultpoint;
    let _g = faultpoint::exclusive();
    faultpoint::clear();
    // gb trips a NaN loss on its second step, mid-gang
    faultpoint::arm("gb/step.loss:1:nan").unwrap();

    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let cfgs = [cfg(4, 3), cfg(4, 9), cfg(4, 7)];
    let serial = serial_runs(&art, &cfgs);
    let spool = spool_dir("fuse_fault");

    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.set_fuse(true);
    for (name, c) in ["ga", "gb", "gc"].iter().zip(&cfgs) {
        engine.admit(name, &art, c.clone()).unwrap();
    }
    let reports = engine.run().unwrap();
    assert_eq!(reports.len(), 3);
    assert!(engine.fusion_stats().fused_passes > 0,
            "the fleet should have been fusing when the fault hit");

    // exactly the faulted member is quarantined, at its last good step
    let rec = reports
        .iter()
        .find(|r| r.name == "gb")
        .unwrap()
        .fault()
        .expect("gb should be quarantined");
    assert_eq!(rec.kind, FaultKind::Numeric);
    assert_eq!(rec.step, 1, "last good step");
    assert!(!engine.contains("gb"));

    // the survivors kept fusing and finished bit-identically
    for (i, name) in [(0usize, "ga"), (2usize, "gc")] {
        let r = reports.iter().find(|r| r.name == name).unwrap();
        let got: Vec<StepSig> = r
            .train()
            .unwrap_or_else(|| panic!("{name} should complete"))
            .rows
            .iter()
            .map(|row| {
                (row.loss.to_bits(), row.metric.to_bits(),
                 row.activation_bytes)
            })
            .collect();
        assert_eq!(got, serial[i].0, "{name}: rows diverged");
        assert_params_eq(&engine.session(name).unwrap().params(),
                         &serial[i].1, name);
    }
    let _ = std::fs::remove_dir_all(&spool);
}

#[test]
fn step_events_follow_admission_order_serial_and_fused() {
    // the StepEvent ordering contract: serial sweeps emit in admission
    // order; fused sweeps emit gang-by-gang, gangs ordered by their
    // first member's admission, members in admission order — so the
    // event stream is a pure function of the admitted fleet
    use ambp::coordinator::engine::{StepEvent, StepEventKind};
    let rt = rt();
    let vit = Artifact::synth(&rt, "vitt_loraqv_gelu_ln").unwrap();
    let llama = Artifact::synth(&rt, "llama_loraall_silu_rms").unwrap();
    let stepped_names = |engine: &mut Engine| -> Vec<String> {
        let mut events: Vec<StepEvent> = Vec::new();
        engine.round_with(&mut events).unwrap();
        events
            .iter()
            .filter(|e| e.kind == StepEventKind::Stepped)
            .map(|e| e.name.clone())
            .collect()
    };

    let mut serial = Engine::unbounded();
    serial.admit("v0", &vit, cfg(2, 1)).unwrap();
    serial.admit("l0", &llama, cfg(2, 2)).unwrap();
    serial.admit("v1", &vit, cfg(2, 3)).unwrap();
    assert_eq!(stepped_names(&mut serial), ["v0", "l0", "v1"],
               "serial sweep must emit in admission order");

    let mut fused = Engine::unbounded();
    fused.set_fuse(true);
    fused.admit("v0", &vit, cfg(2, 1)).unwrap();
    fused.admit("l0", &llama, cfg(2, 2)).unwrap();
    fused.admit("v1", &vit, cfg(2, 3)).unwrap();
    // the vit gang (first member v0) precedes l0's singleton gang,
    // and v1 joins its gang behind v0 despite admitting after l0
    assert_eq!(stepped_names(&mut fused), ["v0", "v1", "l0"],
               "fused sweep must emit gang-by-gang in admission order");
    assert_eq!(stepped_names(&mut fused), ["v0", "v1", "l0"],
               "ordering must be stable across rounds");
}

#[test]
fn names_stay_stable_across_suspension() {
    // regression for the slot-id footgun: evicting slot 0 used to
    // shift every later session's index, so a held id silently pointed
    // at a different tenant. The name-addressed API must keep
    // targeting the same session before and after the shift.
    let rt = rt();
    let art = Artifact::synth(&rt, "vitt_loraqv_regelu2_msln").unwrap();
    let spool = spool_dir("stable_names");
    let mut engine = Engine::unbounded();
    engine.set_spool(spool.clone());
    engine.admit("s0", &art, cfg(4, 3)).unwrap();
    engine.admit("s1", &art, cfg(4, 9)).unwrap();
    engine.admit("s2", &art, cfg(4, 11)).unwrap();
    let s2_trainable_before: Vec<Vec<f32>> = engine
        .session("s2")
        .unwrap()
        .params()
        .iter()
        .map(|t| t.data.clone())
        .collect();
    // suspend slot 0 — under index addressing, "session 2" would now
    // resolve to what used to be slot 3 (out of bounds here)
    engine.suspend("s0").unwrap();
    assert!(!engine.contains("s0"));
    assert!(engine.contains("s1") && engine.contains("s2"));
    let s2 = engine.session("s2").unwrap();
    let after: Vec<Vec<f32>> =
        s2.params().iter().map(|t| t.data.clone()).collect();
    assert_eq!(s2_trainable_before, after,
               "name s2 resolved to a different session after the \
                eviction shifted slot indices");
    // and the shifted tenant is still individually suspendable by name
    engine.suspend("s2").unwrap();
    assert_eq!(engine.suspended_names(),
               vec!["s0".to_string(), "s2".to_string()]);
    assert!(engine.contains("s1"));
    let _ = std::fs::remove_dir_all(&spool);
}
