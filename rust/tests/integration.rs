//! Cross-module integration tests that do NOT need PJRT or artifacts
//! (those live in e2e_runtime.rs): memmodel ↔ paper figures, checkpoint
//! merge math, config plumbing, metrics/JSONL, coeffs end-to-end.

use ambp::coeffs::funcs::{gelu, PAPER_GELU};
use ambp::coeffs::{gelu_bound, objective};
use ambp::coordinator::checkpoint::Checkpoint;
use ambp::memmodel::ops::{ActKind, NormKind, Tuning};
use ambp::memmodel::report::{param_count, peak, trainable_count};
use ambp::memmodel::{block_units, presets as mp, total_bytes};
use ambp::runtime::Tensor;
use std::collections::BTreeMap;

#[test]
fn paper_headline_vit_reduction_about_30pct() {
    // Table 1 headline: LoRA-all ViT-B, ours vs baseline ≈ −30% peak
    let base = peak(&mp::vit_base(64, Tuning::LoraAll, ActKind::Gelu,
                                  NormKind::Ln), 16.0);
    let ours = peak(&mp::vit_base(64, Tuning::LoraAll, ActKind::ReGelu2,
                                  NormKind::MsLn), 16.0);
    let rel = 1.0 - ours.total as f64 / base.total as f64;
    assert!(rel > 0.20 && rel < 0.45, "reduction {rel}");
}

#[test]
fn paper_headline_llama_reduction_about_29pct() {
    let b = 4.5; // NF4 weight bits
    let base = peak(&mp::llama7b(4, 512, ActKind::Silu, NormKind::Rms), b);
    let ours = peak(&mp::llama7b(4, 512, ActKind::ReSilu2,
                                 NormKind::MsRms), b);
    let rel = 1.0 - ours.total as f64 / base.total as f64;
    assert!(rel > 0.15 && rel < 0.45, "reduction {rel}");
}

#[test]
fn single_changes_are_smaller_than_combined() {
    // Table 1 ordering: each single change saves; combined saves most
    let t = |act, norm| {
        total_bytes(&mp::vit_base(64, Tuning::LoraAll, act, norm))
    };
    let base = t(ActKind::Gelu, NormKind::Ln);
    let only_act = t(ActKind::ReGelu2, NormKind::Ln);
    let only_norm = t(ActKind::Gelu, NormKind::MsLn);
    let both = t(ActKind::ReGelu2, NormKind::MsLn);
    assert!(both < only_act && only_act < base);
    assert!(both < only_norm && only_norm < base);
}

#[test]
fn mesa_saves_less_than_ours() {
    // Mesa 8-bit > ReGELU2 2-bit residuals
    let t = |act, norm| {
        total_bytes(&mp::vit_base(64, Tuning::LoraQv, act, norm))
    };
    assert!(t(ActKind::ReGelu2, NormKind::MsLn)
        < t(ActKind::MesaGelu8, NormKind::MesaLn8));
}

#[test]
fn ckpt_mode_dominates_all_on_memory() {
    let mut cfg = mp::vit_base(64, Tuning::LoraQv, ActKind::Gelu,
                               NormKind::Ln);
    let base = total_bytes(&cfg);
    cfg.ckpt = true;
    assert!(total_bytes(&cfg) < base / 2);
}

#[test]
fn fig5_fig6_units_regression() {
    // lock the Figure 5/6 parity numbers down to a tight tolerance
    let u = |cfg| block_units(&cfg);
    assert!((u(mp::vit_base(64, Tuning::Full, ActKind::Gelu,
                            NormKind::Ln)) - 19.0).abs() < 0.1);
    assert!((u(mp::vit_base(64, Tuning::Frozen, ActKind::Gelu,
                            NormKind::Ln)) - 12.0).abs() < 0.1);
    assert!((u(mp::vit_base(64, Tuning::Full, ActKind::ReGelu2,
                            NormKind::MsLn)) - 11.5).abs() < 0.1);
    let llama = |act, norm, tun| {
        let mut c = mp::llama13b(4, 2048, act, norm);
        c.tuning = tun;
        block_units(&c)
    };
    assert!((llama(ActKind::Silu, NormKind::Rms, Tuning::Full) - 21.8)
        .abs() < 0.1);
    assert!((llama(ActKind::Silu, NormKind::Rms, Tuning::Frozen) - 16.1)
        .abs() < 0.1);
    assert!((llama(ActKind::ReSilu2, NormKind::MsRms, Tuning::Full)
        - 15.4375).abs() < 0.1);
}

#[test]
fn lora_param_fractions() {
    let cfg = mp::llama7b(4, 512, ActKind::Silu, NormKind::Rms);
    let t = trainable_count(&cfg);
    let p = param_count(&cfg);
    // r=64 LoRA-all on 7B ≈ 160M trainables, ~2.4%
    assert!(t > 50_000_000 && t < 400_000_000, "{t}");
    assert!((t as f64) < 0.05 * p as f64);
}

#[test]
fn checkpoint_merge_preserves_linear_output() {
    // y = W(α⊙z + β... ) — directly verify W̃z + b̃ == W(diag(α)z+β)+b
    let p = 8;
    let dout = 5;
    let mut rngv = 1u64;
    let mut rnd = || {
        rngv = rngv.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((rngv >> 33) as f32 / 2f32.powi(31) - 0.5) * 2.0
    };
    let alpha: Vec<f32> = (0..p).map(|_| rnd()).collect();
    let beta: Vec<f32> = (0..p).map(|_| rnd()).collect();
    let w: Vec<f32> = (0..p * dout).map(|_| rnd()).collect();
    let b: Vec<f32> = (0..dout).map(|_| rnd()).collect();
    let z: Vec<f32> = (0..p).map(|_| rnd()).collect();

    // reference: y1 = W (α⊙z + β) + b
    let mut y1 = vec![0f32; dout];
    for o in 0..dout {
        let mut acc = b[o];
        for i in 0..p {
            acc += w[o * p + i] * (alpha[i] * z[i] + beta[i]);
        }
        y1[o] = acc;
    }
    // merged: W̃ = W diag(α), b̃ = Wβ + b; y2 = W̃ z + b̃
    let mut y2 = vec![0f32; dout];
    for o in 0..dout {
        let mut acc = b[o];
        for i in 0..p {
            acc += w[o * p + i] * beta[i];
            acc += w[o * p + i] * alpha[i] * z[i];
        }
        y2[o] = acc;
    }
    for (a, c) in y1.iter().zip(&y2) {
        assert!((a - c).abs() < 1e-5);
    }
}

#[test]
fn checkpoint_save_restore_via_tensor_map() {
    let dir = std::env::temp_dir().join("ambp_int_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let mut tensors = BTreeMap::new();
    for i in 0..5 {
        tensors.insert(
            format!("block{i}.attn.q.W"),
            Tensor::from_f32(&[3, 3], &[i as f32; 9]),
        );
    }
    let ck = Checkpoint { tensors };
    ck.save(&dir).unwrap();
    let ck2 = Checkpoint::load(&dir).unwrap();
    assert_eq!(ck2.tensors.len(), 5);
    for i in 0..5 {
        assert_eq!(
            ck2.tensors[&format!("block{i}.attn.q.W")].as_f32()[0],
            i as f32
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn coeffs_objective_paper_vs_naive() {
    // the paper's coefficients must beat a naive single-ReLU-like h̃
    let b = gelu_bound(1e-8);
    let paper = objective(&gelu, &PAPER_GELU, -b, b);
    let naive = objective(
        &gelu,
        &ambp::coeffs::funcs::ReluComb { a: [0.0, 1.0],
                                         c: [-1.0, 0.0, 1.0] },
        -b,
        b,
    );
    assert!(paper < naive / 5.0, "paper {paper} naive {naive}");
}

#[test]
fn tab12_throughput_model_improves_with_batch() {
    // the ZeRO comm model: throughput strictly increases in batch
    let thr = |b: f64| 4.0 * b / (b + 2.0);
    assert!(thr(14.0) > thr(10.0));
    assert!((thr(14.0) / thr(10.0) - 1.0) > 0.04);
}

#[test]
fn memmodel_tape_mode_counts_lora_u() {
    use ambp::memmodel::model_entries;
    let mut cfg = mp::vit_base(8, Tuning::LoraQv, ActKind::Gelu,
                               NormKind::Ln);
    cfg.mode = ambp::memmodel::ops::Mode::Tape;
    let entries = model_entries(&cfg);
    assert!(entries.iter().any(|e| e.kind == "lora_u"));
    assert!(entries.iter().any(|e| e.kind == "attn_qkv"));
    // tape mode: attention saves exactly 3 [B,N,C] tensors
    let qkv: u64 = entries.iter().filter(|e| e.kind == "attn_qkv")
        .map(|e| e.bytes).sum();
    let unit = (8 * 197 * 768 * 4) as u64;
    assert_eq!(qkv, 3 * unit * cfg.depth as u64);
}
