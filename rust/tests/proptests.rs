//! Property tests (in-tree harness; proptest unavailable offline):
//! randomized invariants over the coordinator substrates, seeded and
//! iterated — shrinkless but deterministic and reproducible.

use ambp::coeffs::funcs::{PAPER_GELU, PAPER_SILU};
use ambp::coordinator::optimizer::{AdamW, Optimizer, Sgd};
use ambp::coordinator::scheduler::Schedule;
use ambp::packing;
use ambp::quant::{int8, nf4};
use ambp::runtime::Tensor;
use ambp::util::json::Json;
use ambp::util::rng::Rng;

const CASES: usize = 64;

#[test]
fn prop_pack2_roundtrip() {
    let mut rng = Rng::new(11);
    for _ in 0..CASES {
        let n = 1 + rng.below(4096);
        let codes: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
        let packed = packing::pack2(&codes);
        assert_eq!(packed.len(), n.div_ceil(4));
        assert_eq!(packing::unpack2(&packed, n), codes);
    }
}

#[test]
fn prop_pack1_roundtrip() {
    let mut rng = Rng::new(12);
    for _ in 0..CASES {
        let n = 1 + rng.below(4096);
        let bits: Vec<u8> = (0..n).map(|_| rng.below(2) as u8).collect();
        assert_eq!(packing::unpack1(&packing::pack1(&bits), n), bits);
    }
}

#[test]
fn prop_decode_matches_scalar_derivative() {
    let mut rng = Rng::new(13);
    for comb in [PAPER_GELU, PAPER_SILU] {
        for _ in 0..CASES / 2 {
            let n = 4 + rng.below(512);
            let xs: Vec<f32> =
                (0..n).map(|_| rng.normal_f32() * 5.0).collect();
            let gy: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let packed = packing::pack2(&packing::bucketize2(&xs, comb.c));
            let gx = packing::apply_slopes(&packed, &gy, comb.slopes());
            for i in 0..n {
                let want = gy[i] as f64 * comb.derivative(xs[i] as f64);
                assert!((gx[i] as f64 - want).abs() < 1e-5);
            }
        }
    }
}

#[test]
fn prop_int8_fused_group_kernels_match_split_reference() {
    // the fused packed-layout kernels (what the _mesa tape stores) are
    // bit-identical to quant_rows/dequant_rows with group = row
    let mut rng = Rng::new(21);
    for _ in 0..CASES {
        let group = 1 + rng.below(96);
        let groups = 1 + rng.below(12);
        let x: Vec<f32> = (0..groups * group)
            .map(|_| rng.normal_f32() * rng.range(0.1, 50.0) as f32)
            .collect();
        let (q, s) = int8::quant_rows(&x, group);
        let mut packed = vec![0u8; int8::packed_len(x.len(), group)];
        int8::quantize_into(&x, group, &mut packed);
        let row = group + int8::GROUP_FOOTER_BYTES;
        for g in 0..groups {
            let r = &packed[g * row..(g + 1) * row];
            for c in 0..group {
                assert_eq!(r[c] as i8, q[g * group + c]);
            }
            let scale =
                f32::from_le_bytes(r[group..].try_into().unwrap());
            assert_eq!(scale, s[g]);
        }
        let mut back = vec![0f32; x.len()];
        int8::dequantize_into(&packed, group, &mut back);
        assert_eq!(back, int8::dequant_rows(&q, &s, group));
    }
}

#[test]
fn prop_int8_group_roundtrip_bounded_and_zero_exact() {
    // quantize→dequantize error ≤ scale/2 per element (scale read back
    // from the packed footer), and exact zeros survive exactly
    let mut rng = Rng::new(22);
    for _ in 0..CASES {
        let group = 2 + rng.below(64);
        let groups = 1 + rng.below(8);
        let mut x: Vec<f32> = (0..groups * group)
            .map(|_| rng.normal_f32() * rng.range(0.1, 100.0) as f32)
            .collect();
        // plant exact zeros
        for i in (0..x.len()).step_by(5) {
            x[i] = 0.0;
        }
        let mut packed = vec![0u8; int8::packed_len(x.len(), group)];
        int8::quantize_into(&x, group, &mut packed);
        let mut back = vec![0f32; x.len()];
        int8::dequantize_into(&packed, group, &mut back);
        let row = group + int8::GROUP_FOOTER_BYTES;
        for g in 0..groups {
            let scale = f32::from_le_bytes(
                packed[g * row + group..(g + 1) * row]
                    .try_into()
                    .unwrap(),
            );
            for c in 0..group {
                let i = g * group + c;
                assert!((x[i] - back[i]).abs() <= scale * 0.5 + 1e-7,
                        "err {} > scale/2 {}", (x[i] - back[i]).abs(),
                        scale * 0.5);
                if x[i] == 0.0 {
                    assert_eq!(back[i], 0.0, "zero not exact at {i}");
                }
            }
        }
    }
}

#[test]
fn prop_int8_quantize_partition_invariant() {
    // the pool determinism contract for the fused kernels: any logical
    // AMBP_THREADS partition produces bit-identical packed bytes and
    // bit-identical dequantized f32s (groups never straddle chunks)
    use ambp::runtime::native::pool::with_threads;
    let mut rng = Rng::new(23);
    let group = 48;
    let x: Vec<f32> = (0..group * 101)
        .map(|_| rng.normal_f32() * 3.0)
        .collect();
    let mut want = vec![0u8; int8::packed_len(x.len(), group)];
    with_threads(1, || int8::quantize_into(&x, group, &mut want));
    let mut want_f = vec![0f32; x.len()];
    with_threads(1, || int8::dequantize_into(&want, group, &mut want_f));
    for nt in [2usize, 3, 7, 16] {
        let mut got = vec![0u8; want.len()];
        with_threads(nt, || int8::quantize_into(&x, group, &mut got));
        assert_eq!(got, want, "quantize differs at nt={nt}");
        let mut got_f = vec![0f32; x.len()];
        with_threads(nt, || {
            int8::dequantize_into(&got, group, &mut got_f)
        });
        assert!(got_f.iter().zip(&want_f).all(|(a, b)| {
            a.to_bits() == b.to_bits()
        }), "dequantize differs at nt={nt}");
    }
}

#[test]
fn prop_int8_error_bound() {
    let mut rng = Rng::new(14);
    for _ in 0..CASES {
        let cols = 1 + rng.below(256);
        let rows = 1 + rng.below(8);
        let x: Vec<f32> = (0..rows * cols)
            .map(|_| rng.normal_f32() * rng.range(0.1, 100.0) as f32)
            .collect();
        let (q, s) = int8::quant_rows(&x, cols);
        let xh = int8::dequant_rows(&q, &s, cols);
        for r in 0..rows {
            let amax = x[r * cols..(r + 1) * cols]
                .iter()
                .fold(0f32, |m, v| m.max(v.abs()));
            for c in 0..cols {
                let i = r * cols + c;
                assert!((x[i] - xh[i]).abs() <= amax / 127.0 * 0.5 + 1e-6);
            }
        }
    }
}

#[test]
fn prop_nf4_idempotent() {
    // quantize(dequantize(q)) == q — codes are fixed points
    let mut rng = Rng::new(15);
    for _ in 0..16 {
        let n = 64 + rng.below(512);
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let t = nf4::quantize(&x, 64);
        let xh = nf4::dequantize(&t);
        let t2 = nf4::quantize(&xh, 64);
        let xh2 = nf4::dequantize(&t2);
        for (a, b) in xh.iter().zip(&xh2) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(16);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Json::Str(format!("s{}-\"q\"\n", rng.below(1000))),
            4 => Json::Arr((0..rng.below(5))
                .map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj((0..rng.below(5))
                .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                .collect()),
        }
    }
    for _ in 0..CASES {
        let v = gen(&mut rng, 3);
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }
}

#[test]
fn prop_sgd_descends_convex() {
    // on a convex quadratic, each SGD step reduces distance to optimum
    let mut rng = Rng::new(17);
    for _ in 0..16 {
        let n = 1 + rng.below(64);
        let target: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut p = Tensor::from_f32(
            &[n], &(0..n).map(|_| rng.normal_f32() * 5.0).collect::<Vec<_>>());
        let mut opt = Sgd::new(0.0);
        let mut prev = dist(&p, &target);
        for _ in 0..20 {
            let g: Vec<f32> = p.as_f32().iter().zip(&target)
                .map(|(a, b)| a - b).collect();
            let g = Tensor::from_f32(&[n], &g);
            opt.step(&mut [&mut p], &[g], 0.1);
            let d = dist(&p, &target);
            assert!(d <= prev + 1e-6);
            prev = d;
        }
    }
}

fn dist(p: &Tensor, t: &[f32]) -> f64 {
    p.as_f32().iter().zip(t)
        .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt()
}

#[test]
fn prop_adamw_bounded_step_size() {
    // |Δp| ≤ lr · (1/(1−β1)) approx bound per step (no decay)
    let mut rng = Rng::new(18);
    for _ in 0..16 {
        let n = 1 + rng.below(32);
        let mut p = Tensor::from_f32(&[n], &vec![0.0; n]);
        let mut opt = AdamW::new(0.0);
        let lr = 0.01f32;
        for _ in 0..5 {
            let g: Vec<f32> = (0..n)
                .map(|_| rng.normal_f32() * 100.0).collect();
            let before = p.as_f32().to_vec();
            opt.step(&mut [&mut p],
                     &[Tensor::from_f32(&[n], &g)], lr);
            for (b, a) in before.iter().zip(p.as_f32()) {
                assert!((a - b).abs() <= lr * 12.0, "step too large");
            }
        }
    }
}

#[test]
fn prop_schedule_bounded_by_base() {
    let mut rng = Rng::new(19);
    for _ in 0..CASES {
        let total = 10 + rng.below(500);
        let base = rng.range(1e-5, 1.0) as f32;
        for s in [
            Schedule::Constant,
            Schedule::WarmupCosine { warmup: total / 10, warmup_init: 0.0 },
            Schedule::WarmupLinear { warmup_frac: 0.1 },
        ] {
            for step in 0..total {
                let lr = s.lr(base, step, total);
                assert!(lr >= 0.0 && lr <= base * 1.0001);
            }
        }
    }
}

#[test]
fn prop_rng_shuffle_uniform_first_element() {
    // coarse uniformity: each element appears first ~equally often
    let mut rng = Rng::new(20);
    let k = 8;
    let mut counts = vec![0usize; k];
    let trials = 8000;
    for _ in 0..trials {
        let mut v: Vec<usize> = (0..k).collect();
        rng.shuffle(&mut v);
        counts[v[0]] += 1;
    }
    let expect = trials as f64 / k as f64;
    for c in counts {
        assert!((c as f64 - expect).abs() < expect * 0.2, "{c}");
    }
}
