//! End-to-end runtime tests: the cross-language proof that all three
//! layers compose. Requires `make artifacts` (the DEFAULT preset set).
//!
//! For each preset under test: compile the HLO through PJRT, execute the
//! selfcheck batch, and compare loss/metric/grads against the values the
//! L2 model computed eagerly at export time. Then run real training steps
//! and check the loss goes down and the measured residual bytes match
//! the manifest.

use std::path::PathBuf;

use ambp::coordinator::checkpoint::{merge_affine, Checkpoint};
use ambp::coordinator::{TrainCfg, Trainer};
use ambp::runtime::{Artifact, DType, Runtime, Tensor};

fn rt() -> &'static Runtime {
    // Backends may be !Send (the PJRT client is Rc-based): one runtime
    // per test thread.
    thread_local! {
        static RT: &'static Runtime =
            Box::leak(Box::new(Runtime::cpu().expect("CPU runtime")));
    }
    RT.with(|rt| *rt)
}

fn adir() -> PathBuf {
    ambp::runtime::artifacts_dir()
}

fn have(preset: &str) -> bool {
    let ok = adir().join(preset).join("manifest.json").is_file();
    if !ok {
        eprintln!("SKIP: artifact {preset} not built (make artifacts)");
    }
    ok
}

/// Load a built artifact, or skip when the active backend cannot execute
/// it (the native backend now covers every preset axis — ckpt since
/// the Layer/Tape refactor, Mesa via the `_mesa` int8 tape slots — but
/// legacy exporter spellings like `mesa_mesaln` and param layouts it
/// cannot reproduce still only run under --features pjrt).
fn try_load(preset: &str) -> Option<Artifact> {
    if !have(preset) {
        return None;
    }
    match Artifact::load(rt(), &adir().join(preset)) {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("SKIP: {preset} not loadable on this backend: {e}");
            None
        }
    }
}

fn load_selfcheck_batch(art: &Artifact) -> (Tensor, Tensor) {
    let m = &art.manifest;
    let xb = std::fs::read(art.dir.join("selfcheck_x.bin")).unwrap();
    let yb = std::fs::read(art.dir.join("selfcheck_y.bin")).unwrap();
    let mut x = Tensor::zeros(&m.x.shape, m.x.dtype);
    x.data.copy_from_slice(&xb);
    let mut y = Tensor::zeros(&m.y.shape, m.y.dtype);
    y.data.copy_from_slice(&yb);
    (x, y)
}

fn selfcheck_preset(preset: &str) {
    let Some(art) = try_load(preset) else {
        return;
    };
    let params = art.load_params().unwrap();
    let (x, y) = load_selfcheck_batch(&art);

    // fwd: loss/metric must match the eager L2 computation at export time
    let out = art.run_fwd(&params, &x, &y).unwrap();
    let sc = &art.manifest.selfcheck;
    assert!(
        (out.loss as f64 - sc.loss).abs() < 1e-4 * sc.loss.abs().max(1.0),
        "{preset}: loss {} vs selfcheck {}", out.loss, sc.loss
    );
    assert!(
        (out.metric as f64 - sc.metric).abs() < 1e-4,
        "{preset}: metric {} vs {}", out.metric, sc.metric
    );

    // residual ABI: shapes/dtypes/bytes match the manifest exactly
    assert_eq!(out.residuals.len(), art.manifest.residuals.len());
    let mut total = 0u64;
    for (t, info) in out.residuals.iter().zip(&art.manifest.residuals) {
        assert_eq!(t.shape, info.shape, "{preset}: {}", info.name);
        assert_eq!(t.nbytes() as u64, info.bytes);
        total += info.bytes;
    }
    assert_eq!(total, art.manifest.residual_bytes_total);

    // bwd: per-tensor grads must match the export-time eager grads
    let grads = art.run_bwd(&params, &out.residuals, &x, &y).unwrap();
    assert_eq!(grads.len(), sc.grad_l2.len());
    let gfile = std::fs::read(art.dir.join("selfcheck_grads.bin")).unwrap();
    let mut off = 0usize;
    for (gi, g) in grads.iter().enumerate() {
        let n = g.elems();
        let want: &[f32] = unsafe {
            std::slice::from_raw_parts(
                gfile[off..].as_ptr() as *const f32, n)
        };
        off += n * 4;
        let gv = g.as_f32();
        let mut max_err = 0f32;
        for (a, b) in gv.iter().zip(want) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 2e-4, "{preset}: grad[{gi}] max err {max_err}");
        let l2 = g.l2();
        assert!(
            (l2 - sc.grad_l2[gi]).abs() < 1e-3 * sc.grad_l2[gi].max(1.0),
            "{preset}: grad l2 {l2} vs {}", sc.grad_l2[gi]
        );
    }
}

#[test]
fn selfcheck_vit_baseline() {
    selfcheck_preset("vitt_loraqv_gelu_ln");
}

#[test]
fn selfcheck_vit_ours() {
    selfcheck_preset("vitt_loraqv_regelu2_msln");
}

#[test]
fn selfcheck_vit_ckpt() {
    selfcheck_preset("vitt_loraqv_gelu_ln_ckpt");
}

#[test]
fn selfcheck_llama_both() {
    selfcheck_preset("llama_loraall_silu_rms");
    selfcheck_preset("llama_loraall_resilu2_msrms");
}

#[test]
fn selfcheck_pallas_lowered() {
    // the composition proof: this artifact's HLO went through the Pallas
    // kernels (interpret=True) at lowering time
    selfcheck_preset("pallas_vit_regelu2_msln");
}

#[test]
fn training_reduces_loss_and_tracks_memory() {
    let Some(art) = try_load("vitt_loraqv_regelu2_msln") else {
        return;
    };
    let mut t = Trainer::new(
        &art,
        TrainCfg { steps: 12, lr: 2e-3, log_every: 0,
                   ..Default::default() },
    )
    .unwrap();
    let rep = t.train().unwrap();
    let first = rep.rows.first().unwrap().loss;
    let last = rep.rows.last().unwrap().loss;
    assert!(last < first, "loss did not decrease: {first} → {last}");
    assert_eq!(
        rep.rows[0].activation_bytes,
        art.manifest.residual_bytes_total
    );
    assert!(rep.peak_activation_bytes >= art.manifest.residual_bytes_total);
}

#[test]
fn measured_memory_ordering_matches_paper() {
    // ours < mesa < baseline, and ckpt < ours (Figure 1 / Table 1 shape)
    // mesa/ckpt only load under the pjrt backend; read their manifests
    // directly so the ordering check runs wherever artifacts exist
    for p in ["vitt_loraqv_gelu_ln", "vitt_loraqv_regelu2_msln",
              "vitt_loraqv_mesa_mesaln", "vitt_loraqv_gelu_ln_ckpt"] {
        if !have(p) {
            return;
        }
    }
    let bytes = |p: &str| {
        ambp::runtime::Manifest::load(&adir().join(p))
            .unwrap()
            .residual_bytes_total
    };
    let base = bytes("vitt_loraqv_gelu_ln");
    let ours = bytes("vitt_loraqv_regelu2_msln");
    let mesa = bytes("vitt_loraqv_mesa_mesaln");
    let ckpt = bytes("vitt_loraqv_gelu_ln_ckpt");
    assert!(ours < mesa, "ours {ours} !< mesa {mesa}");
    assert!(mesa < base, "mesa {mesa} !< base {base}");
    assert!(ckpt < ours, "ckpt {ckpt} !< ours {ours}");
}

#[test]
fn grad_accumulation_equivalence() {
    // 1 step × accum 2 must equal averaging two single-microbatch grads
    let Some(art) = try_load("vitt_loraqv_gelu_ln") else {
        return;
    };
    let params = art.load_params().unwrap();
    let (x, y) = load_selfcheck_batch(&art);
    let out = art.run_fwd(&params, &x, &y).unwrap();
    let g1 = art.run_bwd(&params, &out.residuals, &x, &y).unwrap();
    // same batch twice → average equals the single-batch grad
    let avg: Vec<Tensor> = g1
        .iter()
        .map(|g| {
            let v: Vec<f32> =
                g.as_f32().iter().map(|a| (a + a) / 2.0).collect();
            Tensor::from_f32(&g.shape, &v)
        })
        .collect();
    for (a, b) in g1.iter().zip(&avg) {
        for (x1, x2) in a.as_f32().iter().zip(b.as_f32()) {
            assert!((x1 - x2).abs() < 1e-6);
        }
    }
}

#[test]
fn affine_merge_roundtrip_across_presets() {
    // eq. 16→18 at the whole-model level: restore an LN checkpoint into
    // the MS-LN preset via merge_affine; the fine-tuned starting loss
    // must match the LN model's loss on the same batch (identical fwd).
    let (Some(ln), Some(ms)) = (try_load("vitt_loraqv_gelu_ln"),
                                try_load("vitt_loraqv_gelu_msln"))
    else {
        return;
    };
    let ln_params = ln.load_params().unwrap();
    let (x, y) = load_selfcheck_batch(&ln);
    let ln_loss = ln.run_fwd(&ln_params, &x, &y).unwrap().loss;

    let ck = Checkpoint::from_params(&ln.manifest, &ln_params);
    let merged = merge_affine(&ck, &ms.manifest).unwrap();
    let mut ms_params = ms.load_params().unwrap();
    let restored = merged.restore(&ms.manifest, &mut ms_params).unwrap();
    assert!(restored > 0);
    let ms_loss = ms.run_fwd(&ms_params, &x, &y).unwrap().loss;
    // init affine is (α=1, β=0) so the merge is numerically trivial here,
    // but the ABI path (names, shapes, ordering) is fully exercised; a
    // non-trivial merge is covered by the vit_lora_finetune example after
    // pretraining perturbs the affine params.
    assert!(
        (ln_loss - ms_loss).abs() < 1e-4,
        "merged fwd differs: {ln_loss} vs {ms_loss}"
    );
}

#[test]
fn residual_dtype_checks() {
    let Some(art) = try_load("vitt_loraqv_regelu2_msln") else {
        return;
    };
    // 2-bit code tensors surface as uint8 with C/4 trailing dim
    let codes: Vec<_> = art
        .manifest
        .residuals
        .iter()
        .filter(|r| r.kind == "act_codes")
        .collect();
    assert_eq!(codes.len(), art.manifest.depth);
    for c in codes {
        assert_eq!(c.dtype, DType::U8);
        assert!((c.bits_per_elem - 2.0).abs() < 1e-9);
    }
}
