//! Coefficient-solver benchmarks (Appendix E substrate): objective
//! evaluation, quadrature, SA+NM end-to-end solve.

use ambp::coeffs::funcs::{gelu, PAPER_GELU};
use ambp::coeffs::integrate::{adaptive_simpson, integrate_piecewise};
use ambp::coeffs::{gelu_bound, objective, solve_gelu};
use ambp::util::bench::{bench, black_box};

fn main() {
    let b = gelu_bound(1e-8);
    bench("objective(gelu, paper) @1e-10", 50, || {
        black_box(objective(&gelu, &PAPER_GELU, -b, b));
    });
    bench("adaptive_simpson gaussian", 100, || {
        black_box(adaptive_simpson(&|x: f64| (-x * x).exp(), -8.0, 8.0,
                                   1e-10));
    });
    bench("integrate_piecewise (3 kinks)", 100, || {
        let f = |x: f64| {
            let d = gelu(x) - PAPER_GELU.eval(x);
            d * d
        };
        black_box(integrate_piecewise(&f, -b, b, &PAPER_GELU.c, 1e-10));
    });
    bench("solve_gelu (SA 8k + NM polish)", 1, || {
        black_box(solve_gelu(1));
    });
}
