//! Memory-model benchmarks: full-model entry generation at paper scale,
//! peak estimation, and the Table 9/11 budget searches.

use ambp::memmodel::ops::{ActKind, NormKind, Tuning};
use ambp::memmodel::report::{gib, peak};
use ambp::memmodel::{model_entries, presets as mp, total_bytes};
use ambp::util::bench::{bench, black_box};

fn main() {
    let vit = mp::vit_base(64, Tuning::LoraQv, ActKind::Gelu, NormKind::Ln);
    let llama = mp::llama13b(4, 2048, ActKind::Silu, NormKind::Rms);
    bench("model_entries vit-b (12 blocks)", 1000, || {
        black_box(model_entries(black_box(&vit)));
    });
    bench("model_entries llama-13b (40 blocks)", 1000, || {
        black_box(model_entries(black_box(&llama)));
    });
    bench("peak estimate llama-13b", 1000, || {
        black_box(peak(black_box(&llama), 4.5));
    });
    bench("tab9 max-seq binary search", 100, || {
        let fits = |seq: usize| {
            gib(peak(&mp::llama7b(1, seq, ActKind::ReSilu2,
                                  NormKind::MsRms), 4.5).total) <= 24.0
        };
        let (mut lo, mut hi) = (256usize, 1 << 20);
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if fits(mid) { lo = mid } else { hi = mid - 1 }
        }
        black_box(lo);
    });
    // table-shape sanity printed for the record
    let base = total_bytes(&mp::llama13b(4, 2048, ActKind::Silu,
                                         NormKind::Rms));
    let ours = total_bytes(&mp::llama13b(4, 2048, ActKind::ReSilu2,
                                         NormKind::MsRms));
    println!("\nllama-13b activation reduction (ours vs base): {:.1}%",
             100.0 * (1.0 - ours as f64 / base as f64));
}
