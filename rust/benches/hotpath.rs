//! End-to-end hot-path benchmarks (in-tree harness; criterion is
//! unavailable offline). One section per paper table's cost driver:
//! fwd/bwd step latency per variant (Tables 1–4 throughput columns),
//! packing/codec microbenches, optimizer step, data synthesis.
//!
//! Emits `BENCH_hotpath.json` (`name → mean ns/iter`) at the repo root
//! so the perf trajectory is diffable across PRs.
//!
//!   cargo bench --bench hotpath

use ambp::coordinator::optimizer::{AdamW, Optimizer};
use ambp::data::synth_images::ImageTask;
use ambp::packing;
use ambp::quant::{int8, nf4};
use ambp::runtime::{load_or_synth, Runtime, Tensor};
use ambp::util::bench::{bench, black_box, repo_root, write_json,
                        BenchResult};
use ambp::util::rng::Rng;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    println!("== packing / codec microbenches (1M elements) ==");
    let mut rng = Rng::new(0);
    let xs: Vec<f32> = (0..1 << 20).map(|_| rng.normal_f32() * 3.0).collect();
    let gy: Vec<f32> = (0..1 << 20).map(|_| rng.normal_f32()).collect();
    let comb = ambp::coeffs::funcs::PAPER_GELU;
    let codes = packing::bucketize2(&xs, comb.c);
    let packed = packing::pack2(&codes);
    results.push(bench("bucketize2 (encode)", 20, || {
        black_box(packing::bucketize2(black_box(&xs), comb.c));
    }));
    results.push(bench("pack2", 20, || {
        black_box(packing::pack2(black_box(&codes)));
    }));
    results.push(bench("apply_slopes (decode-bwd)", 20, || {
        black_box(packing::apply_slopes(black_box(&packed), &gy,
                                        comb.slopes()));
    }));
    results.push(bench("int8 quant_rows (Mesa baseline)", 20, || {
        black_box(int8::quant_rows(black_box(&xs), 1024));
    }));
    results.push(bench("nf4 quantize (QLoRA weights)", 5, || {
        black_box(nf4::quantize(black_box(&xs), 64));
    }));

    println!("\n== optimizer step (1M params) ==");
    let mut p = Tensor::from_f32(&[1 << 20], &xs);
    let g = Tensor::from_f32(&[1 << 20], &gy);
    let mut opt = AdamW::new(0.01);
    results.push(bench("adamw step 1M", 20, || {
        opt.step(&mut [&mut p], std::slice::from_ref(&g), 1e-3);
    }));

    println!("\n== data pipeline ==");
    let task = ImageTask::new(10, 64, 48, 0.5, 0);
    results.push(bench("synth image batch b=16", 50, || {
        black_box(task.batch(0, 16));
    }));

    println!("\n== end-to-end train step (native fwd+bwd), per variant ==");
    let rt = Runtime::cpu().expect("native runtime");
    for preset in [
        "vitt_loraqv_gelu_ln",
        "vitt_loraqv_regelu2_msln",
        "vitt_full_gelu_ln",
        "vitt_full_regelu2_msln",
        "llama_loraall_silu_rms",
        "llama_loraall_resilu2_msrms",
    ] {
        let art = match load_or_synth(&rt, preset) {
            Ok(a) => a,
            Err(e) => {
                println!("{preset:<44} [unavailable: {e}]");
                continue;
            }
        };
        let params = art.load_params().expect("params");
        let (x, y) = make_batch(&art.manifest);
        results.push(bench(&format!("{preset} fwd"), 10, || {
            black_box(art.run_fwd(&params, &x, &y).expect("fwd"));
        }));
        let out = art.run_fwd(&params, &x, &y).expect("fwd");
        results.push(bench(&format!("{preset} bwd"), 10, || {
            black_box(
                art.run_bwd(&params, &out.residuals, &x, &y).expect("bwd"),
            );
        }));
    }

    let out_path = repo_root().join("BENCH_hotpath.json");
    write_json(&results, &out_path).expect("write BENCH_hotpath.json");
    println!("\nwrote {} entries to {:?}", results.len(), out_path);
}

fn make_batch(m: &ambp::runtime::Manifest) -> (Tensor, Tensor) {
    let mut rng = Rng::new(1);
    match m.arch.as_str() {
        "vit" => {
            let n: usize = m.x.shape.iter().product();
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let ny: usize = m.y.shape.iter().product();
            let y: Vec<i32> =
                (0..ny).map(|_| rng.below(m.n_classes) as i32).collect();
            (Tensor::from_f32(&m.x.shape, &x), Tensor::from_i32(&m.y.shape, &y))
        }
        _ => {
            let n: usize = m.x.shape.iter().product();
            let x: Vec<i32> =
                (0..n).map(|_| rng.below(m.vocab) as i32).collect();
            let ny: usize = m.y.shape.iter().product();
            let hi = if m.arch == "llama" { m.vocab } else { m.n_classes };
            let y: Vec<i32> = (0..ny).map(|_| rng.below(hi) as i32).collect();
            (Tensor::from_i32(&m.x.shape, &x), Tensor::from_i32(&m.y.shape, &y))
        }
    }
}
