//! End-to-end hot-path benchmarks (in-tree harness; criterion is
//! unavailable offline). One section per paper table's cost driver:
//! GEMM kernel throughput (naive-reference vs blocked, with a
//! thread-scaling sweep), fwd/bwd step latency per variant (Tables 1–4
//! throughput columns), packing/codec microbenches, optimizer step, data
//! synthesis.
//!
//! Emits `BENCH_hotpath.json` (`name → mean ns/iter`) at the repo root,
//! printing a `name → old/new/Δ%` diff against the previous run first,
//! so the perf trajectory is visible across PRs.
//!
//!   cargo bench --bench hotpath
//!
//! `AMBP_BENCH_SAMPLES=n` caps every section's sample count (the CI
//! smoke run uses 2 so the harness cannot bit-rot without burning CI
//! minutes).

use ambp::coordinator::optimizer::{AdamW, Optimizer};
use ambp::data::synth_images::ImageTask;
use ambp::packing;
use ambp::quant::{int8, nf4};
use ambp::runtime::native::kernels::matmul_nt;
use ambp::runtime::native::pool::{threads, with_threads};
use ambp::runtime::native::spec::{parse_preset, sample_batch};
use ambp::runtime::native::{Arena, Model, Profiler};
use ambp::runtime::{load_or_synth, Runtime, Tensor};
use ambp::util::bench::{bench, black_box, fmt_ns, repo_root,
                        write_json_with_diff, BenchResult};
use ambp::util::rng::Rng;

/// Per-section sample count, capped by `AMBP_BENCH_SAMPLES`.
fn samples(default: usize) -> usize {
    match std::env::var("AMBP_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        Some(cap) => default.min(cap.max(1)),
        None => default,
    }
}

/// The pre-PR `matmul_nt` inner loop (per-element sequential dot), kept
/// here as the fixed reference the blocked kernel is measured against.
fn naive_matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize,
                   n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for (j, cv) in c[i * n..(i + 1) * n].iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *cv = acc;
        }
    }
    c
}

fn gflops(flops: usize, mean_ns: f64) -> f64 {
    flops as f64 / mean_ns
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    println!("== GEMM kernel (m,k,n) = (512,768,768), f32 ==");
    let (m, k, n) = (512usize, 768usize, 768usize);
    let flops = 2 * m * k * n;
    let mut rng = Rng::new(7);
    let ga: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
    let gb: Vec<f32> = (0..n * k).map(|_| rng.normal_f32()).collect();
    let r = with_threads(1, || {
        bench("matmul_nt 512x768x768 naive 1t (pre-PR)", samples(5),
              || {
                  black_box(naive_matmul_nt(black_box(&ga), &gb, m, k, n));
              })
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops, r.mean_ns));
    results.push(r);
    let r = with_threads(1, || {
        bench("matmul_nt 512x768x768 blocked 1t", samples(10), || {
            black_box(matmul_nt(black_box(&ga), &gb, m, k, n));
        })
    });
    println!("    -> {:.2} GFLOP/s", gflops(flops, r.mean_ns));
    results.push(r);
    println!("-- thread scaling (logical partition; {} resident \
              workers + the caller) --",
             threads().saturating_sub(1));
    for nt in [2usize, 4, 8] {
        let r = with_threads(nt, || {
            bench(&format!("matmul_nt 512x768x768 blocked {nt}t"),
                  samples(10), || {
                      black_box(matmul_nt(black_box(&ga), &gb, m, k, n));
                  })
        });
        println!("    -> {:.2} GFLOP/s at nt={nt}",
                 gflops(flops, r.mean_ns));
        results.push(r);
    }
    println!("-- prepacked B panels: pack once vs repack per call --");
    {
        use ambp::runtime::native::gemm::{gemm_packed_into, pack_b_once};
        let mut c = vec![0f32; m * n];
        let pb = pack_b_once(&gb, k, n, true);
        let r = with_threads(1, || {
            bench("gemm_packed_into 512x768x768 pack-once 1t",
                  samples(10), || {
                      gemm_packed_into(black_box(&mut c),
                                       black_box(&ga), &pb, m, false,
                                       false);
                  })
        });
        println!("    -> {:.2} GFLOP/s (frozen-base steady state)",
                 gflops(flops, r.mean_ns));
        results.push(r);
        let r = with_threads(1, || {
            bench("gemm_packed_into 512x768x768 repack-each-call 1t",
                  samples(10), || {
                      let pb = pack_b_once(black_box(&gb), k, n, true);
                      gemm_packed_into(&mut c, &ga, &pb, m, false,
                                       false);
                  })
        });
        println!("    -> {:.2} GFLOP/s (pre-cache behavior)",
                 gflops(flops, r.mean_ns));
        results.push(r);
    }

    println!("\n== packing / codec microbenches (1M elements) ==");
    let mut rng = Rng::new(0);
    let xs: Vec<f32> = (0..1 << 20).map(|_| rng.normal_f32() * 3.0).collect();
    let gy: Vec<f32> = (0..1 << 20).map(|_| rng.normal_f32()).collect();
    let comb = ambp::coeffs::funcs::PAPER_GELU;
    let codes = packing::bucketize2(&xs, comb.c);
    let packed = packing::pack2(&codes);
    results.push(bench("bucketize2 (encode)", samples(20), || {
        black_box(packing::bucketize2(black_box(&xs), comb.c));
    }));
    results.push(bench("pack2", samples(20), || {
        black_box(packing::pack2(black_box(&codes)));
    }));
    results.push(bench("encode2 (fused bucketize+pack)", samples(20),
                       || {
                           black_box(packing::encode2(black_box(&xs),
                                                      comb.c));
                       }));
    results.push(bench("apply_slopes (decode-bwd)", samples(20), || {
        black_box(packing::apply_slopes(black_box(&packed), &gy,
                                        comb.slopes()));
    }));
    results.push(bench("int8 quant_rows (Mesa baseline)", samples(20),
                       || {
                           black_box(int8::quant_rows(black_box(&xs),
                                                      1024));
                       }));
    // the fused pool-parallel group kernels backing the _mesa tape
    let mut packed = vec![0u8; int8::packed_len(xs.len(), 1024)];
    results.push(bench("int8 quantize_into g=1024 (mesa tape)",
                       samples(20), || {
                           int8::quantize_into(black_box(&xs), 1024,
                                               &mut packed);
                       }));
    let mut dequant = vec![0f32; xs.len()];
    results.push(bench("int8 dequantize_into g=1024 (mesa tape)",
                       samples(20), || {
                           int8::dequantize_into(black_box(&packed),
                                                 1024, &mut dequant);
                       }));
    results.push(bench("nf4 quantize (QLoRA weights)", samples(5), || {
        black_box(nf4::quantize(black_box(&xs), 64));
    }));

    println!("\n== optimizer step (1M params) ==");
    let mut p = Tensor::from_f32(&[1 << 20], &xs);
    let g = Tensor::from_f32(&[1 << 20], &gy);
    let mut opt = AdamW::new(0.01);
    results.push(bench("adamw step 1M", samples(20), || {
        opt.step(&mut [&mut p], std::slice::from_ref(&g), 1e-3);
    }));

    println!("\n== data pipeline ==");
    let task = ImageTask::new(10, 64, 48, 0.5, 0);
    results.push(bench("synth image batch b=16", samples(50), || {
        black_box(task.batch(0, 16));
    }));

    println!("\n== end-to-end train step (native fwd+bwd), per variant ==");
    let rt = Runtime::cpu().expect("native runtime");
    for preset in [
        "vitt_loraqv_gelu_ln",
        "vitt_loraqv_regelu2_msln",
        "vitt_full_gelu_ln",
        "vitt_full_regelu2_msln",
        "llama_loraall_silu_rms",
        "llama_loraall_resilu2_msrms",
        "llama_loraall_silu_rms_swiglu",
        "vitt_loraqv_gelu_ln_ckpt",
        "vitt_loraqv_gelu_ln_mesa",
    ] {
        let art = match load_or_synth(&rt, preset) {
            Ok(a) => a,
            Err(e) => {
                println!("{preset:<44} [unavailable: {e}]");
                continue;
            }
        };
        let params = art.load_params().expect("params");
        let (x, y) = make_batch(&art.manifest);
        // recycling between iterations keeps the executor's arena in
        // its steady state, which is what a real train loop measures
        results.push(bench(&format!("{preset} fwd"), samples(10), || {
            let out = art.run_fwd(&params, &x, &y).expect("fwd");
            art.recycle(black_box(out).residuals);
        }));
        let out = art.run_fwd(&params, &x, &y).expect("fwd");
        results.push(bench(&format!("{preset} bwd"), samples(10), || {
            let grads =
                art.run_bwd(&params, &out.residuals, &x, &y).expect("bwd");
            art.recycle(black_box(grads));
        }));
        art.recycle(out.residuals);
    }

    println!("\n== per-layer fwd/bwd latency (Layer/Tape dispatch) ==");
    // one profiled preset per Layer-impl family: the vitt shape covers
    // Embed/Norm/Linear/Attention/Activation/Head, the ckpt preset adds
    // CkptBlock, the swiglu llama adds SwiGlu (+RoPE inside Attention)
    for preset in ["vitt_loraqv_regelu2_msln", "vitt_loraqv_gelu_ln_ckpt",
                   "llama_loraall_silu_rms_swiglu"] {
        for r in profile_layers(preset, samples(10)) {
            r.report();
            results.push(r);
        }
    }

    println!("\n== multi-tenant engine: sessions on one shared frozen \
              base ==");
    for r in bench_engine(&rt, samples(3)) {
        results.push(r);
    }

    let out_path = repo_root().join("BENCH_hotpath.json");
    // snapshot the previous entries before the overwrite, for the
    // optional end-to-end regression gate below
    let prev = std::fs::read_to_string(&out_path)
        .ok()
        .and_then(|t| ambp::util::json::Json::parse(&t).ok());
    write_json_with_diff(&results, &out_path)
        .expect("write BENCH_hotpath.json");
    println!("\nwrote {} entries to {:?}", results.len(), out_path);

    // AMBP_BENCH_ASSERT=<pct>: fail when the end-to-end refactor
    // canaries regressed by more than <pct>% vs the previous run (off
    // by default — cross-machine BENCH files are not comparable).
    if let Some(tol) = std::env::var("AMBP_BENCH_ASSERT")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
    {
        // Fused execution must not be slower than round-robin on the
        // same 4-session fleet (within tolerance — at bench dims the
        // win is modest and we only guard against regression). Both
        // rows are samples/s from this run, so no previous file is
        // needed.
        let row = |name: &str| {
            results.iter().find(|r| r.name == name).map(|r| r.mean_ns)
        };
        if let (Some(fused), Some(rr)) =
            (row("engine 4 sessions fused samples_per_s"),
             row("engine 4 sessions shared-base samples_per_s"))
        {
            let ratio = fused / rr;
            println!("assert fused/round-robin throughput ratio: \
                      {ratio:.3} (tol {tol}%)");
            assert!(ratio >= 1.0 - tol / 100.0,
                    "fused execution slower than round-robin: \
                     {fused:.1} vs {rr:.1} samples/s");
        }
        let Some(prev) = prev else {
            println!("(no previous BENCH_hotpath.json; assert skipped)");
            return;
        };
        let mut failed = false;
        for name in ["vitt_loraqv_regelu2_msln fwd",
                     "vitt_loraqv_regelu2_msln bwd"] {
            let Some(old) = prev.opt(name).and_then(|v| v.as_f64().ok())
            else {
                continue;
            };
            let Some(new) = results
                .iter()
                .find(|r| r.name == name)
                .map(|r| r.mean_ns)
            else {
                continue;
            };
            let delta = (new - old) / old * 100.0;
            println!("assert {name}: {} -> {} ({delta:+.1}%, tol \
                      {tol}%)",
                     fmt_ns(old), fmt_ns(new));
            if delta > tol {
                failed = true;
            }
        }
        assert!(!failed,
                "end-to-end step regressed beyond AMBP_BENCH_ASSERT \
                 tolerance");
    }
}

/// Run `iters` profiled fwd+bwd steps of `preset` and aggregate
/// per-layer wall-clock into one bench row per `(layer, pass)`.
fn profile_layers(preset: &str, iters: usize) -> Vec<BenchResult> {
    let cfg = parse_preset(preset).expect("preset");
    let model = Model::build(cfg.clone()).expect("build");
    let params = model.init_params(42);
    let (x, y) = sample_batch(&cfg, 0, 0);
    let mut arena = Arena::new();
    let step = |arena: &mut Arena, fp: &mut Profiler,
                bp: &mut Profiler| {
        let (_l, _m, res) = model
            .forward_profiled(arena, &params, &x, &y, fp)
            .expect("fwd");
        let grads = model
            .backward_profiled(arena, &params, &res, &x, &y, bp)
            .expect("bwd");
        for t in res {
            arena.recycle_tensor(t);
        }
        for t in grads {
            arena.recycle_tensor(t);
        }
    };
    // warmup (arena fill + page faults), profiled into a discard sink
    let (mut d1, mut d2) = (Profiler::new(), Profiler::new());
    step(&mut arena, &mut d1, &mut d2);
    let mut fwd_prof = Profiler::new();
    let mut bwd_prof = Profiler::new();
    for _ in 0..iters {
        step(&mut arena, &mut fwd_prof, &mut bwd_prof);
    }
    let mut out = Vec::new();
    for (prof, pass) in [(&fwd_prof, "fwd"), (&bwd_prof, "bwd")] {
        for &(name, total_ns, calls) in prof.rows() {
            let mean = total_ns / calls as f64;
            out.push(BenchResult {
                name: format!("layer {name} {pass} @{preset}"),
                iters: calls as usize,
                mean_ns: mean,
                p50_ns: mean,
                p95_ns: mean,
                min_ns: mean,
            });
        }
    }
    out
}

/// A flat JSON metric row (the value is *not* nanoseconds — the name
/// says what it is): used to record the engine's aggregate throughput
/// and byte peaks next to the latency entries.
fn metric_row(name: &str, value: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: value,
        p50_ns: value,
        p95_ns: value,
        min_ns: value,
    }
}

/// The tenancy benchmark: 1 vs 4 concurrent sessions interleaved on
/// one shared frozen base, vs 4 serial single-job runs of the same
/// work. Records wall-clock rows plus aggregate samples/sec, fleet
/// peak bytes, and resident parameter bytes.
fn bench_engine(rt: &Runtime, iters: usize) -> Vec<BenchResult> {
    use ambp::coordinator::{Engine, Session, StepOutcome, TrainCfg};
    let preset = "vitt_loraqv_regelu2_msln";
    let steps = 4usize;
    let art = load_or_synth(rt, preset).expect("synth");
    let cfg = |seed: u64| TrainCfg {
        steps,
        lr: 1e-3,
        log_every: 0,
        eval_batches: 0,
        seed,
        ..TrainCfg::default()
    };
    // (secs, fleet peak bytes, resident param bytes) of one engine run;
    // like `ambp serve`, the clock covers the interleaved steps only —
    // admission (each session's one-off warmup fwd/bwd) is setup
    let run_concurrent = |k: usize, fuse: bool| -> (f64, u64, u64) {
        let mut engine = Engine::unbounded();
        engine.set_fuse(fuse);
        for i in 0..k {
            engine
                .admit(&format!("s{i}"), &art, cfg(i as u64))
                .expect("admit");
        }
        let t0 = std::time::Instant::now();
        while engine.round().expect("round") > 0 {}
        if fuse {
            assert!(engine.fusion_stats().fused_passes > 0,
                    "fused run never ganged");
        }
        (t0.elapsed().as_secs_f64(), engine.fleet.peak_bytes,
         engine.resident_param_bytes())
    };
    let run_serial = |k: usize| -> (f64, u64) {
        let mut sessions: Vec<Session> = (0..k)
            .map(|i| Session::new(&art, cfg(i as u64)).expect("session"))
            .collect();
        let t0 = std::time::Instant::now();
        let mut peak = 0u64;
        for s in &mut sessions {
            while matches!(s.step().expect("step"),
                           StepOutcome::Stepped(_)) {}
            peak = peak.max(s.memory.peak_bytes);
        }
        (t0.elapsed().as_secs_f64(), peak)
    };

    let mut out = Vec::new();
    let samples_per_run =
        |k: usize| (k * steps * art.manifest.batch) as f64;
    let (s1, peak1, res1) = run_concurrent(1, false);
    let (s4, peak4, res4) = run_concurrent(4, false);
    let (sf, fpeak, _) = run_concurrent(4, true);
    let (ss, speak) = run_serial(4);
    println!("1 session : {:.1} samples/s, fleet peak {:.2} MiB, \
              resident params {:.2} MiB",
             samples_per_run(1) / s1, peak1 as f64 / 1048576.0,
             res1 as f64 / 1048576.0);
    println!("4 sessions: {:.1} samples/s, fleet peak {:.2} MiB, \
              resident params {:.2} MiB (base stored once)",
             samples_per_run(4) / s4, peak4 as f64 / 1048576.0,
             res4 as f64 / 1048576.0);
    println!("4 fused   : {:.1} samples/s, fleet peak {:.2} MiB \
              (one physical pass per layer serves the gang)",
             samples_per_run(4) / sf, fpeak as f64 / 1048576.0);
    println!("4 serial  : {:.1} samples/s, per-job peak {:.2} MiB",
             samples_per_run(4) / ss, speak as f64 / 1048576.0);
    out.push(metric_row("engine 1 session samples_per_s",
                        samples_per_run(1) / s1));
    out.push(metric_row("engine 4 sessions shared-base samples_per_s",
                        samples_per_run(4) / s4));
    out.push(metric_row("engine 4 sessions fused samples_per_s",
                        samples_per_run(4) / sf));
    out.push(metric_row("engine 4 serial jobs samples_per_s",
                        samples_per_run(4) / ss));
    out.push(metric_row("engine 4 sessions fleet peak bytes",
                        peak4 as f64));
    out.push(metric_row("engine 4 sessions resident param bytes",
                        res4 as f64));
    out.push(metric_row("engine 4 serial jobs peak bytes",
                        speak as f64));
    out.push(bench("engine 1 session e2e (4 steps)", iters, || {
        black_box(run_concurrent(1, false));
    }));
    out.push(bench("engine 4 sessions shared-base e2e (4 steps)", iters,
                   || {
                       black_box(run_concurrent(4, false));
                   }));
    out.push(bench("engine 4 sessions fused e2e (4 steps)", iters, || {
        black_box(run_concurrent(4, true));
    }));
    out.push(bench("engine 4 serial jobs e2e (4 steps)", iters, || {
        black_box(run_serial(4));
    }));
    out
}

fn make_batch(m: &ambp::runtime::Manifest) -> (Tensor, Tensor) {
    let mut rng = Rng::new(1);
    match m.arch.as_str() {
        "vit" => {
            let n: usize = m.x.shape.iter().product();
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let ny: usize = m.y.shape.iter().product();
            let y: Vec<i32> =
                (0..ny).map(|_| rng.below(m.n_classes) as i32).collect();
            (Tensor::from_f32(&m.x.shape, &x), Tensor::from_i32(&m.y.shape, &y))
        }
        _ => {
            let n: usize = m.x.shape.iter().product();
            let x: Vec<i32> =
                (0..n).map(|_| rng.below(m.vocab) as i32).collect();
            let ny: usize = m.y.shape.iter().product();
            let hi = if m.arch == "llama" { m.vocab } else { m.n_classes };
            let y: Vec<i32> = (0..ny).map(|_| rng.below(hi) as i32).collect();
            (Tensor::from_i32(&m.x.shape, &x), Tensor::from_i32(&m.y.shape, &y))
        }
    }
}
