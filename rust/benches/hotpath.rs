//! End-to-end hot-path benchmarks (in-tree harness; criterion is
//! unavailable offline). One section per paper table's cost driver:
//! fwd/bwd step latency per variant (Tables 1–4 throughput columns),
//! packing/codec microbenches, optimizer step, data synthesis.
//!
//!   make artifacts && cargo bench --bench hotpath

use ambp::coordinator::optimizer::{AdamW, Optimizer};
use ambp::data::synth_images::ImageTask;
use ambp::packing;
use ambp::quant::{int8, nf4};
use ambp::runtime::{Artifact, Runtime, Tensor};
use ambp::util::bench::{bench, black_box};
use ambp::util::rng::Rng;

fn main() {
    println!("== packing / codec microbenches (1M elements) ==");
    let mut rng = Rng::new(0);
    let xs: Vec<f32> = (0..1 << 20).map(|_| rng.normal_f32() * 3.0).collect();
    let gy: Vec<f32> = (0..1 << 20).map(|_| rng.normal_f32()).collect();
    let comb = ambp::coeffs::funcs::PAPER_GELU;
    let codes = packing::bucketize2(&xs, comb.c);
    let packed = packing::pack2(&codes);
    bench("bucketize2 (encode)", 20, || {
        black_box(packing::bucketize2(black_box(&xs), comb.c));
    });
    bench("pack2", 20, || {
        black_box(packing::pack2(black_box(&codes)));
    });
    bench("apply_slopes (decode-bwd)", 20, || {
        black_box(packing::apply_slopes(black_box(&packed), &gy,
                                        comb.slopes()));
    });
    bench("int8 quant_rows (Mesa baseline)", 20, || {
        black_box(int8::quant_rows(black_box(&xs), 1024));
    });
    bench("nf4 quantize (QLoRA weights)", 5, || {
        black_box(nf4::quantize(black_box(&xs), 64));
    });

    println!("\n== optimizer step (1M params) ==");
    let mut p = Tensor::from_f32(&[1 << 20], &xs);
    let g = Tensor::from_f32(&[1 << 20], &gy);
    let mut opt = AdamW::new(0.01);
    bench("adamw step 1M", 20, || {
        opt.step(&mut [&mut p], std::slice::from_ref(&g), 1e-3);
    });

    println!("\n== data pipeline ==");
    let task = ImageTask::new(10, 64, 48, 0.5, 0);
    bench("synth image batch b=16", 50, || {
        black_box(task.batch(0, 16));
    });

    println!("\n== end-to-end train step (PJRT fwd+bwd), per variant ==");
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("PJRT unavailable: {e}");
            return;
        }
    };
    for preset in [
        "vitt_loraqv_gelu_ln",
        "vitt_loraqv_regelu2_msln",
        "vitt_loraqv_mesa_mesaln",
        "vitt_loraqv_gelu_ln_ckpt",
        "llama_loraall_silu_rms",
        "llama_loraall_resilu2_msrms",
    ] {
        let dir = ambp::runtime::artifacts_dir().join(preset);
        if !dir.join("manifest.json").is_file() {
            println!("{preset:<44} [artifact not built — make artifacts]");
            continue;
        }
        let art = Artifact::load(&rt, &dir).expect("load artifact");
        let params = art.load_params().expect("params");
        let m = &art.manifest;
        let (x, y) = make_batch(m);
        bench(&format!("{preset} fwd"), 10, || {
            black_box(art.run_fwd(&params, &x, &y).expect("fwd"));
        });
        let out = art.run_fwd(&params, &x, &y).expect("fwd");
        bench(&format!("{preset} bwd"), 10, || {
            black_box(
                art.run_bwd(&params, &out.residuals, &x, &y).expect("bwd"),
            );
        });
    }
}

fn make_batch(m: &ambp::runtime::Manifest) -> (Tensor, Tensor) {
    let mut rng = Rng::new(1);
    match m.arch.as_str() {
        "vit" => {
            let n: usize = m.x.shape.iter().product();
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let ny: usize = m.y.shape.iter().product();
            let y: Vec<i32> =
                (0..ny).map(|_| rng.below(m.n_classes) as i32).collect();
            (Tensor::from_f32(&m.x.shape, &x), Tensor::from_i32(&m.y.shape, &y))
        }
        _ => {
            let n: usize = m.x.shape.iter().product();
            let x: Vec<i32> =
                (0..n).map(|_| rng.below(m.vocab) as i32).collect();
            let ny: usize = m.y.shape.iter().product();
            let hi = if m.arch == "llama" { m.vocab } else { m.n_classes };
            let y: Vec<i32> = (0..ny).map(|_| rng.below(hi) as i32).collect();
            (Tensor::from_i32(&m.x.shape, &x), Tensor::from_i32(&m.y.shape, &y))
        }
    }
}
