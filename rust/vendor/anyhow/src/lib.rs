//! Minimal, API-compatible subset of the `anyhow` error crate.
//!
//! The offline testbed has no crates.io access, so this in-tree shim
//! provides the slice of `anyhow` the workspace actually uses:
//!
//! * [`Error`]: an opaque boxed error with a human-readable context chain.
//! * [`Result<T>`]: `std::result::Result<T, Error>` with a defaultable
//!   error type, so `anyhow::Result<T, E>` also works.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `impl From<E: std::error::Error>` coherent.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message plus an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a standard error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend a higher-level context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause, if this error wraps a standard error.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }

    /// Walk the source chain for the first error of concrete type `E`.
    ///
    /// Like the real crate's method of the same name, this is how
    /// callers classify an opaque `Error` (e.g. "was this caused by an
    /// `io::Error`?"). Context frames in this shim only rewrite the
    /// message, so the chain from `source()` down is the full chain.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        let mut src = self.source();
        while let Some(e) = src {
            if let Some(hit) = e.downcast_ref::<E>() {
                return Some(hit);
            }
            src = e.source();
        }
        None
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source();
        while let Some(e) = src {
            write!(f, "\n\ncaused by: {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod private {
    /// Anything `.context(..)` can promote into an [`crate::Error`].
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::new(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Attach context to the error variant of a `Result` or to a `None`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::other("disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_chains_messages() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: disk on fire");
        let e = Result::<(), Error>::Err(e)
            .with_context(|| format!("loading {}", "x"))
            .unwrap_err();
        assert!(e.to_string().starts_with("loading x: reading"));
    }

    #[test]
    fn downcast_ref_walks_the_chain() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").context("outermost").unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().unwrap();
        assert_eq!(io.to_string(), "disk on fire");
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // A message-only error has no chain to walk.
        assert!(anyhow!("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            ensure!(x != 13);
            Ok(x)
        }
        assert_eq!(f(7).unwrap(), 7);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        assert!(f(13).unwrap_err().to_string().contains("x != 13"));
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
