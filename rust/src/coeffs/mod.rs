//! Appendix E substrate: re-derive the ReGELU2/ReSiLU2 coefficients.
//!
//! Solves min_{a,c} ∫ (h(x) − h̃_{a,c}(x))² dx   (eq. 14)
//! over the tail-bounded interval (eqs. 43–45 / 49–51), by simulated
//! annealing + Nelder–Mead polish, and the derivative-matching variant
//! (eq. 63, ReGELU2-d). `exp appe` checks agreement with the paper's
//! published constants.

pub mod anneal;
pub mod funcs;
pub mod integrate;

use anneal::{anneal, nelder_mead, SaOpts};
use funcs::{dgelu, gelu, silu, ReluComb};
use integrate::integrate_piecewise;

/// L2 objective between primitive h and h̃_{a,c} on [lo, hi].
pub fn objective<H: Fn(f64) -> f64>(h: &H, comb: &ReluComb, lo: f64,
                                    hi: f64) -> f64 {
    let f = |x: f64| {
        let d = h(x) - comb.eval(x);
        d * d
    };
    integrate_piecewise(&f, lo, hi, &comb.c, 1e-10)
}

/// Derivative-matching objective (eq. 63) — breakpoints make the
/// integrand piecewise smooth; integrate piece-by-piece.
pub fn objective_d<H: Fn(f64) -> f64>(dh: &H, comb: &ReluComb, lo: f64,
                                      hi: f64) -> f64 {
    let f = |x: f64| {
        let d = dh(x) - comb.derivative(x);
        d * d
    };
    integrate_piecewise(&f, lo, hi, &comb.c, 1e-10)
}

fn vec_to_comb(v: &[f64]) -> ReluComb {
    ReluComb { a: [v[0], v[1]], c: [v[2], v[3], v[4]] }
}

/// Result of a coefficient solve.
pub struct Solved {
    /// The optimized 3-ReLU combination.
    pub comb: ReluComb,
    /// Final objective value (eq. 14 / eq. 63).
    pub objective: f64,
}

fn solve<H: Fn(f64) -> f64 + Sync>(h: H, lo: f64, hi: f64, x0: &[f64; 5],
                                   seed: u64, derivative: bool) -> Solved {
    let obj = |v: &[f64]| {
        let comb = vec_to_comb(v);
        // keep thresholds ordered; penalize violations smoothly
        let mut pen = 0.0;
        if comb.c[0] > comb.c[1] {
            pen += (comb.c[0] - comb.c[1]).powi(2) * 10.0;
        }
        if comb.c[1] > comb.c[2] {
            pen += (comb.c[1] - comb.c[2]).powi(2) * 10.0;
        }
        let o = if derivative {
            objective_d(&h, &comb, lo, hi)
        } else {
            objective(&h, &comb, lo, hi)
        };
        o + pen
    };
    let opts = SaOpts { iters: 8_000, seed, ..Default::default() };
    let (x, _) = anneal(&obj, x0, &opts);
    let (x, fx) = nelder_mead(&obj, &x, 0.05, 4_000);
    let (x, fx2) = nelder_mead(&obj, &x, 0.005, 4_000);
    let fx = fx.min(fx2);
    Solved { comb: vec_to_comb(&x), objective: fx }
}

/// Tail bound for GELU (eq. 43–45): B = √(−2 ln ε).
pub fn gelu_bound(eps: f64) -> f64 {
    (-2.0 * eps.ln()).sqrt()
}

/// Tail bound for SiLU (eq. 49–51): B = −2 ln(ε/2).
pub fn silu_bound(eps: f64) -> f64 {
    -2.0 * (eps / 2.0).ln()
}

/// Re-derive the ReGELU2 coefficients (Appendix E, eq. 14 objective).
pub fn solve_gelu(seed: u64) -> Solved {
    let b = gelu_bound(1e-8);
    solve(gelu, -b, b, &[-0.05, 1.1, -3.0, 0.0, 3.0], seed, false)
}

/// Re-derive the ReSiLU2 coefficients (Appendix E, eq. 14 objective).
pub fn solve_silu(seed: u64) -> Solved {
    let b = silu_bound(1e-8);
    solve(silu, -b, b, &[-0.04, 1.08, -6.0, 0.0, 6.0], seed, false)
}

/// Re-derive the ReGELU2-d coefficients (Appendix I, derivative
/// objective, eq. 63).
pub fn solve_gelu_d(seed: u64) -> Solved {
    // derivative objective decays fast; a modest window suffices
    solve(dgelu, -8.0, 8.0, &[0.33, 0.35, -0.5, 0.0, 0.5], seed, true)
}

#[cfg(test)]
mod tests {
    use super::funcs::{PAPER_GELU, PAPER_GELU_D, PAPER_SILU};
    use super::*;

    #[test]
    fn paper_gelu_objective_value() {
        let b = gelu_bound(1e-8);
        let o = objective(&gelu, &PAPER_GELU, -b, b);
        assert!(o > 0.0 && o < 0.011, "{o}");
    }

    #[test]
    fn paper_silu_objective_value() {
        let b = silu_bound(1e-8);
        let o = objective(&silu, &PAPER_SILU, -b, b);
        assert!(o > 0.0 && o < 0.045, "{o}");
    }

    #[test]
    fn solver_matches_paper_gelu() {
        let s = solve_gelu(0);
        let b = gelu_bound(1e-8);
        let paper = objective(&gelu, &PAPER_GELU, -b, b);
        // our optimum must be at least as good as the paper's constants
        assert!(s.objective <= paper * 1.02,
                "ours {} vs paper {paper}", s.objective);
        // and land on (a close cousin of) the same solution
        for (got, want) in s.comb.a.iter().zip(&PAPER_GELU.a) {
            assert!((got - want).abs() < 0.05, "{:?}", s.comb);
        }
    }

    #[test]
    fn solver_matches_paper_silu() {
        let s = solve_silu(0);
        let b = silu_bound(1e-8);
        let paper = objective(&silu, &PAPER_SILU, -b, b);
        assert!(s.objective <= paper * 1.02,
                "ours {} vs paper {paper}", s.objective);
        for (got, want) in s.comb.a.iter().zip(&PAPER_SILU.a) {
            assert!((got - want).abs() < 0.05, "{:?}", s.comb);
        }
    }

    #[test]
    fn solver_matches_paper_gelu_d() {
        let s = solve_gelu_d(0);
        let paper = objective_d(&dgelu, &PAPER_GELU_D, -8.0, 8.0);
        assert!(s.objective <= paper * 1.05,
                "ours {} vs paper {paper}", s.objective);
    }

    #[test]
    fn tail_bounds_match_appendix() {
        // ε=1e-8 → B = √(−2 ln ε) ≈ 6.07 (gelu), −2 ln(ε/2) ≈ 38.2 (silu)
        assert!((gelu_bound(1e-8) - 6.069).abs() < 0.01);
        assert!((silu_bound(1e-8) - 38.23).abs() < 0.3);
    }
}
