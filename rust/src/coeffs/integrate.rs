//! Adaptive Simpson quadrature (QUADPACK stand-in, Appendix E's
//! "definite integral over a bounded interval ... by numerical methods").

/// Adaptive Simpson on [a, b] to absolute tolerance `tol`.
pub fn adaptive_simpson<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64,
                                           tol: f64) -> f64 {
    let fa = f(a);
    let fb = f(b);
    let m = 0.5 * (a + b);
    let fm = f(m);
    let whole = simpson(a, b, fa, fm, fb);
    rec(f, a, b, fa, fm, fb, whole, tol, 50)
}

fn simpson(a: f64, b: f64, fa: f64, fm: f64, fb: f64) -> f64 {
    (b - a) / 6.0 * (fa + 4.0 * fm + fb)
}

#[allow(clippy::too_many_arguments)]
fn rec<F: Fn(f64) -> f64>(f: &F, a: f64, b: f64, fa: f64, fm: f64,
                          fb: f64, whole: f64, tol: f64,
                          depth: u32) -> f64 {
    let m = 0.5 * (a + b);
    let lm = 0.5 * (a + m);
    let rm = 0.5 * (m + b);
    let flm = f(lm);
    let frm = f(rm);
    let left = simpson(a, m, fa, flm, fm);
    let right = simpson(m, b, fm, frm, fb);
    let delta = left + right - whole;
    if depth == 0 || delta.abs() <= 15.0 * tol {
        left + right + delta / 15.0
    } else {
        rec(f, a, m, fa, flm, fm, left, 0.5 * tol, depth - 1)
            + rec(f, m, b, fm, frm, fb, right, 0.5 * tol, depth - 1)
    }
}

/// Piecewise integration with interior breakpoints (the h̃ kinks at c_i):
/// integrating each smooth piece separately keeps Simpson's convergence.
pub fn integrate_piecewise<F: Fn(f64) -> f64>(
    f: &F, a: f64, b: f64, breaks: &[f64], tol: f64,
) -> f64 {
    let mut pts: Vec<f64> = vec![a];
    let mut br: Vec<f64> = breaks
        .iter()
        .copied()
        .filter(|x| *x > a && *x < b)
        .collect();
    br.sort_by(|x, y| x.partial_cmp(y).unwrap());
    pts.extend(br);
    pts.push(b);
    let per = tol / (pts.len() - 1) as f64;
    pts.windows(2)
        .map(|w| adaptive_simpson(f, w[0], w[1], per))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_polynomial_exactly() {
        // Simpson is exact for cubics
        let f = |x: f64| x * x * x - 2.0 * x + 1.0;
        let got = adaptive_simpson(&f, -1.0, 3.0, 1e-12);
        // ∫ = x⁴/4 − x² + x → (81/4−9+3) − (1/4−1−1) = 14.25 + 1.75
        assert!((got - 16.0).abs() < 1e-9, "{got}");
    }

    #[test]
    fn integrates_gaussian() {
        let f = |x: f64| (-x * x).exp();
        let got = adaptive_simpson(&f, -8.0, 8.0, 1e-12);
        assert!((got - std::f64::consts::PI.sqrt()).abs() < 1e-9, "{got}");
    }

    #[test]
    fn integrates_abs_with_breakpoint() {
        let f = |x: f64| x.abs();
        let got = integrate_piecewise(&f, -1.0, 1.0, &[0.0], 1e-12);
        assert!((got - 1.0).abs() < 1e-10, "{got}");
    }

    #[test]
    fn kinked_integrand_converges() {
        // integrand with two kinks: ∫₀³ max(x-1,0)·max(2-x,0) dx
        let f = |x: f64| (x - 1.0f64).max(0.0) * (2.0 - x).max(0.0);
        let got = integrate_piecewise(&f, 0.0, 3.0, &[1.0, 2.0], 1e-12);
        // on [1,2]: ∫ (x-1)(2-x) dx = 1/6
        assert!((got - 1.0 / 6.0).abs() < 1e-10, "{got}");
    }
}
