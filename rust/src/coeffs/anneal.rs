//! Simulated annealing (Kirkpatrick et al., 1983) — the paper's solver for
//! the 5-scalar problem (14), plus a Nelder–Mead polish stage matching the
//! "SGD also works, searched multiple inits" remark in Appendix E.

use crate::util::rng::Rng;

/// Simulated-annealing hyperparameters.
pub struct SaOpts {
    /// Total proposal iterations.
    pub iters: usize,
    /// Initial temperature.
    pub t0: f64,
    /// Final temperature (geometric cooling to `t1`).
    pub t1: f64,
    /// Initial per-coordinate proposal scale.
    pub step0: f64,
    /// RNG seed (runs are deterministic given the seed).
    pub seed: u64,
}

impl Default for SaOpts {
    fn default() -> Self {
        SaOpts { iters: 30_000, t0: 1e-2, t1: 1e-9, step0: 0.5, seed: 0 }
    }
}

/// Minimize `f` over R^n starting at `x0`; returns (x*, f(x*)).
pub fn anneal<F: Fn(&[f64]) -> f64>(f: &F, x0: &[f64],
                                    opts: &SaOpts) -> (Vec<f64>, f64) {
    let mut rng = Rng::new(opts.seed);
    let n = x0.len();
    let mut x = x0.to_vec();
    let mut fx = f(&x);
    let mut best = x.clone();
    let mut fbest = fx;
    let cool = (opts.t1 / opts.t0).powf(1.0 / opts.iters as f64);
    let mut t = opts.t0;
    for it in 0..opts.iters {
        // proposal scale tracks the temperature schedule
        let frac = it as f64 / opts.iters as f64;
        let step = opts.step0 * (1.0 - 0.95 * frac);
        let mut cand = x.clone();
        let k = rng.below(n);
        cand[k] += rng.normal() * step;
        let fc = f(&cand);
        let accept = fc < fx || rng.f64() < ((fx - fc) / t).exp();
        if accept {
            x = cand;
            fx = fc;
            if fx < fbest {
                best = x.clone();
                fbest = fx;
            }
        }
        t *= cool;
    }
    (best, fbest)
}

/// Nelder–Mead downhill simplex polish.
pub fn nelder_mead<F: Fn(&[f64]) -> f64>(
    f: &F, x0: &[f64], scale: f64, iters: usize,
) -> (Vec<f64>, f64) {
    let n = x0.len();
    let mut simplex: Vec<Vec<f64>> = vec![x0.to_vec()];
    for i in 0..n {
        let mut v = x0.to_vec();
        v[i] += scale;
        simplex.push(v);
    }
    let mut fv: Vec<f64> = simplex.iter().map(|v| f(v)).collect();
    for _ in 0..iters {
        // sort simplex by f
        let mut idx: Vec<usize> = (0..=n).collect();
        idx.sort_by(|&a, &b| fv[a].partial_cmp(&fv[b]).unwrap());
        let ordered: Vec<Vec<f64>> =
            idx.iter().map(|&i| simplex[i].clone()).collect();
        let fo: Vec<f64> = idx.iter().map(|&i| fv[i]).collect();
        simplex = ordered;
        fv = fo;
        if (fv[n] - fv[0]).abs() < 1e-15 {
            break;
        }
        // centroid of all but worst
        let mut c = vec![0.0; n];
        for v in &simplex[..n] {
            for (ci, vi) in c.iter_mut().zip(v) {
                *ci += vi / n as f64;
            }
        }
        let worst = simplex[n].clone();
        let refl: Vec<f64> = c
            .iter()
            .zip(&worst)
            .map(|(ci, wi)| ci + (ci - wi))
            .collect();
        let fr = f(&refl);
        if fr < fv[0] {
            // expand
            let exp: Vec<f64> = c
                .iter()
                .zip(&worst)
                .map(|(ci, wi)| ci + 2.0 * (ci - wi))
                .collect();
            let fe = f(&exp);
            if fe < fr {
                simplex[n] = exp;
                fv[n] = fe;
            } else {
                simplex[n] = refl;
                fv[n] = fr;
            }
        } else if fr < fv[n - 1] {
            simplex[n] = refl;
            fv[n] = fr;
        } else {
            // contract
            let con: Vec<f64> = c
                .iter()
                .zip(&worst)
                .map(|(ci, wi)| ci + 0.5 * (wi - ci))
                .collect();
            let fc = f(&con);
            if fc < fv[n] {
                simplex[n] = con;
                fv[n] = fc;
            } else {
                // shrink toward best
                let bestv = simplex[0].clone();
                for v in simplex.iter_mut().skip(1) {
                    for (vi, bi) in v.iter_mut().zip(&bestv) {
                        *vi = bi + 0.5 * (*vi - bi);
                    }
                }
                for i in 1..=n {
                    fv[i] = f(&simplex[i]);
                }
            }
        }
    }
    let mut besti = 0;
    for i in 1..=n {
        if fv[i] < fv[besti] {
            besti = i;
        }
    }
    (simplex[besti].clone(), fv[besti])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rosenbrock(x: &[f64]) -> f64 {
        (1.0 - x[0]).powi(2) + 100.0 * (x[1] - x[0] * x[0]).powi(2)
    }

    fn sphere5(x: &[f64]) -> f64 {
        x.iter().enumerate()
            .map(|(i, v)| (v - i as f64 * 0.1).powi(2))
            .sum()
    }

    #[test]
    fn nm_solves_rosenbrock() {
        let (x, fx) = nelder_mead(&rosenbrock, &[-1.2, 1.0], 0.5, 2000);
        assert!(fx < 1e-10, "{fx}");
        assert!((x[0] - 1.0).abs() < 1e-4 && (x[1] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn sa_plus_nm_solves_sphere() {
        let opts = SaOpts { iters: 5000, ..Default::default() };
        let (x, _) = anneal(&sphere5, &[2.0; 5], &opts);
        let (x, fx) = nelder_mead(&sphere5, &x, 0.1, 1000);
        assert!(fx < 1e-10, "{fx}");
        for (i, v) in x.iter().enumerate() {
            assert!((v - i as f64 * 0.1).abs() < 1e-4);
        }
    }

    #[test]
    fn sa_is_deterministic_given_seed() {
        let opts = SaOpts { iters: 1000, ..Default::default() };
        let a = anneal(&sphere5, &[2.0; 5], &opts);
        let b = anneal(&sphere5, &[2.0; 5], &opts);
        assert_eq!(a.0, b.0);
    }
}
