//! Scalar math for the coefficient solver: erf, GELU, SiLU, and the
//! 3-ReLU combination h̃_{a,c} (eq. 13, k = 2).

/// Error function, |rel err| < 1.2e-7 (Numerical Recipes erfc rational
/// Chebyshev fit). Good enough: the objective integrand only needs ~1e-7.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function (`1 − erf`).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223
                                            + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Exact GELU, eq. (40).
pub fn gelu(x: f64) -> f64 {
    0.5 * x * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Exact GELU derivative: `Φ(x) + x·φ(x)`.
pub fn dgelu(x: f64) -> f64 {
    let cdf = 0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2));
    let pdf = (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt();
    cdf + x * pdf
}

/// SiLU, eq. (47).
pub fn silu(x: f64) -> f64 {
    x / (1.0 + (-x).exp())
}

/// Exact SiLU derivative: `σ(x)·(1 + x·(1 − σ(x)))`.
pub fn dsilu(x: f64) -> f64 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// Coefficients of the 3-ReLU combination.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReluComb {
    pub a: [f64; 2],
    pub c: [f64; 3],
}

impl ReluComb {
    pub fn eval(&self, x: f64) -> f64 {
        let [a1, a2] = self.a;
        let [c1, c2, c3] = self.c;
        a1 * (x - c1).max(0.0)
            + a2 * (x - c2).max(0.0)
            + (1.0 - a1 - a2) * (x - c3).max(0.0)
    }

    /// The 4-segment step derivative (Prop 4.3): [0, a1, a1+a2, 1].
    pub fn slopes(&self) -> [f64; 4] {
        [0.0, self.a[0], self.a[0] + self.a[1], 1.0]
    }

    /// 2-bit segment code of x against the thresholds.
    pub fn code(&self, x: f64) -> u8 {
        (x >= self.c[0]) as u8 + (x >= self.c[1]) as u8
            + (x >= self.c[2]) as u8
    }

    pub fn derivative(&self, x: f64) -> f64 {
        self.slopes()[self.code(x) as usize]
    }

    /// Zero-intercept constraint value of eq. (13) (should be ≈ 0).
    pub fn constraint(&self) -> f64 {
        let [a1, a2] = self.a;
        let [c1, c2, c3] = self.c;
        a1 * c1 + a2 * c2 + (1.0 - a1 - a2) * c3
    }
}

/// The paper's published solutions (Appendix E / I).
pub const PAPER_GELU: ReluComb = ReluComb {
    a: [-0.04922261145617846, 1.0979632065417297],
    c: [-3.1858810036855245, -0.001178821281161997, 3.190832613414926],
};

pub const PAPER_SILU: ReluComb = ReluComb {
    a: [-0.04060357190528599, 1.080925428529668],
    c: [-6.3050461001646445, -0.0008684942046214787, 6.325815242089708],
};

pub const PAPER_GELU_D: ReluComb = ReluComb {
    a: [0.32465931184406527, 0.34812875668739607],
    c: [-0.4535743722857079, -0.0010587205574873046, 0.4487575313884231],
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // table values of erf
        for (x, want) in [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (-1.0, -0.8427007929),
        ] {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x})");
        }
    }

    #[test]
    fn gelu_values() {
        assert!((gelu(0.0)).abs() < 1e-12);
        assert!((gelu(1.0) - 0.8413447461).abs() < 1e-6);
        assert!((gelu(-1.0) + 0.1586552539).abs() < 1e-6);
        // limits: gelu(x) → x for large x, → 0 for very negative x
        assert!((gelu(20.0) - 20.0).abs() < 1e-9);
        assert!(gelu(-20.0).abs() < 1e-9);
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0)).abs() < 1e-12);
        assert!((silu(1.0) - 0.7310585786).abs() < 1e-9);
        assert!((silu(-30.0)).abs() < 1e-9);
    }

    #[test]
    fn derivatives_match_finite_difference() {
        let h = 1e-6;
        for x in [-3.0, -1.0, -0.1, 0.0, 0.3, 1.7, 4.0] {
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((dgelu(x) - fd).abs() < 1e-5, "dgelu({x})");
            let fd = (silu(x + h) - silu(x - h)) / (2.0 * h);
            assert!((dsilu(x) - fd).abs() < 1e-5, "dsilu({x})");
        }
    }

    #[test]
    fn relu_comb_limiting_behavior() {
        // Prop 4.3: h̃ → h at ±∞
        for (comb, h) in [(PAPER_GELU, gelu as fn(f64) -> f64),
                          (PAPER_SILU, silu as fn(f64) -> f64)] {
            assert!((comb.eval(50.0) - h(50.0)).abs() < 1e-4);
            assert!((comb.eval(-50.0) - h(-50.0)).abs() < 1e-4);
        }
    }

    #[test]
    fn paper_constraint_nearly_zero() {
        assert!(PAPER_GELU.constraint().abs() < 2e-2);
        assert!(PAPER_SILU.constraint().abs() < 2e-2);
    }

    #[test]
    fn golden_appendix_e_coefficients() {
        // Pin the published Appendix E / I solutions bit-for-bit: any
        // edit to these constants is a deliberate, reviewed change.
        let g = PAPER_GELU;
        assert_eq!(g.a, [-0.04922261145617846, 1.0979632065417297]);
        assert_eq!(
            g.c,
            [-3.1858810036855245, -0.001178821281161997,
             3.190832613414926]
        );
        let s = PAPER_SILU;
        assert_eq!(s.a, [-0.04060357190528599, 1.080925428529668]);
        assert_eq!(
            s.c,
            [-6.3050461001646445, -0.0008684942046214787,
             6.325815242089708]
        );
        let d = PAPER_GELU_D;
        assert_eq!(d.a, [0.32465931184406527, 0.34812875668739607]);
        assert_eq!(
            d.c,
            [-0.4535743722857079, -0.0010587205574873046,
             0.4487575313884231]
        );
        // derived quantities the kernels depend on
        assert!((g.slopes()[2] - (g.a[0] + g.a[1])).abs() < 1e-15);
        assert!(g.constraint().abs() < 2e-2);
        assert!(s.constraint().abs() < 2e-2);
    }

    #[test]
    fn step_derivative_segments() {
        let c = PAPER_GELU;
        assert_eq!(c.derivative(-10.0), 0.0);
        assert_eq!(c.derivative(-1.0), c.a[0]);
        assert_eq!(c.derivative(1.0), c.a[0] + c.a[1]);
        assert_eq!(c.derivative(10.0), 1.0);
    }
}
