//! Deterministic fault injection: site-keyed, step-counted fault
//! points, compiled in always and armed at runtime.
//!
//! A *fault point* is a named call site (`trip("spool.write")`) that
//! normally does nothing. Arming a plan — from the `AMBP_FAULTS`
//! environment variable or programmatically via [`arm`] — makes
//! selected sites misbehave on selected hits:
//!
//! ```text
//! AMBP_FAULTS=site:hit:kind[:count][,site:hit:kind[:count]...]
//!            site  — site key, optionally scoped: "s1/step.loss"
//!            hit   — 0-based hit index at which the fault fires
//!            kind  — panic | io | nan
//!            count — number of consecutive hits that fault
//!                    (default 1; "*" = every hit from `hit` on)
//! ```
//!
//! Scoping: the engine wraps each tenant's step in
//! [`with_scope`]`(name, ..)`; a spec keyed `"name/site"` matches only
//! hits made under that scope, while a bare `"site"` spec matches hits
//! from any (or no) scope. Scoped and bare specs keep independent hit
//! counters, so "the 2nd spool write of tenant s1" is expressible even
//! when other tenants write in between.
//!
//! Kinds:
//! * `panic` — [`trip`] panics with a recognizable message (the
//!   supervisor's `catch_unwind` sees it like any library panic).
//! * `io`    — [`trip`] returns `Err(io::Error)` of kind `Other` with
//!   a recognizable message (models a transient I/O fault).
//! * `nan`   — [`trip`] returns `Ok(true)`: the *call site* corrupts
//!   its own data (poison a loss, flip a byte) — the harness cannot
//!   know what "NaN" means for an arbitrary site.
//!
//! The armed check is a single relaxed atomic load when no plan is
//! armed, so leaving the sites compiled into release builds is free.

use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// What an armed site does when its hit index comes up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InjectKind {
    /// `trip` panics.
    Panic,
    /// `trip` returns an injected `io::Error`.
    Io,
    /// `trip` returns `Ok(true)`; the call site corrupts its own data.
    Nan,
}

impl InjectKind {
    fn parse(s: &str) -> Option<InjectKind> {
        match s {
            "panic" => Some(InjectKind::Panic),
            "io" => Some(InjectKind::Io),
            "nan" => Some(InjectKind::Nan),
            _ => None,
        }
    }
}

/// One armed fault: fire `kind` at `site` on hit indices
/// `[at, at + count)` (count == u32::MAX means "forever").
#[derive(Clone, Debug)]
struct FaultSpec {
    site: String,
    at: u32,
    kind: InjectKind,
    count: u32,
    hits: u32,
}

fn plan() -> &'static Mutex<Vec<FaultSpec>> {
    static PLAN: OnceLock<Mutex<Vec<FaultSpec>>> = OnceLock::new();
    PLAN.get_or_init(|| Mutex::new(Vec::new()))
}

/// Fast path: false ⇒ no spec is armed and `hit` returns None without
/// taking the lock.
static ARMED: AtomicBool = AtomicBool::new(false);

/// `AMBP_FAULTS` is read once, lazily, on the first `hit`/`arm`.
fn env_init() {
    static INIT: OnceLock<()> = OnceLock::new();
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("AMBP_FAULTS") {
            if !v.trim().is_empty() {
                // Env arming is best-effort: a malformed var aborts
                // loudly rather than silently running faultless.
                arm(&v).expect("malformed AMBP_FAULTS");
            }
        }
    });
}

/// Parse a fault plan (`site:hit:kind[:count],…`) and add it to the
/// armed set. Specs accumulate across calls; use [`clear`] to reset.
pub fn arm(spec: &str) -> Result<(), String> {
    let mut specs = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        // site may itself contain '/' but not ':'.
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() < 3 || fields.len() > 4 {
            return Err(format!(
                "fault spec `{part}`: want site:hit:kind[:count]"
            ));
        }
        let at: u32 = fields[1]
            .parse()
            .map_err(|_| format!("fault spec `{part}`: bad hit index"))?;
        let kind = InjectKind::parse(fields[2]).ok_or(format!(
            "fault spec `{part}`: kind must be panic|io|nan"
        ))?;
        let count: u32 = match fields.get(3) {
            None => 1,
            Some(&"*") => u32::MAX,
            Some(c) => c
                .parse()
                .map_err(|_| format!("fault spec `{part}`: bad count"))?,
        };
        specs.push(FaultSpec {
            site: fields[0].to_string(),
            at,
            kind,
            count,
            hits: 0,
        });
    }
    if !specs.is_empty() {
        plan().lock().unwrap().append(&mut specs);
        ARMED.store(true, Ordering::Release);
    }
    Ok(())
}

/// Disarm everything and reset all hit counters.
pub fn clear() {
    plan().lock().unwrap().clear();
    ARMED.store(false, Ordering::Release);
}

/// Serialize tests that arm fault plans: the guard holds a process-wide
/// mutex and clears the plan on acquire and on drop, so `cargo test`'s
/// in-binary parallelism cannot interleave two armed plans.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        clear();
    }
}

pub fn exclusive() -> FaultGuard {
    static GATE: Mutex<()> = Mutex::new(());
    let lock = GATE.lock().unwrap_or_else(|e| e.into_inner());
    clear();
    FaultGuard { _lock: lock }
}

thread_local! {
    static SCOPE: std::cell::RefCell<Vec<String>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Run `f` with hits attributed to scope `name`: a spec keyed
/// `"name/site"` matches only inside, a bare `"site"` spec still
/// matches everywhere. Scopes nest; the innermost wins for prefixing.
pub fn with_scope<R>(name: &str, f: impl FnOnce() -> R) -> R {
    SCOPE.with(|s| s.borrow_mut().push(name.to_string()));
    struct Pop;
    impl Drop for Pop {
        fn drop(&mut self) {
            SCOPE.with(|s| {
                s.borrow_mut().pop();
            });
        }
    }
    let _pop = Pop;
    f()
}

fn current_scope() -> Option<String> {
    SCOPE.with(|s| s.borrow().last().cloned())
}

/// Record a hit at `site`; returns the kind to inject if an armed spec
/// fires on this hit. Both the scoped key (`"{scope}/{site}"`) and the
/// bare key count hits independently; if both fire, scoped wins.
pub fn hit(site: &str) -> Option<InjectKind> {
    env_init();
    if !ARMED.load(Ordering::Acquire) {
        return None;
    }
    let scoped = current_scope().map(|sc| format!("{sc}/{site}"));
    let mut fired = None;
    let mut specs = plan().lock().unwrap();
    for spec in specs.iter_mut() {
        let matches = spec.site == site
            || scoped.as_deref() == Some(spec.site.as_str());
        if !matches {
            continue;
        }
        let n = spec.hits;
        spec.hits = spec.hits.saturating_add(1);
        let firing = n >= spec.at
            && (spec.count == u32::MAX
                || n < spec.at.saturating_add(spec.count));
        if firing {
            // Scoped specs take precedence over bare ones.
            let scoped_spec = spec.site.contains('/');
            if fired.is_none() || scoped_spec {
                fired = Some(spec.kind);
            }
        }
    }
    fired
}

/// The standard fault-point shape for fallible call sites.
///
/// * not armed / not firing → `Ok(false)`
/// * `io`    → `Err(injected io::Error)`
/// * `panic` → panics
/// * `nan`   → `Ok(true)` — the caller corrupts its own data
pub fn trip(site: &str) -> io::Result<bool> {
    match hit(site) {
        None => Ok(false),
        Some(InjectKind::Nan) => Ok(true),
        Some(InjectKind::Io) => Err(io::Error::other(format!(
            "injected fault: io at {site}"
        ))),
        Some(InjectKind::Panic) => {
            panic!("injected fault: panic at {site}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_is_inert() {
        let _g = exclusive();
        assert_eq!(hit("anything"), None);
        assert!(!trip("anything").unwrap());
    }

    #[test]
    fn fires_on_exact_hit_index_with_count() {
        let _g = exclusive();
        arm("x:1:io:2").unwrap();
        assert_eq!(hit("x"), None); // hit 0
        assert_eq!(hit("x"), Some(InjectKind::Io)); // hit 1
        assert_eq!(hit("x"), Some(InjectKind::Io)); // hit 2
        assert_eq!(hit("x"), None); // hit 3
    }

    #[test]
    fn forever_count_and_multi_spec_parse() {
        let _g = exclusive();
        arm("a:0:nan:*, b:0:panic").unwrap();
        for _ in 0..4 {
            assert_eq!(hit("a"), Some(InjectKind::Nan));
        }
        assert_eq!(hit("c"), None);
    }

    #[test]
    fn scoped_spec_only_fires_in_scope_and_wins_over_bare() {
        let _g = exclusive();
        arm("t1/x:0:panic:*,x:0:io:*").unwrap();
        // Outside the scope only the bare spec matches.
        assert_eq!(hit("x"), Some(InjectKind::Io));
        // Inside scope t1 the scoped spec wins.
        with_scope("t1", || {
            assert_eq!(hit("x"), Some(InjectKind::Panic));
        });
        with_scope("t2", || {
            assert_eq!(hit("x"), Some(InjectKind::Io));
        });
    }

    #[test]
    fn scoped_and_bare_counters_are_independent() {
        let _g = exclusive();
        arm("t1/x:1:nan").unwrap();
        // Bare hits do not advance the scoped counter.
        assert_eq!(hit("x"), None);
        assert_eq!(hit("x"), None);
        with_scope("t1", || {
            assert_eq!(hit("x"), None); // scoped hit 0
            assert_eq!(hit("x"), Some(InjectKind::Nan)); // scoped hit 1
        });
    }

    #[test]
    fn malformed_specs_are_rejected() {
        let _g = exclusive();
        assert!(arm("x:0").is_err());
        assert!(arm("x:zero:io").is_err());
        assert!(arm("x:0:frobnicate").is_err());
        assert!(arm("x:0:io:many").is_err());
        // Nothing armed by the failed calls.
        assert_eq!(hit("x"), None);
    }

    #[test]
    fn trip_maps_kinds() {
        let _g = exclusive();
        arm("io.site:0:io,nan.site:0:nan").unwrap();
        assert!(!trip("clean.site").unwrap());
        assert!(trip("nan.site").unwrap());
        let e = trip("io.site").unwrap_err();
        assert!(e.to_string().contains("injected fault: io"));
    }

    #[test]
    fn panic_kind_panics_with_recognizable_payload() {
        let _g = exclusive();
        arm("boom:0:panic").unwrap();
        let r = std::panic::catch_unwind(|| {
            let _ = trip("boom");
        });
        let payload = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(payload.contains("injected fault: panic at boom"));
    }
}
