//! Minimal bench harness for `cargo bench` targets (criterion unavailable).
//!
//! Measures wall-clock with warmup, reports mean / p50 / p95 / min in a
//! criterion-like one-liner. Deterministic iteration counts so runs are
//! comparable across the perf-pass iterations logged in EXPERIMENTS.md.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: a few warmup calls, then timed samples.
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..2.min(samples) {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters: samples,
        mean_ns: mean,
        p50_ns: times[times.len() / 2],
        p95_ns: times[((times.len() as f64 * 0.95) as usize)
            .min(times.len() - 1)],
        min_ns: times[0],
    };
    r.report();
    r
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write bench results as a flat `{name: mean_ns_per_iter}` JSON object
/// (the `BENCH_*.json` files future PRs diff to track the perf
/// trajectory).
pub fn write_json(results: &[BenchResult],
                  path: &std::path::Path) -> std::io::Result<()> {
    use crate::util::json::{num, Json};
    let obj = Json::Obj(
        results
            .iter()
            .map(|r| (r.name.clone(), num(r.mean_ns)))
            .collect(),
    );
    std::fs::write(path, obj.to_string() + "\n")
}

/// Like [`write_json`], but first diffs the fresh results against the
/// previous `BENCH_*.json` at `path` (if any) and prints a
/// `name → old/new/Δ%` table, so perf regressions are visible directly
/// in the run log before the file is overwritten. (On a fresh checkout
/// there is no previous file and the table is skipped.)
pub fn write_json_with_diff(results: &[BenchResult],
                            path: &std::path::Path)
                            -> std::io::Result<()> {
    if let Ok(prev) = std::fs::read_to_string(path) {
        match crate::util::json::Json::parse(&prev) {
            Ok(j) => {
                println!("\n== diff vs previous {} ==", path.display());
                let mut overlap = 0usize;
                for r in results {
                    let Some(old) =
                        j.opt(&r.name).and_then(|v| v.as_f64().ok())
                    else {
                        println!("{:<44} {:>12} (new entry)", r.name,
                                 fmt_ns(r.mean_ns));
                        continue;
                    };
                    let delta = if old > 0.0 {
                        (r.mean_ns - old) / old * 100.0
                    } else {
                        0.0
                    };
                    println!(
                        "{:<44} {:>12} -> {:>12}  {:+8.1}%",
                        r.name,
                        fmt_ns(old),
                        fmt_ns(r.mean_ns),
                        delta
                    );
                    overlap += 1;
                }
                if overlap == 0 {
                    println!("(no overlapping entries)");
                }
            }
            Err(e) => {
                println!("(previous {} unparsable: {e})", path.display());
            }
        }
    }
    write_json(results, path)
}

/// The repository root seen from wherever cargo runs the bench (package
/// dir or repo root) — the canonical place for `BENCH_*.json`.
pub fn repo_root() -> std::path::PathBuf {
    for base in [".", ".."] {
        let p = std::path::Path::new(base).join("ROADMAP.md");
        if p.is_file() {
            return std::path::PathBuf::from(base);
        }
    }
    std::path::PathBuf::from(".")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let mut acc = 0u64;
        let r = bench("noop", 50, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(r.iters, 50);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn write_json_roundtrips() {
        let r = bench("noop2", 5, || {});
        let dir = std::env::temp_dir().join("ambp_bench_json");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_test.json");
        write_json(std::slice::from_ref(&r), &p).unwrap();
        let j = crate::util::json::Json::parse(
            &std::fs::read_to_string(&p).unwrap()).unwrap();
        assert!(j.get("noop2").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn diff_write_updates_file() {
        let r1 = bench("entry_a", 3, || {});
        let dir = std::env::temp_dir().join("ambp_bench_diff");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_diff.json");
        let _ = std::fs::remove_file(&p);
        // first write: no previous file → plain write
        write_json_with_diff(std::slice::from_ref(&r1), &p).unwrap();
        // second write: diffs against the first, then overwrites
        let r2 = bench("entry_a", 3, || {});
        write_json_with_diff(std::slice::from_ref(&r2), &p).unwrap();
        let j = crate::util::json::Json::parse(
            &std::fs::read_to_string(&p).unwrap()).unwrap();
        assert!(
            (j.get("entry_a").unwrap().as_f64().unwrap() - r2.mean_ns)
                .abs()
                < 1e-9
        );
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
