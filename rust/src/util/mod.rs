//! In-tree utility substrates (offline testbed: no serde/clap/rand/criterion).

pub mod bench;
pub mod cli;
pub mod faultpoint;
pub mod hash;
pub mod json;
pub mod rng;
