//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--")
                {
                    out.flags.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&argv(&[
            "train", "--steps", "100", "--fast", "--lr=0.1", "cfg.json",
        ]));
        assert_eq!(a.positional, vec!["train", "cfg.json"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("lr"), Some("0.1"));
        assert!(a.bool("fast"));
        assert_eq!(a.usize_or("steps", 1).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(&["x"]));
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert!(!a.bool("fast"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&argv(&["--steps", "abc"]));
        assert!(a.usize_or("steps", 1).is_err());
    }
}
