//! Deterministic PRNG (SplitMix64 + xoshiro256**) — the offline testbed
//! has no `rand` crate. Used by the synthetic datasets, the simulated
//! annealing solver, and the in-tree property tests.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // seed expansion via SplitMix64
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
