//! FNV-1a 64-bit — the checksum/fingerprint primitive for the durable
//! state subsystem (statefiles, frozen-base identity).
//!
//! Chosen over a cryptographic hash deliberately: the threat model is
//! accidental corruption (truncation, bit rot, partial writes), not an
//! adversary, and FNV-1a is a dozen lines with no dependencies, is
//! byte-order independent by construction (it consumes a byte stream),
//! and is trivially reimplementable by the fixture generator script.

const OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a 64-bit hasher.
#[derive(Debug, Clone)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64 { state: OFFSET_BASIS }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
        self.state = h;
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot FNV-1a 64 over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Published FNV-1a 64 vectors (draft-eastlake-fnv).
    #[test]
    fn known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f736_7e83);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let mut h = Fnv64::new();
        h.update(b"foo");
        h.update(b"");
        h.update(b"bar");
        assert_eq!(h.finish(), fnv1a64(b"foobar"));
    }
}
