//! 2-bit / 1-bit residual packing — rust mirror of the Pallas kernels'
//! byte layout (4 codes/byte resp. 8 signs/byte, little-endian in-byte).
//! Used by the memory accounting, the quant baselines, and as the oracle
//! for the in-tree property tests.

/// Pack 2-bit codes (values 0..=3), 4 per byte. Length padded with zeros.
pub fn pack2(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(4)];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c < 4);
        out[i / 4] |= (c & 3) << (2 * (i % 4));
    }
    out
}

pub fn unpack2(packed: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push((packed[i / 4] >> (2 * (i % 4))) & 3);
    }
    out
}

/// Pack 1-bit signs, 8 per byte.
pub fn pack1(bits: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; bits.len().div_ceil(8)];
    for (i, &b) in bits.iter().enumerate() {
        debug_assert!(b < 2);
        out[i / 8] |= (b & 1) << (i % 8);
    }
    out
}

pub fn unpack1(packed: &[u8], n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push((packed[i / 8] >> (i % 8)) & 1);
    }
    out
}

/// Bucketize f32s against 3 thresholds → 2-bit codes (ReGELU2 encode).
pub fn bucketize2(xs: &[f32], c: [f64; 3]) -> Vec<u8> {
    xs.iter()
        .map(|&x| {
            let x = x as f64;
            (x >= c[0]) as u8 + (x >= c[1]) as u8 + (x >= c[2]) as u8
        })
        .collect()
}

/// Apply the 4-entry slope table to packed codes (ReGELU2 decode-bwd).
pub fn apply_slopes(packed: &[u8], gy: &[f32], slopes: [f64; 4]) -> Vec<f32> {
    let s: [f32; 4] = [slopes[0] as f32, slopes[1] as f32,
                       slopes[2] as f32, slopes[3] as f32];
    gy.iter()
        .enumerate()
        .map(|(i, &g)| g * s[((packed[i / 4] >> (2 * (i % 4))) & 3) as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack2_roundtrip_odd_lengths() {
        let mut rng = Rng::new(0);
        for n in [1usize, 3, 4, 5, 17, 64, 1001] {
            let codes: Vec<u8> =
                (0..n).map(|_| rng.below(4) as u8).collect();
            let packed = pack2(&codes);
            assert_eq!(packed.len(), n.div_ceil(4));
            assert_eq!(unpack2(&packed, n), codes);
        }
    }

    #[test]
    fn pack1_roundtrip() {
        let mut rng = Rng::new(1);
        for n in [1, 7, 8, 9, 250] {
            let bits: Vec<u8> = (0..n).map(|_| rng.below(2) as u8).collect();
            assert_eq!(unpack1(&pack1(&bits), n), bits);
        }
    }

    #[test]
    fn bucketize_matches_kernel_semantics() {
        let c = crate::coeffs::funcs::PAPER_GELU.c;
        let xs = [-10.0f32, -1.0, 0.5, 10.0];
        assert_eq!(bucketize2(&xs, c), vec![0, 1, 2, 3]);
    }

    #[test]
    fn apply_slopes_matches_scalar() {
        let comb = crate::coeffs::funcs::PAPER_GELU;
        let mut rng = Rng::new(2);
        let xs: Vec<f32> = (0..97).map(|_| rng.normal_f32() * 3.0).collect();
        let gy: Vec<f32> = (0..97).map(|_| rng.normal_f32()).collect();
        let packed = pack2(&bucketize2(&xs, comb.c));
        let got = apply_slopes(&packed, &gy, comb.slopes());
        for ((x, g), got) in xs.iter().zip(&gy).zip(&got) {
            let want = *g as f64 * comb.derivative(*x as f64);
            assert!((*got as f64 - want).abs() < 1e-6);
        }
    }
}
