//! 2-bit / 1-bit residual packing — rust mirror of the Pallas kernels'
//! byte layout (4 codes/byte resp. 8 signs/byte, little-endian in-byte).
//! Used by the memory accounting, the quant baselines, and as the oracle
//! for the in-tree property tests.

/// Pack 2-bit codes (values 0..=3), 4 per byte. Length padded with zeros.
pub fn pack2(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; codes.len().div_ceil(4)];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c < 4);
        out[i / 4] |= (c & 3) << (2 * (i % 4));
    }
    out
}

/// Unpack the first `n` 2-bit codes.
///
/// Contract: `n ≤ 4 · packed.len()` — `packed` must come from a `pack2`
/// of at least `n` codes. Violations panic (with a clear message rather
/// than a raw index-out-of-bounds) instead of fabricating codes.
pub fn unpack2(packed: &[u8], n: usize) -> Vec<u8> {
    assert!(
        n <= packed.len() * 4,
        "unpack2: n={n} exceeds packed capacity {}",
        packed.len() * 4
    );
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push((packed[i / 4] >> (2 * (i % 4))) & 3);
    }
    out
}

/// Pack 1-bit signs, 8 per byte.
pub fn pack1(bits: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; bits.len().div_ceil(8)];
    for (i, &b) in bits.iter().enumerate() {
        debug_assert!(b < 2);
        out[i / 8] |= (b & 1) << (i % 8);
    }
    out
}

/// Unpack the first `n` 1-bit signs.
///
/// Contract: `n ≤ 8 · packed.len()` (see [`unpack2`]); panics otherwise.
pub fn unpack1(packed: &[u8], n: usize) -> Vec<u8> {
    assert!(
        n <= packed.len() * 8,
        "unpack1: n={n} exceeds packed capacity {}",
        packed.len() * 8
    );
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        out.push((packed[i / 8] >> (i % 8)) & 1);
    }
    out
}

/// Bucketize f32s against 3 thresholds → 2-bit codes (ReGELU2 encode).
///
/// Kernel semantics: code = #{thresholds ≤ x}, so a value exactly at a
/// threshold belongs to the segment *above* it (`>=`, matching the
/// Pallas kernels and `ReluComb::code`).
pub fn bucketize2(xs: &[f32], c: [f64; 3]) -> Vec<u8> {
    xs.iter()
        .map(|&x| {
            let x = x as f64;
            (x >= c[0]) as u8 + (x >= c[1]) as u8 + (x >= c[2]) as u8
        })
        .collect()
}

/// Fused single-pass encode: bucketize against the 3 thresholds *and*
/// pack 4 codes/byte straight into `out` — no intermediate code vector.
/// Byte-identical to `pack2(&bucketize2(xs, c))` (the tail of a partial
/// final quad is zero-padded the same way); that identity is what the
/// property tests pin.
///
/// `out.len()` must be exactly `xs.len().div_ceil(4)`; every byte of
/// `out` is overwritten.
pub fn encode2_into(xs: &[f32], c: [f64; 3], out: &mut [u8]) {
    assert_eq!(
        out.len(),
        xs.len().div_ceil(4),
        "encode2_into: output must hold exactly {} packed bytes",
        xs.len().div_ceil(4)
    );
    for (byte, quad) in out.iter_mut().zip(xs.chunks(4)) {
        let mut b = 0u8;
        for (s, &x) in quad.iter().enumerate() {
            let x = x as f64;
            let code =
                (x >= c[0]) as u8 + (x >= c[1]) as u8 + (x >= c[2]) as u8;
            b |= code << (2 * s);
        }
        *byte = b;
    }
}

/// Allocating wrapper over [`encode2_into`] — the fused form of
/// `pack2(&bucketize2(xs, c))`.
pub fn encode2(xs: &[f32], c: [f64; 3]) -> Vec<u8> {
    let mut out = vec![0u8; xs.len().div_ceil(4)];
    encode2_into(xs, c, &mut out);
    out
}

/// Apply the 4-entry slope table to packed codes (ReGELU2 decode-bwd)
/// into a caller buffer: `gx[i] = gy[i] · slopes[code(i)]`.
///
/// Contract: `out.len() == gy.len() ≤ 4 · packed.len()`; panics
/// otherwise.
pub fn apply_slopes_into(out: &mut [f32], packed: &[u8], gy: &[f32],
                         slopes: [f64; 4]) {
    assert_eq!(out.len(), gy.len(),
               "apply_slopes_into: out/gy length mismatch");
    assert!(
        gy.len() <= packed.len() * 4,
        "apply_slopes: gy length {} exceeds packed capacity {}",
        gy.len(),
        packed.len() * 4
    );
    let s: [f32; 4] = [slopes[0] as f32, slopes[1] as f32,
                       slopes[2] as f32, slopes[3] as f32];
    for (i, (o, &g)) in out.iter_mut().zip(gy).enumerate() {
        *o = g * s[((packed[i / 4] >> (2 * (i % 4))) & 3) as usize];
    }
}

/// Allocating wrapper over [`apply_slopes_into`].
pub fn apply_slopes(packed: &[u8], gy: &[f32], slopes: [f64; 4]) -> Vec<f32> {
    let mut out = vec![0f32; gy.len()];
    apply_slopes_into(&mut out, packed, gy, slopes);
    out
}

/// Fused single-pass 1-bit encode: the sign bit `x > 0` packed 8 per
/// byte straight into `out` — the ReLU backward residual (its
/// derivative is exactly 0/1, so one bit is lossless). Byte-identical
/// to `pack1(&signs)` with `signs[i] = (xs[i] > 0) as u8`.
///
/// `out.len()` must be exactly `xs.len().div_ceil(8)`; every byte of
/// `out` is overwritten.
pub fn encode1_into(xs: &[f32], out: &mut [u8]) {
    assert_eq!(
        out.len(),
        xs.len().div_ceil(8),
        "encode1_into: output must hold exactly {} packed bytes",
        xs.len().div_ceil(8)
    );
    for (byte, oct) in out.iter_mut().zip(xs.chunks(8)) {
        let mut b = 0u8;
        for (s, &x) in oct.iter().enumerate() {
            b |= u8::from(x > 0.0) << s;
        }
        *byte = b;
    }
}

/// Allocating wrapper over [`encode1_into`].
pub fn encode1(xs: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; xs.len().div_ceil(8)];
    encode1_into(xs, &mut out);
    out
}

/// Apply packed 1-bit sign codes to an upstream gradient into a caller
/// buffer: `gx[i] = gy[i]` where the bit is set, `0` otherwise — the
/// exact ReLU backward.
///
/// Contract: `out.len() == gy.len() ≤ 8 · packed.len()`; panics
/// otherwise.
pub fn apply_signs_into(out: &mut [f32], packed: &[u8], gy: &[f32]) {
    assert_eq!(out.len(), gy.len(),
               "apply_signs_into: out/gy length mismatch");
    assert!(
        gy.len() <= packed.len() * 8,
        "apply_signs: gy length {} exceeds packed capacity {}",
        gy.len(),
        packed.len() * 8
    );
    for (i, (o, &g)) in out.iter_mut().zip(gy).enumerate() {
        *o = g * ((packed[i / 8] >> (i % 8)) & 1) as f32;
    }
}

/// Allocating wrapper over [`apply_signs_into`].
pub fn apply_signs(packed: &[u8], gy: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; gy.len()];
    apply_signs_into(&mut out, packed, gy);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pack2_roundtrip_odd_lengths() {
        let mut rng = Rng::new(0);
        for n in [1usize, 3, 4, 5, 17, 64, 1001] {
            let codes: Vec<u8> =
                (0..n).map(|_| rng.below(4) as u8).collect();
            let packed = pack2(&codes);
            assert_eq!(packed.len(), n.div_ceil(4));
            assert_eq!(unpack2(&packed, n), codes);
        }
    }

    #[test]
    fn pack1_roundtrip() {
        let mut rng = Rng::new(1);
        for n in [1, 7, 8, 9, 250] {
            let bits: Vec<u8> = (0..n).map(|_| rng.below(2) as u8).collect();
            assert_eq!(unpack1(&pack1(&bits), n), bits);
        }
    }

    #[test]
    fn bucketize_matches_kernel_semantics() {
        let c = crate::coeffs::funcs::PAPER_GELU.c;
        let xs = [-10.0f32, -1.0, 0.5, 10.0];
        assert_eq!(bucketize2(&xs, c), vec![0, 1, 2, 3]);
    }

    #[test]
    fn bucketize_threshold_boundaries() {
        // exactly-at-threshold values take the segment ABOVE (x >= c)
        let c = [-1.0f64, 0.0, 1.0];
        let xs = [-1.0f32, 0.0, 1.0];
        assert_eq!(bucketize2(&xs, c), vec![1, 2, 3]);
        // just below each threshold stays in the segment below
        let eps = 1e-4f32;
        let xs = [-1.0 - eps, 0.0 - eps, 1.0 - eps];
        assert_eq!(bucketize2(&xs, c), vec![0, 1, 2]);
        // paper thresholds behave identically
        let pc = crate::coeffs::funcs::PAPER_GELU.c;
        let at: Vec<f32> = pc.iter().map(|v| *v as f32).collect();
        let codes = bucketize2(&at, pc);
        for (i, code) in codes.iter().enumerate() {
            // f32 rounding can land just below the f64 threshold; the
            // code must be the exact count of thresholds ≤ the f32 value
            let want = pc.iter()
                .filter(|&&t| at[i] as f64 >= t)
                .count() as u8;
            assert_eq!(*code, want);
        }
    }

    #[test]
    fn unpack_full_capacity_ok() {
        // n exactly at capacity (including the zero-padded tail codes)
        let packed = pack2(&[1, 2, 3]); // capacity 4
        assert_eq!(unpack2(&packed, 4), vec![1, 2, 3, 0]);
        let packed = pack1(&[1, 0, 1]); // capacity 8
        assert_eq!(unpack1(&packed, 8), vec![1, 0, 1, 0, 0, 0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "exceeds packed capacity")]
    fn unpack2_beyond_capacity_panics() {
        let packed = pack2(&[1, 2, 3]); // 1 byte, capacity 4
        let _ = unpack2(&packed, 5);
    }

    #[test]
    #[should_panic(expected = "exceeds packed capacity")]
    fn unpack1_beyond_capacity_panics() {
        let packed = pack1(&[1]); // 1 byte, capacity 8
        let _ = unpack1(&packed, 9);
    }

    #[test]
    fn encode1_matches_pack1_and_signs_gate_gradients() {
        let mut rng = Rng::new(21);
        for n in [1usize, 7, 8, 9, 64, 1001] {
            let xs: Vec<f32> =
                (0..n).map(|_| rng.normal_f32()).collect();
            let signs: Vec<u8> =
                xs.iter().map(|&x| u8::from(x > 0.0)).collect();
            let packed = encode1(&xs);
            assert_eq!(packed, pack1(&signs), "n={n}");
            let gy: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let gx = apply_signs(&packed, &gy);
            for i in 0..n {
                let want = if xs[i] > 0.0 { gy[i] } else { 0.0 };
                assert_eq!(gx[i], want, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn encode2_matches_bucketize_then_pack() {
        let comb = crate::coeffs::funcs::PAPER_GELU;
        let mut rng = Rng::new(7);
        // odd lengths exercise the zero-padded partial final quad
        for n in [1usize, 3, 4, 5, 17, 64, 1001] {
            let xs: Vec<f32> =
                (0..n).map(|_| rng.normal_f32() * 3.0).collect();
            let want = pack2(&bucketize2(&xs, comb.c));
            assert_eq!(encode2(&xs, comb.c), want, "n={n}");
        }
    }

    #[test]
    fn encode2_threshold_boundaries() {
        // the fused pass must keep the >= boundary semantics
        let c = [-1.0f64, 0.0, 1.0];
        let xs = [-1.0f32, 0.0, 1.0];
        assert_eq!(encode2(&xs, c), pack2(&[1, 2, 3]));
        let eps = 1e-4f32;
        let xs = [-1.0 - eps, 0.0 - eps, 1.0 - eps];
        assert_eq!(encode2(&xs, c), pack2(&[0, 1, 2]));
    }

    #[test]
    #[should_panic(expected = "packed bytes")]
    fn encode2_into_wrong_len_panics() {
        let mut out = vec![0u8; 2];
        encode2_into(&[1.0f32; 4], [0.0, 1.0, 2.0], &mut out);
    }

    #[test]
    fn apply_slopes_matches_scalar() {
        let comb = crate::coeffs::funcs::PAPER_GELU;
        let mut rng = Rng::new(2);
        let xs: Vec<f32> = (0..97).map(|_| rng.normal_f32() * 3.0).collect();
        let gy: Vec<f32> = (0..97).map(|_| rng.normal_f32()).collect();
        let packed = pack2(&bucketize2(&xs, comb.c));
        let got = apply_slopes(&packed, &gy, comb.slopes());
        for ((x, g), got) in xs.iter().zip(&gy).zip(&got) {
            let want = *g as f64 * comb.derivative(*x as f64);
            assert!((*got as f64 - want).abs() < 1e-6);
        }
    }
}
