//! `ambp` CLI — the L3 launcher.
//!
//! Subcommands:
//!   train     fine-tune a preset artifact (the main entry point)
//!   serve     multi-tenant engine: run N fine-tuning sessions that
//!             share frozen bases, under a byte budget; with --trace,
//!             a job trace drives the priority queue under a
//!             scheduling policy (--policy)
//!   bench-fleet  policy × preset-group serving benchmark on a seeded
//!             trace; writes BENCH_fleet.json
//!   fleet     sessions-per-budget capacity report (baseline vs ours
//!             vs mesa), cross-checked against a measured probe step
//!   suspend   train a session for K steps, then spool its durable
//!             state to a statefile (crash-safe, bit-exact)
//!   resume    continue a suspended session from its statefile to
//!             completion — bit-identical to an uninterrupted run
//!   eval      forward-only evaluation of a (possibly restored) model
//!   exp       reproduce a paper table/figure (fig1..fig8, tab1..tab12,
//!             appc, appe, all)
//!   mem       analytical activation-memory report for a named scale
//!   convert   merge LN/RMS affine params into the following linears
//!             (eq. 17) to produce an MS-LN/MS-RMSNorm checkpoint
//!   solve     re-derive the ReGELU2/ReSiLU2 coefficients (Appendix E)
//!   info      print a preset's manifest summary

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use ambp::config::RunCfg;
use ambp::coordinator::checkpoint::{merge_affine, Checkpoint};
use ambp::coordinator::engine::fleet_capacity;
use ambp::coordinator::{
    frontline, statefile, supervisor, traffic, Engine, FleetMetrics,
    FrontCfg, JobSpec, Policy, Session, StepOutcome, TrafficCfg,
    TrainCfg, Trainer,
};
use ambp::runtime::{Artifact, Runtime};
use ambp::util::cli::Args;
use ambp::util::json::obj;
use anyhow::{bail, ensure, Context, Result};

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => train(&args),
        "serve" => serve(&args),
        "bench-fleet" => bench_fleet(&args),
        "suspend" => suspend_cmd(&args),
        "resume" => resume_cmd(&args),
        "fleet" => fleet(&args),
        "eval" => eval(&args),
        "exp" => {
            let id = args
                .positional
                .get(1)
                .context("usage: ambp exp <fig1..|tab1..|appc|appe|all>")?;
            ambp::exp::run(id, &args)
        }
        "mem" => mem_report(&args),
        "convert" => convert(&args),
        "solve" => ambp::exp::appendix::appe(&args),
        "info" => info(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn runtime(args: &Args) -> Result<Runtime> {
    Runtime::from_name(args.get_or("backend", "native"))
}

fn load_artifact(cfg: &RunCfg, args: &Args) -> Result<Artifact> {
    let rt = runtime(args)?;
    ambp::runtime::load_or_synth_in(&rt, &cfg.artifacts_dir, &cfg.preset)
}

fn train(args: &Args) -> Result<()> {
    let cfg = RunCfg::from_args(args)?;
    let art = load_artifact(&cfg, args)?;
    println!(
        "preset {} — arch={} tuning={} act={} norm={} | {} params \
         ({} trainable), {} residuals",
        cfg.preset,
        art.manifest.arch,
        art.manifest.tuning,
        art.manifest.activation,
        art.manifest.norm,
        art.manifest.params.len(),
        art.manifest.trainable_indices().len(),
        art.manifest.residuals.len()
    );
    if let Some(p) = args.get("save-artifact") {
        statefile::save_artifact(Path::new(p), &art)?;
        println!("artifact statefile saved to {p:?} (fingerprint \
                  {:#018x})",
                 art.frozen_base().fingerprint());
    }
    let mut trainer = Trainer::new(&art, cfg.train.clone())?;
    if let Some(src) = &cfg.init_from {
        let ck = Checkpoint::load(src)?;
        let n = ck.restore(&art.manifest, &mut trainer.params)?;
        println!("restored {n} tensors from {src:?}");
    }
    let report = trainer.train()?;
    println!(
        "\ndone: final loss {:.4}  eval acc {:.3}  throughput {:.1} \
         samples/s  peak activation {:.1} MiB",
        report.final_loss,
        report.eval_metric,
        report.throughput,
        report.peak_activation_bytes as f64 / 1048576.0
    );
    println!("activation memory by kind:");
    for (kind, bytes) in &report.by_kind {
        println!("  {:<14} {:>10.2} MiB", kind,
                 *bytes as f64 / 1048576.0);
    }
    if let Some(dst) = &cfg.save_to {
        Checkpoint::from_params(&art.manifest, &trainer.params)
            .save(dst)?;
        println!("checkpoint saved to {dst:?}");
    }
    Ok(())
}

/// Multi-tenant serving: admit `--jobs preset[:steps[:seed[:prio]]],…`
/// sessions against `--budget <MiB>`, interleave their steps
/// round-robin, report per-session results + fleet accounting. With
/// `--spool DIR`, suspended sessions live as statefiles there:
/// `--preempt` lets a higher-priority job evict lower-priority ones
/// instead of being rejected, `--halt-after R` suspends the whole
/// fleet after R rounds (deterministic stand-in for a crash), and any
/// `*.state` already in the spool is warm-restarted — so running the
/// same `serve` again finishes the interrupted work bit-identically.
fn serve(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let budget =
        (args.f64_or("budget", 1024.0)? * 1048576.0).round() as u64;
    let spool = args.get("spool").map(PathBuf::from);
    let preempt = args.bool("preempt");
    ensure!(!preempt || spool.is_some(), "--preempt requires --spool");
    let halt_after = args.usize_or("halt-after", 0)?;
    ensure!(halt_after == 0 || spool.is_some(),
            "--halt-after requires --spool");
    let strict = args.bool("strict");
    let max_retries = args.usize_or("max-retries", 2)? as u32;
    // cross-tenant fused execution (off by default; --no-fuse makes
    // the serial baseline explicit for A/B runs)
    let fuse = args.bool("fuse") && !args.bool("no-fuse");
    let metrics_dir = args.get("metrics-dir").map(PathBuf::from);
    if let Some(f) = args.get("faults") {
        ambp::util::faultpoint::arm(f)
            .map_err(|e| anyhow::anyhow!("--faults {f:?}: {e}"))?;
        println!("fault injection armed: {f}");
    }
    // front-line mode: a job trace + scheduling policy drive the
    // engine through the priority queue instead of a fixed --jobs list
    if args.get("trace").is_some() || args.get("policy").is_some() {
        return serve_frontline(&rt, args, budget, spool, preempt, fuse);
    }
    // salvaging warm-restart scan: healthy statefiles resume, corrupt
    // ones are quarantined (renamed + report) instead of blocking the
    // whole fleet — unless --strict, where the first bad file errors
    let mut spooled: Vec<statefile::SessionHandle> = Vec::new();
    if let Some(dir) = &spool {
        std::fs::create_dir_all(dir)?;
        let scan = supervisor::scan_spool(dir, max_retries, strict)?;
        for rec in &scan.quarantined {
            println!(
                "QUARANTINED spool file for {} ({} fault) → {:?}",
                rec.name,
                rec.kind,
                rec.state_path.as_deref().unwrap_or(Path::new("?"))
            );
        }
        spooled = scan.healthy;
    }
    let jobs = match args.get("jobs") {
        Some(j) => j,
        None if !spooled.is_empty() => "",
        None => bail!(
            "--jobs preset[:steps[:seed[:prio]]],... required (or an \
             existing --spool with suspended sessions)"
        ),
    };
    let base_cfg = TrainCfg {
        steps: args.usize_or("steps", 20)?,
        lr: args.f64_or("lr", 1e-3)? as f32,
        log_every: args.usize_or("log-every", 0)?,
        seed: args.usize_or("seed", 0)? as u64,
        // serving is about step throughput; held-out evaluation is
        // opt-in so it does not distort the aggregate samples/s
        eval_batches: args.usize_or("eval-batches", 0)?,
        ..TrainCfg::default()
    };
    let mut specs = Vec::new();
    for (i, token) in
        jobs.split(',').filter(|t| !t.trim().is_empty()).enumerate()
    {
        specs.push(JobSpec::parse(token.trim(), &base_cfg, i)?);
    }
    // one artifact per unique preset (jobs ∪ spooled sessions):
    // sessions on the same preset share its frozen base by
    // construction
    let mut arts: BTreeMap<String, Artifact> = BTreeMap::new();
    let presets = specs
        .iter()
        .map(|s| s.preset.clone())
        .chain(spooled.iter().map(|h| h.preset.clone()));
    for preset in presets {
        if let std::collections::btree_map::Entry::Vacant(slot) =
            arts.entry(preset.clone())
        {
            slot.insert(ambp::runtime::load_or_synth(&rt, &preset)?);
        }
    }
    let mut engine = Engine::new(budget);
    engine.set_strict(strict);
    engine.set_max_retries(max_retries);
    engine.set_fuse(fuse);
    if let Some(dir) = &spool {
        engine.set_spool(dir.clone());
    }
    if preempt {
        engine.enable_preempt()?;
    }
    let mut admitted_samples = 0u64;
    // warm restart first: interrupted work precedes new jobs (a
    // preempting higher-priority job can still evict it)
    for h in &spooled {
        let art = &arts[&h.preset];
        admitted_samples += ((h.steps_total - h.steps_done)
            * art.manifest.batch) as u64;
        let now = engine.spool_in(art, &h.path)?;
        println!(
            "{} {} ({}) at step {}/{} from {:?}",
            if now { "resumed" } else { "queued suspended" },
            h.name, h.preset, h.steps_done, h.steps_total, h.path
        );
    }
    // fresh-job names dedupe against the spooled sessions' names: a
    // colliding job gets a deterministic `s<i>_<k>` suffix instead of
    // shadowing (or being shadowed by) the warm-restarted session
    let mut used: std::collections::BTreeSet<String> =
        spooled.iter().map(|h| h.name.clone()).collect();
    for (i, spec) in specs.iter().enumerate() {
        let mut name = format!("s{i}");
        let mut k = 1usize;
        while used.contains(&name) {
            name = format!("s{i}_{k}");
            k += 1;
        }
        if k > 1 {
            println!("job {i} renamed to {name} (name s{i} is taken \
                      by a spooled session)");
        }
        used.insert(name.clone());
        let art = &arts[&spec.preset];
        let mut cfg = spec.cfg.clone();
        if let Some(md) = &metrics_dir {
            cfg.metrics_jsonl = Some(md.join(format!("{name}.jsonl")));
        }
        let suspended_before = engine.suspended_names().len();
        match engine.admit_prio(&name, art, cfg, spec.priority) {
            Ok(()) => {
                admitted_samples += (art.manifest.batch
                    * spec.cfg.grad_accum
                    * spec.cfg.steps) as u64;
                println!("admitted {name} ({}): \
                          {} steps, seed {}, priority {}",
                         spec.preset, spec.cfg.steps, spec.cfg.seed,
                         spec.priority);
            }
            Err(e) if strict => {
                return Err(e.context(format!(
                    "--strict: job {name} ({}) was not admitted",
                    spec.preset
                )));
            }
            Err(e) => println!("REJECTED {name} ({}): {e}", spec.preset),
        }
        for v in &engine.suspended_names()[suspended_before..] {
            println!("  (preempted {v} to the spool)");
        }
    }
    if engine.is_empty() && !engine.has_unfinished() {
        bail!("no session fit the {:.1} MiB budget",
              budget as f64 / 1048576.0);
    }
    // the throughput clock covers the interleaved steps only —
    // admission (each session's one-off warmup) and the end-of-run
    // held-out evaluation inside finish() are setup/reporting
    let t0 = std::time::Instant::now();
    let mut rounds = 0usize;
    while engine.round()? > 0 {
        rounds += 1;
        if halt_after > 0 && rounds >= halt_after
            && engine.has_unfinished()
        {
            let handles = engine.suspend_all()?;
            println!("\nhalted after {rounds} round(s); suspended {} \
                      session(s) to the spool:",
                     handles.len());
            for h in &handles {
                println!("  {} ({}) at step {}/{} → {:?}", h.name,
                         h.preset, h.steps_done, h.steps_total, h.path);
            }
            println!("re-run `ambp serve --spool` to finish them");
            return Ok(());
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let reports = engine.run()?;
    println!("\nper-session results:");
    for r in &reports {
        match (&r.outcome, &r.admission) {
            (
                ambp::coordinator::SessionOutcome::Completed(rep),
                adm,
            ) => {
                let tape = adm
                    .as_ref()
                    .map(|a| a.tape_bytes as f64 / 1048576.0)
                    .unwrap_or(0.0);
                println!(
                    "  {:<4} {:<40} loss {:.4}  metric {:.3}  act \
                     peak {:>8.2} MiB (predicted tape {:>8.2} MiB)",
                    r.name,
                    r.preset,
                    rep.final_loss,
                    rep.final_metric,
                    rep.peak_activation_bytes as f64 / 1048576.0,
                    tape
                );
            }
            (
                ambp::coordinator::SessionOutcome::Quarantined(rec),
                _,
            ) => {
                println!(
                    "  {:<4} {:<40} QUARANTINED ({} fault at step \
                     {}, {} retries) → {:?}",
                    r.name,
                    r.preset,
                    rec.kind,
                    rec.step,
                    rec.retries,
                    rec.state_path
                        .as_deref()
                        .unwrap_or(Path::new("(state not spooled)"))
                );
                if let Some(line) = rec.detail.lines().next() {
                    println!("       {line}");
                }
            }
        }
    }
    println!("\nfleet: {} sessions | resident params {:.2} MiB \
              (bases stored once) | predicted {:.2} MiB of {:.1} MiB \
              budget | measured peak {:.2} MiB | aggregate {:.1} \
              samples/s",
             reports.len(),
             engine.resident_param_bytes() as f64 / 1048576.0,
             engine.predicted_bytes() as f64 / 1048576.0,
             budget as f64 / 1048576.0,
             engine.fleet.peak_bytes as f64 / 1048576.0,
             admitted_samples as f64 / wall);
    if fuse {
        let fs = engine.fusion_stats();
        let occ: Vec<String> = fs
            .occupancy
            .iter()
            .map(|(n, c)| format!("{n}-way×{c}"))
            .collect();
        println!("fusion: {} fused passes | {} serial passes | gang \
                  occupancy [{}]",
                 fs.fused_passes, fs.serial_passes, occ.join(", "));
    }
    Ok(())
}

/// Front-line serving: a JSONL job trace (arrival/preset/steps/seed/
/// prio per line) drives the engine through the priority queue under
/// `--policy round-robin|first-fit|best-fit`, with fleet metrics
/// printed and optionally written as JSON (`--fleet-json`).
fn serve_frontline(rt: &Runtime, args: &Args, budget: u64,
                   spool: Option<PathBuf>, preempt: bool,
                   fuse: bool) -> Result<()> {
    let trace_path = PathBuf::from(args.get("trace").context(
        "--policy requires --trace FILE (a JSONL job trace; write one \
         with `ambp bench-fleet --save-trace DIR`)",
    )?);
    let policy = Policy::parse(args.get_or("policy", "first-fit"))?;
    let trace = traffic::load_trace(&trace_path)?;
    ensure!(!trace.is_empty(), "trace {trace_path:?} is empty");
    if let Some(dir) = &spool {
        std::fs::create_dir_all(dir)?;
    }
    let base_cfg = TrainCfg {
        lr: args.f64_or("lr", 1e-3)? as f32,
        log_every: 0,
        eval_batches: args.usize_or("eval-batches", 0)?,
        ..TrainCfg::default()
    };
    let mut arts: BTreeMap<String, Artifact> = BTreeMap::new();
    for job in &trace {
        if let std::collections::btree_map::Entry::Vacant(slot) =
            arts.entry(job.preset.clone())
        {
            slot.insert(ambp::runtime::load_or_synth(rt, &job.preset)?);
        }
    }
    let fcfg = FrontCfg {
        policy,
        budget,
        base_cfg,
        max_ticks: args.usize_or("ticks", 0)? as u64,
        spool,
        preempt,
        fuse,
    };
    println!("front line: {} jobs from {:?}, policy {}, budget {:.1} \
              MiB{}",
             trace.len(), trace_path, policy.as_str(),
             budget as f64 / 1048576.0,
             if fcfg.max_ticks > 0 {
                 format!(", horizon {} ticks", fcfg.max_ticks)
             } else {
                 String::new()
             });
    let rep = frontline::serve(&arts, &trace, &fcfg)?;
    print_fleet(&rep.metrics);
    if let Some(p) = args.get("fleet-json") {
        std::fs::write(p, rep.metrics.json().to_string() + "\n")?;
        println!("fleet metrics JSON → {p:?}");
    }
    Ok(())
}

fn print_fleet(m: &FleetMetrics) {
    println!("\nper-job results (virtual time; 1 tick = 1 engine \
              round):");
    println!("  {:<5} {:<34} {:>4} {:>7} {:>6} {:>6} {:>5} {:>5}  {}",
             "job", "preset", "prio", "arrive", "admit", "finish",
             "wait", "steps", "outcome");
    for s in &m.sessions {
        let opt = |v: Option<u64>| match v {
            Some(x) => x.to_string(),
            None => "-".to_string(),
        };
        println!("  {:<5} {:<34} {:>4} {:>7} {:>6} {:>6} {:>5} {:>5}  {}",
                 s.name, s.preset, s.priority, s.arrival,
                 opt(s.admit), opt(s.finish), opt(s.queue_wait()),
                 s.steps, s.outcome);
    }
    println!("fleet[{}]: {} submitted | {} admitted | {} completed | \
              {} rejected | {} quarantined | {} preemptions | {} \
              ticks | {:.3} jobs/tick",
             m.policy, m.submitted, m.admitted, m.completed,
             m.rejected, m.quarantined, m.preemptions, m.ticks,
             m.throughput_jobs_per_tick());
    println!("  queue wait  p50/p90/p99: {:.0}/{:.0}/{:.0} ticks",
             m.queue_wait_ticks.p50, m.queue_wait_ticks.p90,
             m.queue_wait_ticks.p99);
    println!("  step latency p50/p90/p99: {:.1}/{:.1}/{:.1} ms \
              (wall clock — not deterministic)",
             m.step_latency_s.p50 * 1e3, m.step_latency_s.p90 * 1e3,
             m.step_latency_s.p99 * 1e3);
    if m.fused_passes > 0 {
        let occ: Vec<String> = m
            .gang_occupancy
            .iter()
            .map(|(n, c)| format!("{n}-way×{c}"))
            .collect();
        println!("  fusion: {} fused passes | {} serial passes | gang \
                  occupancy [{}]",
                 m.fused_passes, m.serial_passes, occ.join(", "));
    }
}

/// Policy × preset-group serving benchmark: one seeded bursty trace
/// shape, replayed with baseline / ours / mesa presets swapped in
/// position-for-position, under each scheduling policy and one shared
/// byte budget. Writes the fleet-metrics JSON grid to
/// `BENCH_fleet.json` next to the other `BENCH_*.json` files.
fn bench_fleet(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let jobs = args.usize_or("jobs", 12)?;
    let ticks = args.usize_or("ticks", 24)? as u64;
    let fuse = args.bool("fuse") && !args.bool("no-fuse");
    // equal-length preset lists so every group consumes the RNG
    // identically: same arrivals/steps/seeds, presets swapped
    let groups: Vec<(&str, Vec<&str>)> = vec![
        ("baseline",
         vec!["vitt_loraqv_gelu_ln", "llama_loraall_silu_rms"]),
        ("ours",
         vec!["vitt_loraqv_regelu2_msln",
              "llama_loraall_resilu2_msrms"]),
        ("mesa",
         vec!["vitt_loraqv_gelu_ln_mesa",
              "llama_loraall_silu_rms_mesa"]),
    ];
    let mut arts: BTreeMap<String, Artifact> = BTreeMap::new();
    for (_, presets) in &groups {
        for preset in presets {
            if let std::collections::btree_map::Entry::Vacant(slot) =
                arts.entry(preset.to_string())
            {
                slot.insert(ambp::runtime::load_or_synth(&rt, preset)?);
            }
        }
    }
    let base_cfg = TrainCfg {
        log_every: 0,
        eval_batches: 0,
        ..TrainCfg::default()
    };
    // default budget: the baseline group's bases + headroom for ~2 of
    // its largest sessions — binding for baseline, roomy for the
    // smaller-tape ours/mesa marginals (override with --budget MiB)
    let budget = match args.f64_or("budget", 0.0)? {
        b if b > 0.0 => (b * 1048576.0).round() as u64,
        _ => {
            let baseline = &groups[0].1;
            let bases: u64 = baseline
                .iter()
                .map(|p| arts[*p].frozen_base().nbytes())
                .sum();
            let max_marginal = baseline
                .iter()
                .map(|p| {
                    ambp::coordinator::engine::predict(&arts[*p],
                                                       &base_cfg)
                        .marginal()
                })
                .max()
                .unwrap_or(0);
            bases + 2 * max_marginal
        }
    };
    println!("bench-fleet: seed {seed}, {jobs} jobs, horizon {ticks} \
              ticks, budget {:.2} MiB{}",
             budget as f64 / 1048576.0,
             if fuse { ", fused execution" } else { "" });
    println!("{:<10} {:<12} {:>8} {:>9} {:>9} {:>10} {:>11}",
             "group", "policy", "admitted", "completed", "rejected",
             "wait p50", "jobs/tick");
    let mut results: Vec<(String, FleetMetrics)> = Vec::new();
    for (gname, presets) in &groups {
        let tcfg = TrafficCfg {
            seed,
            jobs,
            presets: presets.iter().map(|p| p.to_string()).collect(),
            // all priorities equal: the bench compares pure packing
            max_priority: 0,
            ..TrafficCfg::default()
        };
        let trace = traffic::generate(&tcfg)?;
        if let Some(dir) = args.get("save-trace") {
            let p = PathBuf::from(dir).join(format!("{gname}.jsonl"));
            traffic::save_trace(&p, &trace)?;
            println!("  trace[{gname}] → {p:?}");
        }
        for policy in
            [Policy::RoundRobin, Policy::FirstFit, Policy::BestFit]
        {
            let fcfg = FrontCfg {
                policy,
                budget,
                base_cfg: base_cfg.clone(),
                max_ticks: ticks,
                spool: None,
                preempt: false,
                fuse,
            };
            let m = frontline::serve(&arts, &trace, &fcfg)?.metrics;
            println!("{:<10} {:<12} {:>8} {:>9} {:>9} {:>10.0} \
                      {:>11.3}",
                     gname, policy.as_str(), m.admitted, m.completed,
                     m.rejected, m.queue_wait_ticks.p50,
                     m.throughput_jobs_per_tick());
            results.push((format!("{gname}/{}", policy.as_str()), m));
        }
    }
    let out = match args.get("out") {
        Some(p) => PathBuf::from(p),
        None => ambp::util::bench::repo_root().join("BENCH_fleet.json"),
    };
    let json = obj(results
        .iter()
        .map(|(k, m)| (k.as_str(), m.json()))
        .collect());
    std::fs::write(&out, json.to_string() + "\n")?;
    println!("fleet bench grid → {out:?}");
    if args.bool("assert") {
        let admitted = |g: &str, p: &str| -> usize {
            results
                .iter()
                .find(|(k, _)| k == &format!("{g}/{p}"))
                .map(|(_, m)| m.admitted)
                .unwrap_or(0)
        };
        for (g, _) in &groups {
            let (rr, ff, bf) = (admitted(g, "round-robin"),
                                admitted(g, "first-fit"),
                                admitted(g, "best-fit"));
            ensure!(bf >= ff && ff >= rr,
                    "policy ordering violated for {g}: best-fit {bf} \
                     / first-fit {ff} / round-robin {rr}");
        }
        for p in ["round-robin", "first-fit", "best-fit"] {
            for g in ["ours", "mesa"] {
                ensure!(admitted(g, p) >= admitted("baseline", p),
                        "{g}/{p} admitted {} < baseline/{p} {}",
                        admitted(g, p), admitted("baseline", p));
            }
        }
        let mut better = 0usize;
        for p in ["round-robin", "first-fit", "best-fit"] {
            for g in ["ours", "mesa"] {
                if admitted(g, p) > admitted("baseline", p) {
                    better += 1;
                }
            }
        }
        ensure!(better > 0,
                "ours/mesa never admitted strictly more jobs than \
                 baseline under the shared budget");
        if fuse {
            let fused: u64 =
                results.iter().map(|(_, m)| m.fused_passes).sum();
            ensure!(fused > 0,
                    "--fuse was set but no fused passes were recorded \
                     anywhere in the grid");
            println!("assertions passed: {fused} fused passes \
                      recorded across the grid");
        }
        println!("assertions passed: best-fit ≥ first-fit ≥ \
                  round-robin per group; ours/mesa ≥ baseline per \
                  policy (strictly better in {better} cells)");
    }
    Ok(())
}

/// Train a single session for `--at K` steps, then suspend it to a
/// durable statefile — the CLI half of the crash/kill story (CI runs
/// suspend, then `ambp resume`, and checks the result matches an
/// uninterrupted `ambp train` bit-for-bit).
fn suspend_cmd(args: &Args) -> Result<()> {
    let cfg = RunCfg::from_args(args)?;
    let art = load_artifact(&cfg, args)?;
    let state = PathBuf::from(
        args.get("state").context("--state <file.state> required")?);
    let at = args.usize_or("at", cfg.train.steps / 2)?;
    ensure!(at < cfg.train.steps,
            "--at {at} must leave steps to resume (steps {})",
            cfg.train.steps);
    let name = args.get_or("name", "s0");
    let mut s = Session::new(&art, cfg.train.clone())?;
    for _ in 0..at {
        match s.step()? {
            StepOutcome::Stepped(_) => {}
            StepOutcome::Exhausted => bail!("step budget exhausted"),
        }
    }
    let handle =
        statefile::save_session(&state, name, 0, &s.into_state())?;
    println!("suspended {} ({}) at step {}/{} → {:?}", handle.name,
             handle.preset, handle.steps_done, handle.steps_total,
             handle.path);
    Ok(())
}

/// Continue a suspended session from its statefile to completion.
/// The artifact is re-synthesized from the saved preset (or loaded
/// from `--artifact-state`); the frozen-base fingerprint check
/// guarantees the trainables are resumed against the exact weights
/// they were split from. Deletes the statefile on success.
fn resume_cmd(args: &Args) -> Result<()> {
    let state = PathBuf::from(
        args.get("state").context("--state <file.state> required")?);
    let rt = runtime(args)?;
    let saved = statefile::load_session(&state)?;
    let art = match args.get("artifact-state") {
        Some(p) => statefile::load_artifact(&rt, Path::new(p))?,
        None => ambp::runtime::load_or_synth(&rt, &saved.state.preset)?,
    };
    println!("resuming {} ({}) at step {}/{}", saved.name,
             saved.state.preset, saved.state.step,
             saved.state.cfg.steps);
    let mut s = Session::resume(&art, saved.state)?;
    while let StepOutcome::Stepped(_) = s.step()? {}
    let report = s.finish()?;
    println!(
        "done: final loss {:.4}  metric {:.3}  steps {} (peak \
         activation {:.1} MiB)",
        report.final_loss, report.final_metric, report.steps,
        report.peak_activation_bytes as f64 / 1048576.0
    );
    if let Some(dst) = args.get("save-to") {
        Checkpoint::from_params(&art.manifest, &s.params())
            .save(Path::new(dst))?;
        println!("checkpoint saved to {dst:?}");
    }
    std::fs::remove_file(&state)?;
    Ok(())
}

/// Sessions-per-budget capacity report: baseline vs ours
/// (`*_regelu2_msln`) vs mesa under one byte budget — the Table-1
/// savings restated as tenancy.
fn fleet(args: &Args) -> Result<()> {
    let rt = runtime(args)?;
    let budget =
        (args.f64_or("budget", 64.0)? * 1048576.0).round() as u64;
    let base = args.get_or("base", "vitt_loraqv");
    let presets: Vec<String> = match args.get("presets") {
        Some(list) => {
            list.split(',').map(|s| s.trim().to_string()).collect()
        }
        None => vec![
            format!("{base}_gelu_ln"),
            format!("{base}_gelu_ln_mesa"),
            format!("{base}_regelu2_msln"),
            format!("{base}_regelu2_msln_mesa"),
        ],
    };
    let cfg = TrainCfg {
        steps: 1,
        log_every: 0,
        eval_batches: 0,
        ..TrainCfg::default()
    };
    let probe = !args.bool("no-probe");
    let rows = fleet_capacity(&rt, budget, &presets, &cfg, probe)?;
    println!("fleet capacity @ {:.1} MiB budget (marginal = tape + \
              grads + optimizer + trainable; base stored once)",
             budget as f64 / 1048576.0);
    println!("{:<44} {:>10} {:>12} {:>12} {:>9}",
             "preset", "base MiB", "marginal MiB", "measured MiB",
             "sessions");
    for r in &rows {
        println!(
            "{:<44} {:>10.2} {:>12.3} {:>12} {:>9}",
            r.preset,
            r.base_bytes as f64 / 1048576.0,
            r.admission.marginal() as f64 / 1048576.0,
            match r.measured_tape {
                Some(b) => format!("{:.3}", b as f64 / 1048576.0),
                None => "-".to_string(),
            },
            r.admitted
        );
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let cfg = RunCfg::from_args(args)?;
    let art = load_artifact(&cfg, args)?;
    let mut trainer = Trainer::new(&art, TrainCfg {
        log_every: 0,
        ..cfg.train.clone()
    })?;
    if let Some(src) = &cfg.init_from {
        let ck = Checkpoint::load(src)?;
        let n = ck.restore(&art.manifest, &mut trainer.params)?;
        println!("restored {n} tensors from {src:?}");
    }
    let batches = args.usize_or("batches", 16)?;
    let (loss, metric) = trainer.evaluate(1_000_000, batches)?;
    println!("eval: loss {loss:.4}  metric {metric:.3}  \
              ({batches} held-out batches)");
    Ok(())
}

fn mem_report(args: &Args) -> Result<()> {
    use ambp::memmodel::presets as mp;
    use ambp::memmodel::report::{mib, peak};
    use ambp::memmodel::{block_units, by_category, total_bytes};
    let scale = args.get_or("scale", "vit_base");
    let act = ambp::exp::helpers::act_kind(args.get_or("act", "gelu"));
    let norm = ambp::exp::helpers::norm_kind(args.get_or("norm", "ln"));
    let tuning =
        ambp::exp::helpers::tuning_kind(args.get_or("tuning", "lora_qv"));
    let batch = args.usize_or("batch", 64)?;
    let seq = args.usize_or("seq", 512)?;
    let mut cfg = match scale {
        "vit_base" => mp::vit_base(batch, tuning, act, norm),
        "vit_large" => mp::vit_large(batch, tuning, act, norm),
        "llama7b" => mp::llama7b(batch, seq, act, norm),
        "llama13b" => mp::llama13b(batch, seq, act, norm),
        "roberta" => mp::roberta_base(batch, seq, act, norm),
        "swin_tiny" => mp::swin_tiny(batch, act, norm),
        "bert_base" => mp::bert_base(batch, seq, act, norm),
        "bert_large" => mp::bert_large(batch, seq, act, norm),
        other => bail!("unknown scale {other:?}"),
    };
    cfg.tuning = tuning;
    cfg.mesa = args.bool("mesa");
    let bits = args.f64_or("weight-bits", 16.0)?;
    let est = peak(&cfg, bits);
    println!("{scale} | act={act:?} norm={norm:?} tuning={tuning:?} \
              batch={batch}");
    println!("  per-block units: {:.2}", block_units(&cfg));
    println!("  activations: {:>10.1} MiB", mib(est.activations));
    println!("  weights:     {:>10.1} MiB ({bits}-bit)",
             mib(est.weights));
    println!("  grads:       {:>10.1} MiB", mib(est.grads));
    println!("  optimizer:   {:>10.1} MiB", mib(est.optimizer));
    println!("  peak total:  {:>10.1} MiB", mib(est.total));
    println!("  activation breakdown:");
    let total = total_bytes(&cfg);
    for (cat, b) in by_category(&cfg) {
        println!("    {:<16} {:>10.1} MiB  {:>5.1}%", cat, mib(b),
                 100.0 * b as f64 / total as f64);
    }
    Ok(())
}

fn convert(args: &Args) -> Result<()> {
    let src = PathBuf::from(
        args.get("src").context("--src <ckpt dir> required")?);
    let dst = PathBuf::from(
        args.get("dst").context("--dst <ckpt dir> required")?);
    let preset = args
        .get("to-preset")
        .context("--to-preset <ms preset> required")?;
    let dir = ambp::runtime::artifacts_dir().join(preset);
    let manifest = ambp::runtime::Manifest::load(Path::new(&dir))?;
    let ck = Checkpoint::load(&src)?;
    let merged = merge_affine(&ck, &manifest)?;
    merged.save(&dst)?;
    println!("merged {} tensors → {:?} (eq. 17: W̃=W·diag(α), b̃=Wβ+b)",
             merged.tensors.len(), dst);
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let cfg = RunCfg::from_args(args)?;
    // metadata-only query: read manifest.json directly when it exists
    // (works for every preset, incl. ones no backend can execute);
    // otherwise synthesize the manifest via the backend.
    let dir = cfg.artifacts_dir.join(&cfg.preset);
    let loaded;
    let synthesized;
    let m = if dir.join("manifest.json").is_file() {
        loaded = ambp::runtime::Manifest::load(&dir)?;
        &loaded
    } else {
        synthesized = load_artifact(&cfg, args)?;
        &synthesized.manifest
    };
    println!("preset {}: arch={} dim={} depth={} tuning={} act={} norm={}",
             m.preset, m.arch, m.dim, m.depth, m.tuning, m.activation,
             m.norm);
    println!("  params: {} ({} trainable)", m.params.len(),
             m.trainable_indices().len());
    println!("  residuals: {} tensors, {:.2} MiB total",
             m.residuals.len(),
             m.residual_bytes_total as f64 / 1048576.0);
    for (kind, bytes) in m.residual_bytes_by_kind() {
        println!("    {:<14} {:>10.2} MiB", kind,
                 bytes as f64 / 1048576.0);
    }
    println!("  selfcheck: loss={:.4} metric={:.3}", m.selfcheck.loss,
             m.selfcheck.metric);
    Ok(())
}

fn print_help() {
    println!(
        "ambp — Approximate & Memory-Sharing Backpropagation (ICML 2024)
usage: ambp <cmd> [--flags]
global: --backend native|pjrt   (default native; presets with no on-disk
        artifact are synthesized by the native backend, e.g.
        vitt_loraqv_regelu2_msln, llama_loraall_resilu2_msrms)
  train   --preset P [--steps N --lr X --optimizer adamw|sgd
          --schedule constant|warmup_cosine|warmup_linear
          --grad-accum K --seed S --metrics out.jsonl
          --init-from ckpt/ --save-to ckpt/ --save-artifact a.state]
  serve   --budget MiB --jobs P[:steps[:seed[:prio]]],...
          [--steps N --lr X --seed S --log-every K --eval-batches E
           --strict --spool DIR --preempt --halt-after R
           --max-retries K --faults SPEC --metrics-dir DIR
           --fuse | --no-fuse]
          multi-tenant engine: sessions share frozen bases; admission
          is gated on predicted tape+grads+optimizer bytes
          (--strict: error out if any job is rejected or any fault
          occurs; --preempt: evict lower-priority sessions to --spool
          instead; --halt-after R: suspend the fleet after R rounds —
          re-run with the same --spool, no --jobs, to finish; any
          *.state already in --spool is warm-restarted first, and a
          corrupt one is quarantined to <name>.state.quarantine with
          a .json report instead of blocking the fleet)
          front line: --trace FILE [--policy round-robin|first-fit|
          best-fit --ticks T --fleet-json OUT] replaces --jobs with a
          JSONL job trace (arrival/preset/steps/seed/prio per line)
          driving the priority queue under a memmodel-guided
          scheduling policy; --ticks caps the virtual-time horizon
          and --fleet-json writes the fleet metrics (queue-wait and
          step-latency percentiles per session)
          supervision: a faulting tenant is retried from its last
          good state on transient I/O errors (--max-retries K,
          default 2) and quarantined on panics / non-finite loss or
          gradients — the other tenants keep running; --faults
          site:hit:kind[:count],... (kind panic|io|nan; also env
          AMBP_FAULTS) arms the deterministic fault-injection sites
          step.loss, step.compute, spool.write, spool.read —
          prefix \"name/site\" targets one tenant;
          --metrics-dir DIR writes per-session JSONL loss curves
          fusion: --fuse gangs sessions on the same frozen base (same
          preset + grad-accum) and runs each gang through one
          panel-packed pass per layer — per-session results stay
          bit-identical to the serial sweep; ignored under --strict;
          a faulting gang member is peeled and the survivors keep
          fusing
  bench-fleet [--seed S --jobs N --ticks T --budget MiB --out F
          --save-trace DIR --assert --fuse]
          policy (round-robin/first-fit/best-fit) × preset group
          (baseline/ours/mesa) grid on one seeded bursty trace shape
          under one byte budget; writes BENCH_fleet.json; --assert
          checks best-fit ≥ first-fit ≥ round-robin admissions and
          ours/mesa ≥ baseline under the shared budget
  suspend --preset P --state f.state [--at K --steps N --name s0 ...]
          run K steps, then spool the session's durable state
  resume  --state f.state [--artifact-state a.state --save-to ckpt/]
          continue a suspended session to completion (bit-identical
          to an uninterrupted run; deletes f.state on success)
  fleet   [--budget MiB --base vitt_loraqv | --presets P,P,...
          --no-probe]   sessions-per-budget capacity report
  eval    --preset P [--init-from ckpt/ --batches N]
  exp     <fig1..fig8|tab1..tab12|appc|appe|all> [--steps N]
  mem     --scale vit_base|vit_large|llama7b|llama13b|roberta|swin_tiny|\
bert_base|bert_large
          [--act gelu|regelu2|.. --norm ln|msln|.. --tuning full|lora_qv|..
           --batch B --seq T --weight-bits 16]
  convert --src ckpt/ --dst ckpt/ --to-preset P
  solve   [--seeds N]        re-derive a*,c* (Appendix E)
  info    --preset P"
    );
}
