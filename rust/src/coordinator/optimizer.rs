//! Host-side optimizers over the trainable parameter tensors.
//!
//! The optimizer is deliberately on the rust side of the ABI: parameter
//! state lives in host memory (like the paper's paged AdamW in QLoRA),
//! only fwd/bwd run through PJRT.

use crate::runtime::Tensor;

pub trait Optimizer {
    /// In-place update of `params[i]` from `grads[i]` (same order).
    fn step(&mut self, params: &mut [&mut Tensor], grads: &[Tensor], lr: f32);
    fn name(&self) -> &'static str;
}

/// AdamW (Loshchilov & Hutter, 2017) — the paper's optimizer.
pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl AdamW {
    pub fn new(weight_decay: f32) -> AdamW {
        AdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Optimizer-state bytes (the Tables' "optimizer" memory term).
    pub fn state_bytes(&self) -> usize {
        self.m.iter().map(|v| v.len() * 4).sum::<usize>()
            + self.v.iter().map(|v| v.len() * 4).sum::<usize>()
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, params: &mut [&mut Tensor], grads: &[Tensor],
            lr: f32) {
        assert_eq!(params.len(), grads.len());
        if self.m.is_empty() {
            for g in grads {
                self.m.push(vec![0.0; g.elems()]);
                self.v.push(vec![0.0; g.elems()]);
            }
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let pv = p.as_f32_mut();
            let gv = g.as_f32();
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            for j in 0..pv.len() {
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * gv[j];
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * gv[j] * gv[j];
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                pv[j] -= lr * (mhat / (vhat.sqrt() + self.eps)
                    + self.weight_decay * pv[j]);
            }
        }
    }

    fn name(&self) -> &'static str {
        "adamw"
    }
}

/// Plain SGD (with optional momentum) — the convergence-theory baseline
/// (Theorem 4.2 is stated for SGD).
pub struct Sgd {
    pub momentum: f32,
    vel: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Sgd {
        Sgd { momentum, vel: Vec::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [&mut Tensor], grads: &[Tensor],
            lr: f32) {
        if self.momentum > 0.0 && self.vel.is_empty() {
            for g in grads {
                self.vel.push(vec![0.0; g.elems()]);
            }
        }
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let pv = p.as_f32_mut();
            let gv = g.as_f32();
            if self.momentum > 0.0 {
                let vel = &mut self.vel[i];
                for j in 0..pv.len() {
                    vel[j] = self.momentum * vel[j] + gv[j];
                    pv[j] -= lr * vel[j];
                }
            } else {
                for j in 0..pv.len() {
                    pv[j] -= lr * gv[j];
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &Tensor) -> Tensor {
        // f(p) = ||p - 3||²/2, grad = p - 3
        let g: Vec<f32> = p.as_f32().iter().map(|v| v - 3.0).collect();
        Tensor::from_f32(&p.shape, &g)
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        let mut p = Tensor::from_f32(&[4], &[0.0, 10.0, -5.0, 3.0]);
        let mut opt = AdamW::new(0.0);
        for _ in 0..800 {
            let g = quad_grad(&p);
            opt.step(&mut [&mut p], &[g], 0.05);
        }
        for v in p.as_f32() {
            assert!((v - 3.0).abs() < 0.05, "{v}");
        }
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut p = Tensor::from_f32(&[2], &[10.0, -10.0]);
        let mut opt = Sgd::new(0.9);
        for _ in 0..300 {
            let g = quad_grad(&p);
            opt.step(&mut [&mut p], &[g], 0.05);
        }
        for v in p.as_f32() {
            assert!((v - 3.0).abs() < 0.01, "{v}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p = Tensor::from_f32(&[1], &[1.0]);
        let mut opt = AdamW::new(0.5);
        let zero = Tensor::from_f32(&[1], &[0.0]);
        for _ in 0..10 {
            opt.step(&mut [&mut p], std::slice::from_ref(&zero), 0.1);
        }
        assert!(p.as_f32()[0] < 1.0);
    }

    #[test]
    fn adamw_state_bytes_tracks_params() {
        let mut p = Tensor::from_f32(&[8], &[0.0; 8]);
        let mut opt = AdamW::new(0.0);
        assert_eq!(opt.state_bytes(), 0);
        let g = quad_grad(&p);
        opt.step(&mut [&mut p], &[g], 0.1);
        assert_eq!(opt.state_bytes(), 2 * 8 * 4);
    }
}
