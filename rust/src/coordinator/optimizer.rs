//! Host-side optimizers over the trainable parameter tensors.
//!
//! The optimizer is deliberately on the rust side of the ABI: parameter
//! state lives in host memory (like the paper's paged AdamW in QLoRA),
//! only fwd/bwd run through PJRT.

use anyhow::{ensure, Result};

use crate::coordinator::statefile::{Cur, Enc, StateError};
use crate::runtime::Tensor;

pub trait Optimizer {
    /// In-place update of `params[i]` from `grads[i]` (same order).
    fn step(&mut self, params: &mut [&mut Tensor], grads: &[Tensor], lr: f32);
    fn name(&self) -> &'static str;

    /// Resident optimizer-state bytes (AdamW m+v, SGD velocity) — the
    /// engine's measured per-session accounting; 0 until the first
    /// step materializes the state.
    fn state_bytes(&self) -> usize {
        0
    }

    /// Serialize the complete update state (step counter, moments,
    /// velocities) to raw little-endian bytes — the `session.opt`
    /// statefile section. Restoring via [`Optimizer::state_load`] on a
    /// same-typed, same-hyperparameter optimizer must continue the
    /// trajectory bit-identically. Stateless optimizers return empty.
    fn state_save(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state produced by [`Optimizer::state_save`] on the same
    /// optimizer type. The default (for stateless optimizers) accepts
    /// only an empty buffer.
    fn state_load(&mut self, bytes: &[u8]) -> Result<()> {
        ensure!(
            bytes.is_empty(),
            "optimizer {:?} carries no state but got {} bytes",
            self.name(),
            bytes.len()
        );
        Ok(())
    }

    /// [`Optimizer::step`] over trainables embedded in a *full*
    /// manifest-ordered vector: `idx` are the trainable indices
    /// (strictly increasing), `grads` in the same order. This is the
    /// safe replacement for the old raw-pointer disjoint-borrow dance
    /// in `Trainer::train` — [`disjoint_mut`] carves the references
    /// with `split_at_mut` — shared by any full-layout caller (e.g. a
    /// future fused-update path); `Session` itself keeps its
    /// trainables dense and calls `step` directly.
    fn step_indexed(&mut self, params: &mut [Tensor], idx: &[usize],
                    grads: &[Tensor], lr: f32) {
        let mut refs = disjoint_mut(params, idx);
        self.step(&mut refs, grads, lr);
    }
}

/// Encode a list of f32 state vectors (u32 count implied by the
/// caller; per-vector u32 length + raw f32 LE values).
fn write_vecs(e: &mut Enc, vecs: &[Vec<f32>]) {
    for v in vecs {
        e.u32(v.len() as u32);
        for &x in v {
            e.f32(x);
        }
    }
}

/// Bounds-checked inverse of [`write_vecs`] for `n` vectors.
fn read_vecs(c: &mut Cur, n: usize) -> Result<Vec<Vec<f32>>, StateError> {
    let mut out = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let len = c.u32()? as usize;
        let raw = c.bytes(len * 4)?;
        let mut v = Vec::with_capacity(len);
        for chunk in raw.chunks_exact(4) {
            v.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        out.push(v);
    }
    Ok(out)
}

/// Safe disjoint mutable borrows of `items` at strictly-increasing
/// indices: an index-sorted `split_at_mut` walker. Panics when the
/// indices are not strictly increasing or out of range — the same
/// conditions under which the old `unsafe` pointer version was UB.
pub fn disjoint_mut<'a, T>(items: &'a mut [T],
                           sorted_idx: &[usize]) -> Vec<&'a mut T> {
    let mut out = Vec::with_capacity(sorted_idx.len());
    let mut rest = items;
    let mut base = 0usize;
    for &i in sorted_idx {
        assert!(i >= base, "indices must be strictly increasing");
        let tail = rest.split_at_mut(i - base).1;
        let (head, tail) =
            tail.split_first_mut().expect("index out of range");
        out.push(head);
        rest = tail;
        base = i + 1;
    }
    out
}

/// AdamW (Loshchilov & Hutter, 2017) — the paper's optimizer.
pub struct AdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl AdamW {
    pub fn new(weight_decay: f32) -> AdamW {
        AdamW {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Optimizer-state bytes (the Tables' "optimizer" memory term).
    pub fn state_bytes(&self) -> usize {
        self.m.iter().map(|v| v.len() * 4).sum::<usize>()
            + self.v.iter().map(|v| v.len() * 4).sum::<usize>()
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, params: &mut [&mut Tensor], grads: &[Tensor],
            lr: f32) {
        assert_eq!(params.len(), grads.len());
        if self.m.is_empty() {
            for g in grads {
                self.m.push(vec![0.0; g.elems()]);
                self.v.push(vec![0.0; g.elems()]);
            }
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t);
        let bc2 = 1.0 - self.beta2.powi(self.t);
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let pv = p.as_f32_mut();
            let gv = g.as_f32();
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            for j in 0..pv.len() {
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * gv[j];
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * gv[j] * gv[j];
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                pv[j] -= lr * (mhat / (vhat.sqrt() + self.eps)
                    + self.weight_decay * pv[j]);
            }
        }
    }

    fn name(&self) -> &'static str {
        "adamw"
    }

    fn state_bytes(&self) -> usize {
        AdamW::state_bytes(self)
    }

    fn state_save(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.i64(self.t as i64);
        e.u32(self.m.len() as u32);
        write_vecs(&mut e, &self.m);
        write_vecs(&mut e, &self.v);
        e.into_bytes()
    }

    fn state_load(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = Cur::new(bytes, "session.opt (adamw)");
        let t = c.i64()?;
        ensure!(
            t >= 0 && t <= i32::MAX as i64,
            "adamw state: step counter {t} out of range"
        );
        let n = c.u32()? as usize;
        let m = read_vecs(&mut c, n)?;
        let v = read_vecs(&mut c, n)?;
        c.done()?;
        for (a, b) in m.iter().zip(&v) {
            ensure!(
                a.len() == b.len(),
                "adamw state: m/v length mismatch ({} vs {})",
                a.len(),
                b.len()
            );
        }
        self.t = t as i32;
        self.m = m;
        self.v = v;
        Ok(())
    }
}

/// Plain SGD (with optional momentum) — the convergence-theory baseline
/// (Theorem 4.2 is stated for SGD).
pub struct Sgd {
    pub momentum: f32,
    vel: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Sgd {
        Sgd { momentum, vel: Vec::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [&mut Tensor], grads: &[Tensor],
            lr: f32) {
        if self.momentum > 0.0 && self.vel.is_empty() {
            for g in grads {
                self.vel.push(vec![0.0; g.elems()]);
            }
        }
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let pv = p.as_f32_mut();
            let gv = g.as_f32();
            if self.momentum > 0.0 {
                let vel = &mut self.vel[i];
                for j in 0..pv.len() {
                    vel[j] = self.momentum * vel[j] + gv[j];
                    pv[j] -= lr * vel[j];
                }
            } else {
                for j in 0..pv.len() {
                    pv[j] -= lr * gv[j];
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn state_bytes(&self) -> usize {
        self.vel.iter().map(|v| v.len() * 4).sum()
    }

    fn state_save(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.u32(self.vel.len() as u32);
        write_vecs(&mut e, &self.vel);
        e.into_bytes()
    }

    fn state_load(&mut self, bytes: &[u8]) -> Result<()> {
        let mut c = Cur::new(bytes, "session.opt (sgd)");
        let n = c.u32()? as usize;
        let vel = read_vecs(&mut c, n)?;
        c.done()?;
        self.vel = vel;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &Tensor) -> Tensor {
        // f(p) = ||p - 3||²/2, grad = p - 3
        let g: Vec<f32> = p.as_f32().iter().map(|v| v - 3.0).collect();
        Tensor::from_f32(&p.shape, &g)
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        let mut p = Tensor::from_f32(&[4], &[0.0, 10.0, -5.0, 3.0]);
        let mut opt = AdamW::new(0.0);
        for _ in 0..800 {
            let g = quad_grad(&p);
            opt.step(&mut [&mut p], &[g], 0.05);
        }
        for v in p.as_f32() {
            assert!((v - 3.0).abs() < 0.05, "{v}");
        }
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut p = Tensor::from_f32(&[2], &[10.0, -10.0]);
        let mut opt = Sgd::new(0.9);
        for _ in 0..300 {
            let g = quad_grad(&p);
            opt.step(&mut [&mut p], &[g], 0.05);
        }
        for v in p.as_f32() {
            assert!((v - 3.0).abs() < 0.01, "{v}");
        }
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p = Tensor::from_f32(&[1], &[1.0]);
        let mut opt = AdamW::new(0.5);
        let zero = Tensor::from_f32(&[1], &[0.0]);
        for _ in 0..10 {
            opt.step(&mut [&mut p], std::slice::from_ref(&zero), 0.1);
        }
        assert!(p.as_f32()[0] < 1.0);
    }

    #[test]
    fn disjoint_mut_returns_requested_slots() {
        let mut v = vec![0, 10, 20, 30, 40];
        let refs = disjoint_mut(&mut v, &[1, 2, 4]);
        assert_eq!(refs.len(), 3);
        for r in refs {
            *r += 1;
        }
        assert_eq!(v, vec![0, 11, 21, 30, 41]);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn disjoint_mut_rejects_unsorted() {
        let mut v = vec![0, 1, 2];
        let _ = disjoint_mut(&mut v, &[2, 1]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn disjoint_mut_rejects_out_of_range() {
        let mut v = vec![0, 1, 2];
        let _ = disjoint_mut(&mut v, &[3]);
    }

    #[test]
    fn step_indexed_matches_dense_step() {
        // a full vector with trainables at {0, 2}: step_indexed must
        // update exactly those, identically to a dense step
        let mut full = vec![
            Tensor::from_f32(&[2], &[1.0, 2.0]),
            Tensor::from_f32(&[2], &[9.0, 9.0]),
            Tensor::from_f32(&[2], &[-1.0, 4.0]),
        ];
        let grads =
            vec![quad_grad(&full[0]), quad_grad(&full[2])];
        let mut dense0 = full[0].clone();
        let mut dense2 = full[2].clone();
        let mut a = AdamW::new(0.0);
        let mut b = AdamW::new(0.0);
        a.step_indexed(&mut full, &[0, 2], &grads, 0.05);
        b.step(&mut [&mut dense0, &mut dense2], &grads, 0.05);
        assert_eq!(full[0].as_f32(), dense0.as_f32());
        assert_eq!(full[2].as_f32(), dense2.as_f32());
        assert_eq!(full[1].as_f32(), &[9.0, 9.0]);
    }

    #[test]
    fn sgd_state_bytes_tracks_velocity() {
        let mut p = Tensor::from_f32(&[4], &[0.0; 4]);
        let mut opt = Sgd::new(0.9);
        assert_eq!(Optimizer::state_bytes(&opt), 0);
        let g = quad_grad(&p);
        opt.step(&mut [&mut p], &[g], 0.1);
        assert_eq!(Optimizer::state_bytes(&opt), 4 * 4);
    }

    #[test]
    fn adamw_state_bytes_tracks_params() {
        let mut p = Tensor::from_f32(&[8], &[0.0; 8]);
        let mut opt = AdamW::new(0.0);
        assert_eq!(opt.state_bytes(), 0);
        let g = quad_grad(&p);
        opt.step(&mut [&mut p], &[g], 0.1);
        assert_eq!(opt.state_bytes(), 2 * 8 * 4);
    }

    /// Save at step k, load into a fresh optimizer, and the continued
    /// trajectory must be bit-identical to the uninterrupted one.
    fn check_resume_bit_identity(mk: impl Fn() -> Box<dyn Optimizer>) {
        let start = [0.0f32, 10.0, -5.0, 3.0];
        let mut p_full = Tensor::from_f32(&[4], &start);
        let mut opt_full = mk();
        let mut p_half = Tensor::from_f32(&[4], &start);
        let mut opt_half = mk();
        for _ in 0..5 {
            let g = quad_grad(&p_full);
            opt_full.step(&mut [&mut p_full], &[g], 0.05);
            let g = quad_grad(&p_half);
            opt_half.step(&mut [&mut p_half], &[g], 0.05);
        }
        let saved = opt_half.state_save();
        let mut opt_resumed = mk();
        opt_resumed.state_load(&saved).unwrap();
        assert_eq!(
            opt_resumed.state_bytes(),
            opt_half.state_bytes(),
            "restored state bytes"
        );
        for _ in 0..5 {
            let g = quad_grad(&p_full);
            opt_full.step(&mut [&mut p_full], &[g], 0.05);
            let g = quad_grad(&p_half);
            opt_resumed.step(&mut [&mut p_half], &[g], 0.05);
        }
        assert_eq!(p_full.data, p_half.data, "bitwise trajectory");
    }

    #[test]
    fn adamw_state_roundtrip_is_bit_identical() {
        check_resume_bit_identity(|| Box::new(AdamW::new(0.01)));
    }

    #[test]
    fn sgd_state_roundtrip_is_bit_identical() {
        check_resume_bit_identity(|| Box::new(Sgd::new(0.9)));
    }

    #[test]
    fn fresh_state_roundtrips_and_preserves_lazy_init() {
        let mut opt = AdamW::new(0.0);
        let fresh = opt.state_save();
        opt.state_load(&fresh).unwrap();
        assert_eq!(opt.state_bytes(), 0);
        // A lazily-initializing optimizer restored from pre-first-step
        // state must still initialize on the first real step.
        let mut p = Tensor::from_f32(&[2], &[1.0, 2.0]);
        let g = quad_grad(&p);
        opt.step(&mut [&mut p], &[g], 0.05);
        assert_eq!(opt.state_bytes(), 2 * 2 * 4);
    }

    #[test]
    fn corrupt_state_is_error_not_panic() {
        let mut opt = AdamW::new(0.0);
        assert!(opt.state_load(&[1, 2, 3]).is_err());
        let mut good = {
            let mut p = Tensor::from_f32(&[2], &[1.0, 2.0]);
            let mut o = AdamW::new(0.0);
            let g = quad_grad(&p);
            o.step(&mut [&mut p], &[g], 0.05);
            o.state_save()
        };
        good.truncate(good.len() - 3);
        assert!(opt.state_load(&good).is_err());
        let mut sgd = Sgd::new(0.9);
        assert!(sgd.state_load(&[0xFF; 7]).is_err());
    }
}
