//! Checkpoints + cross-preset conversion (pretrain → fine-tune, and the
//! eq. 17 affine merge that turns an LN/RMS checkpoint into an
//! MS-LN/MS-RMSNorm one).
//!
//! Format: one `ckpt.state` statefile per checkpoint directory
//! (sections `ckpt.index` + `ckpt.data`, see `statefile` for the
//! container layout) — checksummed, versioned, typed errors on
//! corruption, dtype-faithful. Replaces the old two-file
//! `ckpt.json` + `ckpt.bin` pair, which was f32-only and silently
//! loaded truncated payloads.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::statefile::{
    self, StateFile, Writer,
};
use crate::runtime::{Manifest, Tensor};

pub struct Checkpoint {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn from_params(manifest: &Manifest, params: &[Tensor]) -> Self {
        let tensors = manifest
            .params
            .iter()
            .zip(params)
            .map(|(info, t)| (info.name.clone(), t.clone()))
            .collect();
        Checkpoint { tensors }
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        let entries: Vec<(&str, &Tensor)> = self
            .tensors
            .iter()
            .map(|(n, t)| (n.as_str(), t))
            .collect();
        let (index, data) = statefile::encode_tensors(&entries);
        let mut w = Writer::new();
        w.add("ckpt.index", index);
        w.add("ckpt.data", data);
        w.write(&dir.join("ckpt.state"))
    }

    pub fn load(dir: &Path) -> Result<Checkpoint> {
        let path = dir.join("ckpt.state");
        let buf = std::fs::read(&path)
            .with_context(|| format!("reading checkpoint {path:?}"))?;
        let sf = StateFile::parse(&buf)?;
        let tensors = statefile::decode_tensors(
            sf.section("ckpt.index")?,
            sf.section("ckpt.data")?,
            "ckpt",
        )?
        .into_iter()
        .collect();
        Ok(Checkpoint { tensors })
    }

    /// Restore into a parameter vector ordered by `manifest`.
    /// Missing tensors (e.g. fresh LoRA adapters) keep their init values.
    /// Returns the number of restored tensors.
    pub fn restore(&self, manifest: &Manifest,
                   params: &mut [Tensor]) -> Result<usize> {
        let mut n = 0;
        for (info, p) in manifest.params.iter().zip(params.iter_mut()) {
            if let Some(t) = self.tensors.get(&info.name) {
                if t.shape != info.shape {
                    bail!("shape mismatch for {}: ckpt {:?} vs manifest {:?}",
                          info.name, t.shape, info.shape);
                }
                p.data.copy_from_slice(&t.data);
                n += 1;
            }
        }
        Ok(n)
    }
}

/// eq. (17): merge each norm's affine (α, β) into the following linears:
///   W̃ = W·diag(α),  b̃ = W·β + b
/// Consumes a checkpoint trained with LN/RMS affine and produces the
/// parameter set for the matching MS-LN/MS-RMSNorm preset.
pub fn merge_affine(src: &Checkpoint, ms_manifest: &Manifest)
                    -> Result<Checkpoint> {
    let mut out = BTreeMap::new();
    // start from every tensor the MS model also has
    for info in &ms_manifest.params {
        if let Some(t) = src.tensors.get(&info.name) {
            out.insert(info.name.clone(), t.clone());
        }
    }
    for m in &ms_manifest.merges {
        let alpha = src.tensors.get(&format!("{}.w", m.norm));
        let beta = src.tensors.get(&format!("{}.b", m.norm));
        let Some(alpha) = alpha else {
            // source model had no affine (already MS) — nothing to merge
            continue;
        };
        let a = alpha.as_f32();
        for lin in &m.linears {
            let wname = format!("{lin}.W");
            let Some(w) = out.get(&wname).cloned() else {
                bail!("merge target {wname} missing");
            };
            let (dout, din) = (w.shape[0], w.shape[1]);
            anyhow::ensure!(din == a.len(),
                            "affine dim mismatch on {wname}");
            let mut wm = w.clone();
            {
                let wv = wm.as_f32_mut();
                for o in 0..dout {
                    for i in 0..din {
                        wv[o * din + i] *= a[i];
                    }
                }
            }
            if let Some(beta) = beta {
                let bname = format!("{lin}.b");
                let bv = beta.as_f32();
                if let Some(bold) = out.get(&bname).cloned() {
                    let wv = w.as_f32();
                    let mut bm = bold.clone();
                    let bmv = bm.as_f32_mut();
                    for o in 0..dout {
                        let mut acc = 0f32;
                        for i in 0..din {
                            acc += wv[o * din + i] * bv[i];
                        }
                        bmv[o] += acc;
                    }
                    out.insert(bname, bm);
                }
            }
            out.insert(wname, wm);
        }
    }
    Ok(Checkpoint { tensors: out })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ambp_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut tensors = BTreeMap::new();
        tensors.insert("a.W".to_string(),
                       Tensor::from_f32(&[2, 2], &[1., 2., 3., 4.]));
        tensors.insert("a.b".to_string(),
                       Tensor::from_f32(&[2], &[5., 6.]));
        let ck = Checkpoint { tensors };
        ck.save(&dir).unwrap();
        let ck2 = Checkpoint::load(&dir).unwrap();
        assert_eq!(ck2.tensors.len(), 2);
        assert_eq!(ck2.tensors["a.W"].as_f32(), &[1., 2., 3., 4.]);
        assert_eq!(ck2.tensors["a.b"].shape, vec![2]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
