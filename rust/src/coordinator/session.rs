//! A reentrant fine-tuning session: the step-driven decomposition of
//! the old monolithic `Trainer::train` loop.
//!
//! A [`Session`] owns everything that is *per-tenant* — the trainable
//! parameter slice, optimizer state, batch producer + prefetcher,
//! activation arena (a forked executor), metrics, and the measured
//! memory tracker — while reading the frozen base through the
//! artifact's `Arc`-shared [`FrozenBase`]. One call to
//! [`Session::step`] runs one full optimizer step (all `grad_accum`
//! microbatches), so an engine can interleave many sessions fairly at
//! step granularity; `Trainer::train` is now a thin loop over `step`.
//!
//! Determinism contract: a session's work depends only on
//! (artifact, `TrainCfg`) — the data stream is indexed, the optimizer
//! state is private, and the forked executor runs the same
//! deterministic kernels — so K sessions interleaved in any order
//! produce bit-identical losses and parameters to the same K jobs run
//! serially (pinned by `tests/engine.rs`).

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::coordinator::memory::MemoryTracker;
use crate::coordinator::metrics::{Metrics, StepRow};
use crate::coordinator::optimizer::{AdamW, Optimizer, Sgd};
use crate::coordinator::supervisor::NumericFault;
use crate::coordinator::trainer::{TrainCfg, TrainReport};
use crate::data::loader::{Batch, Prefetcher};
use crate::data::synth_images::ImageTask;
use crate::data::synth_text::TextTask;
use crate::runtime::{Artifact, Executor, FrozenBase, FwdOut, Tensor};

/// Deterministic, index-addressed batch producer shared by the
/// prefetcher (training stream), the warmup step, and evaluation.
pub type Producer = Arc<dyn Fn(usize) -> Batch + Send + Sync>;

/// Build the task-appropriate batch producer for an artifact. Errors on
/// an arch tag this coordinator has no generator for (same contract as
/// the other manifest parse paths — never panics on input data).
pub(crate) fn make_producer(art: &Artifact,
                            cfg: &TrainCfg) -> Result<Producer> {
    let m = &art.manifest;
    let b = m.batch;
    let p: Producer = match m.arch.as_str() {
        "vit" => {
            let task = ImageTask::new(m.n_classes, m.n_tokens, m.patch_dim,
                                      cfg.data_noise, cfg.seed);
            Arc::new(move |step| {
                let (x, y) = task.batch(step as u64 * b as u64, b);
                Batch::Images { x, y }
            })
        }
        "llama" => {
            let task = TextTask::new(m.vocab, m.n_tokens, 4, 0.85,
                                     cfg.seed);
            Arc::new(move |step| {
                let (x, y) = task.batch_lm(step as u64 * b as u64, b);
                Batch::Tokens { x, y }
            })
        }
        "roberta" => {
            let task = TextTask::new(m.vocab, m.n_tokens, m.n_classes,
                                     0.85, cfg.seed);
            Arc::new(move |step| {
                let (x, y) = task.batch_cls(step as u64 * b as u64, b);
                Batch::Tokens { x, y }
            })
        }
        other => anyhow::bail!(
            "unknown arch {other:?} (trainer has batch generators for \
             vit|llama|roberta)"
        ),
    };
    Ok(p)
}

pub(crate) fn to_tensors(art: &Artifact, batch: Batch) -> (Tensor, Tensor) {
    let m = &art.manifest;
    match batch {
        Batch::Images { x, y } => (
            Tensor::from_f32(&m.x.shape, &x),
            Tensor::from_i32(&m.y.shape, &y),
        ),
        Batch::Tokens { x, y } => (
            Tensor::from_i32(&m.x.shape, &x),
            Tensor::from_i32(&m.y.shape, &y),
        ),
    }
}

/// The portable state of a suspended session — everything a
/// same-artifact process needs to continue the run bit-identically:
/// the trainable tensors, the raw optimizer state, the step counter
/// (which, because the data producer is index-addressed, *is* the
/// producer position: micro-batch index = step × grad_accum), the
/// metrics rows, and the memory tracker. The frozen base is NOT here —
/// it is identified by fingerprint and re-attached from the resident
/// artifact on resume (stored-once across suspend/resume).
///
/// Serialized to disk by `statefile::save_session` / rebuilt by
/// `statefile::load_session`; turned back into a live [`Session`] by
/// [`Session::resume`].
#[derive(Debug, Clone)]
pub struct SessionState {
    /// Artifact preset this state belongs to.
    pub preset: String,
    /// Fingerprint of the frozen base the trainables were split from.
    pub base_fingerprint: u64,
    /// The full training configuration.
    pub cfg: TrainCfg,
    /// Optimizer steps completed.
    pub step: usize,
    /// Manifest names of the trainable tensors, in trainable order.
    pub trainable_names: Vec<String>,
    /// The trainable tensors, in manifest trainable order.
    pub trainable: Vec<Tensor>,
    /// Optimizer identifier (`"adamw"`, `"sgd"`).
    pub opt_name: String,
    /// Raw optimizer state (`Optimizer::state_save`).
    pub opt_state: Vec<u8>,
    /// Loss-curve rows logged so far.
    pub rows: Vec<StepRow>,
    /// Measured memory accounting at suspend time.
    pub memory: MemoryTracker,
}

/// Result of one [`Session::step`] call.
pub enum StepOutcome {
    /// One optimizer step completed.
    Stepped(StepStats),
    /// The configured step budget was already exhausted; nothing ran.
    Exhausted,
}

/// Per-step statistics of a completed [`Session::step`].
#[derive(Debug, Clone)]
pub struct StepStats {
    /// 0-based index of the step that just completed.
    pub step: usize,
    /// Microbatch-averaged training loss.
    pub loss: f32,
    /// Microbatch-averaged task metric.
    pub metric: f32,
    /// Learning rate applied.
    pub lr: f32,
    /// Measured residual (activation) bytes of the step.
    pub activation_bytes: u64,
}

/// In-flight state of one optimizer step, threaded through the
/// decomposed step phases ([`Session::begin_step`] →
/// `grad_accum` × ([`Session::next_micro`] → fwd →
/// [`Session::absorb_fwd`] → bwd → [`Session::absorb_bwd`]) →
/// [`Session::finish_step`]). The serial [`Session::step`] drives the
/// same phases back-to-back; the engine's fused path interleaves them
/// across gang members, executing the fwd/bwd passes through the
/// `_many` executor entry points instead.
pub(crate) struct StepCtx {
    step: usize,
    lr: f32,
    loss_acc: f32,
    metric_acc: f32,
    accum: Option<Vec<Tensor>>,
    /// Whether the step's fwd/bwd ran through the artifact's shared
    /// executor (the fused path) rather than this session's fork —
    /// residual and gradient buffers must be recycled where they came
    /// from.
    fused: bool,
}

/// Constructor result that, on failure, carries the caller's
/// parameters back out (rejoined to the full manifest-ordered vector)
/// instead of dropping them — so `Trainer::train` can restore a
/// checkpoint-loaded state exactly after a failed session build.
type Recoverable<'a> = std::result::Result<Session<'a>,
                                           (anyhow::Error, Vec<Tensor>)>;

/// A reentrant fine-tuning session over an artifact (see module docs).
pub struct Session<'a> {
    art: &'a Artifact,
    cfg: TrainCfg,
    base: Arc<FrozenBase>,
    trainable: Vec<Tensor>,
    opt: Box<dyn Optimizer>,
    /// Measured activation-memory accounting for this session.
    pub memory: MemoryTracker,
    /// Forked per-session executor (own arena); `None` falls back to
    /// the artifact's shared executor.
    exec: Option<Box<dyn Executor>>,
    /// Flat-ABI fallback for executors without split support (e.g.
    /// PJRT, which neither forks nor overrides `run_fwd_split`): one
    /// materialized full parameter vector plus the trainable indices,
    /// kept in sync after each optimizer step. Without this, the
    /// default split impls would deep-copy the whole parameter set on
    /// every fwd *and* bwd. `None` on backends that fork (native).
    flat: Option<(Vec<Tensor>, Vec<usize>)>,
    producer: Producer,
    prefetch: Prefetcher,
    metrics: Metrics,
    step: usize,
}

impl<'a> Session<'a> {
    /// Session sharing the artifact's frozen base (`Arc`-shared with
    /// every other session on this artifact) and a fresh copy of the
    /// trainable slice. Warms up exactly once (see [`Session::build`]).
    pub fn new(art: &'a Artifact, cfg: TrainCfg) -> Result<Session<'a>> {
        Session::build(art, cfg, art.frozen_base(), art.trainable_init(),
                       0, false)
            .map_err(|(e, _)| e)
    }

    /// Rebuild a live session from suspended state against a resident
    /// artifact — the other half of [`Session::snapshot`] /
    /// [`Session::into_state`]. The session re-attaches to the
    /// artifact's `Arc`-shared frozen base (validated by fingerprint,
    /// so the trainables provably belong to these frozen weights), the
    /// data producer restarts at micro-batch `step × grad_accum`, and
    /// the optimizer state is restored bit-exactly — the continued run
    /// is bit-identical to one that was never suspended (pinned by
    /// `tests/statefile.rs`). The original session already paid the
    /// one-off warmup pass, so resume skips it — warmup performs no
    /// parameter update and feeds an out-of-range batch index, so
    /// skipping it cannot perturb the training state.
    pub fn resume(art: &'a Artifact,
                  state: SessionState) -> Result<Session<'a>> {
        let SessionState {
            preset,
            base_fingerprint,
            cfg,
            step,
            trainable_names,
            trainable,
            opt_name,
            opt_state,
            rows,
            memory,
        } = state;
        ensure!(
            preset == art.manifest.preset,
            "session resume: state is for preset {preset:?}, artifact \
             is {:?}",
            art.manifest.preset
        );
        let base = art.frozen_base();
        ensure!(
            base.fingerprint() == base_fingerprint,
            "session resume: frozen-base fingerprint {:#018x} does not \
             match the saved {base_fingerprint:#018x} — these trainables \
             belong to different frozen weights",
            base.fingerprint()
        );
        ensure!(
            step <= cfg.steps,
            "session resume: step {step} beyond configured total {}",
            cfg.steps
        );
        let expect: Vec<_> =
            art.manifest.params.iter().filter(|p| p.trainable).collect();
        ensure!(
            expect.len() == trainable.len()
                && trainable_names.len() == trainable.len(),
            "session resume: {} trainable tensors ({} names) vs {} in \
             the manifest",
            trainable.len(),
            trainable_names.len(),
            expect.len()
        );
        for ((p, name), t) in
            expect.iter().zip(&trainable_names).zip(&trainable)
        {
            ensure!(
                p.name == *name,
                "session resume: trainable {name:?} where the manifest \
                 expects {:?}",
                p.name
            );
            ensure!(
                p.shape == t.shape,
                "session resume: {name:?} has shape {:?}, manifest says \
                 {:?}",
                t.shape,
                p.shape
            );
        }
        let mut s = Session::build(art, cfg, base, trainable, step, true)
            .map_err(|(e, _)| e)?;
        ensure!(
            s.opt.name() == opt_name,
            "session resume: saved optimizer {opt_name:?}, config \
             builds {:?}",
            s.opt.name()
        );
        s.opt.state_load(&opt_state)?;
        let samples = rows.len() as u64
            * (art.manifest.batch * s.cfg.grad_accum) as u64;
        s.metrics.restore(rows, samples)?;
        s.memory = memory;
        Ok(s)
    }

    /// Session over explicit full parameters (e.g. restored from a
    /// checkpoint): splits them along the manifest boundary into a
    /// *private* frozen base plus the trainable slice. Numerically
    /// identical to [`Session::new`] when `full` equals the artifact's
    /// initial parameters.
    pub fn with_params(art: &'a Artifact, cfg: TrainCfg,
                       full: Vec<Tensor>) -> Result<Session<'a>> {
        Session::try_with_params(art, cfg, full).map_err(|(e, _)| e)
    }

    /// [`Session::with_params`] that, on failure, returns the caller's
    /// full parameter vector (values intact) alongside the error.
    pub(crate) fn try_with_params(art: &'a Artifact, cfg: TrainCfg,
                                  full: Vec<Tensor>) -> Recoverable<'a> {
        if full.len() != art.manifest.params.len() {
            let e = anyhow::anyhow!(
                "param arity: got {}, manifest has {}", full.len(),
                art.manifest.params.len());
            return Err((e, full));
        }
        let (base, trainable) = FrozenBase::split(&art.manifest, full)
            .expect("arity checked above");
        let base = Arc::new(base);
        Session::build(art, cfg, base.clone(), trainable, 0, false)
            .map_err(|(e, trainable)| (e, base.join(trainable)))
    }

    /// Shared constructor: fork the executor, build the single batch
    /// producer (prefetcher + warmup + eval all reuse it), run the one
    /// unmeasured warmup fwd/bwd — so first-run lazy initialization
    /// (page faults on the parameter arrays, arena fill) is not charged
    /// to the throughput meter — and only then start the metrics clock.
    /// On failure the trainable tensors ride back out with the error.
    ///
    /// `start_step > 0` is the resume path: the prefetcher starts at
    /// micro-batch `start_step × grad_accum` and the step counter at
    /// `start_step`, so the session sees exactly the tail of the batch
    /// sequence an uninterrupted run would. `warmed` marks a session
    /// whose state already went through warmup once (the resume path):
    /// the pass is skipped there — it performs no parameter update, so
    /// identity holds either way, but skipping it saves one full
    /// fwd/bwd of compute per resume.
    fn build(art: &'a Artifact, cfg: TrainCfg, base: Arc<FrozenBase>,
             trainable: Vec<Tensor>, start_step: usize,
             warmed: bool) -> Recoverable<'a> {
        if trainable.len() != base.n_trainable() {
            let e = anyhow::anyhow!(
                "trainable slice arity: got {}, base expects {}",
                trainable.len(), base.n_trainable());
            return Err((e, trainable));
        }
        let opt: Box<dyn Optimizer> = match cfg.optimizer.as_str() {
            "sgd" => Box::new(Sgd::new(0.9)),
            _ => Box::new(AdamW::new(cfg.weight_decay)),
        };
        let producer = match make_producer(art, &cfg) {
            Ok(p) => p,
            Err(e) => return Err((e, trainable)),
        };
        let stream = producer.clone();
        let prefetch = Prefetcher::spawn_range(
            start_step * cfg.grad_accum,
            cfg.steps * cfg.grad_accum,
            2,
            move |s| (stream.as_ref())(s),
        );
        let exec = art.fork_exec();
        // on a backend without native split support, materialize one
        // flat vector now instead of letting the default split impls
        // clone the whole set per pass
        let flat = if art.supports_split() {
            None
        } else {
            Some((base.join(trainable.clone()),
                  art.manifest.trainable_indices()))
        };
        let mut s = Session {
            art,
            cfg,
            base,
            trainable,
            opt,
            memory: MemoryTracker::new(),
            exec,
            flat,
            producer,
            prefetch,
            metrics: Metrics::new(None).expect("no-sink metrics"),
            step: start_step,
        };
        if !warmed {
            if let Err(e) = s.warmup() {
                return Err((e, s.take_trainable()));
            }
        }
        // the metrics clock (throughput denominator) starts post-warmup
        let sink = s.cfg.metrics_jsonl.clone();
        match Metrics::new(sink.as_deref()) {
            Ok(m) => s.metrics = m,
            Err(e) => return Err((e, s.take_trainable())),
        }
        Ok(s)
    }

    /// One unmeasured fwd/bwd. The batch index is far outside any
    /// train/eval index range, but small enough that `step * batch`
    /// cannot overflow inside the producer.
    fn warmup(&mut self) -> Result<()> {
        let (x, y) = to_tensors(self.art, self.produce(u32::MAX as usize));
        let out = self.fwd(&x, &y)?;
        let g = self.bwd(&out.residuals, &x, &y)?;
        self.recycle(out.residuals);
        self.recycle(g);
        Ok(())
    }

    fn take_trainable(self) -> Vec<Tensor> {
        let Session { trainable, .. } = self;
        trainable
    }

    fn exec(&self) -> &dyn Executor {
        match &self.exec {
            Some(e) => e.as_ref(),
            None => self.art.executor(),
        }
    }

    fn produce(&self, idx: usize) -> Batch {
        (self.producer.as_ref())(idx)
    }

    fn fwd(&self, x: &Tensor, y: &Tensor) -> Result<FwdOut> {
        let out = match &self.flat {
            Some((full, _)) => self.exec().run_fwd(full, x, y)?,
            None => self
                .exec()
                .run_fwd_split(&self.base, &self.trainable, x, y)?,
        };
        self.art.verify_fwd(&out)?;
        Ok(out)
    }

    fn bwd(&self, residuals: &[Tensor], x: &Tensor,
           y: &Tensor) -> Result<Vec<Tensor>> {
        let grads = match &self.flat {
            Some((full, _)) => {
                self.exec().run_bwd(full, residuals, x, y)?
            }
            None => self.exec().run_bwd_split(&self.base,
                                              &self.trainable,
                                              residuals, x, y)?,
        };
        self.art.verify_bwd(&grads)?;
        Ok(grads)
    }

    /// Copy the (just-updated) trainable tensors back into the flat
    /// fallback vector, if one exists.
    fn sync_flat(&mut self) {
        if let Some((full, tidx)) = &mut self.flat {
            for (rank, &i) in tidx.iter().enumerate() {
                full[i].data.copy_from_slice(&self.trainable[rank].data);
            }
        }
    }

    /// Return step-scoped tensors to this session's executor arena.
    pub fn recycle(&self, tensors: Vec<Tensor>) {
        self.exec().recycle(tensors);
    }

    /// Route step-scoped tensors back to the arena they came from: a
    /// fused step's buffers were taken from the artifact's shared
    /// executor, a serial step's from this session's fork.
    fn recycle_routed(&self, fused: bool, tensors: Vec<Tensor>) {
        if fused {
            self.art.recycle(tensors);
        } else {
            self.recycle(tensors);
        }
    }

    /// Whether this session can join a fused gang: it must read the
    /// split parameter ABI (flat-fallback sessions have no shared
    /// frozen base for the gang to sweep once).
    pub(crate) fn fusable(&self) -> bool {
        self.flat.is_none()
    }

    /// The session's trainable tensors (manifest trainable order) — the
    /// per-member half of a fused `_many` job.
    pub(crate) fn trainable_slice(&self) -> &[Tensor] {
        &self.trainable
    }

    /// Microbatches per optimizer step.
    pub(crate) fn grad_accum(&self) -> usize {
        self.cfg.grad_accum
    }

    /// The artifact this session fine-tunes.
    pub fn artifact(&self) -> &'a Artifact {
        self.art
    }

    /// The shared frozen base handle (engine accounting + the
    /// stored-once assertion compare `Arc` identities through this).
    pub fn base(&self) -> &Arc<FrozenBase> {
        &self.base
    }

    /// Resident bytes of this session's private trainable tensors.
    pub fn trainable_bytes(&self) -> u64 {
        self.trainable.iter().map(|t| t.nbytes() as u64).sum()
    }

    /// All parameter bytes this session privately holds: the trainable
    /// slice, plus (on non-forking backends only) the flat-ABI fallback
    /// vector — which duplicates the full parameter set.
    pub fn resident_param_bytes(&self) -> u64 {
        let flat: u64 = self
            .flat
            .as_ref()
            .map(|(full, _)| {
                full.iter().map(|t| t.nbytes() as u64).sum()
            })
            .unwrap_or(0);
        self.trainable_bytes() + flat
    }

    /// Resident bytes of the optimizer state (0 until the first step
    /// materializes it).
    pub fn opt_state_bytes(&self) -> u64 {
        self.opt.state_bytes() as u64
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Whether the configured step budget is exhausted.
    pub fn is_done(&self) -> bool {
        self.step >= self.cfg.steps
    }

    /// Open one optimizer step: capture the step index and scheduled
    /// learning rate. `None` when the step budget is exhausted.
    /// `fused` marks a step whose fwd/bwd will run through the
    /// artifact's shared executor (the engine's gang path) — it only
    /// routes buffer recycling; all arithmetic is identical.
    pub(crate) fn begin_step(&self, fused: bool) -> Option<StepCtx> {
        if self.is_done() {
            return None;
        }
        let step = self.step;
        let lr = self.cfg.schedule.lr(self.cfg.lr, step, self.cfg.steps);
        Some(StepCtx {
            step,
            lr,
            loss_acc: 0.0,
            metric_acc: 0.0,
            accum: None,
            fused,
        })
    }

    /// Pull the next microbatch off this session's prefetcher and
    /// materialize it as input tensors.
    pub(crate) fn next_micro(&mut self) -> Result<(Tensor, Tensor)> {
        let batch = self
            .prefetch
            .next()
            .ok_or_else(|| anyhow::anyhow!("prefetcher exhausted"))?;
        Ok(to_tensors(self.art, batch))
    }

    /// Absorb one microbatch's forward output: accumulate loss/metric
    /// and record the measured activation-memory moment. Fault site
    /// "step.loss" lives here, so fused gangs attribute it to the
    /// member whose absorb is running.
    pub(crate) fn absorb_fwd(&mut self, ctx: &mut StepCtx,
                             out: &FwdOut) -> Result<()> {
        let grad_accum = self.cfg.grad_accum;
        ctx.loss_acc += out.loss / grad_accum as f32;
        ctx.metric_acc += out.metric / grad_accum as f32;
        // fault site "step.loss": `nan` poisons the accumulated
        // loss; `io`/`panic` abort the microbatch loop here
        if crate::util::faultpoint::trip("step.loss")? {
            ctx.loss_acc = f32::NAN;
        }
        // ---- the measured activation-memory moment ----
        self.memory.observe_residuals(&self.art.manifest,
                                      &out.residuals);
        Ok(())
    }

    /// Absorb one microbatch's backward output: account the gradient
    /// peak, retire the residuals, and fold the gradients into the
    /// step's accumulator. Fault site "step.compute" lives here.
    pub(crate) fn absorb_bwd(&mut self, ctx: &mut StepCtx,
                             residuals: Vec<Tensor>,
                             mut grads: Vec<Tensor>) -> Result<()> {
        // fault site "step.compute": `nan` poisons one gradient
        // element (caught by the norm gate in `finish_step`)
        if crate::util::faultpoint::trip("step.compute")? {
            if let Some(v) = grads
                .first_mut()
                .and_then(|g| g.as_f32_mut().first_mut())
            {
                *v = f32::NAN;
            }
        }
        // at the peak both the fresh gradients and (under
        // grad_accum > 1) the running accumulator are live
        let gbytes: u64 =
            grads.iter().map(|g| g.nbytes() as u64).sum();
        let abytes: u64 = ctx
            .accum
            .as_ref()
            .map(|acc| acc.iter().map(|g| g.nbytes() as u64).sum())
            .unwrap_or(0);
        self.memory.observe_extra(gbytes + abytes);
        self.memory.release();
        // the residuals are dead past this point — hand their
        // buffers back to the executor's arena for the next step
        self.recycle_routed(ctx.fused, residuals);
        match &mut ctx.accum {
            None => {
                ctx.accum = Some(grads);
            }
            Some(acc) => {
                for (a, g) in acc.iter_mut().zip(&grads) {
                    let av = a.as_f32_mut();
                    for (ai, gi) in av.iter_mut().zip(g.as_f32()) {
                        *ai += gi;
                    }
                }
                self.recycle_routed(ctx.fused, grads);
            }
        }
        Ok(())
    }

    /// Close one optimizer step: numeric health gates, the optimizer
    /// update, metrics logging, and the step-counter advance.
    pub(crate) fn finish_step(&mut self,
                              mut ctx: StepCtx) -> Result<StepStats> {
        let mut grads =
            ctx.accum.take().expect("finish_step before any microbatch");
        let StepCtx { step, lr, loss_acc, metric_acc, fused, .. } = ctx;
        let grad_accum = self.cfg.grad_accum;
        if grad_accum > 1 {
            let inv = 1.0 / grad_accum as f32;
            for g in &mut grads {
                for v in g.as_f32_mut() {
                    *v *= inv;
                }
            }
        }
        // Numeric health gate — *before* the optimizer update, so a
        // poisoned step returns a typed error while the trainables and
        // optimizer state are still at their last good values (the
        // supervisor quarantines from exactly this state).
        if !loss_acc.is_finite() {
            return Err(NumericFault {
                what: "loss",
                value: loss_acc as f64,
                step,
            }
            .into());
        }
        if !metric_acc.is_finite() {
            return Err(NumericFault {
                what: "metric",
                value: metric_acc as f64,
                step,
            }
            .into());
        }
        let grad_sq: f64 = grads
            .iter()
            .map(|g| {
                g.as_f32()
                    .iter()
                    .map(|&v| v as f64 * v as f64)
                    .sum::<f64>()
            })
            .sum();
        if !grad_sq.is_finite() {
            return Err(NumericFault {
                what: "gradient norm",
                value: grad_sq,
                step,
            }
            .into());
        }
        {
            let mut refs: Vec<&mut Tensor> =
                self.trainable.iter_mut().collect();
            self.opt.step(&mut refs, &grads, lr);
        }
        self.sync_flat();
        // the gradient tensors' buffers came from the executor's
        // arena (native backend); hand them back for the next step
        self.recycle_routed(fused, grads);
        let activation_bytes = self.memory.last_residual_bytes;
        self.metrics.log_step(
            StepRow {
                step,
                loss: loss_acc,
                metric: metric_acc,
                lr,
                activation_bytes,
                elapsed_s: self.metrics.elapsed_s(),
            },
            self.art.manifest.batch * grad_accum,
        )?;
        if self.cfg.log_every > 0 && step % self.cfg.log_every == 0 {
            eprintln!(
                "step {step:>5}  loss {loss_acc:.4}  metric \
                 {metric_acc:.3}  lr {lr:.2e}  act \
                 {:.1} MiB",
                activation_bytes as f64 / 1048576.0
            );
        }
        self.step += 1;
        Ok(StepStats {
            step,
            loss: loss_acc,
            metric: metric_acc,
            lr,
            activation_bytes,
        })
    }

    /// Discard an in-flight step (the engine peels a faulted gang
    /// member): hand any accumulated gradient buffers back to their
    /// arena. No session state changes — the step counter only
    /// advances in [`Session::finish_step`], so the session is still
    /// at its last good state afterwards.
    pub(crate) fn abort_step(&self, ctx: StepCtx) {
        let StepCtx { accum, fused, .. } = ctx;
        if let Some(grads) = accum {
            self.recycle_routed(fused, grads);
        }
    }

    /// Run one full optimizer step: `grad_accum` microbatches of
    /// fwd → observe residuals → bwd → accumulate, then the optimizer
    /// update over the trainable slice (no raw-pointer disjoint-borrow
    /// dance: the trainables are a dense per-session vector). The body
    /// is exactly the decomposed phase sequence the engine's fused path
    /// drives, so serial and fused steps share every line of per-step
    /// arithmetic.
    pub fn step(&mut self) -> Result<StepOutcome> {
        let mut ctx = match self.begin_step(false) {
            Some(c) => c,
            None => return Ok(StepOutcome::Exhausted),
        };
        for _ in 0..self.cfg.grad_accum {
            let (x, y) = self.next_micro()?;
            let out = self.fwd(&x, &y)?;
            self.absorb_fwd(&mut ctx, &out)?;
            let grads = self.bwd(&out.residuals, &x, &y)?;
            self.absorb_bwd(&mut ctx, out.residuals, grads)?;
        }
        Ok(StepOutcome::Stepped(self.finish_step(ctx)?))
    }

    /// Evaluate on held-out batches (forward only), reusing the
    /// session's producer — no per-call producer rebuild — and leaving
    /// the step counter untouched.
    pub fn evaluate(&mut self, start: usize,
                    n_batches: usize) -> Result<(f32, f32)> {
        let mut loss = 0f32;
        let mut metric = 0f32;
        for i in 0..n_batches {
            let (x, y) = to_tensors(self.art, self.produce(start + i));
            let out = self.fwd(&x, &y)?;
            loss += out.loss / n_batches as f32;
            metric += out.metric / n_batches as f32;
            self.recycle(out.residuals);
        }
        Ok((loss, metric))
    }

    /// Flush metrics, run the end-of-training held-out evaluation
    /// (fresh data indices past the training range), and assemble the
    /// final report. Callable once the step budget is exhausted — or
    /// earlier, for a partial report.
    pub fn finish(&mut self) -> Result<TrainReport> {
        self.metrics.flush()?;
        let (eval_loss, eval_metric) = self.evaluate(
            self.cfg.steps * self.cfg.grad_accum + 1000,
            self.cfg.eval_batches,
        )?;
        Ok(TrainReport {
            final_loss: self.metrics.mean_recent_loss(20),
            final_metric: self.metrics.mean_recent_metric(20),
            eval_loss,
            eval_metric,
            throughput: self.metrics.throughput(),
            peak_activation_bytes: self.memory.peak_bytes,
            steps: self.step,
            rows: self.metrics.rows.clone(),
            by_kind: self.memory.by_kind.clone(),
            by_module: self.memory.by_module.clone(),
        })
    }

    /// The full parameter vector (manifest order): frozen tensors
    /// cloned from the (possibly shared) base, trainables cloned from
    /// this session.
    pub fn params(&self) -> Vec<Tensor> {
        self.base.join(self.trainable.clone())
    }

    /// Consume the session into its full parameter vector, moving the
    /// trainable tensors out (frozen tensors are still cloned — the
    /// base may be shared with other sessions).
    pub fn into_params(self) -> Vec<Tensor> {
        let Session { base, trainable, .. } = self;
        base.join(trainable)
    }

    /// Manifest names of the trainable tensors, in trainable order.
    fn trainable_names(&self) -> Vec<String> {
        self.art
            .manifest
            .params
            .iter()
            .filter(|p| p.trainable)
            .map(|p| p.name.clone())
            .collect()
    }

    /// Clone this session's portable state (see [`SessionState`]); the
    /// session stays live. Use [`Session::into_state`] to consume it
    /// instead (moves the trainables, no copy).
    pub fn snapshot(&self) -> SessionState {
        SessionState {
            preset: self.art.manifest.preset.clone(),
            base_fingerprint: self.base.fingerprint(),
            cfg: self.cfg.clone(),
            step: self.step,
            trainable_names: self.trainable_names(),
            trainable: self.trainable.clone(),
            opt_name: self.opt.name().to_string(),
            opt_state: self.opt.state_save(),
            rows: self.metrics.rows.clone(),
            memory: self.memory.clone(),
        }
    }

    /// Consume the session into its portable state — the suspend path:
    /// the trainable tensors move out (no copy), the prefetcher thread
    /// is joined by drop, and the `Arc` on the shared frozen base is
    /// released (its bytes stay resident with the artifact).
    pub fn into_state(self) -> SessionState {
        let preset = self.art.manifest.preset.clone();
        let base_fingerprint = self.base.fingerprint();
        let trainable_names = self.trainable_names();
        let opt_name = self.opt.name().to_string();
        let opt_state = self.opt.state_save();
        let rows = self.metrics.rows.clone();
        let Session { cfg, trainable, memory, step, .. } = self;
        SessionState {
            preset,
            base_fingerprint,
            cfg,
            step,
            trainable_names,
            trainable,
            opt_name,
            opt_state,
            rows,
            memory,
        }
    }
}
