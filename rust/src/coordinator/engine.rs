//! The multi-tenant fine-tuning engine: memory-budgeted admission +
//! fair step interleaving over sessions that share frozen bases.
//!
//! The paper's observation — activation memory, not weights, is the
//! per-job scaling bottleneck — becomes *capacity* here: the frozen
//! base of an artifact is resident once (`Arc`-shared
//! [`FrozenBase`]), so the marginal footprint of one more session is
//! its activation tape + gradients + optimizer state + trainable
//! slice. Admission control meters exactly that, using the analytical
//! memmodel prediction ([`MemCfg::from_manifest`], `Mode::Tape`)
//! cross-checked against the schema-derived manifest total; scheduling
//! is round-robin at [`Session::step`] granularity over the shared
//! worker pool; the fleet-wide peak is tracked with the same
//! [`MemoryTracker`] the single-job path uses. [`fleet_capacity`]
//! restates the paper's Table-1 savings as sessions-per-budget:
//! `*_regelu2_msln` / `*_mesa` presets admit strictly more tenants
//! than their baselines under the same byte budget.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::memory::MemoryTracker;
use crate::coordinator::session::{Session, StepOutcome};
use crate::coordinator::trainer::{TrainCfg, TrainReport};
use crate::memmodel::{total_bytes, MemCfg};
use crate::runtime::{Artifact, Runtime};

/// One job request: a preset plus its trainer hyper-parameters.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Preset name (artifact to load or synthesize).
    pub preset: String,
    /// Per-session hyper-parameters.
    pub cfg: TrainCfg,
}

impl JobSpec {
    /// Parse a `preset[:steps[:seed]]` job token (the `--jobs` list
    /// grammar). Defaults come from `base`; when no seed is given, the
    /// job index is added to the base seed so identical presets stream
    /// distinct tenant data.
    pub fn parse(token: &str, base: &TrainCfg,
                 job_index: usize) -> Result<JobSpec> {
        let mut parts = token.split(':');
        let preset = parts
            .next()
            .filter(|p| !p.is_empty())
            .with_context(|| format!("empty job spec {token:?}"))?
            .to_string();
        let mut cfg = base.clone();
        cfg.seed = base.seed + job_index as u64;
        if let Some(s) = parts.next() {
            cfg.steps = s
                .parse()
                .with_context(|| format!("bad steps in job {token:?}"))?;
        }
        if let Some(s) = parts.next() {
            cfg.seed = s
                .parse()
                .with_context(|| format!("bad seed in job {token:?}"))?;
        }
        if let Some(extra) = parts.next() {
            bail!("job {token:?}: unexpected field {extra:?} \
                   (grammar: preset[:steps[:seed]])");
        }
        Ok(JobSpec { preset, cfg })
    }
}

/// The memmodel-backed per-session footprint prediction admission
/// control gates on. All figures are bytes.
#[derive(Debug, Clone)]
pub struct Admission {
    /// Predicted activation tape held between fwd and bwd —
    /// `max(memmodel Tape-mode total, manifest residual total)`.
    pub tape_bytes: u64,
    /// Gradient sets held at the step peak: one, or two with
    /// `grad_accum > 1` (the running accumulator is live while the
    /// next microbatch's fresh gradients materialize).
    pub grad_bytes: u64,
    /// Optimizer state (AdamW m+v, SGD velocity).
    pub opt_bytes: u64,
    /// The session's private trainable parameter copy.
    pub trainable_bytes: u64,
    /// Extra full-parameter copy a session on a *non-forking* backend
    /// materializes as its flat-ABI fallback (0 on backends with split
    /// support, i.e. native): without this term, admission would
    /// undercount real residency by ~one base per session there.
    pub flat_copy_bytes: u64,
}

impl Admission {
    /// The session's marginal footprint on top of the shared base.
    pub fn marginal(&self) -> u64 {
        self.tape_bytes + self.grad_bytes + self.opt_bytes
            + self.trainable_bytes + self.flat_copy_bytes
    }
}

/// Predict one session's footprint on `art` under `cfg` — no step has
/// to run. The tape term is the paper's subject; grads/optimizer/
/// trainables scale with the tuning mode (tiny under LoRA).
pub fn predict(art: &Artifact, cfg: &TrainCfg) -> Admission {
    let m = &art.manifest;
    let analytic = MemCfg::from_manifest(m)
        .map(|c| total_bytes(&c))
        .unwrap_or(0);
    let tape_bytes = analytic.max(m.residual_bytes_total);
    let trainable_elems: u64 = m
        .params
        .iter()
        .filter(|p| p.trainable)
        .map(|p| p.shape.iter().product::<usize>() as u64)
        .sum();
    let trainable_bytes = trainable_elems * 4;
    let grad_bytes =
        trainable_bytes * if cfg.grad_accum > 1 { 2 } else { 1 };
    let opt_bytes = match cfg.optimizer.as_str() {
        "sgd" => trainable_bytes,
        _ => 2 * trainable_bytes, // AdamW m+v
    };
    // a backend without split support gets a per-session flat
    // fallback vector (see Session): meter that copy too
    let flat_copy_bytes = if art.supports_split() {
        0
    } else {
        art.frozen_base().nbytes() + trainable_bytes
    };
    Admission {
        tape_bytes,
        grad_bytes,
        opt_bytes,
        trainable_bytes,
        flat_copy_bytes,
    }
}

/// Final engine output for one session.
pub struct EngineReport {
    /// Session name (from `admit`).
    pub name: String,
    /// Preset the session trained.
    pub preset: String,
    /// What admission predicted.
    pub admission: Admission,
    /// The session's training report.
    pub report: TrainReport,
}

struct Slot<'a> {
    name: String,
    session: Session<'a>,
    admission: Admission,
    done: bool,
}

/// Multi-tenant engine: admits sessions against a byte budget and
/// interleaves their steps round-robin (see module docs).
pub struct Engine<'a> {
    budget: u64,
    /// Unique shared bases: (`Arc` pointer identity, frozen bytes).
    bases: Vec<(usize, u64)>,
    slots: Vec<Slot<'a>>,
    /// Fleet-wide measured accounting: `current_bytes` carries the
    /// resident set (bases + trainables + optimizer state), the peak
    /// adds every admitted session's measured tape+grad peak — the
    /// capacity-planning view where all tenants are mid-step at once
    /// (exactly what admission budgets for).
    pub fleet: MemoryTracker,
}

impl<'a> Engine<'a> {
    /// Engine with a byte budget (use [`Engine::unbounded`] for tests
    /// and benches that only want the scheduler).
    pub fn new(budget_bytes: u64) -> Engine<'a> {
        Engine {
            budget: budget_bytes,
            bases: Vec::new(),
            slots: Vec::new(),
            fleet: MemoryTracker::new(),
        }
    }

    /// Engine with an effectively infinite budget.
    pub fn unbounded() -> Engine<'a> {
        Engine::new(u64::MAX)
    }

    /// The configured byte budget.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Admitted session count.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether no session was admitted.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Predicted fleet footprint: every unique base once + each
    /// admitted session's marginal.
    pub fn predicted_bytes(&self) -> u64 {
        self.bases.iter().map(|(_, b)| b).sum::<u64>()
            + self
                .slots
                .iter()
                .map(|s| s.admission.marginal())
                .sum::<u64>()
    }

    /// *Actual* resident parameter bytes: each unique frozen base
    /// exactly once (it is `Arc`-shared storage, not an accounting
    /// convention) plus every session's private trainable tensors.
    /// Adding a session on an already-resident base grows this by only
    /// the trainable slice — the stored-once assertion of the tests.
    pub fn resident_param_bytes(&self) -> u64 {
        self.bases.iter().map(|(_, b)| b).sum::<u64>()
            + self
                .slots
                .iter()
                .map(|s| s.session.resident_param_bytes())
                .sum::<u64>()
    }

    /// Measured optimizer-state bytes across sessions.
    pub fn opt_state_bytes(&self) -> u64 {
        self.slots.iter().map(|s| s.session.opt_state_bytes()).sum()
    }

    /// Admit a session for `cfg` on `art`, or reject it when the
    /// predicted footprint would exceed the budget — the error carries
    /// the memmodel's predicted bytes. Admission constructs the
    /// session (which warms up once), so an `Ok` session is ready to
    /// step.
    pub fn admit(&mut self, name: &str, art: &'a Artifact,
                 cfg: TrainCfg) -> Result<usize> {
        let admission = predict(art, &cfg);
        let base = art.frozen_base();
        let key = Arc::as_ptr(&base) as usize;
        let base_new = !self.bases.iter().any(|(k, _)| *k == key);
        let base_cost = if base_new { base.nbytes() } else { 0 };
        let projected =
            self.predicted_bytes() + base_cost + admission.marginal();
        if projected > self.budget {
            bail!(
                "admission rejected for {name} ({}): predicted session \
                 footprint {} bytes (tape {} + grads {} + optimizer {} \
                 + trainable params {}{}){} would put the fleet at {} \
                 of budget {} bytes",
                art.manifest.preset,
                admission.marginal(),
                admission.tape_bytes,
                admission.grad_bytes,
                admission.opt_bytes,
                admission.trainable_bytes,
                if admission.flat_copy_bytes > 0 {
                    format!(" + flat fallback {}",
                            admission.flat_copy_bytes)
                } else {
                    String::new()
                },
                if base_new {
                    format!(" + shared base {base_cost}")
                } else {
                    String::new()
                },
                projected,
                self.budget
            );
        }
        let session = Session::new(art, cfg)?;
        if base_new {
            self.bases.push((key, base.nbytes()));
        }
        self.slots.push(Slot {
            name: name.to_string(),
            session,
            admission,
            done: false,
        });
        Ok(self.slots.len() - 1)
    }

    /// Direct access to an admitted session (tests: parameter and
    /// base-identity assertions).
    pub fn session(&self, id: usize) -> &Session<'a> {
        &self.slots[id].session
    }

    /// Advance every unfinished session by one optimizer step, in
    /// admission order. Returns how many sessions stepped (0 = all
    /// exhausted). Fleet accounting is refreshed after the sweep.
    pub fn round(&mut self) -> Result<usize> {
        let mut stepped = 0usize;
        for slot in &mut self.slots {
            if slot.done {
                continue;
            }
            match slot.session.step()? {
                StepOutcome::Stepped(_) => stepped += 1,
                StepOutcome::Exhausted => slot.done = true,
            }
        }
        // capacity-planning peak: resident set + every session's
        // measured tape/grad peak as if all tenants were mid-step
        self.fleet.current_bytes =
            self.resident_param_bytes() + self.opt_state_bytes();
        let tapes: u64 = self
            .slots
            .iter()
            .map(|s| s.session.memory.peak_bytes)
            .sum();
        self.fleet.observe_extra(tapes);
        Ok(stepped)
    }

    /// Round-robin every session to exhaustion, then finish each
    /// (held-out evaluation + report), in admission order.
    pub fn run(&mut self) -> Result<Vec<EngineReport>> {
        while self.round()? > 0 {}
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &mut self.slots {
            let report = slot.session.finish()?;
            out.push(EngineReport {
                name: slot.name.clone(),
                preset: slot.session.artifact().manifest.preset.clone(),
                admission: slot.admission.clone(),
                report,
            });
        }
        Ok(out)
    }
}

/// One row of the fleet-capacity report.
pub struct CapacityRow {
    /// Preset under consideration.
    pub preset: String,
    /// Shared-base bytes (resident once regardless of session count).
    pub base_bytes: u64,
    /// Predicted per-session marginal bytes.
    pub admission: Admission,
    /// Sessions-per-budget: how many sessions admission control fits.
    pub admitted: usize,
    /// Measured per-session tape bytes from a probe step (when run).
    pub measured_tape: Option<u64>,
}

/// The paper's Table-1 story restated as tenancy: for each preset,
/// predict the per-session marginal footprint, derive
/// sessions-per-budget, and (optionally) run a 1-step probe session to
/// cross-check the predicted tape against the measured residual bytes.
pub fn fleet_capacity(rt: &Runtime, budget_bytes: u64,
                      presets: &[String], cfg: &TrainCfg,
                      probe: bool) -> Result<Vec<CapacityRow>> {
    let mut out = Vec::with_capacity(presets.len());
    for preset in presets {
        let art = crate::runtime::load_or_synth(rt, preset)?;
        let admission = predict(&art, cfg);
        let base_bytes = art.frozen_base().nbytes();
        let admitted = if budget_bytes <= base_bytes {
            0
        } else {
            ((budget_bytes - base_bytes) / admission.marginal().max(1))
                as usize
        };
        let measured_tape = if probe {
            let mut probe_cfg = cfg.clone();
            probe_cfg.steps = 1;
            probe_cfg.log_every = 0;
            probe_cfg.eval_batches = 0;
            let mut s = Session::new(&art, probe_cfg)?;
            s.step()?;
            Some(s.memory.last_residual_bytes)
        } else {
            None
        };
        out.push(CapacityRow {
            preset: preset.clone(),
            base_bytes,
            admission,
            admitted,
            measured_tape,
        });
    }
    Ok(out)
}
