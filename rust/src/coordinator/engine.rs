//! The multi-tenant fine-tuning engine: memory-budgeted admission +
//! fair step interleaving over sessions that share frozen bases.
//!
//! The paper's observation — activation memory, not weights, is the
//! per-job scaling bottleneck — becomes *capacity* here: the frozen
//! base of an artifact is resident once (`Arc`-shared
//! [`FrozenBase`]), so the marginal footprint of one more session is
//! its activation tape + gradients + optimizer state + trainable
//! slice. Admission control meters exactly that, using the analytical
//! memmodel prediction ([`MemCfg::from_manifest`], `Mode::Tape`)
//! cross-checked against the schema-derived manifest total; scheduling
//! is round-robin at [`Session::step`] granularity over the shared
//! worker pool; the fleet-wide peak is tracked with the same
//! [`MemoryTracker`] the single-job path uses. [`fleet_capacity`]
//! restates the paper's Table-1 savings as sessions-per-budget:
//! `*_regelu2_msln` / `*_mesa` presets admit strictly more tenants
//! than their baselines under the same byte budget.
//!
//! With a spool directory and preemption enabled, an over-budget
//! admission no longer rejects outright: lower-priority unfinished
//! sessions are suspended to disk (durable statefiles, see
//! `statefile`) to make room, and [`Engine::round`] resumes them —
//! highest priority first — as budget frees up. Because a session's
//! state is bit-exactly portable (indexed data stream, raw optimizer
//! state), the preempted runs finish bit-identical to uninterrupted
//! ones.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::memory::MemoryTracker;
use crate::coordinator::session::{Session, StepCtx, StepOutcome};
use crate::coordinator::statefile::{self, SavedSession, SessionHandle};
use crate::coordinator::supervisor::{self, FaultKind, FaultRecord};
use crate::coordinator::trainer::{TrainCfg, TrainReport};
use crate::memmodel::{total_bytes, MemCfg};
use crate::runtime::{Artifact, BwdSplitJob, FwdSplitJob, Runtime,
                     Tensor};
use crate::util::faultpoint;

/// One job request: a preset plus its trainer hyper-parameters.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Preset name (artifact to load or synthesize).
    pub preset: String,
    /// Per-session hyper-parameters.
    pub cfg: TrainCfg,
    /// Scheduling priority (higher = more important; default 0). A
    /// preempting engine may suspend lower-priority sessions to admit
    /// this one.
    pub priority: i64,
}

impl JobSpec {
    /// Parse a `preset[:steps[:seed[:prio]]]` job token (the `--jobs`
    /// list grammar). Defaults come from `base`; when no seed is given,
    /// the job index is added to the base seed so identical presets
    /// stream distinct tenant data. Priority defaults to 0.
    pub fn parse(token: &str, base: &TrainCfg,
                 job_index: usize) -> Result<JobSpec> {
        let mut parts = token.split(':');
        let preset = parts
            .next()
            .filter(|p| !p.is_empty())
            .with_context(|| format!("empty job spec {token:?}"))?
            .to_string();
        let mut cfg = base.clone();
        cfg.seed = base.seed + job_index as u64;
        if let Some(s) = parts.next() {
            cfg.steps = s
                .parse()
                .with_context(|| format!("bad steps in job {token:?}"))?;
        }
        if let Some(s) = parts.next() {
            cfg.seed = s
                .parse()
                .with_context(|| format!("bad seed in job {token:?}"))?;
        }
        let mut priority = 0i64;
        if let Some(s) = parts.next() {
            priority = s.parse().with_context(|| {
                format!("bad priority in job {token:?}")
            })?;
        }
        if let Some(extra) = parts.next() {
            bail!("job {token:?}: unexpected field {extra:?} \
                   (grammar: preset[:steps[:seed[:prio]]])");
        }
        Ok(JobSpec { preset, cfg, priority })
    }
}

/// The memmodel-backed per-session footprint prediction admission
/// control gates on. All figures are bytes.
#[derive(Debug, Clone)]
pub struct Admission {
    /// Predicted activation tape held between fwd and bwd —
    /// `max(memmodel Tape-mode total, manifest residual total)`.
    pub tape_bytes: u64,
    /// Gradient sets held at the step peak: one, or two with
    /// `grad_accum > 1` (the running accumulator is live while the
    /// next microbatch's fresh gradients materialize).
    pub grad_bytes: u64,
    /// Optimizer state (AdamW m+v, SGD velocity).
    pub opt_bytes: u64,
    /// The session's private trainable parameter copy.
    pub trainable_bytes: u64,
    /// Extra full-parameter copy a session on a *non-forking* backend
    /// materializes as its flat-ABI fallback (0 on backends with split
    /// support, i.e. native): without this term, admission would
    /// undercount real residency by ~one base per session there.
    pub flat_copy_bytes: u64,
}

impl Admission {
    /// The session's marginal footprint on top of the shared base.
    pub fn marginal(&self) -> u64 {
        self.tape_bytes + self.grad_bytes + self.opt_bytes
            + self.trainable_bytes + self.flat_copy_bytes
    }
}

/// Predict one session's footprint on `art` under `cfg` — no step has
/// to run. The tape term is the paper's subject; grads/optimizer/
/// trainables scale with the tuning mode (tiny under LoRA).
pub fn predict(art: &Artifact, cfg: &TrainCfg) -> Admission {
    let m = &art.manifest;
    let analytic = MemCfg::from_manifest(m)
        .map(|c| total_bytes(&c))
        .unwrap_or(0);
    let tape_bytes = analytic.max(m.residual_bytes_total);
    let trainable_elems: u64 = m
        .params
        .iter()
        .filter(|p| p.trainable)
        .map(|p| p.shape.iter().product::<usize>() as u64)
        .sum();
    let trainable_bytes = trainable_elems * 4;
    let grad_bytes =
        trainable_bytes * if cfg.grad_accum > 1 { 2 } else { 1 };
    let opt_bytes = match cfg.optimizer.as_str() {
        "sgd" => trainable_bytes,
        _ => 2 * trainable_bytes, // AdamW m+v
    };
    // a backend without split support gets a per-session flat
    // fallback vector (see Session): meter that copy too
    let flat_copy_bytes = if art.supports_split() {
        0
    } else {
        art.frozen_base().nbytes() + trainable_bytes
    };
    Admission {
        tape_bytes,
        grad_bytes,
        opt_bytes,
        trainable_bytes,
        flat_copy_bytes,
    }
}

/// How one admitted session ended.
pub enum SessionOutcome {
    /// The session ran its full step budget; here is its report.
    Completed(TrainReport),
    /// The supervisor isolated a fault: the session was removed from
    /// the fleet (its last good state spooled to
    /// `<name>.state.quarantine` when a spool directory exists) and
    /// every other tenant kept running.
    Quarantined(FaultRecord),
}

/// Final engine output for one session.
pub struct EngineReport {
    /// Session name (from `admit`).
    pub name: String,
    /// Preset the session trained.
    pub preset: String,
    /// What admission predicted (`None` only for sessions that never
    /// reached admission, e.g. a spool file quarantined at scan time).
    pub admission: Option<Admission>,
    /// How the session ended.
    pub outcome: SessionOutcome,
}

impl EngineReport {
    /// The training report, when the session completed.
    pub fn train(&self) -> Option<&TrainReport> {
        match &self.outcome {
            SessionOutcome::Completed(r) => Some(r),
            SessionOutcome::Quarantined(_) => None,
        }
    }

    /// The fault record, when the session was quarantined.
    pub fn fault(&self) -> Option<&FaultRecord> {
        match &self.outcome {
            SessionOutcome::Completed(_) => None,
            SessionOutcome::Quarantined(rec) => Some(rec),
        }
    }
}

/// What one session did during a [`Engine::round_with`] sweep — the
/// front line's observability feed (per-session step-latency
/// percentiles, completion detection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepEventKind {
    /// The session completed one optimizer step.
    Stepped,
    /// The session's step budget ran out this sweep (no step ran).
    Finished,
    /// The supervisor quarantined the session this sweep.
    Quarantined,
}

/// Fused-execution observability: how many physical microbatch sweeps
/// (one fwd+bwd pass through the layer stack) ran fused vs serial, and
/// the gang occupancy of each fused sweep. One fused pass serving N
/// sessions replaces N serial passes, so
/// `Σ occupancy·count + serial_passes` equals the total
/// session-microbatches executed.
#[derive(Debug, Clone, Default)]
pub struct FusionStats {
    /// Physical fwd+bwd sweeps that served a whole gang at once.
    pub fused_passes: u64,
    /// Physical fwd+bwd sweeps that served a single session.
    pub serial_passes: u64,
    /// Fused-pass count keyed by gang occupancy (sessions per pass).
    pub occupancy: BTreeMap<usize, u64>,
}

/// One per-session event from a [`Engine::round_with`] sweep.
///
/// Ordering contract (pinned by `tests/engine.rs`): events within one
/// sweep are emitted in **admission order** under serial scheduling;
/// under fusion ([`Engine::set_fuse`]) they are emitted gang-by-gang,
/// where gangs form in admission order of their first member and
/// members within a gang stay in admission order — so the event stream
/// is a pure function of the admitted fleet, never of wall-clock, and
/// `FleetMetrics` built from it are deterministic in virtual time.
#[derive(Debug, Clone)]
pub struct StepEvent {
    /// Session name.
    pub name: String,
    /// Steps the session has completed after this event.
    pub step: usize,
    /// Wall-clock seconds the step took (0 for non-`Stepped` events).
    /// Latency is measurement, not state: it is *not* part of the
    /// determinism contract.
    pub dur_s: f64,
    /// What happened.
    pub kind: StepEventKind,
}

struct Slot<'a> {
    name: String,
    session: Session<'a>,
    admission: Admission,
    priority: i64,
    done: bool,
    /// Consecutive supervised-step I/O retries since the last good
    /// step (reset on success; bounded by `Engine::max_retries`).
    retries: u32,
}

/// A session evicted to disk: the durable handle plus the resident
/// artifact it resumes against and the admission prediction used for
/// the fits-now check (recomputing it would need the on-disk cfg).
struct Suspended<'a> {
    handle: SessionHandle,
    art: &'a Artifact,
    admission: Admission,
}

/// Multi-tenant engine: admits sessions against a byte budget and
/// interleaves their steps round-robin (see module docs).
pub struct Engine<'a> {
    budget: u64,
    /// Unique shared bases: (`Arc` pointer identity, frozen bytes).
    bases: Vec<(usize, u64)>,
    slots: Vec<Slot<'a>>,
    /// Where suspended sessions spool to (`None` = suspension off).
    spool: Option<PathBuf>,
    /// Whether over-budget admission may evict lower-priority sessions.
    preempt: bool,
    /// Sessions currently evicted to the spool.
    suspended: Vec<Suspended<'a>>,
    /// Fail-fast mode: any session fault aborts the whole fleet run
    /// (the pre-supervision behavior). Off by default — the supervisor
    /// isolates faults per tenant instead.
    strict: bool,
    /// Bound on consecutive transient-I/O retries per session before
    /// the fault is treated as terminal and the session quarantined.
    max_retries: u32,
    /// Cross-tenant fusion: gang compatible sessions per sweep and run
    /// each gang through one physical pass per microbatch (off by
    /// default; supervised mode only).
    fuse: bool,
    /// Fused-vs-serial pass counters (see [`FusionStats`]).
    fstats: FusionStats,
    /// Sessions the supervisor removed from the fleet this run, with
    /// the admission they held (if any); drained into
    /// [`EngineReport`]s by [`Engine::run`].
    quarantined: Vec<(Option<Admission>, FaultRecord)>,
    /// Fleet-wide measured accounting: `current_bytes` carries the
    /// resident set (bases + trainables + optimizer state), the peak
    /// adds every admitted session's measured tape+grad peak — the
    /// capacity-planning view where all tenants are mid-step at once
    /// (exactly what admission budgets for).
    pub fleet: MemoryTracker,
}

impl<'a> Engine<'a> {
    /// Engine with a byte budget (use [`Engine::unbounded`] for tests
    /// and benches that only want the scheduler).
    pub fn new(budget_bytes: u64) -> Engine<'a> {
        Engine {
            budget: budget_bytes,
            bases: Vec::new(),
            slots: Vec::new(),
            spool: None,
            preempt: false,
            suspended: Vec::new(),
            strict: false,
            max_retries: 2,
            fuse: false,
            fstats: FusionStats::default(),
            quarantined: Vec::new(),
            fleet: MemoryTracker::new(),
        }
    }

    /// Enable cross-tenant fused execution: each
    /// [`Engine::round_with`] sweep gangs unfinished sessions by
    /// fusion key — frozen-base identity (`Arc` pointer, which implies
    /// artifact, preset, and batch/seq shape) plus `grad_accum` phase —
    /// and runs each gang through the executor's `_many` entry points,
    /// one physical pass per microbatch. Per-session results are
    /// bit-identical to serial scheduling (DESIGN.md §3.5); a faulting
    /// member is peeled out and retried/quarantined alone while the
    /// survivors keep fusing. Ignored under [`Engine::set_strict`]
    /// (strict mode keeps the historical serial fail-fast sweep).
    pub fn set_fuse(&mut self, fuse: bool) {
        self.fuse = fuse;
    }

    /// Fused-vs-serial pass counters accumulated so far.
    pub fn fusion_stats(&self) -> &FusionStats {
        &self.fstats
    }

    /// Fail-fast mode: propagate the first session fault out of
    /// [`Engine::round`] instead of isolating it (the `--strict`
    /// behavior). Off by default.
    pub fn set_strict(&mut self, strict: bool) {
        self.strict = strict;
    }

    /// Bound on consecutive transient-I/O retries per session before
    /// the supervisor quarantines it (default 2).
    pub fn set_max_retries(&mut self, max_retries: u32) {
        self.max_retries = max_retries;
    }

    /// Set the directory suspended sessions spool to. Required before
    /// [`Engine::suspend`] / [`Engine::enable_preempt`].
    pub fn set_spool(&mut self, dir: PathBuf) {
        self.spool = Some(dir);
    }

    /// Allow over-budget admissions to evict lower-priority sessions
    /// to the spool instead of rejecting. Requires a spool directory.
    pub fn enable_preempt(&mut self) -> Result<()> {
        ensure!(self.spool.is_some(),
                "preemption requires a spool directory (set_spool)");
        self.preempt = true;
        Ok(())
    }

    /// Engine with an effectively infinite budget.
    pub fn unbounded() -> Engine<'a> {
        Engine::new(u64::MAX)
    }

    /// The configured byte budget.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Admitted session count.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether no session was admitted.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// What one resident slot is predicted to cost right now: the full
    /// marginal while it can still step; once done, only its residency
    /// (optimizer state + trainables + flat fallback) — a finished
    /// session holds no tape and materializes no fresh gradients, so
    /// its budget share shrinks and preempted work can come back.
    fn slot_cost(slot: &Slot<'a>) -> u64 {
        if slot.done {
            slot.admission.opt_bytes + slot.admission.trainable_bytes
                + slot.admission.flat_copy_bytes
        } else {
            slot.admission.marginal()
        }
    }

    /// Predicted fleet footprint: every unique base once + each
    /// resident session's [`Engine::slot_cost`].
    pub fn predicted_bytes(&self) -> u64 {
        self.bases.iter().map(|(_, b)| b).sum::<u64>()
            + self.slots.iter().map(Engine::slot_cost).sum::<u64>()
    }

    /// Total frozen-base bytes resident (each unique base once).
    pub fn base_bytes(&self) -> u64 {
        self.bases.iter().map(|(_, b)| b).sum()
    }

    /// *Actual* resident parameter bytes: each unique frozen base
    /// exactly once (it is `Arc`-shared storage, not an accounting
    /// convention) plus every session's private trainable tensors.
    /// Adding a session on an already-resident base grows this by only
    /// the trainable slice — the stored-once assertion of the tests.
    pub fn resident_param_bytes(&self) -> u64 {
        self.bases.iter().map(|(_, b)| b).sum::<u64>()
            + self
                .slots
                .iter()
                .map(|s| s.session.resident_param_bytes())
                .sum::<u64>()
    }

    /// Measured optimizer-state bytes across sessions.
    pub fn opt_state_bytes(&self) -> u64 {
        self.slots.iter().map(|s| s.session.opt_state_bytes()).sum()
    }

    /// Admit a session for `cfg` on `art` at priority 0, or reject it
    /// when the predicted footprint would exceed the budget — the
    /// error carries the memmodel's predicted bytes. Admission
    /// constructs the session (which warms up once), so an `Ok`
    /// session is ready to step. Sessions are addressed by `name` from
    /// here on ([`Engine::session`], [`Engine::suspend`]) — slot
    /// positions are an internal detail.
    pub fn admit(&mut self, name: &str, art: &'a Artifact,
                 cfg: TrainCfg) -> Result<()> {
        self.admit_prio(name, art, cfg, 0)
    }

    /// [`Engine::admit`] with an explicit priority. Under
    /// [`Engine::enable_preempt`], an over-budget admission first
    /// suspends enough strictly-lower-priority unfinished sessions
    /// (lowest priority first, FIFO within a priority) to fit the new
    /// job; when even evicting all eligible victims would not fit, no
    /// one is evicted and the job is rejected with the usual detailed
    /// error.
    pub fn admit_prio(&mut self, name: &str, art: &'a Artifact,
                      cfg: TrainCfg, priority: i64) -> Result<()> {
        ensure!(
            self.find(name).is_none()
                && !self.suspended.iter().any(|s| s.handle.name == name),
            "admission rejected for {name}: a session with that name \
             is already resident or suspended"
        );
        let admission = predict(art, &cfg);
        let base = art.frozen_base();
        let key = Arc::as_ptr(&base) as usize;
        let base_new = !self.bases.iter().any(|(k, _)| *k == key);
        let base_cost = if base_new { base.nbytes() } else { 0 };
        let needed = base_cost + admission.marginal();
        if self.preempt && self.predicted_bytes() + needed > self.budget
        {
            // victims: unfinished, strictly lower priority; evict the
            // least important first (ascending priority, then FIFO)
            let mut victims: Vec<usize> = (0..self.slots.len())
                .filter(|&i| {
                    !self.slots[i].done
                        && self.slots[i].priority < priority
                })
                .collect();
            victims.sort_by_key(|&i| (self.slots[i].priority, i));
            let reclaim: u64 = victims
                .iter()
                .map(|&i| Engine::slot_cost(&self.slots[i]))
                .sum();
            // all-or-nothing feasibility: never evict anyone for a job
            // that still would not fit
            if self.predicted_bytes() + needed <= self.budget + reclaim {
                let names: Vec<String> = victims
                    .iter()
                    .map(|&i| self.slots[i].name.clone())
                    .collect();
                for victim in names {
                    if self.predicted_bytes() + needed <= self.budget {
                        break;
                    }
                    // a victim may have vanished (e.g. quarantined by
                    // the supervisor between selection and eviction):
                    // degrade to the ordinary rejected-admission path
                    // instead of panicking
                    let Some(id) = self.find(&victim) else { break };
                    match self.suspend_idx(id) {
                        Ok(_) => {}
                        Err(e) if self.strict => return Err(e),
                        // eviction failed (e.g. spool I/O): the victim
                        // was restored in place, so stop evicting and
                        // let the fit check below reject the admission
                        Err(_) => break,
                    }
                }
            }
        }
        let projected = self.predicted_bytes() + needed;
        if projected > self.budget {
            bail!(
                "admission rejected for {name} ({}): predicted session \
                 footprint {} bytes (tape {} + grads {} + optimizer {} \
                 + trainable params {}{}){} would put the fleet at {} \
                 of budget {} bytes",
                art.manifest.preset,
                admission.marginal(),
                admission.tape_bytes,
                admission.grad_bytes,
                admission.opt_bytes,
                admission.trainable_bytes,
                if admission.flat_copy_bytes > 0 {
                    format!(" + flat fallback {}",
                            admission.flat_copy_bytes)
                } else {
                    String::new()
                },
                if base_new {
                    format!(" + shared base {base_cost}")
                } else {
                    String::new()
                },
                projected,
                self.budget
            );
        }
        let session = Session::new(art, cfg)?;
        if base_new {
            self.bases.push((key, base.nbytes()));
        }
        self.slots.push(Slot {
            name: name.to_string(),
            session,
            admission,
            priority,
            done: false,
            retries: 0,
        });
        Ok(())
    }

    /// What admitting a session for `cfg` on `art` would add to the
    /// predicted fleet footprint *right now*: the memmodel marginal
    /// plus the frozen base — the latter only when no resident session
    /// already shares it. This is the number scheduling policies
    /// fit-check against the budget before committing any bytes.
    pub fn admission_cost(&self, art: &'a Artifact,
                          cfg: &TrainCfg) -> u64 {
        self.base_cost_for(art) + predict(art, cfg).marginal()
    }

    /// Direct access to a resident session by name (tests: parameter
    /// and base-identity assertions). `None` when no resident session
    /// carries that name (it may be suspended, quarantined, or done
    /// and retired).
    pub fn session(&self, name: &str) -> Option<&Session<'a>> {
        self.find(name).map(|id| &self.slots[id].session)
    }

    /// Whether a resident session carries this name (suspended
    /// sessions are listed by [`Engine::suspended_names`] instead).
    pub fn contains(&self, name: &str) -> bool {
        self.find(name).is_some()
    }

    /// Slot index of a resident session by name. Internal only: slot
    /// indices shift whenever a session is suspended, quarantined, or
    /// retired, so the public API deals exclusively in stable names.
    fn find(&self, name: &str) -> Option<usize> {
        self.slots.iter().position(|s| s.name == name)
    }

    /// Names of the sessions currently evicted to the spool.
    pub fn suspended_names(&self) -> Vec<String> {
        self.suspended
            .iter()
            .map(|s| s.handle.name.clone())
            .collect()
    }

    /// Whether any session — resident or suspended — still has steps
    /// left.
    pub fn has_unfinished(&self) -> bool {
        !self.suspended.is_empty()
            || self.slots.iter().any(|s| !s.done)
    }

    /// Evict a resident unfinished session (addressed by its stable
    /// name) to the spool: its portable state (trainables, raw
    /// optimizer state, step counter, metrics rows, memory accounting)
    /// is written to `<spool>/<name>.state` and the slot is dropped —
    /// freeing its tape/grad/optimizer/trainable budget share while
    /// the `Arc`-shared frozen base stays resident with the artifact
    /// (stored-once across suspend/resume). Returns the durable
    /// handle.
    pub fn suspend(&mut self, name: &str) -> Result<SessionHandle> {
        let id = self.find(name).with_context(|| {
            format!("no resident session named {name:?}")
        })?;
        self.suspend_idx(id)
    }

    /// [`Engine::suspend`] by slot index — the internal spelling every
    /// eviction path funnels through (indices are only stable within
    /// one call, which is why the public API takes a name).
    fn suspend_idx(&mut self, id: usize) -> Result<SessionHandle> {
        let spool = self
            .spool
            .clone()
            .context("suspend requires a spool directory (set_spool)")?;
        ensure!(id < self.slots.len(), "no session slot {id}");
        ensure!(
            !self.slots[id].done,
            "refusing to suspend finished session {:?} — its report is \
             pending, not its steps",
            self.slots[id].name
        );
        let slot = self.slots.remove(id);
        let Slot { name, session, admission, priority, done, retries } =
            slot;
        let art = session.artifact();
        let state = session.into_state();
        let path = spool.join(format!("{name}.state"));
        let saved = if self.strict {
            statefile::save_session(&path, &name, priority, &state)
        } else {
            supervisor::with_io_retry(self.max_retries + 1, || {
                supervisor::catch_fault(|| {
                    statefile::save_session(&path, &name, priority,
                                            &state)
                })
            })
        };
        match saved {
            Ok(handle) => {
                let out = handle.clone();
                self.suspended.push(Suspended {
                    handle,
                    art,
                    admission,
                });
                Ok(out)
            }
            Err(e) => {
                // spooling failed: rebuild the live session from the
                // state we just took so no work is lost — the slot
                // returns to its old position and the caller decides
                // what to do with the error
                match supervisor::catch_fault(|| {
                    Session::resume(art, state)
                }) {
                    Ok(session) => {
                        self.slots.insert(id, Slot {
                            name: name.clone(),
                            session,
                            admission,
                            priority,
                            done,
                            retries,
                        });
                        Err(e.context(format!(
                            "suspending {name} failed; session \
                             restored in place"
                        )))
                    }
                    Err(re) => Err(e.context(format!(
                        "suspending {name} failed AND restoring the \
                         live session failed ({re:#}); session lost"
                    ))),
                }
            }
        }
    }

    /// Suspend every unfinished resident session (checkpoint-on-halt:
    /// the warm-restart path rebuilds the fleet from these files).
    /// Returns the handles, in eviction order.
    pub fn suspend_all(&mut self) -> Result<Vec<SessionHandle>> {
        let mut out = Vec::new();
        while let Some(id) = self.slots.iter().position(|s| !s.done) {
            out.push(self.suspend_idx(id)?);
        }
        Ok(out)
    }

    /// Re-admit a loaded session state against its (resident)
    /// artifact: fit-check like [`Engine::admit`], rebuild the live
    /// session bit-exactly via [`Session::resume`], and — only on
    /// success — delete `origin` (the statefile it was loaded from).
    pub fn resume_saved(&mut self, saved: SavedSession,
                        art: &'a Artifact,
                        origin: Option<&Path>) -> Result<()> {
        let SavedSession { name, priority, state } = saved;
        let admission = predict(art, &state.cfg);
        let base = art.frozen_base();
        let key = Arc::as_ptr(&base) as usize;
        let base_new = !self.bases.iter().any(|(k, _)| *k == key);
        let base_cost = if base_new { base.nbytes() } else { 0 };
        let projected =
            self.predicted_bytes() + base_cost + admission.marginal();
        ensure!(
            projected <= self.budget,
            "resume rejected for {name}: predicted footprint {} bytes \
             would put the fleet at {projected} of budget {} bytes",
            admission.marginal(),
            self.budget
        );
        let session = Session::resume(art, state)?;
        if base_new {
            self.bases.push((key, base.nbytes()));
        }
        let done = session.is_done();
        self.slots.push(Slot {
            name,
            session,
            admission,
            priority,
            done,
            retries: 0,
        });
        if let Some(p) = origin {
            std::fs::remove_file(p).with_context(|| {
                format!("removing resumed statefile {p:?}")
            })?;
        }
        Ok(())
    }

    /// [`Engine::resume_saved`] straight from a statefile on disk.
    pub fn resume_file(&mut self, art: &'a Artifact,
                       path: &Path) -> Result<()> {
        let saved = statefile::load_session(path)?;
        self.resume_saved(saved, art, Some(path))
    }

    /// Warm-restart path: register an on-disk session statefile —
    /// resume it right away when it fits the budget (the file is then
    /// deleted), otherwise queue it as suspended so [`Engine::round`]
    /// brings it back once budget frees up. Returns whether it
    /// resumed immediately.
    pub fn spool_in(&mut self, art: &'a Artifact,
                    path: &Path) -> Result<bool> {
        let saved = statefile::load_session(path)?;
        let admission = predict(art, &saved.state.cfg);
        if self.predicted_bytes()
            + self.base_cost_for(art)
            + admission.marginal()
            <= self.budget
        {
            self.resume_saved(saved, art, Some(path))?;
            Ok(true)
        } else {
            let handle = statefile::peek_session(path)?;
            self.suspended.push(Suspended { handle, art, admission });
            Ok(false)
        }
    }

    /// Bytes admitting a session on `art` would add for its frozen
    /// base: 0 when that base is already resident.
    fn base_cost_for(&self, art: &'a Artifact) -> u64 {
        let base = art.frozen_base();
        let key = Arc::as_ptr(&base) as usize;
        if self.bases.iter().any(|(k, _)| *k == key) {
            0
        } else {
            base.nbytes()
        }
    }

    /// Bring back as many suspended sessions as now fit the budget —
    /// highest priority first, FIFO within a priority. Returns how
    /// many resumed.
    fn try_resume_suspended(&mut self) -> Result<usize> {
        let mut resumed = 0usize;
        loop {
            let mut order: Vec<usize> =
                (0..self.suspended.len()).collect();
            // stable sort: FIFO among equal priorities
            order.sort_by_key(|&i| {
                std::cmp::Reverse(self.suspended[i].handle.priority)
            });
            let picked = order.into_iter().find(|&i| {
                let s = &self.suspended[i];
                self.predicted_bytes()
                    + self.base_cost_for(s.art)
                    + s.admission.marginal()
                    <= self.budget
            });
            let Some(i) = picked else { break };
            let s = self.suspended.remove(i);
            let saved = statefile::load_session(&s.handle.path)?;
            self.resume_saved(saved, s.art, Some(&s.handle.path))?;
            resumed += 1;
        }
        Ok(resumed)
    }

    /// [`Engine::try_resume_suspended`] under supervision: a statefile
    /// that refuses to load (after bounded I/O retries) is quarantined
    /// — renamed to `<name>.state.quarantine` with a report beside it —
    /// instead of failing the round, and the scan moves on. Resolving a
    /// blocking entry either way counts as progress, so the deadlock
    /// detector never trips on a file the supervisor just retired.
    fn try_resume_suspended_supervised(&mut self) -> usize {
        let mut resumed = 0usize;
        loop {
            let mut order: Vec<usize> =
                (0..self.suspended.len()).collect();
            order.sort_by_key(|&i| {
                std::cmp::Reverse(self.suspended[i].handle.priority)
            });
            let picked = order.into_iter().find(|&i| {
                let s = &self.suspended[i];
                self.predicted_bytes()
                    + self.base_cost_for(s.art)
                    + s.admission.marginal()
                    <= self.budget
            });
            let Some(i) = picked else { break };
            let s = self.suspended.remove(i);
            let attempt =
                supervisor::with_io_retry(self.max_retries + 1, || {
                    supervisor::catch_fault(|| {
                        statefile::load_session(&s.handle.path)
                    })
                })
                .and_then(|saved| {
                    supervisor::catch_fault(|| {
                        self.resume_saved(saved, s.art,
                                          Some(&s.handle.path))
                    })
                });
            match attempt {
                Ok(_) => resumed += 1,
                Err(e) => {
                    let kind = supervisor::classify(&e);
                    let mut rec = FaultRecord {
                        name: s.handle.name.clone(),
                        preset: s.handle.preset.clone(),
                        kind,
                        step: s.handle.steps_done,
                        retries: if kind == FaultKind::Io {
                            self.max_retries
                        } else {
                            0
                        },
                        detail: format!("{e:?}"),
                        state_path: None,
                        report_path: None,
                    };
                    if s.handle.path.exists() {
                        if let Err(e2) = supervisor::quarantine_file(
                            &s.handle.path,
                            &mut rec,
                        ) {
                            rec.detail.push_str(&format!(
                                "; quarantine failed: {e2:?}"
                            ));
                        }
                    }
                    self.quarantined.push((Some(s.admission), rec));
                    // the blocking entry is resolved — that is
                    // progress for the deadlock detector
                    resumed += 1;
                }
            }
        }
        resumed
    }

    /// Remove slot `idx` from the fleet as a quarantined tenant: its
    /// last good state is spooled to `<name>.state.quarantine` (when a
    /// spool directory is set) with a diagnostic report beside it, and
    /// the record is queued for [`Engine::run`]'s output. Infallible —
    /// quarantine is the error path's terminal state, so secondary
    /// failures (e.g. the quarantine write itself faulting) are folded
    /// into the record's detail instead of propagating.
    fn quarantine_slot(&mut self, idx: usize, kind: FaultKind,
                       detail: String) {
        let slot = self.slots.remove(idx);
        let Slot { name, session, admission, priority, retries, .. } =
            slot;
        let mut rec = FaultRecord {
            name: name.clone(),
            preset: session.artifact().manifest.preset.clone(),
            kind,
            step: session.steps_done(),
            retries,
            detail,
            state_path: None,
            report_path: None,
        };
        if let Some(spool) = self.spool.clone() {
            let qpath = supervisor::quarantine_state_path(&spool, &name);
            let state = session.into_state();
            let saved =
                supervisor::with_io_retry(self.max_retries + 1, || {
                    supervisor::catch_fault(|| {
                        statefile::save_session(&qpath, &name, priority,
                                                &state)
                    })
                });
            match saved {
                Ok(_) => rec.state_path = Some(qpath),
                Err(e) => rec.detail.push_str(&format!(
                    "; quarantine state write failed: {e:?}"
                )),
            }
            match supervisor::write_report(&spool, &rec) {
                Ok(p) => rec.report_path = Some(p),
                Err(e) => rec.detail.push_str(&format!(
                    "; quarantine report write failed: {e:?}"
                )),
            }
        }
        self.quarantined.push((Some(admission), rec));
    }

    /// The classic sweep: every unfinished resident session steps
    /// alone, in admission order.
    fn sweep_serial(&mut self,
                    events: &mut Vec<StepEvent>) -> Result<usize> {
        if self.strict {
            let mut stepped = 0usize;
            for i in 0..self.slots.len() {
                if self.slots[i].done {
                    continue;
                }
                let name = self.slots[i].name.clone();
                let t0 = std::time::Instant::now();
                match self.slots[i].session.step()? {
                    StepOutcome::Stepped(_) => {
                        stepped += 1;
                        self.fstats.serial_passes +=
                            self.slots[i].session.grad_accum() as u64;
                        events.push(StepEvent {
                            name,
                            step: self.slots[i].session.steps_done(),
                            dur_s: t0.elapsed().as_secs_f64(),
                            kind: StepEventKind::Stepped,
                        });
                    }
                    StepOutcome::Exhausted => {
                        self.slots[i].done = true;
                        events.push(StepEvent {
                            name,
                            step: self.slots[i].session.steps_done(),
                            dur_s: 0.0,
                            kind: StepEventKind::Finished,
                        });
                    }
                }
            }
            return Ok(stepped);
        }
        // supervised: walk the admission-order name list — quarantine
        // removes slots mid-sweep, so names are the stable handle
        let names: Vec<String> =
            self.slots.iter().map(|s| s.name.clone()).collect();
        let mut stepped = 0usize;
        for name in names {
            stepped += self.step_serial_one(&name, events);
        }
        Ok(stepped)
    }

    /// One supervised single-session step, addressed by name (0 or 1
    /// units of progress). No-op when the session is done or no longer
    /// resident. This is both the supervised serial sweep body and the
    /// singleton-gang path of the fused sweep.
    fn step_serial_one(&mut self, name: &str,
                       events: &mut Vec<StepEvent>) -> usize {
        let Some(i) = self.find(name) else { return 0 };
        if self.slots[i].done {
            return 0;
        }
        let t0 = std::time::Instant::now();
        let r = supervisor::supervised_step(
            name,
            &mut self.slots[i].session,
        );
        match r {
            Ok(StepOutcome::Stepped(_)) => {
                self.slots[i].retries = 0;
                self.fstats.serial_passes +=
                    self.slots[i].session.grad_accum() as u64;
                events.push(StepEvent {
                    name: name.to_string(),
                    step: self.slots[i].session.steps_done(),
                    dur_s: t0.elapsed().as_secs_f64(),
                    kind: StepEventKind::Stepped,
                });
                1
            }
            Ok(StepOutcome::Exhausted) => {
                self.slots[i].done = true;
                events.push(StepEvent {
                    name: name.to_string(),
                    step: self.slots[i].session.steps_done(),
                    dur_s: 0.0,
                    kind: StepEventKind::Finished,
                });
                0
            }
            Err(e) => {
                let mut stepped = 0usize;
                self.peel_member(name, None, e, events, &mut stepped);
                stepped
            }
        }
    }

    /// Handle one faulted tenant mid-sweep, by name: abort its
    /// in-flight step context (when the fused path holds one), then
    /// apply the supervised policy — transient I/O faults rebuild the
    /// session bit-exactly from its last good (pre-step) state, up to
    /// `max_retries` consecutive times (the failed attempt may have
    /// consumed prefetched batches; resume replays the data stream
    /// from the committed step counter); everything else quarantines
    /// the tenant. A scheduled retry counts as progress so `run()`
    /// comes back for the re-attempt.
    fn peel_member(&mut self, name: &str, ctx: Option<StepCtx>,
                   e: anyhow::Error, events: &mut Vec<StepEvent>,
                   stepped: &mut usize) {
        let Some(i) = self.find(name) else { return };
        if let Some(ctx) = ctx {
            self.slots[i].session.abort_step(ctx);
        }
        let kind = supervisor::classify(&e);
        let step_now = self.slots[i].session.steps_done();
        if kind == FaultKind::Io
            && self.slots[i].retries < self.max_retries
        {
            self.slots[i].retries += 1;
            supervisor::backoff(self.slots[i].retries);
            let art = self.slots[i].session.artifact();
            let snap = self.slots[i].session.snapshot();
            let rebuilt = supervisor::catch_fault(|| {
                Session::resume(art, snap)
            });
            match rebuilt {
                Ok(fresh) => {
                    self.slots[i].session = fresh;
                    *stepped += 1;
                }
                Err(re) => {
                    self.quarantine_slot(
                        i,
                        kind,
                        format!("{e:?}; retry rebuild failed: {re:?}"),
                    );
                    events.push(StepEvent {
                        name: name.to_string(),
                        step: step_now,
                        dur_s: 0.0,
                        kind: StepEventKind::Quarantined,
                    });
                }
            }
        } else {
            self.quarantine_slot(i, kind, format!("{e:?}"));
            events.push(StepEvent {
                name: name.to_string(),
                step: step_now,
                dur_s: 0.0,
                kind: StepEventKind::Quarantined,
            });
        }
    }

    /// The fused sweep: group unfinished sessions into gangs by fusion
    /// key and run each gang's optimizer step through one physical
    /// pass per microbatch. Gangs form in admission order (see
    /// [`StepEvent`] for the pinned event-ordering contract).
    fn sweep_fused(&mut self,
                   events: &mut Vec<StepEvent>) -> Result<usize> {
        // Fusion key: frozen-base Arc identity (which implies
        // artifact, preset, manifest shapes) + grad_accum phase.
        // Unfusable sessions (flat-ABI fallback) get singleton gangs.
        let mut gangs: Vec<(Option<(usize, usize)>, Vec<String>)> =
            Vec::new();
        for slot in &self.slots {
            if slot.done {
                continue;
            }
            let key = if slot.session.fusable() {
                Some((Arc::as_ptr(slot.session.base()) as usize,
                      slot.session.grad_accum()))
            } else {
                None
            };
            match key {
                Some(k) => {
                    if let Some((_, members)) = gangs
                        .iter_mut()
                        .find(|(gk, _)| *gk == Some(k))
                    {
                        members.push(slot.name.clone());
                    } else {
                        gangs.push((Some(k), vec![slot.name.clone()]));
                    }
                }
                None => gangs.push((None, vec![slot.name.clone()])),
            }
        }
        let mut stepped = 0usize;
        for (_, members) in gangs {
            if members.len() == 1 {
                stepped += self.step_serial_one(&members[0], events);
            } else {
                stepped += self.step_gang(&members, events)?;
            }
        }
        Ok(stepped)
    }

    /// One fused optimizer step for a gang of ≥ 2 compatible sessions:
    /// every microbatch runs fwd and bwd through the artifact's `_many`
    /// entry points — one packed sweep of the shared frozen panels
    /// serves every member — while all per-member bookkeeping (batch
    /// draw, loss/grad absorption, optimizer update) runs in the
    /// member's own fault scope, in admission order. A faulting member
    /// is peeled out ([`Engine::peel_member`]) and the survivors keep
    /// fusing; an error from the `_many` call itself is infrastructure
    /// (it cannot be attributed to one member) and fails the round.
    fn step_gang(&mut self, members: &[String],
                 events: &mut Vec<StepEvent>) -> Result<usize> {
        let Some(i0) = self.find(&members[0]) else { return Ok(0) };
        // same fusion key ⇒ same frozen-base Arc ⇒ same artifact
        let art = self.slots[i0].session.artifact();
        let base = art.frozen_base();
        let grad_accum = self.slots[i0].session.grad_accum();
        let t0 = std::time::Instant::now();
        let mut stepped = 0usize;
        // open every member's step; budget-exhausted members finish
        let mut live: Vec<(String, StepCtx)> = Vec::new();
        for name in members {
            let Some(i) = self.find(name) else { continue };
            match self.slots[i].session.begin_step(true) {
                Some(ctx) => live.push((name.clone(), ctx)),
                None => {
                    self.slots[i].done = true;
                    events.push(StepEvent {
                        name: name.clone(),
                        step: self.slots[i].session.steps_done(),
                        dur_s: 0.0,
                        kind: StepEventKind::Finished,
                    });
                }
            }
        }
        for _micro in 0..grad_accum {
            if live.is_empty() {
                break;
            }
            // phase 1: each member draws its microbatch (own scope)
            let mut armed: Vec<(String, StepCtx, Tensor, Tensor)> =
                Vec::with_capacity(live.len());
            for (name, ctx) in live.drain(..) {
                let i = self
                    .find(&name)
                    .expect("gang member vanished mid-pass");
                let r = supervisor::catch_fault(|| {
                    faultpoint::with_scope(&name, || {
                        self.slots[i].session.next_micro()
                    })
                });
                match r {
                    Ok((x, y)) => armed.push((name, ctx, x, y)),
                    Err(e) => self.peel_member(&name, Some(ctx), e,
                                               events, &mut stepped),
                }
            }
            if armed.is_empty() {
                break;
            }
            // phase 2: ONE physical forward pass for the whole gang
            let jobs: Vec<FwdSplitJob<'_>> = armed
                .iter()
                .map(|(name, _, x, y)| {
                    let i = self
                        .find(name)
                        .expect("gang member vanished mid-pass");
                    FwdSplitJob {
                        trainable: self.slots[i]
                            .session
                            .trainable_slice(),
                        x,
                        y,
                    }
                })
                .collect();
            let outs = art.run_fwd_split_many(&base, &jobs)?;
            drop(jobs);
            self.fstats.fused_passes += 1;
            *self.fstats.occupancy.entry(armed.len()).or_insert(0) += 1;
            // phase 3: absorb each member's forward output (own scope)
            let mut absorbed: Vec<(String, StepCtx, Tensor, Tensor,
                                   crate::runtime::FwdOut)> =
                Vec::with_capacity(armed.len());
            for ((name, mut ctx, x, y), out) in
                armed.drain(..).zip(outs)
            {
                let i = self
                    .find(&name)
                    .expect("gang member vanished mid-pass");
                let r = supervisor::catch_fault(|| {
                    faultpoint::with_scope(&name, || {
                        self.slots[i]
                            .session
                            .absorb_fwd(&mut ctx, &out)
                    })
                });
                match r {
                    Ok(()) => absorbed.push((name, ctx, x, y, out)),
                    Err(e) => {
                        art.recycle(out.residuals);
                        self.peel_member(&name, Some(ctx), e, events,
                                         &mut stepped);
                    }
                }
            }
            if absorbed.is_empty() {
                break;
            }
            // phase 4: ONE physical backward pass for the survivors
            let bjobs: Vec<BwdSplitJob<'_>> = absorbed
                .iter()
                .map(|(name, _, x, y, out)| {
                    let i = self
                        .find(name)
                        .expect("gang member vanished mid-pass");
                    BwdSplitJob {
                        trainable: self.slots[i]
                            .session
                            .trainable_slice(),
                        residuals: &out.residuals,
                        x,
                        y,
                    }
                })
                .collect();
            let gradss = art.run_bwd_split_many(&base, &bjobs)?;
            drop(bjobs);
            // phase 5: absorb gradients per member (own scope)
            for ((name, mut ctx, _x, _y, out), grads) in
                absorbed.drain(..).zip(gradss)
            {
                let i = self
                    .find(&name)
                    .expect("gang member vanished mid-pass");
                let r = supervisor::catch_fault(|| {
                    faultpoint::with_scope(&name, || {
                        self.slots[i].session.absorb_bwd(
                            &mut ctx,
                            out.residuals,
                            grads,
                        )
                    })
                });
                match r {
                    Ok(()) => live.push((name, ctx)),
                    Err(e) => self.peel_member(&name, Some(ctx), e,
                                               events, &mut stepped),
                }
            }
        }
        // close every surviving member's step (numeric gates +
        // optimizer update run per member, in its own scope)
        let share = t0.elapsed().as_secs_f64() / live.len().max(1) as f64;
        for (name, ctx) in live {
            let i = self
                .find(&name)
                .expect("gang member vanished mid-pass");
            let r = supervisor::catch_fault(|| {
                faultpoint::with_scope(&name, || {
                    self.slots[i].session.finish_step(ctx)
                })
            });
            match r {
                Ok(_) => {
                    self.slots[i].retries = 0;
                    stepped += 1;
                    events.push(StepEvent {
                        name: name.clone(),
                        step: self.slots[i].session.steps_done(),
                        dur_s: share,
                        kind: StepEventKind::Stepped,
                    });
                }
                Err(e) => self.peel_member(&name, None, e, events,
                                           &mut stepped),
            }
        }
        Ok(stepped)
    }

    /// Whether admitting `(art, cfg)` at `priority` under preemption
    /// would *strand* work: simulate the exact victim selection
    /// [`Engine::admit_prio`] would perform, and report `true` when
    /// any evicted victim — or, if this admission makes a new frozen
    /// base resident, any already-suspended session — could never be
    /// resumed again even into an otherwise-empty fleet (bases never
    /// leave residency, so `bases + marginal > budget` is permanent:
    /// the scheduling-deadlock bail in [`Engine::round_with`] would be
    /// inevitable). Front lines call this before a preempting
    /// admission and requeue the job instead of dooming the fleet.
    pub fn preempt_would_strand(&self, art: &'a Artifact, cfg: &TrainCfg,
                                priority: i64) -> bool {
        let admission = predict(art, cfg);
        let base_cost = self.base_cost_for(art);
        let needed = base_cost + admission.marginal();
        let mut predicted = self.predicted_bytes();
        if predicted + needed <= self.budget {
            return false; // fits without evicting anyone
        }
        let bases_after = self.base_bytes() + base_cost;
        let mut victims: Vec<usize> = (0..self.slots.len())
            .filter(|&i| {
                !self.slots[i].done && self.slots[i].priority < priority
            })
            .collect();
        victims.sort_by_key(|&i| (self.slots[i].priority, i));
        let reclaim: u64 = victims
            .iter()
            .map(|&i| Engine::slot_cost(&self.slots[i]))
            .sum();
        if predicted + needed > self.budget + reclaim {
            // admit_prio's all-or-nothing check evicts no one and
            // rejects normally — no stranding hazard
            return false;
        }
        let mut evicted = Vec::new();
        for &i in &victims {
            if predicted + needed <= self.budget {
                break;
            }
            predicted -= Engine::slot_cost(&self.slots[i]);
            evicted.push(i);
        }
        evicted.iter().any(|&i| {
            bases_after + self.slots[i].admission.marginal()
                > self.budget
        }) || (base_cost > 0
            && self.suspended.iter().any(|s| {
                bases_after + s.admission.marginal() > self.budget
            }))
    }

    /// Advance every unfinished resident session by one optimizer
    /// step, in admission order, then resume any suspended sessions
    /// that now fit the freed budget. Returns how many sessions made
    /// progress — stepped or came back from the spool (0 = all work
    /// exhausted). Fleet accounting is refreshed after the sweep.
    ///
    /// In the default (supervised) mode a faulting tenant never fails
    /// the round: transient I/O faults are retried from the last good
    /// state up to `max_retries` times, everything else quarantines the
    /// tenant ([`Engine::quarantine_slot`]) and the sweep continues.
    /// Under [`Engine::set_strict`] the first fault propagates, as it
    /// did before supervision existed.
    pub fn round(&mut self) -> Result<usize> {
        let mut events = Vec::new();
        self.round_with(&mut events)
    }

    /// [`Engine::round`] that additionally appends one [`StepEvent`]
    /// per session touched — wall-clock step durations for the front
    /// line's latency percentiles, plus `Finished` / `Quarantined`
    /// markers. The scheduling behavior is identical to `round`.
    pub fn round_with(&mut self,
                      events: &mut Vec<StepEvent>) -> Result<usize> {
        let stepped = if self.fuse && !self.strict {
            self.sweep_fused(events)?
        } else {
            self.sweep_serial(events)?
        };
        // capacity-planning peak: resident set + every session's
        // measured tape/grad peak as if all tenants were mid-step
        self.fleet.current_bytes =
            self.resident_param_bytes() + self.opt_state_bytes();
        let tapes: u64 = self
            .slots
            .iter()
            .map(|s| s.session.memory.peak_bytes)
            .sum();
        self.fleet.observe_extra(tapes);
        let resumed = if self.strict {
            self.try_resume_suspended()?
        } else {
            self.try_resume_suspended_supervised()
        };
        if stepped == 0 && resumed == 0 && !self.suspended.is_empty() {
            // every resident session is done, yet the spooled ones
            // still don't fit: no future round can change that
            bail!(
                "scheduling deadlock: suspended sessions {:?} cannot \
                 fit the remaining budget ({} predicted of {} bytes) \
                 even with all resident sessions finished",
                self.suspended_names(),
                self.predicted_bytes(),
                self.budget
            );
        }
        Ok(stepped + resumed)
    }

    /// Round-robin every session to exhaustion, then finish each
    /// (held-out evaluation + report), in admission order. Quarantined
    /// tenants appear at the end of the report list as
    /// [`SessionOutcome::Quarantined`] — the fleet run itself still
    /// returns `Ok` (supervised mode's whole point); only `--strict`
    /// mode (or an engine-level failure like a scheduling deadlock)
    /// surfaces an `Err`.
    pub fn run(&mut self) -> Result<Vec<EngineReport>> {
        while self.round()? > 0 {}
        let mut out =
            Vec::with_capacity(self.slots.len() + self.quarantined.len());
        let mut i = 0usize;
        while i < self.slots.len() {
            let report = if self.strict {
                self.slots[i].session.finish()?
            } else {
                match supervisor::catch_fault(|| {
                    self.slots[i].session.finish()
                }) {
                    Ok(r) => r,
                    Err(e) => {
                        let kind = supervisor::classify(&e);
                        self.quarantine_slot(i, kind, format!("{e:?}"));
                        continue;
                    }
                }
            };
            let slot = &self.slots[i];
            out.push(EngineReport {
                name: slot.name.clone(),
                preset: slot.session.artifact().manifest.preset.clone(),
                admission: Some(slot.admission.clone()),
                outcome: SessionOutcome::Completed(report),
            });
            i += 1;
        }
        for (admission, rec) in self.quarantined.drain(..) {
            out.push(EngineReport {
                name: rec.name.clone(),
                preset: rec.preset.clone(),
                admission,
                outcome: SessionOutcome::Quarantined(rec),
            });
        }
        Ok(out)
    }

    /// Finish and *remove* every done session, and drain the
    /// quarantine queue, returning their [`EngineReport`]s. This is
    /// the long-running front-line counterpart to [`Engine::run`]:
    /// `run` leaves finished slots resident (callers inspect their
    /// parameters afterwards), but a finished slot still holds its
    /// optimizer-state + trainable + flat-fallback residency — over an
    /// open-ended job queue that would pin budget forever. Retiring
    /// frees exactly that share; the `Arc`-shared frozen bases stay
    /// resident with their artifacts (a later session on the same base
    /// still admits at zero base cost).
    pub fn retire_done(&mut self) -> Result<Vec<EngineReport>> {
        let mut out = Vec::new();
        let mut i = 0usize;
        while i < self.slots.len() {
            if !self.slots[i].done {
                i += 1;
                continue;
            }
            let report = if self.strict {
                self.slots[i].session.finish()?
            } else {
                match supervisor::catch_fault(|| {
                    self.slots[i].session.finish()
                }) {
                    Ok(r) => r,
                    Err(e) => {
                        let kind = supervisor::classify(&e);
                        self.quarantine_slot(i, kind, format!("{e:?}"));
                        continue;
                    }
                }
            };
            let slot = self.slots.remove(i);
            out.push(EngineReport {
                name: slot.name,
                preset: slot.session.artifact().manifest.preset.clone(),
                admission: Some(slot.admission),
                outcome: SessionOutcome::Completed(report),
            });
        }
        for (admission, rec) in self.quarantined.drain(..) {
            out.push(EngineReport {
                name: rec.name.clone(),
                preset: rec.preset.clone(),
                admission,
                outcome: SessionOutcome::Quarantined(rec),
            });
        }
        Ok(out)
    }
}

/// One row of the fleet-capacity report.
pub struct CapacityRow {
    /// Preset under consideration.
    pub preset: String,
    /// Shared-base bytes (resident once regardless of session count).
    pub base_bytes: u64,
    /// Predicted per-session marginal bytes.
    pub admission: Admission,
    /// Sessions-per-budget: how many sessions admission control fits.
    pub admitted: usize,
    /// Measured per-session tape bytes from a probe step (when run).
    pub measured_tape: Option<u64>,
}

/// The paper's Table-1 story restated as tenancy: for each preset,
/// predict the per-session marginal footprint, derive
/// sessions-per-budget, and (optionally) run a 1-step probe session to
/// cross-check the predicted tape against the measured residual bytes.
pub fn fleet_capacity(rt: &Runtime, budget_bytes: u64,
                      presets: &[String], cfg: &TrainCfg,
                      probe: bool) -> Result<Vec<CapacityRow>> {
    let mut out = Vec::with_capacity(presets.len());
    for preset in presets {
        let art = crate::runtime::load_or_synth(rt, preset)?;
        let admission = predict(&art, cfg);
        let base_bytes = art.frozen_base().nbytes();
        let admitted = if budget_bytes <= base_bytes {
            0
        } else {
            ((budget_bytes - base_bytes) / admission.marginal().max(1))
                as usize
        };
        let measured_tape = if probe {
            let mut probe_cfg = cfg.clone();
            probe_cfg.steps = 1;
            probe_cfg.log_every = 0;
            probe_cfg.eval_batches = 0;
            let mut s = Session::new(&art, probe_cfg)?;
            s.step()?;
            Some(s.memory.last_residual_bytes)
        } else {
            None
        };
        out.push(CapacityRow {
            preset: preset.clone(),
            base_bytes,
            admission,
            admitted,
            measured_tape,
        });
    }
    Ok(out)
}
