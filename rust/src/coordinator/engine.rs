//! The multi-tenant fine-tuning engine: memory-budgeted admission +
//! fair step interleaving over sessions that share frozen bases.
//!
//! The paper's observation — activation memory, not weights, is the
//! per-job scaling bottleneck — becomes *capacity* here: the frozen
//! base of an artifact is resident once (`Arc`-shared
//! [`FrozenBase`]), so the marginal footprint of one more session is
//! its activation tape + gradients + optimizer state + trainable
//! slice. Admission control meters exactly that, using the analytical
//! memmodel prediction ([`MemCfg::from_manifest`], `Mode::Tape`)
//! cross-checked against the schema-derived manifest total; scheduling
//! is round-robin at [`Session::step`] granularity over the shared
//! worker pool; the fleet-wide peak is tracked with the same
//! [`MemoryTracker`] the single-job path uses. [`fleet_capacity`]
//! restates the paper's Table-1 savings as sessions-per-budget:
//! `*_regelu2_msln` / `*_mesa` presets admit strictly more tenants
//! than their baselines under the same byte budget.
//!
//! With a spool directory and preemption enabled, an over-budget
//! admission no longer rejects outright: lower-priority unfinished
//! sessions are suspended to disk (durable statefiles, see
//! `statefile`) to make room, and [`Engine::round`] resumes them —
//! highest priority first — as budget frees up. Because a session's
//! state is bit-exactly portable (indexed data stream, raw optimizer
//! state), the preempted runs finish bit-identical to uninterrupted
//! ones.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::memory::MemoryTracker;
use crate::coordinator::session::{Session, StepOutcome};
use crate::coordinator::statefile::{self, SavedSession, SessionHandle};
use crate::coordinator::supervisor::{self, FaultKind, FaultRecord};
use crate::coordinator::trainer::{TrainCfg, TrainReport};
use crate::memmodel::{total_bytes, MemCfg};
use crate::runtime::{Artifact, Runtime};

/// One job request: a preset plus its trainer hyper-parameters.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Preset name (artifact to load or synthesize).
    pub preset: String,
    /// Per-session hyper-parameters.
    pub cfg: TrainCfg,
    /// Scheduling priority (higher = more important; default 0). A
    /// preempting engine may suspend lower-priority sessions to admit
    /// this one.
    pub priority: i64,
}

impl JobSpec {
    /// Parse a `preset[:steps[:seed[:prio]]]` job token (the `--jobs`
    /// list grammar). Defaults come from `base`; when no seed is given,
    /// the job index is added to the base seed so identical presets
    /// stream distinct tenant data. Priority defaults to 0.
    pub fn parse(token: &str, base: &TrainCfg,
                 job_index: usize) -> Result<JobSpec> {
        let mut parts = token.split(':');
        let preset = parts
            .next()
            .filter(|p| !p.is_empty())
            .with_context(|| format!("empty job spec {token:?}"))?
            .to_string();
        let mut cfg = base.clone();
        cfg.seed = base.seed + job_index as u64;
        if let Some(s) = parts.next() {
            cfg.steps = s
                .parse()
                .with_context(|| format!("bad steps in job {token:?}"))?;
        }
        if let Some(s) = parts.next() {
            cfg.seed = s
                .parse()
                .with_context(|| format!("bad seed in job {token:?}"))?;
        }
        let mut priority = 0i64;
        if let Some(s) = parts.next() {
            priority = s.parse().with_context(|| {
                format!("bad priority in job {token:?}")
            })?;
        }
        if let Some(extra) = parts.next() {
            bail!("job {token:?}: unexpected field {extra:?} \
                   (grammar: preset[:steps[:seed[:prio]]])");
        }
        Ok(JobSpec { preset, cfg, priority })
    }
}

/// The memmodel-backed per-session footprint prediction admission
/// control gates on. All figures are bytes.
#[derive(Debug, Clone)]
pub struct Admission {
    /// Predicted activation tape held between fwd and bwd —
    /// `max(memmodel Tape-mode total, manifest residual total)`.
    pub tape_bytes: u64,
    /// Gradient sets held at the step peak: one, or two with
    /// `grad_accum > 1` (the running accumulator is live while the
    /// next microbatch's fresh gradients materialize).
    pub grad_bytes: u64,
    /// Optimizer state (AdamW m+v, SGD velocity).
    pub opt_bytes: u64,
    /// The session's private trainable parameter copy.
    pub trainable_bytes: u64,
    /// Extra full-parameter copy a session on a *non-forking* backend
    /// materializes as its flat-ABI fallback (0 on backends with split
    /// support, i.e. native): without this term, admission would
    /// undercount real residency by ~one base per session there.
    pub flat_copy_bytes: u64,
}

impl Admission {
    /// The session's marginal footprint on top of the shared base.
    pub fn marginal(&self) -> u64 {
        self.tape_bytes + self.grad_bytes + self.opt_bytes
            + self.trainable_bytes + self.flat_copy_bytes
    }
}

/// Predict one session's footprint on `art` under `cfg` — no step has
/// to run. The tape term is the paper's subject; grads/optimizer/
/// trainables scale with the tuning mode (tiny under LoRA).
pub fn predict(art: &Artifact, cfg: &TrainCfg) -> Admission {
    let m = &art.manifest;
    let analytic = MemCfg::from_manifest(m)
        .map(|c| total_bytes(&c))
        .unwrap_or(0);
    let tape_bytes = analytic.max(m.residual_bytes_total);
    let trainable_elems: u64 = m
        .params
        .iter()
        .filter(|p| p.trainable)
        .map(|p| p.shape.iter().product::<usize>() as u64)
        .sum();
    let trainable_bytes = trainable_elems * 4;
    let grad_bytes =
        trainable_bytes * if cfg.grad_accum > 1 { 2 } else { 1 };
    let opt_bytes = match cfg.optimizer.as_str() {
        "sgd" => trainable_bytes,
        _ => 2 * trainable_bytes, // AdamW m+v
    };
    // a backend without split support gets a per-session flat
    // fallback vector (see Session): meter that copy too
    let flat_copy_bytes = if art.supports_split() {
        0
    } else {
        art.frozen_base().nbytes() + trainable_bytes
    };
    Admission {
        tape_bytes,
        grad_bytes,
        opt_bytes,
        trainable_bytes,
        flat_copy_bytes,
    }
}

/// How one admitted session ended.
pub enum SessionOutcome {
    /// The session ran its full step budget; here is its report.
    Completed(TrainReport),
    /// The supervisor isolated a fault: the session was removed from
    /// the fleet (its last good state spooled to
    /// `<name>.state.quarantine` when a spool directory exists) and
    /// every other tenant kept running.
    Quarantined(FaultRecord),
}

/// Final engine output for one session.
pub struct EngineReport {
    /// Session name (from `admit`).
    pub name: String,
    /// Preset the session trained.
    pub preset: String,
    /// What admission predicted (`None` only for sessions that never
    /// reached admission, e.g. a spool file quarantined at scan time).
    pub admission: Option<Admission>,
    /// How the session ended.
    pub outcome: SessionOutcome,
}

impl EngineReport {
    /// The training report, when the session completed.
    pub fn train(&self) -> Option<&TrainReport> {
        match &self.outcome {
            SessionOutcome::Completed(r) => Some(r),
            SessionOutcome::Quarantined(_) => None,
        }
    }

    /// The fault record, when the session was quarantined.
    pub fn fault(&self) -> Option<&FaultRecord> {
        match &self.outcome {
            SessionOutcome::Completed(_) => None,
            SessionOutcome::Quarantined(rec) => Some(rec),
        }
    }
}

/// What one session did during a [`Engine::round_with`] sweep — the
/// front line's observability feed (per-session step-latency
/// percentiles, completion detection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepEventKind {
    /// The session completed one optimizer step.
    Stepped,
    /// The session's step budget ran out this sweep (no step ran).
    Finished,
    /// The supervisor quarantined the session this sweep.
    Quarantined,
}

/// One per-session event from a [`Engine::round_with`] sweep.
#[derive(Debug, Clone)]
pub struct StepEvent {
    /// Session name.
    pub name: String,
    /// Steps the session has completed after this event.
    pub step: usize,
    /// Wall-clock seconds the step took (0 for non-`Stepped` events).
    /// Latency is measurement, not state: it is *not* part of the
    /// determinism contract.
    pub dur_s: f64,
    /// What happened.
    pub kind: StepEventKind,
}

struct Slot<'a> {
    name: String,
    session: Session<'a>,
    admission: Admission,
    priority: i64,
    done: bool,
    /// Consecutive supervised-step I/O retries since the last good
    /// step (reset on success; bounded by `Engine::max_retries`).
    retries: u32,
}

/// A session evicted to disk: the durable handle plus the resident
/// artifact it resumes against and the admission prediction used for
/// the fits-now check (recomputing it would need the on-disk cfg).
struct Suspended<'a> {
    handle: SessionHandle,
    art: &'a Artifact,
    admission: Admission,
}

/// Multi-tenant engine: admits sessions against a byte budget and
/// interleaves their steps round-robin (see module docs).
pub struct Engine<'a> {
    budget: u64,
    /// Unique shared bases: (`Arc` pointer identity, frozen bytes).
    bases: Vec<(usize, u64)>,
    slots: Vec<Slot<'a>>,
    /// Where suspended sessions spool to (`None` = suspension off).
    spool: Option<PathBuf>,
    /// Whether over-budget admission may evict lower-priority sessions.
    preempt: bool,
    /// Sessions currently evicted to the spool.
    suspended: Vec<Suspended<'a>>,
    /// Fail-fast mode: any session fault aborts the whole fleet run
    /// (the pre-supervision behavior). Off by default — the supervisor
    /// isolates faults per tenant instead.
    strict: bool,
    /// Bound on consecutive transient-I/O retries per session before
    /// the fault is treated as terminal and the session quarantined.
    max_retries: u32,
    /// Sessions the supervisor removed from the fleet this run, with
    /// the admission they held (if any); drained into
    /// [`EngineReport`]s by [`Engine::run`].
    quarantined: Vec<(Option<Admission>, FaultRecord)>,
    /// Fleet-wide measured accounting: `current_bytes` carries the
    /// resident set (bases + trainables + optimizer state), the peak
    /// adds every admitted session's measured tape+grad peak — the
    /// capacity-planning view where all tenants are mid-step at once
    /// (exactly what admission budgets for).
    pub fleet: MemoryTracker,
}

impl<'a> Engine<'a> {
    /// Engine with a byte budget (use [`Engine::unbounded`] for tests
    /// and benches that only want the scheduler).
    pub fn new(budget_bytes: u64) -> Engine<'a> {
        Engine {
            budget: budget_bytes,
            bases: Vec::new(),
            slots: Vec::new(),
            spool: None,
            preempt: false,
            suspended: Vec::new(),
            strict: false,
            max_retries: 2,
            quarantined: Vec::new(),
            fleet: MemoryTracker::new(),
        }
    }

    /// Fail-fast mode: propagate the first session fault out of
    /// [`Engine::round`] instead of isolating it (the `--strict`
    /// behavior). Off by default.
    pub fn set_strict(&mut self, strict: bool) {
        self.strict = strict;
    }

    /// Bound on consecutive transient-I/O retries per session before
    /// the supervisor quarantines it (default 2).
    pub fn set_max_retries(&mut self, max_retries: u32) {
        self.max_retries = max_retries;
    }

    /// Set the directory suspended sessions spool to. Required before
    /// [`Engine::suspend`] / [`Engine::enable_preempt`].
    pub fn set_spool(&mut self, dir: PathBuf) {
        self.spool = Some(dir);
    }

    /// Allow over-budget admissions to evict lower-priority sessions
    /// to the spool instead of rejecting. Requires a spool directory.
    pub fn enable_preempt(&mut self) -> Result<()> {
        ensure!(self.spool.is_some(),
                "preemption requires a spool directory (set_spool)");
        self.preempt = true;
        Ok(())
    }

    /// Engine with an effectively infinite budget.
    pub fn unbounded() -> Engine<'a> {
        Engine::new(u64::MAX)
    }

    /// The configured byte budget.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Admitted session count.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether no session was admitted.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// What one resident slot is predicted to cost right now: the full
    /// marginal while it can still step; once done, only its residency
    /// (optimizer state + trainables + flat fallback) — a finished
    /// session holds no tape and materializes no fresh gradients, so
    /// its budget share shrinks and preempted work can come back.
    fn slot_cost(slot: &Slot<'a>) -> u64 {
        if slot.done {
            slot.admission.opt_bytes + slot.admission.trainable_bytes
                + slot.admission.flat_copy_bytes
        } else {
            slot.admission.marginal()
        }
    }

    /// Predicted fleet footprint: every unique base once + each
    /// resident session's [`Engine::slot_cost`].
    pub fn predicted_bytes(&self) -> u64 {
        self.bases.iter().map(|(_, b)| b).sum::<u64>()
            + self.slots.iter().map(Engine::slot_cost).sum::<u64>()
    }

    /// Total frozen-base bytes resident (each unique base once).
    pub fn base_bytes(&self) -> u64 {
        self.bases.iter().map(|(_, b)| b).sum()
    }

    /// *Actual* resident parameter bytes: each unique frozen base
    /// exactly once (it is `Arc`-shared storage, not an accounting
    /// convention) plus every session's private trainable tensors.
    /// Adding a session on an already-resident base grows this by only
    /// the trainable slice — the stored-once assertion of the tests.
    pub fn resident_param_bytes(&self) -> u64 {
        self.bases.iter().map(|(_, b)| b).sum::<u64>()
            + self
                .slots
                .iter()
                .map(|s| s.session.resident_param_bytes())
                .sum::<u64>()
    }

    /// Measured optimizer-state bytes across sessions.
    pub fn opt_state_bytes(&self) -> u64 {
        self.slots.iter().map(|s| s.session.opt_state_bytes()).sum()
    }

    /// Admit a session for `cfg` on `art` at priority 0, or reject it
    /// when the predicted footprint would exceed the budget — the
    /// error carries the memmodel's predicted bytes. Admission
    /// constructs the session (which warms up once), so an `Ok`
    /// session is ready to step. Sessions are addressed by `name` from
    /// here on ([`Engine::session`], [`Engine::suspend`]) — slot
    /// positions are an internal detail.
    pub fn admit(&mut self, name: &str, art: &'a Artifact,
                 cfg: TrainCfg) -> Result<()> {
        self.admit_prio(name, art, cfg, 0)
    }

    /// [`Engine::admit`] with an explicit priority. Under
    /// [`Engine::enable_preempt`], an over-budget admission first
    /// suspends enough strictly-lower-priority unfinished sessions
    /// (lowest priority first, FIFO within a priority) to fit the new
    /// job; when even evicting all eligible victims would not fit, no
    /// one is evicted and the job is rejected with the usual detailed
    /// error.
    pub fn admit_prio(&mut self, name: &str, art: &'a Artifact,
                      cfg: TrainCfg, priority: i64) -> Result<()> {
        ensure!(
            self.find(name).is_none()
                && !self.suspended.iter().any(|s| s.handle.name == name),
            "admission rejected for {name}: a session with that name \
             is already resident or suspended"
        );
        let admission = predict(art, &cfg);
        let base = art.frozen_base();
        let key = Arc::as_ptr(&base) as usize;
        let base_new = !self.bases.iter().any(|(k, _)| *k == key);
        let base_cost = if base_new { base.nbytes() } else { 0 };
        let needed = base_cost + admission.marginal();
        if self.preempt && self.predicted_bytes() + needed > self.budget
        {
            // victims: unfinished, strictly lower priority; evict the
            // least important first (ascending priority, then FIFO)
            let mut victims: Vec<usize> = (0..self.slots.len())
                .filter(|&i| {
                    !self.slots[i].done
                        && self.slots[i].priority < priority
                })
                .collect();
            victims.sort_by_key(|&i| (self.slots[i].priority, i));
            let reclaim: u64 = victims
                .iter()
                .map(|&i| Engine::slot_cost(&self.slots[i]))
                .sum();
            // all-or-nothing feasibility: never evict anyone for a job
            // that still would not fit
            if self.predicted_bytes() + needed <= self.budget + reclaim {
                let names: Vec<String> = victims
                    .iter()
                    .map(|&i| self.slots[i].name.clone())
                    .collect();
                for victim in names {
                    if self.predicted_bytes() + needed <= self.budget {
                        break;
                    }
                    // a victim may have vanished (e.g. quarantined by
                    // the supervisor between selection and eviction):
                    // degrade to the ordinary rejected-admission path
                    // instead of panicking
                    let Some(id) = self.find(&victim) else { break };
                    match self.suspend_idx(id) {
                        Ok(_) => {}
                        Err(e) if self.strict => return Err(e),
                        // eviction failed (e.g. spool I/O): the victim
                        // was restored in place, so stop evicting and
                        // let the fit check below reject the admission
                        Err(_) => break,
                    }
                }
            }
        }
        let projected = self.predicted_bytes() + needed;
        if projected > self.budget {
            bail!(
                "admission rejected for {name} ({}): predicted session \
                 footprint {} bytes (tape {} + grads {} + optimizer {} \
                 + trainable params {}{}){} would put the fleet at {} \
                 of budget {} bytes",
                art.manifest.preset,
                admission.marginal(),
                admission.tape_bytes,
                admission.grad_bytes,
                admission.opt_bytes,
                admission.trainable_bytes,
                if admission.flat_copy_bytes > 0 {
                    format!(" + flat fallback {}",
                            admission.flat_copy_bytes)
                } else {
                    String::new()
                },
                if base_new {
                    format!(" + shared base {base_cost}")
                } else {
                    String::new()
                },
                projected,
                self.budget
            );
        }
        let session = Session::new(art, cfg)?;
        if base_new {
            self.bases.push((key, base.nbytes()));
        }
        self.slots.push(Slot {
            name: name.to_string(),
            session,
            admission,
            priority,
            done: false,
            retries: 0,
        });
        Ok(())
    }

    /// What admitting a session for `cfg` on `art` would add to the
    /// predicted fleet footprint *right now*: the memmodel marginal
    /// plus the frozen base — the latter only when no resident session
    /// already shares it. This is the number scheduling policies
    /// fit-check against the budget before committing any bytes.
    pub fn admission_cost(&self, art: &'a Artifact,
                          cfg: &TrainCfg) -> u64 {
        self.base_cost_for(art) + predict(art, cfg).marginal()
    }

    /// Direct access to a resident session by name (tests: parameter
    /// and base-identity assertions). `None` when no resident session
    /// carries that name (it may be suspended, quarantined, or done
    /// and retired).
    pub fn session(&self, name: &str) -> Option<&Session<'a>> {
        self.find(name).map(|id| &self.slots[id].session)
    }

    /// Whether a resident session carries this name (suspended
    /// sessions are listed by [`Engine::suspended_names`] instead).
    pub fn contains(&self, name: &str) -> bool {
        self.find(name).is_some()
    }

    /// Slot index of a resident session by name. Internal only: slot
    /// indices shift whenever a session is suspended, quarantined, or
    /// retired, so the public API deals exclusively in stable names.
    fn find(&self, name: &str) -> Option<usize> {
        self.slots.iter().position(|s| s.name == name)
    }

    /// Names of the sessions currently evicted to the spool.
    pub fn suspended_names(&self) -> Vec<String> {
        self.suspended
            .iter()
            .map(|s| s.handle.name.clone())
            .collect()
    }

    /// Whether any session — resident or suspended — still has steps
    /// left.
    pub fn has_unfinished(&self) -> bool {
        !self.suspended.is_empty()
            || self.slots.iter().any(|s| !s.done)
    }

    /// Evict a resident unfinished session (addressed by its stable
    /// name) to the spool: its portable state (trainables, raw
    /// optimizer state, step counter, metrics rows, memory accounting)
    /// is written to `<spool>/<name>.state` and the slot is dropped —
    /// freeing its tape/grad/optimizer/trainable budget share while
    /// the `Arc`-shared frozen base stays resident with the artifact
    /// (stored-once across suspend/resume). Returns the durable
    /// handle.
    pub fn suspend(&mut self, name: &str) -> Result<SessionHandle> {
        let id = self.find(name).with_context(|| {
            format!("no resident session named {name:?}")
        })?;
        self.suspend_idx(id)
    }

    /// [`Engine::suspend`] by slot index — the internal spelling every
    /// eviction path funnels through (indices are only stable within
    /// one call, which is why the public API takes a name).
    fn suspend_idx(&mut self, id: usize) -> Result<SessionHandle> {
        let spool = self
            .spool
            .clone()
            .context("suspend requires a spool directory (set_spool)")?;
        ensure!(id < self.slots.len(), "no session slot {id}");
        ensure!(
            !self.slots[id].done,
            "refusing to suspend finished session {:?} — its report is \
             pending, not its steps",
            self.slots[id].name
        );
        let slot = self.slots.remove(id);
        let Slot { name, session, admission, priority, done, retries } =
            slot;
        let art = session.artifact();
        let state = session.into_state();
        let path = spool.join(format!("{name}.state"));
        let saved = if self.strict {
            statefile::save_session(&path, &name, priority, &state)
        } else {
            supervisor::with_io_retry(self.max_retries + 1, || {
                supervisor::catch_fault(|| {
                    statefile::save_session(&path, &name, priority,
                                            &state)
                })
            })
        };
        match saved {
            Ok(handle) => {
                let out = handle.clone();
                self.suspended.push(Suspended {
                    handle,
                    art,
                    admission,
                });
                Ok(out)
            }
            Err(e) => {
                // spooling failed: rebuild the live session from the
                // state we just took so no work is lost — the slot
                // returns to its old position and the caller decides
                // what to do with the error
                match supervisor::catch_fault(|| {
                    Session::resume(art, state)
                }) {
                    Ok(session) => {
                        self.slots.insert(id, Slot {
                            name: name.clone(),
                            session,
                            admission,
                            priority,
                            done,
                            retries,
                        });
                        Err(e.context(format!(
                            "suspending {name} failed; session \
                             restored in place"
                        )))
                    }
                    Err(re) => Err(e.context(format!(
                        "suspending {name} failed AND restoring the \
                         live session failed ({re:#}); session lost"
                    ))),
                }
            }
        }
    }

    /// Suspend every unfinished resident session (checkpoint-on-halt:
    /// the warm-restart path rebuilds the fleet from these files).
    /// Returns the handles, in eviction order.
    pub fn suspend_all(&mut self) -> Result<Vec<SessionHandle>> {
        let mut out = Vec::new();
        while let Some(id) = self.slots.iter().position(|s| !s.done) {
            out.push(self.suspend_idx(id)?);
        }
        Ok(out)
    }

    /// Re-admit a loaded session state against its (resident)
    /// artifact: fit-check like [`Engine::admit`], rebuild the live
    /// session bit-exactly via [`Session::resume`], and — only on
    /// success — delete `origin` (the statefile it was loaded from).
    pub fn resume_saved(&mut self, saved: SavedSession,
                        art: &'a Artifact,
                        origin: Option<&Path>) -> Result<()> {
        let SavedSession { name, priority, state } = saved;
        let admission = predict(art, &state.cfg);
        let base = art.frozen_base();
        let key = Arc::as_ptr(&base) as usize;
        let base_new = !self.bases.iter().any(|(k, _)| *k == key);
        let base_cost = if base_new { base.nbytes() } else { 0 };
        let projected =
            self.predicted_bytes() + base_cost + admission.marginal();
        ensure!(
            projected <= self.budget,
            "resume rejected for {name}: predicted footprint {} bytes \
             would put the fleet at {projected} of budget {} bytes",
            admission.marginal(),
            self.budget
        );
        let session = Session::resume(art, state)?;
        if base_new {
            self.bases.push((key, base.nbytes()));
        }
        let done = session.is_done();
        self.slots.push(Slot {
            name,
            session,
            admission,
            priority,
            done,
            retries: 0,
        });
        if let Some(p) = origin {
            std::fs::remove_file(p).with_context(|| {
                format!("removing resumed statefile {p:?}")
            })?;
        }
        Ok(())
    }

    /// [`Engine::resume_saved`] straight from a statefile on disk.
    pub fn resume_file(&mut self, art: &'a Artifact,
                       path: &Path) -> Result<()> {
        let saved = statefile::load_session(path)?;
        self.resume_saved(saved, art, Some(path))
    }

    /// Warm-restart path: register an on-disk session statefile —
    /// resume it right away when it fits the budget (the file is then
    /// deleted), otherwise queue it as suspended so [`Engine::round`]
    /// brings it back once budget frees up. Returns whether it
    /// resumed immediately.
    pub fn spool_in(&mut self, art: &'a Artifact,
                    path: &Path) -> Result<bool> {
        let saved = statefile::load_session(path)?;
        let admission = predict(art, &saved.state.cfg);
        if self.predicted_bytes()
            + self.base_cost_for(art)
            + admission.marginal()
            <= self.budget
        {
            self.resume_saved(saved, art, Some(path))?;
            Ok(true)
        } else {
            let handle = statefile::peek_session(path)?;
            self.suspended.push(Suspended { handle, art, admission });
            Ok(false)
        }
    }

    /// Bytes admitting a session on `art` would add for its frozen
    /// base: 0 when that base is already resident.
    fn base_cost_for(&self, art: &'a Artifact) -> u64 {
        let base = art.frozen_base();
        let key = Arc::as_ptr(&base) as usize;
        if self.bases.iter().any(|(k, _)| *k == key) {
            0
        } else {
            base.nbytes()
        }
    }

    /// Bring back as many suspended sessions as now fit the budget —
    /// highest priority first, FIFO within a priority. Returns how
    /// many resumed.
    fn try_resume_suspended(&mut self) -> Result<usize> {
        let mut resumed = 0usize;
        loop {
            let mut order: Vec<usize> =
                (0..self.suspended.len()).collect();
            // stable sort: FIFO among equal priorities
            order.sort_by_key(|&i| {
                std::cmp::Reverse(self.suspended[i].handle.priority)
            });
            let picked = order.into_iter().find(|&i| {
                let s = &self.suspended[i];
                self.predicted_bytes()
                    + self.base_cost_for(s.art)
                    + s.admission.marginal()
                    <= self.budget
            });
            let Some(i) = picked else { break };
            let s = self.suspended.remove(i);
            let saved = statefile::load_session(&s.handle.path)?;
            self.resume_saved(saved, s.art, Some(&s.handle.path))?;
            resumed += 1;
        }
        Ok(resumed)
    }

    /// [`Engine::try_resume_suspended`] under supervision: a statefile
    /// that refuses to load (after bounded I/O retries) is quarantined
    /// — renamed to `<name>.state.quarantine` with a report beside it —
    /// instead of failing the round, and the scan moves on. Resolving a
    /// blocking entry either way counts as progress, so the deadlock
    /// detector never trips on a file the supervisor just retired.
    fn try_resume_suspended_supervised(&mut self) -> usize {
        let mut resumed = 0usize;
        loop {
            let mut order: Vec<usize> =
                (0..self.suspended.len()).collect();
            order.sort_by_key(|&i| {
                std::cmp::Reverse(self.suspended[i].handle.priority)
            });
            let picked = order.into_iter().find(|&i| {
                let s = &self.suspended[i];
                self.predicted_bytes()
                    + self.base_cost_for(s.art)
                    + s.admission.marginal()
                    <= self.budget
            });
            let Some(i) = picked else { break };
            let s = self.suspended.remove(i);
            let attempt =
                supervisor::with_io_retry(self.max_retries + 1, || {
                    supervisor::catch_fault(|| {
                        statefile::load_session(&s.handle.path)
                    })
                })
                .and_then(|saved| {
                    supervisor::catch_fault(|| {
                        self.resume_saved(saved, s.art,
                                          Some(&s.handle.path))
                    })
                });
            match attempt {
                Ok(_) => resumed += 1,
                Err(e) => {
                    let kind = supervisor::classify(&e);
                    let mut rec = FaultRecord {
                        name: s.handle.name.clone(),
                        preset: s.handle.preset.clone(),
                        kind,
                        step: s.handle.steps_done,
                        retries: if kind == FaultKind::Io {
                            self.max_retries
                        } else {
                            0
                        },
                        detail: format!("{e:?}"),
                        state_path: None,
                        report_path: None,
                    };
                    if s.handle.path.exists() {
                        if let Err(e2) = supervisor::quarantine_file(
                            &s.handle.path,
                            &mut rec,
                        ) {
                            rec.detail.push_str(&format!(
                                "; quarantine failed: {e2:?}"
                            ));
                        }
                    }
                    self.quarantined.push((Some(s.admission), rec));
                    // the blocking entry is resolved — that is
                    // progress for the deadlock detector
                    resumed += 1;
                }
            }
        }
        resumed
    }

    /// Remove slot `idx` from the fleet as a quarantined tenant: its
    /// last good state is spooled to `<name>.state.quarantine` (when a
    /// spool directory is set) with a diagnostic report beside it, and
    /// the record is queued for [`Engine::run`]'s output. Infallible —
    /// quarantine is the error path's terminal state, so secondary
    /// failures (e.g. the quarantine write itself faulting) are folded
    /// into the record's detail instead of propagating.
    fn quarantine_slot(&mut self, idx: usize, kind: FaultKind,
                       detail: String) {
        let slot = self.slots.remove(idx);
        let Slot { name, session, admission, priority, retries, .. } =
            slot;
        let mut rec = FaultRecord {
            name: name.clone(),
            preset: session.artifact().manifest.preset.clone(),
            kind,
            step: session.steps_done(),
            retries,
            detail,
            state_path: None,
            report_path: None,
        };
        if let Some(spool) = self.spool.clone() {
            let qpath = supervisor::quarantine_state_path(&spool, &name);
            let state = session.into_state();
            let saved =
                supervisor::with_io_retry(self.max_retries + 1, || {
                    supervisor::catch_fault(|| {
                        statefile::save_session(&qpath, &name, priority,
                                                &state)
                    })
                });
            match saved {
                Ok(_) => rec.state_path = Some(qpath),
                Err(e) => rec.detail.push_str(&format!(
                    "; quarantine state write failed: {e:?}"
                )),
            }
            match supervisor::write_report(&spool, &rec) {
                Ok(p) => rec.report_path = Some(p),
                Err(e) => rec.detail.push_str(&format!(
                    "; quarantine report write failed: {e:?}"
                )),
            }
        }
        self.quarantined.push((Some(admission), rec));
    }

    /// Advance every unfinished resident session by one optimizer
    /// step, in admission order, then resume any suspended sessions
    /// that now fit the freed budget. Returns how many sessions made
    /// progress — stepped or came back from the spool (0 = all work
    /// exhausted). Fleet accounting is refreshed after the sweep.
    ///
    /// In the default (supervised) mode a faulting tenant never fails
    /// the round: transient I/O faults are retried from the last good
    /// state up to `max_retries` times, everything else quarantines the
    /// tenant ([`Engine::quarantine_slot`]) and the sweep continues.
    /// Under [`Engine::set_strict`] the first fault propagates, as it
    /// did before supervision existed.
    pub fn round(&mut self) -> Result<usize> {
        let mut events = Vec::new();
        self.round_with(&mut events)
    }

    /// [`Engine::round`] that additionally appends one [`StepEvent`]
    /// per session touched — wall-clock step durations for the front
    /// line's latency percentiles, plus `Finished` / `Quarantined`
    /// markers. The scheduling behavior is identical to `round`.
    pub fn round_with(&mut self,
                      events: &mut Vec<StepEvent>) -> Result<usize> {
        let mut stepped = 0usize;
        let mut i = 0usize;
        while i < self.slots.len() {
            if self.slots[i].done {
                i += 1;
                continue;
            }
            let name = self.slots[i].name.clone();
            if self.strict {
                let t0 = std::time::Instant::now();
                match self.slots[i].session.step()? {
                    StepOutcome::Stepped(_) => {
                        stepped += 1;
                        events.push(StepEvent {
                            name,
                            step: self.slots[i].session.steps_done(),
                            dur_s: t0.elapsed().as_secs_f64(),
                            kind: StepEventKind::Stepped,
                        });
                    }
                    StepOutcome::Exhausted => {
                        self.slots[i].done = true;
                        events.push(StepEvent {
                            name,
                            step: self.slots[i].session.steps_done(),
                            dur_s: 0.0,
                            kind: StepEventKind::Finished,
                        });
                    }
                }
                i += 1;
                continue;
            }
            let t0 = std::time::Instant::now();
            let r = supervisor::supervised_step(
                &name,
                &mut self.slots[i].session,
            );
            match r {
                Ok(StepOutcome::Stepped(_)) => {
                    self.slots[i].retries = 0;
                    stepped += 1;
                    events.push(StepEvent {
                        name,
                        step: self.slots[i].session.steps_done(),
                        dur_s: t0.elapsed().as_secs_f64(),
                        kind: StepEventKind::Stepped,
                    });
                    i += 1;
                }
                Ok(StepOutcome::Exhausted) => {
                    self.slots[i].done = true;
                    events.push(StepEvent {
                        name,
                        step: self.slots[i].session.steps_done(),
                        dur_s: 0.0,
                        kind: StepEventKind::Finished,
                    });
                    i += 1;
                }
                Err(e) => {
                    let kind = supervisor::classify(&e);
                    let step_now = self.slots[i].session.steps_done();
                    if kind == FaultKind::Io
                        && self.slots[i].retries < self.max_retries
                    {
                        // transient: rebuild the session bit-exactly
                        // from its last good (pre-step) state — the
                        // failed attempt may have consumed prefetched
                        // batches, and resume replays the data stream
                        // from the committed step counter
                        self.slots[i].retries += 1;
                        supervisor::backoff(self.slots[i].retries);
                        let art = self.slots[i].session.artifact();
                        let snap = self.slots[i].session.snapshot();
                        let rebuilt = supervisor::catch_fault(|| {
                            Session::resume(art, snap)
                        });
                        match rebuilt {
                            Ok(fresh) => {
                                self.slots[i].session = fresh;
                                // the retry is scheduled work: count it
                                // as progress so run() comes back for
                                // the re-attempt
                                stepped += 1;
                                i += 1;
                            }
                            Err(re) => {
                                self.quarantine_slot(
                                    i,
                                    kind,
                                    format!(
                                        "{e:?}; retry rebuild \
                                         failed: {re:?}"
                                    ),
                                );
                                events.push(StepEvent {
                                    name,
                                    step: step_now,
                                    dur_s: 0.0,
                                    kind: StepEventKind::Quarantined,
                                });
                            }
                        }
                    } else {
                        self.quarantine_slot(i, kind, format!("{e:?}"));
                        events.push(StepEvent {
                            name,
                            step: step_now,
                            dur_s: 0.0,
                            kind: StepEventKind::Quarantined,
                        });
                    }
                }
            }
        }
        // capacity-planning peak: resident set + every session's
        // measured tape/grad peak as if all tenants were mid-step
        self.fleet.current_bytes =
            self.resident_param_bytes() + self.opt_state_bytes();
        let tapes: u64 = self
            .slots
            .iter()
            .map(|s| s.session.memory.peak_bytes)
            .sum();
        self.fleet.observe_extra(tapes);
        let resumed = if self.strict {
            self.try_resume_suspended()?
        } else {
            self.try_resume_suspended_supervised()
        };
        if stepped == 0 && resumed == 0 && !self.suspended.is_empty() {
            // every resident session is done, yet the spooled ones
            // still don't fit: no future round can change that
            bail!(
                "scheduling deadlock: suspended sessions {:?} cannot \
                 fit the remaining budget ({} predicted of {} bytes) \
                 even with all resident sessions finished",
                self.suspended_names(),
                self.predicted_bytes(),
                self.budget
            );
        }
        Ok(stepped + resumed)
    }

    /// Round-robin every session to exhaustion, then finish each
    /// (held-out evaluation + report), in admission order. Quarantined
    /// tenants appear at the end of the report list as
    /// [`SessionOutcome::Quarantined`] — the fleet run itself still
    /// returns `Ok` (supervised mode's whole point); only `--strict`
    /// mode (or an engine-level failure like a scheduling deadlock)
    /// surfaces an `Err`.
    pub fn run(&mut self) -> Result<Vec<EngineReport>> {
        while self.round()? > 0 {}
        let mut out =
            Vec::with_capacity(self.slots.len() + self.quarantined.len());
        let mut i = 0usize;
        while i < self.slots.len() {
            let report = if self.strict {
                self.slots[i].session.finish()?
            } else {
                match supervisor::catch_fault(|| {
                    self.slots[i].session.finish()
                }) {
                    Ok(r) => r,
                    Err(e) => {
                        let kind = supervisor::classify(&e);
                        self.quarantine_slot(i, kind, format!("{e:?}"));
                        continue;
                    }
                }
            };
            let slot = &self.slots[i];
            out.push(EngineReport {
                name: slot.name.clone(),
                preset: slot.session.artifact().manifest.preset.clone(),
                admission: Some(slot.admission.clone()),
                outcome: SessionOutcome::Completed(report),
            });
            i += 1;
        }
        for (admission, rec) in self.quarantined.drain(..) {
            out.push(EngineReport {
                name: rec.name.clone(),
                preset: rec.preset.clone(),
                admission,
                outcome: SessionOutcome::Quarantined(rec),
            });
        }
        Ok(out)
    }

    /// Finish and *remove* every done session, and drain the
    /// quarantine queue, returning their [`EngineReport`]s. This is
    /// the long-running front-line counterpart to [`Engine::run`]:
    /// `run` leaves finished slots resident (callers inspect their
    /// parameters afterwards), but a finished slot still holds its
    /// optimizer-state + trainable + flat-fallback residency — over an
    /// open-ended job queue that would pin budget forever. Retiring
    /// frees exactly that share; the `Arc`-shared frozen bases stay
    /// resident with their artifacts (a later session on the same base
    /// still admits at zero base cost).
    pub fn retire_done(&mut self) -> Result<Vec<EngineReport>> {
        let mut out = Vec::new();
        let mut i = 0usize;
        while i < self.slots.len() {
            if !self.slots[i].done {
                i += 1;
                continue;
            }
            let report = if self.strict {
                self.slots[i].session.finish()?
            } else {
                match supervisor::catch_fault(|| {
                    self.slots[i].session.finish()
                }) {
                    Ok(r) => r,
                    Err(e) => {
                        let kind = supervisor::classify(&e);
                        self.quarantine_slot(i, kind, format!("{e:?}"));
                        continue;
                    }
                }
            };
            let slot = self.slots.remove(i);
            out.push(EngineReport {
                name: slot.name,
                preset: slot.session.artifact().manifest.preset.clone(),
                admission: Some(slot.admission),
                outcome: SessionOutcome::Completed(report),
            });
        }
        for (admission, rec) in self.quarantined.drain(..) {
            out.push(EngineReport {
                name: rec.name.clone(),
                preset: rec.preset.clone(),
                admission,
                outcome: SessionOutcome::Quarantined(rec),
            });
        }
        Ok(out)
    }
}

/// One row of the fleet-capacity report.
pub struct CapacityRow {
    /// Preset under consideration.
    pub preset: String,
    /// Shared-base bytes (resident once regardless of session count).
    pub base_bytes: u64,
    /// Predicted per-session marginal bytes.
    pub admission: Admission,
    /// Sessions-per-budget: how many sessions admission control fits.
    pub admitted: usize,
    /// Measured per-session tape bytes from a probe step (when run).
    pub measured_tape: Option<u64>,
}

/// The paper's Table-1 story restated as tenancy: for each preset,
/// predict the per-session marginal footprint, derive
/// sessions-per-budget, and (optionally) run a 1-step probe session to
/// cross-check the predicted tape against the measured residual bytes.
pub fn fleet_capacity(rt: &Runtime, budget_bytes: u64,
                      presets: &[String], cfg: &TrainCfg,
                      probe: bool) -> Result<Vec<CapacityRow>> {
    let mut out = Vec::with_capacity(presets.len());
    for preset in presets {
        let art = crate::runtime::load_or_synth(rt, preset)?;
        let admission = predict(&art, cfg);
        let base_bytes = art.frozen_base().nbytes();
        let admitted = if budget_bytes <= base_bytes {
            0
        } else {
            ((budget_bytes - base_bytes) / admission.marginal().max(1))
                as usize
        };
        let measured_tape = if probe {
            let mut probe_cfg = cfg.clone();
            probe_cfg.steps = 1;
            probe_cfg.log_every = 0;
            probe_cfg.eval_batches = 0;
            let mut s = Session::new(&art, probe_cfg)?;
            s.step()?;
            Some(s.memory.last_residual_bytes)
        } else {
            None
        };
        out.push(CapacityRow {
            preset: preset.clone(),
            base_bytes,
            admission,
            admitted,
            measured_tape,
        });
    }
    Ok(out)
}
