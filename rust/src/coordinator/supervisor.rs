//! Fleet supervision: fault classification, bounded retry, and
//! quarantine for the multi-tenant engine.
//!
//! PR 6 gave the engine corruption *detection* (typed [`StateError`]s,
//! per-section checksums, bit-identical suspend/resume); this module
//! builds *survival* on top of it. A tenant's `step()` runs under
//! [`supervised_step`] — `catch_unwind` plus [`classify`] — so one
//! faulting tenant degrades to a per-tenant outcome instead of killing
//! the fleet:
//!
//! | fault                     | kind      | policy                    |
//! |---------------------------|-----------|---------------------------|
//! | panic (any `panic!`)      | `Panic`   | quarantine                |
//! | `io::Error` in the chain  | `Io`      | bounded retry, then       |
//! |                           |           | quarantine                |
//! | NaN/Inf loss or grad norm | `Numeric` | quarantine                |
//! | `StateError` (statefile)  | `State`   | quarantine                |
//! | anything else             | `Other`   | quarantine                |
//!
//! Quarantine means: the tenant's last good state is spooled to
//! `<name>.state.quarantine` (extension `quarantine`, so naive
//! `*.state` globs no longer match it), a diagnostic report naming
//! the fault, step, and preset is written to `<name>.quarantine.json`,
//! and the fleet keeps stepping every other tenant. Under `--strict` none of
//! this engages — any fault propagates out of `Engine::round` exactly
//! as before this layer existed.
//!
//! [`scan_spool`] is the salvaging warm-restart: it enumerates a spool
//! directory, retries transient read faults with bounded backoff, and
//! quarantines files that still refuse to parse — so one corrupt
//! statefile no longer blocks every healthy session's restart.
//!
//! Every branch here is reachable deterministically through
//! `util::faultpoint` (`AMBP_FAULTS` / `ambp serve --faults`); the
//! armed sites are `step.loss`, `step.compute`, `spool.write`, and
//! `spool.read`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::session::{Session, StepOutcome};
use crate::coordinator::statefile::{self, SessionHandle, StateError};
use crate::util::faultpoint;
use crate::util::json::{num, obj, s};

/// Classification of a tenant fault — what failed, which picks the
/// recovery policy (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A caught panic (library bug, injected fault).
    Panic,
    /// An `io::Error` somewhere in the source chain — treated as
    /// transient and retried with bounded backoff.
    Io,
    /// Non-finite loss/metric or gradient norm ([`NumericFault`]).
    Numeric,
    /// Statefile corruption ([`StateError`]).
    State,
    /// An error none of the typed probes matched — terminal, like a
    /// panic.
    Other,
}

impl FaultKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Io => "io",
            FaultKind::Numeric => "numeric",
            FaultKind::State => "state",
            FaultKind::Other => "other",
        }
    }
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Numeric-health failure raised by `Session::step` *before* the
/// optimizer update — so the session it comes from is still at its
/// last good state.
#[derive(Debug, Clone)]
pub struct NumericFault {
    /// Which quantity went non-finite (`"loss"`, `"metric"`,
    /// `"gradient norm"`).
    pub what: &'static str,
    /// The offending value.
    pub value: f64,
    /// The 0-based step that produced it.
    pub step: usize,
}

impl std::fmt::Display for NumericFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "non-finite {} ({}) at step {}",
            self.what, self.value, self.step
        )
    }
}

impl std::error::Error for NumericFault {}

/// A caught panic preserved as a typed error, so [`classify`] can tell
/// it from ordinary library errors after `catch_unwind`.
#[derive(Debug)]
pub struct PanicFault(pub String);

impl std::fmt::Display for PanicFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "panic: {}", self.0)
    }
}

impl std::error::Error for PanicFault {}

/// Best-effort extraction of a panic payload's message.
pub fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(m) = p.downcast_ref::<&str>() {
        (*m).to_string()
    } else if let Some(m) = p.downcast_ref::<String>() {
        m.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Classify an error by walking its source chain for the typed causes
/// the policy table keys on. Probe order is most-specific first:
/// a [`PanicFault`] or [`NumericFault`] wins over an incidental
/// `io::Error` deeper in the chain.
pub fn classify(e: &anyhow::Error) -> FaultKind {
    if e.downcast_ref::<PanicFault>().is_some() {
        FaultKind::Panic
    } else if e.downcast_ref::<NumericFault>().is_some() {
        FaultKind::Numeric
    } else if e.downcast_ref::<StateError>().is_some() {
        FaultKind::State
    } else if e.downcast_ref::<std::io::Error>().is_some() {
        FaultKind::Io
    } else {
        FaultKind::Other
    }
}

/// Run `f`, converting a panic into a typed [`PanicFault`] error
/// instead of unwinding through the fleet loop.
pub fn catch_fault<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(p) => Err(PanicFault(panic_message(p)).into()),
    }
}

/// One tenant step under supervision: panics become typed errors, and
/// fault points scoped `"<name>/<site>"` fire only for this tenant.
pub fn supervised_step(name: &str,
                       session: &mut Session<'_>) -> Result<StepOutcome> {
    catch_fault(|| faultpoint::with_scope(name, || session.step()))
}

/// Bounded backoff between I/O retry attempts (milliseconds, doubling,
/// capped — short enough for tests, long enough to skip a transient).
pub fn backoff(attempt: u32) {
    std::thread::sleep(Duration::from_millis(2u64 << attempt.min(5)));
}

/// Run `f` up to `attempts` times total, retrying (with [`backoff`])
/// only faults that classify as [`FaultKind::Io`]; every other error —
/// and the last I/O error — returns immediately.
pub fn with_io_retry<T>(attempts: u32,
                        mut f: impl FnMut() -> Result<T>) -> Result<T> {
    let attempts = attempts.max(1);
    let mut k = 0u32;
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) => {
                k += 1;
                if classify(&e) != FaultKind::Io || k >= attempts {
                    return Err(e);
                }
                backoff(k);
            }
        }
    }
}

/// Everything a quarantine records about one faulted tenant.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    /// Engine-visible session name.
    pub name: String,
    /// Preset the session trained (empty when the fault predates
    /// knowing it, e.g. an unreadable spool file).
    pub preset: String,
    /// What failed.
    pub kind: FaultKind,
    /// Steps the session had completed when it faulted.
    pub step: usize,
    /// I/O retries spent before giving up (0 for terminal kinds).
    pub retries: u32,
    /// Human-readable fault chain (the supervisor's evidence).
    pub detail: String,
    /// Where the last good state was quarantined, when it could be.
    pub state_path: Option<PathBuf>,
    /// Where the diagnostic report was written, when it could be.
    pub report_path: Option<PathBuf>,
}

/// `<dir>/<name>.state.quarantine` — the extension is `quarantine`,
/// deliberately *not* `state`, so external `*.state` globs cannot pick
/// up a quarantined file as resumable work.
pub fn quarantine_state_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.state.quarantine"))
}

/// `<dir>/<name>.quarantine.json`.
pub fn quarantine_report_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.quarantine.json"))
}

/// Whether a path is a quarantined statefile. Accepts both the current
/// `<name>.state.quarantine` suffix and the legacy
/// `<name>.quarantine.state` one (spool dirs written before the
/// rename), so old quarantines stay invisible to spool scans.
pub fn is_quarantine(path: &Path) -> bool {
    path.file_name()
        .map(|f| {
            let f = f.to_string_lossy();
            f.ends_with(".state.quarantine")
                || f.ends_with(".quarantine.state")
        })
        .unwrap_or(false)
}

/// Write the diagnostic report (`<name>.quarantine.json`) for a fault.
pub fn write_report(dir: &Path, rec: &FaultRecord) -> Result<PathBuf> {
    let p = quarantine_report_path(dir, &rec.name);
    let j = obj(vec![
        ("name", s(&rec.name)),
        ("preset", s(&rec.preset)),
        ("fault", s(rec.kind.as_str())),
        ("step", num(rec.step as f64)),
        ("retries", num(rec.retries as f64)),
        ("detail", s(&rec.detail)),
    ]);
    std::fs::write(&p, format!("{}\n", j.to_string()))
        .with_context(|| format!("writing quarantine report {p:?}"))?;
    Ok(p)
}

/// Quarantine an on-disk statefile: rename it to
/// `<name>.state.quarantine` and write the diagnostic report next to
/// it. Updates `rec` with both paths.
pub fn quarantine_file(path: &Path, rec: &mut FaultRecord) -> Result<()> {
    let dir = path.parent().unwrap_or(Path::new("."));
    let q = quarantine_state_path(dir, &rec.name);
    std::fs::rename(path, &q).with_context(|| {
        format!("quarantining statefile {path:?} -> {q:?}")
    })?;
    rec.state_path = Some(q);
    rec.report_path = Some(write_report(dir, rec)?);
    Ok(())
}

/// Result of a salvaging spool scan: the sessions worth resuming and
/// the files that were quarantined instead.
#[derive(Debug, Default)]
pub struct SpoolScan {
    /// Statefiles that parsed — resumable work.
    pub healthy: Vec<SessionHandle>,
    /// Files that failed to parse even after retries, now renamed to
    /// `<name>.state.quarantine` with a report beside them.
    pub quarantined: Vec<FaultRecord>,
}

/// Enumerate a spool directory's `*.state` files (skipping anything
/// already quarantined), retrying transient read faults up to
/// `max_retries` times. With `strict`, the first unreadable file fails
/// the scan (today's behavior); otherwise it is quarantined — renamed
/// plus a diagnostic report carrying the typed `StateError` (which
/// names the damaged section) — and the scan continues, so one corrupt
/// file no longer blocks every healthy session's warm restart.
pub fn scan_spool(dir: &Path, max_retries: u32,
                  strict: bool) -> Result<SpoolScan> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("scanning spool {dir:?}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.extension().map(|x| x == "state").unwrap_or(false)
                && !is_quarantine(p)
        })
        .collect();
    paths.sort();
    let mut scan = SpoolScan::default();
    for p in paths {
        if strict {
            scan.healthy.push(statefile::peek_session(&p)?);
            continue;
        }
        let attempt = with_io_retry(max_retries + 1, || {
            catch_fault(|| statefile::peek_session(&p))
        });
        match attempt {
            Ok(h) => scan.healthy.push(h),
            Err(e) => {
                let kind = classify(&e);
                let name = p
                    .file_stem()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "unknown".to_string());
                let mut rec = FaultRecord {
                    name,
                    preset: String::new(),
                    kind,
                    step: 0,
                    retries: if kind == FaultKind::Io {
                        max_retries
                    } else {
                        0
                    },
                    detail: format!("{e:?}"),
                    state_path: None,
                    report_path: None,
                };
                if let Err(e2) = quarantine_file(&p, &mut rec) {
                    rec.detail
                        .push_str(&format!("; quarantine failed: {e2}"));
                }
                scan.quarantined.push(rec);
            }
        }
    }
    Ok(scan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    fn io_err() -> anyhow::Error {
        std::io::Error::other("transient").into()
    }

    #[test]
    fn classify_probes_the_source_chain() {
        assert_eq!(classify(&io_err()), FaultKind::Io);
        assert_eq!(
            classify(&io_err().context("outer").context("outermost")),
            FaultKind::Io
        );
        assert_eq!(
            classify(
                &NumericFault { what: "loss", value: f64::NAN, step: 3 }
                    .into()
            ),
            FaultKind::Numeric
        );
        assert_eq!(
            classify(
                &StateError::MissingSection { section: "x".into() }
                    .into()
            ),
            FaultKind::State
        );
        assert_eq!(
            classify(&PanicFault("boom".into()).into()),
            FaultKind::Panic
        );
        assert_eq!(classify(&anyhow!("who knows")), FaultKind::Other);
    }

    #[test]
    fn catch_fault_types_the_panic() {
        let e = catch_fault::<()>(|| panic!("kaboom {}", 7)).unwrap_err();
        assert_eq!(classify(&e), FaultKind::Panic);
        assert!(e.to_string().contains("kaboom 7"));
        assert_eq!(catch_fault(|| Ok(5)).unwrap(), 5);
    }

    #[test]
    fn io_retry_is_bounded_and_io_only() {
        // two transient I/O failures, then success
        let mut calls = 0;
        let r: Result<u32> = with_io_retry(3, || {
            calls += 1;
            if calls < 3 { Err(io_err()) } else { Ok(calls) }
        });
        assert_eq!(r.unwrap(), 3);
        // exhaustion returns the last error
        let mut calls = 0;
        let r: Result<()> = with_io_retry(2, || {
            calls += 1;
            Err(io_err())
        });
        assert_eq!(classify(&r.unwrap_err()), FaultKind::Io);
        assert_eq!(calls, 2);
        // non-I/O faults are never retried
        let mut calls = 0;
        let r: Result<()> = with_io_retry(5, || {
            calls += 1;
            Err(anyhow!("terminal"))
        });
        assert!(r.is_err());
        assert_eq!(calls, 1);
    }

    #[test]
    fn quarantine_renames_and_reports() {
        let dir = std::env::temp_dir().join(format!(
            "ambp_supervisor_quarantine_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let victim = dir.join("s7.state");
        std::fs::write(&victim, b"not a statefile").unwrap();
        let mut rec = FaultRecord {
            name: "s7".into(),
            preset: "p".into(),
            kind: FaultKind::State,
            step: 4,
            retries: 0,
            detail: "statefile: bad magic".into(),
            state_path: None,
            report_path: None,
        };
        quarantine_file(&victim, &mut rec).unwrap();
        assert!(!victim.exists());
        let q = quarantine_state_path(&dir, "s7");
        assert_eq!(q, dir.join("s7.state.quarantine"));
        assert!(q.is_file());
        assert!(q.extension().map(|x| x != "state").unwrap_or(false),
                "a quarantine must not ride the .state extension");
        assert!(is_quarantine(&q));
        assert!(!is_quarantine(&victim));
        // the legacy suffix (pre-rename spool dirs) is still recognized
        assert!(is_quarantine(Path::new("/spool/s7.quarantine.state")));
        assert!(!is_quarantine(Path::new("/spool/s7.state")));
        let report = std::fs::read_to_string(
            quarantine_report_path(&dir, "s7"),
        )
        .unwrap();
        let j = crate::util::json::Json::parse(&report).unwrap();
        assert_eq!(j.get("fault").unwrap().as_str().unwrap(), "state");
        assert_eq!(j.get("step").unwrap().as_usize().unwrap(), 4);
        assert!(j
            .get("detail")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("bad magic"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
