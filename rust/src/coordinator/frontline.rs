//! Serving front line: a priority job queue driving the [`Engine`]
//! step loop under a memmodel-guided scheduling policy.
//!
//! Time is *virtual*: 1 tick = one engine round (every unfinished
//! resident session advances one optimizer step per tick). Each tick
//! runs four stages in a fixed order:
//!
//! 1. **arrivals** — trace jobs whose arrival tick has come are
//!    enqueued (jobs that cannot fit even an empty fleet are rejected
//!    outright);
//! 2. **retire** — finished sessions are evaluated and removed,
//!    freeing their optimizer/trainable/flat residency
//!    ([`Engine::retire_done`]);
//! 3. **admit** — the policy scans the queue and admits every job the
//!    memmodel prediction says fits the byte budget
//!    ([`Engine::admission_cost`]), *before any bytes are allocated*;
//! 4. **round** — one [`Engine::round_with`] sweep.
//!
//! Retiring *before* the round keeps an invariant the engine's
//! deadlock detector relies on: at round entry every resident slot is
//! unfinished, so a round that makes no progress while sessions sit in
//! the spool really is a dead end.
//!
//! Queue-wait (admit tick − arrival tick) and everything else derived
//! from virtual time is deterministic — a pure function of
//! (trace, budget, policy). Wall-clock step latency is measurement
//! only and excluded from the determinism contract.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::engine::{
    predict, Engine, EngineReport, SessionOutcome, StepEvent,
    StepEventKind,
};
use crate::coordinator::metrics::{
    FleetMetrics, Percentiles, SessionSummary,
};
use crate::coordinator::traffic::TrafficJob;
use crate::coordinator::trainer::TrainCfg;
use crate::runtime::Artifact;

/// Admission-ordering policy. All three fit-check against the same
/// memmodel prediction; they differ only in *which* queued jobs are
/// offered to the budget, and in what order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Strict FIFO by arrival: only the queue head is considered each
    /// tick, and a head that does not fit blocks everyone behind it
    /// (the pre-front-line `--jobs` admission order).
    RoundRobin,
    /// Scan the queue priority-descending (FIFO within a priority) and
    /// admit every job that fits.
    FirstFit,
    /// Pack the budget best, where "best" is measured in admitted
    /// jobs: repeatedly admit the *cheapest* predicted-cost fitting
    /// job (ascending-cost greedy is count-optimal for a single byte
    /// budget; ties broken priority-descending, then FIFO). Per tick
    /// this admits at least as many jobs as either other policy.
    BestFit,
}

impl Policy {
    pub fn parse(token: &str) -> Result<Policy> {
        match token {
            "rr" | "round-robin" => Ok(Policy::RoundRobin),
            "ff" | "first-fit" => Ok(Policy::FirstFit),
            "bf" | "best-fit" => Ok(Policy::BestFit),
            _ => Err(anyhow!(
                "unknown policy {token:?} (expected round-robin, \
                 first-fit or best-fit)"
            )),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::FirstFit => "first-fit",
            Policy::BestFit => "best-fit",
        }
    }
}

/// Front-line configuration.
#[derive(Debug, Clone)]
pub struct FrontCfg {
    pub policy: Policy,
    /// Fleet byte budget.
    pub budget: u64,
    /// Template `TrainCfg`; each job overrides `steps` and `seed` from
    /// its trace entry (and never writes per-session JSONL).
    pub base_cfg: TrainCfg,
    /// Tick horizon; 0 = run until the trace drains.
    pub max_ticks: u64,
    /// Spool directory (required for preemption).
    pub spool: Option<PathBuf>,
    /// Allow admissions to evict lower-priority sessions to the spool.
    pub preempt: bool,
    /// Cross-tenant fused execution ([`Engine::set_fuse`]): gang
    /// compatible sessions and run each gang through one physical pass
    /// per layer. Also makes [`Policy::BestFit`] prefer admitting jobs
    /// that join an already-resident gang.
    pub fuse: bool,
}

/// What a front-line run produced: the observability surface plus the
/// raw per-session engine reports (the bit-identity tests compare
/// these against serial twins).
pub struct FrontReport {
    pub metrics: FleetMetrics,
    pub reports: Vec<EngineReport>,
}

/// Per-job bookkeeping, indexed by trace position.
struct JobRec {
    job: TrafficJob,
    name: String,
    /// Memmodel-predicted marginal bytes (computed once, up front).
    marginal: u64,
    admit: Option<u64>,
    finish: Option<u64>,
    steps: usize,
    peak: u64,
    lat: Vec<f64>,
    outcome: &'static str,
}

fn job_cfg(base: &TrainCfg, job: &TrafficJob) -> TrainCfg {
    let mut c = base.clone();
    c.steps = job.steps;
    c.seed = job.seed;
    c.metrics_jsonl = None;
    c
}

/// Predicted cost of admitting `rec` right now, and whether it fits.
fn fit_now<'a>(engine: &Engine<'a>, art: &'a Artifact,
               c: &TrainCfg) -> (u64, bool) {
    let cost = engine.admission_cost(art, c);
    (cost, engine.predicted_bytes() + cost <= engine.budget())
}

/// Run `trace` through an engine under `cfg`, returning fleet metrics
/// and the per-session reports.
pub fn serve<'a>(arts: &'a BTreeMap<String, Artifact>,
                 trace: &[TrafficJob],
                 cfg: &FrontCfg) -> Result<FrontReport> {
    let mut engine: Engine<'a> = Engine::new(cfg.budget);
    if let Some(dir) = &cfg.spool {
        engine.set_spool(dir.clone());
    }
    if cfg.preempt {
        engine.enable_preempt()?;
    }
    engine.set_fuse(cfg.fuse);

    // --- per-job records, name → index map, preset validation -------
    let mut states: Vec<JobRec> = Vec::with_capacity(trace.len());
    let mut by_name: BTreeMap<String, usize> = BTreeMap::new();
    for (idx, job) in trace.iter().enumerate() {
        let art = arts.get(&job.preset).with_context(|| {
            format!("trace job {idx}: unknown preset {:?}", job.preset)
        })?;
        let marginal = predict(art, &job_cfg(&cfg.base_cfg, job)).marginal();
        let name = format!("j{idx}");
        by_name.insert(name.clone(), idx);
        states.push(JobRec {
            job: job.clone(),
            name,
            marginal,
            admit: None,
            finish: None,
            steps: 0,
            peak: 0,
            lat: Vec::new(),
            outcome: "queued",
        });
    }

    let mut pending: Vec<usize> = Vec::new();
    let mut reports: Vec<EngineReport> = Vec::new();
    let mut events: Vec<StepEvent> = Vec::new();
    let mut next = 0usize;
    let mut tick = 0u64;
    let mut preemptions = 0usize;
    let mut iters = 0u64;

    // attempt one admission; returns whether the job went in
    let try_admit = |engine: &mut Engine<'a>,
                     rec: &mut JobRec,
                     preemptions: &mut usize,
                     tick: u64| -> Result<bool> {
        let art = &arts[&rec.job.preset];
        let c = job_cfg(&cfg.base_cfg, &rec.job);
        let (_, fits) = fit_now(engine, art, &c);
        let admitted = if fits {
            engine.admit_prio(&rec.name, art, c, rec.job.priority)?;
            true
        } else if cfg.preempt {
            // over budget: the engine may evict lower-priority victims
            // — but never for a job whose eviction set cannot produce
            // a feasible fleet (a stranded victim would make the
            // engine's scheduling-deadlock bail inevitable). Such a
            // job stays queued and is retried on a later tick, once
            // retirements have shrunk the fleet.
            if engine.preempt_would_strand(art, &c, rec.job.priority) {
                false
            } else {
                // a rejection here is a no-fit, not an error
                let before = engine.suspended_names().len();
                match engine.admit_prio(&rec.name, art, c,
                                        rec.job.priority) {
                    Ok(()) => {
                        *preemptions +=
                            engine.suspended_names().len() - before;
                        true
                    }
                    Err(_) => false,
                }
            }
        } else {
            false
        };
        if admitted && rec.admit.is_none() {
            rec.admit = Some(tick);
        }
        Ok(admitted)
    };

    // one policy pass over the queue; returns admissions made
    let admit_phase = |engine: &mut Engine<'a>,
                       pending: &mut Vec<usize>,
                       states: &mut Vec<JobRec>,
                       preemptions: &mut usize,
                       tick: u64| -> Result<usize> {
        let mut admitted = 0usize;
        match cfg.policy {
            Policy::RoundRobin => {
                // head-of-line: stop at the first job that doesn't fit
                while let Some(&j) = pending.first() {
                    if !try_admit(engine, &mut states[j], preemptions,
                                  tick)? {
                        break;
                    }
                    pending.remove(0);
                    admitted += 1;
                }
            }
            Policy::FirstFit => {
                let mut order = pending.clone();
                order.sort_by_key(|&j| {
                    (-states[j].job.priority, states[j].job.arrival, j)
                });
                for j in order {
                    if try_admit(engine, &mut states[j], preemptions,
                                 tick)? {
                        pending.retain(|&p| p != j);
                        admitted += 1;
                    }
                }
            }
            Policy::BestFit => {
                loop {
                    // the fitting job with the smallest predicted cost
                    // (count-optimal greedy); under --fuse, jobs whose
                    // preset already has a resident session come first
                    // — completing an existing gang raises per-pass
                    // occupancy at the same byte cost; ties: cost asc,
                    // priority desc, arrival asc, index asc
                    let resident: std::collections::BTreeSet<String> =
                        if cfg.fuse {
                            states
                                .iter()
                                .filter(|r| engine.contains(&r.name))
                                .map(|r| r.job.preset.clone())
                                .collect()
                        } else {
                            Default::default()
                        };
                    let mut best: Option<(usize, u64, bool)> = None;
                    for &j in pending.iter() {
                        let art = &arts[&states[j].job.preset];
                        let c = job_cfg(&cfg.base_cfg, &states[j].job);
                        let (cost, fits) = fit_now(engine, art, &c);
                        if !fits {
                            continue;
                        }
                        let joins =
                            resident.contains(&states[j].job.preset);
                        let better = match best {
                            None => true,
                            Some((b, bcost, bjoins)) => {
                                (!joins, cost, -states[j].job.priority,
                                 states[j].job.arrival, j)
                                    < (!bjoins, bcost,
                                       -states[b].job.priority,
                                       states[b].job.arrival, b)
                            }
                        };
                        if better {
                            best = Some((j, cost, joins));
                        }
                    }
                    let picked = match best {
                        Some((j, _, _)) => {
                            // the plain fit check passed, so this must go in
                            let ok = try_admit(engine, &mut states[j],
                                               preemptions, tick)?;
                            debug_assert!(ok);
                            ok.then_some(j)
                        }
                        None if cfg.preempt => {
                            // nothing fits outright: offer the cheapest
                            // job first and let eviction decide
                            let mut order = pending.clone();
                            order.sort_by_key(|&j| {
                                let art =
                                    &arts[&states[j].job.preset];
                                let c = job_cfg(&cfg.base_cfg,
                                                &states[j].job);
                                (fit_now(engine, art, &c).0,
                                 -states[j].job.priority,
                                 states[j].job.arrival, j)
                            });
                            let mut hit = None;
                            for j in order {
                                if try_admit(engine, &mut states[j],
                                             preemptions, tick)? {
                                    hit = Some(j);
                                    break;
                                }
                            }
                            hit
                        }
                        None => None,
                    };
                    match picked {
                        Some(j) => {
                            pending.retain(|&p| p != j);
                            admitted += 1;
                        }
                        None => break,
                    }
                }
            }
        }
        Ok(admitted)
    };

    loop {
        iters += 1;
        if iters > 1_000_000 {
            bail!("front line exceeded its safety bound of 1M ticks");
        }

        // 1. arrivals — jobs too big for even an empty fleet are
        // rejected outright (the budget can never hold base + marginal)
        while next < states.len() && states[next].job.arrival <= tick {
            let art = &arts[&states[next].job.preset];
            let floor = art.frozen_base().nbytes() + states[next].marginal;
            if floor > cfg.budget {
                states[next].outcome = "rejected";
            } else {
                pending.push(next);
            }
            next += 1;
        }

        // 2. retire finished sessions
        for r in engine.retire_done()? {
            record_report(&mut states, &by_name, &mut reports, r, tick);
        }

        // 3. policy admissions
        admit_phase(&mut engine, &mut pending, &mut states,
                    &mut preemptions, tick)?;

        // wedge check: with nothing resident or suspended, the fleet
        // is bases-only — the smallest it will ever be again — so a
        // queued job that does not fit *now* never will
        if engine.is_empty()
            && engine.suspended_names().is_empty()
            && !pending.is_empty()
        {
            let before = pending.len();
            let mut keep = Vec::new();
            for &j in pending.iter() {
                let art = &arts[&states[j].job.preset];
                let c = job_cfg(&cfg.base_cfg, &states[j].job);
                if fit_now(&engine, art, &c).1 {
                    keep.push(j);
                } else {
                    states[j].outcome = "rejected";
                }
            }
            pending = keep;
            if pending.len() != before {
                admit_phase(&mut engine, &mut pending, &mut states,
                            &mut preemptions, tick)?;
            }
        }

        // drained?
        if next >= states.len()
            && pending.is_empty()
            && engine.is_empty()
            && engine.suspended_names().is_empty()
        {
            break;
        }

        // 4. one engine round
        if engine.has_unfinished() {
            engine.round_with(&mut events)?;
            for ev in events.drain(..) {
                let Some(&j) = by_name.get(&ev.name) else { continue };
                states[j].steps = ev.step;
                if ev.kind == StepEventKind::Stepped {
                    states[j].lat.push(ev.dur_s);
                }
            }
        }

        // horizon / advance
        if cfg.max_ticks > 0 && tick + 1 >= cfg.max_ticks {
            tick += 1;
            break;
        }
        if engine.is_empty()
            && engine.suspended_names().is_empty()
            && pending.is_empty()
            && next < states.len()
        {
            // idle: fast-forward virtual time to the next arrival
            tick = states[next].job.arrival;
        } else {
            tick += 1;
        }
    }

    // collect sessions that finished on the last round
    for r in engine.retire_done()? {
        record_report(&mut states, &by_name, &mut reports, r, tick);
    }

    // label what the horizon cut off
    for name in engine.suspended_names() {
        if let Some(&j) = by_name.get(&name) {
            states[j].outcome = "suspended";
        }
    }
    for rec in states.iter_mut() {
        if rec.outcome == "queued" && engine.contains(&rec.name) {
            rec.outcome = "running";
        }
    }

    // --- metrics assembly -------------------------------------------
    let queue_waits: Vec<f64> = states
        .iter()
        .filter_map(|r| {
            r.admit
                .map(|a| a.saturating_sub(r.job.arrival) as f64)
        })
        .collect();
    let all_lat: Vec<f64> =
        states.iter().flat_map(|r| r.lat.iter().copied()).collect();
    let sessions: Vec<SessionSummary> = states
        .iter()
        .map(|r| SessionSummary {
            name: r.name.clone(),
            preset: r.job.preset.clone(),
            priority: r.job.priority,
            arrival: r.job.arrival,
            admit: r.admit,
            finish: r.finish,
            steps: r.steps,
            predicted_marginal_bytes: r.marginal,
            peak_activation_bytes: r.peak,
            step_latency_s: Percentiles::from_samples(&r.lat),
            outcome: r.outcome.to_string(),
        })
        .collect();
    let metrics = FleetMetrics {
        policy: cfg.policy.as_str().to_string(),
        budget_bytes: cfg.budget,
        ticks: tick,
        horizon: cfg.max_ticks,
        submitted: states.len(),
        admitted: states.iter().filter(|r| r.admit.is_some()).count(),
        rejected: states
            .iter()
            .filter(|r| r.outcome == "rejected")
            .count(),
        completed: states
            .iter()
            .filter(|r| r.outcome == "completed")
            .count(),
        quarantined: states
            .iter()
            .filter(|r| r.outcome == "quarantined")
            .count(),
        preemptions,
        fused_passes: engine.fusion_stats().fused_passes,
        serial_passes: engine.fusion_stats().serial_passes,
        gang_occupancy: engine
            .fusion_stats()
            .occupancy
            .iter()
            .map(|(&n, &c)| (n, c))
            .collect(),
        queue_wait_ticks: Percentiles::from_samples(&queue_waits),
        step_latency_s: Percentiles::from_samples(&all_lat),
        sessions,
    };
    Ok(FrontReport { metrics, reports })
}

fn record_report(states: &mut [JobRec],
                 by_name: &BTreeMap<String, usize>,
                 reports: &mut Vec<EngineReport>,
                 r: EngineReport,
                 tick: u64) {
    if let Some(&j) = by_name.get(&r.name) {
        states[j].finish = Some(tick);
        match &r.outcome {
            SessionOutcome::Completed(tr) => {
                states[j].outcome = "completed";
                states[j].steps = tr.steps;
                states[j].peak = tr.peak_activation_bytes;
            }
            SessionOutcome::Quarantined(_) => {
                states[j].outcome = "quarantined";
            }
        }
    }
    reports.push(r);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("rr").unwrap(), Policy::RoundRobin);
        assert_eq!(Policy::parse("round-robin").unwrap(),
                   Policy::RoundRobin);
        assert_eq!(Policy::parse("first-fit").unwrap(),
                   Policy::FirstFit);
        assert_eq!(Policy::parse("bf").unwrap(), Policy::BestFit);
        assert!(Policy::parse("lifo").is_err());
        assert_eq!(Policy::BestFit.as_str(), "best-fit");
    }
}
