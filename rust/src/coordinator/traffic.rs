//! Deterministic synthetic traffic: seeded bursty job arrivals over the
//! existing synth data, replayable as JSONL trace files.
//!
//! A trace is a list of [`TrafficJob`]s sorted by arrival tick. The
//! generator is a pure function of [`TrafficCfg`] — two runs with the
//! same config produce byte-identical traces, and the RNG consumption
//! per job is independent of the preset *names*, so two configs that
//! differ only in their preset lists (same list length) produce traces
//! with identical arrivals/steps/seeds/priorities and presets swapped
//! position-for-position. `bench-fleet` leans on that to compare
//! baseline vs ours/mesa preset groups under the same traffic shape.

use std::fs;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;

/// One job in a traffic trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficJob {
    /// Virtual arrival tick (1 tick = one engine round).
    pub arrival: u64,
    /// Preset name to train.
    pub preset: String,
    /// Optimizer steps the job requests.
    pub steps: usize,
    /// Data/init seed for the job's `TrainCfg`.
    pub seed: u64,
    /// Scheduling priority (higher runs first among fitting jobs).
    pub priority: i64,
}

/// Generator knobs. All sampling is driven by `seed` alone.
#[derive(Debug, Clone)]
pub struct TrafficCfg {
    /// RNG seed for the whole trace.
    pub seed: u64,
    /// Total jobs to emit.
    pub jobs: usize,
    /// Presets to sample uniformly per job.
    pub presets: Vec<String>,
    /// Mean gap (ticks) between bursts; actual gap is 1..=2*gap.
    pub burst_gap: u64,
    /// Max jobs per burst (burst size is 1..=burst_max).
    pub burst_max: usize,
    /// Per-job step count range (inclusive).
    pub steps_min: usize,
    pub steps_max: usize,
    /// Priorities are sampled uniformly from 0..=max_priority.
    pub max_priority: i64,
}

impl Default for TrafficCfg {
    fn default() -> TrafficCfg {
        TrafficCfg {
            seed: 7,
            jobs: 12,
            presets: Vec::new(),
            burst_gap: 3,
            burst_max: 3,
            steps_min: 2,
            steps_max: 5,
            max_priority: 2,
        }
    }
}

/// Generate a bursty arrival trace. Jobs arrive in bursts of
/// `1..=burst_max` sharing one arrival tick, with `1..=2*burst_gap`
/// ticks between bursts.
pub fn generate(cfg: &TrafficCfg) -> Result<Vec<TrafficJob>> {
    if cfg.presets.is_empty() {
        return Err(anyhow!("traffic: preset list is empty"));
    }
    if cfg.steps_min == 0 || cfg.steps_max < cfg.steps_min {
        return Err(anyhow!(
            "traffic: bad step range {}..={}",
            cfg.steps_min,
            cfg.steps_max
        ));
    }
    let mut rng = Rng::new(cfg.seed);
    let mut out = Vec::with_capacity(cfg.jobs);
    let mut tick = 0u64;
    while out.len() < cfg.jobs {
        let burst = 1 + rng.below(cfg.burst_max as u64) as usize;
        for _ in 0..burst {
            if out.len() == cfg.jobs {
                break;
            }
            let preset =
                cfg.presets[rng.below(cfg.presets.len() as u64) as usize].clone();
            let span = (cfg.steps_max - cfg.steps_min + 1) as u64;
            let steps = cfg.steps_min + rng.below(span) as usize;
            // Seeds stay small: the JSON trace stores numbers as f64,
            // which is exact only below 2^53.
            let seed = rng.below(1_000_000);
            let priority = rng.below(cfg.max_priority as u64 + 1) as i64;
            out.push(TrafficJob { arrival: tick, preset, steps, seed, priority });
        }
        tick += 1 + rng.below(cfg.burst_gap * 2);
    }
    Ok(out)
}

fn job_json(j: &TrafficJob) -> Json {
    obj(vec![
        ("arrival", num(j.arrival as f64)),
        ("preset", s(&j.preset)),
        ("steps", num(j.steps as f64)),
        ("seed", num(j.seed as f64)),
        ("prio", num(j.priority as f64)),
    ])
}

/// Write a trace as JSON lines (one job object per line).
pub fn save_trace(path: &Path, jobs: &[TrafficJob]) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut buf = String::new();
    for j in jobs {
        buf.push_str(&job_json(j).to_string());
        buf.push('\n');
    }
    fs::write(path, buf).with_context(|| format!("writing trace {path:?}"))?;
    Ok(())
}

/// Load a JSONL trace written by [`save_trace`] (or by hand).
pub fn load_trace(path: &Path) -> Result<Vec<TrafficJob>> {
    let text =
        fs::read_to_string(path).with_context(|| format!("reading trace {path:?}"))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("trace {path:?} line {}", lineno + 1))?;
        let field = |k: &str| -> Result<&Json> {
            j.get(k)
                .ok_or_else(|| anyhow!("trace {path:?} line {}: missing {k:?}", lineno + 1))
        };
        out.push(TrafficJob {
            arrival: field("arrival")?.as_usize().ok_or_else(|| {
                anyhow!("trace line {}: arrival not a number", lineno + 1)
            })? as u64,
            preset: field("preset")?
                .as_str()
                .ok_or_else(|| anyhow!("trace line {}: preset not a string", lineno + 1))?
                .to_string(),
            steps: field("steps")?
                .as_usize()
                .ok_or_else(|| anyhow!("trace line {}: steps not a number", lineno + 1))?,
            seed: field("seed")?.as_usize().ok_or_else(|| {
                anyhow!("trace line {}: seed not a number", lineno + 1)
            })? as u64,
            priority: field("prio")?.as_usize().ok_or_else(|| {
                anyhow!("trace line {}: prio not a number", lineno + 1)
            })? as i64,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrafficCfg {
        TrafficCfg {
            seed: 42,
            jobs: 10,
            presets: vec!["a".into(), "b".into()],
            ..TrafficCfg::default()
        }
    }

    #[test]
    fn deterministic_and_sorted() {
        let t1 = generate(&cfg()).unwrap();
        let t2 = generate(&cfg()).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(t1.len(), 10);
        assert!(t1.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        for j in &t1 {
            assert!(j.steps >= 2 && j.steps <= 5);
            assert!(j.priority >= 0 && j.priority <= 2);
            assert!(j.seed < 1_000_000);
        }
    }

    #[test]
    fn preset_swap_keeps_shape() {
        let base = generate(&cfg()).unwrap();
        let mut swapped_cfg = cfg();
        swapped_cfg.presets = vec!["x".into(), "y".into()];
        let swapped = generate(&swapped_cfg).unwrap();
        for (a, b) in base.iter().zip(&swapped) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.steps, b.steps);
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.priority, b.priority);
            // presets swapped position-for-position
            let want = if a.preset == "a" { "x" } else { "y" };
            assert_eq!(b.preset, want);
        }
    }

    #[test]
    fn trace_roundtrip() {
        let jobs = generate(&cfg()).unwrap();
        let dir = std::env::temp_dir().join(format!(
            "ambp_trace_{}_{}",
            std::process::id(),
            "roundtrip"
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        save_trace(&path, &jobs).unwrap();
        let back = load_trace(&path).unwrap();
        assert_eq!(jobs, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_cfg() {
        let mut c = cfg();
        c.presets.clear();
        assert!(generate(&c).is_err());
        let mut c = cfg();
        c.steps_min = 4;
        c.steps_max = 3;
        assert!(generate(&c).is_err());
    }
}
