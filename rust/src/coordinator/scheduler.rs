//! Learning-rate schedules (Appendix H: warmup + cosine for ViT,
//! constant for QLoRA-LLaMA, linear-with-warmup for RoBERTa).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    Constant,
    /// Linear warmup from `warmup_init` over `warmup` steps, then cosine
    /// decay to ~0 over the remaining steps.
    WarmupCosine { warmup: usize, warmup_init: f32 },
    /// Linear warmup then linear decay to 0.
    WarmupLinear { warmup_frac: f32 },
}

impl Schedule {
    pub fn lr(&self, base: f32, step: usize, total: usize) -> f32 {
        match *self {
            Schedule::Constant => base,
            Schedule::WarmupCosine { warmup, warmup_init } => {
                if step < warmup {
                    let t = step as f32 / warmup.max(1) as f32;
                    warmup_init + t * (base - warmup_init)
                } else {
                    let t = (step - warmup) as f32
                        / (total.saturating_sub(warmup)).max(1) as f32;
                    base * 0.5
                        * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos())
                }
            }
            Schedule::WarmupLinear { warmup_frac } => {
                let warmup =
                    ((total as f32) * warmup_frac).round() as usize;
                if step < warmup {
                    base * (step as f32 + 1.0) / warmup.max(1) as f32
                } else {
                    let t = (step - warmup) as f32
                        / (total.saturating_sub(warmup)).max(1) as f32;
                    base * (1.0 - t.min(1.0))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant;
        assert_eq!(s.lr(0.1, 0, 100), 0.1);
        assert_eq!(s.lr(0.1, 99, 100), 0.1);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = Schedule::WarmupCosine { warmup: 10, warmup_init: 1e-6 };
        assert!(s.lr(1.0, 0, 100) < 0.2);
        assert!((s.lr(1.0, 10, 100) - 1.0).abs() < 1e-5);
        assert!(s.lr(1.0, 55, 100) < 1.0);
        assert!(s.lr(1.0, 99, 100) < 0.01);
        // monotone increase during warmup
        for i in 0..9 {
            assert!(s.lr(1.0, i, 100) <= s.lr(1.0, i + 1, 100));
        }
    }

    #[test]
    fn warmup_linear_shape() {
        let s = Schedule::WarmupLinear { warmup_frac: 0.1 };
        assert!(s.lr(1.0, 0, 100) <= 0.1 + 1e-6);
        assert!((s.lr(1.0, 10, 100) - 1.0).abs() < 0.11);
        assert!(s.lr(1.0, 99, 100) < 0.02);
    }

    #[test]
    fn warmup_handoff_is_continuous() {
        // step == warmup switches branches; the two formulas must meet
        // at base without a jump (warmup end feeds cos(0) / t = 0)
        let c = Schedule::WarmupCosine { warmup: 10, warmup_init: 1e-6 };
        assert!((c.lr(1.0, 10, 100) - 1.0).abs() < 1e-6);
        let before = c.lr(1.0, 9, 100);
        let after = c.lr(1.0, 10, 100);
        assert!((after - before).abs() < 0.2, "{before} vs {after}");
        let l = Schedule::WarmupLinear { warmup_frac: 0.1 };
        // linear warmup hits base on its *last* warmup step (step+1
        // numerator), and the decay branch starts back at base
        assert!((l.lr(1.0, 9, 100) - 1.0).abs() < 1e-6);
        assert!((l.lr(1.0, 10, 100) - 1.0).abs() < 1e-6);
        assert_eq!(Schedule::Constant.lr(1.0, 10, 100), 1.0);
    }

    #[test]
    fn zero_warmup_starts_at_base() {
        // warmup = 0: no warmup branch is ever taken; decay starts
        // immediately from base and the max(1) guards avoid 0/0
        let c = Schedule::WarmupCosine { warmup: 0, warmup_init: 0.5 };
        assert!((c.lr(1.0, 0, 100) - 1.0).abs() < 1e-6);
        assert!(c.lr(1.0, 1, 100) < 1.0);
        let l = Schedule::WarmupLinear { warmup_frac: 0.0 };
        assert!((l.lr(1.0, 0, 100) - 1.0).abs() < 1e-6);
        assert!(l.lr(1.0, 50, 100) < 0.51);
        assert_eq!(Schedule::Constant.lr(1.0, 0, 100), 1.0);
    }

    #[test]
    fn total_shorter_than_warmup_stays_finite() {
        // total < warmup: the decay branch's saturating_sub would be 0
        // without the max(1) guard; every step must stay a finite
        // warmup-ramp value below (or at) base
        let c = Schedule::WarmupCosine { warmup: 50, warmup_init: 0.0 };
        for step in 0..60 {
            let lr = c.lr(1.0, step, 10);
            assert!(lr.is_finite() && (0.0..=1.0).contains(&lr),
                    "cosine step {step}: {lr}");
        }
        let l = Schedule::WarmupLinear { warmup_frac: 1.0 };
        for step in 0..20 {
            let lr = l.lr(1.0, step, 10);
            assert!(lr.is_finite() && (0.0..=1.0).contains(&lr),
                    "linear step {step}: {lr}");
        }
        assert_eq!(Schedule::Constant.lr(1.0, 20, 10), 1.0);
    }

    #[test]
    fn final_step_decays_to_zero() {
        let c = Schedule::WarmupCosine { warmup: 10, warmup_init: 0.0 };
        // cos(pi * (total-warmup-ish)/(total-warmup)) → lr ≈ 0 at the
        // last step, exactly 0 past total
        assert!(c.lr(1.0, 99, 100) < 5e-3);
        assert!(c.lr(1.0, 100, 100) < 1e-7);
        assert!(c.lr(1.0, 250, 100) < 1e-7);
        let l = Schedule::WarmupLinear { warmup_frac: 0.1 };
        assert!(l.lr(1.0, 99, 100) < 0.02);
        assert_eq!(l.lr(1.0, 100, 100), 0.0);
        assert_eq!(l.lr(1.0, 250, 100), 0.0);
        // constant never decays — its "final step" is still base
        assert_eq!(Schedule::Constant.lr(1.0, 100, 100), 1.0);
    }

    #[test]
    fn never_negative_or_nan() {
        for s in [
            Schedule::Constant,
            Schedule::WarmupCosine { warmup: 5, warmup_init: 0.0 },
            Schedule::WarmupLinear { warmup_frac: 0.05 },
        ] {
            for step in 0..120 {
                let lr = s.lr(0.3, step, 100);
                assert!(lr.is_finite() && lr >= 0.0, "{s:?} {step} {lr}");
            }
        }
    }
}
