//! Learning-rate schedules (Appendix H: warmup + cosine for ViT,
//! constant for QLoRA-LLaMA, linear-with-warmup for RoBERTa).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    Constant,
    /// Linear warmup from `warmup_init` over `warmup` steps, then cosine
    /// decay to ~0 over the remaining steps.
    WarmupCosine { warmup: usize, warmup_init: f32 },
    /// Linear warmup then linear decay to 0.
    WarmupLinear { warmup_frac: f32 },
}

impl Schedule {
    pub fn lr(&self, base: f32, step: usize, total: usize) -> f32 {
        match *self {
            Schedule::Constant => base,
            Schedule::WarmupCosine { warmup, warmup_init } => {
                if step < warmup {
                    let t = step as f32 / warmup.max(1) as f32;
                    warmup_init + t * (base - warmup_init)
                } else {
                    let t = (step - warmup) as f32
                        / (total.saturating_sub(warmup)).max(1) as f32;
                    base * 0.5
                        * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos())
                }
            }
            Schedule::WarmupLinear { warmup_frac } => {
                let warmup =
                    ((total as f32) * warmup_frac).round() as usize;
                if step < warmup {
                    base * (step as f32 + 1.0) / warmup.max(1) as f32
                } else {
                    let t = (step - warmup) as f32
                        / (total.saturating_sub(warmup)).max(1) as f32;
                    base * (1.0 - t.min(1.0))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant;
        assert_eq!(s.lr(0.1, 0, 100), 0.1);
        assert_eq!(s.lr(0.1, 99, 100), 0.1);
    }

    #[test]
    fn warmup_cosine_shape() {
        let s = Schedule::WarmupCosine { warmup: 10, warmup_init: 1e-6 };
        assert!(s.lr(1.0, 0, 100) < 0.2);
        assert!((s.lr(1.0, 10, 100) - 1.0).abs() < 1e-5);
        assert!(s.lr(1.0, 55, 100) < 1.0);
        assert!(s.lr(1.0, 99, 100) < 0.01);
        // monotone increase during warmup
        for i in 0..9 {
            assert!(s.lr(1.0, i, 100) <= s.lr(1.0, i + 1, 100));
        }
    }

    #[test]
    fn warmup_linear_shape() {
        let s = Schedule::WarmupLinear { warmup_frac: 0.1 };
        assert!(s.lr(1.0, 0, 100) <= 0.1 + 1e-6);
        assert!((s.lr(1.0, 10, 100) - 1.0).abs() < 0.11);
        assert!(s.lr(1.0, 99, 100) < 0.02);
    }

    #[test]
    fn never_negative_or_nan() {
        for s in [
            Schedule::Constant,
            Schedule::WarmupCosine { warmup: 5, warmup_init: 0.0 },
            Schedule::WarmupLinear { warmup_frac: 0.05 },
        ] {
            for step in 0..120 {
                let lr = s.lr(0.3, step, 100);
                assert!(lr.is_finite() && lr >= 0.0, "{s:?} {step} {lr}");
            }
        }
    }
}
