//! Metrics sink: loss curves, throughput, memory — console + JSONL.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::{num, obj, s, Json};

pub struct Metrics {
    writer: Option<BufWriter<File>>,
    start: Instant,
    pub rows: Vec<StepRow>,
    samples_done: u64,
}

#[derive(Debug, Clone)]
pub struct StepRow {
    pub step: usize,
    pub loss: f32,
    pub metric: f32,
    pub lr: f32,
    pub activation_bytes: u64,
    pub elapsed_s: f64,
}

impl Metrics {
    pub fn new(jsonl_path: Option<&Path>) -> Result<Metrics> {
        let writer = match jsonl_path {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    std::fs::create_dir_all(parent)?;
                }
                Some(BufWriter::new(File::create(p)?))
            }
            None => None,
        };
        Ok(Metrics {
            writer,
            start: Instant::now(),
            rows: Vec::new(),
            samples_done: 0,
        })
    }

    fn row_json(row: &StepRow) -> Json {
        obj(vec![
            ("step", num(row.step as f64)),
            ("loss", num(row.loss as f64)),
            ("metric", num(row.metric as f64)),
            ("lr", num(row.lr as f64)),
            ("act_bytes", num(row.activation_bytes as f64)),
            ("t", num(row.elapsed_s)),
        ])
    }

    pub fn log_step(&mut self, row: StepRow, batch: usize) -> Result<()> {
        self.samples_done += batch as u64;
        if let Some(w) = &mut self.writer {
            writeln!(w, "{}", Metrics::row_json(&row).to_string())?;
        }
        self.rows.push(row);
        Ok(())
    }

    /// Re-seed the sink from a resumed session's saved state: the
    /// loss-curve rows and the sample counter continue from where the
    /// suspended run left off. The restored rows are re-written into
    /// the JSONL sink (which `Metrics::new` freshly truncated), so a
    /// resumed run's on-disk metric history stays complete — replayed
    /// steps appear exactly once, with their originally-logged values.
    /// Wall-clock state is deliberately *not* restored — `elapsed_s` /
    /// `throughput` measure this process. See KNOWN.md.
    pub fn restore(&mut self, rows: Vec<StepRow>,
                   samples_done: u64) -> Result<()> {
        if let Some(w) = &mut self.writer {
            for row in &rows {
                writeln!(w, "{}", Metrics::row_json(row).to_string())?;
            }
        }
        self.rows = rows;
        self.samples_done = samples_done;
        Ok(())
    }

    /// Samples per second since construction.
    pub fn throughput(&self) -> f64 {
        self.samples_done as f64 / self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn mean_recent_loss(&self, window: usize) -> f32 {
        let n = self.rows.len();
        if n == 0 {
            return f32::NAN;
        }
        let lo = n.saturating_sub(window);
        let slice = &self.rows[lo..];
        slice.iter().map(|r| r.loss).sum::<f32>() / slice.len() as f32
    }

    pub fn mean_recent_metric(&self, window: usize) -> f32 {
        let n = self.rows.len();
        if n == 0 {
            return f32::NAN;
        }
        let lo = n.saturating_sub(window);
        let slice = &self.rows[lo..];
        slice.iter().map(|r| r.metric).sum::<f32>() / slice.len() as f32
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(w) = &mut self.writer {
            w.flush()?;
        }
        Ok(())
    }

    /// Serialize the final summary as JSON (for EXPERIMENTS.md capture).
    pub fn summary(&self, label: &str, peak_act_bytes: u64) -> Json {
        obj(vec![
            ("label", s(label)),
            ("steps", num(self.rows.len() as f64)),
            ("final_loss", num(self.mean_recent_loss(20) as f64)),
            ("final_metric", num(self.mean_recent_metric(20) as f64)),
            ("throughput_samples_per_s", num(self.throughput())),
            ("peak_activation_bytes", num(peak_act_bytes as f64)),
        ])
    }
}

/// Nearest-rank percentile over an *unsorted* sample set (the input is
/// copied and sorted). Returns 0.0 on an empty set so the JSON surface
/// stays numeric.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * xs.len() as f64).ceil() as usize;
    xs[rank.clamp(1, xs.len()) - 1]
}

/// p50/p90/p99 triple — the percentile surface both the per-session
/// step-latency and the fleet queue-wait aggregations report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Percentiles {
    pub fn from_samples(samples: &[f64]) -> Percentiles {
        Percentiles {
            p50: percentile(samples, 50.0),
            p90: percentile(samples, 90.0),
            p99: percentile(samples, 99.0),
        }
    }

    pub fn json(&self) -> Json {
        obj(vec![
            ("p50", num(self.p50)),
            ("p90", num(self.p90)),
            ("p99", num(self.p99)),
        ])
    }
}

/// Per-session serving metrics the front line aggregates: virtual-time
/// queue accounting (deterministic) plus wall-clock step latency
/// (measurement only — excluded from the determinism contract).
#[derive(Debug, Clone)]
pub struct SessionSummary {
    /// Session name (`j<idx>` in trace order).
    pub name: String,
    /// Preset the job trains.
    pub preset: String,
    /// Scheduling priority.
    pub priority: i64,
    /// Arrival tick from the trace.
    pub arrival: u64,
    /// Tick the job was admitted (None: still queued or rejected).
    pub admit: Option<u64>,
    /// Tick the job's report was retired (None: not finished).
    pub finish: Option<u64>,
    /// Optimizer steps completed.
    pub steps: usize,
    /// Memmodel-predicted marginal bytes admission gated on.
    pub predicted_marginal_bytes: u64,
    /// Measured peak activation bytes (0 until completed).
    pub peak_activation_bytes: u64,
    /// Wall-clock per-step latency percentiles (seconds).
    pub step_latency_s: Percentiles,
    /// `completed | quarantined | running | queued | rejected`.
    pub outcome: String,
}

impl SessionSummary {
    /// Queue wait in ticks (admit − arrival), when admitted.
    pub fn queue_wait(&self) -> Option<u64> {
        self.admit.map(|a| a.saturating_sub(self.arrival))
    }

    pub fn json(&self) -> Json {
        let opt = |v: Option<u64>| match v {
            Some(x) => num(x as f64),
            None => Json::Null,
        };
        obj(vec![
            ("name", s(&self.name)),
            ("preset", s(&self.preset)),
            ("priority", num(self.priority as f64)),
            ("arrival", num(self.arrival as f64)),
            ("admit", opt(self.admit)),
            ("finish", opt(self.finish)),
            ("queue_wait_ticks", opt(self.queue_wait())),
            ("steps", num(self.steps as f64)),
            ("predicted_marginal_bytes",
             num(self.predicted_marginal_bytes as f64)),
            ("peak_activation_bytes",
             num(self.peak_activation_bytes as f64)),
            ("step_latency_s", self.step_latency_s.json()),
            ("outcome", s(&self.outcome)),
        ])
    }
}

/// Fleet-level serving metrics for one front-line run — the JSON
/// surface `ambp bench-fleet` emits next to the `BENCH_*.json` files.
/// Every field except the two wall-clock latency blocks is a pure
/// function of (trace, budget, policy), i.e. deterministic across
/// thread counts and machines.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    /// Scheduling policy that produced this run.
    pub policy: String,
    /// Byte budget the fleet was packed against.
    pub budget_bytes: u64,
    /// Virtual ticks the run consumed (1 tick = one engine round).
    pub ticks: u64,
    /// Tick horizon the run was capped at (0 = ran to completion).
    pub horizon: u64,
    /// Jobs in the trace.
    pub submitted: usize,
    /// Jobs admitted at least once.
    pub admitted: usize,
    /// Jobs that can never fit the budget (rejected at enqueue).
    pub rejected: usize,
    /// Jobs that completed and were retired.
    pub completed: usize,
    /// Jobs the supervisor quarantined.
    pub quarantined: usize,
    /// Preemptions (sessions evicted to the spool by admission).
    pub preemptions: usize,
    /// Physical fwd+bwd sweeps that served a whole gang at once
    /// (0 unless the engine ran with fusion enabled).
    pub fused_passes: u64,
    /// Physical fwd+bwd sweeps that served a single session.
    pub serial_passes: u64,
    /// Fused-pass count per gang occupancy, ascending occupancy — e.g.
    /// `[(4, 120)]` = 120 fused passes each serving 4 sessions.
    pub gang_occupancy: Vec<(usize, u64)>,
    /// Queue-wait percentiles over admitted jobs, in ticks.
    pub queue_wait_ticks: Percentiles,
    /// Fleet-wide wall-clock step-latency percentiles (seconds).
    pub step_latency_s: Percentiles,
    /// Per-session breakdown, in trace order.
    pub sessions: Vec<SessionSummary>,
}

impl FleetMetrics {
    /// Completed jobs per virtual tick — the packing-quality number
    /// the policy/preset comparisons rank on.
    pub fn throughput_jobs_per_tick(&self) -> f64 {
        if self.ticks == 0 {
            0.0
        } else {
            self.completed as f64 / self.ticks as f64
        }
    }

    pub fn json(&self) -> Json {
        obj(vec![
            ("policy", s(&self.policy)),
            ("budget_bytes", num(self.budget_bytes as f64)),
            ("ticks", num(self.ticks as f64)),
            ("horizon", num(self.horizon as f64)),
            ("submitted", num(self.submitted as f64)),
            ("admitted", num(self.admitted as f64)),
            ("rejected", num(self.rejected as f64)),
            ("completed", num(self.completed as f64)),
            ("quarantined", num(self.quarantined as f64)),
            ("preemptions", num(self.preemptions as f64)),
            ("fused_passes", num(self.fused_passes as f64)),
            ("serial_passes", num(self.serial_passes as f64)),
            ("gang_occupancy",
             Json::Obj(
                 self.gang_occupancy
                     .iter()
                     .map(|&(n, c)| (n.to_string(), num(c as f64)))
                     .collect(),
             )),
            ("throughput_jobs_per_tick",
             num(self.throughput_jobs_per_tick())),
            ("queue_wait_ticks", self.queue_wait_ticks.json()),
            ("step_latency_s", self.step_latency_s.json()),
            ("sessions",
             Json::Arr(self.sessions.iter().map(|x| x.json()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 90.0), 90.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        // unsorted input is handled (the helper sorts a copy)
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), 2.0);
    }

    #[test]
    fn fleet_metrics_json_shape() {
        let sess = SessionSummary {
            name: "j0".into(),
            preset: "p".into(),
            priority: 1,
            arrival: 2,
            admit: Some(5),
            finish: Some(9),
            steps: 3,
            predicted_marginal_bytes: 1024,
            peak_activation_bytes: 2048,
            step_latency_s: Percentiles::from_samples(&[0.1, 0.2]),
            outcome: "completed".into(),
        };
        assert_eq!(sess.queue_wait(), Some(3));
        let fleet = FleetMetrics {
            policy: "best-fit".into(),
            budget_bytes: 1 << 20,
            ticks: 10,
            horizon: 0,
            submitted: 1,
            admitted: 1,
            rejected: 0,
            completed: 1,
            quarantined: 0,
            preemptions: 0,
            fused_passes: 120,
            serial_passes: 3,
            gang_occupancy: vec![(2, 20), (4, 100)],
            queue_wait_ticks: Percentiles::from_samples(&[3.0]),
            step_latency_s: Percentiles::from_samples(&[0.1, 0.2]),
            sessions: vec![sess],
        };
        let j = Json::parse(&fleet.json().to_string()).unwrap();
        assert_eq!(j.get("policy").unwrap().as_str().unwrap(),
                   "best-fit");
        assert_eq!(j.get("fused_passes").unwrap().as_usize().unwrap(),
                   120);
        assert_eq!(j.get("serial_passes").unwrap().as_usize().unwrap(),
                   3);
        let occ = j.get("gang_occupancy").unwrap();
        assert_eq!(occ.get("4").unwrap().as_usize().unwrap(), 100);
        assert_eq!(occ.get("2").unwrap().as_usize().unwrap(), 20);
        assert_eq!(j.get("admitted").unwrap().as_usize().unwrap(), 1);
        let qs = j.get("queue_wait_ticks").unwrap();
        assert_eq!(qs.get("p50").unwrap().as_f64().unwrap(), 3.0);
        let sessions = j.get("sessions").unwrap().as_arr().unwrap();
        assert_eq!(sessions.len(), 1);
        assert_eq!(sessions[0].get("queue_wait_ticks").unwrap()
                       .as_usize().unwrap(),
                   3);
        assert!((fleet.throughput_jobs_per_tick() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn rows_and_means() {
        let mut m = Metrics::new(None).unwrap();
        for i in 0..10 {
            m.log_step(
                StepRow {
                    step: i,
                    loss: 10.0 - i as f32,
                    metric: i as f32 / 10.0,
                    lr: 0.1,
                    activation_bytes: 1000,
                    elapsed_s: 0.0,
                },
                4,
            )
            .unwrap();
        }
        assert_eq!(m.rows.len(), 10);
        assert!((m.mean_recent_loss(2) - 1.5).abs() < 1e-6);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn jsonl_written() {
        let dir = std::env::temp_dir().join("ambp_metrics_test");
        let path = dir.join("m.jsonl");
        let mut m = Metrics::new(Some(&path)).unwrap();
        m.log_step(
            StepRow {
                step: 0,
                loss: 1.0,
                metric: 0.5,
                lr: 0.01,
                activation_bytes: 7,
                elapsed_s: 0.1,
            },
            1,
        )
        .unwrap();
        m.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("loss").unwrap().as_f64().unwrap(), 1.0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn restore_rewrites_history_into_a_fresh_sink() {
        let dir = std::env::temp_dir().join(format!(
            "ambp_metrics_restore_test_{}",
            std::process::id()
        ));
        let path = dir.join("m.jsonl");
        let row = |step: usize| StepRow {
            step,
            loss: step as f32,
            metric: 0.0,
            lr: 0.1,
            activation_bytes: 1,
            elapsed_s: 0.0,
        };
        // a fresh sink truncates; restore must re-write the saved rows
        // so the resumed file still carries the full history
        let mut m = Metrics::new(Some(&path)).unwrap();
        m.restore(vec![row(0), row(1)], 8).unwrap();
        m.log_step(row(2), 4).unwrap();
        m.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let steps: Vec<usize> = text
            .lines()
            .map(|l| {
                Json::parse(l).unwrap().get("step").unwrap()
                    .as_usize().unwrap()
            })
            .collect();
        assert_eq!(steps, vec![0, 1, 2]);
        assert_eq!(m.rows.len(), 3);
        let _ = std::fs::remove_dir_all(dir);
    }
}
