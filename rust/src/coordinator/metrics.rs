//! Metrics sink: loss curves, throughput, memory — console + JSONL.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::util::json::{num, obj, s, Json};

pub struct Metrics {
    writer: Option<BufWriter<File>>,
    start: Instant,
    pub rows: Vec<StepRow>,
    samples_done: u64,
}

#[derive(Debug, Clone)]
pub struct StepRow {
    pub step: usize,
    pub loss: f32,
    pub metric: f32,
    pub lr: f32,
    pub activation_bytes: u64,
    pub elapsed_s: f64,
}

impl Metrics {
    pub fn new(jsonl_path: Option<&Path>) -> Result<Metrics> {
        let writer = match jsonl_path {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    std::fs::create_dir_all(parent)?;
                }
                Some(BufWriter::new(File::create(p)?))
            }
            None => None,
        };
        Ok(Metrics {
            writer,
            start: Instant::now(),
            rows: Vec::new(),
            samples_done: 0,
        })
    }

    fn row_json(row: &StepRow) -> Json {
        obj(vec![
            ("step", num(row.step as f64)),
            ("loss", num(row.loss as f64)),
            ("metric", num(row.metric as f64)),
            ("lr", num(row.lr as f64)),
            ("act_bytes", num(row.activation_bytes as f64)),
            ("t", num(row.elapsed_s)),
        ])
    }

    pub fn log_step(&mut self, row: StepRow, batch: usize) -> Result<()> {
        self.samples_done += batch as u64;
        if let Some(w) = &mut self.writer {
            writeln!(w, "{}", Metrics::row_json(&row).to_string())?;
        }
        self.rows.push(row);
        Ok(())
    }

    /// Re-seed the sink from a resumed session's saved state: the
    /// loss-curve rows and the sample counter continue from where the
    /// suspended run left off. The restored rows are re-written into
    /// the JSONL sink (which `Metrics::new` freshly truncated), so a
    /// resumed run's on-disk metric history stays complete — replayed
    /// steps appear exactly once, with their originally-logged values.
    /// Wall-clock state is deliberately *not* restored — `elapsed_s` /
    /// `throughput` measure this process. See KNOWN.md.
    pub fn restore(&mut self, rows: Vec<StepRow>,
                   samples_done: u64) -> Result<()> {
        if let Some(w) = &mut self.writer {
            for row in &rows {
                writeln!(w, "{}", Metrics::row_json(row).to_string())?;
            }
        }
        self.rows = rows;
        self.samples_done = samples_done;
        Ok(())
    }

    /// Samples per second since construction.
    pub fn throughput(&self) -> f64 {
        self.samples_done as f64 / self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn mean_recent_loss(&self, window: usize) -> f32 {
        let n = self.rows.len();
        if n == 0 {
            return f32::NAN;
        }
        let lo = n.saturating_sub(window);
        let slice = &self.rows[lo..];
        slice.iter().map(|r| r.loss).sum::<f32>() / slice.len() as f32
    }

    pub fn mean_recent_metric(&self, window: usize) -> f32 {
        let n = self.rows.len();
        if n == 0 {
            return f32::NAN;
        }
        let lo = n.saturating_sub(window);
        let slice = &self.rows[lo..];
        slice.iter().map(|r| r.metric).sum::<f32>() / slice.len() as f32
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(w) = &mut self.writer {
            w.flush()?;
        }
        Ok(())
    }

    /// Serialize the final summary as JSON (for EXPERIMENTS.md capture).
    pub fn summary(&self, label: &str, peak_act_bytes: u64) -> Json {
        obj(vec![
            ("label", s(label)),
            ("steps", num(self.rows.len() as f64)),
            ("final_loss", num(self.mean_recent_loss(20) as f64)),
            ("final_metric", num(self.mean_recent_metric(20) as f64)),
            ("throughput_samples_per_s", num(self.throughput())),
            ("peak_activation_bytes", num(peak_act_bytes as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_means() {
        let mut m = Metrics::new(None).unwrap();
        for i in 0..10 {
            m.log_step(
                StepRow {
                    step: i,
                    loss: 10.0 - i as f32,
                    metric: i as f32 / 10.0,
                    lr: 0.1,
                    activation_bytes: 1000,
                    elapsed_s: 0.0,
                },
                4,
            )
            .unwrap();
        }
        assert_eq!(m.rows.len(), 10);
        assert!((m.mean_recent_loss(2) - 1.5).abs() < 1e-6);
        assert!(m.throughput() > 0.0);
    }

    #[test]
    fn jsonl_written() {
        let dir = std::env::temp_dir().join("ambp_metrics_test");
        let path = dir.join("m.jsonl");
        let mut m = Metrics::new(Some(&path)).unwrap();
        m.log_step(
            StepRow {
                step: 0,
                loss: 1.0,
                metric: 0.5,
                lr: 0.01,
                activation_bytes: 7,
                elapsed_s: 0.1,
            },
            1,
        )
        .unwrap();
        m.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("loss").unwrap().as_f64().unwrap(), 1.0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn restore_rewrites_history_into_a_fresh_sink() {
        let dir = std::env::temp_dir().join(format!(
            "ambp_metrics_restore_test_{}",
            std::process::id()
        ));
        let path = dir.join("m.jsonl");
        let row = |step: usize| StepRow {
            step,
            loss: step as f32,
            metric: 0.0,
            lr: 0.1,
            activation_bytes: 1,
            elapsed_s: 0.0,
        };
        // a fresh sink truncates; restore must re-write the saved rows
        // so the resumed file still carries the full history
        let mut m = Metrics::new(Some(&path)).unwrap();
        m.restore(vec![row(0), row(1)], 8).unwrap();
        m.log_step(row(2), 4).unwrap();
        m.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let steps: Vec<usize> = text
            .lines()
            .map(|l| {
                Json::parse(l).unwrap().get("step").unwrap()
                    .as_usize().unwrap()
            })
            .collect();
        assert_eq!(steps, vec![0, 1, 2]);
        assert_eq!(m.rows.len(), 3);
        let _ = std::fs::remove_dir_all(dir);
    }
}
