//! L3 coordinator: training loop, optimizers, LR schedules, measured
//! memory accounting, metrics, checkpoints.

pub mod checkpoint;
pub mod memory;
pub mod metrics;
pub mod optimizer;
pub mod scheduler;
pub mod trainer;

pub use trainer::{TrainCfg, TrainReport, Trainer};
