//! L3 coordinator: the step-driven session core, the multi-tenant
//! engine, optimizers, LR schedules, measured memory accounting,
//! metrics, checkpoints, the durable statefile format behind
//! suspend/resume and preemptive scheduling, and the serving front
//! line (job queue + traffic + scheduling policies).

pub mod checkpoint;
pub mod engine;
pub mod frontline;
pub mod memory;
pub mod metrics;
pub mod optimizer;
pub mod scheduler;
pub mod session;
pub mod statefile;
pub mod supervisor;
pub mod traffic;
pub mod trainer;

pub use engine::{Engine, EngineReport, JobSpec, SessionOutcome,
                 StepEvent, StepEventKind};
pub use frontline::{FrontCfg, FrontReport, Policy};
pub use metrics::{FleetMetrics, Percentiles, SessionSummary};
pub use traffic::{TrafficCfg, TrafficJob};
pub use session::{Session, SessionState, StepOutcome, StepStats};
pub use statefile::{SavedSession, SessionHandle, StateError};
pub use supervisor::{FaultKind, FaultRecord, NumericFault};
pub use trainer::{TrainCfg, TrainReport, Trainer};
