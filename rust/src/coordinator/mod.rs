//! L3 coordinator: the step-driven session core, the multi-tenant
//! engine, optimizers, LR schedules, measured memory accounting,
//! metrics, checkpoints.

pub mod checkpoint;
pub mod engine;
pub mod memory;
pub mod metrics;
pub mod optimizer;
pub mod scheduler;
pub mod session;
pub mod trainer;

pub use engine::{Engine, EngineReport, JobSpec};
pub use session::{Session, StepOutcome, StepStats};
pub use trainer::{TrainCfg, TrainReport, Trainer};
