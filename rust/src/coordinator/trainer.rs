//! The fine-tuning training loop.
//!
//! Per step: prefetch batch → backend fwd (loss, metric, residuals) →
//! [residual bytes == activation memory, tracked] → backend bwd (grads)
//! → gradient accumulation → optimizer step on the host. The loop is
//! backend-agnostic: it only speaks the residual ABI of
//! `runtime::Executor`, so the same code drives the native CPU backend
//! and (with `--features pjrt`) compiled XLA artifacts. Storage-format
//! axes ride that contract for free: the `_mesa` presets' int8
//! residual tensors flow through fwd → tracker → bwd → recycle
//! untouched, and the measured `activation_bytes` shrink because the
//! tensors themselves are smaller — not because of any trainer-side
//! accounting rule.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::memory::MemoryTracker;
use crate::coordinator::metrics::{Metrics, StepRow};
use crate::coordinator::optimizer::{AdamW, Optimizer, Sgd};
use crate::coordinator::scheduler::Schedule;
use crate::data::loader::{Batch, Prefetcher};
use crate::data::synth_images::ImageTask;
use crate::data::synth_text::TextTask;
use crate::runtime::{Artifact, Tensor};

/// Trainer hyper-parameters (CLI-overridable; see `config::RunCfg`).
#[derive(Debug, Clone)]
pub struct TrainCfg {
    /// Optimizer steps to run.
    pub steps: usize,
    /// Base learning rate.
    pub lr: f32,
    /// AdamW decoupled weight decay.
    pub weight_decay: f32,
    /// Learning-rate schedule.
    pub schedule: Schedule,
    /// `"adamw"` or `"sgd"`.
    pub optimizer: String,
    /// Microbatches averaged per optimizer step.
    pub grad_accum: usize,
    /// Console logging period (0 = silent).
    pub log_every: usize,
    /// Data seed.
    pub seed: u64,
    /// Per-sample noise of the synthetic image task.
    pub data_noise: f32,
    /// Optional JSONL sink for per-step metrics.
    pub metrics_jsonl: Option<PathBuf>,
    /// Held-out evaluation batches at the end of training.
    pub eval_batches: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 100,
            lr: 1e-3,
            weight_decay: 0.0,
            schedule: Schedule::WarmupCosine {
                warmup: 10,
                warmup_init: 1e-6,
            },
            optimizer: "adamw".into(),
            grad_accum: 1,
            log_every: 10,
            seed: 0,
            data_noise: 0.6,
            metrics_jsonl: None,
            eval_batches: 8,
        }
    }
}

/// Summary of a finished training run.
pub struct TrainReport {
    /// Mean loss over the last up-to-20 steps.
    pub final_loss: f32,
    /// Mean metric over the last up-to-20 steps.
    pub final_metric: f32,
    /// Held-out loss after training.
    pub eval_loss: f32,
    /// Held-out metric after training.
    pub eval_metric: f32,
    /// Samples per second over the whole run.
    pub throughput: f64,
    /// Peak measured activation(+grad) bytes — the paper's headline.
    pub peak_activation_bytes: u64,
    /// Steps actually run.
    pub steps: usize,
    /// Per-step rows (loss/metric/lr/bytes).
    pub rows: Vec<StepRow>,
    /// Residual bytes by kind at the last observation.
    pub by_kind: Vec<(String, u64)>,
    /// Residual bytes by module at the last observation.
    pub by_module: Vec<(String, u64)>,
}

/// Build the task-appropriate batch producer for an artifact. Errors on
/// an arch tag this trainer has no generator for (same contract as the
/// other manifest parse paths — never panics on input data).
fn make_producer(art: &Artifact, cfg: &TrainCfg)
                 -> Result<Box<dyn Fn(usize) -> Batch + Send>> {
    let m = &art.manifest;
    let b = m.batch;
    Ok(match m.arch.as_str() {
        "vit" => {
            let task = ImageTask::new(m.n_classes, m.n_tokens, m.patch_dim,
                                      cfg.data_noise, cfg.seed);
            Box::new(move |step| {
                let (x, y) = task.batch(step as u64 * b as u64, b);
                Batch::Images { x, y }
            })
        }
        "llama" => {
            let task = TextTask::new(m.vocab, m.n_tokens, 4, 0.85,
                                     cfg.seed);
            Box::new(move |step| {
                let (x, y) = task.batch_lm(step as u64 * b as u64, b);
                Batch::Tokens { x, y }
            })
        }
        "roberta" => {
            let task = TextTask::new(m.vocab, m.n_tokens, m.n_classes,
                                     0.85, cfg.seed);
            Box::new(move |step| {
                let (x, y) = task.batch_cls(step as u64 * b as u64, b);
                Batch::Tokens { x, y }
            })
        }
        other => anyhow::bail!(
            "unknown arch {other:?} (trainer has batch generators for \
             vit|llama|roberta)"
        ),
    })
}

fn to_tensors(art: &Artifact, batch: Batch) -> (Tensor, Tensor) {
    let m = &art.manifest;
    match batch {
        Batch::Images { x, y } => (
            Tensor::from_f32(&m.x.shape, &x),
            Tensor::from_i32(&m.y.shape, &y),
        ),
        Batch::Tokens { x, y } => (
            Tensor::from_i32(&m.x.shape, &x),
            Tensor::from_i32(&m.y.shape, &y),
        ),
    }
}

/// Drives fwd/bwd/optimizer over an artifact.
pub struct Trainer<'a> {
    /// The artifact being fine-tuned.
    pub art: &'a Artifact,
    /// Hyper-parameters.
    pub cfg: TrainCfg,
    /// Current parameters (manifest order).
    pub params: Vec<Tensor>,
    /// Host-side optimizer over the trainables.
    pub opt: Box<dyn Optimizer>,
    /// Measured activation-memory accounting.
    pub memory: MemoryTracker,
}

impl<'a> Trainer<'a> {
    /// Build a trainer with the artifact's initial parameters.
    pub fn new(art: &'a Artifact, cfg: TrainCfg) -> Result<Trainer<'a>> {
        let params = art.load_params()?;
        let opt: Box<dyn Optimizer> = match cfg.optimizer.as_str() {
            "sgd" => Box::new(Sgd::new(0.9)),
            _ => Box::new(AdamW::new(cfg.weight_decay)),
        };
        Ok(Trainer { art, cfg, params, opt, memory: MemoryTracker::new() })
    }

    /// Replace initial params (e.g. restored from a pretrain checkpoint).
    pub fn set_params(&mut self, params: Vec<Tensor>) {
        self.params = params;
    }

    /// Run the configured number of steps; returns the report.
    pub fn train(&mut self) -> Result<TrainReport> {
        let cfg = self.cfg.clone();
        let producer = make_producer(self.art, &cfg)?;
        let n_micro = cfg.steps * cfg.grad_accum;
        let prefetch = Prefetcher::spawn(n_micro, 2, producer);
        let tidx = self.art.manifest.trainable_indices();
        let mut accum: Option<Vec<Tensor>> = None;

        // One unmeasured warmup fwd/bwd so first-run lazy initialization
        // (PJRT compilation caches, page faults on the parameter arrays)
        // is not charged to the throughput meter — it systematically
        // penalized whichever variant ran first.
        {
            let producer2 = make_producer(self.art, &cfg)?;
            // far outside any train/eval index range, but small enough
            // that `step * batch` cannot overflow inside the producer
            let (x, y) = to_tensors(self.art, producer2(u32::MAX as usize));
            let out = self.art.run_fwd(&self.params, &x, &y)?;
            let g = self.art.run_bwd(&self.params, &out.residuals,
                                     &x, &y)?;
            self.art.recycle(out.residuals);
            self.art.recycle(g);
        }
        let mut metrics = Metrics::new(cfg.metrics_jsonl.as_deref())?;

        for step in 0..cfg.steps {
            let lr = cfg.schedule.lr(cfg.lr, step, cfg.steps);
            let mut loss_acc = 0f32;
            let mut metric_acc = 0f32;
            for _ in 0..cfg.grad_accum {
                let batch = prefetch.next().expect("prefetcher exhausted");
                let (x, y) = to_tensors(self.art, batch);
                let out = self.art.run_fwd(&self.params, &x, &y)?;
                loss_acc += out.loss / cfg.grad_accum as f32;
                metric_acc += out.metric / cfg.grad_accum as f32;
                // ---- the measured activation-memory moment ----
                self.memory.observe_residuals(&self.art.manifest,
                                              &out.residuals);
                let grads = self.art.run_bwd(&self.params, &out.residuals,
                                             &x, &y)?;
                let gbytes: u64 =
                    grads.iter().map(|g| g.nbytes() as u64).sum();
                self.memory.observe_extra(gbytes);
                self.memory.release();
                // the residuals are dead past this point — hand their
                // buffers back to the executor's arena for the next step
                self.art.recycle(out.residuals);
                match &mut accum {
                    None => {
                        accum = Some(grads);
                    }
                    Some(acc) => {
                        for (a, g) in acc.iter_mut().zip(&grads) {
                            let av = a.as_f32_mut();
                            for (ai, gi) in av.iter_mut()
                                .zip(g.as_f32()) {
                                *ai += gi;
                            }
                        }
                        self.art.recycle(grads);
                    }
                }
            }
            let mut grads = accum.take().unwrap();
            if cfg.grad_accum > 1 {
                let inv = 1.0 / cfg.grad_accum as f32;
                for g in &mut grads {
                    for v in g.as_f32_mut() {
                        *v *= inv;
                    }
                }
            }
            // optimizer step over trainables (grads are in tidx order)
            {
                let mut refs: Vec<&mut Tensor> = Vec::new();
                let mut taken: Vec<(usize, *mut Tensor)> = tidx
                    .iter()
                    .map(|&i| (i, &mut self.params[i] as *mut Tensor))
                    .collect();
                for (_, p) in taken.iter_mut() {
                    // SAFETY: indices are unique; disjoint &mut borrows
                    refs.push(unsafe { &mut **p });
                }
                self.opt.step(&mut refs, &grads, lr);
            }
            // the gradient tensors' buffers came from the executor's
            // arena (native backend); hand them back for the next step
            self.art.recycle(grads);
            metrics.log_step(
                StepRow {
                    step,
                    loss: loss_acc,
                    metric: metric_acc,
                    lr,
                    activation_bytes: self.memory.last_residual_bytes,
                    elapsed_s: metrics.elapsed_s(),
                },
                self.art.manifest.batch * cfg.grad_accum,
            )?;
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                eprintln!(
                    "step {step:>5}  loss {loss_acc:.4}  metric \
                     {metric_acc:.3}  lr {lr:.2e}  act \
                     {:.1} MiB",
                    self.memory.last_residual_bytes as f64 / 1048576.0
                );
            }
        }
        metrics.flush()?;

        // held-out evaluation (fresh data indices past the training range)
        let (eval_loss, eval_metric) =
            self.evaluate(cfg.steps * cfg.grad_accum + 1000,
                          cfg.eval_batches)?;

        Ok(TrainReport {
            final_loss: metrics.mean_recent_loss(20),
            final_metric: metrics.mean_recent_metric(20),
            eval_loss,
            eval_metric,
            throughput: metrics.throughput(),
            peak_activation_bytes: self.memory.peak_bytes,
            steps: cfg.steps,
            rows: metrics.rows.clone(),
            by_kind: self.memory.by_kind.clone(),
            by_module: self.memory.by_module.clone(),
        })
    }

    /// Evaluate on held-out batches (forward only).
    pub fn evaluate(&mut self, start: usize,
                    n_batches: usize) -> Result<(f32, f32)> {
        let producer = make_producer(self.art, &self.cfg)?;
        let mut loss = 0f32;
        let mut metric = 0f32;
        for i in 0..n_batches {
            let (x, y) = to_tensors(self.art, producer(start + i));
            let out = self.art.run_fwd(&self.params, &x, &y)?;
            loss += out.loss / n_batches as f32;
            metric += out.metric / n_batches as f32;
            self.art.recycle(out.residuals);
        }
        Ok((loss, metric))
    }
}
