//! The fine-tuning training loop — now a thin façade over
//! [`Session`](crate::coordinator::session::Session).
//!
//! [`Trainer::train`] constructs one session from the trainer's
//! (possibly checkpoint-restored) parameters and loops
//! `Session::step()` to exhaustion, so the single-job CLI paths keep
//! their exact behavior while the step-driven core is what the
//! multi-tenant [`Engine`](crate::coordinator::engine::Engine)
//! interleaves. Per step: prefetch batch → backend fwd (loss, metric,
//! residuals) → [residual bytes == activation memory, tracked] →
//! backend bwd (grads) → gradient accumulation → optimizer step on the
//! host. The loop is backend-agnostic: it only speaks the residual ABI
//! of `runtime::Executor`, so the same code drives the native CPU
//! backend and (with `--features pjrt`) compiled XLA artifacts.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::memory::MemoryTracker;
use crate::coordinator::metrics::StepRow;
use crate::coordinator::session::{make_producer, to_tensors, Session,
                                  StepOutcome};
use crate::coordinator::scheduler::Schedule;
use crate::runtime::{Artifact, Tensor};

/// Trainer hyper-parameters (CLI-overridable; see `config::RunCfg`).
#[derive(Debug, Clone)]
pub struct TrainCfg {
    /// Optimizer steps to run.
    pub steps: usize,
    /// Base learning rate.
    pub lr: f32,
    /// AdamW decoupled weight decay.
    pub weight_decay: f32,
    /// Learning-rate schedule.
    pub schedule: Schedule,
    /// `"adamw"` or `"sgd"`.
    pub optimizer: String,
    /// Microbatches averaged per optimizer step.
    pub grad_accum: usize,
    /// Console logging period (0 = silent).
    pub log_every: usize,
    /// Data seed.
    pub seed: u64,
    /// Per-sample noise of the synthetic image task.
    pub data_noise: f32,
    /// Optional JSONL sink for per-step metrics.
    pub metrics_jsonl: Option<PathBuf>,
    /// Held-out evaluation batches at the end of training.
    pub eval_batches: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 100,
            lr: 1e-3,
            weight_decay: 0.0,
            schedule: Schedule::WarmupCosine {
                warmup: 10,
                warmup_init: 1e-6,
            },
            optimizer: "adamw".into(),
            grad_accum: 1,
            log_every: 10,
            seed: 0,
            data_noise: 0.6,
            metrics_jsonl: None,
            eval_batches: 8,
        }
    }
}

/// Summary of a finished training run.
pub struct TrainReport {
    /// Mean loss over the last up-to-20 steps.
    pub final_loss: f32,
    /// Mean metric over the last up-to-20 steps.
    pub final_metric: f32,
    /// Held-out loss after training.
    pub eval_loss: f32,
    /// Held-out metric after training.
    pub eval_metric: f32,
    /// Samples per second over the whole run.
    pub throughput: f64,
    /// Peak measured activation(+grad) bytes — the paper's headline.
    pub peak_activation_bytes: u64,
    /// Steps actually run.
    pub steps: usize,
    /// Per-step rows (loss/metric/lr/bytes).
    pub rows: Vec<StepRow>,
    /// Residual bytes by kind at the last observation.
    pub by_kind: Vec<(String, u64)>,
    /// Residual bytes by module at the last observation.
    pub by_module: Vec<(String, u64)>,
}

/// Drives fwd/bwd/optimizer over an artifact (single-job façade).
pub struct Trainer<'a> {
    /// The artifact being fine-tuned.
    pub art: &'a Artifact,
    /// Hyper-parameters.
    pub cfg: TrainCfg,
    /// Current parameters (manifest order); updated after `train`.
    pub params: Vec<Tensor>,
    /// Measured activation-memory accounting of the last `train` run.
    pub memory: MemoryTracker,
}

impl<'a> Trainer<'a> {
    /// Build a trainer with the artifact's initial parameters.
    pub fn new(art: &'a Artifact, cfg: TrainCfg) -> Result<Trainer<'a>> {
        let params = art.load_params()?;
        Ok(Trainer { art, cfg, params, memory: MemoryTracker::new() })
    }

    /// Replace initial params (e.g. restored from a pretrain checkpoint).
    pub fn set_params(&mut self, params: Vec<Tensor>) {
        self.params = params;
    }

    /// Run the configured number of steps; returns the report. This is
    /// a thin loop over [`Session::step`]: the session warms up once at
    /// construction, each `step()` is one full optimizer step, and the
    /// held-out evaluation happens in `finish()`.
    ///
    /// `self.params` stays valid on every path: after a mid-run error
    /// it holds the session's (partially trained) parameters; if the
    /// session could not even be constructed, the exact pre-call values
    /// (e.g. a restored checkpoint) are put back.
    pub fn train(&mut self) -> Result<TrainReport> {
        let params = std::mem::take(&mut self.params);
        let mut session = match Session::try_with_params(
            self.art, self.cfg.clone(), params)
        {
            Ok(s) => s,
            Err((e, params)) => {
                self.params = params;
                return Err(e);
            }
        };
        let result = (|| {
            while let StepOutcome::Stepped(_) = session.step()? {}
            session.finish()
        })();
        self.memory = session.memory.clone();
        self.params = session.into_params();
        result
    }

    /// Evaluate on held-out batches (forward only) with the trainer's
    /// current parameters — the standalone `ambp eval` path (no warmup,
    /// no session state).
    pub fn evaluate(&mut self, start: usize,
                    n_batches: usize) -> Result<(f32, f32)> {
        let producer = make_producer(self.art, &self.cfg)?;
        let mut loss = 0f32;
        let mut metric = 0f32;
        for i in 0..n_batches {
            let (x, y) =
                to_tensors(self.art, (producer.as_ref())(start + i));
            let out = self.art.run_fwd(&self.params, &x, &y)?;
            loss += out.loss / n_batches as f32;
            metric += out.metric / n_batches as f32;
            self.art.recycle(out.residuals);
        }
        Ok((loss, metric))
    }
}
