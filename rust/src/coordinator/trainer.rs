//! The fine-tuning training loop.
//!
//! Per step: prefetch batch → PJRT fwd (loss, metric, residuals) →
//! [residual bytes == activation memory, tracked] → PJRT bwd (grads) →
//! gradient accumulation → optimizer step on the host. Python never runs.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::memory::MemoryTracker;
use crate::coordinator::metrics::{Metrics, StepRow};
use crate::coordinator::optimizer::{AdamW, Optimizer, Sgd};
use crate::coordinator::scheduler::Schedule;
use crate::data::loader::{Batch, Prefetcher};
use crate::data::synth_images::ImageTask;
use crate::data::synth_text::TextTask;
use crate::runtime::{Artifact, Tensor};

#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub schedule: Schedule,
    pub optimizer: String, // "adamw" | "sgd"
    pub grad_accum: usize,
    pub log_every: usize,
    pub seed: u64,
    pub data_noise: f32,
    pub metrics_jsonl: Option<PathBuf>,
    /// held-out evaluation batches at the end of training
    pub eval_batches: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 100,
            lr: 1e-3,
            weight_decay: 0.0,
            schedule: Schedule::WarmupCosine {
                warmup: 10,
                warmup_init: 1e-6,
            },
            optimizer: "adamw".into(),
            grad_accum: 1,
            log_every: 10,
            seed: 0,
            data_noise: 0.6,
            metrics_jsonl: None,
            eval_batches: 8,
        }
    }
}

pub struct TrainReport {
    pub final_loss: f32,
    pub final_metric: f32,
    pub eval_loss: f32,
    pub eval_metric: f32,
    pub throughput: f64,
    pub peak_activation_bytes: u64,
    pub steps: usize,
    pub rows: Vec<StepRow>,
    pub by_kind: Vec<(String, u64)>,
    pub by_module: Vec<(String, u64)>,
}

/// Build the task-appropriate batch producer for an artifact.
fn make_producer(art: &Artifact, cfg: &TrainCfg)
                 -> Box<dyn Fn(usize) -> Batch + Send> {
    let m = &art.manifest;
    let b = m.batch;
    match m.arch.as_str() {
        "vit" => {
            let task = ImageTask::new(m.n_classes, m.n_tokens, m.patch_dim,
                                      cfg.data_noise, cfg.seed);
            Box::new(move |step| {
                let (x, y) = task.batch(step as u64 * b as u64, b);
                Batch::Images { x, y }
            })
        }
        "llama" => {
            let task = TextTask::new(m.vocab, m.n_tokens, 4, 0.85,
                                     cfg.seed);
            Box::new(move |step| {
                let (x, y) = task.batch_lm(step as u64 * b as u64, b);
                Batch::Tokens { x, y }
            })
        }
        "roberta" => {
            let task = TextTask::new(m.vocab, m.n_tokens, m.n_classes,
                                     0.85, cfg.seed);
            Box::new(move |step| {
                let (x, y) = task.batch_cls(step as u64 * b as u64, b);
                Batch::Tokens { x, y }
            })
        }
        other => panic!("unknown arch {other}"),
    }
}

fn to_tensors(art: &Artifact, batch: Batch) -> (Tensor, Tensor) {
    let m = &art.manifest;
    match batch {
        Batch::Images { x, y } => (
            Tensor::from_f32(&m.x.shape, &x),
            Tensor::from_i32(&m.y.shape, &y),
        ),
        Batch::Tokens { x, y } => (
            Tensor::from_i32(&m.x.shape, &x),
            Tensor::from_i32(&m.y.shape, &y),
        ),
    }
}

pub struct Trainer<'a> {
    pub art: &'a Artifact,
    pub cfg: TrainCfg,
    pub params: Vec<Tensor>,
    pub opt: Box<dyn Optimizer>,
    pub memory: MemoryTracker,
}

impl<'a> Trainer<'a> {
    pub fn new(art: &'a Artifact, cfg: TrainCfg) -> Result<Trainer<'a>> {
        let params = art.load_params()?;
        let opt: Box<dyn Optimizer> = match cfg.optimizer.as_str() {
            "sgd" => Box::new(Sgd::new(0.9)),
            _ => Box::new(AdamW::new(cfg.weight_decay)),
        };
        Ok(Trainer { art, cfg, params, opt, memory: MemoryTracker::new() })
    }

    /// Replace initial params (e.g. restored from a pretrain checkpoint).
    pub fn set_params(&mut self, params: Vec<Tensor>) {
        self.params = params;
    }

    pub fn train(&mut self) -> Result<TrainReport> {
        let cfg = self.cfg.clone();
        let producer = make_producer(self.art, &cfg);
        let n_micro = cfg.steps * cfg.grad_accum;
        let prefetch = Prefetcher::spawn(n_micro, 2, producer);
        let tidx = self.art.manifest.trainable_indices();
        let mut accum: Option<Vec<Tensor>> = None;

        // §Perf L3-1: params live as PJRT literals for the whole run;
        // only the trainable ones are re-written after an optimizer step
        // (for LoRA that is a tiny fraction of the bytes). Residuals stay
        // as literals between fwd and bwd — no host materialization.
        let mut param_lits: Vec<xla::Literal> = self
            .params
            .iter()
            .map(|p| p.to_literal())
            .collect::<Result<_>>()?;

        // §Perf L3-3: one unmeasured warmup fwd/bwd so PJRT's first-run
        // lazy initialization is not charged to the throughput meter
        // (it systematically penalized whichever variant ran first).
        {
            let producer2 = make_producer(self.art, &cfg);
            let (x, y) = to_tensors(self.art, producer2(usize::MAX / 2));
            let xl = x.to_literal()?;
            let yl = y.to_literal()?;
            let out = self.art.run_fwd_lit(&param_lits, &xl, &yl)?;
            let _ = self.art.run_bwd_lit(&param_lits, &out.residuals,
                                         &xl, &yl)?;
        }
        let mut metrics = Metrics::new(cfg.metrics_jsonl.as_deref())?;

        for step in 0..cfg.steps {
            let lr = cfg.schedule.lr(cfg.lr, step, cfg.steps);
            let mut loss_acc = 0f32;
            let mut metric_acc = 0f32;
            for _ in 0..cfg.grad_accum {
                let batch = prefetch.next().expect("prefetcher exhausted");
                let (x, y) = to_tensors(self.art, batch);
                let xl = x.to_literal()?;
                let yl = y.to_literal()?;
                let out = self.art.run_fwd_lit(&param_lits, &xl, &yl)?;
                loss_acc += out.loss / cfg.grad_accum as f32;
                metric_acc += out.metric / cfg.grad_accum as f32;
                // ---- the measured activation-memory moment ----
                self.memory.observe_residual_lits(
                    &self.art.manifest, &out.residuals,
                    out.residual_bytes);
                let grads = self.art.run_bwd_lit(
                    &param_lits, &out.residuals, &xl, &yl)?;
                let gbytes: u64 =
                    grads.iter().map(|g| g.nbytes() as u64).sum();
                self.memory.observe_extra(gbytes);
                self.memory.release();
                match &mut accum {
                    None => {
                        accum = Some(grads);
                    }
                    Some(acc) => {
                        for (a, g) in acc.iter_mut().zip(&grads) {
                            let av = a.as_f32_mut();
                            for (ai, gi) in av.iter_mut()
                                .zip(g.as_f32()) {
                                *ai += gi;
                            }
                        }
                    }
                }
            }
            let mut grads = accum.take().unwrap();
            if cfg.grad_accum > 1 {
                let inv = 1.0 / cfg.grad_accum as f32;
                for g in &mut grads {
                    for v in g.as_f32_mut() {
                        *v *= inv;
                    }
                }
            }
            // optimizer step over trainables (grads are in tidx order)
            {
                let mut refs: Vec<&mut Tensor> = Vec::new();
                let mut taken: Vec<(usize, *mut Tensor)> = tidx
                    .iter()
                    .map(|&i| (i, &mut self.params[i] as *mut Tensor))
                    .collect();
                for (_, p) in taken.iter_mut() {
                    // SAFETY: indices are unique; disjoint &mut borrows
                    refs.push(unsafe { &mut **p });
                }
                self.opt.step(&mut refs, &grads, lr);
            }
            // push updated trainables back into the literal mirror
            for &i in &tidx {
                param_lits[i].copy_raw_from::<f32>(
                    self.params[i].as_f32())?;
            }
            metrics.log_step(
                StepRow {
                    step,
                    loss: loss_acc,
                    metric: metric_acc,
                    lr,
                    activation_bytes: self.memory.last_residual_bytes,
                    elapsed_s: metrics.elapsed_s(),
                },
                self.art.manifest.batch * cfg.grad_accum,
            )?;
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                eprintln!(
                    "step {step:>5}  loss {loss_acc:.4}  metric \
                     {metric_acc:.3}  lr {lr:.2e}  act \
                     {:.1} MiB",
                    self.memory.last_residual_bytes as f64 / 1048576.0
                );
            }
        }
        metrics.flush()?;

        // held-out evaluation (fresh data indices past the training range)
        let (eval_loss, eval_metric) =
            self.evaluate(cfg.steps * cfg.grad_accum + 1000,
                          cfg.eval_batches)?;

        Ok(TrainReport {
            final_loss: metrics.mean_recent_loss(20),
            final_metric: metrics.mean_recent_metric(20),
            eval_loss,
            eval_metric,
            throughput: metrics.throughput(),
            peak_activation_bytes: self.memory.peak_bytes,
            steps: cfg.steps,
            rows: metrics.rows.clone(),
            by_kind: self.memory.by_kind.clone(),
            by_module: self.memory.by_module.clone(),
        })
    }

    /// Evaluate on held-out batches (forward only).
    pub fn evaluate(&mut self, start: usize,
                    n_batches: usize) -> Result<(f32, f32)> {
        let producer = make_producer(self.art, &self.cfg);
        let mut loss = 0f32;
        let mut metric = 0f32;
        for i in 0..n_batches {
            let (x, y) = to_tensors(self.art, producer(start + i));
            let out = self.art.run_fwd(&self.params, &x, &y)?;
            loss += out.loss / n_batches as f32;
            metric += out.metric / n_batches as f32;
        }
        Ok((loss, metric))
    }
}
