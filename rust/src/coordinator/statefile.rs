//! Durable state: the versioned binary container every suspended
//! session, serialized artifact, and checkpoint in this repo lives in.
//!
//! # Format (`FORMAT_VERSION` 1)
//!
//! A statefile is a single file: a fixed 32-byte header, an offset
//! index, a string table holding the section names, then the section
//! payloads, each padded to a 64-byte boundary so a reader may mmap
//! the file and hand out aligned zero-copy slices (`StateFile` borrows
//! the buffer; `section()` returns subslices, never copies).
//!
//! | offset | size | field |
//! |-------:|-----:|-------|
//! | 0      | 8    | magic `"AMBPSTF\0"` |
//! | 8      | 4    | format version, u32 LE |
//! | 12     | 4    | section count `n`, u32 LE |
//! | 16     | 8    | total file length in bytes, u64 LE |
//! | 24     | 8    | file checksum, u64 LE (see below) |
//! | 32     | 32·n | index: per section `{name_off u32, name_len u32, payload_off u64, payload_len u64, payload_checksum u64}` |
//! | 32+32·n| —    | string table (concatenated section names) |
//! | pad to 64 | — | zeros |
//! | …      | —    | payloads, each starting on a 64-byte boundary |
//!
//! All integers are little-endian. Offsets are absolute. The checksum
//! is FNV-1a 64 (see `util::hash`) over every byte of the file except
//! the checksum field itself (`bytes[0..24] ++ bytes[32..len]`); each
//! index entry additionally carries FNV-1a 64 of its own payload so a
//! corrupted file can name the damaged section. The writer is fully
//! deterministic — no timestamps, no randomness — so byte-for-byte
//! fixture comparison pins the format (`tests/statefile.rs`).
//!
//! # Error taxonomy
//!
//! Every load failure is a typed [`StateError`] naming the bad
//! section; the loader never panics on hostile bytes and never
//! silently loads a damaged file. Corruption outside any payload
//! (header, index, string table, padding) is attributed to section
//! `"index"`.
//!
//! # What goes in one
//!
//! * **Sessions** (`session.*` sections): trainables, raw optimizer
//!   state, step counter, data-producer seed/position, metrics rows,
//!   memory tracker — everything [`super::session::Session::resume`]
//!   needs to continue bit-identically.
//! * **Artifacts** (`artifact.*` sections): manifest JSON + the
//!   pre-split frozen base stored exactly once + initial trainables,
//!   keyed by the base's content fingerprint so a resumed session can
//!   re-attach to an already-resident base.
//! * **Checkpoints** (`ckpt.*` sections): a flat named-tensor map
//!   (see [`super::checkpoint`]).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use crate::coordinator::memory::MemoryTracker;
use crate::coordinator::metrics::StepRow;
use crate::coordinator::scheduler::Schedule;
use crate::coordinator::session::SessionState;
use crate::coordinator::trainer::TrainCfg;
use crate::runtime::tensor::{DType, Tensor};
use crate::runtime::{Artifact, Manifest, Runtime};
use crate::util::hash::{fnv1a64, Fnv64};

/// First 8 bytes of every statefile.
pub const MAGIC: [u8; 8] = *b"AMBPSTF\0";

/// The format version this build reads and writes. Bump it on any
/// layout change — `tests/statefile.rs` pins the on-disk bytes with a
/// committed fixture and fails until the fixture is regenerated.
pub const FORMAT_VERSION: u32 = 1;

const HEADER_LEN: usize = 32;
const INDEX_ENTRY_LEN: usize = 32;

/// Typed load failure: names the damaged section, never panics,
/// never silently loads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StateError {
    /// The file does not start with [`MAGIC`].
    BadMagic {
        /// The first 8 bytes actually found.
        found: [u8; 8],
    },
    /// The file's format version is not one this build reads — e.g. a
    /// statefile written by a future version of the tool.
    UnsupportedVersion {
        /// Version stored in the file.
        found: u32,
        /// The version this build supports.
        supported: u32,
    },
    /// Fewer bytes than the named section needs (also raised when the
    /// stored file length disagrees with the actual length in either
    /// direction, as section `"file"`).
    Truncated {
        /// Which region came up short.
        section: String,
        /// Bytes required.
        needed: u64,
        /// Bytes available.
        have: u64,
    },
    /// A checksum did not verify. The section is the damaged payload
    /// when one can be identified, `"index"` otherwise.
    ChecksumMismatch {
        /// Damaged section.
        section: String,
        /// Checksum stored in the file.
        stored: u64,
        /// Checksum computed from the bytes.
        computed: u64,
    },
    /// A section the decoder requires is absent.
    MissingSection {
        /// The missing section's name.
        section: String,
    },
    /// A section's bytes do not decode (bad tag, bad UTF-8, trailing
    /// bytes, inconsistent lengths, …).
    Malformed {
        /// The undecodable section.
        section: String,
        /// What failed.
        detail: String,
    },
}

impl std::fmt::Display for StateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StateError::BadMagic { found } => write!(
                f,
                "statefile: bad magic {found:02x?} (not an AMBP statefile)"
            ),
            StateError::UnsupportedVersion { found, supported } => write!(
                f,
                "statefile: format version {found} not supported (this \
                 build reads version {supported})"
            ),
            StateError::Truncated { section, needed, have } => write!(
                f,
                "statefile: section {section:?} truncated: need {needed} \
                 bytes, have {have}"
            ),
            StateError::ChecksumMismatch { section, stored, computed } => {
                write!(
                    f,
                    "statefile: checksum mismatch in section {section:?}: \
                     stored {stored:#018x}, computed {computed:#018x}"
                )
            }
            StateError::MissingSection { section } => {
                write!(f, "statefile: missing section {section:?}")
            }
            StateError::Malformed { section, detail } => write!(
                f,
                "statefile: malformed section {section:?}: {detail}"
            ),
        }
    }
}

impl std::error::Error for StateError {}

fn align64(x: usize) -> usize {
    (x + 63) & !63
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

/// Statefile builder: named sections in, deterministic bytes out.
#[derive(Default)]
pub struct Writer {
    sections: Vec<(String, Vec<u8>)>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    /// Append a section. Section names must be unique within a file
    /// (duplicates are a programming error, not an input condition).
    pub fn add(&mut self, name: &str, data: Vec<u8>) {
        assert!(
            self.sections.iter().all(|(n, _)| n != name),
            "duplicate statefile section {name:?}"
        );
        self.sections.push((name.to_string(), data));
    }

    /// Serialize to the on-disk byte layout (see the module docs).
    pub fn finish(&self) -> Vec<u8> {
        let n = self.sections.len();
        let strtab_off = HEADER_LEN + n * INDEX_ENTRY_LEN;

        // String table: section names concatenated in index order.
        let mut strtab: Vec<u8> = Vec::new();
        let mut name_pos: Vec<(u32, u32)> = Vec::with_capacity(n);
        for (name, _) in &self.sections {
            let off = (strtab_off + strtab.len()) as u32;
            strtab.extend_from_slice(name.as_bytes());
            name_pos.push((off, name.len() as u32));
        }

        // Payload placement: each section starts on a 64-byte boundary.
        let mut cur = strtab_off + strtab.len();
        let mut payload_pos: Vec<(usize, usize)> = Vec::with_capacity(n);
        for (_, data) in &self.sections {
            let off = align64(cur);
            payload_pos.push((off, data.len()));
            cur = off + data.len();
        }
        let file_len = cur;

        let mut buf = vec![0u8; file_len];
        buf[0..8].copy_from_slice(&MAGIC);
        buf[8..12].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
        buf[12..16].copy_from_slice(&(n as u32).to_le_bytes());
        buf[16..24].copy_from_slice(&(file_len as u64).to_le_bytes());
        // buf[24..32] = file checksum, written last.
        for i in 0..n {
            let data = &self.sections[i].1;
            let (name_off, name_len) = name_pos[i];
            let (off, len) = payload_pos[i];
            let e = HEADER_LEN + i * INDEX_ENTRY_LEN;
            buf[e..e + 4].copy_from_slice(&name_off.to_le_bytes());
            buf[e + 4..e + 8].copy_from_slice(&name_len.to_le_bytes());
            buf[e + 8..e + 16]
                .copy_from_slice(&(off as u64).to_le_bytes());
            buf[e + 16..e + 24]
                .copy_from_slice(&(len as u64).to_le_bytes());
            buf[e + 24..e + 32]
                .copy_from_slice(&fnv1a64(data).to_le_bytes());
            buf[off..off + len].copy_from_slice(data);
        }
        buf[strtab_off..strtab_off + strtab.len()]
            .copy_from_slice(&strtab);

        let mut h = Fnv64::new();
        h.update(&buf[0..24]);
        h.update(&buf[HEADER_LEN..]);
        let checksum = h.finish();
        buf[24..32].copy_from_slice(&checksum.to_le_bytes());
        buf
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path`, so a crash mid-write never leaves a half-written
    /// statefile under the final name.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file_name = path
            .file_name()
            .ok_or_else(|| anyhow!("statefile path {path:?} has no file name"))?
            .to_string_lossy()
            .into_owned();
        let tmp = path.with_file_name(format!("{file_name}.tmp"));
        // fault site "spool.write": `io` models a transient write
        // failure, `nan` corrupts one byte of the serialized image (the
        // write itself "succeeds" — detection is the reader's job)
        let corrupt = crate::util::faultpoint::trip("spool.write")
            .with_context(|| format!("writing statefile {tmp:?}"))?;
        let mut bytes = self.finish();
        if corrupt {
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x01;
        }
        std::fs::write(&tmp, bytes)
            .with_context(|| format!("writing statefile {tmp:?}"))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("committing statefile {path:?}"))?;
        Ok(())
    }
}

/// Read a statefile's raw bytes — the single funnel every session/
/// artifact load and peek goes through, and therefore where the
/// "spool.read" fault site lives (`io` = transient read failure,
/// `nan` = one flipped byte, caught downstream by the checksums).
fn read_state_bytes(path: &Path, what: &str) -> Result<Vec<u8>> {
    let corrupt = crate::util::faultpoint::trip("spool.read")
        .with_context(|| format!("reading {what} statefile {path:?}"))?;
    let mut buf = std::fs::read(path)
        .with_context(|| format!("reading {what} statefile {path:?}"))?;
    if corrupt && !buf.is_empty() {
        let mid = buf.len() / 2;
        buf[mid] ^= 0x01;
    }
    Ok(buf)
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

struct SectionMeta {
    name: String,
    off: usize,
    len: usize,
}

/// A parsed statefile: zero-copy named access into the caller's
/// buffer. Parsing validates magic, version, length, the whole-file
/// checksum, and the index; `section()` then hands out subslices.
pub struct StateFile<'a> {
    buf: &'a [u8],
    sections: Vec<SectionMeta>,
}

impl<'a> StateFile<'a> {
    /// Parse and fully validate a statefile buffer.
    pub fn parse(buf: &'a [u8]) -> Result<StateFile<'a>, StateError> {
        if buf.len() < HEADER_LEN {
            return Err(StateError::Truncated {
                section: "header".into(),
                needed: HEADER_LEN as u64,
                have: buf.len() as u64,
            });
        }
        if buf[0..8] != MAGIC {
            let mut found = [0u8; 8];
            found.copy_from_slice(&buf[0..8]);
            return Err(StateError::BadMagic { found });
        }
        let version = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        if version != FORMAT_VERSION {
            return Err(StateError::UnsupportedVersion {
                found: version,
                supported: FORMAT_VERSION,
            });
        }
        let n = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
        let file_len = u64::from_le_bytes(buf[16..24].try_into().unwrap());
        if file_len != buf.len() as u64 {
            return Err(StateError::Truncated {
                section: "file".into(),
                needed: file_len,
                have: buf.len() as u64,
            });
        }
        let stored = u64::from_le_bytes(buf[24..32].try_into().unwrap());
        let mut h = Fnv64::new();
        h.update(&buf[0..24]);
        h.update(&buf[HEADER_LEN..]);
        let computed = h.finish();
        let sum_ok = stored == computed;
        let sum_err = StateError::ChecksumMismatch {
            section: "index".into(),
            stored,
            computed,
        };

        match Self::parse_index(buf, n) {
            Ok(sections) => {
                if sum_ok {
                    return Ok(StateFile { buf, sections });
                }
                // The whole-file checksum failed but the index decodes:
                // use the per-payload checksums to name the damaged
                // section; damage outside every payload reports as
                // "index".
                for (i, s) in sections.iter().enumerate() {
                    let e = HEADER_LEN + i * INDEX_ENTRY_LEN;
                    let sec_stored = u64::from_le_bytes(
                        buf[e + 24..e + 32].try_into().unwrap(),
                    );
                    let sec_computed =
                        fnv1a64(&buf[s.off..s.off + s.len]);
                    if sec_stored != sec_computed {
                        return Err(StateError::ChecksumMismatch {
                            section: s.name.clone(),
                            stored: sec_stored,
                            computed: sec_computed,
                        });
                    }
                }
                Err(sum_err)
            }
            // An undecodable index on a file whose checksum also fails
            // is corruption, not a malformed writer.
            Err(_) if !sum_ok => Err(sum_err),
            Err(e) => Err(e),
        }
    }

    fn parse_index(
        buf: &[u8],
        n: usize,
    ) -> Result<Vec<SectionMeta>, StateError> {
        let malformed = |detail: String| StateError::Malformed {
            section: "index".into(),
            detail,
        };
        let index_end = HEADER_LEN
            .checked_add(
                n.checked_mul(INDEX_ENTRY_LEN)
                    .ok_or_else(|| malformed("entry count overflow".into()))?,
            )
            .ok_or_else(|| malformed("entry count overflow".into()))?;
        if index_end > buf.len() {
            return Err(StateError::Truncated {
                section: "index".into(),
                needed: index_end as u64,
                have: buf.len() as u64,
            });
        }
        let mut sections = Vec::with_capacity(n);
        for i in 0..n {
            let e = HEADER_LEN + i * INDEX_ENTRY_LEN;
            let name_off =
                u32::from_le_bytes(buf[e..e + 4].try_into().unwrap())
                    as usize;
            let name_len =
                u32::from_le_bytes(buf[e + 4..e + 8].try_into().unwrap())
                    as usize;
            let off = u64::from_le_bytes(
                buf[e + 8..e + 16].try_into().unwrap(),
            );
            let len = u64::from_le_bytes(
                buf[e + 16..e + 24].try_into().unwrap(),
            );
            let name_end = name_off.checked_add(name_len).ok_or_else(
                || malformed(format!("entry {i}: name range overflow")),
            )?;
            if name_off < index_end || name_end > buf.len() {
                return Err(malformed(format!(
                    "entry {i}: name range {name_off}..{name_end} out of \
                     bounds"
                )));
            }
            let name = std::str::from_utf8(&buf[name_off..name_end])
                .map_err(|_| {
                    malformed(format!("entry {i}: name is not UTF-8"))
                })?
                .to_string();
            let end = off.checked_add(len).ok_or_else(|| {
                malformed(format!("entry {i}: payload range overflow"))
            })?;
            if end > buf.len() as u64 {
                return Err(malformed(format!(
                    "entry {i} ({name:?}): payload {off}..{end} out of \
                     bounds"
                )));
            }
            if sections.iter().any(|s: &SectionMeta| s.name == name) {
                return Err(malformed(format!(
                    "duplicate section name {name:?}"
                )));
            }
            sections.push(SectionMeta {
                name,
                off: off as usize,
                len: len as usize,
            });
        }
        Ok(sections)
    }

    /// Section names, in file order.
    pub fn names(&self) -> Vec<&str> {
        self.sections.iter().map(|s| s.name.as_str()).collect()
    }

    /// Zero-copy payload of a named section.
    pub fn section(&self, name: &str) -> Result<&'a [u8], StateError> {
        self.sections
            .iter()
            .find(|s| s.name == name)
            .map(|s| &self.buf[s.off..s.off + s.len])
            .ok_or_else(|| StateError::MissingSection {
                section: name.to_string(),
            })
    }
}

// ---------------------------------------------------------------------
// Section codecs
// ---------------------------------------------------------------------

/// Little-endian section-payload encoder (the in-section byte order,
/// as opposed to the file-level layout the `Writer` owns).
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed (u32) UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked section-payload reader: every decode failure is a
/// typed [`StateError`] attributed to the section being read — hostile
/// bytes can never index out of range or allocate unboundedly.
pub struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
    section: String,
}

impl<'a> Cur<'a> {
    pub fn new(buf: &'a [u8], section: &str) -> Cur<'a> {
        Cur { buf, pos: 0, section: section.to_string() }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], StateError> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            StateError::Malformed {
                section: self.section.clone(),
                detail: "length overflow".into(),
            }
        })?;
        if end > self.buf.len() {
            return Err(StateError::Truncated {
                section: self.section.clone(),
                needed: end as u64,
                have: self.buf.len() as u64,
            });
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, StateError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, StateError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, StateError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64, StateError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, StateError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, StateError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u64` that must fit a `usize` (offsets, counts).
    pub fn usize(&mut self) -> Result<usize, StateError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| StateError::Malformed {
            section: self.section.clone(),
            detail: format!("value {v} exceeds usize"),
        })
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], StateError> {
        self.take(n)
    }

    /// Length-prefixed (u32) UTF-8 string.
    pub fn str(&mut self) -> Result<String, StateError> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        std::str::from_utf8(raw)
            .map(|s| s.to_string())
            .map_err(|_| StateError::Malformed {
                section: self.section.clone(),
                detail: "string is not UTF-8".into(),
            })
    }

    pub fn at_end(&self) -> bool {
        self.pos == self.buf.len()
    }

    /// Assert the whole section was consumed.
    pub fn done(&self) -> Result<(), StateError> {
        if self.at_end() {
            Ok(())
        } else {
            Err(StateError::Malformed {
                section: self.section.clone(),
                detail: format!(
                    "{} trailing bytes",
                    self.buf.len() - self.pos
                ),
            })
        }
    }

    fn malformed(&self, detail: String) -> StateError {
        StateError::Malformed { section: self.section.clone(), detail }
    }
}

fn dtype_tag(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::U8 => 2,
        DType::I8 => 3,
    }
}

fn dtype_from_tag(tag: u8) -> Option<DType> {
    Some(match tag {
        0 => DType::F32,
        1 => DType::I32,
        2 => DType::U8,
        3 => DType::I8,
        _ => return None,
    })
}

/// Encode a named tensor list as an (index, data) section pair. Each
/// tensor's raw bytes start on a 64-byte boundary *within the data
/// section*; the file-level writer aligns the section itself, so
/// every tensor payload is 64-byte aligned in the file.
pub fn encode_tensors(entries: &[(&str, &Tensor)]) -> (Vec<u8>, Vec<u8>) {
    let mut idx = Enc::new();
    let mut data: Vec<u8> = Vec::new();
    for (name, t) in entries {
        let off = align64(data.len());
        data.resize(off, 0);
        data.extend_from_slice(&t.data);
        idx.str(name);
        idx.u8(dtype_tag(t.dtype));
        idx.u32(t.shape.len() as u32);
        for &d in &t.shape {
            idx.u64(d as u64);
        }
        idx.u64(off as u64);
        idx.u64(t.data.len() as u64);
    }
    (idx.into_bytes(), data)
}

/// Decode an (index, data) tensor-table pair. `section` labels errors.
pub fn decode_tensors(
    index: &[u8],
    data: &[u8],
    section: &str,
) -> Result<Vec<(String, Tensor)>, StateError> {
    let mut cur = Cur::new(index, section);
    let mut out = Vec::new();
    while !cur.at_end() {
        let name = cur.str()?;
        let tag = cur.u8()?;
        let dtype = dtype_from_tag(tag).ok_or_else(|| {
            cur.malformed(format!("tensor {name:?}: bad dtype tag {tag}"))
        })?;
        let ndim = cur.u32()? as usize;
        if ndim > 16 {
            return Err(cur.malformed(format!(
                "tensor {name:?}: implausible rank {ndim}"
            )));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(cur.usize()?);
        }
        let off = cur.usize()?;
        let len = cur.usize()?;
        let elems = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .ok_or_else(|| {
                cur.malformed(format!("tensor {name:?}: shape overflow"))
            })?;
        if elems.checked_mul(dtype.size()) != Some(len) {
            return Err(cur.malformed(format!(
                "tensor {name:?}: shape {shape:?} × {dtype:?} does not \
                 give {len} bytes"
            )));
        }
        let end = off.checked_add(len).ok_or_else(|| {
            cur.malformed(format!("tensor {name:?}: data range overflow"))
        })?;
        if end > data.len() {
            return Err(StateError::Truncated {
                section: section.to_string(),
                needed: end as u64,
                have: data.len() as u64,
            });
        }
        out.push((
            name,
            Tensor { shape, dtype, data: data[off..end].to_vec() },
        ));
    }
    Ok(out)
}

/// Encode a [`TrainCfg`] into a payload (part of `session.meta`).
pub fn encode_cfg(e: &mut Enc, cfg: &TrainCfg) {
    e.u64(cfg.steps as u64);
    e.f32(cfg.lr);
    e.f32(cfg.weight_decay);
    match cfg.schedule {
        Schedule::Constant => e.u8(0),
        Schedule::WarmupCosine { warmup, warmup_init } => {
            e.u8(1);
            e.u64(warmup as u64);
            e.f32(warmup_init);
        }
        Schedule::WarmupLinear { warmup_frac } => {
            e.u8(2);
            e.f32(warmup_frac);
        }
    }
    e.str(&cfg.optimizer);
    e.u64(cfg.grad_accum as u64);
    e.u64(cfg.log_every as u64);
    e.u64(cfg.seed);
    e.f32(cfg.data_noise);
    match &cfg.metrics_jsonl {
        Some(p) => e.str(&p.to_string_lossy()),
        None => e.str(""),
    }
    e.u64(cfg.eval_batches as u64);
}

/// Decode a [`TrainCfg`] (inverse of [`encode_cfg`]).
pub fn decode_cfg(c: &mut Cur) -> Result<TrainCfg, StateError> {
    let steps = c.usize()?;
    let lr = c.f32()?;
    let weight_decay = c.f32()?;
    let schedule = match c.u8()? {
        0 => Schedule::Constant,
        1 => Schedule::WarmupCosine {
            warmup: c.usize()?,
            warmup_init: c.f32()?,
        },
        2 => Schedule::WarmupLinear { warmup_frac: c.f32()? },
        tag => {
            return Err(c.malformed(format!("bad schedule tag {tag}")))
        }
    };
    let optimizer = c.str()?;
    let grad_accum = c.usize()?;
    let log_every = c.usize()?;
    let seed = c.u64()?;
    let data_noise = c.f32()?;
    let metrics = c.str()?;
    let eval_batches = c.usize()?;
    Ok(TrainCfg {
        steps,
        lr,
        weight_decay,
        schedule,
        optimizer,
        grad_accum,
        log_every,
        seed,
        data_noise,
        metrics_jsonl: if metrics.is_empty() {
            None
        } else {
            Some(PathBuf::from(metrics))
        },
        eval_batches,
    })
}

fn encode_metrics(rows: &[StepRow]) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(rows.len() as u64);
    for r in rows {
        e.u64(r.step as u64);
        e.f32(r.loss);
        e.f32(r.metric);
        e.f32(r.lr);
        e.u64(r.activation_bytes);
        e.f64(r.elapsed_s);
    }
    e.into_bytes()
}

fn decode_metrics(buf: &[u8]) -> Result<Vec<StepRow>, StateError> {
    let mut c = Cur::new(buf, "session.metrics");
    let n = c.usize()?;
    // Each row is 36 bytes on the wire — reject counts the payload
    // cannot hold before allocating.
    if n > buf.len() / 36 {
        return Err(c.malformed(format!(
            "row count {n} exceeds payload capacity"
        )));
    }
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        rows.push(StepRow {
            step: c.usize()?,
            loss: c.f32()?,
            metric: c.f32()?,
            lr: c.f32()?,
            activation_bytes: c.u64()?,
            elapsed_s: c.f64()?,
        });
    }
    c.done()?;
    Ok(rows)
}

fn encode_memory(m: &MemoryTracker) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(m.current_bytes);
    e.u64(m.peak_bytes);
    e.u64(m.last_residual_bytes);
    e.u32(m.by_kind.len() as u32);
    for (k, v) in &m.by_kind {
        e.str(k);
        e.u64(*v);
    }
    e.u32(m.by_module.len() as u32);
    for (k, v) in &m.by_module {
        e.str(k);
        e.u64(*v);
    }
    e.into_bytes()
}

fn decode_memory(buf: &[u8]) -> Result<MemoryTracker, StateError> {
    let mut c = Cur::new(buf, "session.memory");
    let mut m = MemoryTracker {
        current_bytes: c.u64()?,
        peak_bytes: c.u64()?,
        last_residual_bytes: c.u64()?,
        ..Default::default()
    };
    for dst in [&mut m.by_kind, &mut m.by_module] {
        let n = c.u32()? as usize;
        for _ in 0..n {
            let k = c.str()?;
            let v = c.u64()?;
            dst.push((k, v));
        }
    }
    c.done()?;
    Ok(m)
}

// ---------------------------------------------------------------------
// Session save/load
// ---------------------------------------------------------------------

/// A suspended session on disk: everything the engine needs to decide
/// *whether* and *where* to resume it, without re-reading the file.
#[derive(Debug, Clone)]
pub struct SessionHandle {
    /// The statefile holding the session.
    pub path: PathBuf,
    /// Engine-visible session name.
    pub name: String,
    /// Artifact preset the session trains.
    pub preset: String,
    /// Fingerprint of the frozen base the trainables belong to.
    pub base_fingerprint: u64,
    /// Optimizer steps already taken.
    pub steps_done: usize,
    /// Total optimizer steps the run was configured for.
    pub steps_total: usize,
    /// Scheduling priority (higher survives preemption longer).
    pub priority: i64,
}

/// A fully decoded session statefile.
#[derive(Debug)]
pub struct SavedSession {
    /// Engine-visible session name.
    pub name: String,
    /// Scheduling priority.
    pub priority: i64,
    /// The portable session state.
    pub state: SessionState,
}

/// Serialize a suspended session to `path` (atomically).
pub fn save_session(
    path: &Path,
    name: &str,
    priority: i64,
    st: &SessionState,
) -> Result<SessionHandle> {
    ensure!(
        st.trainable_names.len() == st.trainable.len(),
        "session state: {} trainable names vs {} tensors",
        st.trainable_names.len(),
        st.trainable.len()
    );
    let mut meta = Enc::new();
    meta.str(name);
    meta.i64(priority);
    meta.str(&st.preset);
    meta.u64(st.base_fingerprint);
    meta.u64(st.step as u64);
    meta.str(&st.opt_name);
    // Data-producer state. Both values are *derived* (the producer is
    // indexed: micro-batch index = step · grad_accum), stored
    // explicitly so the file is self-describing and the loader can
    // cross-check them against the config.
    meta.u64(st.cfg.seed);
    meta.u64((st.step * st.cfg.grad_accum) as u64);
    encode_cfg(&mut meta, &st.cfg);

    let entries: Vec<(&str, &Tensor)> = st
        .trainable_names
        .iter()
        .map(|s| s.as_str())
        .zip(st.trainable.iter())
        .collect();
    let (tidx, tdata) = encode_tensors(&entries);

    let mut w = Writer::new();
    w.add("session.meta", meta.into_bytes());
    w.add("session.trainable.index", tidx);
    w.add("session.trainable.data", tdata);
    w.add("session.opt", st.opt_state.clone());
    w.add("session.metrics", encode_metrics(&st.rows));
    w.add("session.memory", encode_memory(&st.memory));
    w.write(path)?;
    Ok(SessionHandle {
        path: path.to_path_buf(),
        name: name.to_string(),
        preset: st.preset.clone(),
        base_fingerprint: st.base_fingerprint,
        steps_done: st.step,
        steps_total: st.cfg.steps,
        priority,
    })
}

/// Load and validate a session statefile.
pub fn load_session(path: &Path) -> Result<SavedSession> {
    let buf = read_state_bytes(path, "session")?;
    let sf = StateFile::parse(&buf)?;
    let mut c = Cur::new(sf.section("session.meta")?, "session.meta");
    let name = c.str()?;
    let priority = c.i64()?;
    let preset = c.str()?;
    let base_fingerprint = c.u64()?;
    let step = c.usize()?;
    let opt_name = c.str()?;
    let data_seed = c.u64()?;
    let data_pos = c.usize()?;
    let cfg = decode_cfg(&mut c)?;
    c.done()?;
    if data_seed != cfg.seed {
        return Err(StateError::Malformed {
            section: "session.meta".into(),
            detail: format!(
                "producer seed {data_seed} disagrees with config seed {}",
                cfg.seed
            ),
        }
        .into());
    }
    if step.checked_mul(cfg.grad_accum) != Some(data_pos) {
        return Err(StateError::Malformed {
            section: "session.meta".into(),
            detail: format!(
                "producer position {data_pos} disagrees with step {step} × \
                 grad_accum {}",
                cfg.grad_accum
            ),
        }
        .into());
    }
    if step > cfg.steps {
        return Err(StateError::Malformed {
            section: "session.meta".into(),
            detail: format!(
                "step {step} beyond configured total {}",
                cfg.steps
            ),
        }
        .into());
    }
    let table = decode_tensors(
        sf.section("session.trainable.index")?,
        sf.section("session.trainable.data")?,
        "session.trainable",
    )?;
    let (trainable_names, trainable): (Vec<String>, Vec<Tensor>) =
        table.into_iter().unzip();
    let opt_state = sf.section("session.opt")?.to_vec();
    let rows = decode_metrics(sf.section("session.metrics")?)?;
    let memory = decode_memory(sf.section("session.memory")?)?;
    Ok(SavedSession {
        name,
        priority,
        state: SessionState {
            preset,
            base_fingerprint,
            cfg,
            step,
            trainable_names,
            trainable,
            opt_name,
            opt_state,
            rows,
            memory,
        },
    })
}

/// Read only the scheduling envelope of a session statefile (name,
/// preset, progress, priority) — what `ambp serve --spool` needs to
/// enumerate resumable work without decoding tensor payloads.
pub fn peek_session(path: &Path) -> Result<SessionHandle> {
    let buf = read_state_bytes(path, "session")?;
    let sf = StateFile::parse(&buf)?;
    let mut c = Cur::new(sf.section("session.meta")?, "session.meta");
    let name = c.str()?;
    let priority = c.i64()?;
    let preset = c.str()?;
    let base_fingerprint = c.u64()?;
    let step = c.usize()?;
    let _opt_name = c.str()?;
    let _data_seed = c.u64()?;
    let _data_pos = c.usize()?;
    let cfg = decode_cfg(&mut c)?;
    c.done()?;
    Ok(SessionHandle {
        path: path.to_path_buf(),
        name,
        preset,
        base_fingerprint,
        steps_done: step,
        steps_total: cfg.steps,
        priority,
    })
}

// ---------------------------------------------------------------------
// Artifact save/load
// ---------------------------------------------------------------------

/// Serialize an artifact: manifest JSON, the frozen base stored
/// exactly once (straight out of the shared `Arc` — no flat-vector
/// rebuild), and the initial trainables.
pub fn save_artifact(path: &Path, art: &Artifact) -> Result<()> {
    let base = art.frozen_base();
    let mut frozen: Vec<(&str, &Tensor)> = Vec::new();
    let trainable0 = art.trainable_init();
    let mut t_names: Vec<&str> = Vec::new();
    for (i, p) in art.manifest.params.iter().enumerate() {
        match base.slot(i) {
            Some(t) => frozen.push((p.name.as_str(), t)),
            None => t_names.push(p.name.as_str()),
        }
    }
    ensure!(
        t_names.len() == trainable0.len(),
        "artifact trainable arity: {} names vs {} tensors",
        t_names.len(),
        trainable0.len()
    );
    let t_entries: Vec<(&str, &Tensor)> = t_names
        .into_iter()
        .zip(trainable0.iter())
        .collect();

    let mut meta = Enc::new();
    meta.str(&art.manifest.preset);
    meta.u64(base.fingerprint());

    let (fidx, fdata) = encode_tensors(&frozen);
    let (tidx, tdata) = encode_tensors(&t_entries);
    let mut w = Writer::new();
    w.add("artifact.meta", meta.into_bytes());
    w.add("artifact.manifest", art.manifest.to_json().into_bytes());
    w.add("artifact.frozen.index", fidx);
    w.add("artifact.frozen.data", fdata);
    w.add("artifact.trainable.index", tidx);
    w.add("artifact.trainable.data", tdata);
    w.write(path)
}

/// Load an artifact statefile and rebuild the executor through the
/// runtime's backend. The reconstructed frozen base must reproduce the
/// stored fingerprint bit-for-bit.
pub fn load_artifact(rt: &Runtime, path: &Path) -> Result<Artifact> {
    let buf = read_state_bytes(path, "artifact")?;
    let sf = StateFile::parse(&buf)?;
    let mut c = Cur::new(sf.section("artifact.meta")?, "artifact.meta");
    let preset = c.str()?;
    let fingerprint = c.u64()?;
    c.done()?;
    let mtext = std::str::from_utf8(sf.section("artifact.manifest")?)
        .map_err(|_| StateError::Malformed {
            section: "artifact.manifest".into(),
            detail: "manifest is not UTF-8".into(),
        })?;
    let manifest = Manifest::parse(mtext).map_err(|e| {
        anyhow::Error::from(StateError::Malformed {
            section: "artifact.manifest".into(),
            detail: format!("{e:#}"),
        })
    })?;
    ensure!(
        manifest.preset == preset,
        "artifact statefile: meta preset {preset:?} disagrees with \
         manifest preset {:?}",
        manifest.preset
    );
    let frozen = decode_tensors(
        sf.section("artifact.frozen.index")?,
        sf.section("artifact.frozen.data")?,
        "artifact.frozen",
    )?;
    let trainable = decode_tensors(
        sf.section("artifact.trainable.index")?,
        sf.section("artifact.trainable.data")?,
        "artifact.trainable",
    )?;
    let mut fi = frozen.into_iter();
    let mut ti = trainable.into_iter();
    let mut full = Vec::with_capacity(manifest.params.len());
    for p in &manifest.params {
        let from = if p.trainable { &mut ti } else { &mut fi };
        let (name, t) = from.next().ok_or_else(|| {
            anyhow!(
                "artifact statefile: no tensor left for parameter {:?}",
                p.name
            )
        })?;
        ensure!(
            name == p.name,
            "artifact statefile: tensor {name:?} where the manifest \
             expects {:?}",
            p.name
        );
        ensure!(
            t.shape == p.shape,
            "artifact statefile: {name:?} has shape {:?}, manifest says \
             {:?}",
            t.shape,
            p.shape
        );
        full.push(t);
    }
    ensure!(
        fi.next().is_none() && ti.next().is_none(),
        "artifact statefile: extra tensors beyond the manifest layout"
    );
    let art = rt.assemble(path.to_path_buf(), manifest, full)?;
    let got = art.frozen_base().fingerprint();
    ensure!(
        got == fingerprint,
        "artifact statefile: frozen-base fingerprint {got:#018x} does \
         not reproduce stored {fingerprint:#018x}"
    );
    Ok(art)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_file() -> Vec<u8> {
        let mut w = Writer::new();
        w.add("alpha", b"hello world".to_vec());
        w.add("beta", vec![0xAB; 100]);
        w.add("empty", Vec::new());
        w.finish()
    }

    #[test]
    fn roundtrip_sections() {
        let buf = sample_file();
        let sf = StateFile::parse(&buf).unwrap();
        assert_eq!(sf.names(), vec!["alpha", "beta", "empty"]);
        assert_eq!(sf.section("alpha").unwrap(), b"hello world");
        assert_eq!(sf.section("beta").unwrap(), &[0xAB; 100][..]);
        assert_eq!(sf.section("empty").unwrap(), b"");
        assert!(matches!(
            sf.section("gamma"),
            Err(StateError::MissingSection { .. })
        ));
    }

    #[test]
    fn payloads_are_64_aligned_and_writer_is_deterministic() {
        let buf = sample_file();
        let sf = StateFile::parse(&buf).unwrap();
        for s in &sf.sections {
            assert_eq!(s.off % 64, 0, "section {:?}", s.name);
        }
        assert_eq!(buf, sample_file());
    }

    #[test]
    fn empty_file_is_just_a_header() {
        let buf = Writer::new().finish();
        assert_eq!(buf.len(), HEADER_LEN);
        let sf = StateFile::parse(&buf).unwrap();
        assert!(sf.names().is_empty());
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut buf = sample_file();
        buf[0] ^= 0x01;
        match StateFile::parse(&buf) {
            Err(StateError::BadMagic { found }) => {
                assert_ne!(found, MAGIC)
            }
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn future_version_is_typed() {
        let mut buf = sample_file();
        buf[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        match StateFile::parse(&buf) {
            Err(StateError::UnsupportedVersion { found, supported }) => {
                assert_eq!(found, FORMAT_VERSION + 1);
                assert_eq!(supported, FORMAT_VERSION);
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
    }

    #[test]
    fn truncation_is_typed_at_every_length() {
        let buf = sample_file();
        for keep in [0, 1, 16, 31, 32, buf.len() / 2, buf.len() - 1] {
            match StateFile::parse(&buf[..keep]) {
                Err(StateError::Truncated { .. }) => {}
                other => panic!("keep={keep}: expected Truncated, got \
                                 {other:?}"),
            }
        }
        // Extension is also a length mismatch.
        let mut long = buf.clone();
        long.push(0);
        assert!(matches!(
            StateFile::parse(&long),
            Err(StateError::Truncated { .. })
        ));
    }

    #[test]
    fn payload_corruption_names_the_section() {
        let buf = sample_file();
        let sf = StateFile::parse(&buf).unwrap();
        let beta_off =
            sf.sections.iter().find(|s| s.name == "beta").unwrap().off;
        let mut bad = buf.clone();
        bad[beta_off + 3] ^= 0x40;
        match StateFile::parse(&bad) {
            Err(StateError::ChecksumMismatch { section, .. }) => {
                assert_eq!(section, "beta")
            }
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
    }

    #[test]
    fn header_or_index_corruption_is_checksum_mismatch() {
        let buf = sample_file();
        // Flip the stored checksum itself: nothing else is damaged, so
        // the blame lands on "index".
        let mut bad = buf.clone();
        bad[25] ^= 0x10;
        match StateFile::parse(&bad) {
            Err(StateError::ChecksumMismatch { section, .. }) => {
                assert_eq!(section, "index")
            }
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
        // Flip a bit inside an index entry's stored payload checksum.
        let mut bad = buf;
        bad[HEADER_LEN + 24] ^= 0x01;
        match StateFile::parse(&bad) {
            Err(StateError::ChecksumMismatch { .. }) => {}
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
    }

    #[test]
    fn tensor_table_roundtrip() {
        let a = Tensor::from_f32(&[2, 3], &[1., -2., 3.5, 4., 5., 6.]);
        let b = Tensor::from_i32(&[2], &[7, -8]);
        let c = Tensor::from_u8(&[3], &[1, 2, 3]);
        let (idx, data) = encode_tensors(&[
            ("a.W", &a),
            ("a.b", &b),
            ("codes", &c),
        ]);
        let out = decode_tensors(&idx, &data, "t").unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].0, "a.W");
        assert_eq!(out[0].1.shape, vec![2, 3]);
        assert_eq!(out[0].1.data, a.data);
        assert_eq!(out[1].1.as_i32(), &[7, -8]);
        assert_eq!(out[2].1.dtype, DType::U8);
    }

    #[test]
    fn tensor_table_rejects_inconsistent_lengths() {
        let a = Tensor::from_f32(&[2], &[1., 2.]);
        let (mut idx, data) = encode_tensors(&[("a", &a)]);
        // Corrupt the declared byte length (last 8 bytes of the entry).
        let n = idx.len();
        idx[n - 8] ^= 0x04;
        assert!(matches!(
            decode_tensors(&idx, &data, "t"),
            Err(StateError::Malformed { .. })
                | Err(StateError::Truncated { .. })
        ));
    }

    #[test]
    fn cfg_roundtrip_all_schedules() {
        for schedule in [
            Schedule::Constant,
            Schedule::WarmupCosine { warmup: 7, warmup_init: 1e-5 },
            Schedule::WarmupLinear { warmup_frac: 0.25 },
        ] {
            let cfg = TrainCfg {
                steps: 42,
                lr: 3e-4,
                weight_decay: 0.01,
                schedule,
                optimizer: "sgd".into(),
                grad_accum: 2,
                log_every: 5,
                seed: 99,
                data_noise: 0.7,
                metrics_jsonl: Some(PathBuf::from("/tmp/m.jsonl")),
                eval_batches: 3,
            };
            let mut e = Enc::new();
            encode_cfg(&mut e, &cfg);
            let bytes = e.into_bytes();
            let mut c = Cur::new(&bytes, "cfg");
            let back = decode_cfg(&mut c).unwrap();
            c.done().unwrap();
            assert_eq!(back.steps, cfg.steps);
            assert_eq!(back.lr.to_bits(), cfg.lr.to_bits());
            assert_eq!(back.schedule, cfg.schedule);
            assert_eq!(back.optimizer, cfg.optimizer);
            assert_eq!(back.seed, cfg.seed);
            assert_eq!(back.metrics_jsonl, cfg.metrics_jsonl);
        }
    }

    #[test]
    fn metrics_and_memory_roundtrip() {
        let rows = vec![
            StepRow {
                step: 0,
                loss: 2.5,
                metric: 0.1,
                lr: 1e-3,
                activation_bytes: 4096,
                elapsed_s: 0.25,
            },
            StepRow {
                step: 1,
                loss: 2.25,
                metric: 0.2,
                lr: 9e-4,
                activation_bytes: 4096,
                elapsed_s: 0.5,
            },
        ];
        let back = decode_metrics(&encode_metrics(&rows)).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].loss.to_bits(), rows[1].loss.to_bits());
        assert_eq!(back[1].elapsed_s.to_bits(), rows[1].elapsed_s.to_bits());

        let mem = MemoryTracker {
            current_bytes: 10,
            peak_bytes: 20,
            last_residual_bytes: 5,
            by_kind: vec![("act_codes".into(), 7)],
            by_module: vec![("block0".into(), 3), ("head".into(), 4)],
        };
        let back = decode_memory(&encode_memory(&mem)).unwrap();
        assert_eq!(back.peak_bytes, 20);
        assert_eq!(back.by_kind, mem.by_kind);
        assert_eq!(back.by_module, mem.by_module);
    }

    #[test]
    fn garbage_never_panics() {
        // Random-ish deterministic garbage at assorted lengths.
        let mut x: u64 = 0x243F_6A88_85A3_08D3;
        for len in [0usize, 1, 8, 31, 32, 33, 64, 200, 1000] {
            let mut buf = Vec::with_capacity(len);
            for _ in 0..len {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                buf.push((x >> 33) as u8);
            }
            let _ = StateFile::parse(&buf); // must return, not panic
        }
        // A valid header claiming a huge section count.
        let mut buf = Writer::new().finish();
        buf[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(StateFile::parse(&buf).is_err());
    }
}
