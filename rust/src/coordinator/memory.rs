//! Measured activation-memory accounting — the paper's headline metric,
//! observed at the fwd/bwd residual ABI rather than estimated.
//!
//! Between `fwd` and `bwd` the residual tensors are the *only* live
//! activation state (everything else is recomputed or fused inside the
//! executables), so their byte sum is exactly the "activation memory" of
//! §3.2, and `peak_bytes` is the per-step peak the Tables report.
//!
//! Attribution follows the manifest residual section, which since the
//! Layer/Tape refactor is derived from the model composition — so new
//! residual kinds (`ckpt_input` for gradient-checkpointed blocks,
//! `gate_operand` for SwiGLU) show up in the `by_kind` breakdown with
//! no tracker changes. For checkpointed presets the measured number is
//! the held set (block inputs + head tail); the recompute scratch in
//! bwd lives in the executor's arena and is not residual state.

use crate::runtime::{Manifest, Tensor};

#[derive(Debug, Default, Clone)]
pub struct MemoryTracker {
    pub current_bytes: u64,
    pub peak_bytes: u64,
    pub last_residual_bytes: u64,
    /// (kind, bytes) at the last observation
    pub by_kind: Vec<(String, u64)>,
    /// (module, bytes) at the last observation
    pub by_module: Vec<(String, u64)>,
}

impl MemoryTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the residual set held between fwd and bwd.
    pub fn observe_residuals(&mut self, manifest: &Manifest,
                             residuals: &[Tensor]) {
        let mut total = 0u64;
        let mut by_kind: Vec<(String, u64)> = Vec::new();
        let mut by_module: Vec<(String, u64)> = Vec::new();
        for (info, t) in manifest.residuals.iter().zip(residuals) {
            let b = t.nbytes() as u64;
            debug_assert_eq!(b, info.bytes, "manifest/runtime disagree");
            total += b;
            bump(&mut by_kind, &info.kind, b);
            let module = info
                .module
                .split('.')
                .next()
                .unwrap_or(&info.module)
                .to_string();
            bump(&mut by_module, &module, b);
        }
        self.last_residual_bytes = total;
        self.current_bytes = total;
        self.peak_bytes = self.peak_bytes.max(total);
        self.by_kind = by_kind;
        self.by_module = by_module;
    }

    /// Account additional transient state (grads held before the
    /// optimizer step, accumulated microbatch grads, …).
    pub fn observe_extra(&mut self, bytes: u64) {
        self.peak_bytes = self.peak_bytes.max(self.current_bytes + bytes);
    }

    pub fn release(&mut self) {
        self.current_bytes = 0;
    }

    pub fn mib(&self) -> f64 {
        self.peak_bytes as f64 / (1024.0 * 1024.0)
    }

    /// Bytes attributed to one residual kind at the last observation
    /// (0 when the kind was absent) — e.g. `"ckpt_input"` for the
    /// checkpointing dominance assertions.
    pub fn bytes_of_kind(&self, kind: &str) -> u64 {
        self.by_kind
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, b)| *b)
            .unwrap_or(0)
    }
}

fn bump(v: &mut Vec<(String, u64)>, k: &str, b: u64) {
    match v.iter_mut().find(|(key, _)| key == k) {
        Some((_, old)) => *old += b,
        None => v.push((k.to_string(), b)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_max() {
        let mut m = MemoryTracker::new();
        m.current_bytes = 100;
        m.peak_bytes = 100;
        m.observe_extra(50);
        assert_eq!(m.peak_bytes, 150);
        m.release();
        assert_eq!(m.current_bytes, 0);
        assert_eq!(m.peak_bytes, 150);
    }

    #[test]
    fn bytes_of_kind_lookup() {
        let mut m = MemoryTracker::new();
        m.by_kind = vec![("ckpt_input".to_string(), 64),
                         ("logits".to_string(), 8)];
        assert_eq!(m.bytes_of_kind("ckpt_input"), 64);
        assert_eq!(m.bytes_of_kind("act_codes"), 0);
    }

    #[test]
    fn bump_accumulates() {
        let mut v = Vec::new();
        bump(&mut v, "a", 1);
        bump(&mut v, "b", 2);
        bump(&mut v, "a", 3);
        assert_eq!(v, vec![("a".to_string(), 4), ("b".to_string(), 2)]);
    }
}
