//! Per-row symmetric int8 activation quantization — rust mirror of the
//! Mesa-baseline Pallas kernel (`python/compile/kernels/quant8.py`).

/// Quantize rows of length `cols`. Returns (q, per-row scale).
pub fn quant_rows(x: &[f32], cols: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(x.len() % cols, 0);
    let rows = x.len() / cols;
    let mut q = vec![0i8; x.len()];
    let mut scales = vec![0f32; rows];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let amax = row.iter().fold(1e-12f32, |m, v| m.max(v.abs()));
        let scale = amax / 127.0;
        scales[r] = scale;
        for (i, v) in row.iter().enumerate() {
            q[r * cols + i] = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

pub fn dequant_rows(q: &[i8], scales: &[f32], cols: usize) -> Vec<f32> {
    q.iter()
        .enumerate()
        .map(|(i, &v)| v as f32 * scales[i / cols])
        .collect()
}

/// Bytes stored per element by this codec (8-bit code + amortized scale).
pub fn bits_per_elem(cols: usize) -> f64 {
    8.0 + 32.0 / cols as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(3);
        let cols = 64;
        let x: Vec<f32> = (0..cols * 8).map(|_| rng.normal_f32()).collect();
        let (q, s) = quant_rows(&x, cols);
        let xhat = dequant_rows(&q, &s, cols);
        for (r, chunk) in x.chunks(cols).enumerate() {
            let amax = chunk.iter().fold(0f32, |m, v| m.max(v.abs()));
            let bound = amax / 127.0 * 0.5 + 1e-7;
            for (i, v) in chunk.iter().enumerate() {
                assert!((v - xhat[r * cols + i]).abs() <= bound);
            }
        }
    }

    #[test]
    fn zeros_are_exact() {
        let (q, s) = quant_rows(&[0.0; 16], 8);
        let xhat = dequant_rows(&q, &s, 8);
        assert!(xhat.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn bits_accounting() {
        assert!((bits_per_elem(64) - 8.5).abs() < 1e-9);
        assert!(bits_per_elem(1024) < 8.04);
    }
}
