//! Per-group symmetric int8 activation quantization — rust mirror of
//! the Mesa-baseline Pallas kernel (`python/compile/kernels/quant8.py`).
//!
//! Two layers of API:
//!
//! * [`quant_rows`]/[`dequant_rows`] — the original split codes/scales
//!   form (memmodel oracle, benches).
//! * [`quantize_into`]/[`dequantize_into`] — the fused, pool-parallel
//!   group kernels the native residual tape stores: each group of `g`
//!   elements packs as `g` int8 codes followed by its 4-byte f32 scale
//!   (`g + 4` bytes per group, [`bits_per_elem`]`(g)` bits per logical
//!   element). Work is partitioned on whole-group boundaries and every
//!   group is reduced sequentially by exactly one chunk, so the output
//!   is bit-identical for any `AMBP_THREADS` partition — the same
//!   determinism contract as the GEMM engine.

use crate::runtime::native::pool::{parallel_rows, parallel_rows_u8};

/// Bytes appended to each packed group (the group's f32 scale).
pub const GROUP_FOOTER_BYTES: usize = 4;

/// Packed byte length of `n` elements quantized in groups of `group`.
pub fn packed_len(n: usize, group: usize) -> usize {
    assert!(group > 0 && n % group == 0,
            "quantize group {group} must divide {n}");
    n / group * (group + GROUP_FOOTER_BYTES)
}

/// Fused group quantizer: for each group of `group` elements of `x`,
/// write `group` symmetric int8 codes (scale = amax/127, zero maps to
/// code 0 exactly) followed by the group's f32 scale, straight into the
/// packed residual payload `out` (`out.len()` must equal
/// [`packed_len`]). Pool-parallel over groups; bit-identical for any
/// thread-count partition.
pub fn quantize_into(x: &[f32], group: usize, out: &mut [u8]) {
    let row = group + GROUP_FOOTER_BYTES;
    assert_eq!(out.len(), packed_len(x.len(), group));
    parallel_rows_u8(out, row, 1, |first, chunk| {
        for (i, packed) in chunk.chunks_mut(row).enumerate() {
            let g = first + i;
            let src = &x[g * group..(g + 1) * group];
            let amax = src.iter().fold(1e-12f32, |m, v| m.max(v.abs()));
            let scale = amax / 127.0;
            let (codes, footer) = packed.split_at_mut(group);
            for (o, &v) in codes.iter_mut().zip(src) {
                *o = ((v / scale).round().clamp(-127.0, 127.0) as i8)
                    as u8;
            }
            footer.copy_from_slice(&scale.to_le_bytes());
        }
    });
}

/// Inverse of [`quantize_into`]: expand `packed` (groups of `group`
/// codes + scale footer) back to f32 in `out`. Pool-parallel,
/// partition-invariant like the quantizer.
pub fn dequantize_into(packed: &[u8], group: usize, out: &mut [f32]) {
    let row = group + GROUP_FOOTER_BYTES;
    assert!(group > 0 && packed.len() % row == 0,
            "packed length {} is not a multiple of group+footer {row}",
            packed.len());
    assert_eq!(out.len(), packed.len() / row * group);
    parallel_rows(out, group, 1, |first, chunk| {
        for (i, dst) in chunk.chunks_mut(group).enumerate() {
            let src = &packed[(first + i) * row..(first + i + 1) * row];
            let scale = f32::from_le_bytes([
                src[group],
                src[group + 1],
                src[group + 2],
                src[group + 3],
            ]);
            for (o, &b) in dst.iter_mut().zip(&src[..group]) {
                *o = (b as i8) as f32 * scale;
            }
        }
    });
}

/// Quantize rows of length `cols`. Returns (q, per-row scale).
pub fn quant_rows(x: &[f32], cols: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(x.len() % cols, 0);
    let rows = x.len() / cols;
    let mut q = vec![0i8; x.len()];
    let mut scales = vec![0f32; rows];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let amax = row.iter().fold(1e-12f32, |m, v| m.max(v.abs()));
        let scale = amax / 127.0;
        scales[r] = scale;
        for (i, v) in row.iter().enumerate() {
            q[r * cols + i] = (v / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

pub fn dequant_rows(q: &[i8], scales: &[f32], cols: usize) -> Vec<f32> {
    q.iter()
        .enumerate()
        .map(|(i, &v)| v as f32 * scales[i / cols])
        .collect()
}

/// Bytes stored per element by this codec (8-bit code + amortized scale).
pub fn bits_per_elem(cols: usize) -> f64 {
    8.0 + 32.0 / cols as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(3);
        let cols = 64;
        let x: Vec<f32> = (0..cols * 8).map(|_| rng.normal_f32()).collect();
        let (q, s) = quant_rows(&x, cols);
        let xhat = dequant_rows(&q, &s, cols);
        for (r, chunk) in x.chunks(cols).enumerate() {
            let amax = chunk.iter().fold(0f32, |m, v| m.max(v.abs()));
            let bound = amax / 127.0 * 0.5 + 1e-7;
            for (i, v) in chunk.iter().enumerate() {
                assert!((v - xhat[r * cols + i]).abs() <= bound);
            }
        }
    }

    #[test]
    fn zeros_are_exact() {
        let (q, s) = quant_rows(&[0.0; 16], 8);
        let xhat = dequant_rows(&q, &s, 8);
        assert!(xhat.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn bits_accounting() {
        assert!((bits_per_elem(64) - 8.5).abs() < 1e-9);
        assert!(bits_per_elem(1024) < 8.04);
    }

    #[test]
    fn fused_kernels_match_split_reference() {
        let mut rng = Rng::new(9);
        let (rows, cols) = (7, 24);
        let x: Vec<f32> =
            (0..rows * cols).map(|_| rng.normal_f32() * 3.0).collect();
        let (q, s) = quant_rows(&x, cols);
        let mut packed = vec![0u8; packed_len(x.len(), cols)];
        quantize_into(&x, cols, &mut packed);
        for r in 0..rows {
            let row = &packed[r * (cols + 4)..(r + 1) * (cols + 4)];
            for c in 0..cols {
                assert_eq!(row[c] as i8, q[r * cols + c]);
            }
            let scale = f32::from_le_bytes(
                row[cols..].try_into().unwrap());
            assert_eq!(scale, s[r]);
        }
        let mut back = vec![0f32; x.len()];
        dequantize_into(&packed, cols, &mut back);
        assert_eq!(back, dequant_rows(&q, &s, cols));
    }

    #[test]
    fn packed_len_accounting() {
        assert_eq!(packed_len(128, 64), 2 * 68);
        assert_eq!(packed_len(12, 4), 3 * 8);
    }
}
