//! Quantization substrates: per-row symmetric int8 (Mesa-like activation
//! compression baseline) and NF4 (QLoRA weight storage simulation).

pub mod int8;
pub mod nf4;
