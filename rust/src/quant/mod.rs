//! Quantization substrates: per-group symmetric int8 (the Mesa
//! activation-compression baseline — the fused group kernels back the
//! native `_mesa` presets' residual tape) and NF4 (QLoRA weight storage
//! simulation).

pub mod int8;
pub mod nf4;
