//! NF4 (NormalFloat-4) block-wise quantization — QLoRA's weight storage
//! format (Dettmers et al., 2023), used by the Table 3 simulation to
//! account for frozen-weight memory and to exercise the paper's remark
//! about transposing merged weights to preserve the block-wise
//! quantization conditional distribution.

/// The 16 NF4 levels: quantiles of N(0,1) normalized to [-1, 1]
/// (values from the QLoRA reference implementation).
pub const NF4_LEVELS: [f32; 16] = [
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0,
];

/// Block-wise NF4 quantization: per-block absmax scale + 4-bit codes
/// packed 2 per byte.
pub struct Nf4Tensor {
    pub codes: Vec<u8>,
    pub scales: Vec<f32>,
    pub len: usize,
    pub block: usize,
}

pub fn quantize(x: &[f32], block: usize) -> Nf4Tensor {
    let n_blocks = x.len().div_ceil(block);
    let mut scales = Vec::with_capacity(n_blocks);
    let mut codes = vec![0u8; x.len().div_ceil(2)];
    for b in 0..n_blocks {
        let lo = b * block;
        let hi = (lo + block).min(x.len());
        let amax = x[lo..hi].iter().fold(1e-12f32, |m, v| m.max(v.abs()));
        scales.push(amax);
        for i in lo..hi {
            let v = x[i] / amax;
            let code = nearest_level(v);
            codes[i / 2] |= code << (4 * (i % 2));
        }
    }
    Nf4Tensor { codes, scales, len: x.len(), block }
}

fn nearest_level(v: f32) -> u8 {
    let mut best = 0u8;
    let mut bd = f32::MAX;
    for (i, l) in NF4_LEVELS.iter().enumerate() {
        let d = (v - l).abs();
        if d < bd {
            bd = d;
            best = i as u8;
        }
    }
    best
}

pub fn dequantize(t: &Nf4Tensor) -> Vec<f32> {
    (0..t.len)
        .map(|i| {
            let code = (t.codes[i / 2] >> (4 * (i % 2))) & 0xf;
            NF4_LEVELS[code as usize] * t.scales[i / t.block]
        })
        .collect()
}

/// Stored bits per element (4-bit code + amortized f32 block scale).
pub fn bits_per_elem(block: usize) -> f64 {
    4.0 + 32.0 / block as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn levels_are_sorted_symmetricish() {
        for w in NF4_LEVELS.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(NF4_LEVELS[0], -1.0);
        assert_eq!(NF4_LEVELS[15], 1.0);
        assert_eq!(NF4_LEVELS[7], 0.0);
    }

    #[test]
    fn roundtrip_error_reasonable_for_gaussian() {
        // NF4 is optimal for N(0,1) data: rel RMS error ~ 0.07-0.12
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..4096).map(|_| rng.normal_f32()).collect();
        let t = quantize(&x, 64);
        let xhat = dequantize(&t);
        let mse: f64 = x.iter().zip(&xhat)
            .map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
            / x.len() as f64;
        let var: f64 = x.iter().map(|a| (*a as f64).powi(2)).sum::<f64>()
            / x.len() as f64;
        let rel = (mse / var).sqrt();
        assert!(rel < 0.15, "{rel}");
    }

    #[test]
    fn block_boundary_handling() {
        let x: Vec<f32> = (0..70).map(|i| (i as f32 - 35.0) / 10.0).collect();
        let t = quantize(&x, 64);
        assert_eq!(t.scales.len(), 2);
        let xhat = dequantize(&t);
        assert_eq!(xhat.len(), 70);
    }

    #[test]
    fn exact_at_block_absmax() {
        // the absmax element maps to ±1 level → exact reconstruction
        let x = vec![0.1f32, -2.0, 0.5, 0.3];
        let t = quantize(&x, 4);
        let xhat = dequantize(&t);
        assert!((xhat[1] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn bits_accounting() {
        assert!((bits_per_elem(64) - 4.5).abs() < 1e-9);
    }
}
