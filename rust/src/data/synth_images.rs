//! Gaussian-blob patch-token classification — the CIFAR/FGVC proxy.
//!
//! Each class k has a fixed random class template over the [N, P] patch
//! grid; a sample is template + per-sample noise + a random global shift.
//! Linearly non-separable enough that LoRA fine-tuning has something to
//! learn, cheap enough for a 1-core testbed, and fully deterministic.

use crate::util::rng::Rng;

pub struct ImageTask {
    pub n_classes: usize,
    pub n_tokens: usize,
    pub patch_dim: usize,
    templates: Vec<Vec<f32>>, // [K][N*P]
    noise: f32,
    seed: u64,
}

impl ImageTask {
    pub fn new(n_classes: usize, n_tokens: usize, patch_dim: usize,
               noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC1A55);
        let templates = (0..n_classes)
            .map(|_| {
                (0..n_tokens * patch_dim)
                    .map(|_| rng.normal_f32() * 0.8)
                    .collect()
            })
            .collect();
        ImageTask { n_classes, n_tokens, patch_dim, templates, noise, seed }
    }

    /// Deterministic sample `i`: (x: [N*P], y).
    pub fn sample(&self, i: u64) -> (Vec<f32>, i32) {
        let mut rng = Rng::new(self.seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i));
        let y = rng.below(self.n_classes);
        let shift = rng.normal_f32() * 0.3;
        let x = self.templates[y]
            .iter()
            .map(|t| t + shift + rng.normal_f32() * self.noise)
            .collect();
        (x, y as i32)
    }

    /// Batch of b samples starting at index `start` (x flat, y).
    pub fn batch(&self, start: u64, b: usize) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(b * self.n_tokens * self.patch_dim);
        let mut ys = Vec::with_capacity(b);
        for i in 0..b as u64 {
            let (x, y) = self.sample(start + i);
            xs.extend(x);
            ys.push(y);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_samples() {
        let t = ImageTask::new(10, 8, 12, 0.5, 7);
        let (x1, y1) = t.sample(42);
        let (x2, y2) = t.sample(42);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn labels_cover_classes() {
        let t = ImageTask::new(4, 4, 4, 0.5, 1);
        let (_, ys) = t.batch(0, 256);
        for k in 0..4 {
            assert!(ys.iter().any(|y| *y == k), "class {k} missing");
        }
    }

    #[test]
    fn classes_are_separated() {
        // mean intra-class distance << inter-class distance
        let t = ImageTask::new(3, 8, 8, 0.3, 2);
        let mut by_class: Vec<Vec<Vec<f32>>> = vec![vec![]; 3];
        for i in 0..200 {
            let (x, y) = t.sample(i);
            by_class[y as usize].push(x);
        }
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(p, q)| (p - q).powi(2)).sum()
        };
        let intra = d(&by_class[0][0], &by_class[0][1]);
        let inter = d(&by_class[0][0], &by_class[1][0]);
        assert!(inter > intra, "{inter} vs {intra}");
    }

    #[test]
    fn batch_shapes() {
        let t = ImageTask::new(10, 8, 12, 0.5, 3);
        let (xs, ys) = t.batch(100, 5);
        assert_eq!(xs.len(), 5 * 8 * 12);
        assert_eq!(ys.len(), 5);
    }
}
