//! Synthetic language-model corpus — the Alpaca / GLUE stand-in.
//!
//! A second-order Markov chain over the vocabulary with a planted
//! skip-gram structure: token t is sampled from a class-conditional
//! bigram table, so a causal LM can reduce loss well below uniform and a
//! sequence classifier can recover the generating class. Deterministic.

use crate::util::rng::Rng;

pub struct TextTask {
    pub vocab: usize,
    pub seq: usize,
    pub n_classes: usize,
    /// per-class bigram transition tables, [K][V] -> "preferred next"
    tables: Vec<Vec<u32>>,
    peak: f64, // probability mass on the preferred transition
    seed: u64,
}

impl TextTask {
    pub fn new(vocab: usize, seq: usize, n_classes: usize, peak: f64,
               seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x7E97);
        let tables = (0..n_classes)
            .map(|_| (0..vocab).map(|_| rng.below(vocab) as u32).collect())
            .collect();
        TextTask { vocab, seq, n_classes, tables, peak, seed }
    }

    /// LM sample: (tokens[seq], next_tokens[seq]) for next-token CE.
    pub fn sample_lm(&self, i: u64) -> (Vec<i32>, Vec<i32>) {
        let (toks, _) = self.generate(i, self.seq + 1);
        let x = toks[..self.seq].to_vec();
        let y = toks[1..].to_vec();
        (x, y)
    }

    /// Classification sample: (tokens[seq], class).
    pub fn sample_cls(&self, i: u64) -> (Vec<i32>, i32) {
        let (toks, class) = self.generate(i, self.seq);
        (toks, class as i32)
    }

    fn generate(&self, i: u64, len: usize) -> (Vec<i32>, usize) {
        let mut rng = Rng::new(self.seed
            .wrapping_mul(0xD1B54A32D192ED03)
            .wrapping_add(i));
        let class = rng.below(self.n_classes);
        let table = &self.tables[class];
        let mut toks = Vec::with_capacity(len);
        let mut cur = rng.below(self.vocab);
        toks.push(cur as i32);
        for _ in 1..len {
            cur = if rng.f64() < self.peak {
                table[cur] as usize
            } else {
                rng.below(self.vocab)
            };
            toks.push(cur as i32);
        }
        (toks, class)
    }

    pub fn batch_lm(&self, start: u64, b: usize) -> (Vec<i32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(b * self.seq);
        let mut ys = Vec::with_capacity(b * self.seq);
        for i in 0..b as u64 {
            let (x, y) = self.sample_lm(start + i);
            xs.extend(x);
            ys.extend(y);
        }
        (xs, ys)
    }

    pub fn batch_cls(&self, start: u64, b: usize) -> (Vec<i32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(b * self.seq);
        let mut ys = Vec::with_capacity(b);
        for i in 0..b as u64 {
            let (x, y) = self.sample_cls(start + i);
            xs.extend(x);
            ys.push(y);
        }
        (xs, ys)
    }

    /// Entropy floor sanity: the best possible next-token NLL given the
    /// generator (mixture of peaked bigram + uniform), in nats.
    pub fn nll_floor(&self) -> f64 {
        let p_peak = self.peak + (1.0 - self.peak) / self.vocab as f64;
        let p_rest = (1.0 - self.peak) / self.vocab as f64;
        -(p_peak * p_peak.ln()
            + (self.vocab as f64 - 1.0) * p_rest * p_rest.ln())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let t = TextTask::new(64, 16, 2, 0.8, 5);
        assert_eq!(t.sample_lm(3), t.sample_lm(3));
        assert_eq!(t.sample_cls(9), t.sample_cls(9));
    }

    #[test]
    fn lm_targets_are_shifted_inputs() {
        let t = TextTask::new(64, 16, 2, 0.8, 5);
        let (x, y) = t.sample_lm(0);
        assert_eq!(&x[1..], &y[..y.len() - 1]);
    }

    #[test]
    fn tokens_in_range() {
        let t = TextTask::new(32, 64, 4, 0.7, 1);
        let (xs, _) = t.batch_lm(0, 8);
        assert!(xs.iter().all(|&v| v >= 0 && v < 32));
    }

    #[test]
    fn structure_is_learnable() {
        // empirical: preferred transitions occur ≈ peak of the time
        let t = TextTask::new(16, 256, 1, 0.9, 2);
        let (x, y) = t.sample_lm(0);
        let table = &t.tables[0];
        let hits = x.iter().zip(&y)
            .filter(|(a, b)| table[**a as usize] as i32 == **b)
            .count();
        let frac = hits as f64 / x.len() as f64;
        assert!(frac > 0.8, "{frac}");
    }

    #[test]
    fn nll_floor_below_uniform(){
        let t = TextTask::new(64, 16, 2, 0.8, 5);
        assert!(t.nll_floor() < (64f64).ln());
    }
}
