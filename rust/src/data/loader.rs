//! Batching + background prefetch (std::thread; tokio unavailable offline).
//!
//! The trainer's input pipeline: a producer thread materializes batches a
//! few steps ahead through a bounded channel so host-side data synthesis
//! overlaps PJRT execution — the same role the paper's PyTorch DataLoader
//! workers play.

use std::sync::mpsc;
use std::thread;

/// A materialized training batch (x flat + y flat, any dtype-erased form).
#[derive(Debug, Clone, PartialEq)]
pub enum Batch {
    /// ViT: f32 patches + i32 labels
    Images { x: Vec<f32>, y: Vec<i32> },
    /// LM / classification over tokens: i32 tokens + i32 targets
    Tokens { x: Vec<i32>, y: Vec<i32> },
}

pub struct Prefetcher {
    rx: Option<mpsc::Receiver<Batch>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn a producer calling `make(step)` for step = 0..n_steps.
    pub fn spawn<F>(n_steps: usize, depth: usize, make: F) -> Self
    where
        F: Fn(usize) -> Batch + Send + 'static,
    {
        Prefetcher::spawn_range(0, n_steps, depth, make)
    }

    /// Spawn a producer calling `make(step)` for step = start..end —
    /// the resume path: a session suspended after k micro-batches
    /// restarts its producer at position k and sees the exact batch
    /// sequence an uninterrupted run would have seen (the producer is
    /// a pure function of the step index). `start >= end` yields an
    /// immediately-exhausted producer.
    pub fn spawn_range<F>(start: usize, end: usize, depth: usize,
                          make: F) -> Self
    where
        F: Fn(usize) -> Batch + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = thread::spawn(move || {
            for step in start..end {
                if tx.send(make(step)).is_err() {
                    return; // consumer dropped early
                }
            }
        });
        Prefetcher { rx: Some(rx), handle: Some(handle) }
    }

    pub fn next(&self) -> Option<Batch> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Drop the receiver FIRST so a producer blocked in send() gets a
        // SendError and exits; only then join. (Draining instead would
        // race: the producer can refill the bounded channel and block
        // again before join.)
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_images::ImageTask;

    #[test]
    fn yields_all_batches_in_order() {
        let p = Prefetcher::spawn(5, 2, |step| Batch::Tokens {
            x: vec![step as i32],
            y: vec![step as i32 * 10],
        });
        for step in 0..5 {
            match p.next().unwrap() {
                Batch::Tokens { x, y } => {
                    assert_eq!(x[0], step as i32);
                    assert_eq!(y[0], step as i32 * 10);
                }
                _ => panic!(),
            }
        }
        assert!(p.next().is_none());
    }

    #[test]
    fn early_drop_does_not_hang() {
        let task = ImageTask::new(4, 4, 4, 0.3, 0);
        let p = Prefetcher::spawn(1000, 2, move |step| {
            let (x, y) = task.batch(step as u64 * 4, 4);
            Batch::Images { x, y }
        });
        let _ = p.next();
        drop(p); // must not deadlock
    }

    #[test]
    fn spawn_range_resumes_mid_sequence() {
        let p = Prefetcher::spawn_range(3, 6, 2, |step| Batch::Tokens {
            x: vec![step as i32],
            y: vec![],
        });
        for step in 3..6 {
            match p.next().unwrap() {
                Batch::Tokens { x, .. } => assert_eq!(x[0], step as i32),
                _ => panic!(),
            }
        }
        assert!(p.next().is_none());
        // Degenerate range: already complete.
        let done = Prefetcher::spawn_range(4, 4, 2, |_| Batch::Tokens {
            x: vec![],
            y: vec![],
        });
        assert!(done.next().is_none());
    }

    #[test]
    fn prefetch_matches_direct_synthesis() {
        let task = ImageTask::new(4, 4, 4, 0.3, 9);
        let task2 = ImageTask::new(4, 4, 4, 0.3, 9);
        let p = Prefetcher::spawn(3, 2, move |step| {
            let (x, y) = task.batch(step as u64 * 2, 2);
            Batch::Images { x, y }
        });
        for step in 0..3 {
            let want = task2.batch(step as u64 * 2, 2);
            match p.next().unwrap() {
                Batch::Images { x, y } => {
                    assert_eq!(x, want.0);
                    assert_eq!(y, want.1);
                }
                _ => panic!(),
            }
        }
    }
}
