//! Synthetic datasets + batching (the paper's CIFAR/FGVC/Alpaca/GLUE
//! stand-ins — see DESIGN.md §3 substitution table).

pub mod loader;
pub mod synth_images;
pub mod synth_text;
