//! Per-op residual entries, mirroring the L2 residual tape exactly.
//!
//! Two accounting modes:
//! * `Mode::Paper` — 16-bit activations, fp32 norm stats, FlashAttention
//!   saves {q,k,v,o,l} (Figures 5/6 parity).
//! * `Mode::Tape`  — f32 everything, attention saves {q,k,v} only
//!   (matches the measured artifact manifests bit-for-bit).

use anyhow::{bail, Result};

use crate::runtime::Manifest;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Vit,
    Llama,
    Roberta,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tuning {
    Full,
    LoraQv,
    LoraAll,
    LoraFaQv,
    LoraFaAll,
    Frozen,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActKind {
    Gelu,
    Silu,
    Relu,
    ReGelu2,
    ReGelu2d,
    ReSilu2,
    MesaGelu8,
    MesaSilu8,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    Ln,
    MsLn,
    Rms,
    MsRms,
    MesaLn8,
}

/// Accounting mode: which residual-byte formulas the model applies.
///
/// With `R = batch · n_tokens` rows, width `C`, hidden `M = C·ratio`,
/// heads `H`, and `e` = activation element size:
///
/// * `Paper` (`e = 2`, AMP bf16 activations; Figures 5/6 parity):
///   - norm (LN):  `R·C·4` input (fp32) + `2·R·4` stats (μ, 1/σ)
///   - attention:  `4·R·C·e` (FlashAttention saves {q,k,v,o}) +
///     `R·H·4` logsumexp rows
///   - activation: `R·M·e` full (GELU/SiLU), `R·M/4` 2-bit codes
///     (ReGELU2/ReSiLU2, Prop 4.3), `R·M + R·4` Mesa int8+scale
///   - linear:     `R·din·e` input iff Full/LoRA (shareable), plus
///     `R·r·e` LoRA `u = xA`
/// * `Tape` (`e = 4`, fp32 everything; matches the measured artifact
///   manifests bit-for-bit):
///   - attention saves `3·R·C·4` ({q,k,v} only — probabilities are
///     recomputed in bwd), no logsumexp
///   - everything else as above with `e = 4`
///
/// MS-LN/MS-RMSNorm store one shared `R·C·e` tensor (`norm_shared`)
/// serving both the norm backward and the following linears' inputs —
/// that sharing is the eq. 16–18 saving; plain LN/RMS store the norm
/// input *and* (when a linear needs it) the affine output separately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// 16-bit activations, fp32 norm stats, FlashAttention residual set
    /// `{q,k,v,o,l}` — reproduces the Figure 5/6 unit tallies.
    Paper,
    /// f32 everything, attention saves `{q,k,v}` only — mirrors the
    /// measured residual tape of the artifact manifests.
    Tape,
}

#[derive(Debug, Clone)]
pub struct MemCfg {
    pub arch: Arch,
    pub dim: usize,
    pub depth: usize,
    pub n_heads: usize,
    pub mlp_ratio: f64,
    pub n_tokens: usize,
    pub patch_dim: usize,
    pub n_classes: usize,
    pub vocab: usize,
    pub lora_rank: usize,
    pub batch: usize,
    pub tuning: Tuning,
    pub act: ActKind,
    pub norm: NormKind,
    pub mode: Mode,
    pub ckpt: bool,
    /// Mesa int8 axis (the native `_mesa` suffix): nonlinear-layer
    /// saves — norm x̂ and full-precision pre-activations — store as
    /// int8 codes + a per-row f32 scale, `rows·(cols+4)` bytes instead
    /// of `rows·cols·e`. Generalizes the `MesaGelu8`/`MesaLn8` kinds
    /// (byte-identical where both apply) to every act/norm combination;
    /// in Tape mode it mirrors the native int8 tape slots exactly.
    pub mesa: bool,
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub module: String,
    pub kind: String,
    pub bytes: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LinMode {
    Full,
    Frozen,
    Lora,
    LoraFa,
}

fn linear_mode(which: &str, tuning: Tuning) -> LinMode {
    match tuning {
        Tuning::Full => LinMode::Full,
        Tuning::Frozen => LinMode::Frozen,
        Tuning::LoraQv | Tuning::LoraFaQv => {
            let adapted = which == "q" || which == "v";
            match (adapted, tuning) {
                (true, Tuning::LoraQv) => LinMode::Lora,
                (true, _) => LinMode::LoraFa,
                (false, _) => LinMode::Frozen,
            }
        }
        Tuning::LoraAll => LinMode::Lora,
        Tuning::LoraFaAll => LinMode::LoraFa,
    }
}

impl MemCfg {
    /// The analytical config mirroring a runtime [`Manifest`] in
    /// `Mode::Tape` — what the engine's admission control predicts a
    /// session's residual tape from, before any step runs. Caveat: the
    /// analytical LLaMA block is always gated (SwiGLU), so for a
    /// plain-MLP llama manifest the prediction is an upper bound;
    /// admission resolves divergence conservatively with
    /// `max(analytic, manifest)`.
    pub fn from_manifest(m: &Manifest) -> Result<MemCfg> {
        let arch = match m.arch.as_str() {
            "vit" => Arch::Vit,
            "llama" => Arch::Llama,
            "roberta" => Arch::Roberta,
            other => bail!("memmodel has no arch {other:?}"),
        };
        let tuning = match m.tuning.as_str() {
            "full" => Tuning::Full,
            "frozen" => Tuning::Frozen,
            "lora_qv" | "loraqv" => Tuning::LoraQv,
            "lora_all" | "loraall" => Tuning::LoraAll,
            "lorafa_qv" | "lorafaqv" => Tuning::LoraFaQv,
            "lorafa_all" | "lorafaall" => Tuning::LoraFaAll,
            other => bail!("memmodel has no tuning {other:?}"),
        };
        let act = match m.activation.as_str() {
            "gelu" => ActKind::Gelu,
            "regelu2" => ActKind::ReGelu2,
            "silu" => ActKind::Silu,
            "resilu2" => ActKind::ReSilu2,
            "relu" => ActKind::Relu,
            other => bail!("memmodel has no activation {other:?}"),
        };
        let norm = match m.norm.as_str() {
            "ln" => NormKind::Ln,
            "msln" => NormKind::MsLn,
            "rms" => NormKind::Rms,
            "msrms" => NormKind::MsRms,
            other => bail!("memmodel has no norm {other:?}"),
        };
        Ok(MemCfg {
            arch,
            dim: m.dim,
            depth: m.depth,
            n_heads: m.n_heads,
            mlp_ratio: m.mlp_ratio,
            n_tokens: m.n_tokens,
            patch_dim: m.patch_dim,
            n_classes: m.n_classes,
            vocab: m.vocab,
            lora_rank: m.lora_rank,
            batch: m.batch,
            tuning,
            act,
            norm,
            mode: Mode::Tape,
            ckpt: m.ckpt,
            mesa: m.mesa,
        })
    }

    pub fn hidden(&self) -> usize {
        (self.dim as f64 * self.mlp_ratio) as usize
    }

    fn act_bytes(&self) -> f64 {
        match self.mode {
            Mode::Paper => 2.0,
            Mode::Tape => 4.0,
        }
    }

    fn rows(&self) -> u64 {
        (self.batch * self.n_tokens) as u64
    }
}

struct Acc<'a> {
    cfg: &'a MemCfg,
    out: Vec<Entry>,
}

impl<'a> Acc<'a> {
    fn push(&mut self, module: &str, kind: &str, bytes: f64) {
        if bytes > 0.0 {
            self.out.push(Entry {
                module: module.to_string(),
                kind: kind.to_string(),
                bytes: bytes.round() as u64,
            });
        }
    }

    /// Bytes of one saved `[rows, cols]` nonlinear-layer tensor:
    /// `rows·cols·elem` normally, or int8 codes + per-row f32 scale
    /// (`rows·(cols+4)`) under the mesa axis — the exact byte count of
    /// the native backend's int8 tape slots.
    fn nonlin_saved(&self, cols: usize, elem: f64) -> f64 {
        let rows = self.cfg.rows() as f64;
        if self.cfg.mesa {
            rows * (cols as f64 + 4.0)
        } else {
            rows * cols as f64 * elem
        }
    }

    /// Norm residuals. Returns true when the norm output z is stored and
    /// shareable with the following linears (MS variants).
    fn norm(&mut self, module: &str, cols: usize) -> bool {
        let c = self.cfg;
        let rows = c.rows() as f64;
        let stats = rows * 4.0; // per-row fp32 scalar
        match c.norm {
            NormKind::Ln => {
                // x (fp32 in paper mode, int8 under mesa), mu, rstd
                self.push(module, "norm_input",
                          self.nonlin_saved(cols, 4.0));
                self.push(module, "norm_stat", 2.0 * stats);
                false
            }
            NormKind::Rms => {
                self.push(module, "norm_input",
                          self.nonlin_saved(cols, 4.0));
                self.push(module, "norm_stat", stats);
                false
            }
            NormKind::MesaLn8 => {
                self.push(module, "act_q8", rows * cols as f64);
                self.push(module, "act_scale", stats);
                self.push(module, "norm_stat", 2.0 * stats);
                false
            }
            NormKind::MsLn | NormKind::MsRms => {
                self.push(module, "norm_shared",
                          self.nonlin_saved(cols, c.act_bytes()));
                self.push(module, "norm_stat", stats);
                true
            }
        }
    }

    /// Linear residuals. `have_shared_x`: the input tensor is already
    /// stored (by an MS norm or an earlier sibling linear). Returns
    /// whether x is stored after this linear (for share-chaining).
    fn linear(&mut self, module: &str, which: &str, din: usize,
              have_shared_x: bool) -> bool {
        let c = self.cfg;
        let rows = c.rows() as f64;
        let mode = linear_mode(which, c.tuning);
        let mut stored = have_shared_x;
        if matches!(mode, LinMode::Full | LinMode::Lora) && !have_shared_x {
            self.push(module, "linear_input",
                      rows * din as f64 * c.act_bytes());
            stored = true;
        }
        if matches!(mode, LinMode::Lora | LinMode::LoraFa) {
            self.push(module, "lora_u",
                      rows * c.lora_rank as f64 * c.act_bytes());
        }
        stored
    }

    fn activation(&mut self, module: &str, cols: usize) {
        let c = self.cfg;
        let n = c.rows() as f64 * cols as f64;
        match c.act {
            ActKind::Gelu | ActKind::Silu => {
                self.push(module, "act_full",
                          self.nonlin_saved(cols, c.act_bytes()));
            }
            ActKind::Relu => self.push(module, "act_codes", n / 8.0),
            ActKind::ReGelu2 | ActKind::ReGelu2d | ActKind::ReSilu2 => {
                self.push(module, "act_codes", n / 4.0);
            }
            ActKind::MesaGelu8 | ActKind::MesaSilu8 => {
                self.push(module, "act_q8", n);
                self.push(module, "act_scale", c.rows() as f64 * 4.0);
            }
        }
    }

    fn attn_block(&mut self, i: usize) {
        let c = self.cfg;
        let m = format!("block{i}.attn");
        let rows = c.rows() as f64;
        let d = c.dim as f64;
        let shared = self.norm(&format!("{m}.norm"), c.dim);
        let mut sh = shared;
        for w in ["q", "k", "v"] {
            sh = self.linear(&format!("{m}.{w}"), w, c.dim, sh);
        }
        // attention saves q,k,v (+o and the logsumexp rows in Paper mode,
        // matching the FlashAttention residual set of Figs 5/6)
        let qkv = match c.mode {
            Mode::Paper => 4.0,
            Mode::Tape => 3.0,
        };
        self.push(&m, "attn_qkv", qkv * rows * d * c.act_bytes());
        if c.mode == Mode::Paper {
            self.push(&m, "attn_out", rows * c.n_heads as f64 * 4.0); // l
        }
        self.linear(&format!("{m}.proj"), "proj", c.dim, false);
    }

    fn mlp_block(&mut self, i: usize) {
        let c = self.cfg;
        let m = format!("block{i}.mlp");
        let h = c.hidden();
        let shared = self.norm(&format!("{m}.norm"), c.dim);
        match c.arch {
            Arch::Vit | Arch::Roberta => {
                self.linear(&format!("{m}.fc1"), "fc", c.dim, shared);
                self.activation(&format!("{m}.act"), h);
                self.linear(&format!("{m}.fc2"), "fc", h, false);
            }
            Arch::Llama => {
                let sh = self.linear(&format!("{m}.fc1"), "fc", c.dim,
                                     shared);
                self.linear(&format!("{m}.fc2"), "fc", c.dim, sh);
                self.activation(&format!("{m}.act"), h);
                // gate multiply stores both operands (Fig 6 "+5.4")
                let rows = c.rows() as f64;
                self.push(&m, "gate_operand",
                          2.0 * rows * h as f64 * c.act_bytes());
                self.linear(&format!("{m}.fc3"), "fc", h, false);
            }
        }
    }

    fn embed(&mut self) {
        let c = self.cfg;
        if c.arch == Arch::Vit && c.tuning == Tuning::Full {
            self.push("embed.proj", "linear_input",
                      c.rows() as f64 * c.patch_dim as f64 * c.act_bytes());
        }
        // token embeddings: gather, no residual
    }

    fn head(&mut self) {
        let c = self.cfg;
        let b = c.batch as f64;
        let shared = self.norm("head.norm", c.dim);
        match c.arch {
            Arch::Vit | Arch::Roberta => {
                // pooled input + logits
                self.push("head.fc", "head_input",
                          b * c.dim as f64 * c.act_bytes());
                self.push("head", "head_input",
                          b * c.n_classes as f64 * c.act_bytes());
            }
            Arch::Llama => {
                if !shared {
                    self.push("head", "head_input",
                              c.rows() as f64 * c.dim as f64
                                  * c.act_bytes());
                }
                self.push("head", "head_input",
                          c.rows() as f64 * c.vocab as f64 * c.act_bytes());
            }
        }
    }
}

/// Residual entries for one (attn + mlp) block pair.
pub fn block_entries(cfg: &MemCfg, i: usize) -> Vec<Entry> {
    let mut acc = Acc { cfg, out: Vec::new() };
    acc.attn_block(i);
    acc.mlp_block(i);
    acc.out
}

/// Residual entries for the whole model.
pub fn model_entries(cfg: &MemCfg) -> Vec<Entry> {
    let mut acc = Acc { cfg, out: Vec::new() };
    acc.embed();
    if cfg.ckpt {
        // gradient checkpointing: one block input per block
        for i in 0..cfg.depth * 2 {
            acc.push(&format!("block{}", i / 2), "ckpt_input",
                     cfg.rows() as f64 * cfg.dim as f64 * cfg.act_bytes());
        }
    } else {
        for i in 0..cfg.depth {
            acc.attn_block(i);
            acc.mlp_block(i);
        }
    }
    acc.head();
    acc.out
}
