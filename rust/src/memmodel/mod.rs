//! Analytical activation-memory model.
//!
//! Mirrors the residual-tape semantics of the L2 model exactly (the rust
//! integration tests cross-check it against artifact manifests), and in
//! *paper mode* reproduces the Figure 5/6 per-block unit tallies
//! (ViT 19 / 12 / 11.5; LLaMA-13B 21.8 / 16.1 / 15.4375), the Figure 2
//! composition pies, and the memory columns of Tables 1–4 extrapolated to
//! ViT-B/L and LLaMA-7B/13B scale.

pub mod ops;
pub mod presets;
pub mod report;

pub use ops::{model_entries, Arch, Entry, MemCfg, Mode, NormKind, ActKind,
              Tuning};

/// Sum of residual bytes across the whole model.
pub fn total_bytes(cfg: &MemCfg) -> u64 {
    model_entries(cfg).iter().map(|e| e.bytes).sum()
}

/// Per-block activation units (unit = one 16-bit [B,N,C] tensor), the
/// Figure 5/6 metric. Only counts one attn + one mlp block.
pub fn block_units(cfg: &MemCfg) -> f64 {
    let unit = (cfg.batch * cfg.n_tokens * cfg.dim) as f64 * 2.0;
    ops::block_entries(cfg, 0)
        .iter()
        .map(|e| e.bytes as f64)
        .sum::<f64>()
        / unit
}

/// Group totals by residual category (Figure 2).
pub fn by_category(cfg: &MemCfg) -> Vec<(String, u64)> {
    let mut cats: Vec<(String, u64)> = Vec::new();
    for e in model_entries(cfg) {
        let cat = category(&e.kind).to_string();
        match cats.iter_mut().find(|(c, _)| *c == cat) {
            Some((_, b)) => *b += e.bytes,
            None => cats.push((cat, e.bytes)),
        }
    }
    cats.sort_by(|a, b| b.1.cmp(&a.1));
    cats
}

pub fn category(kind: &str) -> &'static str {
    match kind {
        "act_full" | "act_codes" | "act_q8" | "act_scale" => "activation_fn",
        "norm_input" | "norm_stat" | "norm_shared" => "normalization",
        "attn_qkv" | "attn_out" => "attention",
        "linear_input" | "lora_u" => "linear",
        "gate_operand" => "gate_mul",
        "head_input" | "logits" => "head",
        "ckpt_input" => "checkpoint",
        _ => "other",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use ops::*;

    fn vit_paper(tuning: Tuning, act: ActKind, norm: NormKind) -> MemCfg {
        MemCfg {
            arch: Arch::Vit,
            dim: 768,
            depth: 12,
            n_heads: 12,
            mlp_ratio: 4.0,
            n_tokens: 197,
            patch_dim: 768,
            n_classes: 10,
            vocab: 0,
            lora_rank: 4,
            batch: 64,
            tuning,
            act,
            norm,
            mode: Mode::Paper,
            ckpt: false,
            mesa: false,
        }
    }

    fn llama13b(act: ActKind, norm: NormKind, tuning: Tuning) -> MemCfg {
        MemCfg {
            arch: Arch::Llama,
            dim: 5120,
            depth: 40,
            n_heads: 40,
            mlp_ratio: 2.7,
            n_tokens: 2048,
            patch_dim: 0,
            n_classes: 0,
            vocab: 32000,
            lora_rank: 64,
            batch: 4,
            tuning,
            act,
            norm,
            mode: Mode::Paper,
            ckpt: false,
            mesa: false,
        }
    }

    #[test]
    fn fig5_vit_trainable_19_units() {
        let cfg = vit_paper(Tuning::Full, ActKind::Gelu, NormKind::Ln);
        let u = block_units(&cfg);
        assert!((u - 19.0).abs() < 0.2, "{u}");
    }

    #[test]
    fn fig5_vit_frozen_12_units() {
        let cfg = vit_paper(Tuning::Frozen, ActKind::Gelu, NormKind::Ln);
        let u = block_units(&cfg);
        assert!((u - 12.0).abs() < 0.2, "{u}");
    }

    #[test]
    fn fig5_vit_ours_11_5_units() {
        let cfg = vit_paper(Tuning::Full, ActKind::ReGelu2, NormKind::MsLn);
        let u = block_units(&cfg);
        assert!((u - 11.5).abs() < 0.2, "{u}");
    }

    #[test]
    fn fig6_llama_trainable_21_8_units() {
        let cfg = llama13b(ActKind::Silu, NormKind::Rms, Tuning::Full);
        let u = block_units(&cfg);
        assert!((u - 21.8).abs() < 0.2, "{u}");
    }

    #[test]
    fn fig6_llama_frozen_16_1_units() {
        let cfg = llama13b(ActKind::Silu, NormKind::Rms, Tuning::Frozen);
        let u = block_units(&cfg);
        assert!((u - 16.1).abs() < 0.2, "{u}");
    }

    #[test]
    fn fig6_llama_ours_15_44_units() {
        let cfg =
            llama13b(ActKind::ReSilu2, NormKind::MsRms, Tuning::Full);
        let u = block_units(&cfg);
        assert!((u - 15.4375).abs() < 0.2, "{u}");
    }

    #[test]
    fn fig2_nonlinear_fraction_matches_paper_ballpark() {
        // paper: GELU+LN ≈ 21% each... combined act-fn + norm share of ViT
        // activation memory with frozen linears is large (~63% non-linear
        // incl. attention). Check act_fn+norm ≳ 45% for the frozen ViT.
        let cfg = vit_paper(Tuning::Frozen, ActKind::Gelu, NormKind::Ln);
        let cats = by_category(&cfg);
        let total: u64 = cats.iter().map(|c| c.1).sum();
        let actnorm: u64 = cats.iter()
            .filter(|(c, _)| c == "activation_fn" || c == "normalization")
            .map(|c| c.1).sum();
        let frac = actnorm as f64 / total as f64;
        assert!(frac > 0.4 && frac < 0.8, "{frac}");
    }

    #[test]
    fn ours_saves_about_30_percent_on_llama() {
        // Table 3 shape: ReSiLU2 + MS-RMSNorm ≈ −29% activation memory
        let base = llama13b(ActKind::Silu, NormKind::Rms, Tuning::Full);
        let ours =
            llama13b(ActKind::ReSilu2, NormKind::MsRms, Tuning::Full);
        let rel = 1.0 - total_bytes(&ours) as f64
            / total_bytes(&base) as f64;
        assert!(rel > 0.22 && rel < 0.40, "{rel}");
    }
}
