//! Peak-memory estimation and table/figure renderers.
//!
//! Peak fine-tuning memory ≈ weights + trainable grads + optimizer state
//! + activations (this model) + framework workspace. The workspace terms
//! are calibrated constants; the *activation* term is the paper's subject.

use super::ops::{Arch, MemCfg, Tuning};
use super::{by_category, total_bytes};

/// Parameter count of the configured architecture.
pub fn param_count(cfg: &MemCfg) -> u64 {
    let d = cfg.dim as u64;
    let h = cfg.hidden() as u64;
    let per_block = 4 * d * d          // qkv + proj
        + match cfg.arch {
            Arch::Llama => 3 * d * h,  // up, gate, down
            _ => 2 * d * h + d + h,    // fc1 + fc2 + biases
        }
        + 4 * d; // norms + misc
    let embed = match cfg.arch {
        Arch::Vit => cfg.patch_dim as u64 * d + cfg.n_tokens as u64 * d,
        _ => cfg.vocab as u64 * d,
    };
    let head = match cfg.arch {
        Arch::Llama => cfg.vocab as u64 * d,
        _ => d * cfg.n_classes as u64,
    };
    embed + per_block * cfg.depth as u64 + head
}

/// Trainable parameter count under the tuning mode.
pub fn trainable_count(cfg: &MemCfg) -> u64 {
    let d = cfg.dim as u64;
    let r = cfg.lora_rank as u64;
    let h = cfg.hidden() as u64;
    match cfg.tuning {
        Tuning::Full => param_count(cfg),
        Tuning::Frozen => 0,
        Tuning::LoraQv | Tuning::LoraFaQv => {
            // q and v adapters per attn block (+ head classifier)
            cfg.depth as u64 * 2 * (r * d + d * r)
                + d * cfg.n_classes.max(1) as u64
        }
        Tuning::LoraAll | Tuning::LoraFaAll => {
            let per_attn = 4 * (r * d + d * r);
            let per_mlp = match cfg.arch {
                Arch::Llama => (r * d + h * r) * 2 + (r * h + d * r),
                _ => (r * d + h * r) + (r * h + d * r),
            };
            cfg.depth as u64 * (per_attn + per_mlp)
                + d * cfg.n_classes.max(1) as u64
        }
    }
}

#[derive(Debug, Clone)]
pub struct PeakEstimate {
    pub weights: u64,
    pub grads: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub total: u64,
}

/// Peak memory estimate in bytes.
/// `weight_bits`: 16 (AMP), 32 (fp32), or ~4.5 (QLoRA NF4).
pub fn peak(cfg: &MemCfg, weight_bits: f64) -> PeakEstimate {
    let weights =
        (param_count(cfg) as f64 * weight_bits / 8.0).round() as u64;
    let trainable = trainable_count(cfg);
    let grads = trainable * 4;
    let optimizer = trainable * 8; // AdamW m+v (fp32)
    let activations = total_bytes(cfg);
    PeakEstimate {
        weights,
        grads,
        optimizer,
        activations,
        total: weights + grads + optimizer + activations,
    }
}

pub fn mib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

pub fn gib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0 * 1024.0)
}

/// Render the Figure 2 composition pie as text rows.
pub fn composition_rows(cfg: &MemCfg) -> Vec<(String, f64)> {
    let cats = by_category(cfg);
    let total: u64 = cats.iter().map(|c| c.1).sum();
    cats.into_iter()
        .map(|(name, b)| (name, 100.0 * b as f64 / total as f64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::ops::{ActKind, NormKind};
    use crate::memmodel::presets;

    #[test]
    fn vit_base_param_count_ballpark() {
        // ViT-B ≈ 86M params
        let cfg = presets::vit_base(64, Tuning::Full, ActKind::Gelu,
                                    NormKind::Ln);
        let p = param_count(&cfg);
        assert!(p > 80_000_000 && p < 95_000_000, "{p}");
    }

    #[test]
    fn llama7b_param_count_ballpark() {
        let cfg = presets::llama7b(4, 512, ActKind::Silu, NormKind::Rms);
        let p = param_count(&cfg);
        assert!(p > 6_000_000_000 && p < 7_500_000_000, "{p}");
    }

    #[test]
    fn lora_trainable_tiny_fraction() {
        let cfg = presets::vit_base(64, Tuning::LoraQv, ActKind::Gelu,
                                    NormKind::Ln);
        let t = trainable_count(&cfg);
        let p = param_count(&cfg);
        assert!((t as f64) / (p as f64) < 0.01, "{t}/{p}");
    }

    #[test]
    fn peak_is_dominated_by_activations_for_lora() {
        let cfg = presets::vit_base(64, Tuning::LoraQv, ActKind::Gelu,
                                    NormKind::Ln);
        let est = peak(&cfg, 16.0);
        assert!(est.activations > est.grads + est.optimizer);
        assert_eq!(est.total,
                   est.weights + est.grads + est.optimizer
                       + est.activations);
    }

    #[test]
    fn composition_sums_to_100() {
        let cfg = presets::vit_base(64, Tuning::LoraQv, ActKind::Gelu,
                                    NormKind::Ln);
        let rows = composition_rows(&cfg);
        let s: f64 = rows.iter().map(|r| r.1).sum();
        assert!((s - 100.0).abs() < 1e-6);
    }
}
