//! Paper-scale architecture configs for extrapolation (Tables 1–4, 9–12).

use super::ops::{ActKind, Arch, MemCfg, Mode, NormKind, Tuning};

pub fn vit_base(batch: usize, tuning: Tuning, act: ActKind,
                norm: NormKind) -> MemCfg {
    MemCfg {
        arch: Arch::Vit, dim: 768, depth: 12, n_heads: 12, mlp_ratio: 4.0,
        n_tokens: 197, patch_dim: 768, n_classes: 100, vocab: 0,
        lora_rank: 4, batch, tuning, act, norm, mode: Mode::Paper,
        ckpt: false, mesa: false,
    }
}

pub fn vit_large(batch: usize, tuning: Tuning, act: ActKind,
                 norm: NormKind) -> MemCfg {
    MemCfg {
        arch: Arch::Vit, dim: 1024, depth: 24, n_heads: 16, mlp_ratio: 4.0,
        n_tokens: 197, patch_dim: 1024, n_classes: 100, vocab: 0,
        lora_rank: 4, batch, tuning, act, norm, mode: Mode::Paper,
        ckpt: false, mesa: false,
    }
}

pub fn llama7b(batch: usize, seq: usize, act: ActKind,
               norm: NormKind) -> MemCfg {
    MemCfg {
        arch: Arch::Llama, dim: 4096, depth: 32, n_heads: 32,
        mlp_ratio: 11008.0 / 4096.0, n_tokens: seq, patch_dim: 0,
        n_classes: 0, vocab: 32000, lora_rank: 64, batch,
        tuning: Tuning::LoraAll, act, norm, mode: Mode::Paper,
        ckpt: false, mesa: false,
    }
}

pub fn llama13b(batch: usize, seq: usize, act: ActKind,
                norm: NormKind) -> MemCfg {
    MemCfg {
        arch: Arch::Llama, dim: 5120, depth: 40, n_heads: 40,
        mlp_ratio: 13824.0 / 5120.0, n_tokens: seq, patch_dim: 0,
        n_classes: 0, vocab: 32000, lora_rank: 64, batch,
        tuning: Tuning::LoraAll, act, norm, mode: Mode::Paper,
        ckpt: false, mesa: false,
    }
}

pub fn roberta_base(batch: usize, seq: usize, act: ActKind,
                    norm: NormKind) -> MemCfg {
    MemCfg {
        arch: Arch::Roberta, dim: 768, depth: 12, n_heads: 12,
        mlp_ratio: 4.0, n_tokens: seq, patch_dim: 0, n_classes: 2,
        vocab: 50265, lora_rank: 64, batch, tuning: Tuning::LoraAll, act,
        norm, mode: Mode::Paper, ckpt: false, mesa: false,
    }
}

/// Swin-T proxy (Table 10): hierarchical windows approximated by the
/// dominant stage (stage-3: dim 384, 14×14 tokens per window batch).
pub fn swin_tiny(batch: usize, act: ActKind, norm: NormKind) -> MemCfg {
    MemCfg {
        arch: Arch::Vit, dim: 384, depth: 12, n_heads: 12, mlp_ratio: 4.0,
        n_tokens: 392, patch_dim: 384, n_classes: 20, vocab: 0,
        lora_rank: 4, batch, tuning: Tuning::Full, act, norm,
        mode: Mode::Paper, ckpt: false, mesa: false,
    }
}

pub fn bert_base(batch: usize, seq: usize, act: ActKind,
                 norm: NormKind) -> MemCfg {
    MemCfg {
        arch: Arch::Roberta, dim: 768, depth: 12, n_heads: 12,
        mlp_ratio: 4.0, n_tokens: seq, patch_dim: 0, n_classes: 2,
        vocab: 30522, lora_rank: 4, batch, tuning: Tuning::Full, act, norm,
        mode: Mode::Paper, ckpt: false, mesa: false,
    }
}

pub fn bert_large(batch: usize, seq: usize, act: ActKind,
                  norm: NormKind) -> MemCfg {
    MemCfg {
        arch: Arch::Roberta, dim: 1024, depth: 24, n_heads: 16,
        mlp_ratio: 4.0, n_tokens: seq, patch_dim: 0, n_classes: 2,
        vocab: 30522, lora_rank: 4, batch, tuning: Tuning::Full, act, norm,
        mode: Mode::Paper, ckpt: false, mesa: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::total_bytes;

    #[test]
    fn vit_l_uses_more_than_vit_b() {
        let b = vit_base(64, Tuning::LoraQv, ActKind::Gelu, NormKind::Ln);
        let l = vit_large(64, Tuning::LoraQv, ActKind::Gelu, NormKind::Ln);
        assert!(total_bytes(&l) > 2 * total_bytes(&b));
    }

    #[test]
    fn llama13b_bigger_than_7b() {
        let a = llama7b(4, 512, ActKind::Silu, NormKind::Rms);
        let b = llama13b(4, 512, ActKind::Silu, NormKind::Rms);
        assert!(total_bytes(&b) > total_bytes(&a));
    }
}
