//! Appendix experiments: C (forward substitution degrades) and
//! E/I (coefficient re-derivation).

use anyhow::Result;

use crate::coeffs::funcs::{dgelu, gelu, silu, PAPER_GELU, PAPER_GELU_D,
                           PAPER_SILU};
use crate::coeffs::{gelu_bound, objective, objective_d, silu_bound,
                    solve_gelu, solve_gelu_d, solve_silu};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::{TrainCfg, Trainer};
use crate::util::cli::Args;

use super::helpers::*;

/// Appendix C: keeping the forward pass exact is essential — swapping the
/// pretrained GELU forward for a different forward (ReLU) collapses the
/// model, while swapping only the *backward* (ReGELU2) does not.
pub fn appc(args: &Args) -> Result<()> {
    let steps = default_steps(args, 60);
    println!("Appendix C — substituting the FORWARD pass of the \
              activation degrades a pretrained model");
    // "pretrain" the GELU model, then evaluate the checkpoint under
    // (a) GELU fwd (exact), (b) ReGELU2 (same fwd, approx bwd),
    // (c) ReLU fwd (changed forward).
    let pre = artifact("vitt_loraqv_gelu_ln")?;
    let mut t = Trainer::new(pre, TrainCfg {
        steps,
        lr: 1.25e-3,
        log_every: 0,
        ..Default::default()
    })?;
    let rep = t.train()?;
    let ck = Checkpoint::from_params(&pre.manifest, &t.params);
    println!("  pretrained eval acc: {:.3}", rep.eval_metric);
    for (label, preset) in [
        ("ReGELU2 (fwd unchanged)", "vitt_loraqv_regelu2_ln"),
        ("ReLU forward (changed)", "vitt_loraqv_relu_ln"),
    ] {
        let art = artifact(preset)?;
        let mut t2 = Trainer::new(art, TrainCfg {
            steps: 1,
            log_every: 0,
            ..Default::default()
        })?;
        let restored = ck.restore(&art.manifest, &mut t2.params)?;
        let (loss, acc) = t2.evaluate(1_000_000, 8)?;
        println!("  {label:<26} restored {restored} tensors → eval acc \
                  {acc:.3} (loss {loss:.3})");
    }
    println!("\n(paper: no-tuning MMLU 35.6% → 23.4% when replacing the \
              SiLU forward; ReGELU2/ReSiLU2 keep the forward bit-exact)");
    Ok(())
}

/// Appendix E + I: re-derive a*, c* with the SA + Nelder–Mead solver.
pub fn appe(args: &Args) -> Result<()> {
    let seeds = args.usize_or("seeds", 1)? as u64;
    println!("Appendix E — re-deriving the ReLU-combination coefficients");
    let gb = gelu_bound(1e-8);
    let sb = silu_bound(1e-8);
    println!("  tail bounds (ε=1e-8): gelu ±{gb:.3}, silu ±{sb:.1}");

    for seed in 0..seeds {
        let g = solve_gelu(seed);
        println!("\n  GELU (seed {seed}):");
        println!("    ours : a={:?} c={:?} obj={:.6}", g.comb.a, g.comb.c,
                 g.objective);
        println!("    paper: a={:?} c={:?} obj={:.6}", PAPER_GELU.a,
                 PAPER_GELU.c, objective(&gelu, &PAPER_GELU, -gb, gb));
        let s = solve_silu(seed);
        println!("  SiLU (seed {seed}):");
        println!("    ours : a={:?} c={:?} obj={:.6}", s.comb.a, s.comb.c,
                 s.objective);
        println!("    paper: a={:?} c={:?} obj={:.6}", PAPER_SILU.a,
                 PAPER_SILU.c, objective(&silu, &PAPER_SILU, -sb, sb));
        let d = solve_gelu_d(seed);
        println!("  ReGELU2-d (Appendix I, derivative objective):");
        println!("    ours : a={:?} c={:?} obj={:.6}", d.comb.a, d.comb.c,
                 d.objective);
        println!("    paper: a={:?} c={:?} obj={:.6}", PAPER_GELU_D.a,
                 PAPER_GELU_D.c,
                 objective_d(&dgelu, &PAPER_GELU_D, -8.0, 8.0));
    }
    println!("\n  constraint eq.(13) residual at our solutions: \
              gelu={:.4}, silu={:.4}",
             solve_gelu(0).comb.constraint(),
             solve_silu(0).comb.constraint());
    Ok(())
}
