//! Shared experiment plumbing: artifact cache, short training runs,
//! paper-scale extrapolation, row formatting.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::coordinator::scheduler::Schedule;
use crate::coordinator::{TrainCfg, TrainReport, Trainer};
use crate::memmodel::ops::{ActKind, NormKind, Tuning};
use crate::runtime::{Artifact, Runtime};
use crate::util::cli::Args;

thread_local! {
    // Backends may be !Send (the PJRT client is Rc-based): keep the
    // runtime and the artifact cache per-thread. The experiment harness
    // is effectively single-threaded; leaking is intentional
    // process-lifetime caching. Backend selectable via AMBP_BACKEND
    // (the harness has no CLI plumbing of its own) — needed to run the
    // Mesa/ReLU/ckpt variants on a pjrt-enabled build.
    static RUNTIME: &'static Runtime = Box::leak(Box::new(
        Runtime::from_name(
            &std::env::var("AMBP_BACKEND")
                .unwrap_or_else(|_| "native".into()),
        )
        .expect("experiment runtime (AMBP_BACKEND)"),
    ));
    static ARTIFACTS: std::cell::RefCell<BTreeMap<String, &'static Artifact>> =
        const { std::cell::RefCell::new(BTreeMap::new()) };
}

pub fn runtime() -> &'static Runtime {
    RUNTIME.with(|rt| *rt)
}

/// Load (and cache for the thread lifetime) a preset's artifact.
pub fn artifact(preset: &str) -> Result<&'static Artifact> {
    ARTIFACTS.with(|cell| {
        let mut map = cell.borrow_mut();
        if let Some(a) = map.get(preset) {
            return Ok(*a);
        }
        // on-disk artifact if built, native synthesis otherwise
        let art = crate::runtime::load_or_synth(runtime(), preset)
            .with_context(|| format!("loading {preset}"))?;
        let leaked: &'static Artifact = Box::leak(Box::new(art));
        map.insert(preset.to_string(), leaked);
        Ok(leaked)
    })
}

/// Short measured fine-tuning run of a preset.
pub fn train_preset(preset: &str, steps: usize, lr: f32,
                    seed: u64) -> Result<TrainReport> {
    let art = artifact(preset)?;
    let cfg = TrainCfg {
        steps,
        lr,
        seed,
        log_every: 0,
        schedule: Schedule::WarmupCosine {
            warmup: (steps / 10).max(1),
            warmup_init: 1e-6,
        },
        eval_batches: 8,
        ..Default::default()
    };
    let mut t = Trainer::new(art, cfg)?;
    t.train()
}

/// Map a preset naming suffix to memmodel kinds.
pub fn act_kind(s: &str) -> ActKind {
    match s {
        "regelu2" => ActKind::ReGelu2,
        "regelu2d" => ActKind::ReGelu2d,
        "resilu2" => ActKind::ReSilu2,
        "relu" => ActKind::Relu,
        "mesa" | "mesa_gelu8" => ActKind::MesaGelu8,
        "mesa_silu8" => ActKind::MesaSilu8,
        "silu" => ActKind::Silu,
        _ => ActKind::Gelu,
    }
}

pub fn norm_kind(s: &str) -> NormKind {
    match s {
        "msln" => NormKind::MsLn,
        "rms" => NormKind::Rms,
        "msrms" => NormKind::MsRms,
        "mesaln" | "mesa_ln8" => NormKind::MesaLn8,
        _ => NormKind::Ln,
    }
}

pub fn tuning_kind(s: &str) -> Tuning {
    match s {
        "full" => Tuning::Full,
        "loraall" | "lora_all" => Tuning::LoraAll,
        "lorafaqv" | "lorafa_qv" => Tuning::LoraFaQv,
        "lorafaall" | "lorafa_all" => Tuning::LoraFaAll,
        "frozen" => Tuning::Frozen,
        _ => Tuning::LoraQv,
    }
}

pub fn pct(ours: f64, base: f64) -> String {
    if base <= 0.0 {
        return "--".into();
    }
    format!("{:+.0}%", 100.0 * (ours - base) / base)
}

pub fn default_steps(args: &Args, d: usize) -> usize {
    args.usize_or("steps", d).unwrap_or(d)
}

pub fn hline(width: usize) {
    println!("{}", "-".repeat(width));
}
