//! Experiment harness: one runner per paper table/figure (`ambp exp <id>`).
//!
//! Each runner prints the paper-style rows. Measured numbers come from
//! short fine-tuning runs of the small presets on this testbed; the
//! paper-scale memory columns come from the analytical memmodel at
//! ViT-B/L / LLaMA-7B/13B dimensions (DESIGN.md §3/§4).

pub mod appendix;
pub mod figs;
pub mod helpers;
pub mod tables;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "fig1" => figs::fig1(args),
        "fig2" => figs::fig2(args),
        "fig3" | "fig7" | "fig8" => figs::fig3(args),
        "fig4" => figs::fig4(args),
        "fig5" => figs::fig5(args),
        "fig6" => figs::fig6(args),
        "tab1" => tables::tab1(args),
        "tab2" => tables::tab2(args),
        "tab3" => tables::tab3(args),
        "tab4" => tables::tab4(args),
        "tab5" => tables::tab5(args),
        "tab6" => tables::tab6(args),
        "tab7" => tables::tab7(args),
        "tab8" => tables::tab8(args),
        "tab9" => tables::tab9(args),
        "tab10" => tables::tab10(args),
        "tab11" => tables::tab11(args),
        "tab12" => tables::tab12(args),
        "appc" => appendix::appc(args),
        "appe" => appendix::appe(args),
        "all" => {
            for id in [
                "fig2", "fig3", "fig5", "fig6", "tab5", "tab9", "tab10",
                "tab11", "tab12", "appe", // analytic/cheap first
                "fig1", "fig4", "tab1", "tab2", "tab3", "tab4", "tab6",
                "tab7", "tab8", "appc",
            ] {
                println!("\n════════ exp {id} ════════");
                run(id, args)?;
            }
            Ok(())
        }
        other => bail!(
            "unknown experiment {other:?}; try fig1..fig8, tab1..tab12, \
             appc, appe, all"
        ),
    }
}
