//! Table reproductions (1–12).

use anyhow::Result;

use crate::memmodel::ops::{ActKind, NormKind, Tuning};
use crate::memmodel::report::{gib, mib, peak};
use crate::memmodel::{presets as mp, total_bytes};
use crate::quant::nf4;
use crate::util::cli::Args;

use super::helpers::*;

struct Row {
    label: String,
    top1: f32,
    mem_mib: f64,
    thr: f64,
}

fn print_rows(title: &str, rows: &[Row], big_est: Option<Vec<f64>>) {
    println!("{title}");
    let has_big = big_est.is_some();
    print!("{:<26} {:>9} {:>12} {:>9} {:>12} {:>9}", "variant",
           "top1/acc", "mem (MiB)", "Δmem", "thr (sps)", "Δthr");
    if has_big {
        print!(" {:>14}", "paper-scale");
    }
    println!();
    hline(if has_big { 100 } else { 84 });
    let base = &rows[0];
    for (i, r) in rows.iter().enumerate() {
        print!("{:<26} {:>9.3} {:>12.1} {:>9} {:>12.1} {:>9}",
               r.label, r.top1, r.mem_mib,
               pct(r.mem_mib, base.mem_mib), r.thr, pct(r.thr, base.thr));
        if let Some(big) = &big_est {
            print!(" {:>11.2} GiB", big[i]);
        }
        println!();
    }
}

/// Measure one (preset, label) row.
fn row(label: &str, preset: &str, steps: usize, lr: f32,
       seed: u64) -> Result<Row> {
    let rep = train_preset(preset, steps, lr, seed)?;
    Ok(Row {
        label: label.to_string(),
        top1: rep.eval_metric,
        mem_mib: rep.peak_activation_bytes as f64 / 1048576.0,
        thr: rep.throughput,
    })
}

/// Table 1: ViT-base LoRA / LoRA-FA across activation × norm variants.
pub fn tab1(args: &Args) -> Result<()> {
    let steps = default_steps(args, 40);
    for (tun_tag, tun_label, tun) in [
        ("loraqv", "LoRA r=4 (adapt Q,V)", Tuning::LoraQv),
        ("loraall", "LoRA r=4 (adapt all linear)", Tuning::LoraAll),
    ] {
        // the Mesa row is the `_mesa` suffix preset: int8 act + norm
        // saves, measured natively. The paper's per-site Mesa-GELU /
        // Mesa-LN ablation rows are intentionally dropped from this
        // table (the native axis quantizes both sites at once); the
        // per-site analytics stay reachable via `ambp mem --act mesa
        // --norm mesaln` (ActKind::MesaGelu8 / NormKind::MesaLn8).
        let variants = [
            ("GELU + LN", "gelu_ln", ActKind::Gelu, NormKind::Ln),
            ("ReGELU2 + LN", "regelu2_ln", ActKind::ReGelu2, NormKind::Ln),
            ("GELU + MS-LN", "gelu_msln", ActKind::Gelu, NormKind::MsLn),
            ("Mesa int8 (act+norm)", "gelu_ln_mesa", ActKind::MesaGelu8,
             NormKind::MesaLn8),
            ("ReGELU2 + MS-LN", "regelu2_msln", ActKind::ReGelu2,
             NormKind::MsLn),
        ];
        let mut rows = Vec::new();
        let mut big = Vec::new();
        for (label, suffix, act, norm) in variants {
            rows.push(row(label, &format!("vitt_{tun_tag}_{suffix}"),
                          steps, 1.25e-3, 0)?);
            big.push(gib(peak(&mp::vit_base(64, tun, act, norm), 16.0)
                         .total));
        }
        print_rows(&format!("\nTable 1 — {tun_label} (paper −29%/-30% for \
                             ours)"), &rows, Some(big));
    }
    // LoRA-FA: MS-LN gives no extra win (Prop 5.1 cond. 3) → ReGELU2 only
    let mut rows = Vec::new();
    let mut big = Vec::new();
    for (label, suffix, act, norm) in [
        ("GELU + LN", "gelu_ln", ActKind::Gelu, NormKind::Ln),
        ("Mesa int8 (act+norm)", "gelu_ln_mesa", ActKind::MesaGelu8,
         NormKind::MesaLn8),
        ("ReGELU2 + LN", "regelu2_ln", ActKind::ReGelu2, NormKind::Ln),
    ] {
        rows.push(row(label, &format!("vitt_lorafaqv_{suffix}"), steps,
                      1.25e-3, 0)?);
        big.push(gib(peak(&mp::vit_base(64, Tuning::LoraFaQv, act, norm),
                          16.0).total));
    }
    print_rows("\nTable 1 — LoRA-FA r=4 (adapt Q,V; paper −23% for \
                ReGELU2)", &rows, Some(big));
    Ok(())
}

/// Table 2: full fine-tuning, ViT-base + ViT-large extrapolation.
pub fn tab2(args: &Args) -> Result<()> {
    let steps = default_steps(args, 40);
    let variants = [
        ("GELU + LN", "gelu_ln", ActKind::Gelu, NormKind::Ln),
        ("ReGELU2 + LN", "regelu2_ln", ActKind::ReGelu2, NormKind::Ln),
        ("GELU + MS-LN", "gelu_msln", ActKind::Gelu, NormKind::MsLn),
        ("ReGELU2 + MS-LN", "regelu2_msln", ActKind::ReGelu2,
         NormKind::MsLn),
    ];
    let mut rows = Vec::new();
    let mut big = Vec::new();
    for (label, suffix, act, norm) in variants {
        rows.push(row(label, &format!("vitt_full_{suffix}"), steps,
                      1.25e-5 * 100.0, 0)?);
        let b = gib(peak(&mp::vit_base(64, Tuning::Full, act, norm), 16.0)
                    .total);
        let l = gib(peak(&mp::vit_large(64, Tuning::Full, act, norm),
                         16.0).total);
        big.push(b + l * 0.0); // base col; large printed separately below
    }
    print_rows("\nTable 2 — Full-Tuning ViT (paper −27% for ours)",
               &rows, Some(big));
    println!("\nViT-large peak estimates (paper: 15.7 → 11.5 GiB):");
    for (label, _, act, norm) in variants {
        let est = peak(&mp::vit_large(64, Tuning::Full, act, norm), 16.0);
        println!("  {:<18} {:>8.2} GiB", label, gib(est.total));
    }
    Ok(())
}

/// Table 3: LLaMA QLoRA-sim (NF4 weights + LoRA-all + Alpaca stand-in).
pub fn tab3(args: &Args) -> Result<()> {
    let steps = default_steps(args, 30);
    let variants = [
        ("SiLU + RMSNorm", "silu_rms", ActKind::Silu, NormKind::Rms),
        ("ReSiLU2 + RMSNorm", "resilu2_rms", ActKind::ReSilu2,
         NormKind::Rms),
        ("SiLU + MS-RMSNorm", "silu_msrms", ActKind::Silu,
         NormKind::MsRms),
        ("ReSiLU2 + MS-RMSNorm", "resilu2_msrms", ActKind::ReSilu2,
         NormKind::MsRms),
    ];
    let mut rows = Vec::new();
    let mut big = Vec::new();
    for (label, suffix, act, norm) in variants {
        rows.push(row(label, &format!("llama_loraall_{suffix}"), steps,
                      1e-4 * 20.0, 0)?);
        // QLoRA: NF4 weights (bits_per_elem@block64) + bf16 activations
        let cfg7 = mp::llama7b(4, 512, act, norm);
        big.push(gib(peak(&cfg7, nf4::bits_per_elem(64)).total));
    }
    print_rows("\nTable 3 — LLaMA-style QLoRA (paper: 20.6 → 14.6 GiB on \
                7B, −29%)", &rows, Some(big));
    println!("\nLLaMA-13B peak estimates (paper: 31.4 → 22.3 GiB):");
    for (label, _, act, norm) in variants {
        let est = peak(&mp::llama13b(4, 512, act, norm),
                       nf4::bits_per_elem(64));
        println!("  {:<22} {:>8.2} GiB", label, gib(est.total));
    }
    Ok(())
}

/// Table 4: RoBERTa-style LoRA on 5 synthetic GLUE stand-in tasks.
pub fn tab4(args: &Args) -> Result<()> {
    let steps = default_steps(args, 30);
    let tasks = ["CoLA*", "SST-2*", "MRPC*", "STS-B*", "RTE*"];
    let variants = [
        ("GELU + LN", "gelu_ln"),
        ("ReGELU2 + LN", "regelu2_ln"),
        ("GELU + MS-LN", "gelu_msln"),
        ("ReGELU2 + MS-LN", "regelu2_msln"),
    ];
    println!("\nTable 4 — RoBERTa-style LoRA r=4, 5 synthetic tasks \
              (* = synthetic stand-in; paper −21% mem for ours)");
    print!("{:<18}", "variant");
    for t in tasks {
        print!(" {t:>8}");
    }
    println!(" {:>8} {:>12} {:>12}", "mean", "mem (MiB)", "thr (sps)");
    hline(100);
    let mut base_mem = 0.0;
    for (label, suffix) in variants {
        let mut accs = Vec::new();
        let mut mem = 0f64;
        let mut thr = 0f64;
        for (ti, _) in tasks.iter().enumerate() {
            let rep = train_preset(&format!("rob_loraall_{suffix}"),
                                   steps, 5e-4, ti as u64)?;
            accs.push(rep.eval_metric);
            mem = rep.peak_activation_bytes as f64 / 1048576.0;
            thr += rep.throughput / tasks.len() as f64;
        }
        if base_mem == 0.0 {
            base_mem = mem;
        }
        let mean: f32 = accs.iter().sum::<f32>() / accs.len() as f32;
        print!("{label:<18}");
        for a in &accs {
            print!(" {a:>8.3}");
        }
        println!(" {:>8.3} {:>7.1} ({:>4}) {:>12.1}", mean, mem,
                 pct(mem, base_mem), thr);
    }
    Ok(())
}

/// Table 5: qualitative comparison matrix (+ programmatic evidence).
pub fn tab5(_args: &Args) -> Result<()> {
    println!("Table 5 — qualitative comparison");
    println!("{:<12} {:>11} {:>17} {:>12}", "method", "non-linear",
             "keep throughput", "beyond LoRA");
    hline(56);
    for (m, a, b, c) in [
        ("Freeze", "x", "ok", "ok"),
        ("CKPT", "ok", "x", "ok"),
        ("ACT/Mesa", "ok", "x", "ok"),
        ("LoRA-FA", "x", "ok", "x"),
        ("Ours", "ok", "ok", "ok"),
    ] {
        println!("{m:<12} {a:>11} {b:>17} {c:>12}");
    }
    println!("\nprogrammatic evidence (analytical, ViT-B LoRA bs=64):");
    let base = total_bytes(&mp::vit_base(64, Tuning::LoraQv,
                                         ActKind::Gelu, NormKind::Ln));
    let ours = total_bytes(&mp::vit_base(64, Tuning::LoraQv,
                                         ActKind::ReGelu2, NormKind::MsLn));
    println!("  ours reduces non-linear activation bytes: {:.0} → {:.0} \
              MiB ({})", mib(base), mib(ours),
             pct(mib(ours), mib(base)));
    Ok(())
}

/// Table 6 / Appendix I: ReGELU2-d (derivative-matching) ablation.
pub fn tab6(args: &Args) -> Result<()> {
    let steps = default_steps(args, 40);
    println!("Table 6 — optimization-objective ablation (paper: ReGELU2 ≥ \
              ReGELU2-d on every dataset)");
    println!("{:<16} {:>10} {:>10} {:>10}", "activation", "task0",
             "task1", "mean");
    hline(50);
    for (label, preset) in [
        ("GELU", "vitt_loraqv_gelu_ln"),
        ("ReGELU2-d", "vitt_loraqv_regelu2d_ln"),
        ("ReGELU2", "vitt_loraqv_regelu2_ln"),
    ] {
        let mut accs = Vec::new();
        for seed in 0..2 {
            accs.push(train_preset(preset, steps, 1.25e-3, seed)?
                      .eval_metric);
        }
        let mean: f32 = accs.iter().sum::<f32>() / accs.len() as f32;
        println!("{:<16} {:>10.3} {:>10.3} {:>10.3}", label, accs[0],
                 accs[1], mean);
    }
    Ok(())
}

/// Table 7: expanded ViT table — 7 synthetic tasks (incl. ReLU row).
pub fn tab7(args: &Args) -> Result<()> {
    let steps = default_steps(args, 30);
    let n_tasks = args.usize_or("tasks", 3)?;
    println!("\nTable 7 — per-dataset expansion, LoRA q,v ({n_tasks} \
              synthetic tasks; paper: ReLU degrades, ReGELU2 ≈ GELU)");
    print!("{:<16}", "activation");
    for t in 0..n_tasks {
        print!("  task{t:>4}");
    }
    println!(" {:>8} {:>12}", "mean", "mem (MiB)");
    hline(70);
    for (label, preset) in [
        ("GELU", "vitt_loraqv_gelu_ln"),
        ("ReLU", "vitt_loraqv_relu_ln"),
        ("Mesa int8", "vitt_loraqv_gelu_ln_mesa"),
        ("ReGELU2", "vitt_loraqv_regelu2_ln"),
        ("ReGELU2+MS-LN", "vitt_loraqv_regelu2_msln"),
    ] {
        let mut accs = Vec::new();
        let mut mem = 0.0;
        let mut err = None;
        for t in 0..n_tasks {
            // every row (ReLU since the Layer/Tape refactor, Mesa via
            // the `_mesa` int8 tape slots) synthesizes natively; keep
            // the per-row resilience for non-default backends
            match train_preset(preset, steps, 1.25e-3, t as u64) {
                Ok(rep) => {
                    accs.push(rep.eval_metric);
                    mem = rep.peak_activation_bytes as f64 / 1048576.0;
                }
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = err {
            println!("{label:<16} [unavailable: {e}]");
            continue;
        }
        let mean: f32 = accs.iter().sum::<f32>() / accs.len() as f32;
        print!("{label:<16}");
        for a in &accs {
            print!("  {a:>7.3}");
        }
        println!(" {mean:>8.3} {mem:>12.1}");
    }
    Ok(())
}

/// Table 8: supplementary LLaMA metrics — 7 held-out eval suites.
pub fn tab8(args: &Args) -> Result<()> {
    let steps = default_steps(args, 30);
    let suites = ["BoolQ*", "PIQA*", "SIQA*", "HS*", "WG*", "ARC*",
                  "OBQA*"];
    println!("\nTable 8 — supplementary eval suites (synthetic stand-ins; \
              paper: ours ≈ baseline across the board)");
    print!("{:<22}", "checkpoint");
    for s in suites {
        print!(" {s:>7}");
    }
    println!();
    hline(80);
    for (label, preset) in [
        ("fine-tuned (baseline)", "llama_loraall_silu_rms"),
        ("with ReSiLU2+MS-RMS", "llama_loraall_resilu2_msrms"),
    ] {
        let art = artifact(preset)?;
        let mut t = crate::coordinator::Trainer::new(
            art,
            crate::coordinator::TrainCfg {
                steps,
                lr: 2e-3,
                log_every: 0,
                ..Default::default()
            },
        )?;
        let _ = t.train()?;
        print!("{label:<22}");
        for (si, _) in suites.iter().enumerate() {
            // each "suite" = a disjoint held-out slice of the task space
            let (_, acc) = t.evaluate(100_000 + si * 1000, 4)?;
            print!(" {acc:>7.3}");
        }
        println!();
    }
    Ok(())
}

/// Table 9: max affordable sequence length under a fixed memory budget.
pub fn tab9(args: &Args) -> Result<()> {
    let budget_gib = args.f64_or("budget", 24.0)?; // RTX4090
    println!("Table 9 — max trainable sequence length, LLaMA-7B QLoRA, \
              bs=1, {budget_gib:.0} GiB budget (paper: +46% for ours)");
    let mut base_len = 0usize;
    for (label, act, norm) in [
        ("SiLU + RMSNorm", ActKind::Silu, NormKind::Rms),
        ("ReSiLU2 + RMSNorm", ActKind::ReSilu2, NormKind::Rms),
        ("SiLU + MS-RMSNorm", ActKind::Silu, NormKind::MsRms),
        ("ReSiLU2 + MS-RMSNorm", ActKind::ReSilu2, NormKind::MsRms),
    ] {
        // binary search the longest sequence fitting the budget
        let fits = |seq: usize| -> bool {
            let cfg = mp::llama7b(1, seq, act, norm);
            gib(peak(&cfg, nf4::bits_per_elem(64)).total) <= budget_gib
        };
        let (mut lo, mut hi) = (256usize, 1_048_576usize);
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if fits(mid) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        if base_len == 0 {
            base_len = lo;
        }
        println!("  {:<22} {:>8} tokens  ({})", label, lo,
                 pct(lo as f64, base_len as f64));
    }
    Ok(())
}

/// Table 10: Swin + RetinaNet detection proxy (analytical).
pub fn tab10(_args: &Args) -> Result<()> {
    println!("Table 10 — Swin-T full-tuning detection proxy \
              (paper: −18% total memory)");
    let mut base = 0.0;
    for (label, act, norm) in [
        ("GELU + LN", ActKind::Gelu, NormKind::Ln),
        ("ReGELU2 + MS-LN", ActKind::ReGelu2, NormKind::MsLn),
    ] {
        let cfg = mp::swin_tiny(4, act, norm);
        // detection head/neck ≈ fixed extra workspace (backbone dominates)
        let est = peak(&cfg, 32.0);
        let total = gib(est.total) + 1.5;
        if base == 0.0 {
            base = total;
        }
        println!("  {:<18} {:>7.2} GiB  ({})", label, total,
                 pct(total, base));
    }
    Ok(())
}

/// Table 11: BERT-base max batch via memory budget (+ throughput note).
pub fn tab11(args: &Args) -> Result<()> {
    let budget_gib = args.f64_or("budget", 12.0)?; // RTX3060
    println!("Table 11 — BERT-base full-tuning max batch per GPU, \
              {budget_gib:.0} GiB (paper: 30 → 36, +20%)");
    let mut base = 0usize;
    for (label, act, norm) in [
        ("GELU + LN", ActKind::Gelu, NormKind::Ln),
        ("ReGELU2 + MS-LN", ActKind::ReGelu2, NormKind::MsLn),
    ] {
        let fits = |b: usize| {
            gib(peak(&mp::bert_base(b, 384, act, norm), 32.0).total)
                <= budget_gib
        };
        let mut b = 1;
        while fits(b + 1) && b < 4096 {
            b += 1;
        }
        if base == 0 {
            base = b;
        }
        println!("  {:<18} batch {:>4}  ({})", label, b,
                 pct(b as f64, base as f64));
    }
    Ok(())
}

/// Table 12: BERT-large ZeRO-3 throughput model (+26% via bigger batch).
pub fn tab12(args: &Args) -> Result<()> {
    let budget_gib = args.f64_or("budget", 12.0)?;
    let n_gpus = 4.0;
    println!("Table 12 — BERT-large ZeRO3+offload data-parallel \
              throughput model, {n_gpus:.0} GPUs (paper: +26%)");
    // ZeRO-3: per-step cost = compute(batch) + comm(params) — a bigger
    // affordable batch amortizes the (fixed) parameter all-gather.
    let comm_cost = 2.0; // normalized fixed cost per step
    let mut base_thr = 0.0;
    for (label, act, norm) in [
        ("GELU + LN", ActKind::Gelu, NormKind::Ln),
        ("ReGELU2 + MS-LN", ActKind::ReGelu2, NormKind::MsLn),
    ] {
        let fits = |b: usize| {
            gib(peak(&mp::bert_large(b, 384, act, norm), 32.0).total)
                <= budget_gib
        };
        let mut b = 1;
        while fits(b + 1) && b < 4096 {
            b += 1;
        }
        let thr = n_gpus * b as f64 / (b as f64 + comm_cost);
        if base_thr == 0.0 {
            base_thr = thr;
        }
        println!("  {:<18} batch {:>4}  model-thr {:>6.2} ({})", label, b,
                 thr, pct(thr, base_thr));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::ops::{Arch, MemCfg, Mode};

    #[test]
    fn tab9_budget_search_monotone() {
        // sanity on the binary search: larger budget → longer sequence
        let len = |budget: f64| {
            let fits = |seq: usize| {
                gib(peak(&mp::llama7b(1, seq, ActKind::Silu, NormKind::Rms),
                         4.5).total) <= budget
            };
            let (mut lo, mut hi) = (256usize, 1_048_576usize);
            while lo < hi {
                let mid = (lo + hi + 1) / 2;
                if fits(mid) { lo = mid } else { hi = mid - 1 }
            }
            lo
        };
        assert!(len(30.0) > len(20.0));
    }

    #[test]
    fn ours_extends_sequence_length() {
        // Table 9 shape: ReSiLU2+MS-RMSNorm affords longer sequences
        let max_len = |act: ActKind, norm: NormKind| {
            let fits = |seq: usize| {
                gib(peak(&mp::llama7b(1, seq, act, norm), 4.5).total)
                    <= 24.0
            };
            let (mut lo, mut hi) = (256usize, 1_048_576usize);
            while lo < hi {
                let mid = (lo + hi + 1) / 2;
                if fits(mid) { lo = mid } else { hi = mid - 1 }
            }
            lo
        };
        let base = max_len(ActKind::Silu, NormKind::Rms);
        let ours = max_len(ActKind::ReSilu2, NormKind::MsRms);
        let gain = ours as f64 / base as f64;
        assert!(gain > 1.2, "gain {gain}");
    }

    #[test]
    fn memcfg_is_send_static() {
        fn assert_send<T: Send + 'static>() {}
        assert_send::<MemCfg>();
        let _ = Mode::Paper;
        let _ = Arch::Vit;
    }
}
