//! Figure reproductions (1–8).

use anyhow::Result;

use crate::coeffs::funcs::{gelu, silu, PAPER_GELU, PAPER_SILU};
use crate::memmodel::ops::{ActKind, NormKind, Tuning};
use crate::memmodel::{block_units, by_category, presets as mp, total_bytes};
use crate::memmodel::report::{composition_rows, mib, peak};
use crate::util::cli::Args;

use super::helpers::*;

/// Figure 1: LoRA vs +CKPT vs +Mesa vs +Ours — throughput & memory.
pub fn fig1(args: &Args) -> Result<()> {
    let steps = default_steps(args, 30);
    println!("Figure 1 — fine-tuning ViT-style with LoRA r=4 (measured on \
              this testbed; ViT-B column = analytical model @ bs=64)");
    println!("{:<18} {:>12} {:>14} {:>16} {:>14}", "variant",
             "thr (img/s)", "act mem (MiB)", "Δmem vs LoRA", "ViT-B est GiB");
    hline(84);
    let variants: [(&str, &str, ActKind, NormKind, bool); 4] = [
        ("LoRA", "vitt_loraqv_gelu_ln", ActKind::Gelu, NormKind::Ln, false),
        ("LoRA + CKPT", "vitt_loraqv_gelu_ln_ckpt", ActKind::Gelu,
         NormKind::Ln, true),
        ("LoRA + Mesa", "vitt_loraqv_gelu_ln_mesa", ActKind::MesaGelu8,
         NormKind::MesaLn8, false),
        ("LoRA + Ours", "vitt_loraqv_regelu2_msln", ActKind::ReGelu2,
         NormKind::MsLn, false),
    ];
    let mut base_mem = 0f64;
    for (label, preset, act, norm, ckpt) in variants {
        // every row — Mesa included, via the `_mesa` int8 tape slots —
        // runs on the synthesized native presets; a row only degrades
        // to [unavailable] on a non-default AMBP_BACKEND that cannot
        // execute it
        let rep = match train_preset(preset, steps, 1.25e-3, 0) {
            Ok(rep) => rep,
            Err(e) => {
                println!("{label:<18} [unavailable: {e}]");
                continue;
            }
        };
        let act_mib = rep.peak_activation_bytes as f64 / 1048576.0;
        if label == "LoRA" {
            base_mem = act_mib;
        }
        let mut big = mp::vit_base(64, Tuning::LoraQv, act, norm);
        big.ckpt = ckpt;
        let est = peak(&big, 16.0);
        println!("{:<18} {:>12.1} {:>14.1} {:>16} {:>14.2}", label,
                 rep.throughput, act_mib, pct(act_mib, base_mem),
                 est.total as f64 / 1073741824.0);
    }
    println!("\n(CKPT trades ~recompute time for memory; Mesa trades \
              quant/dequant time; Ours reduces memory at baseline speed — \
              the Figure 1 shape.)");
    Ok(())
}

/// Figure 2: composition of activation memory (ViT-B and LLaMA-13B).
pub fn fig2(_args: &Args) -> Result<()> {
    println!("Figure 2 — activation-memory composition (analytical, \
              paper-mode accounting)");
    for (name, cfg) in [
        ("ViT-B (LoRA q,v bs=64 n=197)",
         mp::vit_base(64, Tuning::LoraQv, ActKind::Gelu, NormKind::Ln)),
        ("LLaMA-13B (LoRA all, bs=4, seq=2048)",
         mp::llama13b(4, 2048, ActKind::Silu, NormKind::Rms)),
    ] {
        println!("\n  {name}  (total {:.0} MiB)",
                 mib(total_bytes(&cfg)));
        for (cat, pctg) in composition_rows(&cfg) {
            println!("    {:<16} {:>5.1}%", cat, pctg);
        }
    }
    println!("\n  paper: GELU+LN ≈ 21% each in ViT; SiLU 12.4% + RMSNorm \
              18.4% in LLaMA (split parts of the pies)");
    Ok(())
}

/// Figures 3/7/8: ReGELU2 / ReSiLU2 curves + 4-segment derivative.
pub fn fig3(args: &Args) -> Result<()> {
    let n = default_steps(args, 33);
    println!("Figures 3/7/8 — primitive vs h̃ and the 2-bit step derivative");
    println!("{:>8} {:>10} {:>10} {:>7} | {:>10} {:>10} {:>7}",
             "x", "gelu", "h̃_gelu", "dh̃", "silu", "h̃_silu", "dh̃");
    for i in 0..n {
        let x = -8.0 + 16.0 * i as f64 / (n - 1) as f64;
        println!(
            "{:>8.3} {:>10.5} {:>10.5} {:>7.4} | {:>10.5} {:>10.5} {:>7.4}",
            x, gelu(x), PAPER_GELU.eval(x), PAPER_GELU.derivative(x),
            silu(x), PAPER_SILU.eval(x), PAPER_SILU.derivative(x));
    }
    Ok(())
}

/// Figure 4: convergence of ReGELU2 / MS-LN vs baselines (LoRA ViT).
pub fn fig4(args: &Args) -> Result<()> {
    let steps = default_steps(args, 60);
    let seeds: u64 = args.usize_or("seeds", 2)? as u64;
    println!("Figure 4 — training-loss curves, LoRA r=4 ViT-style \
              ({seeds} seeds)");
    let variants = [
        ("GELU+LN", "vitt_loraqv_gelu_ln"),
        ("ReGELU2+LN", "vitt_loraqv_regelu2_ln"),
        ("GELU+MS-LN", "vitt_loraqv_gelu_msln"),
        ("ReGELU2+MS-LN", "vitt_loraqv_regelu2_msln"),
    ];
    let mut curves: Vec<(&str, Vec<f32>)> = Vec::new();
    for (label, preset) in variants {
        let mut acc = vec![0f32; steps];
        for s in 0..seeds {
            let rep = train_preset(preset, steps, 1.25e-3, s)?;
            for (a, r) in acc.iter_mut().zip(&rep.rows) {
                *a += r.loss / seeds as f32;
            }
        }
        curves.push((label, acc));
    }
    print!("{:>6}", "step");
    for (label, _) in &curves {
        print!(" {label:>14}");
    }
    println!();
    for i in (0..steps).step_by((steps / 15).max(1)) {
        print!("{i:>6}");
        for (_, c) in &curves {
            print!(" {:>14.4}", c[i]);
        }
        println!();
    }
    println!("\n(paper: ReGELU2 tracks GELU; MS-LN converges slightly \
              faster)");
    Ok(())
}

/// Figure 5: ViT per-block activation units.
pub fn fig5(_args: &Args) -> Result<()> {
    println!("Figure 5 — ViT block activation memory \
              (units of one 16-bit [b,n,c] tensor; paper: 19 / 12 / 11.5)");
    for (label, tun, act, norm) in [
        ("trainable (GELU+LN)", Tuning::Full, ActKind::Gelu, NormKind::Ln),
        ("frozen    (GELU+LN)", Tuning::Frozen, ActKind::Gelu, NormKind::Ln),
        ("ours (ReGELU2+MS-LN)", Tuning::Full, ActKind::ReGelu2,
         NormKind::MsLn),
    ] {
        let cfg = mp::vit_base(64, tun, act, norm);
        println!("  {:<22} {:>6.2} units", label, block_units(&cfg));
    }
    Ok(())
}

/// Figure 6: LLaMA per-block activation units.
pub fn fig6(_args: &Args) -> Result<()> {
    println!("Figure 6 — LLaMA-13B block activation memory \
              (paper: 21.8 / 16.1 / 15.4375)");
    for (label, tun, act, norm) in [
        ("trainable (SiLU+RMS)", Tuning::Full, ActKind::Silu, NormKind::Rms),
        ("frozen    (SiLU+RMS)", Tuning::Frozen, ActKind::Silu,
         NormKind::Rms),
        ("ours (ReSiLU2+MS-RMS)", Tuning::Full, ActKind::ReSilu2,
         NormKind::MsRms),
    ] {
        let mut cfg = mp::llama13b(4, 2048, act, norm);
        cfg.tuning = tun;
        println!("  {:<22} {:>6.2} units", label, block_units(&cfg));
    }
    // also show the measured breakdown of the small llama artifact if built
    if let Ok(art) = artifact("llama_loraall_silu_rms") {
        println!("\n  measured small-model residual breakdown \
                  (manifest {}):", art.manifest.preset);
        for (kind, bytes) in art.manifest.residual_bytes_by_kind() {
            println!("    {:<14} {:>10.2} MiB", kind,
                     bytes as f64 / 1048576.0);
        }
    }
    let _ = by_category(&mp::llama13b(4, 2048, ActKind::Silu,
                                      NormKind::Rms));
    Ok(())
}
