//! Run configuration: JSON config files + CLI overrides.
//!
//! A run = (preset artifact, trainer hyper-parameters). Config files are
//! JSON (the in-tree parser); every field can be overridden on the CLI:
//!   ambp train --preset vitt_loraqv_gelu_ln --steps 200 --lr 1e-3

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::scheduler::Schedule;
use crate::coordinator::TrainCfg;
use crate::util::cli::Args;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct RunCfg {
    pub preset: String,
    pub artifacts_dir: PathBuf,
    pub train: TrainCfg,
    pub init_from: Option<PathBuf>,
    pub save_to: Option<PathBuf>,
}

impl RunCfg {
    pub fn from_args(args: &Args) -> Result<RunCfg> {
        // optional JSON config file, then CLI overrides
        let mut cfg = match args.get("config") {
            Some(path) => Self::from_json_file(path)?,
            None => RunCfg {
                preset: "vitt_loraqv_gelu_ln".into(),
                artifacts_dir: crate::runtime::artifacts_dir(),
                train: TrainCfg::default(),
                init_from: None,
                save_to: None,
            },
        };
        if let Some(p) = args.get("preset") {
            cfg.preset = p.to_string();
        }
        if let Some(d) = args.get("artifacts") {
            cfg.artifacts_dir = PathBuf::from(d);
        }
        cfg.train.steps = args.usize_or("steps", cfg.train.steps)?;
        cfg.train.lr = args.f64_or("lr", cfg.train.lr as f64)? as f32;
        cfg.train.weight_decay =
            args.f64_or("weight-decay", cfg.train.weight_decay as f64)?
                as f32;
        cfg.train.grad_accum =
            args.usize_or("grad-accum", cfg.train.grad_accum)?;
        cfg.train.seed = args.usize_or("seed", cfg.train.seed as usize)?
            as u64;
        cfg.train.log_every =
            args.usize_or("log-every", cfg.train.log_every)?;
        if let Some(o) = args.get("optimizer") {
            cfg.train.optimizer = o.to_string();
        }
        if let Some(s) = args.get("schedule") {
            cfg.train.schedule = parse_schedule(s)?;
        }
        if let Some(p) = args.get("metrics") {
            cfg.train.metrics_jsonl = Some(PathBuf::from(p));
        }
        if let Some(p) = args.get("init-from") {
            cfg.init_from = Some(PathBuf::from(p));
        }
        if let Some(p) = args.get("save-to") {
            cfg.save_to = Some(PathBuf::from(p));
        }
        Ok(cfg)
    }

    pub fn from_json_file(path: &str) -> Result<RunCfg> {
        let j = Json::parse(&std::fs::read_to_string(path)?)?;
        let mut train = TrainCfg::default();
        if let Some(t) = j.opt("train") {
            if let Some(v) = t.opt("steps") {
                train.steps = v.as_usize()?;
            }
            if let Some(v) = t.opt("lr") {
                train.lr = v.as_f64()? as f32;
            }
            if let Some(v) = t.opt("weight_decay") {
                train.weight_decay = v.as_f64()? as f32;
            }
            if let Some(v) = t.opt("grad_accum") {
                train.grad_accum = v.as_usize()?;
            }
            if let Some(v) = t.opt("optimizer") {
                train.optimizer = v.as_str()?.to_string();
            }
            if let Some(v) = t.opt("schedule") {
                train.schedule = parse_schedule(v.as_str()?)?;
            }
            if let Some(v) = t.opt("seed") {
                train.seed = v.as_f64()? as u64;
            }
        }
        Ok(RunCfg {
            preset: j.get("preset")?.as_str()?.to_string(),
            artifacts_dir: j
                .opt("artifacts_dir")
                .and_then(|v| v.as_str().ok().map(PathBuf::from))
                .unwrap_or_else(crate::runtime::artifacts_dir),
            train,
            init_from: j
                .opt("init_from")
                .and_then(|v| v.as_str().ok().map(PathBuf::from)),
            save_to: j
                .opt("save_to")
                .and_then(|v| v.as_str().ok().map(PathBuf::from)),
        })
    }
}

pub fn parse_schedule(s: &str) -> Result<Schedule> {
    Ok(match s {
        "constant" => Schedule::Constant,
        "warmup_cosine" => Schedule::WarmupCosine {
            warmup: 10,
            warmup_init: 1e-6,
        },
        "warmup_linear" => Schedule::WarmupLinear { warmup_frac: 0.1 },
        other => anyhow::bail!("unknown schedule {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cli_overrides() {
        let args = Args::parse(&[
            "--preset".into(), "x".into(),
            "--steps".into(), "42".into(),
            "--lr".into(), "0.5".into(),
            "--optimizer".into(), "sgd".into(),
            "--schedule".into(), "constant".into(),
        ]);
        let cfg = RunCfg::from_args(&args).unwrap();
        assert_eq!(cfg.preset, "x");
        assert_eq!(cfg.train.steps, 42);
        assert_eq!(cfg.train.lr, 0.5);
        assert_eq!(cfg.train.optimizer, "sgd");
        assert_eq!(cfg.train.schedule, Schedule::Constant);
    }

    #[test]
    fn json_config_file() {
        let dir = std::env::temp_dir().join("ambp_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{
            "preset": "llama_loraall_silu_rms",
            "train": {"steps": 7, "lr": 0.001, "optimizer": "adamw",
                      "schedule": "constant", "grad_accum": 2}
        }"#).unwrap();
        let cfg = RunCfg::from_json_file(p.to_str().unwrap()).unwrap();
        assert_eq!(cfg.preset, "llama_loraall_silu_rms");
        assert_eq!(cfg.train.steps, 7);
        assert_eq!(cfg.train.grad_accum, 2);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bad_schedule_rejected() {
        assert!(parse_schedule("nope").is_err());
    }
}
