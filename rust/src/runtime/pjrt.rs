//! PJRT/XLA backend (feature `pjrt`, off by default).
//!
//! Loads `artifacts/<preset>/{fwd,bwd}.hlo.txt`, compiles them on the
//! PJRT CPU client, and executes from the training hot path. Wiring
//! follows the HLO *text* interchange path (the text parser reassigns the
//! 64-bit instruction ids jax ≥ 0.5 emits that xla_extension 0.5.1 would
//! reject), `return_tuple=True` on the python side, `to_tuple()` here.
//!
//! NOTE: building with `--features pjrt` additionally requires adding the
//! external `xla` crate to Cargo.toml — it is not available offline and
//! is deliberately kept out of the default dependency graph. See
//! DESIGN.md §2.4.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::{DType, Tensor};
use crate::runtime::{Artifact, Backend, Executor, FwdOut};

fn primitive(dtype: DType) -> xla::PrimitiveType {
    match dtype {
        DType::F32 => xla::PrimitiveType::F32,
        DType::I32 => xla::PrimitiveType::S32,
        DType::U8 => xla::PrimitiveType::U8,
        DType::I8 => xla::PrimitiveType::S8,
    }
}

/// Convert a host tensor to a PJRT literal (copies).
fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let mut lit =
        xla::Literal::create_from_shape(primitive(t.dtype), &t.shape);
    match t.dtype {
        DType::F32 => lit.copy_raw_from::<f32>(t.as_f32())?,
        DType::I32 => lit.copy_raw_from::<i32>(t.as_i32())?,
        DType::U8 => lit.copy_raw_from::<u8>(&t.data)?,
        DType::I8 => lit.copy_raw_from::<i8>(unsafe {
            std::slice::from_raw_parts(
                t.data.as_ptr() as *const i8,
                t.data.len(),
            )
        })?,
    }
    Ok(lit)
}

/// Read a PJRT literal back into a host tensor.
fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let dtype = match shape.primitive_type() {
        xla::PrimitiveType::F32 => DType::F32,
        xla::PrimitiveType::S32 => DType::I32,
        xla::PrimitiveType::U8 => DType::U8,
        xla::PrimitiveType::S8 => DType::I8,
        t => bail!("unsupported literal type {t:?}"),
    };
    let mut t = Tensor::zeros(&dims, dtype);
    match dtype {
        DType::F32 => lit.copy_raw_to::<f32>(t.as_f32_mut())?,
        DType::I32 => {
            let n = t.data.len() / 4;
            let sl = unsafe {
                std::slice::from_raw_parts_mut(
                    t.data.as_mut_ptr() as *mut i32,
                    n,
                )
            };
            lit.copy_raw_to::<i32>(sl)?;
        }
        DType::U8 => lit.copy_raw_to::<u8>(&mut t.data)?,
        DType::I8 => {
            let sl = unsafe {
                std::slice::from_raw_parts_mut(
                    t.data.as_mut_ptr() as *mut i8,
                    t.data.len(),
                )
            };
            lit.copy_raw_to::<i8>(sl)?;
        }
    }
    Ok(t)
}

/// PJRT CPU client wrapper.
pub struct PjrtBackend {
    client: std::rc::Rc<xla::PjRtClient>,
}

impl PjrtBackend {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            client: std::rc::Rc::new(xla::PjRtClient::cpu()?),
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(&self, dir: &Path) -> Result<Artifact> {
        let manifest = Manifest::load(dir)?;
        let fwd = compile(&self.client, &dir.join("fwd.hlo.txt"))
            .with_context(|| format!("compiling fwd for {dir:?}"))?;
        let bwd = compile(&self.client, &dir.join("bwd.hlo.txt"))
            .with_context(|| format!("compiling bwd for {dir:?}"))?;
        let params0 = manifest.load_params(dir)?;
        let exec = PjrtExec {
            fwd,
            bwd,
            n_residuals: manifest.residuals.len(),
            n_train: manifest.trainable_indices().len(),
        };
        Ok(Artifact::from_parts(dir.to_path_buf(), manifest, params0,
                                Box::new(exec)))
    }
}

fn compile(client: &xla::PjRtClient,
           path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 path")?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

struct PjrtExec {
    fwd: xla::PjRtLoadedExecutable,
    bwd: xla::PjRtLoadedExecutable,
    n_residuals: usize,
    n_train: usize,
}

impl Executor for PjrtExec {
    fn run_fwd(&self, params: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<FwdOut> {
        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(params.len() + 2);
        for p in params {
            args.push(to_literal(p)?);
        }
        args.push(to_literal(x)?);
        args.push(to_literal(y)?);
        let bufs = self.fwd.execute::<xla::Literal>(&args)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let mut outs = tuple.to_tuple()?;
        anyhow::ensure!(
            outs.len() == 2 + self.n_residuals,
            "fwd arity mismatch: got {}, manifest says {}",
            outs.len(),
            2 + self.n_residuals
        );
        let residuals = outs
            .split_off(2)
            .iter()
            .map(from_literal)
            .collect::<Result<Vec<_>>>()?;
        let loss = outs[0].to_vec::<f32>()?[0];
        let metric = outs[1].to_vec::<f32>()?[0];
        Ok(FwdOut { loss, metric, residuals })
    }

    fn run_bwd(&self, params: &[Tensor], residuals: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<Vec<Tensor>> {
        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(params.len() + residuals.len() + 2);
        for p in params {
            args.push(to_literal(p)?);
        }
        for r in residuals {
            args.push(to_literal(r)?);
        }
        args.push(to_literal(x)?);
        args.push(to_literal(y)?);
        let bufs = self.bwd.execute::<xla::Literal>(&args)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        anyhow::ensure!(
            outs.len() == self.n_train,
            "bwd arity mismatch: got {}, expected {}",
            outs.len(),
            self.n_train
        );
        outs.iter().map(from_literal).collect()
    }
}
