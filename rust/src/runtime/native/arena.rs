//! Step-scoped buffer arena: a free-list of `Vec<f32>` / `Vec<u8>`
//! buffers keyed by exact length, owned by the executor and threaded
//! through the model's forward/backward passes.
//!
//! This is the systems-level twin of the paper's activation-memory
//! *sharing* idea: because a train step's activation/gradient shapes are
//! identical every step, every buffer taken during step *s* and put back
//! (directly, or via [`Arena::recycle_tensor`] after the trainer is done
//! with the residuals) is a free-list **hit** in step *s+1* — so the
//! steady-state step performs no activation allocations at all. The
//! hit/miss counters make that claim testable
//! (`tests/native_backend.rs::arena_reuse_steady_state`).
//!
//! `take_f32`/`take_u8` return buffers with **unspecified contents** —
//! the overwhelmingly common consumers (`*_into` kernels) fully
//! overwrite them, so reused buffers skip the memset. The few
//! accumulating consumers (pooled head input, norm-scale/embedding/
//! position gradients) use `take_f32_zeroed` instead.

use std::collections::HashMap;

use crate::runtime::tensor::{DType, Tensor};

/// Free-list hit/miss counters (see [`Arena::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ArenaStats {
    /// Takes served from the free list.
    pub hits: u64,
    /// Takes that had to allocate a fresh buffer.
    pub misses: u64,
    /// Buffers currently parked in the free lists.
    pub pooled: usize,
    /// Bytes currently parked in the free lists.
    pub pooled_bytes: usize,
}

/// The arena. One per executor; not thread-safe by itself (the executor
/// wraps it in a mutex).
#[derive(Default)]
pub struct Arena {
    f32s: HashMap<usize, Vec<Vec<f32>>>,
    u8s: HashMap<usize, Vec<Vec<u8>>>,
    hits: u64,
    misses: u64,
}

impl Arena {
    /// An empty arena.
    pub fn new() -> Arena {
        Arena::default()
    }

    /// A `len`-element f32 buffer with **unspecified contents** (the
    /// caller must fully overwrite it), reused when one of exactly this
    /// length was previously [`put_f32`](Arena::put_f32).
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        if let Some(v) = self.f32s.get_mut(&len).and_then(|l| l.pop()) {
            self.hits += 1;
            return v;
        }
        self.misses += 1;
        vec![0f32; len]
    }

    /// Like [`take_f32`](Arena::take_f32) but guaranteed zeroed — for
    /// consumers that accumulate (`+=`) into the buffer.
    pub fn take_f32_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_f32(len);
        v.fill(0.0);
        v
    }

    /// Return an f32 buffer to the free list.
    pub fn put_f32(&mut self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        self.f32s.entry(v.len()).or_default().push(v);
    }

    /// A `len`-byte buffer with **unspecified contents** (callers fully
    /// overwrite it — residual payloads and 2-bit code planes).
    pub fn take_u8(&mut self, len: usize) -> Vec<u8> {
        if let Some(v) = self.u8s.get_mut(&len).and_then(|l| l.pop()) {
            self.hits += 1;
            return v;
        }
        self.misses += 1;
        vec![0u8; len]
    }

    /// Return a byte buffer to the free list.
    pub fn put_u8(&mut self, v: Vec<u8>) {
        if v.capacity() == 0 {
            return;
        }
        self.u8s.entry(v.len()).or_default().push(v);
    }

    /// Build an f32 tensor whose backing bytes come from the arena (the
    /// copy remains; the *allocation* is pooled).
    pub fn tensor_from_f32(&mut self, shape: &[usize],
                           v: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), v.len());
        let mut data = self.take_u8(v.len() * 4);
        // SAFETY: plain byte view of an f32 slice.
        let src = unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8,
                                       v.len() * 4)
        };
        data.copy_from_slice(src);
        Tensor { shape: shape.to_vec(), dtype: DType::F32, data }
    }

    /// Reclaim a tensor's backing buffer (any dtype — the pool is keyed
    /// by byte length).
    pub fn recycle_tensor(&mut self, t: Tensor) {
        self.put_u8(t.data);
    }

    /// Current hit/miss/pool counters.
    pub fn stats(&self) -> ArenaStats {
        let mut pooled = 0usize;
        let mut pooled_bytes = 0usize;
        for (len, l) in &self.f32s {
            pooled += l.len();
            pooled_bytes += len * 4 * l.len();
        }
        for (len, l) in &self.u8s {
            pooled += l.len();
            pooled_bytes += len * l.len();
        }
        ArenaStats {
            hits: self.hits,
            misses: self.misses,
            pooled,
            pooled_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_roundtrip_hits() {
        let mut a = Arena::new();
        let v = a.take_f32(16);
        assert_eq!(v.len(), 16);
        assert_eq!(a.stats().misses, 1);
        a.put_f32(v);
        assert_eq!(a.stats().pooled, 1);
        let mut v = a.take_f32(16);
        assert_eq!(a.stats().hits, 1);
        v[3] = 5.0;
        a.put_f32(v);
        // the zeroed take clears reused (dirty) buffers
        let v = a.take_f32_zeroed(16);
        assert!(v.iter().all(|x| *x == 0.0));
        a.put_f32(v);
    }

    #[test]
    fn length_keys_are_exact() {
        let mut a = Arena::new();
        a.put_f32(vec![0f32; 8]);
        let _ = a.take_f32(9);
        assert_eq!(a.stats().misses, 1);
        assert_eq!(a.stats().hits, 0);
    }

    #[test]
    fn tensor_roundtrip_through_arena() {
        let mut a = Arena::new();
        let t = a.tensor_from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.as_f32(), &[1.0, 2.0, 3.0, 4.0]);
        let misses = a.stats().misses;
        a.recycle_tensor(t);
        let t2 = a.tensor_from_f32(&[4], &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(t2.as_f32(), &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.stats().misses, misses, "recycled buffer must hit");
    }

    #[test]
    fn zero_len_buffers_are_not_pooled() {
        let mut a = Arena::new();
        a.put_f32(Vec::new());
        a.put_u8(Vec::new());
        assert_eq!(a.stats().pooled, 0);
    }
}
