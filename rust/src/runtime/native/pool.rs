//! Persistent worker pool for the native backend's hot loops.
//!
//! Work is split into contiguous row chunks and fanned out over a set of
//! **long-lived** worker threads (spawned once, parked on a condvar
//! between jobs), so the thousands of kernel dispatches per train step
//! stop paying thread-creation latency. The determinism contract is
//! unchanged from the scoped-thread version: every output element is
//! reduced sequentially by exactly one chunk, and each chunk's contents
//! are fully defined by its own row range — so results are bit-identical
//! for any thread count (and for any chunk partition).
//!
//! ## Dispatch protocol
//!
//! One job at a time (serialized by a dispatch mutex). The caller
//! publishes an epoch-stamped, lifetime-erased job (a `Fn(chunk_index)`
//! borrowed from its stack), wakes all workers, and participates in
//! chunk-claiming itself. Chunks are claimed with an atomic counter, and
//! every worker checks in exactly once per epoch; the caller returns
//! only after all workers have checked in, which is what makes borrowing
//! stack data from long-lived threads sound. Worker panics are caught,
//! flagged, and re-raised on the caller.
//!
//! Nested calls (a kernel dispatched from inside a worker chunk, e.g.
//! the per-head matmuls inside attention) run serially on the calling
//! thread — the `IN_POOL` thread-local makes this automatic and
//! deadlock-free.
//!
//! Known tradeoff: every dispatch wakes **all** resident workers and
//! waits for each to check in (that barrier is what makes the
//! stack-borrowed job sound), so per-dispatch sync cost is O(pool
//! size) even for jobs with few chunks. At the default cap of 16
//! threads this is a few µs — far below the spawn-per-call cost it
//! replaces; very large explicit `AMBP_THREADS` values trade small-
//! kernel latency for big-kernel throughput.
//!
//! ## Thread-count policy (`AMBP_THREADS`)
//!
//! * Explicit `AMBP_THREADS=n` is clamped to `1..=MAX_THREADS` (64) —
//!   an explicit override may exceed the automatic default cap.
//! * Without the variable, `available_parallelism` is clamped to
//!   `1..=DEFAULT_CAP` (16) — a conservative default for shared boxes.
//! * [`with_threads`] overrides the *logical* chunk partition for the
//!   current thread (used by the thread-scaling bench and the
//!   determinism tests); execution still uses the resident workers.
//!
//! The policy lives in [`resolve_threads`] and is unit-tested.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

/// Hard upper bound on the worker count (explicit `AMBP_THREADS`).
pub const MAX_THREADS: usize = 64;

/// Cap applied to `available_parallelism` when `AMBP_THREADS` is unset.
pub const DEFAULT_CAP: usize = 16;

/// The thread-count policy, factored out of [`threads`] so it is
/// testable without touching process environment:
/// `env` (the `AMBP_THREADS` value, if any) is clamped to
/// `1..=MAX_THREADS`; unset or unparsable falls back to
/// `available.clamp(1, DEFAULT_CAP)`.
pub fn resolve_threads(env: Option<&str>, available: usize) -> usize {
    if let Some(v) = env {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, MAX_THREADS);
        }
    }
    available.clamp(1, DEFAULT_CAP)
}

/// Number of worker threads the pool fans out to (resident workers =
/// `threads() - 1`; the dispatching thread is the remaining one).
pub fn threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        resolve_threads(std::env::var("AMBP_THREADS").ok().as_deref(),
                        avail)
    })
}

thread_local! {
    /// Logical-partition override installed by [`with_threads`].
    static LOGICAL: Cell<Option<usize>> = const { Cell::new(None) };
    /// True on pool workers and on a caller while it participates in a
    /// dispatch — nested parallel calls fall back to serial execution.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with the *logical* thread count (the chunk partition) forced
/// to `n` on the current thread. Execution still uses the resident
/// workers; by the determinism contract the results are bit-identical
/// either way — this exists so tests can verify exactly that, and so
/// the bench can report scaling without respawning the process.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            LOGICAL.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(
        LOGICAL.with(|c| c.replace(Some(n.clamp(1, MAX_THREADS)))),
    );
    f()
}

fn logical_threads() -> usize {
    LOGICAL.with(|c| c.get()).unwrap_or_else(threads)
}

fn in_pool() -> bool {
    IN_POOL.with(|c| c.get())
}

/// A lifetime-erased job: `f(chunk_index)` plus the claim/completion
/// state, all borrowed from the dispatching caller's stack. Sound
/// because the caller blocks until every worker has checked in for the
/// job's epoch before any of this is dropped.
type PanicPayload = Box<dyn Any + Send + 'static>;

#[derive(Clone, Copy)]
struct JobRef {
    f: *const (dyn Fn(usize) + Sync),
    next: *const AtomicUsize,
    panicked: *const AtomicBool,
    payload: *const Mutex<Option<PanicPayload>>,
    total: usize,
}

// SAFETY: the pointers stay valid for the whole epoch (see above); the
// pointee closure is Sync, the atomics are Sync.
unsafe impl Send for JobRef {}

struct State {
    epoch: u64,
    job: Option<JobRef>,
    checked_in: usize,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    workers: usize,
    dispatch: Mutex<()>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = threads().saturating_sub(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                checked_in: 0,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        for w in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("ambp-pool-{w}"))
                .spawn(move || worker_loop(sh, workers))
                .expect("spawn pool worker");
        }
        Pool { shared, workers, dispatch: Mutex::new(()) }
    })
}

fn run_chunks(job: &JobRef) {
    // SAFETY: valid for the epoch — the dispatcher is blocked on our
    // check-in and keeps the pointees alive.
    let f = unsafe { &*job.f };
    let next = unsafe { &*job.next };
    let panicked = unsafe { &*job.panicked };
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= job.total || panicked.load(Ordering::Relaxed) {
            break;
        }
        if let Err(e) = catch_unwind(AssertUnwindSafe(|| f(i))) {
            panicked.store(true, Ordering::Relaxed);
            // keep the FIRST payload so the dispatcher can re-raise the
            // original panic (message and all), not a generic one
            let mut slot = lock(unsafe { &*job.payload });
            if slot.is_none() {
                *slot = Some(e);
            }
            break;
        }
    }
}

fn worker_loop(sh: Arc<Shared>, nworkers: usize) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut g = lock(&sh.state);
            while g.epoch == seen {
                g = sh.work_cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            seen = g.epoch;
            g.job.expect("job must be published with its epoch")
        };
        run_chunks(&job);
        let mut g = lock(&sh.state);
        g.checked_in += 1;
        if g.checked_in == nworkers {
            sh.done_cv.notify_one();
        }
    }
}

/// Run `f(chunk_index)` for every index in `0..total` across the pool.
/// The caller participates; returns after all chunks are done and all
/// workers have detached from the job.
fn dispatch(f: &(dyn Fn(usize) + Sync), total: usize) {
    let p = pool();
    if p.workers == 0 {
        for i in 0..total {
            f(i);
        }
        return;
    }
    let _guard = lock(&p.dispatch);
    let next = AtomicUsize::new(0);
    let panicked = AtomicBool::new(false);
    let payload: Mutex<Option<PanicPayload>> = Mutex::new(None);
    // SAFETY: lifetime erasure only — the closure outlives every access
    // (the wait-for-check-in below is what enforces it).
    let f_static: &'static (dyn Fn(usize) + Sync) =
        unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync),
                                  &'static (dyn Fn(usize) + Sync)>(f)
        };
    let job = JobRef {
        f: f_static,
        next: &next,
        panicked: &panicked,
        payload: &payload,
        total,
    };
    {
        let mut g = lock(&p.shared.state);
        g.checked_in = 0;
        g.job = Some(job);
        g.epoch = g.epoch.wrapping_add(1);
        p.shared.work_cv.notify_all();
    }
    IN_POOL.with(|c| c.set(true));
    let caller = catch_unwind(AssertUnwindSafe(|| run_chunks(&job)));
    IN_POOL.with(|c| c.set(false));
    {
        let mut g = lock(&p.shared.state);
        while g.checked_in < p.workers {
            g = p
                .shared
                .done_cv
                .wait(g)
                .unwrap_or_else(|e| e.into_inner());
        }
        g.job = None;
    }
    if let Err(e) = caller {
        resume_unwind(e);
    }
    if panicked.load(Ordering::Relaxed) {
        match lock(&payload).take() {
            Some(e) => resume_unwind(e),
            None => panic!("worker pool chunk panicked"),
        }
    }
}

#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: chunks derived from it are disjoint per chunk index.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Element-type-generic body of [`parallel_rows`]/[`parallel_rows_u8`].
fn parallel_rows_of<T, F>(out: &mut [T], row_len: usize, grain: usize,
                          f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0);
    let rows = out.len() / row_len;
    let nt = logical_threads()
        .min(rows.div_ceil(grain.max(1)))
        .max(1);
    if nt <= 1 || in_pool() {
        f(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(nt);
    let n_chunks = rows.div_ceil(chunk_rows);
    let base = SendPtr(out.as_mut_ptr());
    let run = move |ci: usize| {
        let first = ci * chunk_rows;
        let end = rows.min(first + chunk_rows);
        if first >= end {
            return;
        }
        // SAFETY: [first, end) ranges are disjoint across chunk indices
        // and in-bounds (end <= rows).
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(
                base.0.add(first * row_len),
                (end - first) * row_len,
            )
        };
        f(first, chunk);
    };
    dispatch(&run, n_chunks);
}

/// Split the rows of `out` (`out.len() = rows * row_len`) into contiguous
/// chunks of at least `grain` rows and run `f(first_row, chunk)` on each,
/// in parallel. `f` must fully define the chunk's contents from its own
/// row range — chunks are disjoint `&mut` slices.
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, grain: usize,
                        f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    parallel_rows_of(out, row_len, grain, f)
}

/// [`parallel_rows`] over a byte buffer — used by the int8 group
/// quantizer, whose packed output interleaves codes and scales. The
/// determinism contract is the same: chunk boundaries fall on whole
/// rows, so any partition produces bit-identical bytes.
pub fn parallel_rows_u8<F>(out: &mut [u8], row_len: usize, grain: usize,
                           f: F)
where
    F: Fn(usize, &mut [u8]) + Sync,
{
    parallel_rows_of(out, row_len, grain, f)
}

/// Run `f(task)` for every task index in `0..n_tasks`, in parallel, each
/// task writing its results into the matching `slot_len`-sized slot of
/// `out` (`out.len() = n_tasks * slot_len`). Used for per-(batch, head)
/// attention work.
pub fn parallel_tasks<F>(out: &mut [f32], slot_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(slot_len > 0 && out.len() % slot_len == 0);
    parallel_rows(out, slot_len, 1, |first, chunk| {
        for (i, slot) in chunk.chunks_mut(slot_len).enumerate() {
            f(first + i, slot);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_cover_everything_once() {
        let rows = 37;
        let cols = 5;
        let mut out = vec![0f32; rows * cols];
        parallel_rows(&mut out, cols, 1, |first, chunk| {
            for (i, row) in chunk.chunks_mut(cols).enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v += ((first + i) * cols + j) as f32;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn tasks_fill_slots() {
        let mut out = vec![0f32; 6 * 4];
        parallel_tasks(&mut out, 4, |t, slot| {
            for v in slot.iter_mut() {
                *v = t as f32;
            }
        });
        for t in 0..6 {
            assert!(out[t * 4..(t + 1) * 4].iter().all(|v| *v == t as f32));
        }
    }

    #[test]
    fn serial_fallback_small_work() {
        let mut out = vec![0f32; 3];
        parallel_rows(&mut out, 1, 1000, |first, chunk| {
            assert_eq!(first, 0);
            for v in chunk.iter_mut() {
                *v = 1.0;
            }
        });
        assert_eq!(out, vec![1.0; 3]);
    }

    #[test]
    fn threads_positive() {
        assert!(threads() >= 1);
    }

    #[test]
    fn policy_env_overrides_and_clamps() {
        // explicit values clamp to 1..=MAX_THREADS
        assert_eq!(resolve_threads(Some("32"), 2), 32);
        assert_eq!(resolve_threads(Some("9999"), 2), MAX_THREADS);
        assert_eq!(resolve_threads(Some("0"), 2), 1);
        assert_eq!(resolve_threads(Some(" 8 "), 2), 8);
        // unparsable falls through to the default path
        assert_eq!(resolve_threads(Some("lots"), 8), 8);
        // default caps available_parallelism at DEFAULT_CAP
        assert_eq!(resolve_threads(None, 4), 4);
        assert_eq!(resolve_threads(None, 128), DEFAULT_CAP);
        assert_eq!(resolve_threads(None, 0), 1);
    }

    #[test]
    fn partition_is_invisible_in_results() {
        // the determinism contract: any logical thread count produces
        // bit-identical output
        let rows = 53;
        let cols = 7;
        let fill = |out: &mut Vec<f32>| {
            parallel_rows(out, cols, 1, |first, chunk| {
                for (i, row) in chunk.chunks_mut(cols).enumerate() {
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = ((first + i) * cols + j) as f32 * 0.5;
                    }
                }
            });
        };
        let mut want = vec![0f32; rows * cols];
        with_threads(1, || fill(&mut want));
        for nt in [2usize, 3, 5, 8, 16] {
            let mut got = vec![0f32; rows * cols];
            with_threads(nt, || fill(&mut got));
            assert_eq!(got, want, "nt={nt}");
        }
    }

    #[test]
    fn pool_survives_many_dispatches() {
        // exercises the epoch/check-in protocol back-to-back
        let mut out = vec![0f32; 64];
        for round in 0..200u32 {
            parallel_rows(&mut out, 1, 1, |first, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (first + i) as f32 + round as f32;
                }
            });
            assert_eq!(out[63], 63.0 + round as f32, "round {round}");
        }
    }

    #[test]
    fn nested_dispatch_runs_serially() {
        // a chunk body that itself calls parallel_rows must not deadlock
        let mut out = vec![0f32; 8];
        with_threads(4, || {
            parallel_rows(&mut out, 1, 1, |first, chunk| {
                let mut inner = vec![0f32; 4];
                parallel_rows(&mut inner, 1, 1, |f2, c2| {
                    for (i, v) in c2.iter_mut().enumerate() {
                        *v = (f2 + i) as f32;
                    }
                });
                let s: f32 = inner.iter().sum();
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (first + i) as f32 + s;
                }
            });
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32 + 6.0);
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_recovers() {
        let result = std::panic::catch_unwind(|| {
            let mut out = vec![0f32; 32];
            with_threads(8, || {
                parallel_rows(&mut out, 1, 1, |first, _chunk| {
                    if first == 0 {
                        panic!("boom");
                    }
                });
            });
        });
        // the ORIGINAL payload must survive the pool crossing
        let payload = result.unwrap_err();
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // the pool must still be usable afterwards
        let mut out = vec![0f32; 16];
        parallel_rows(&mut out, 1, 1, |first, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (first + i) as f32;
            }
        });
        assert_eq!(out[15], 15.0);
    }
}
