//! Chunked worker pool for the native backend's hot loops.
//!
//! Work is split into contiguous row chunks and fanned out over scoped
//! threads, so the matmul / attention / activation kernels scale with
//! cores while staying deterministic: every output element is reduced
//! sequentially by exactly one worker, so results are bit-identical for
//! any thread count.
//!
//! Thread count: `min(available_parallelism, 16)`, overridable with the
//! `AMBP_THREADS` environment variable (useful for benchmarking scaling).

use std::sync::OnceLock;

/// Number of worker threads the pool fans out to.
pub fn threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("AMBP_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.clamp(1, 64);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .clamp(1, 16)
    })
}

/// Split the rows of `out` (`out.len() = rows * row_len`) into contiguous
/// chunks of at least `grain` rows and run `f(first_row, chunk)` on each,
/// in parallel. `f` must fully define the chunk's contents from its own
/// row range — chunks are disjoint `&mut` slices.
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, grain: usize,
                        f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && out.len() % row_len == 0);
    let rows = out.len() / row_len;
    let nt = threads()
        .min(rows.div_ceil(grain.max(1)))
        .max(1);
    if nt <= 1 {
        f(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(nt);
    std::thread::scope(|s| {
        let fr = &f;
        for (t, chunk) in out.chunks_mut(chunk_rows * row_len).enumerate() {
            s.spawn(move || fr(t * chunk_rows, chunk));
        }
    });
}

/// Run `f(task)` for every task index in `0..n_tasks`, in parallel, each
/// task writing its results into the matching `slot_len`-sized slot of
/// `out` (`out.len() = n_tasks * slot_len`). Used for per-(batch, head)
/// attention work.
pub fn parallel_tasks<F>(out: &mut [f32], slot_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(slot_len > 0 && out.len() % slot_len == 0);
    parallel_rows(out, slot_len, 1, |first, chunk| {
        for (i, slot) in chunk.chunks_mut(slot_len).enumerate() {
            f(first + i, slot);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_cover_everything_once() {
        let rows = 37;
        let cols = 5;
        let mut out = vec![0f32; rows * cols];
        parallel_rows(&mut out, cols, 1, |first, chunk| {
            for (i, row) in chunk.chunks_mut(cols).enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v += ((first + i) * cols + j) as f32;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn tasks_fill_slots() {
        let mut out = vec![0f32; 6 * 4];
        parallel_tasks(&mut out, 4, |t, slot| {
            for v in slot.iter_mut() {
                *v = t as f32;
            }
        });
        for t in 0..6 {
            assert!(out[t * 4..(t + 1) * 4].iter().all(|v| *v == t as f32));
        }
    }

    #[test]
    fn serial_fallback_small_work() {
        let mut out = vec![0f32; 3];
        parallel_rows(&mut out, 1, 1000, |first, chunk| {
            assert_eq!(first, 0);
            for v in chunk.iter_mut() {
                *v = 1.0;
            }
        });
        assert_eq!(out, vec![1.0; 3]);
    }

    #[test]
    fn threads_positive() {
        assert!(threads() >= 1);
    }
}
