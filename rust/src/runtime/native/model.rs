//! The native backend's transformer: built directly from a manifest
//! config, with *manually decoupled* forward/backward passes.
//!
//! The forward pass saves exactly the residual set the paper's tape
//! stores (see DESIGN.md §2.2): per block, the normalized input (shared
//! with the following linears under MS-LN/MS-RMSNorm), the per-row norm
//! statistic, q/k/v (attention probabilities are recomputed in backward),
//! the linear inputs that weight/LoRA gradients need, and the activation
//! residual — a full-precision pre-activation for GELU/SiLU, or a 2-bit
//! packed code tensor for ReGELU2/ReSiLU2 (Prop 4.3: the backward slope
//! is one of 4 values, so 2 bits suffice).
//!
//! The backward pass consumes the residual list in exact reverse push
//! order; the gradient math was cross-checked against finite differences
//! for every (arch × tuning × norm) combination.
//!
//! Every intermediate activation, backward scratch buffer, and residual
//! payload is taken from (and returned to) the step-scoped
//! [`Arena`] the executor owns, so a steady-state train step performs no
//! activation allocations — see `arena.rs`.

use anyhow::{bail, ensure, Result};

use super::arena::Arena;
use super::kernels::{
    add_bias, add_inplace, attn_bwd_into, attn_fwd_into, colsum_into,
    matmul_nn_acc_into, matmul_nn_into, matmul_nt_acc_into,
    matmul_nt_into, matmul_tn_into, norm_bwd_into, norm_fwd_into,
    softmax_ce, softmax_ce_grad_into, AttnDims,
};
use crate::coeffs::funcs::{ReluComb, PAPER_GELU, PAPER_SILU};
use crate::packing;
use crate::runtime::manifest::ParamInfo;
use crate::runtime::tensor::{DType, Tensor};
use crate::util::rng::Rng;

/// Model family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// Patch-token classifier (ViT): f32 `[B,N,P]` input, `[B]` labels.
    Vit,
    /// Causal LM (LLaMA-style: RMS norms, no biases): i32 `[B,N]` tokens,
    /// `[B,N]` next-token targets.
    Llama,
    /// Bidirectional sequence classifier (RoBERTa-style): i32 `[B,N]`
    /// tokens, `[B]` labels.
    Roberta,
}

/// Which parameters train (the paper's Table 1/3 axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tuning {
    /// Everything trains.
    Full,
    /// Only the classifier head trains (linear probe).
    Frozen,
    /// LoRA adapters on q/v (+ head).
    LoraQv,
    /// LoRA adapters on every block linear (+ head).
    LoraAll,
    /// LoRA-FA on q/v: A frozen, so linear inputs need not be saved.
    LoraFaQv,
    /// LoRA-FA on every block linear.
    LoraFaAll,
}

/// Activation function variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    /// Exact GELU fwd, exact bwd from the saved f32 pre-activation.
    Gelu,
    /// Exact GELU fwd, approximate bwd from 2-bit codes (ReGELU2).
    ReGelu2,
    /// Exact SiLU fwd/bwd.
    Silu,
    /// Exact SiLU fwd, approximate bwd from 2-bit codes (ReSiLU2).
    ReSilu2,
}

/// Normalization variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Norm {
    /// LayerNorm with affine; stores x̂ *and* the affine output.
    Ln,
    /// Memory-sharing LayerNorm: affine merged into the next linears
    /// (eq. 17), one shared x̂ residual.
    MsLn,
    /// RMSNorm with scale.
    Rms,
    /// Memory-sharing RMSNorm.
    MsRms,
}

/// Architecture + variant configuration of a native model, mirroring the
/// manifest `config` section.
#[derive(Debug, Clone)]
pub struct NetCfg {
    /// Model family.
    pub arch: Arch,
    /// Embedding width C.
    pub dim: usize,
    /// Number of transformer blocks.
    pub depth: usize,
    /// Attention heads (must divide `dim`).
    pub n_heads: usize,
    /// Tokens per sequence N.
    pub n_tokens: usize,
    /// Batch size B.
    pub batch: usize,
    /// Classifier classes (ViT / RoBERTa).
    pub n_classes: usize,
    /// Vocabulary size (LLaMA / RoBERTa).
    pub vocab: usize,
    /// MLP expansion ratio (hidden = dim · ratio).
    pub mlp_ratio: f64,
    /// LoRA rank r.
    pub lora_rank: usize,
    /// Patch dimension P (ViT input feature size).
    pub patch_dim: usize,
    /// Trainability mode.
    pub tuning: Tuning,
    /// Activation variant.
    pub act: Act,
    /// Normalization variant.
    pub norm: Norm,
}

impl NetCfg {
    /// MLP hidden width M.
    pub fn hidden(&self) -> usize {
        (self.dim as f64 * self.mlp_ratio) as usize
    }

    fn is_ms(&self) -> bool {
        matches!(self.norm, Norm::MsLn | Norm::MsRms)
    }

    fn is_rms(&self) -> bool {
        matches!(self.norm, Norm::Rms | Norm::MsRms)
    }

    fn has_affine(&self) -> bool {
        matches!(self.norm, Norm::Ln | Norm::Rms)
    }

    fn use_bias(&self) -> bool {
        self.arch != Arch::Llama
    }

    fn causal(&self) -> bool {
        self.arch == Arch::Llama
    }

    fn act_exact_bwd(&self) -> bool {
        matches!(self.act, Act::Gelu | Act::Silu)
    }

    fn is_gelu(&self) -> bool {
        matches!(self.act, Act::Gelu | Act::ReGelu2)
    }

    fn comb(&self) -> &'static ReluComb {
        if self.is_gelu() { &PAPER_GELU } else { &PAPER_SILU }
    }

    fn lora_fa(&self) -> bool {
        matches!(self.tuning, Tuning::LoraFaQv | Tuning::LoraFaAll)
    }

    fn lora_on(&self, which: &str) -> bool {
        match self.tuning {
            Tuning::LoraQv | Tuning::LoraFaQv => which == "q" || which == "v",
            Tuning::LoraAll | Tuning::LoraFaAll => true,
            Tuning::Full | Tuning::Frozen => false,
        }
    }

    fn head_trainable(&self) -> bool {
        match self.arch {
            Arch::Llama => self.tuning == Tuning::Full,
            _ => true,
        }
    }

    /// Basic structural validation; returns a descriptive error on
    /// configs the native backend cannot run.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.dim > 0 && self.depth > 0 && self.n_tokens > 0
                    && self.batch > 0, "empty model dims");
        ensure!(self.dim % self.n_heads == 0,
                "dim {} not divisible by n_heads {}", self.dim,
                self.n_heads);
        ensure!(self.hidden() % 4 == 0,
                "mlp hidden {} must be a multiple of 4 (2-bit packing)",
                self.hidden());
        match self.arch {
            Arch::Vit => ensure!(self.patch_dim > 0 && self.n_classes > 1,
                                 "vit needs patch_dim and n_classes"),
            Arch::Llama => ensure!(self.vocab > 1, "llama needs vocab"),
            Arch::Roberta => ensure!(self.vocab > 1 && self.n_classes > 1,
                                     "roberta needs vocab and n_classes"),
        }
        if matches!(self.tuning, Tuning::LoraQv | Tuning::LoraAll
                        | Tuning::LoraFaQv | Tuning::LoraFaAll) {
            ensure!(self.lora_rank > 0, "lora tuning needs lora_rank > 0");
        }
        Ok(())
    }

    /// Parse the manifest `tuning` string (both `lora_qv` and `loraqv`
    /// spellings are accepted).
    pub fn tuning_from_str(s: &str) -> Result<Tuning> {
        Ok(match s {
            "full" => Tuning::Full,
            "frozen" => Tuning::Frozen,
            "lora_qv" | "loraqv" => Tuning::LoraQv,
            "lora_all" | "loraall" => Tuning::LoraAll,
            "lorafa_qv" | "lorafaqv" => Tuning::LoraFaQv,
            "lorafa_all" | "lorafaall" => Tuning::LoraFaAll,
            other => bail!("unsupported tuning {other:?}"),
        })
    }

    /// Parse the manifest `activation` string.
    pub fn act_from_str(s: &str) -> Result<Act> {
        Ok(match s {
            "gelu" => Act::Gelu,
            "regelu2" => Act::ReGelu2,
            "silu" => Act::Silu,
            "resilu2" => Act::ReSilu2,
            other => bail!("unsupported activation {other:?} (native \
                            backend supports gelu|regelu2|silu|resilu2)"),
        })
    }

    /// Parse the manifest `norm` string.
    pub fn norm_from_str(s: &str) -> Result<Norm> {
        Ok(match s {
            "ln" => Norm::Ln,
            "msln" => Norm::MsLn,
            "rms" => Norm::Rms,
            "msrms" => Norm::MsRms,
            other => bail!("unsupported norm {other:?} (native backend \
                            supports ln|msln|rms|msrms)"),
        })
    }

    /// Parse the manifest `arch` string.
    pub fn arch_from_str(s: &str) -> Result<Arch> {
        Ok(match s {
            "vit" => Arch::Vit,
            "llama" => Arch::Llama,
            "roberta" => Arch::Roberta,
            other => bail!("unsupported arch {other:?}"),
        })
    }
}

/// One residual pushed by the forward pass (a manifest `ResInfo` minus
/// the derived byte counts).
pub struct SavedRes {
    /// Producing module path (e.g. `block0.attn.q`).
    pub module: String,
    /// Residual kind (`norm_input`, `attn_qkv`, `act_codes`, …).
    pub kind: &'static str,
    /// The saved tensor.
    pub tensor: Tensor,
}

struct LinDef {
    name: String,
    din: usize,
    dout: usize,
    w: usize,
    b: Option<usize>,
    la: Option<usize>,
    lb: Option<usize>,
    fa: bool,
    base_train: bool,
}

impl LinDef {
    fn need_x(&self) -> bool {
        self.base_train || (self.la.is_some() && !self.fa)
    }
}

struct NormDef {
    name: String,
    g: Option<usize>,
    b: Option<usize>,
}

struct BlockDef {
    // precomputed residual module names ("block{i}.attn.qkv",
    // "block{i}.mlp.act") so the per-step save path does not format!
    qkv_name: String,
    act_name: String,
    norm1: NormDef,
    q: LinDef,
    k: LinDef,
    v: LinDef,
    proj: LinDef,
    norm2: NormDef,
    fc1: LinDef,
    fc2: LinDef,
}

/// A built native model: the parameter layout plus fwd/bwd execution.
pub struct Model {
    /// The configuration the layout was derived from.
    pub cfg: NetCfg,
    /// Parameter layout in manifest order.
    pub infos: Vec<ParamInfo>,
    embed_w: Option<usize>,
    embed_b: Option<usize>,
    tok_e: Option<usize>,
    pos: usize,
    blocks: Vec<BlockDef>,
    normf: NormDef,
    head: LinDef,
}

struct Reg {
    infos: Vec<ParamInfo>,
}

impl Reg {
    fn add(&mut self, name: String, shape: Vec<usize>,
           trainable: bool) -> usize {
        self.infos.push(ParamInfo { name, shape, trainable });
        self.infos.len() - 1
    }
}

impl Model {
    /// Derive the parameter layout from a config.
    pub fn build(cfg: NetCfg) -> Result<Model> {
        cfg.validate()?;
        let c = cfg.dim;
        let m = cfg.hidden();
        let r = cfg.lora_rank;
        let full = cfg.tuning == Tuning::Full;
        let mut reg = Reg { infos: Vec::new() };

        let (embed_w, embed_b, tok_e) = match cfg.arch {
            Arch::Vit => (
                Some(reg.add("embed.proj.W".into(),
                             vec![c, cfg.patch_dim], full)),
                Some(reg.add("embed.proj.b".into(), vec![c], full)),
                None,
            ),
            _ => (
                None,
                None,
                Some(reg.add("embed.tok.E".into(), vec![cfg.vocab, c],
                             full)),
            ),
        };
        let pos = reg.add("embed.pos".into(), vec![cfg.n_tokens, c], full);

        let add_norm = |reg: &mut Reg, name: &str| -> NormDef {
            if cfg.has_affine() {
                let g = reg.add(format!("{name}.w"), vec![c], full);
                let b = if cfg.is_rms() {
                    None
                } else {
                    Some(reg.add(format!("{name}.b"), vec![c], full))
                };
                NormDef { name: name.to_string(), g: Some(g), b }
            } else {
                NormDef { name: name.to_string(), g: None, b: None }
            }
        };
        let add_lin = |reg: &mut Reg, name: &str, which: &str, din: usize,
                       dout: usize| -> LinDef {
            let w = reg.add(format!("{name}.W"), vec![dout, din], full);
            let b = if cfg.use_bias() {
                Some(reg.add(format!("{name}.b"), vec![dout], full))
            } else {
                None
            };
            let (la, lb) = if cfg.lora_on(which) {
                (
                    Some(reg.add(format!("{name}.lora_a"), vec![r, din],
                                 !cfg.lora_fa())),
                    Some(reg.add(format!("{name}.lora_b"), vec![dout, r],
                                 true)),
                )
            } else {
                (None, None)
            };
            LinDef {
                name: name.to_string(),
                din,
                dout,
                w,
                b,
                la,
                lb,
                fa: cfg.lora_fa(),
                base_train: full,
            }
        };

        let mut blocks = Vec::with_capacity(cfg.depth);
        for i in 0..cfg.depth {
            let an = format!("block{i}.attn");
            let mn = format!("block{i}.mlp");
            let norm1 = add_norm(&mut reg, &format!("{an}.norm"));
            let q = add_lin(&mut reg, &format!("{an}.q"), "q", c, c);
            let k = add_lin(&mut reg, &format!("{an}.k"), "k", c, c);
            let v = add_lin(&mut reg, &format!("{an}.v"), "v", c, c);
            let proj =
                add_lin(&mut reg, &format!("{an}.proj"), "proj", c, c);
            let norm2 = add_norm(&mut reg, &format!("{mn}.norm"));
            let fc1 = add_lin(&mut reg, &format!("{mn}.fc1"), "fc1", c, m);
            let fc2 = add_lin(&mut reg, &format!("{mn}.fc2"), "fc2", m, c);
            blocks.push(BlockDef {
                qkv_name: format!("{an}.qkv"),
                act_name: format!("{mn}.act"),
                norm1,
                q,
                k,
                v,
                proj,
                norm2,
                fc1,
                fc2,
            });
        }
        let normf = add_norm(&mut reg, "head.norm");
        let head_out = match cfg.arch {
            Arch::Llama => cfg.vocab,
            _ => cfg.n_classes,
        };
        let ht = cfg.head_trainable();
        let hw = reg.add("head.fc.W".into(), vec![head_out, c], ht);
        let hb = if cfg.use_bias() {
            Some(reg.add("head.fc.b".into(), vec![head_out], ht))
        } else {
            None
        };
        let head = LinDef {
            name: "head.fc".into(),
            din: c,
            dout: head_out,
            w: hw,
            b: hb,
            la: None,
            lb: None,
            fa: false,
            base_train: ht,
        };
        Ok(Model {
            cfg,
            infos: reg.infos,
            embed_w,
            embed_b,
            tok_e,
            pos,
            blocks,
            normf,
            head,
        })
    }

    /// Deterministic parameter init (He-scaled weights, identity norms,
    /// zero biases and LoRA-B). Each tensor's stream is keyed by
    /// `(seed, name)`, so parameters shared between presets (e.g. the
    /// frozen base under different LoRA layouts) get identical values —
    /// which is also what makes LoRA variants start exactly at the base
    /// model.
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        fn fnv1a(s: &str) -> u64 {
            let mut h = 0xcbf29ce484222325u64;
            for b in s.bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            h
        }
        self.infos
            .iter()
            .map(|info| {
                let mut rng = Rng::new(seed ^ fnv1a(&info.name));
                let n: usize = info.shape.iter().product();
                let mut v = vec![0f32; n];
                let name = info.name.as_str();
                if name.ends_with(".norm.w") {
                    v.fill(1.0);
                } else if name == "head.fc.W"
                    || name == "embed.pos"
                    || name == "embed.tok.E"
                {
                    for x in v.iter_mut() {
                        *x = rng.normal_f32() * 0.02;
                    }
                } else if name.ends_with(".W") || name.ends_with(".lora_a")
                {
                    let scale =
                        1.0 / (info.shape[1] as f32).sqrt();
                    for x in v.iter_mut() {
                        *x = rng.normal_f32() * scale;
                    }
                }
                // biases, lora_b, norm .b stay zero
                Tensor::from_f32(&info.shape, &v)
            })
            .collect()
    }

    fn norm_kind(&self) -> &'static str {
        if self.cfg.is_ms() { "norm_shared" } else { "norm_input" }
    }

    fn rows(&self) -> usize {
        self.cfg.batch * self.cfg.n_tokens
    }

    fn attn_dims(&self) -> AttnDims {
        AttnDims {
            b: self.cfg.batch,
            n: self.cfg.n_tokens,
            h: self.cfg.n_heads,
            dh: self.cfg.dim / self.cfg.n_heads,
        }
    }

    fn check_batch(&self, x: &Tensor, y: &Tensor) -> Result<()> {
        let (b, n) = (self.cfg.batch, self.cfg.n_tokens);
        match self.cfg.arch {
            Arch::Vit => {
                ensure!(x.dtype == DType::F32
                            && x.shape == [b, n, self.cfg.patch_dim],
                        "bad x for vit: {:?}", x.shape);
                ensure!(y.dtype == DType::I32 && y.elems() == b,
                        "bad y for vit: {:?}", y.shape);
            }
            Arch::Llama => {
                ensure!(x.dtype == DType::I32 && x.shape == [b, n],
                        "bad x for llama: {:?}", x.shape);
                ensure!(y.dtype == DType::I32 && y.elems() == b * n,
                        "bad y for llama: {:?}", y.shape);
            }
            Arch::Roberta => {
                ensure!(x.dtype == DType::I32 && x.shape == [b, n],
                        "bad x for roberta: {:?}", x.shape);
                ensure!(y.dtype == DType::I32 && y.elems() == b,
                        "bad y for roberta: {:?}", y.shape);
            }
        }
        // labels index the logits in softmax_ce: range-check them like
        // embed_fwd does for input token ids
        let hi = match self.cfg.arch {
            Arch::Llama => self.cfg.vocab,
            _ => self.cfg.n_classes,
        };
        for &t in y.as_i32() {
            ensure!(t >= 0 && (t as usize) < hi,
                    "label {t} out of range 0..{hi}");
        }
        Ok(())
    }

    fn embed_fwd(&self, arena: &mut Arena, params: &[Tensor],
                 x: &Tensor) -> Result<Vec<f32>> {
        let c = self.cfg.dim;
        let rows = self.rows();
        let mut h = arena.take_f32(rows * c);
        match self.cfg.arch {
            Arch::Vit => {
                matmul_nt_into(&mut h, x.as_f32(),
                               params[self.embed_w.unwrap()].as_f32(),
                               rows, self.cfg.patch_dim, c);
                add_bias(&mut h, params[self.embed_b.unwrap()].as_f32());
            }
            _ => {
                let emb = params[self.tok_e.unwrap()].as_f32();
                let toks = x.as_i32();
                for (r, &t) in toks.iter().enumerate() {
                    ensure!((t as usize) < self.cfg.vocab,
                            "token {t} out of range");
                    let t = t as usize;
                    h[r * c..(r + 1) * c]
                        .copy_from_slice(&emb[t * c..(t + 1) * c]);
                }
            }
        }
        let pos = params[self.pos].as_f32();
        let n = self.cfg.n_tokens;
        for r in 0..rows {
            let prow = &pos[(r % n) * c..(r % n + 1) * c];
            add_inplace(&mut h[r * c..(r + 1) * c], prow);
        }
        Ok(h)
    }

    fn norm_affine(&self, arena: &mut Arena, params: &[Tensor],
                   nd: &NormDef, xhat: &[f32]) -> Option<Vec<f32>> {
        let gi = nd.g?;
        let g = params[gi].as_f32();
        let c = g.len();
        let mut y = arena.take_f32(xhat.len());
        for (yrow, xrow) in y.chunks_mut(c).zip(xhat.chunks(c)) {
            for ((o, &xh), &gv) in yrow.iter_mut().zip(xrow).zip(g) {
                *o = xh * gv;
            }
        }
        if let Some(bi) = nd.b {
            add_bias(&mut y, params[bi].as_f32());
        }
        Some(y)
    }

    /// Accumulate a gradient buffer into the staging slot for `idx`,
    /// returning the buffer to the arena when it is merged (or when the
    /// parameter is frozen).
    fn acc(&self, arena: &mut Arena, grads: &mut [Option<Vec<f32>>],
           idx: usize, g: Vec<f32>) {
        if !self.infos[idx].trainable {
            arena.put_f32(g);
            return;
        }
        match &mut grads[idx] {
            Some(a) => {
                add_inplace(a, &g);
                arena.put_f32(g);
            }
            slot @ None => *slot = Some(g),
        }
    }

    fn save(&self, arena: &mut Arena, saves: &mut Vec<SavedRes>,
            module: String, kind: &'static str, shape: &[usize],
            v: &[f32]) {
        saves.push(SavedRes {
            module,
            kind,
            tensor: arena.tensor_from_f32(shape, v),
        });
    }

    fn lin_fwd(&self, arena: &mut Arena, params: &[Tensor], lin: &LinDef,
               x: &[f32], rows: usize, lead: &[usize],
               saves: &mut Vec<SavedRes>) -> Vec<f32> {
        let mut y = arena.take_f32(rows * lin.dout);
        matmul_nt_into(&mut y, x, params[lin.w].as_f32(), rows, lin.din,
                       lin.dout);
        if let Some(bi) = lin.b {
            add_bias(&mut y, params[bi].as_f32());
        }
        if let (Some(lai), Some(lbi)) = (lin.la, lin.lb) {
            let r = self.cfg.lora_rank;
            let mut u = arena.take_f32(rows * r);
            matmul_nt_into(&mut u, x, params[lai].as_f32(), rows, lin.din,
                           r);
            let mut shape = lead.to_vec();
            shape.push(r);
            self.save(arena, saves, lin.name.clone(), "lora_u", &shape,
                      &u);
            matmul_nt_acc_into(&mut y, &u, params[lbi].as_f32(), rows, r,
                               lin.dout);
            arena.put_f32(u);
        }
        y
    }

    fn lin_bwd(&self, arena: &mut Arena, params: &[Tensor], lin: &LinDef,
               dy: &[f32], x: Option<&[f32]>, u: Option<&[f32]>,
               rows: usize,
               grads: &mut [Option<Vec<f32>>]) -> Vec<f32> {
        if lin.base_train {
            let xx = x.expect("linear input residual missing");
            let mut dw = arena.take_f32(lin.dout * lin.din);
            matmul_tn_into(&mut dw, dy, xx, lin.dout, rows, lin.din);
            self.acc(arena, grads, lin.w, dw);
            if let Some(bi) = lin.b {
                let mut db = arena.take_f32(lin.dout);
                colsum_into(&mut db, dy, rows, lin.dout);
                self.acc(arena, grads, bi, db);
            }
        }
        let mut dx = arena.take_f32(rows * lin.din);
        matmul_nn_into(&mut dx, dy, params[lin.w].as_f32(), rows,
                       lin.dout, lin.din);
        if let (Some(lai), Some(lbi)) = (lin.la, lin.lb) {
            let r = self.cfg.lora_rank;
            let uu = u.expect("lora_u residual missing");
            let mut du = arena.take_f32(rows * r);
            matmul_nn_into(&mut du, dy, params[lbi].as_f32(), rows,
                           lin.dout, r);
            let mut dlb = arena.take_f32(lin.dout * r);
            matmul_tn_into(&mut dlb, dy, uu, lin.dout, rows, r);
            self.acc(arena, grads, lbi, dlb);
            if !lin.fa {
                let xx = x.expect("linear input residual missing (lora)");
                let mut dla = arena.take_f32(r * lin.din);
                matmul_tn_into(&mut dla, &du, xx, r, rows, lin.din);
                self.acc(arena, grads, lai, dla);
            }
            matmul_nn_acc_into(&mut dx, &du, params[lai].as_f32(), rows,
                               r, lin.din);
            arena.put_f32(du);
        }
        dx
    }

    fn norm_param_bwd(&self, arena: &mut Arena, params: &[Tensor],
                      nd: &NormDef, dy: &[f32], xhat: &[f32],
                      stat: &[f32], rows: usize,
                      grads: &mut [Option<Vec<f32>>]) -> Vec<f32> {
        let c = self.cfg.dim;
        let mut dx = arena.take_f32(rows * c);
        if let Some(gi) = nd.g {
            let mut dg = arena.take_f32_zeroed(c);
            for (dyrow, xrow) in dy.chunks(c).zip(xhat.chunks(c)) {
                for ((o, &d), &xh) in dg.iter_mut().zip(dyrow).zip(xrow) {
                    *o += d * xh;
                }
            }
            self.acc(arena, grads, gi, dg);
            if let Some(bi) = nd.b {
                let mut db = arena.take_f32(c);
                colsum_into(&mut db, dy, rows, c);
                self.acc(arena, grads, bi, db);
            }
            let g = params[gi].as_f32();
            let mut dyh = arena.take_f32(dy.len());
            for (orow, dyrow) in dyh.chunks_mut(c).zip(dy.chunks(c)) {
                for ((o, &d), &gv) in orow.iter_mut().zip(dyrow).zip(g) {
                    *o = d * gv;
                }
            }
            norm_bwd_into(&mut dx, &dyh, xhat, stat, rows, c,
                          self.cfg.is_rms());
            arena.put_f32(dyh);
        } else {
            norm_bwd_into(&mut dx, dy, xhat, stat, rows, c,
                          self.cfg.is_rms());
        }
        dx
    }

    /// Forward pass with a throwaway arena (tests / one-shot callers).
    /// The executor path uses [`Model::forward_in`] with its persistent
    /// arena.
    pub fn forward(&self, params: &[Tensor], x: &Tensor,
                   y: &Tensor) -> Result<(f32, f32, Vec<SavedRes>)> {
        self.forward_in(&mut Arena::new(), params, x, y)
    }

    /// Forward pass. Returns `(loss, metric, residuals)` with residuals
    /// in the canonical push order (the manifest order). Activations and
    /// residual payloads are drawn from `arena`.
    pub fn forward_in(&self, arena: &mut Arena, params: &[Tensor],
                      x: &Tensor,
                      y: &Tensor) -> Result<(f32, f32, Vec<SavedRes>)> {
        ensure!(params.len() == self.infos.len(),
                "param arity: got {}, expected {}", params.len(),
                self.infos.len());
        self.check_batch(x, y)?;
        let cfg = &self.cfg;
        let (bsz, n, c) = (cfg.batch, cfg.n_tokens, cfg.dim);
        let rows = self.rows();
        let mut saves: Vec<SavedRes> = Vec::new();
        let mut h = self.embed_fwd(arena, params, x)?;
        for blk in &self.blocks {
            h = self.block_fwd(arena, params, blk, h, &mut saves);
        }
        let mut xhatf = arena.take_f32(rows * c);
        let mut statf = arena.take_f32(rows);
        norm_fwd_into(&mut xhatf, &mut statf, &h, rows, c, cfg.is_rms());
        arena.put_f32(h);
        self.save(arena, &mut saves, self.normf.name.clone(),
                  self.norm_kind(), &[bsz, n, c], &xhatf);
        self.save(arena, &mut saves, self.normf.name.clone(), "norm_stat",
                  &[bsz, n], &statf);
        let afff = self.norm_affine(arena, params, &self.normf, &xhatf);
        let (loss, metric) = match cfg.arch {
            Arch::Llama => {
                let hn: &[f32] = afff.as_deref().unwrap_or(&xhatf);
                if self.head.need_x() {
                    self.save(arena, &mut saves, self.head.name.clone(),
                              "head_input", &[bsz, n, c], hn);
                }
                let z = self.lin_fwd(arena, params, &self.head, hn, rows,
                                     &[bsz, n], &mut saves);
                let out = softmax_ce(&z, rows, cfg.vocab, y.as_i32());
                self.save(arena, &mut saves, "head".into(), "logits",
                          &[bsz, n, cfg.vocab], &z);
                arena.put_f32(z);
                out
            }
            _ => {
                let hn: &[f32] = afff.as_deref().unwrap_or(&xhatf);
                let mut pooled = arena.take_f32_zeroed(bsz * c);
                for b in 0..bsz {
                    let prow = &mut pooled[b * c..(b + 1) * c];
                    for i in 0..n {
                        let hrow = &hn[(b * n + i) * c..(b * n + i + 1) * c];
                        add_inplace(prow, hrow);
                    }
                    for v in prow.iter_mut() {
                        *v /= n as f32;
                    }
                }
                self.save(arena, &mut saves, self.head.name.clone(),
                          "head_input", &[bsz, c], &pooled);
                let z = self.lin_fwd(arena, params, &self.head, &pooled,
                                     bsz, &[bsz], &mut saves);
                arena.put_f32(pooled);
                let out = softmax_ce(&z, bsz, cfg.n_classes, y.as_i32());
                self.save(arena, &mut saves, "head".into(), "logits",
                          &[bsz, cfg.n_classes], &z);
                arena.put_f32(z);
                out
            }
        };
        if let Some(aff) = afff {
            arena.put_f32(aff);
        }
        arena.put_f32(xhatf);
        arena.put_f32(statf);
        Ok((loss, metric, saves))
    }

    fn block_fwd(&self, arena: &mut Arena, params: &[Tensor],
                 blk: &BlockDef, mut h: Vec<f32>,
                 saves: &mut Vec<SavedRes>) -> Vec<f32> {
        let cfg = &self.cfg;
        let (bsz, n, c) = (cfg.batch, cfg.n_tokens, cfg.dim);
        let rows = self.rows();
        let lead = [bsz, n];
        // ---- attention half ----
        let mut xhat1 = arena.take_f32(rows * c);
        let mut stat1 = arena.take_f32(rows);
        norm_fwd_into(&mut xhat1, &mut stat1, &h, rows, c, cfg.is_rms());
        self.save(arena, saves, blk.norm1.name.clone(), self.norm_kind(),
                  &[bsz, n, c], &xhat1);
        self.save(arena, saves, blk.norm1.name.clone(), "norm_stat",
                  &[bsz, n], &stat1);
        let aff1 = self.norm_affine(arena, params, &blk.norm1, &xhat1);
        let xn1: &[f32] = aff1.as_deref().unwrap_or(&xhat1);
        let need_qkv_x =
            blk.q.need_x() || blk.k.need_x() || blk.v.need_x();
        if !cfg.is_ms() && need_qkv_x {
            self.save(arena, saves, blk.qkv_name.clone(),
                      "linear_input", &[bsz, n, c], xn1);
        }
        let q = self.lin_fwd(arena, params, &blk.q, xn1, rows, &lead,
                             saves);
        let k = self.lin_fwd(arena, params, &blk.k, xn1, rows, &lead,
                             saves);
        let v = self.lin_fwd(arena, params, &blk.v, xn1, rows, &lead,
                             saves);
        for (name, t) in [(&blk.q.name, &q), (&blk.k.name, &k),
                          (&blk.v.name, &v)] {
            self.save(arena, saves, name.clone(), "attn_qkv",
                      &[bsz, n, c], t);
        }
        let mut o = arena.take_f32(rows * c);
        let mut hm = arena.take_f32(rows * c);
        attn_fwd_into(&mut o, &mut hm, &q, &k, &v, &self.attn_dims(),
                      cfg.causal());
        arena.put_f32(hm);
        arena.put_f32(q);
        arena.put_f32(k);
        arena.put_f32(v);
        if let Some(aff) = aff1 {
            arena.put_f32(aff);
        }
        arena.put_f32(xhat1);
        arena.put_f32(stat1);
        if blk.proj.need_x() {
            self.save(arena, saves, blk.proj.name.clone(), "linear_input",
                      &[bsz, n, c], &o);
        }
        let po = self.lin_fwd(arena, params, &blk.proj, &o, rows, &lead,
                              saves);
        arena.put_f32(o);
        add_inplace(&mut h, &po);
        arena.put_f32(po);
        // ---- mlp half ----
        let m = cfg.hidden();
        let mut xhat2 = arena.take_f32(rows * c);
        let mut stat2 = arena.take_f32(rows);
        norm_fwd_into(&mut xhat2, &mut stat2, &h, rows, c, cfg.is_rms());
        self.save(arena, saves, blk.norm2.name.clone(), self.norm_kind(),
                  &[bsz, n, c], &xhat2);
        self.save(arena, saves, blk.norm2.name.clone(), "norm_stat",
                  &[bsz, n], &stat2);
        let aff2 = self.norm_affine(arena, params, &blk.norm2, &xhat2);
        let xn2: &[f32] = aff2.as_deref().unwrap_or(&xhat2);
        if !cfg.is_ms() && blk.fc1.need_x() {
            self.save(arena, saves, blk.fc1.name.clone(), "linear_input",
                      &[bsz, n, c], xn2);
        }
        let u = self.lin_fwd(arena, params, &blk.fc1, xn2, rows, &lead,
                             saves);
        if let Some(aff) = aff2 {
            arena.put_f32(aff);
        }
        arena.put_f32(xhat2);
        arena.put_f32(stat2);
        let mut hact = arena.take_f32(rows * m);
        super::kernels::act_fwd_into(&mut hact, &u, cfg.is_gelu());
        if cfg.act_exact_bwd() {
            self.save(arena, saves, blk.act_name.clone(), "act_full",
                      &[bsz, n, m], &u);
        } else {
            // fused bucketize+pack straight into the residual payload:
            // no intermediate code vector, no fresh allocation
            let mut codes = arena.take_u8(rows * m / 4);
            packing::encode2_into(&u, cfg.comb().c, &mut codes);
            saves.push(SavedRes {
                module: blk.act_name.clone(),
                kind: "act_codes",
                tensor: Tensor {
                    shape: vec![bsz, n, m / 4],
                    dtype: DType::U8,
                    data: codes,
                },
            });
        }
        arena.put_f32(u);
        if blk.fc2.need_x() {
            self.save(arena, saves, blk.fc2.name.clone(), "linear_input",
                      &[bsz, n, m], &hact);
        }
        let mo = self.lin_fwd(arena, params, &blk.fc2, &hact, rows,
                              &lead, saves);
        arena.put_f32(hact);
        add_inplace(&mut h, &mo);
        arena.put_f32(mo);
        h
    }

    /// Backward pass with a throwaway arena (tests / one-shot callers).
    pub fn backward(&self, params: &[Tensor], residuals: &[Tensor],
                    x: &Tensor, y: &Tensor) -> Result<Vec<Tensor>> {
        self.backward_in(&mut Arena::new(), params, residuals, x, y)
    }

    /// Backward pass from the residual list `forward` produced. Returns
    /// gradients for the trainable parameters, in manifest order.
    /// Scratch buffers are drawn from `arena`.
    pub fn backward_in(&self, arena: &mut Arena, params: &[Tensor],
                       residuals: &[Tensor], x: &Tensor,
                       y: &Tensor) -> Result<Vec<Tensor>> {
        ensure!(params.len() == self.infos.len(), "param arity");
        self.check_batch(x, y)?;
        let cfg = &self.cfg;
        let (bsz, n, c) = (cfg.batch, cfg.n_tokens, cfg.dim);
        let rows = self.rows();
        let mut grads: Vec<Option<Vec<f32>>> = Vec::new();
        grads.resize_with(self.infos.len(), || None);
        let mut st = Stack { res: residuals, top: residuals.len() };

        // ---- head / loss ----
        let z = st.pop()?;
        let dhn: Vec<f32> = match cfg.arch {
            Arch::Llama => {
                ensure!(z.elems() == rows * cfg.vocab, "bad z residual");
                let mut dz = arena.take_f32(rows * cfg.vocab);
                softmax_ce_grad_into(&mut dz, z.as_f32(), rows, cfg.vocab,
                                     y.as_i32());
                let hn = if self.head.need_x() {
                    Some(st.pop()?)
                } else {
                    None
                };
                let d = self.lin_bwd(arena, params, &self.head, &dz,
                                     hn.map(|t| t.as_f32()), None, rows,
                                     &mut grads);
                arena.put_f32(dz);
                d
            }
            _ => {
                ensure!(z.elems() == bsz * cfg.n_classes,
                        "bad z residual");
                let mut dz = arena.take_f32(bsz * cfg.n_classes);
                softmax_ce_grad_into(&mut dz, z.as_f32(), bsz,
                                     cfg.n_classes, y.as_i32());
                let pooled = st.pop()?;
                let dpooled = self.lin_bwd(arena, params, &self.head,
                                           &dz, Some(pooled.as_f32()),
                                           None, bsz, &mut grads);
                arena.put_f32(dz);
                let mut dhn = arena.take_f32(rows * c);
                let inv = 1.0 / n as f32;
                for b in 0..bsz {
                    let src = &dpooled[b * c..(b + 1) * c];
                    for i in 0..n {
                        let dst = &mut dhn
                            [(b * n + i) * c..(b * n + i + 1) * c];
                        for (d, &s) in dst.iter_mut().zip(src) {
                            *d = s * inv;
                        }
                    }
                }
                arena.put_f32(dpooled);
                dhn
            }
        };
        let statf = st.pop()?;
        let xhatf = st.pop()?;
        debug_assert_eq!(statf.elems(), rows);
        debug_assert_eq!(xhatf.elems(), rows * c);
        let mut dh = self.norm_param_bwd(arena, params, &self.normf, &dhn,
                                         xhatf.as_f32(), statf.as_f32(),
                                         rows, &mut grads);
        arena.put_f32(dhn);
        // ---- blocks in reverse ----
        for blk in self.blocks.iter().rev() {
            dh = self.block_bwd(arena, params, blk, dh, &mut st,
                                &mut grads)?;
        }
        ensure!(st.top == 0, "residual stack not fully consumed: {} left",
                st.top);
        // ---- embedding ----
        match cfg.arch {
            Arch::Vit => {
                if self.infos[self.embed_w.unwrap()].trainable {
                    let mut dw =
                        arena.take_f32(c * cfg.patch_dim);
                    matmul_tn_into(&mut dw, &dh, x.as_f32(), c, rows,
                                   cfg.patch_dim);
                    self.acc(arena, &mut grads, self.embed_w.unwrap(),
                             dw);
                    let mut db = arena.take_f32(c);
                    colsum_into(&mut db, &dh, rows, c);
                    self.acc(arena, &mut grads, self.embed_b.unwrap(),
                             db);
                }
            }
            _ => {
                let ei = self.tok_e.unwrap();
                if self.infos[ei].trainable {
                    let mut de = arena.take_f32_zeroed(cfg.vocab * c);
                    for (r, &t) in x.as_i32().iter().enumerate() {
                        let t = t as usize;
                        add_inplace(&mut de[t * c..(t + 1) * c],
                                    &dh[r * c..(r + 1) * c]);
                    }
                    self.acc(arena, &mut grads, ei, de);
                }
            }
        }
        if self.infos[self.pos].trainable {
            let mut dpos = arena.take_f32_zeroed(n * c);
            for r in 0..rows {
                let i = r % n;
                add_inplace(&mut dpos[i * c..(i + 1) * c],
                            &dh[r * c..(r + 1) * c]);
            }
            self.acc(arena, &mut grads, self.pos, dpos);
        }
        arena.put_f32(dh);
        // ---- collect trainable grads in manifest order ----
        let mut out = Vec::new();
        for (i, info) in self.infos.iter().enumerate() {
            if info.trainable {
                let g = grads[i]
                    .take()
                    .ok_or_else(|| anyhow::anyhow!(
                        "missing gradient for {}", info.name))?;
                // gradient tensors draw their payloads from the arena
                // too; the trainer recycles them after the optimizer
                // step, so steady-state steps allocate nothing here
                out.push(arena.tensor_from_f32(&info.shape, &g));
                arena.put_f32(g);
            }
        }
        Ok(out)
    }

    fn block_bwd(&self, arena: &mut Arena, params: &[Tensor],
                 blk: &BlockDef, dh: Vec<f32>, st: &mut Stack<'_>,
                 grads: &mut [Option<Vec<f32>>]) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let c = cfg.dim;
        let m = cfg.hidden();
        let rows = self.rows();
        // ---- mlp half (reverse of push order) ----
        let u_fc2 = if blk.fc2.la.is_some() { Some(st.pop()?) } else { None };
        let hact = if blk.fc2.need_x() { Some(st.pop()?) } else { None };
        let act_save = st.pop()?;
        let u_fc1 = if blk.fc1.la.is_some() { Some(st.pop()?) } else { None };
        let xn2s = if !cfg.is_ms() && blk.fc1.need_x() {
            Some(st.pop()?)
        } else {
            None
        };
        let stat2 = st.pop()?;
        let xhat2 = st.pop()?;
        debug_assert_eq!(stat2.elems(), rows);
        debug_assert_eq!(xhat2.elems(), rows * c);
        let xn2: Option<&[f32]> = if cfg.is_ms() {
            Some(xhat2.as_f32())
        } else {
            xn2s.map(|t| t.as_f32())
        };
        let dhact = self.lin_bwd(arena, params, &blk.fc2, &dh,
                                 hact.map(|t| t.as_f32()),
                                 u_fc2.map(|t| t.as_f32()), rows, grads);
        let mut du = arena.take_f32(rows * m);
        if cfg.act_exact_bwd() {
            ensure!(act_save.dtype == DType::F32
                        && act_save.elems() == rows * m,
                    "bad act_full residual");
            super::kernels::act_bwd_exact_into(&mut du, act_save.as_f32(),
                                               &dhact, cfg.is_gelu());
        } else {
            ensure!(act_save.dtype == DType::U8
                        && act_save.nbytes() == rows * m / 4,
                    "bad act_codes residual");
            packing::apply_slopes_into(&mut du, &act_save.data, &dhact,
                                       cfg.comb().slopes());
        }
        arena.put_f32(dhact);
        let dxn2 = self.lin_bwd(arena, params, &blk.fc1, &du, xn2,
                                u_fc1.map(|t| t.as_f32()), rows, grads);
        arena.put_f32(du);
        let dnorm2 = self.norm_param_bwd(arena, params, &blk.norm2,
                                         &dxn2, xhat2.as_f32(),
                                         stat2.as_f32(), rows, grads);
        arena.put_f32(dxn2);
        let mut dh1 = dh;
        add_inplace(&mut dh1, &dnorm2);
        arena.put_f32(dnorm2);
        // ---- attention half ----
        let u_proj =
            if blk.proj.la.is_some() { Some(st.pop()?) } else { None };
        let o = if blk.proj.need_x() { Some(st.pop()?) } else { None };
        let v = st.pop()?;
        let k = st.pop()?;
        let q = st.pop()?;
        debug_assert_eq!(q.elems(), rows * c);
        let u_v = if blk.v.la.is_some() { Some(st.pop()?) } else { None };
        let u_k = if blk.k.la.is_some() { Some(st.pop()?) } else { None };
        let u_q = if blk.q.la.is_some() { Some(st.pop()?) } else { None };
        let need_qkv_x =
            blk.q.need_x() || blk.k.need_x() || blk.v.need_x();
        let xn1s = if !cfg.is_ms() && need_qkv_x {
            Some(st.pop()?)
        } else {
            None
        };
        let stat1 = st.pop()?;
        let xhat1 = st.pop()?;
        debug_assert_eq!(stat1.elems(), rows);
        debug_assert_eq!(xhat1.elems(), rows * c);
        let xn1: Option<&[f32]> = if cfg.is_ms() {
            Some(xhat1.as_f32())
        } else {
            xn1s.map(|t| t.as_f32())
        };
        let do_ = self.lin_bwd(arena, params, &blk.proj, &dh1,
                               o.map(|t| t.as_f32()),
                               u_proj.map(|t| t.as_f32()), rows, grads);
        let mut dq = arena.take_f32(rows * c);
        let mut dk = arena.take_f32(rows * c);
        let mut dv = arena.take_f32(rows * c);
        let mut scr = arena.take_f32(3 * rows * c);
        attn_bwd_into(&mut dq, &mut dk, &mut dv, &mut scr, &do_,
                      q.as_f32(), k.as_f32(), v.as_f32(),
                      &self.attn_dims(), cfg.causal());
        arena.put_f32(scr);
        arena.put_f32(do_);
        let mut dxn1 = self.lin_bwd(arena, params, &blk.q, &dq, xn1,
                                    u_q.map(|t| t.as_f32()), rows, grads);
        arena.put_f32(dq);
        let dk_in = self.lin_bwd(arena, params, &blk.k, &dk, xn1,
                                 u_k.map(|t| t.as_f32()), rows, grads);
        arena.put_f32(dk);
        add_inplace(&mut dxn1, &dk_in);
        arena.put_f32(dk_in);
        let dv_in = self.lin_bwd(arena, params, &blk.v, &dv, xn1,
                                 u_v.map(|t| t.as_f32()), rows, grads);
        arena.put_f32(dv);
        add_inplace(&mut dxn1, &dv_in);
        arena.put_f32(dv_in);
        let dnorm1 = self.norm_param_bwd(arena, params, &blk.norm1,
                                         &dxn1, xhat1.as_f32(),
                                         stat1.as_f32(), rows, grads);
        arena.put_f32(dxn1);
        add_inplace(&mut dh1, &dnorm1);
        arena.put_f32(dnorm1);
        Ok(dh1)
    }
}

struct Stack<'a> {
    res: &'a [Tensor],
    top: usize,
}

impl<'a> Stack<'a> {
    fn pop(&mut self) -> Result<&'a Tensor> {
        ensure!(self.top > 0, "residual stack underflow");
        self.top -= 1;
        Ok(&self.res[self.top])
    }
}
