//! The native backend's transformer, assembled from the composable
//! [`layers`](super::layers) API: `Model::build` registers parameters
//! and mints residual-tape slots while composing a [`Seq`] of `Layer`
//! objects per block, so the residual ABI (DESIGN.md §2.2) is *derived*
//! from the composition — the manifest residual section, the measured
//! memory accounting, and the fwd/bwd push/pop symmetry all come from
//! the same slot list, enforced by the tape cursors.
//!
//! Block structure (pre-norm): `h += Attention(Norm(h))` then
//! `h += Mlp(Norm(h))`, where the MLP is `fc1 → act → fc2` or, with
//! `swiglu`, the gated LLaMA form (plus RoPE inside the attention and
//! no learned positions). With `ckpt`, each half is wrapped in a
//! [`CkptBlock`] that stores only the half's input and recomputes the
//! inner residuals in backward.
//!
//! The gradient math is cross-checked against finite differences for
//! every (arch × tuning × act × norm [× swiglu × ckpt × mesa])
//! combination; the full grid is pinned by `tests/tape_grid.rs`.

use anyhow::{bail, ensure, Result};

use super::arena::Arena;
use super::layers::{
    Activation, Attention, CkptBlock, Composer, Embed, Head, Layer,
    Linear, Norm as NormLayer, ParamReg, Profiler, Residual, Seq,
    SlotInfo, SwiGlu, TapeReader, TapeWriter,
};
use super::layers::{BwdCtx, BwdLane, FwdCtx, FwdLane};
use crate::coeffs::funcs::{ReluComb, PAPER_GELU, PAPER_SILU};
use crate::runtime::manifest::ParamInfo;
use crate::runtime::params::Params;
use crate::runtime::tensor::{DType, Tensor};
use crate::util::rng::Rng;

/// Model family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// Patch-token classifier (ViT): f32 `[B,N,P]` input, `[B]` labels.
    Vit,
    /// Causal LM (LLaMA-style: RMS norms, no biases): i32 `[B,N]` tokens,
    /// `[B,N]` next-token targets.
    Llama,
    /// Bidirectional sequence classifier (RoBERTa-style): i32 `[B,N]`
    /// tokens, `[B]` labels.
    Roberta,
}

/// Which parameters train (the paper's Table 1/3 axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tuning {
    /// Everything trains.
    Full,
    /// Only the classifier head trains (linear probe).
    Frozen,
    /// LoRA adapters on q/v (+ head).
    LoraQv,
    /// LoRA adapters on every block linear (+ head).
    LoraAll,
    /// LoRA-FA on q/v: A frozen, so linear inputs need not be saved.
    LoraFaQv,
    /// LoRA-FA on every block linear.
    LoraFaAll,
}

/// Activation function variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    /// Exact GELU fwd, exact bwd from the saved f32 pre-activation.
    Gelu,
    /// Exact GELU fwd, approximate bwd from 2-bit codes (ReGELU2).
    ReGelu2,
    /// Exact SiLU fwd/bwd.
    Silu,
    /// Exact SiLU fwd, approximate bwd from 2-bit codes (ReSiLU2).
    ReSilu2,
    /// ReLU: exact bwd from 1-bit sign codes (Table 7's ReLU column).
    Relu,
}

impl Act {
    /// Whether the exact forward/backward uses the GELU primitives.
    pub fn is_gelu(self) -> bool {
        matches!(self, Act::Gelu | Act::ReGelu2)
    }

    /// The 3-ReLU combination whose thresholds/slopes the 2-bit codecs
    /// use. Panics for [`Act::Relu`], which has no combination (its
    /// 1-bit codes need only the sign).
    pub fn comb(self) -> &'static ReluComb {
        match self {
            Act::Gelu | Act::ReGelu2 => &PAPER_GELU,
            Act::Silu | Act::ReSilu2 => &PAPER_SILU,
            Act::Relu => panic!("relu has no 3-ReLU combination"),
        }
    }
}

/// Normalization variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Norm {
    /// LayerNorm with affine; stores x̂ *and* the affine output.
    Ln,
    /// Memory-sharing LayerNorm: affine merged into the next linears
    /// (eq. 17), one shared x̂ residual.
    MsLn,
    /// RMSNorm with scale.
    Rms,
    /// Memory-sharing RMSNorm.
    MsRms,
}

/// Architecture + variant configuration of a native model, mirroring the
/// manifest `config` section.
#[derive(Debug, Clone)]
pub struct NetCfg {
    /// Model family.
    pub arch: Arch,
    /// Embedding width C.
    pub dim: usize,
    /// Number of transformer blocks.
    pub depth: usize,
    /// Attention heads (must divide `dim`).
    pub n_heads: usize,
    /// Tokens per sequence N.
    pub n_tokens: usize,
    /// Batch size B.
    pub batch: usize,
    /// Classifier classes (ViT / RoBERTa).
    pub n_classes: usize,
    /// Vocabulary size (LLaMA / RoBERTa).
    pub vocab: usize,
    /// MLP expansion ratio (hidden = dim · ratio).
    pub mlp_ratio: f64,
    /// LoRA rank r.
    pub lora_rank: usize,
    /// Patch dimension P (ViT input feature size).
    pub patch_dim: usize,
    /// Trainability mode.
    pub tuning: Tuning,
    /// Activation variant.
    pub act: Act,
    /// Normalization variant.
    pub norm: Norm,
    /// SwiGLU gated MLP + RoPE attention (the real LLaMA block shape;
    /// LLaMA arch only). Replaces the learned position table.
    pub swiglu: bool,
    /// Gradient checkpointing: store one input per block half,
    /// recompute the rest in bwd.
    pub ckpt: bool,
    /// Mesa-style int8 activation quantization (the `_mesa` preset
    /// axis): the nonlinear-layer saves — norm x̂ (plain or shared)
    /// and full-precision pre-activations — are stored on the tape as
    /// per-group symmetric int8 codes + f32 scales and dequantized on
    /// pop in bwd. Forward stays exact; backward carries the
    /// quantization error (the Mesa tradeoff the paper benchmarks
    /// against).
    pub mesa: bool,
}

impl NetCfg {
    /// MLP hidden width M.
    pub fn hidden(&self) -> usize {
        (self.dim as f64 * self.mlp_ratio) as usize
    }

    /// Memory-sharing norm variant?
    pub fn is_ms(&self) -> bool {
        matches!(self.norm, Norm::MsLn | Norm::MsRms)
    }

    /// RMS-family norm (single stat, no mean subtraction)?
    pub fn is_rms(&self) -> bool {
        matches!(self.norm, Norm::Rms | Norm::MsRms)
    }

    /// Does the norm own an affine transform (plain variants)?
    pub fn has_affine(&self) -> bool {
        matches!(self.norm, Norm::Ln | Norm::Rms)
    }

    /// Linears carry biases (everything but LLaMA).
    pub fn use_bias(&self) -> bool {
        self.arch != Arch::Llama
    }

    /// Causal attention mask (LLaMA).
    pub fn causal(&self) -> bool {
        self.arch == Arch::Llama
    }

    /// Rotary position embedding (tied to the `swiglu` axis: the real
    /// LLaMA block shape).
    pub fn rope(&self) -> bool {
        self.swiglu
    }

    /// Full fine-tuning?
    pub fn tuning_full(&self) -> bool {
        self.tuning == Tuning::Full
    }

    /// LoRA-FA (A frozen) variant?
    pub fn lora_fa(&self) -> bool {
        matches!(self.tuning, Tuning::LoraFaQv | Tuning::LoraFaAll)
    }

    /// Does linear `which` (`"q"`, `"v"`, `"fc1"`, …) carry a LoRA
    /// adapter under this tuning?
    pub fn lora_on(&self, which: &str) -> bool {
        match self.tuning {
            Tuning::LoraQv | Tuning::LoraFaQv => which == "q" || which == "v",
            Tuning::LoraAll | Tuning::LoraFaAll => true,
            Tuning::Full | Tuning::Frozen => false,
        }
    }

    /// Does the head train?
    pub fn head_trainable(&self) -> bool {
        match self.arch {
            Arch::Llama => self.tuning == Tuning::Full,
            _ => true,
        }
    }

    /// Basic structural validation; returns a descriptive error on
    /// configs the native backend cannot run.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.dim > 0 && self.depth > 0 && self.n_tokens > 0
                    && self.batch > 0, "empty model dims");
        ensure!(self.dim % self.n_heads == 0,
                "dim {} not divisible by n_heads {}", self.dim,
                self.n_heads);
        ensure!(self.hidden() % 4 == 0,
                "mlp hidden {} must be a multiple of 4 (2-bit packing)",
                self.hidden());
        if self.act == Act::Relu {
            ensure!(self.hidden() % 8 == 0,
                    "mlp hidden {} must be a multiple of 8 (1-bit relu \
                     packing)",
                    self.hidden());
        }
        if self.swiglu {
            ensure!(self.arch == Arch::Llama,
                    "swiglu/rope is a llama-family axis");
            ensure!((self.dim / self.n_heads) % 2 == 0,
                    "rope needs an even head dim, got {}",
                    self.dim / self.n_heads);
        }
        match self.arch {
            Arch::Vit => ensure!(self.patch_dim > 0 && self.n_classes > 1,
                                 "vit needs patch_dim and n_classes"),
            Arch::Llama => ensure!(self.vocab > 1, "llama needs vocab"),
            Arch::Roberta => ensure!(self.vocab > 1 && self.n_classes > 1,
                                     "roberta needs vocab and n_classes"),
        }
        if matches!(self.tuning, Tuning::LoraQv | Tuning::LoraAll
                        | Tuning::LoraFaQv | Tuning::LoraFaAll) {
            ensure!(self.lora_rank > 0, "lora tuning needs lora_rank > 0");
        }
        Ok(())
    }

    /// Parse the manifest `tuning` string (both `lora_qv` and `loraqv`
    /// spellings are accepted).
    pub fn tuning_from_str(s: &str) -> Result<Tuning> {
        Ok(match s {
            "full" => Tuning::Full,
            "frozen" => Tuning::Frozen,
            "lora_qv" | "loraqv" => Tuning::LoraQv,
            "lora_all" | "loraall" => Tuning::LoraAll,
            "lorafa_qv" | "lorafaqv" => Tuning::LoraFaQv,
            "lorafa_all" | "lorafaall" => Tuning::LoraFaAll,
            other => bail!("unsupported tuning {other:?}"),
        })
    }

    /// Parse the manifest `activation` string.
    pub fn act_from_str(s: &str) -> Result<Act> {
        Ok(match s {
            "gelu" => Act::Gelu,
            "regelu2" => Act::ReGelu2,
            "silu" => Act::Silu,
            "resilu2" => Act::ReSilu2,
            "relu" => Act::Relu,
            other => bail!("unsupported activation {other:?} (native \
                            backend supports \
                            gelu|regelu2|silu|resilu2|relu)"),
        })
    }

    /// Parse the manifest `norm` string.
    pub fn norm_from_str(s: &str) -> Result<Norm> {
        Ok(match s {
            "ln" => Norm::Ln,
            "msln" => Norm::MsLn,
            "rms" => Norm::Rms,
            "msrms" => Norm::MsRms,
            other => bail!("unsupported norm {other:?} (native backend \
                            supports ln|msln|rms|msrms)"),
        })
    }

    /// Parse the manifest `arch` string.
    pub fn arch_from_str(s: &str) -> Result<Arch> {
        Ok(match s {
            "vit" => Arch::Vit,
            "llama" => Arch::Llama,
            "roberta" => Arch::Roberta,
            other => bail!("unsupported arch {other:?}"),
        })
    }
}

/// A built native model: the parameter layout, the derived residual
/// tape schema, and the layer composition that executes fwd/bwd.
pub struct Model {
    /// The configuration the layout was derived from.
    pub cfg: NetCfg,
    /// Parameter layout in manifest order.
    pub infos: Vec<ParamInfo>,
    seq: Seq,
    schema: Vec<SlotInfo>,
}

impl Model {
    /// Compose the layer stack for a config, deriving the parameter
    /// layout and the residual tape schema as a side effect of the
    /// composition.
    pub fn build(cfg: NetCfg) -> Result<Model> {
        cfg.validate()?;
        let (bsz, n, c) = (cfg.batch, cfg.n_tokens, cfg.dim);
        let m = cfg.hidden();
        let lead = [bsz, n];
        let mut reg = ParamReg::new();
        let mut comp = Composer::with_mesa(cfg.mesa);
        let mut layers: Vec<Box<dyn Layer>> = Vec::new();
        layers.push(Box::new(Embed::new(&cfg, &mut reg)));
        for i in 0..cfg.depth {
            let an = format!("block{i}.attn");
            let mn = format!("block{i}.mlp");
            // ---- attention half: h += Attn(Norm(h)) ----
            {
                let half = |reg: &mut ParamReg, comp: &mut Composer| {
                    let norm = NormLayer::new(&cfg, reg, comp,
                                              &format!("{an}.norm"),
                                              &lead);
                    let shared = norm.shared_slot();
                    let attn = Attention::new(&cfg, reg, comp, &an,
                                              &lead, shared);
                    Seq::new(vec![Box::new(norm), Box::new(attn)])
                };
                if cfg.ckpt {
                    // the inner (recomputed) tape quantizes the same
                    // saves a stored tape would — ckpt and mesa compose
                    let mut inner = Composer::with_mesa(cfg.mesa);
                    let seq = half(&mut reg, &mut inner);
                    layers.push(Box::new(CkptBlock::new(
                        &mut comp, &an, &[bsz, n, c],
                        Box::new(Residual::new(seq)), inner.finish())));
                } else {
                    layers.push(Box::new(Residual::new(
                        half(&mut reg, &mut comp))));
                }
            }
            // ---- mlp half: h += Mlp(Norm(h)) ----
            {
                let half = |reg: &mut ParamReg, comp: &mut Composer| {
                    let norm = NormLayer::new(&cfg, reg, comp,
                                              &format!("{mn}.norm"),
                                              &lead);
                    let shared = norm.shared_slot();
                    let mut inner: Vec<Box<dyn Layer>> =
                        vec![Box::new(norm)];
                    if cfg.swiglu {
                        inner.push(Box::new(SwiGlu::new(&cfg, reg, comp,
                                                        &mn, &lead,
                                                        shared)));
                    } else {
                        inner.push(Box::new(Linear::new(
                            &cfg, reg, comp, &format!("{mn}.fc1"), "fc1",
                            c, m, &lead, shared)));
                        inner.push(Box::new(Activation::new(
                            &cfg, comp, &format!("{mn}.act"), &lead, m)));
                        inner.push(Box::new(Linear::new(
                            &cfg, reg, comp, &format!("{mn}.fc2"), "fc2",
                            m, c, &lead, None)));
                    }
                    Seq::new(inner)
                };
                if cfg.ckpt {
                    let mut inner = Composer::with_mesa(cfg.mesa);
                    let seq = half(&mut reg, &mut inner);
                    layers.push(Box::new(CkptBlock::new(
                        &mut comp, &mn, &[bsz, n, c],
                        Box::new(Residual::new(seq)), inner.finish())));
                } else {
                    layers.push(Box::new(Residual::new(
                        half(&mut reg, &mut comp))));
                }
            }
        }
        layers.push(Box::new(NormLayer::new(&cfg, &mut reg, &mut comp,
                                            "head.norm", &lead)));
        layers.push(Box::new(Head::new(&cfg, &mut reg, &mut comp)));
        Ok(Model {
            cfg,
            infos: reg.infos,
            seq: Seq::new(layers),
            schema: comp.finish(),
        })
    }

    /// The derived residual tape schema (push order) — the single
    /// source of the residual ABI: `forward` emits exactly these
    /// tensors, and the manifest residual section is synthesized from
    /// this list (`spec::build_manifest`).
    pub fn schema(&self) -> &[SlotInfo] {
        &self.schema
    }

    /// Deterministic parameter init (He-scaled weights, identity norms,
    /// zero biases and LoRA-B). Each tensor's stream is keyed by
    /// `(seed, name)`, so parameters shared between presets (e.g. the
    /// frozen base under different LoRA layouts) get identical values —
    /// which is also what makes LoRA variants start exactly at the base
    /// model.
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        fn fnv1a(s: &str) -> u64 {
            let mut h = 0xcbf29ce484222325u64;
            for b in s.bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            h
        }
        self.infos
            .iter()
            .map(|info| {
                let mut rng = Rng::new(seed ^ fnv1a(&info.name));
                let n: usize = info.shape.iter().product();
                let mut v = vec![0f32; n];
                let name = info.name.as_str();
                if name.ends_with(".norm.w") {
                    v.fill(1.0);
                } else if name == "head.fc.W"
                    || name == "embed.pos"
                    || name == "embed.tok.E"
                {
                    for x in v.iter_mut() {
                        *x = rng.normal_f32() * 0.02;
                    }
                } else if name.ends_with(".W") || name.ends_with(".lora_a")
                {
                    let scale =
                        1.0 / (info.shape[1] as f32).sqrt();
                    for x in v.iter_mut() {
                        *x = rng.normal_f32() * scale;
                    }
                }
                // biases, lora_b, norm .b stay zero
                Tensor::from_f32(&info.shape, &v)
            })
            .collect()
    }

    fn check_batch(&self, x: &Tensor, y: &Tensor) -> Result<()> {
        let (b, n) = (self.cfg.batch, self.cfg.n_tokens);
        match self.cfg.arch {
            Arch::Vit => {
                ensure!(x.dtype == DType::F32
                            && x.shape == [b, n, self.cfg.patch_dim],
                        "bad x for vit: {:?}", x.shape);
                ensure!(y.dtype == DType::I32 && y.elems() == b,
                        "bad y for vit: {:?}", y.shape);
            }
            Arch::Llama => {
                ensure!(x.dtype == DType::I32 && x.shape == [b, n],
                        "bad x for llama: {:?}", x.shape);
                ensure!(y.dtype == DType::I32 && y.elems() == b * n,
                        "bad y for llama: {:?}", y.shape);
            }
            Arch::Roberta => {
                ensure!(x.dtype == DType::I32 && x.shape == [b, n],
                        "bad x for roberta: {:?}", x.shape);
                ensure!(y.dtype == DType::I32 && y.elems() == b,
                        "bad y for roberta: {:?}", y.shape);
            }
        }
        // labels index the logits in softmax_ce: range-check them like
        // the embedding gather does for input token ids
        let hi = match self.cfg.arch {
            Arch::Llama => self.cfg.vocab,
            _ => self.cfg.n_classes,
        };
        for &t in y.as_i32() {
            ensure!(t >= 0 && (t as usize) < hi,
                    "label {t} out of range 0..{hi}");
        }
        Ok(())
    }

    /// Forward pass with a throwaway arena (tests / one-shot callers).
    /// The executor path uses [`Model::forward_in`] with its persistent
    /// arena.
    pub fn forward(&self, params: &[Tensor], x: &Tensor,
                   y: &Tensor) -> Result<(f32, f32, Vec<Tensor>)> {
        self.forward_in(&mut Arena::new(), params, x, y)
    }

    /// Forward pass. Returns `(loss, metric, residuals)` with residuals
    /// in tape-schema (= manifest) order. Activations and residual
    /// payloads are drawn from `arena`.
    pub fn forward_in(&self, arena: &mut Arena, params: &[Tensor],
                      x: &Tensor,
                      y: &Tensor) -> Result<(f32, f32, Vec<Tensor>)> {
        self.forward_impl(arena, Params::Flat(params), x, y, None)
    }

    /// Forward pass over a [`Params`] view — the multi-tenant entry
    /// point: a session passes its `Arc`-shared frozen base plus its
    /// private trainables and the layer stack reads both zero-copy.
    pub fn forward_view(&self, arena: &mut Arena, params: Params<'_>,
                        x: &Tensor,
                        y: &Tensor) -> Result<(f32, f32, Vec<Tensor>)> {
        self.forward_impl(arena, params, x, y, None)
    }

    /// [`Model::forward_in`] with a per-layer latency profiler attached
    /// (the hotpath bench's per-layer section).
    pub fn forward_profiled(&self, arena: &mut Arena, params: &[Tensor],
                            x: &Tensor, y: &Tensor, prof: &mut Profiler)
                            -> Result<(f32, f32, Vec<Tensor>)> {
        self.forward_impl(arena, Params::Flat(params), x, y, Some(prof))
    }

    fn forward_impl(&self, arena: &mut Arena, params: Params<'_>,
                    x: &Tensor, y: &Tensor,
                    profiler: Option<&mut Profiler>)
                    -> Result<(f32, f32, Vec<Tensor>)> {
        ensure!(params.len() == self.infos.len(),
                "param arity: got {}, expected {}", params.len(),
                self.infos.len());
        self.check_batch(x, y)?;
        let mut ctx = FwdCtx {
            params,
            arena,
            x,
            y,
            h: Vec::new(),
            loss: 0.0,
            metric: 0.0,
            profiler,
        };
        let mut tape = TapeWriter::new(&self.schema);
        self.seq.fwd(&mut ctx, &mut tape)?;
        let h = std::mem::take(&mut ctx.h);
        ctx.arena.put_f32(h);
        let res = tape.finish()?;
        Ok((ctx.loss, ctx.metric, res))
    }

    /// Fused multi-session forward: one walk of the layer stack
    /// advances every job through each layer before the next layer
    /// runs, so fused leaves (the frozen-weight linears) sweep all N
    /// activation blocks through one packed panel. Per job the result
    /// is bit-identical to [`Model::forward_view`] — the lanes share
    /// only the arena (buffer pooling) and the read-only base.
    pub fn forward_many(&self, arena: &mut Arena,
                        jobs: &[(Params<'_>, &Tensor, &Tensor)])
                        -> Result<Vec<(f32, f32, Vec<Tensor>)>> {
        let mut lanes: Vec<FwdLane<'_>> =
            Vec::with_capacity(jobs.len());
        for &(params, x, y) in jobs {
            ensure!(params.len() == self.infos.len(),
                    "param arity: got {}, expected {}", params.len(),
                    self.infos.len());
            self.check_batch(x, y)?;
            lanes.push(FwdLane {
                params,
                x,
                y,
                h: Vec::new(),
                loss: 0.0,
                metric: 0.0,
                tape: TapeWriter::new(&self.schema),
            });
        }
        self.seq.fwd_many(arena, &mut lanes)?;
        let mut out = Vec::with_capacity(lanes.len());
        for lane in lanes {
            arena.put_f32(lane.h);
            let res = lane.tape.finish()?;
            out.push((lane.loss, lane.metric, res));
        }
        Ok(out)
    }

    /// Fused multi-session backward (see [`Model::forward_many`]):
    /// per-job gradients bit-identical to [`Model::backward_view`], in
    /// job order.
    pub fn backward_many(&self, arena: &mut Arena,
                         jobs: &[(Params<'_>, &[Tensor], &Tensor,
                                  &Tensor)])
                         -> Result<Vec<Vec<Tensor>>> {
        let mut lanes: Vec<BwdLane<'_>> =
            Vec::with_capacity(jobs.len());
        for &(params, residuals, x, y) in jobs {
            ensure!(params.len() == self.infos.len(), "param arity");
            self.check_batch(x, y)?;
            let mut grads: Vec<Option<Vec<f32>>> = Vec::new();
            grads.resize_with(self.infos.len(), || None);
            lanes.push(BwdLane {
                params,
                infos: &self.infos,
                x,
                y,
                dh: Vec::new(),
                grads,
                tape: TapeReader::new(&self.schema, residuals)?,
            });
        }
        self.seq.bwd_many(arena, &mut lanes)?;
        let mut out = Vec::with_capacity(lanes.len());
        for mut lane in lanes {
            lane.tape.finish()?;
            let mut gs = Vec::new();
            for (i, info) in self.infos.iter().enumerate() {
                if info.trainable {
                    let g = lane.grads[i]
                        .take()
                        .ok_or_else(|| anyhow::anyhow!(
                            "missing gradient for {}", info.name))?;
                    gs.push(arena.tensor_from_f32(&info.shape, &g));
                    arena.put_f32(g);
                }
            }
            out.push(gs);
        }
        Ok(out)
    }

    /// Backward pass with a throwaway arena (tests / one-shot callers).
    pub fn backward(&self, params: &[Tensor], residuals: &[Tensor],
                    x: &Tensor, y: &Tensor) -> Result<Vec<Tensor>> {
        self.backward_in(&mut Arena::new(), params, residuals, x, y)
    }

    /// Backward pass from the residual list `forward` produced. Returns
    /// gradients for the trainable parameters, in manifest order.
    /// Scratch buffers are drawn from `arena`.
    pub fn backward_in(&self, arena: &mut Arena, params: &[Tensor],
                       residuals: &[Tensor], x: &Tensor,
                       y: &Tensor) -> Result<Vec<Tensor>> {
        self.backward_impl(arena, Params::Flat(params), residuals, x, y,
                           None)
    }

    /// Backward pass over a [`Params`] view (see
    /// [`Model::forward_view`]).
    pub fn backward_view(&self, arena: &mut Arena, params: Params<'_>,
                         residuals: &[Tensor], x: &Tensor,
                         y: &Tensor) -> Result<Vec<Tensor>> {
        self.backward_impl(arena, params, residuals, x, y, None)
    }

    /// [`Model::backward_in`] with a per-layer latency profiler.
    pub fn backward_profiled(&self, arena: &mut Arena, params: &[Tensor],
                             residuals: &[Tensor], x: &Tensor,
                             y: &Tensor, prof: &mut Profiler)
                             -> Result<Vec<Tensor>> {
        self.backward_impl(arena, Params::Flat(params), residuals, x, y,
                           Some(prof))
    }

    fn backward_impl(&self, arena: &mut Arena, params: Params<'_>,
                     residuals: &[Tensor], x: &Tensor, y: &Tensor,
                     profiler: Option<&mut Profiler>)
                     -> Result<Vec<Tensor>> {
        ensure!(params.len() == self.infos.len(), "param arity");
        self.check_batch(x, y)?;
        let mut grads: Vec<Option<Vec<f32>>> = Vec::new();
        grads.resize_with(self.infos.len(), || None);
        {
            let mut ctx = BwdCtx {
                params,
                infos: &self.infos,
                arena,
                x,
                y,
                dh: Vec::new(),
                grads: &mut grads,
                profiler,
            };
            let mut tape = TapeReader::new(&self.schema, residuals)?;
            self.seq.bwd(&mut ctx, &mut tape)?;
            tape.finish()?;
        }
        // ---- collect trainable grads in manifest order ----
        let mut out = Vec::new();
        for (i, info) in self.infos.iter().enumerate() {
            if info.trainable {
                let g = grads[i]
                    .take()
                    .ok_or_else(|| anyhow::anyhow!(
                        "missing gradient for {}", info.name))?;
                // gradient tensors draw their payloads from the arena
                // too; the trainer recycles them after the optimizer
                // step, so steady-state steps allocate nothing here
                out.push(arena.tensor_from_f32(&info.shape, &g));
                arena.put_f32(g);
            }
        }
        Ok(out)
    }
}
