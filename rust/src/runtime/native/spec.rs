//! Preset specs for the native backend: parse
//! `{model}_{tuning}_{act}_{norm}[_swiglu][_ckpt][_mesa]` preset names,
//! synthesize manifests, and load on-disk artifacts (manifest.json +
//! params.bin) without any compiled HLO.
//!
//! The manifest residual section is **derived from the model's tape
//! schema** — the slot list the layer composition minted at build time
//! (`Model::schema`) — not captured from a dry run. A dry run still
//! happens once per synthesis, but only to fill the selfcheck block
//! (loss/metric/grad-norms of one deterministic batch) and to
//! cross-check that the executed tape matches the derived schema
//! byte-for-byte; `tests/tape_grid.rs` pins that identity over the full
//! preset grid.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use super::model::{Act, Arch, Model, NetCfg, Norm, Tuning};
use super::NativeExec;
use crate::data::synth_images::ImageTask;
use crate::data::synth_text::TextTask;
use crate::runtime::manifest::{
    BatchInfo, Manifest, MergeOp, ResInfo, SelfCheck,
};
use crate::runtime::tensor::Tensor;
use crate::runtime::Artifact;

/// Preset names the native backend can synthesize from nothing.
pub const SYNTH_MODELS: &[&str] = &["vitt", "llama", "roberta"];

fn base_cfg(model: &str) -> Result<NetCfg> {
    Ok(match model {
        // ViT-tiny-ish patch-token classifier on the blob task
        "vitt" => NetCfg {
            arch: Arch::Vit,
            dim: 64,
            depth: 3,
            n_heads: 4,
            n_tokens: 64,
            batch: 8,
            n_classes: 10,
            vocab: 0,
            mlp_ratio: 4.0,
            lora_rank: 4,
            patch_dim: 48,
            tuning: Tuning::LoraQv,
            act: Act::Gelu,
            norm: Norm::Ln,
            swiglu: false,
            ckpt: false,
            mesa: false,
        },
        // small causal LM on the Markov-chain corpus
        "llama" => NetCfg {
            arch: Arch::Llama,
            dim: 64,
            depth: 2,
            n_heads: 4,
            n_tokens: 32,
            batch: 4,
            n_classes: 0,
            vocab: 256,
            mlp_ratio: 4.0,
            lora_rank: 8,
            patch_dim: 0,
            tuning: Tuning::LoraAll,
            act: Act::Silu,
            norm: Norm::Rms,
            swiglu: false,
            ckpt: false,
            mesa: false,
        },
        // small bidirectional sequence classifier
        "roberta" => NetCfg {
            arch: Arch::Roberta,
            dim: 64,
            depth: 2,
            n_heads: 4,
            n_tokens: 32,
            batch: 4,
            n_classes: 4,
            vocab: 256,
            mlp_ratio: 4.0,
            lora_rank: 8,
            patch_dim: 0,
            tuning: Tuning::LoraAll,
            act: Act::Gelu,
            norm: Norm::Ln,
            swiglu: false,
            ckpt: false,
            mesa: false,
        },
        other => bail!(
            "unknown synth model {other:?} (supported: {SYNTH_MODELS:?})"
        ),
    })
}

/// Parse a `{model}_{tuning}_{act}_{norm}[_swiglu][_ckpt][_mesa]`
/// preset name into a config. `swiglu` (LLaMA only) selects the gated
/// MLP + RoPE block shape; `ckpt` enables gradient checkpointing;
/// `mesa` stores the nonlinear-layer saves as int8 codes + scales
/// (the paper's Mesa activation-quantization baseline, native since
/// the int8 tape slots — no compiled artifacts involved).
pub fn parse_preset(preset: &str) -> Result<NetCfg> {
    let parts: Vec<&str> = preset.split('_').collect();
    let mut end = parts.len();
    let mesa = end >= 1 && parts[end - 1] == "mesa";
    if mesa {
        end -= 1;
    }
    let ckpt = end >= 1 && parts[end - 1] == "ckpt";
    if ckpt {
        end -= 1;
    }
    let swiglu = end >= 1 && parts[end - 1] == "swiglu";
    if swiglu {
        end -= 1;
    }
    ensure!(
        end == 4,
        "preset {preset:?} is not \
         {{model}}_{{tuning}}_{{act}}_{{norm}}[_swiglu][_ckpt][_mesa]"
    );
    let mut cfg = base_cfg(parts[0])?;
    cfg.tuning = NetCfg::tuning_from_str(parts[1])?;
    cfg.act = NetCfg::act_from_str(parts[2])?;
    cfg.norm = NetCfg::norm_from_str(parts[3])?;
    cfg.swiglu = swiglu;
    cfg.ckpt = ckpt;
    cfg.mesa = mesa;
    cfg.validate()?;
    Ok(cfg)
}

fn arch_str(a: Arch) -> &'static str {
    match a {
        Arch::Vit => "vit",
        Arch::Llama => "llama",
        Arch::Roberta => "roberta",
    }
}

fn tuning_str(t: Tuning) -> &'static str {
    match t {
        Tuning::Full => "full",
        Tuning::Frozen => "frozen",
        Tuning::LoraQv => "lora_qv",
        Tuning::LoraAll => "lora_all",
        Tuning::LoraFaQv => "lorafa_qv",
        Tuning::LoraFaAll => "lorafa_all",
    }
}

fn act_str(a: Act) -> &'static str {
    match a {
        Act::Gelu => "gelu",
        Act::ReGelu2 => "regelu2",
        Act::Silu => "silu",
        Act::ReSilu2 => "resilu2",
        Act::Relu => "relu",
    }
}

fn norm_str(n: Norm) -> &'static str {
    match n {
        Norm::Ln => "ln",
        Norm::MsLn => "msln",
        Norm::Rms => "rms",
        Norm::MsRms => "msrms",
    }
}

/// Deterministic batch for a config (the same generators and defaults the
/// trainer uses), used for the manifest dry run.
pub fn sample_batch(cfg: &NetCfg, step: u64, seed: u64)
                    -> (Tensor, Tensor) {
    let (b, n) = (cfg.batch, cfg.n_tokens);
    match cfg.arch {
        Arch::Vit => {
            let task =
                ImageTask::new(cfg.n_classes, n, cfg.patch_dim, 0.6, seed);
            let (x, y) = task.batch(step * b as u64, b);
            (
                Tensor::from_f32(&[b, n, cfg.patch_dim], &x),
                Tensor::from_i32(&[b], &y),
            )
        }
        Arch::Llama => {
            let task = TextTask::new(cfg.vocab, n, 4, 0.85, seed);
            let (x, y) = task.batch_lm(step * b as u64, b);
            (
                Tensor::from_i32(&[b, n], &x),
                Tensor::from_i32(&[b, n], &y),
            )
        }
        Arch::Roberta => {
            let task =
                TextTask::new(cfg.vocab, n, cfg.n_classes, 0.85, seed);
            let (x, y) = task.batch_cls(step * b as u64, b);
            (Tensor::from_i32(&[b, n], &x), Tensor::from_i32(&[b], &y))
        }
    }
}

fn merge_ops(model: &Model) -> Vec<MergeOp> {
    let cfg = &model.cfg;
    if !matches!(cfg.norm, Norm::MsLn | Norm::MsRms) {
        return Vec::new();
    }
    let mut out = Vec::new();
    for i in 0..cfg.depth {
        out.push(MergeOp {
            norm: format!("block{i}.attn.norm"),
            linears: vec![
                format!("block{i}.attn.q"),
                format!("block{i}.attn.k"),
                format!("block{i}.attn.v"),
            ],
        });
        // the MLP norm feeds fc1 — and, under SwiGLU, the up
        // projection fc2 as well (both read the shared x̂)
        let mut linears = vec![format!("block{i}.mlp.fc1")];
        if cfg.swiglu {
            linears.push(format!("block{i}.mlp.fc2"));
        }
        out.push(MergeOp { norm: format!("block{i}.mlp.norm"), linears });
    }
    out.push(MergeOp {
        norm: "head.norm".into(),
        linears: vec!["head.fc".into()],
    });
    out
}

/// Residual section synthesized from the model's derived tape schema —
/// no execution involved.
pub fn schema_residuals(model: &Model) -> Vec<ResInfo> {
    model
        .schema()
        .iter()
        .map(|s| ResInfo {
            name: format!("{}.{}", s.module, s.kind.as_str()),
            kind: s.kind.as_str().to_string(),
            module: s.module.clone(),
            shape: s.shape.clone(),
            dtype: s.dtype,
            bits_per_elem: s.bits_per_elem,
            bytes: s.bytes(),
        })
        .collect()
}

/// Assemble the full manifest: the residual section comes from the tape
/// schema; one dry run fills the selfcheck block and cross-checks that
/// the executed tape matches the schema byte-for-byte.
fn build_manifest(preset: &str, model: &Model,
                  params: &[Tensor]) -> Result<Manifest> {
    let cfg = &model.cfg;
    let residuals = schema_residuals(model);
    let (x, y) = sample_batch(cfg, 0, 0);
    let (loss, metric, res) = model.forward(params, &x, &y)?;
    ensure!(res.len() == residuals.len(),
            "dry run produced {} residuals, schema derives {}",
            res.len(), residuals.len());
    for (t, info) in res.iter().zip(&residuals) {
        ensure!(t.shape == info.shape && t.dtype == info.dtype
                    && t.nbytes() as u64 == info.bytes,
                "dry-run residual {} deviates from the derived schema",
                info.name);
    }
    let grads = model.backward(params, &res, &x, &y)?;
    let residual_bytes_total = residuals.iter().map(|r| r.bytes).sum();
    Ok(Manifest {
        preset: preset.to_string(),
        arch: arch_str(cfg.arch).to_string(),
        tuning: tuning_str(cfg.tuning).to_string(),
        activation: act_str(cfg.act).to_string(),
        norm: norm_str(cfg.norm).to_string(),
        dim: cfg.dim,
        depth: cfg.depth,
        n_heads: cfg.n_heads,
        n_tokens: cfg.n_tokens,
        batch: cfg.batch,
        n_classes: cfg.n_classes,
        vocab: cfg.vocab,
        mlp_ratio: cfg.mlp_ratio,
        lora_rank: cfg.lora_rank,
        patch_dim: cfg.patch_dim,
        ckpt: cfg.ckpt,
        swiglu: cfg.swiglu,
        mesa: cfg.mesa,
        params: model.infos.clone(),
        x: BatchInfo { shape: x.shape.clone(), dtype: x.dtype },
        y: BatchInfo { shape: y.shape.clone(), dtype: y.dtype },
        residuals,
        residual_bytes_total,
        merges: merge_ops(model),
        selfcheck: SelfCheck {
            loss: loss as f64,
            metric: metric as f64,
            grad_l2: grads.iter().map(|g| g.l2()).collect(),
        },
    })
}

/// Synthesize a named preset entirely in memory.
pub fn synth_artifact(preset: &str) -> Result<Artifact> {
    let cfg = parse_preset(preset)?;
    let model = Model::build(cfg)?;
    let params = model.init_params(42);
    let manifest = build_manifest(preset, &model, &params)
        .with_context(|| format!("synthesizing preset {preset:?}"))?;
    Ok(Artifact::from_parts(
        format!("<synthetic>/{preset}").into(),
        manifest,
        params,
        Box::new(NativeExec::new(model)),
    ))
}

/// Load an on-disk artifact (manifest.json + params.bin) onto the native
/// backend. The residual/selfcheck sections are rebuilt (schema-derived
/// residuals + a dry run) so the manifest always matches this backend's
/// ABI exactly.
pub fn load_artifact(dir: &Path) -> Result<Artifact> {
    let disk = Manifest::load(dir)?;
    let params = disk.load_params(dir)?;
    assemble_artifact(dir.to_path_buf(), disk, params)
}

/// Rebuild a native artifact from an already-parsed manifest plus a
/// full manifest-ordered parameter vector — the shared tail of
/// [`load_artifact`] and the statefile loader (`Backend::assemble`),
/// which reads both out of a single `.state` file. `dir` is a
/// provenance label only.
pub fn assemble_artifact(dir: PathBuf, disk: Manifest,
                         params: Vec<Tensor>) -> Result<Artifact> {
    let cfg = NetCfg {
        arch: NetCfg::arch_from_str(&disk.arch)?,
        dim: disk.dim,
        depth: disk.depth,
        n_heads: disk.n_heads,
        n_tokens: disk.n_tokens,
        batch: disk.batch,
        n_classes: disk.n_classes,
        vocab: disk.vocab,
        mlp_ratio: disk.mlp_ratio,
        lora_rank: disk.lora_rank,
        patch_dim: disk.patch_dim,
        tuning: NetCfg::tuning_from_str(&disk.tuning)?,
        act: NetCfg::act_from_str(&disk.activation)?,
        norm: NetCfg::norm_from_str(&disk.norm)?,
        swiglu: disk.swiglu,
        ckpt: disk.ckpt,
        mesa: disk.mesa,
    };
    let model = Model::build(cfg)?;
    ensure!(
        model.infos.len() == disk.params.len(),
        "native param layout has {} tensors, manifest has {} — this \
         artifact was exported for a different model structure",
        model.infos.len(),
        disk.params.len()
    );
    for (a, b) in model.infos.iter().zip(&disk.params) {
        ensure!(a.name == b.name && a.shape == b.shape,
                "param mismatch: native {:?}{:?} vs manifest {:?}{:?}",
                a.name, a.shape, b.name, b.shape);
    }
    let mut manifest = build_manifest(&disk.preset, &model, &params)?;
    // keep the exporter's selfcheck + merge table; ours replaced the
    // residual plan, which is what must match this executor
    manifest.merges = disk.merges;
    manifest.selfcheck = disk.selfcheck;
    Ok(Artifact::from_parts(
        dir,
        manifest,
        params,
        Box::new(NativeExec::new(model)),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::DType;

    #[test]
    fn parse_known_presets() {
        for p in [
            "vitt_loraqv_gelu_ln",
            "vitt_loraqv_regelu2_msln",
            "vitt_full_regelu2_msln",
            "vitt_loraqv_relu_ln",
            "vitt_loraqv_gelu_ln_ckpt",
            "llama_loraall_silu_rms",
            "llama_loraall_resilu2_msrms",
            "llama_loraall_silu_rms_swiglu",
            "llama_loraall_resilu2_msrms_swiglu_ckpt",
            "roberta_lorafaall_gelu_ln",
            "vitt_loraqv_gelu_ln_mesa",
            "llama_loraqv_regelu2_msln_mesa",
            "llama_loraall_silu_rms_swiglu_ckpt_mesa",
        ] {
            let cfg = parse_preset(p).unwrap();
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn parse_suffix_axes() {
        let cfg = parse_preset("llama_loraall_silu_rms_swiglu").unwrap();
        assert!(cfg.swiglu && !cfg.ckpt && !cfg.mesa);
        let cfg = parse_preset("vitt_loraqv_gelu_ln_ckpt").unwrap();
        assert!(cfg.ckpt && !cfg.swiglu && !cfg.mesa);
        let cfg =
            parse_preset("llama_full_silu_msrms_swiglu_ckpt").unwrap();
        assert!(cfg.swiglu && cfg.ckpt && !cfg.mesa);
        let cfg = parse_preset("vitt_full_gelu_ln_mesa").unwrap();
        assert!(cfg.mesa && !cfg.ckpt && !cfg.swiglu);
        let cfg =
            parse_preset("llama_full_silu_msrms_swiglu_ckpt_mesa")
                .unwrap();
        assert!(cfg.swiglu && cfg.ckpt && cfg.mesa);
    }

    #[test]
    fn reject_unsupported_presets() {
        // "mesa" is a suffix axis, not an act/norm spelling
        assert!(parse_preset("vitt_loraqv_mesa_mesaln").is_err());
        assert!(parse_preset("nope_full_gelu_ln").is_err());
        // swiglu/rope is a llama-family axis
        assert!(parse_preset("vitt_loraqv_gelu_ln_swiglu").is_err());
        // suffixes only in canonical [_swiglu][_ckpt][_mesa] order
        assert!(
            parse_preset("llama_loraall_silu_rms_ckpt_swiglu").is_err()
        );
        assert!(
            parse_preset("vitt_loraqv_gelu_ln_mesa_ckpt").is_err()
        );
    }

    #[test]
    fn mesa_manifest_uses_int8_slots() {
        let art = synth_artifact("vitt_loraqv_gelu_ln_mesa").unwrap();
        let m = &art.manifest;
        assert!(m.mesa);
        // every norm x̂ and full-precision pre-activation stores int8
        // groups: g codes + 4 scale bytes per row, 8 + 32/g bits/elem
        let q8: Vec<_> = m
            .residuals
            .iter()
            .filter(|r| r.dtype == DType::I8)
            .collect();
        assert!(!q8.is_empty());
        for r in &q8 {
            let g = *r.shape.last().unwrap() - 4;
            assert!(matches!(r.kind.as_str(),
                             "norm_input" | "norm_shared" | "act_full"),
                    "{} unexpectedly quantized", r.name);
            assert!((r.bits_per_elem - (8.0 + 32.0 / g as f64)).abs()
                        < 1e-9);
        }
        // one quantized x̂ per norm (2 per block + head), one act/block
        let norms =
            q8.iter().filter(|r| r.kind == "norm_input").count();
        assert_eq!(norms, 2 * m.depth + 1);
        let acts = q8.iter().filter(|r| r.kind == "act_full").count();
        assert_eq!(acts, m.depth);
        // attention q/k/v and the head stay f32 (the paper's Mesa
        // decomposition — see Kind::mesa_quantized)
        assert!(m.residuals.iter()
                    .filter(|r| r.kind == "attn_qkv" || r.kind == "logits")
                    .all(|r| r.dtype == DType::F32));
    }

    #[test]
    fn mesa_memory_between_ours_and_baseline() {
        // the Table 1/7 ordering on the synthesized manifests:
        // ours < mesa < baseline
        let base = synth_artifact("vitt_loraqv_gelu_ln").unwrap();
        let mesa = synth_artifact("vitt_loraqv_gelu_ln_mesa").unwrap();
        let ours = synth_artifact("vitt_loraqv_regelu2_msln").unwrap();
        let b = base.manifest.residual_bytes_total;
        let m = mesa.manifest.residual_bytes_total;
        let o = ours.manifest.residual_bytes_total;
        assert!(m < b, "mesa {m} !< base {b}");
        assert!(o < m, "ours {o} !< mesa {m}");
    }

    #[test]
    fn synth_manifest_is_self_consistent() {
        let art = synth_artifact("vitt_loraqv_regelu2_msln").unwrap();
        let m = &art.manifest;
        assert_eq!(m.arch, "vit");
        assert_eq!(m.activation, "regelu2");
        let total: u64 = m.residuals.iter().map(|r| r.bytes).sum();
        assert_eq!(total, m.residual_bytes_total);
        // 2-bit act codes: one per block, uint8, bits_per_elem = 2
        let codes: Vec<_> = m
            .residuals
            .iter()
            .filter(|r| r.kind == "act_codes")
            .collect();
        assert_eq!(codes.len(), m.depth);
        for c in codes {
            assert_eq!(c.dtype, DType::U8);
            assert!((c.bits_per_elem - 2.0).abs() < 1e-9);
        }
        // selfcheck was populated by the dry run
        assert!(m.selfcheck.loss.is_finite() && m.selfcheck.loss > 0.0);
        assert!(!m.selfcheck.grad_l2.is_empty());
    }

    #[test]
    fn relu_manifest_uses_one_bit_codes() {
        let art = synth_artifact("vitt_loraqv_relu_ln").unwrap();
        let m = &art.manifest;
        let codes: Vec<_> = m
            .residuals
            .iter()
            .filter(|r| r.kind == "act_codes")
            .collect();
        assert_eq!(codes.len(), m.depth);
        for c in codes {
            assert_eq!(c.dtype, DType::U8);
            assert!((c.bits_per_elem - 1.0).abs() < 1e-9);
            // 1-bit codes: hidden/8 bytes per row
            assert_eq!(*c.shape.last().unwrap(),
                       (m.dim as f64 * m.mlp_ratio) as usize / 8);
        }
    }

    #[test]
    fn ckpt_manifest_stores_only_block_inputs() {
        let art = synth_artifact("vitt_loraqv_gelu_ln_ckpt").unwrap();
        let m = &art.manifest;
        assert!(m.ckpt);
        let ckpts: Vec<_> = m
            .residuals
            .iter()
            .filter(|r| r.kind == "ckpt_input")
            .collect();
        // one per block half
        assert_eq!(ckpts.len(), 2 * m.depth);
        // no inner-block residual kinds survive on the model tape
        assert!(m.residuals.iter().all(|r| {
            r.kind != "attn_qkv" && r.kind != "act_full"
                && r.kind != "lora_u"
        }));
    }

    #[test]
    fn swiglu_manifest_has_gate_params_and_operands() {
        let art =
            synth_artifact("llama_loraall_silu_rms_swiglu").unwrap();
        let m = &art.manifest;
        assert!(m.swiglu);
        // no learned positions under rope
        assert!(m.params.iter().all(|p| p.name != "embed.pos"));
        // gate/up/down per block
        for which in ["fc1", "fc2", "fc3"] {
            assert!(m.params.iter().any(|p| {
                p.name == format!("block0.mlp.{which}.W")
            }));
        }
        let gates = m
            .residuals
            .iter()
            .filter(|r| r.kind == "gate_operand")
            .count();
        assert_eq!(gates, 2 * m.depth);
    }

    #[test]
    fn memory_ordering_matches_paper() {
        // ckpt < ours (2-bit codes + shared norm) < baseline, same dims
        let base = synth_artifact("vitt_loraqv_gelu_ln").unwrap();
        let ours = synth_artifact("vitt_loraqv_regelu2_msln").unwrap();
        let ckpt = synth_artifact("vitt_loraqv_gelu_ln_ckpt").unwrap();
        assert!(
            ours.manifest.residual_bytes_total
                < base.manifest.residual_bytes_total,
            "ours {} !< base {}",
            ours.manifest.residual_bytes_total,
            base.manifest.residual_bytes_total
        );
        assert!(
            ckpt.manifest.residual_bytes_total
                < ours.manifest.residual_bytes_total,
            "ckpt {} !< ours {}",
            ckpt.manifest.residual_bytes_total,
            ours.manifest.residual_bytes_total
        );
        // single changes each save something too
        let only_act = synth_artifact("vitt_loraqv_regelu2_ln").unwrap();
        let only_norm = synth_artifact("vitt_loraqv_gelu_msln").unwrap();
        for a in [&only_act, &only_norm] {
            assert!(a.manifest.residual_bytes_total
                        < base.manifest.residual_bytes_total);
            assert!(ours.manifest.residual_bytes_total
                        <= a.manifest.residual_bytes_total);
        }
    }
}
