//! CPU kernels for the native backend: cache-blocked panel-packed
//! matmuls (see [`super::gemm`]), layer norms, softmax cross-entropy,
//! multi-head attention, and activation forward/backward — parallelized
//! over contiguous row chunks via [`super::pool`], all deterministic
//! (each output element is reduced sequentially, in a fixed k order, by
//! one worker).
//!
//! Matrix layout is row-major. Linear weights follow the `[dout, din]`
//! convention (`y = x · Wᵀ`), which is what the checkpoint affine-merge
//! (eq. 17) assumes.
//!
//! Every allocating kernel has an `_into` twin that writes a
//! caller-provided buffer — the model threads its step-scoped
//! [`super::arena::Arena`] buffers through those, so the hot path does
//! not touch the allocator in steady state. The attention kernels'
//! per-head gather/score scratch lives in grow-only thread-locals for
//! the same reason.

use std::cell::RefCell;
use std::sync::Arc;

use super::gemm::{gemm_into, gemm_packed_into, pack_b_once, PackedB};
use super::pool::parallel_rows;
use crate::coeffs::funcs;
use crate::runtime::params::Params;

/// Epsilon used by every normalization variant.
pub const NORM_EPS: f32 = 1e-5;

/// `c[m,n] = a[m,k] · b[k,n]`.
pub fn matmul_nn_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize,
                      k: usize, n: usize) {
    gemm_into(c, a, b, m, k, n, false, false, false);
}

/// `c[m,n] += a[m,k] · b[k,n]`.
pub fn matmul_nn_acc_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize,
                          k: usize, n: usize) {
    gemm_into(c, a, b, m, k, n, false, false, true);
}

/// `c[m,n] = a[m,k] · b[n,k]ᵀ`.
pub fn matmul_nt_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize,
                      k: usize, n: usize) {
    gemm_into(c, a, b, m, k, n, false, true, false);
}

/// `c[m,n] += a[m,k] · b[n,k]ᵀ`.
pub fn matmul_nt_acc_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize,
                          k: usize, n: usize) {
    gemm_into(c, a, b, m, k, n, false, true, true);
}

/// `c[m,n] = a[k,m]ᵀ · b[k,n]` — the weight-gradient product
/// (`dW = dyᵀ · x`).
pub fn matmul_tn_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize,
                      k: usize, n: usize) {
    gemm_into(c, a, b, m, k, n, true, false, false);
}

/// The prepacked panels for parameter `widx` of a split view at B
/// layout `b_trans`, packing into the base's [`PanelCache`] on first
/// use. `None` when the view is flat or the parameter trains — those
/// mutate between steps and must take the per-call packing path.
///
/// [`PanelCache`]: crate::runtime::params::PanelCache
pub fn frozen_packed(params: Params<'_>, widx: usize, k: usize,
                     n: usize, b_trans: bool) -> Option<Arc<PackedB>> {
    let (cache, t) = params.frozen_cache(widx)?;
    let pb = cache.get_or_insert((widx, b_trans), || {
        let pb = pack_b_once(t.as_f32(), k, n, b_trans);
        let bytes = pb.nbytes();
        (pb, bytes)
    });
    debug_assert_eq!(pb.shape(), (k, n), "cached panel shape drift");
    Some(pb)
}

/// [`matmul_nt_into`] with `b = params[widx]`, served from the shared
/// base's prepacked-panel cache when the parameter is frozen
/// (bit-identical — same worker loop, packing skipped), falling back
/// to the per-call packing path otherwise.
pub fn matmul_nt_frozen_into(c: &mut [f32], a: &[f32],
                             params: Params<'_>, widx: usize, m: usize,
                             k: usize, n: usize) {
    match frozen_packed(params, widx, k, n, true) {
        Some(pb) => gemm_packed_into(c, a, &pb, m, false, false),
        None => matmul_nt_into(c, a, params[widx].as_f32(), m, k, n),
    }
}

/// [`matmul_nn_into`] with `b = params[widx]` — cache-served like
/// [`matmul_nt_frozen_into`], at the untransposed B layout (the
/// `dx = dy · W` backward product).
pub fn matmul_nn_frozen_into(c: &mut [f32], a: &[f32],
                             params: Params<'_>, widx: usize, m: usize,
                             k: usize, n: usize) {
    match frozen_packed(params, widx, k, n, false) {
        Some(pb) => gemm_packed_into(c, a, &pb, m, false, false),
        None => matmul_nn_into(c, a, params[widx].as_f32(), m, k, n),
    }
}

/// Allocating wrapper over [`matmul_nn_into`].
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize,
                 n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    matmul_nn_into(&mut c, a, b, m, k, n);
    c
}

/// Allocating wrapper over [`matmul_nt_into`].
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize,
                 n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    matmul_nt_into(&mut c, a, b, m, k, n);
    c
}

/// Allocating wrapper over [`matmul_tn_into`].
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize,
                 n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    matmul_tn_into(&mut c, a, b, m, k, n);
    c
}

/// Dot product, sequential accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Column sums of `a[rows, cols]` into `out[cols]` (bias gradients).
pub fn colsum_into(out: &mut [f32], a: &[f32], rows: usize, cols: usize) {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(out.len(), cols);
    out.fill(0.0);
    for r in 0..rows {
        let arow = &a[r * cols..(r + 1) * cols];
        for (o, &v) in out.iter_mut().zip(arow) {
            *o += v;
        }
    }
}

/// Allocating wrapper over [`colsum_into`].
pub fn colsum(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; cols];
    colsum_into(&mut out, a, rows, cols);
    out
}

/// `a += b`, elementwise.
pub fn add_inplace(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// `out = a ∘ b`, elementwise (SwiGLU gate multiply), parallelized over
/// contiguous chunks.
pub fn mul_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    parallel_rows(out, 1, 4096, |i0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = a[i0 + i] * b[i0 + i];
        }
    });
}

/// Broadcast-add a `[cols]` bias onto every row of `a[rows, cols]`.
pub fn add_bias(a: &mut [f32], bias: &[f32]) {
    for row in a.chunks_mut(bias.len()) {
        for (x, &v) in row.iter_mut().zip(bias) {
            *x += v;
        }
    }
}

/// Normalization forward into caller buffers: `xhat[rows·c]` gets the
/// normalized rows, `stat[rows]` the per-row reciprocal std (LN) or
/// reciprocal RMS (RMSNorm); the affine transform, if any, is applied by
/// the caller.
pub fn norm_fwd_into(xhat: &mut [f32], stat: &mut [f32], x: &[f32],
                     rows: usize, c: usize, rms: bool) {
    assert_eq!(x.len(), rows * c);
    assert_eq!(xhat.len(), rows * c);
    assert_eq!(stat.len(), rows);
    for r in 0..rows {
        let xr = &x[r * c..(r + 1) * c];
        let hr = &mut xhat[r * c..(r + 1) * c];
        if rms {
            let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / c as f32;
            let rho = 1.0 / (ms + NORM_EPS).sqrt();
            stat[r] = rho;
            for (h, &v) in hr.iter_mut().zip(xr) {
                *h = v * rho;
            }
        } else {
            let mu: f32 = xr.iter().sum::<f32>() / c as f32;
            let var: f32 =
                xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>()
                    / c as f32;
            let rstd = 1.0 / (var + NORM_EPS).sqrt();
            stat[r] = rstd;
            for (h, &v) in hr.iter_mut().zip(xr) {
                *h = (v - mu) * rstd;
            }
        }
    }
}

/// Allocating wrapper over [`norm_fwd_into`].
pub fn norm_fwd(x: &[f32], rows: usize, c: usize,
                rms: bool) -> (Vec<f32>, Vec<f32>) {
    let mut xhat = vec![0f32; rows * c];
    let mut stat = vec![0f32; rows];
    norm_fwd_into(&mut xhat, &mut stat, x, rows, c, rms);
    (xhat, stat)
}

/// Normalization backward given the upstream grad `dyh` (already
/// multiplied by the affine weight when one exists):
///
/// * LN:  `dx = rstd · (dyh − mean(dyh) − x̂ · mean(dyh·x̂))`
/// * RMS: `dx = ρ · (dyh − x̂ · mean(dyh·x̂))`
pub fn norm_bwd_into(dx: &mut [f32], dyh: &[f32], xhat: &[f32],
                     stat: &[f32], rows: usize, c: usize, rms: bool) {
    assert_eq!(dx.len(), rows * c);
    for r in 0..rows {
        let dyr = &dyh[r * c..(r + 1) * c];
        let xr = &xhat[r * c..(r + 1) * c];
        let out = &mut dx[r * c..(r + 1) * c];
        let m2: f32 = dot(dyr, xr) / c as f32;
        if rms {
            for ((o, &d), &xh) in out.iter_mut().zip(dyr).zip(xr) {
                *o = stat[r] * (d - xh * m2);
            }
        } else {
            let m1: f32 = dyr.iter().sum::<f32>() / c as f32;
            for ((o, &d), &xh) in out.iter_mut().zip(dyr).zip(xr) {
                *o = stat[r] * (d - m1 - xh * m2);
            }
        }
    }
}

/// Allocating wrapper over [`norm_bwd_into`].
pub fn norm_bwd(dyh: &[f32], xhat: &[f32], stat: &[f32], rows: usize,
                c: usize, rms: bool) -> Vec<f32> {
    let mut dx = vec![0f32; rows * c];
    norm_bwd_into(&mut dx, dyh, xhat, stat, rows, c, rms);
    dx
}

/// Mean softmax cross-entropy over `rows` rows of `k` logits.
/// Returns `(loss, accuracy)`.
pub fn softmax_ce(z: &[f32], rows: usize, k: usize,
                  y: &[i32]) -> (f32, f32) {
    assert_eq!(z.len(), rows * k);
    assert_eq!(y.len(), rows);
    let mut loss = 0f64;
    let mut hits = 0usize;
    for r in 0..rows {
        let zr = &z[r * k..(r + 1) * k];
        let (mut mx, mut arg) = (f32::NEG_INFINITY, 0usize);
        for (j, &v) in zr.iter().enumerate() {
            if v > mx {
                mx = v;
                arg = j;
            }
        }
        let lse: f32 =
            mx + zr.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
        let t = y[r] as usize;
        loss += (lse - zr[t]) as f64;
        hits += usize::from(arg == t);
    }
    ((loss / rows as f64) as f32, hits as f32 / rows as f32)
}

/// Gradient of [`softmax_ce`] w.r.t. the logits, into `dz`:
/// `dz = (softmax(z) − onehot(y)) / rows`.
pub fn softmax_ce_grad_into(dz: &mut [f32], z: &[f32], rows: usize,
                            k: usize, y: &[i32]) {
    assert_eq!(dz.len(), rows * k);
    let inv = 1.0 / rows as f32;
    for r in 0..rows {
        let zr = &z[r * k..(r + 1) * k];
        let out = &mut dz[r * k..(r + 1) * k];
        let mx = zr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for (o, &v) in out.iter_mut().zip(zr) {
            *o = (v - mx).exp();
            sum += *o;
        }
        for o in out.iter_mut() {
            *o = *o / sum * inv;
        }
        out[y[r] as usize] -= inv;
    }
}

/// Allocating wrapper over [`softmax_ce_grad_into`].
pub fn softmax_ce_grad(z: &[f32], rows: usize, k: usize,
                       y: &[i32]) -> Vec<f32> {
    let mut dz = vec![0f32; rows * k];
    softmax_ce_grad_into(&mut dz, z, rows, k, y);
    dz
}

/// Shape of a multi-head attention problem over `[B·N, H·dh]` tensors.
#[derive(Debug, Clone, Copy)]
pub struct AttnDims {
    /// Batch size.
    pub b: usize,
    /// Tokens per sequence.
    pub n: usize,
    /// Number of heads.
    pub h: usize,
    /// Head dimension (`C = h · dh`).
    pub dh: usize,
}

impl AttnDims {
    fn c(&self) -> usize {
        self.h * self.dh
    }
}

thread_local! {
    // Per-head gather/score scratch (qs|ks|vs|[dos]|p|[ds]); grow-only,
    // reused across every attention dispatch on this thread.
    static HEAD_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

fn head_scratch<R>(need: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    HEAD_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < need {
            buf.resize(need, 0.0);
        }
        f(&mut buf[..need])
    })
}

fn gather_head(src: &[f32], d: &AttnDims, bi: usize, hi: usize,
               out: &mut [f32]) {
    let c = d.c();
    for i in 0..d.n {
        let row = (bi * d.n + i) * c + hi * d.dh;
        out[i * d.dh..(i + 1) * d.dh]
            .copy_from_slice(&src[row..row + d.dh]);
    }
}

/// Row-softmax of the scaled score matrix `q·kᵀ/√dh` for one head, into
/// `p[n·n]`. The scores come from the blocked GEMM (`QKᵀ` computed as a
/// full matrix even under causal masking — the SIMD matmul beats
/// triangle-skipping at these head sizes); rows past the causal limit
/// are written as exact zeros so the `P·V` product can also run as a
/// full GEMM.
fn head_probs_into(p: &mut [f32], qs: &[f32], ks: &[f32], d: &AttnDims,
                   causal: bool) {
    let n = d.n;
    let scale = 1.0 / (d.dh as f32).sqrt();
    matmul_nt_into(p, qs, ks, n, d.dh, n);
    for i in 0..n {
        let lim = if causal { i + 1 } else { n };
        let prow = &mut p[i * n..(i + 1) * n];
        let mut mx = f32::NEG_INFINITY;
        for pv in &mut prow[..lim] {
            *pv *= scale;
            if *pv > mx {
                mx = *pv;
            }
        }
        let mut sum = 0f32;
        for pv in &mut prow[..lim] {
            *pv = (*pv - mx).exp();
            sum += *pv;
        }
        for pv in &mut prow[..lim] {
            *pv /= sum;
        }
        for pv in &mut prow[lim..] {
            *pv = 0.0;
        }
    }
}

/// Multi-head attention forward into `o` (`[B·N, C]` row-major), using
/// `hm` (`[B·H·N·dh]`) as the head-major staging buffer:
/// `o = softmax(q·kᵀ/√dh)·v`, one `(batch, head)` task per pool slot.
/// Probabilities are **not** retained — the backward pass recomputes
/// them from the saved q/k (the FlashAttn residual policy the measured
/// tape assumes). Both score and value products run through the blocked
/// GEMM.
pub fn attn_fwd_into(o: &mut [f32], hm: &mut [f32], q: &[f32], k: &[f32],
                     v: &[f32], d: &AttnDims, causal: bool) {
    let (n, dh, c) = (d.n, d.dh, d.c());
    let tasks = d.b * d.h;
    assert_eq!(o.len(), d.b * n * c);
    assert_eq!(hm.len(), tasks * n * dh);
    super::pool::parallel_tasks(hm, n * dh, |t, slot| {
        let (bi, hi) = (t / d.h, t % d.h);
        head_scratch(3 * n * dh + n * n, |buf| {
            let (qs, rest) = buf.split_at_mut(n * dh);
            let (ks, rest) = rest.split_at_mut(n * dh);
            let (vs, p) = rest.split_at_mut(n * dh);
            gather_head(q, d, bi, hi, qs);
            gather_head(k, d, bi, hi, ks);
            gather_head(v, d, bi, hi, vs);
            head_probs_into(p, qs, ks, d, causal);
            matmul_nn_into(slot, p, vs, n, n, dh);
        });
    });
    // head-major [B,H,N,dh] → row-major [B·N, C]
    for t in 0..tasks {
        let (bi, hi) = (t / d.h, t % d.h);
        for i in 0..n {
            let src = &hm[(t * n + i) * dh..(t * n + i + 1) * dh];
            let row = (bi * n + i) * c + hi * dh;
            o[row..row + dh].copy_from_slice(src);
        }
    }
}

/// Allocating wrapper over [`attn_fwd_into`].
pub fn attn_fwd(q: &[f32], k: &[f32], v: &[f32], d: &AttnDims,
                causal: bool) -> Vec<f32> {
    let (n, dh, c) = (d.n, d.dh, d.c());
    let mut o = vec![0f32; d.b * n * c];
    let mut hm = vec![0f32; d.b * d.h * n * dh];
    attn_fwd_into(&mut o, &mut hm, q, k, v, d, causal);
    o
}

/// Multi-head attention backward into `dq`/`dk`/`dv` (`[B·N, C]`
/// layout), using `scr` (`[B·H · 3·n·dh]`) as the head-major staging
/// buffer. Recomputes the probabilities from the saved `q`/`k`; the
/// `do·Vᵀ`, `dS·K`, `dSᵀ·Q`, and `Pᵀ·do` products all run through the
/// blocked GEMM (with the causal mask applied by zeroing the `P`/`dS`
/// tails).
pub fn attn_bwd_into(dq: &mut [f32], dk: &mut [f32], dv: &mut [f32],
                     scr: &mut [f32], dout: &[f32], q: &[f32], k: &[f32],
                     v: &[f32], d: &AttnDims, causal: bool) {
    let (n, dh, c) = (d.n, d.dh, d.c());
    let scale = 1.0 / (dh as f32).sqrt();
    let tasks = d.b * d.h;
    assert_eq!(scr.len(), tasks * 3 * n * dh);
    assert_eq!(dq.len(), d.b * n * c);
    super::pool::parallel_tasks(scr, 3 * n * dh, |t, slot| {
        let (bi, hi) = (t / d.h, t % d.h);
        head_scratch(4 * n * dh + 2 * n * n, |buf| {
            let (qs, rest) = buf.split_at_mut(n * dh);
            let (ks, rest) = rest.split_at_mut(n * dh);
            let (vs, rest) = rest.split_at_mut(n * dh);
            let (dos, rest) = rest.split_at_mut(n * dh);
            let (p, ds) = rest.split_at_mut(n * n);
            gather_head(q, d, bi, hi, qs);
            gather_head(k, d, bi, hi, ks);
            gather_head(v, d, bi, hi, vs);
            gather_head(dout, d, bi, hi, dos);
            head_probs_into(p, qs, ks, d, causal);
            // dp = do · vᵀ (full matrix; only the causal prefix is used)
            matmul_nt_into(ds, dos, vs, n, dh, n);
            // ds = p ∘ (dp − Σ_j dp∘p) · scale, masked tail zeroed
            for i in 0..n {
                let lim = if causal { i + 1 } else { n };
                let prow = &p[i * n..i * n + lim];
                let dsrow = &mut ds[i * n..(i + 1) * n];
                let mut inner = 0f32;
                for (dsv, &pv) in dsrow[..lim].iter().zip(prow) {
                    inner += *dsv * pv;
                }
                for (dsv, &pv) in dsrow[..lim].iter_mut().zip(prow) {
                    *dsv = pv * (*dsv - inner) * scale;
                }
                for dsv in &mut dsrow[lim..] {
                    *dsv = 0.0;
                }
            }
            let (dq_s, rest) = slot.split_at_mut(n * dh);
            let (dk_s, dv_s) = rest.split_at_mut(n * dh);
            // dq = ds·k ; dk = dsᵀ·q ; dv = pᵀ·do
            matmul_nn_into(dq_s, ds, ks, n, n, dh);
            matmul_tn_into(dk_s, ds, qs, n, n, dh);
            matmul_tn_into(dv_s, p, dos, n, n, dh);
        });
    });
    for t in 0..tasks {
        let (bi, hi) = (t / d.h, t % d.h);
        let base = t * 3 * n * dh;
        for i in 0..n {
            let row = (bi * n + i) * c + hi * dh;
            let off = base + i * dh;
            dq[row..row + dh].copy_from_slice(&scr[off..off + dh]);
            let off = base + (n + i) * dh;
            dk[row..row + dh].copy_from_slice(&scr[off..off + dh]);
            let off = base + (2 * n + i) * dh;
            dv[row..row + dh].copy_from_slice(&scr[off..off + dh]);
        }
    }
}

/// Allocating wrapper over [`attn_bwd_into`].
pub fn attn_bwd(dout: &[f32], q: &[f32], k: &[f32], v: &[f32],
                d: &AttnDims, causal: bool)
                -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (n, c) = (d.n, d.c());
    let sz = d.b * n * c;
    let mut dq = vec![0f32; sz];
    let mut dk = vec![0f32; sz];
    let mut dv = vec![0f32; sz];
    let mut scr = vec![0f32; 3 * sz];
    attn_bwd_into(&mut dq, &mut dk, &mut dv, &mut scr, dout, q, k, v, d,
                  causal);
    (dq, dk, dv)
}

/// Exact activation forward (`GELU` per eq. 40 / `SiLU` per eq. 47) into
/// `out`; the same forward is used by the ReGELU2/ReSiLU2 variants —
/// only the saved residual and the backward differ.
pub fn act_fwd_into(out: &mut [f32], u: &[f32], gelu: bool) {
    assert_eq!(out.len(), u.len());
    parallel_rows(out, 1, 4096, |i0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let x = u[i0 + i] as f64;
            *o = if gelu { funcs::gelu(x) } else { funcs::silu(x) } as f32;
        }
    });
}

/// Allocating wrapper over [`act_fwd_into`].
pub fn act_fwd(u: &[f32], gelu: bool) -> Vec<f32> {
    let mut out = vec![0f32; u.len()];
    act_fwd_into(&mut out, u, gelu);
    out
}

/// ReLU forward into `out` (`y = max(x, 0)`; the backward multiplies by
/// packed 1-bit sign codes — see `packing::apply_signs_into`).
pub fn relu_fwd_into(out: &mut [f32], u: &[f32]) {
    assert_eq!(out.len(), u.len());
    parallel_rows(out, 1, 4096, |i0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = u[i0 + i].max(0.0);
        }
    });
}

/// Rotary position embedding (RoPE, adjacent-pair convention) applied
/// in place to a `[B·N, C]` q/k tensor: within each head, the pair
/// `(x₂ⱼ, x₂ⱼ₊₁)` of token `pos` is rotated by
/// `θ = pos · 10000^{−2j/dh}`. `cos`/`sin` are the `[N, dh/2]` tables;
/// `inverse` rotates by `−θ` (the transpose — RoPE is orthogonal, so
/// this is exactly the backward of the forward rotation).
pub fn rope_into(x: &mut [f32], cos: &[f32], sin: &[f32], d: &AttnDims,
                 inverse: bool) {
    let (n, dh, c) = (d.n, d.dh, d.c());
    let half = dh / 2;
    assert_eq!(x.len(), d.b * n * c);
    assert_eq!(cos.len(), n * half);
    assert_eq!(sin.len(), n * half);
    let sign = if inverse { -1.0f32 } else { 1.0 };
    parallel_rows(x, c, 64, |r0, chunk| {
        for (i, row) in chunk.chunks_mut(c).enumerate() {
            let pos = (r0 + i) % n;
            let tc = &cos[pos * half..(pos + 1) * half];
            let ts = &sin[pos * half..(pos + 1) * half];
            for head in row.chunks_mut(dh) {
                for j in 0..half {
                    let (c0, s0) = (tc[j], sign * ts[j]);
                    let x0 = head[2 * j];
                    let x1 = head[2 * j + 1];
                    head[2 * j] = x0 * c0 - x1 * s0;
                    head[2 * j + 1] = x0 * s0 + x1 * c0;
                }
            }
        }
    });
}

/// Exact activation backward into `out`: `du = dy ∘ h'(u)` from the
/// full-precision saved pre-activation.
pub fn act_bwd_exact_into(out: &mut [f32], u: &[f32], dy: &[f32],
                          gelu: bool) {
    assert_eq!(out.len(), u.len());
    parallel_rows(out, 1, 4096, |i0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let x = u[i0 + i] as f64;
            let d = if gelu { funcs::dgelu(x) } else { funcs::dsilu(x) };
            *o = dy[i0 + i] * d as f32;
        }
    });
}

/// Allocating wrapper over [`act_bwd_exact_into`].
pub fn act_bwd_exact(u: &[f32], dy: &[f32], gelu: bool) -> Vec<f32> {
    let mut out = vec![0f32; u.len()];
    act_bwd_exact_into(&mut out, u, dy, gelu);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize,
                n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for t in 0..k {
                    acc += (a[i * k + t] * b[t * n + j]) as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_variants_agree_with_naive() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (7, 11, 5);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let want = naive_nn(&a, &b, m, k, n);
        let got = matmul_nn(&a, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
        // bt[n,k] with bt[j,t] = b[t,j] → nt must match nn
        let mut bt = vec![0f32; n * k];
        for t in 0..k {
            for j in 0..n {
                bt[j * k + t] = b[t * n + j];
            }
        }
        let got = matmul_nt(&a, &bt, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
        // at[k,m] with at[t,i] = a[i,t] → tn must match nn
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for t in 0..k {
                at[t * m + i] = a[i * k + t];
            }
        }
        let got = matmul_tn(&at, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn acc_variants_accumulate() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (6, 9, 10);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let base = matmul_nn(&a, &b, m, k, n);
        let mut c = base.clone();
        matmul_nn_acc_into(&mut c, &a, &b, m, k, n);
        for (x, y) in c.iter().zip(&base) {
            assert!((x - 2.0 * y).abs() < 1e-4);
        }
    }

    #[test]
    fn norm_fwd_is_normalized() {
        let mut rng = Rng::new(4);
        let (rows, c) = (6, 16);
        let x = randv(&mut rng, rows * c);
        let (xhat, stat) = norm_fwd(&x, rows, c, false);
        for r in 0..rows {
            let row = &xhat[r * c..(r + 1) * c];
            let mu: f32 = row.iter().sum::<f32>() / c as f32;
            let var: f32 =
                row.iter().map(|v| v * v).sum::<f32>() / c as f32;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
            assert!(stat[r] > 0.0);
        }
        let (xhat, _) = norm_fwd(&x, rows, c, true);
        for r in 0..rows {
            let row = &xhat[r * c..(r + 1) * c];
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / c as f32;
            assert!((ms - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let k = 8;
        let z = vec![0f32; 2 * k];
        let (loss, _) = softmax_ce(&z, 2, k, &[1, 5]);
        assert!((loss - (k as f32).ln()).abs() < 1e-5);
        let dz = softmax_ce_grad(&z, 2, k, &[1, 5]);
        // rows of dz sum to zero
        for r in 0..2 {
            let s: f32 = dz[r * k..(r + 1) * k].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn attn_rows_are_convex_combinations() {
        // with v = const per row index, each output stays in the convex
        // hull of the values; causal row 0 attends only to itself
        let d = AttnDims { b: 1, n: 4, h: 1, dh: 2 };
        let mut rng = Rng::new(5);
        let q = randv(&mut rng, 8);
        let k = randv(&mut rng, 8);
        let v: Vec<f32> =
            (0..8).map(|i| (i / 2) as f32).collect(); // row j → value j
        let o = attn_fwd(&q, &k, &v, &d, true);
        assert!((o[0] - 0.0).abs() < 1e-6); // row 0 sees only v[0] = 0
        assert!(o[6] >= 0.0 && o[6] <= 3.0);
    }

    #[test]
    fn attn_bwd_matches_finite_difference() {
        let d = AttnDims { b: 2, n: 3, h: 2, dh: 2 };
        let c = d.h * d.dh;
        let sz = d.b * d.n * c;
        let mut rng = Rng::new(6);
        let q = randv(&mut rng, sz);
        let k = randv(&mut rng, sz);
        let v = randv(&mut rng, sz);
        let w = randv(&mut rng, sz); // random linear functional
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            attn_fwd(q, k, v, &d, false)
                .iter()
                .zip(&w)
                .map(|(a, b)| (a * b) as f64)
                .sum()
        };
        let (dq, dk, dv) = attn_bwd(&w, &q, &k, &v, &d, false);
        let eps = 1e-3f32;
        for (buf, grad, which) in [(&q, &dq, 0), (&k, &dk, 1), (&v, &dv, 2)]
        {
            for i in [0usize, 5, sz - 1] {
                let mut plus = buf.to_vec();
                plus[i] += eps;
                let mut minus = buf.to_vec();
                minus[i] -= eps;
                let (lp, lm) = match which {
                    0 => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
                    1 => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
                    _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
                };
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                assert!(
                    (fd - grad[i]).abs() < 2e-2 * fd.abs().max(1.0),
                    "which={which} i={i}: fd={fd} an={}", grad[i]
                );
            }
        }
    }

    #[test]
    fn attn_causal_bwd_matches_finite_difference() {
        // the masked-tail-zeroing path (causal GEMM attention) must also
        // be exactly the gradient of the causal forward
        let d = AttnDims { b: 1, n: 5, h: 2, dh: 3 };
        let c = d.h * d.dh;
        let sz = d.b * d.n * c;
        let mut rng = Rng::new(16);
        let q = randv(&mut rng, sz);
        let k = randv(&mut rng, sz);
        let v = randv(&mut rng, sz);
        let w = randv(&mut rng, sz);
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            attn_fwd(q, k, v, &d, true)
                .iter()
                .zip(&w)
                .map(|(a, b)| (a * b) as f64)
                .sum()
        };
        let (dq, dk, dv) = attn_bwd(&w, &q, &k, &v, &d, true);
        let eps = 1e-3f32;
        for (buf, grad, which) in [(&q, &dq, 0), (&k, &dk, 1), (&v, &dv, 2)]
        {
            for i in [0usize, 7, sz - 1] {
                let mut plus = buf.to_vec();
                plus[i] += eps;
                let mut minus = buf.to_vec();
                minus[i] -= eps;
                let (lp, lm) = match which {
                    0 => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
                    1 => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
                    _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
                };
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                assert!(
                    (fd - grad[i]).abs() < 2e-2 * fd.abs().max(1.0),
                    "which={which} i={i}: fd={fd} an={}", grad[i]
                );
            }
        }
    }

    #[test]
    fn act_exact_matches_scalar() {
        let u = [-2.0f32, -0.5, 0.0, 0.7, 3.0];
        let dy = [1.0f32; 5];
        let y = act_fwd(&u, true);
        let du = act_bwd_exact(&u, &dy, true);
        for i in 0..5 {
            assert!((y[i] as f64 - funcs::gelu(u[i] as f64)).abs() < 1e-6);
            assert!((du[i] as f64 - funcs::dgelu(u[i] as f64)).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_inverse_roundtrip_and_norm_preserving() {
        let d = AttnDims { b: 2, n: 5, h: 2, dh: 6 };
        let c = d.h * d.dh;
        let half = d.dh / 2;
        let mut cos = Vec::new();
        let mut sin = Vec::new();
        for pos in 0..d.n {
            for j in 0..half {
                let th = pos as f64
                    * 10000f64.powf(-2.0 * j as f64 / d.dh as f64);
                cos.push(th.cos() as f32);
                sin.push(th.sin() as f32);
            }
        }
        let mut rng = Rng::new(9);
        let x0 = randv(&mut rng, d.b * d.n * c);
        let mut x = x0.clone();
        rope_into(&mut x, &cos, &sin, &d, false);
        // rotation preserves the per-pair norm
        for (a, b) in x0.chunks(2).zip(x.chunks(2)) {
            let na = a[0] * a[0] + a[1] * a[1];
            let nb = b[0] * b[0] + b[1] * b[1];
            assert!((na - nb).abs() < 1e-4);
        }
        // token 0 is unrotated
        assert_eq!(&x[..c], &x0[..c]);
        // inverse rotation restores the input
        rope_into(&mut x, &cos, &sin, &d, true);
        for (a, b) in x0.iter().zip(&x) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn relu_fwd_matches_scalar() {
        let u = [-2.0f32, -0.5, 0.0, 0.7, 3.0];
        let mut y = [0f32; 5];
        relu_fwd_into(&mut y, &u);
        assert_eq!(y, [0.0, 0.0, 0.0, 0.7, 3.0]);
    }

    #[test]
    fn mul_into_elementwise() {
        let a = [1f32, 2., 3., 4.];
        let b = [5f32, 6., 7., 8.];
        let mut o = [0f32; 4];
        mul_into(&mut o, &a, &b);
        assert_eq!(o, [5.0, 12.0, 21.0, 32.0]);
    }

    #[test]
    fn colsum_and_bias() {
        let a = [1f32, 2., 3., 4., 5., 6.];
        assert_eq!(colsum(&a, 2, 3), vec![5.0, 7.0, 9.0]);
        let mut b = a;
        add_bias(&mut b, &[10.0, 20.0, 30.0]);
        assert_eq!(b[0], 11.0);
        assert_eq!(b[5], 36.0);
    }
}
