//! CPU kernels for the native backend: blocked matmuls, layer norms,
//! softmax cross-entropy, multi-head attention, and activation
//! forward/backward — all parallelized over contiguous row chunks via
//! [`super::pool`], all deterministic (each output element is reduced
//! sequentially by one worker).
//!
//! Matrix layout is row-major. Linear weights follow the `[dout, din]`
//! convention (`y = x · Wᵀ`), which is what the checkpoint affine-merge
//! (eq. 17) assumes.

use super::pool::parallel_rows;
use crate::coeffs::funcs;

/// Epsilon used by every normalization variant.
pub const NORM_EPS: f32 = 1e-5;

fn grain(work_per_row: usize) -> usize {
    (1 << 15) / work_per_row.max(1) + 1
}

/// `c[m,n] = a[m,k] · b[k,n]`.
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize,
                 n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * n];
    parallel_rows(&mut c, n, grain(k * n), |i0, chunk| {
        for (ci, crow) in chunk.chunks_mut(n).enumerate() {
            let arow = &a[(i0 + ci) * k..(i0 + ci + 1) * k];
            for (t, &av) in arow.iter().enumerate() {
                let brow = &b[t * n..(t + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
    c
}

/// `c[m,n] = a[m,k] · b[n,k]ᵀ` — both operands walked contiguously.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize,
                 n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    let mut c = vec![0f32; m * n];
    parallel_rows(&mut c, n, grain(k * n), |i0, chunk| {
        for (ci, crow) in chunk.chunks_mut(n).enumerate() {
            let arow = &a[(i0 + ci) * k..(i0 + ci + 1) * k];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                *cv = dot(arow, brow);
            }
        }
    });
    c
}

/// `c[m,n] = a[k,m]ᵀ · b[k,n]` — the weight-gradient product
/// (`dW = dyᵀ · x`).
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize,
                 n: usize) -> Vec<f32> {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * n];
    parallel_rows(&mut c, n, grain(k * n), |i0, chunk| {
        for (ci, crow) in chunk.chunks_mut(n).enumerate() {
            let i = i0 + ci;
            for t in 0..k {
                let av = a[t * m + i];
                let brow = &b[t * n..(t + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
    c
}

/// Dot product, sequential accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Column sums of `a[rows, cols]` (bias gradients).
pub fn colsum(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(a.len(), rows * cols);
    let mut out = vec![0f32; cols];
    for r in 0..rows {
        let arow = &a[r * cols..(r + 1) * cols];
        for (o, &v) in out.iter_mut().zip(arow) {
            *o += v;
        }
    }
    out
}

/// `a += b`, elementwise.
pub fn add_inplace(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Broadcast-add a `[cols]` bias onto every row of `a[rows, cols]`.
pub fn add_bias(a: &mut [f32], bias: &[f32]) {
    for row in a.chunks_mut(bias.len()) {
        for (x, &v) in row.iter_mut().zip(bias) {
            *x += v;
        }
    }
}

/// Normalization forward. Returns `(xhat, stat)` where `stat` is the
/// per-row reciprocal std (LN) or reciprocal RMS (RMSNorm); the affine
/// transform, if any, is applied by the caller.
pub fn norm_fwd(x: &[f32], rows: usize, c: usize,
                rms: bool) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), rows * c);
    let mut xhat = vec![0f32; rows * c];
    let mut stat = vec![0f32; rows];
    for r in 0..rows {
        let xr = &x[r * c..(r + 1) * c];
        let hr = &mut xhat[r * c..(r + 1) * c];
        if rms {
            let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / c as f32;
            let rho = 1.0 / (ms + NORM_EPS).sqrt();
            stat[r] = rho;
            for (h, &v) in hr.iter_mut().zip(xr) {
                *h = v * rho;
            }
        } else {
            let mu: f32 = xr.iter().sum::<f32>() / c as f32;
            let var: f32 =
                xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>()
                    / c as f32;
            let rstd = 1.0 / (var + NORM_EPS).sqrt();
            stat[r] = rstd;
            for (h, &v) in hr.iter_mut().zip(xr) {
                *h = (v - mu) * rstd;
            }
        }
    }
    (xhat, stat)
}

/// Normalization backward given the upstream grad `dyh` (already
/// multiplied by the affine weight when one exists):
///
/// * LN:  `dx = rstd · (dyh − mean(dyh) − x̂ · mean(dyh·x̂))`
/// * RMS: `dx = ρ · (dyh − x̂ · mean(dyh·x̂))`
pub fn norm_bwd(dyh: &[f32], xhat: &[f32], stat: &[f32], rows: usize,
                c: usize, rms: bool) -> Vec<f32> {
    let mut dx = vec![0f32; rows * c];
    for r in 0..rows {
        let dyr = &dyh[r * c..(r + 1) * c];
        let xr = &xhat[r * c..(r + 1) * c];
        let out = &mut dx[r * c..(r + 1) * c];
        let m2: f32 = dot(dyr, xr) / c as f32;
        if rms {
            for ((o, &d), &xh) in out.iter_mut().zip(dyr).zip(xr) {
                *o = stat[r] * (d - xh * m2);
            }
        } else {
            let m1: f32 = dyr.iter().sum::<f32>() / c as f32;
            for ((o, &d), &xh) in out.iter_mut().zip(dyr).zip(xr) {
                *o = stat[r] * (d - m1 - xh * m2);
            }
        }
    }
    dx
}

/// Mean softmax cross-entropy over `rows` rows of `k` logits.
/// Returns `(loss, accuracy)`.
pub fn softmax_ce(z: &[f32], rows: usize, k: usize,
                  y: &[i32]) -> (f32, f32) {
    assert_eq!(z.len(), rows * k);
    assert_eq!(y.len(), rows);
    let mut loss = 0f64;
    let mut hits = 0usize;
    for r in 0..rows {
        let zr = &z[r * k..(r + 1) * k];
        let (mut mx, mut arg) = (f32::NEG_INFINITY, 0usize);
        for (j, &v) in zr.iter().enumerate() {
            if v > mx {
                mx = v;
                arg = j;
            }
        }
        let lse: f32 =
            mx + zr.iter().map(|v| (v - mx).exp()).sum::<f32>().ln();
        let t = y[r] as usize;
        loss += (lse - zr[t]) as f64;
        hits += usize::from(arg == t);
    }
    ((loss / rows as f64) as f32, hits as f32 / rows as f32)
}

/// Gradient of [`softmax_ce`] w.r.t. the logits:
/// `dz = (softmax(z) − onehot(y)) / rows`.
pub fn softmax_ce_grad(z: &[f32], rows: usize, k: usize,
                       y: &[i32]) -> Vec<f32> {
    let mut dz = vec![0f32; rows * k];
    let inv = 1.0 / rows as f32;
    for r in 0..rows {
        let zr = &z[r * k..(r + 1) * k];
        let out = &mut dz[r * k..(r + 1) * k];
        let mx = zr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for (o, &v) in out.iter_mut().zip(zr) {
            *o = (v - mx).exp();
            sum += *o;
        }
        for o in out.iter_mut() {
            *o = *o / sum * inv;
        }
        out[y[r] as usize] -= inv;
    }
    dz
}

/// Shape of a multi-head attention problem over `[B·N, H·dh]` tensors.
#[derive(Debug, Clone, Copy)]
pub struct AttnDims {
    /// Batch size.
    pub b: usize,
    /// Tokens per sequence.
    pub n: usize,
    /// Number of heads.
    pub h: usize,
    /// Head dimension (`C = h · dh`).
    pub dh: usize,
}

impl AttnDims {
    fn c(&self) -> usize {
        self.h * self.dh
    }
}

fn gather_head(src: &[f32], d: &AttnDims, bi: usize, hi: usize,
               out: &mut [f32]) {
    let c = d.c();
    for i in 0..d.n {
        let row = (bi * d.n + i) * c + hi * d.dh;
        out[i * d.dh..(i + 1) * d.dh]
            .copy_from_slice(&src[row..row + d.dh]);
    }
}

/// Row-softmax of the scaled score matrix `q·kᵀ/√dh` for one head.
/// `lim(i)` = number of valid key positions for query `i`.
fn head_probs(qs: &[f32], ks: &[f32], d: &AttnDims, causal: bool)
              -> Vec<f32> {
    let n = d.n;
    let scale = 1.0 / (d.dh as f32).sqrt();
    let mut p = vec![0f32; n * n];
    for i in 0..n {
        let lim = if causal { i + 1 } else { n };
        let prow = &mut p[i * n..i * n + lim];
        let qrow = &qs[i * d.dh..(i + 1) * d.dh];
        for (j, pv) in prow.iter_mut().enumerate() {
            *pv = dot(qrow, &ks[j * d.dh..(j + 1) * d.dh]) * scale;
        }
        let mx = prow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for pv in prow.iter_mut() {
            *pv = (*pv - mx).exp();
            sum += *pv;
        }
        for pv in prow.iter_mut() {
            *pv /= sum;
        }
    }
    p
}

/// Multi-head attention forward: `o = softmax(q·kᵀ/√dh)·v`, computed per
/// `(batch, head)` task in parallel. Probabilities are **not** retained —
/// the backward pass recomputes them from the saved q/k (the FlashAttn
/// residual policy the measured tape assumes).
pub fn attn_fwd(q: &[f32], k: &[f32], v: &[f32], d: &AttnDims,
                causal: bool) -> Vec<f32> {
    let (n, dh, c) = (d.n, d.dh, d.c());
    let tasks = d.b * d.h;
    let mut o_hm = vec![0f32; tasks * n * dh];
    super::pool::parallel_tasks(&mut o_hm, n * dh, |t, slot| {
        let (bi, hi) = (t / d.h, t % d.h);
        let mut qs = vec![0f32; n * dh];
        let mut ks = vec![0f32; n * dh];
        let mut vs = vec![0f32; n * dh];
        gather_head(q, d, bi, hi, &mut qs);
        gather_head(k, d, bi, hi, &mut ks);
        gather_head(v, d, bi, hi, &mut vs);
        let p = head_probs(&qs, &ks, d, causal);
        for i in 0..n {
            let orow = &mut slot[i * dh..(i + 1) * dh];
            let lim = if causal { i + 1 } else { n };
            for (j, &pv) in p[i * n..i * n + lim].iter().enumerate() {
                let vrow = &vs[j * dh..(j + 1) * dh];
                for (ov, &vv) in orow.iter_mut().zip(vrow) {
                    *ov += pv * vv;
                }
            }
        }
    });
    // head-major [B,H,N,dh] → row-major [B·N, C]
    let mut o = vec![0f32; d.b * n * c];
    for t in 0..tasks {
        let (bi, hi) = (t / d.h, t % d.h);
        for i in 0..n {
            let src = &o_hm[(t * n + i) * dh..(t * n + i + 1) * dh];
            let row = (bi * n + i) * c + hi * dh;
            o[row..row + dh].copy_from_slice(src);
        }
    }
    o
}

/// Multi-head attention backward. Recomputes the probabilities from the
/// saved `q`/`k`, then returns `(dq, dk, dv)` in `[B·N, C]` layout.
pub fn attn_bwd(dout: &[f32], q: &[f32], k: &[f32], v: &[f32],
                d: &AttnDims, causal: bool)
                -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (n, dh, c) = (d.n, d.dh, d.c());
    let scale = 1.0 / (dh as f32).sqrt();
    let tasks = d.b * d.h;
    // one slot per task holding [dq | dk | dv] head-major
    let mut dqkv = vec![0f32; tasks * 3 * n * dh];
    super::pool::parallel_tasks(&mut dqkv, 3 * n * dh, |t, slot| {
        let (bi, hi) = (t / d.h, t % d.h);
        let mut qs = vec![0f32; n * dh];
        let mut ks = vec![0f32; n * dh];
        let mut vs = vec![0f32; n * dh];
        let mut dos = vec![0f32; n * dh];
        gather_head(q, d, bi, hi, &mut qs);
        gather_head(k, d, bi, hi, &mut ks);
        gather_head(v, d, bi, hi, &mut vs);
        gather_head(dout, d, bi, hi, &mut dos);
        let p = head_probs(&qs, &ks, d, causal);
        let (dq_s, rest) = slot.split_at_mut(n * dh);
        let (dk_s, dv_s) = rest.split_at_mut(n * dh);
        let mut ds = vec![0f32; n * n];
        for i in 0..n {
            let lim = if causal { i + 1 } else { n };
            let prow = &p[i * n..i * n + lim];
            let dorow = &dos[i * dh..(i + 1) * dh];
            // dp row, then ds = p ∘ (dp − Σ dp∘p)
            let dsrow = &mut ds[i * n..i * n + lim];
            let mut inner = 0f32;
            for (j, dsv) in dsrow.iter_mut().enumerate() {
                *dsv = dot(dorow, &vs[j * dh..(j + 1) * dh]); // dp
                inner += *dsv * prow[j];
            }
            for (dsv, &pv) in dsrow.iter_mut().zip(prow) {
                *dsv = pv * (*dsv - inner);
            }
            // dv += pᵀ·do ; dq = ds·k·scale ; dk += dsᵀ·q·scale
            let qrow = &qs[i * dh..(i + 1) * dh];
            let dqrow = &mut dq_s[i * dh..(i + 1) * dh];
            for j in 0..lim {
                let pv = prow[j];
                let dsv = ds[i * n + j];
                let krow = &ks[j * dh..(j + 1) * dh];
                let vrow_d = &mut dv_s[j * dh..(j + 1) * dh];
                for (x, &dv_) in vrow_d.iter_mut().zip(dorow) {
                    *x += pv * dv_;
                }
                for (x, &kv) in dqrow.iter_mut().zip(krow) {
                    *x += dsv * kv * scale;
                }
                let krow_d = &mut dk_s[j * dh..(j + 1) * dh];
                for (x, &qv) in krow_d.iter_mut().zip(qrow) {
                    *x += dsv * qv * scale;
                }
            }
        }
    });
    let mut dq = vec![0f32; d.b * n * c];
    let mut dk = vec![0f32; d.b * n * c];
    let mut dv = vec![0f32; d.b * n * c];
    for t in 0..tasks {
        let (bi, hi) = (t / d.h, t % d.h);
        let base = t * 3 * n * dh;
        for i in 0..n {
            let row = (bi * n + i) * c + hi * dh;
            let off = base + i * dh;
            dq[row..row + dh].copy_from_slice(&dqkv[off..off + dh]);
            let off = base + (n + i) * dh;
            dk[row..row + dh].copy_from_slice(&dqkv[off..off + dh]);
            let off = base + (2 * n + i) * dh;
            dv[row..row + dh].copy_from_slice(&dqkv[off..off + dh]);
        }
    }
    (dq, dk, dv)
}

/// Exact activation forward (`GELU` per eq. 40 / `SiLU` per eq. 47); the
/// same forward is used by the ReGELU2/ReSiLU2 variants — only the saved
/// residual and the backward differ.
pub fn act_fwd(u: &[f32], gelu: bool) -> Vec<f32> {
    let mut out = vec![0f32; u.len()];
    parallel_rows(&mut out, 1, 4096, |i0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let x = u[i0 + i] as f64;
            *o = if gelu { funcs::gelu(x) } else { funcs::silu(x) } as f32;
        }
    });
    out
}

/// Exact activation backward: `du = dy ∘ h'(u)` from the full-precision
/// saved pre-activation.
pub fn act_bwd_exact(u: &[f32], dy: &[f32], gelu: bool) -> Vec<f32> {
    let mut out = vec![0f32; u.len()];
    parallel_rows(&mut out, 1, 4096, |i0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let x = u[i0 + i] as f64;
            let d = if gelu { funcs::dgelu(x) } else { funcs::dsilu(x) };
            *o = dy[i0 + i] * d as f32;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize,
                n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for t in 0..k {
                    acc += (a[i * k + t] * b[t * n + j]) as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_variants_agree_with_naive() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (7, 11, 5);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let want = naive_nn(&a, &b, m, k, n);
        let got = matmul_nn(&a, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
        // bt[n,k] with bt[j,t] = b[t,j] → nt must match nn
        let mut bt = vec![0f32; n * k];
        for t in 0..k {
            for j in 0..n {
                bt[j * k + t] = b[t * n + j];
            }
        }
        let got = matmul_nt(&a, &bt, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
        // at[k,m] with at[t,i] = a[i,t] → tn must match nn
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for t in 0..k {
                at[t * m + i] = a[i * k + t];
            }
        }
        let got = matmul_tn(&at, &b, m, k, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn norm_fwd_is_normalized() {
        let mut rng = Rng::new(4);
        let (rows, c) = (6, 16);
        let x = randv(&mut rng, rows * c);
        let (xhat, stat) = norm_fwd(&x, rows, c, false);
        for r in 0..rows {
            let row = &xhat[r * c..(r + 1) * c];
            let mu: f32 = row.iter().sum::<f32>() / c as f32;
            let var: f32 =
                row.iter().map(|v| v * v).sum::<f32>() / c as f32;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
            assert!(stat[r] > 0.0);
        }
        let (xhat, _) = norm_fwd(&x, rows, c, true);
        for r in 0..rows {
            let row = &xhat[r * c..(r + 1) * c];
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / c as f32;
            assert!((ms - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let k = 8;
        let z = vec![0f32; 2 * k];
        let (loss, _) = softmax_ce(&z, 2, k, &[1, 5]);
        assert!((loss - (k as f32).ln()).abs() < 1e-5);
        let dz = softmax_ce_grad(&z, 2, k, &[1, 5]);
        // rows of dz sum to zero
        for r in 0..2 {
            let s: f32 = dz[r * k..(r + 1) * k].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn attn_rows_are_convex_combinations() {
        // with v = const per row index, each output stays in the convex
        // hull of the values; causal row 0 attends only to itself
        let d = AttnDims { b: 1, n: 4, h: 1, dh: 2 };
        let mut rng = Rng::new(5);
        let q = randv(&mut rng, 8);
        let k = randv(&mut rng, 8);
        let v: Vec<f32> =
            (0..8).map(|i| (i / 2) as f32).collect(); // row j → value j
        let o = attn_fwd(&q, &k, &v, &d, true);
        assert!((o[0] - 0.0).abs() < 1e-6); // row 0 sees only v[0] = 0
        assert!(o[6] >= 0.0 && o[6] <= 3.0);
    }

    #[test]
    fn attn_bwd_matches_finite_difference() {
        let d = AttnDims { b: 2, n: 3, h: 2, dh: 2 };
        let c = d.h * d.dh;
        let sz = d.b * d.n * c;
        let mut rng = Rng::new(6);
        let q = randv(&mut rng, sz);
        let k = randv(&mut rng, sz);
        let v = randv(&mut rng, sz);
        let w = randv(&mut rng, sz); // random linear functional
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            attn_fwd(q, k, v, &d, false)
                .iter()
                .zip(&w)
                .map(|(a, b)| (a * b) as f64)
                .sum()
        };
        let (dq, dk, dv) = attn_bwd(&w, &q, &k, &v, &d, false);
        let eps = 1e-3f32;
        for (buf, grad, which) in [(&q, &dq, 0), (&k, &dk, 1), (&v, &dv, 2)]
        {
            for i in [0usize, 5, sz - 1] {
                let mut plus = buf.to_vec();
                plus[i] += eps;
                let mut minus = buf.to_vec();
                minus[i] -= eps;
                let (lp, lm) = match which {
                    0 => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
                    1 => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
                    _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
                };
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                assert!(
                    (fd - grad[i]).abs() < 2e-2 * fd.abs().max(1.0),
                    "which={which} i={i}: fd={fd} an={}", grad[i]
                );
            }
        }
    }

    #[test]
    fn act_exact_matches_scalar() {
        let u = [-2.0f32, -0.5, 0.0, 0.7, 3.0];
        let dy = [1.0f32; 5];
        let y = act_fwd(&u, true);
        let du = act_bwd_exact(&u, &dy, true);
        for i in 0..5 {
            assert!((y[i] as f64 - funcs::gelu(u[i] as f64)).abs() < 1e-6);
            assert!((du[i] as f64 - funcs::dgelu(u[i] as f64)).abs() < 1e-6);
        }
    }

    #[test]
    fn colsum_and_bias() {
        let a = [1f32, 2., 3., 4., 5., 6.];
        assert_eq!(colsum(&a, 2, 3), vec![5.0, 7.0, 9.0]);
        let mut b = a;
        add_bias(&mut b, &[10.0, 20.0, 30.0]);
        assert_eq!(b[0], 11.0);
        assert_eq!(b[5], 36.0);
    }
}
