//! The in-tree pure-Rust CPU backend (default).
//!
//! Executes the fine-tuning step directly from the manifest: the
//! [`model`] module assembles the transformer as a composition of
//! [`layers`] (each a decoupled fwd/bwd pair against the typed residual
//! tape, whose slot list *is* the residual ABI), [`kernels`] provides
//! the matmul / attention / norm / activation primitives on top of the
//! cache-blocked panel-packed [`gemm`] engine, [`pool`] fans the hot
//! loops out over a persistent worker pool, [`arena`] pools the
//! step-scoped activation buffers, and [`spec`] parses preset names and
//! synthesizes manifests from the derived tape schema — so `ambp train
//! --preset vitt_loraqv_regelu2_msln` works with zero build-time
//! artifacts.

pub mod arena;
pub mod gemm;
pub mod kernels;
pub mod layers;
pub mod model;
pub mod pool;
pub mod spec;

use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::runtime::{Artifact, Backend, Executor, FwdOut, Tensor};

pub use arena::{Arena, ArenaStats};
pub use layers::Profiler;
pub use model::{Act, Arch, Model, NetCfg, Norm, Tuning};

/// The native CPU backend (unit struct — all state lives in artifacts).
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self, dir: &Path) -> Result<Artifact> {
        spec::load_artifact(dir)
    }

    fn synthesize(&self, preset: &str) -> Result<Artifact> {
        spec::synth_artifact(preset)
    }
}

/// [`Executor`] over a built native [`Model`], owning the step-scoped
/// buffer [`Arena`]: activations and residual payloads are taken from
/// (and, via [`Executor::recycle`], returned to) its free lists, so the
/// steady-state train step allocates nothing.
pub struct NativeExec {
    /// The model whose layout matches the artifact manifest.
    pub model: Model,
    arena: Mutex<Arena>,
}

impl NativeExec {
    /// Wrap a built model with a fresh arena.
    pub fn new(model: Model) -> NativeExec {
        NativeExec { model, arena: Mutex::new(Arena::new()) }
    }

    /// Free-list hit/miss counters of the owned arena (the steady-state
    /// zero-allocation claim is asserted against these in the tests).
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .stats()
    }
}

impl Executor for NativeExec {
    fn run_fwd(&self, params: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<FwdOut> {
        let mut arena =
            self.arena.lock().unwrap_or_else(|e| e.into_inner());
        let (loss, metric, residuals) =
            self.model.forward_in(&mut arena, params, x, y)?;
        Ok(FwdOut { loss, metric, residuals })
    }

    fn run_bwd(&self, params: &[Tensor], residuals: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<Vec<Tensor>> {
        let mut arena =
            self.arena.lock().unwrap_or_else(|e| e.into_inner());
        self.model.backward_in(&mut arena, params, residuals, x, y)
    }

    fn recycle(&self, residuals: Vec<Tensor>) {
        let mut arena =
            self.arena.lock().unwrap_or_else(|e| e.into_inner());
        for t in residuals {
            arena.recycle_tensor(t);
        }
    }
}
