//! The in-tree pure-Rust CPU backend (default).
//!
//! Executes the fine-tuning step directly from the manifest: the
//! [`model`] module assembles the transformer as a composition of
//! [`layers`] (each a decoupled fwd/bwd pair against the typed residual
//! tape, whose slot list *is* the residual ABI), [`kernels`] provides
//! the matmul / attention / norm / activation primitives on top of the
//! cache-blocked panel-packed [`gemm`] engine, [`pool`] fans the hot
//! loops out over a persistent worker pool, [`arena`] pools the
//! step-scoped activation buffers, and [`spec`] parses preset names and
//! synthesizes manifests from the derived tape schema — so `ambp train
//! --preset vitt_loraqv_regelu2_msln` works with zero build-time
//! artifacts.

pub mod arena;
pub mod gemm;
pub mod kernels;
pub mod layers;
pub mod model;
pub mod pool;
pub mod spec;

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::runtime::{Artifact, Backend, BwdSplitJob, Executor,
                     FrozenBase, FwdOut, FwdSplitJob, Manifest, Params,
                     Tensor};

pub use arena::{Arena, ArenaStats};
pub use layers::Profiler;
pub use model::{Act, Arch, Model, NetCfg, Norm, Tuning};

/// The native CPU backend (unit struct — all state lives in artifacts).
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self, dir: &Path) -> Result<Artifact> {
        spec::load_artifact(dir)
    }

    fn synthesize(&self, preset: &str) -> Result<Artifact> {
        spec::synth_artifact(preset)
    }

    fn assemble(&self, dir: PathBuf, manifest: Manifest,
                params0: Vec<Tensor>) -> Result<Artifact> {
        spec::assemble_artifact(dir, manifest, params0)
    }
}

/// [`Executor`] over a built native [`Model`], owning the step-scoped
/// buffer [`Arena`]: activations and residual payloads are taken from
/// (and, via [`Executor::recycle`], returned to) its free lists, so the
/// steady-state train step allocates nothing. The model itself is
/// `Arc`-shared: [`Executor::fork`] hands out sibling executors over
/// the same compiled layer stack, each with a private arena, which is
/// what lets N concurrent sessions share one frozen base without
/// contending on scratch buffers.
pub struct NativeExec {
    /// The model whose layout matches the artifact manifest (shared
    /// between this executor and any fork of it).
    pub model: Arc<Model>,
    arena: Mutex<Arena>,
}

impl NativeExec {
    /// Wrap a built model with a fresh arena.
    pub fn new(model: Model) -> NativeExec {
        NativeExec::from_shared(Arc::new(model))
    }

    /// Wrap an already-shared model with a fresh arena (the fork path).
    pub fn from_shared(model: Arc<Model>) -> NativeExec {
        NativeExec { model, arena: Mutex::new(Arena::new()) }
    }

    /// Free-list hit/miss counters of the owned arena (the steady-state
    /// zero-allocation claim is asserted against these in the tests).
    pub fn arena_stats(&self) -> ArenaStats {
        self.arena
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .stats()
    }

    fn fwd_view(&self, params: Params<'_>, x: &Tensor,
                y: &Tensor) -> Result<FwdOut> {
        let mut arena =
            self.arena.lock().unwrap_or_else(|e| e.into_inner());
        let (loss, metric, residuals) =
            self.model.forward_view(&mut arena, params, x, y)?;
        Ok(FwdOut { loss, metric, residuals })
    }

    fn bwd_view(&self, params: Params<'_>, residuals: &[Tensor],
                x: &Tensor, y: &Tensor) -> Result<Vec<Tensor>> {
        let mut arena =
            self.arena.lock().unwrap_or_else(|e| e.into_inner());
        self.model.backward_view(&mut arena, params, residuals, x, y)
    }
}

impl Executor for NativeExec {
    fn run_fwd(&self, params: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<FwdOut> {
        self.fwd_view(Params::Flat(params), x, y)
    }

    fn run_bwd(&self, params: &[Tensor], residuals: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<Vec<Tensor>> {
        self.bwd_view(Params::Flat(params), residuals, x, y)
    }

    fn run_fwd_split(&self, base: &FrozenBase, trainable: &[Tensor],
                     x: &Tensor, y: &Tensor) -> Result<FwdOut> {
        self.fwd_view(Params::Split { base, trainable }, x, y)
    }

    fn run_bwd_split(&self, base: &FrozenBase, trainable: &[Tensor],
                     residuals: &[Tensor], x: &Tensor,
                     y: &Tensor) -> Result<Vec<Tensor>> {
        self.bwd_view(Params::Split { base, trainable }, residuals, x, y)
    }

    fn run_fwd_split_many(&self, base: &FrozenBase,
                          jobs: &[FwdSplitJob<'_>])
                          -> Result<Vec<FwdOut>> {
        let mut arena =
            self.arena.lock().unwrap_or_else(|e| e.into_inner());
        let view: Vec<(Params<'_>, &Tensor, &Tensor)> = jobs
            .iter()
            .map(|j| {
                (Params::Split { base, trainable: j.trainable }, j.x, j.y)
            })
            .collect();
        let outs = self.model.forward_many(&mut arena, &view)?;
        Ok(outs
            .into_iter()
            .map(|(loss, metric, residuals)| FwdOut {
                loss,
                metric,
                residuals,
            })
            .collect())
    }

    fn run_bwd_split_many(&self, base: &FrozenBase,
                          jobs: &[BwdSplitJob<'_>])
                          -> Result<Vec<Vec<Tensor>>> {
        let mut arena =
            self.arena.lock().unwrap_or_else(|e| e.into_inner());
        let view: Vec<(Params<'_>, &[Tensor], &Tensor, &Tensor)> = jobs
            .iter()
            .map(|j| {
                (Params::Split { base, trainable: j.trainable },
                 j.residuals, j.x, j.y)
            })
            .collect();
        self.model.backward_many(&mut arena, &view)
    }

    fn supports_split(&self) -> bool {
        true
    }

    fn fork(&self) -> Option<Box<dyn Executor>> {
        Some(Box::new(NativeExec::from_shared(self.model.clone())))
    }

    fn recycle(&self, residuals: Vec<Tensor>) {
        let mut arena =
            self.arena.lock().unwrap_or_else(|e| e.into_inner());
        for t in residuals {
            arena.recycle_tensor(t);
        }
    }
}
