//! The in-tree pure-Rust CPU backend (default).
//!
//! Executes the fine-tuning step directly from the manifest: the
//! [`model`] module builds the transformer and runs the decoupled
//! forward/backward passes, [`kernels`] provides the blocked matmul /
//! attention / norm / activation primitives, [`pool`] fans the hot loops
//! out over cores, and [`spec`] parses preset names and synthesizes
//! manifests by dry-running the model — so `ambp train --preset
//! vitt_loraqv_regelu2_msln` works with zero build-time artifacts.

pub mod kernels;
pub mod model;
pub mod pool;
pub mod spec;

use std::path::Path;

use anyhow::Result;

use crate::runtime::{Artifact, Backend, Executor, FwdOut, Tensor};

pub use model::{Act, Arch, Model, NetCfg, Norm, Tuning};

/// The native CPU backend (unit struct — all state lives in artifacts).
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self, dir: &Path) -> Result<Artifact> {
        spec::load_artifact(dir)
    }

    fn synthesize(&self, preset: &str) -> Result<Artifact> {
        spec::synth_artifact(preset)
    }
}

/// [`Executor`] over a built native [`Model`].
pub struct NativeExec {
    /// The model whose layout matches the artifact manifest.
    pub model: Model,
}

impl Executor for NativeExec {
    fn run_fwd(&self, params: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<FwdOut> {
        let (loss, metric, saves) = self.model.forward(params, x, y)?;
        Ok(FwdOut {
            loss,
            metric,
            residuals: saves.into_iter().map(|s| s.tensor).collect(),
        })
    }

    fn run_bwd(&self, params: &[Tensor], residuals: &[Tensor], x: &Tensor,
               y: &Tensor) -> Result<Vec<Tensor>> {
        self.model.backward(params, residuals, x, y)
    }
}
