//! Composable layer API for the native model.
//!
//! A native model is a [`Seq`] of boxed [`Layer`]s. Each layer
//! implements a decoupled forward/backward pair against the typed
//! residual tape of [`tape`]: `fwd` transforms the activation carried by
//! [`FwdCtx`] and pushes the residuals *it* declared at build time;
//! `bwd` transforms the gradient carried by [`BwdCtx`] and pops exactly
//! those slots in reverse. Because the same [`SlotId`] fields drive both
//! passes, the fwd/bwd residual contract cannot drift — and the flat
//! slot list doubles as the manifest residual section, so the ABI is
//! *derived* from the composition rather than maintained by hand
//! (DESIGN.md §2.2).
//!
//! Layer inventory: [`Embed`], [`Norm`] (plain + memory-sharing),
//! [`Linear`] (with optional LoRA adapter), [`Attention`] (optional
//! RoPE), [`Activation`] (GELU/SiLU/ReLU exact + ReGELU2/ReSiLU2 2-bit),
//! [`SwiGlu`], [`Head`], and the combinators [`Seq`], [`Residual`]
//! (pre-norm skip connection) and [`CkptBlock`] (gradient
//! checkpointing: store the block input, recompute the inner tape in
//! bwd). Adding a scenario means adding a `Layer` impl, not editing a
//! monolithic fwd/bwd pair.

pub mod activation;
pub mod attention;
pub mod ckpt;
pub mod embed;
pub mod head;
pub mod linear;
pub mod norm;
pub mod swiglu;
pub mod tape;

use std::time::Instant;

use anyhow::Result;

use super::arena::Arena;
use crate::runtime::manifest::ParamInfo;
use crate::runtime::params::Params;
use crate::runtime::tensor::Tensor;

pub use activation::Activation;
pub use attention::Attention;
pub use ckpt::CkptBlock;
pub use embed::Embed;
pub use head::Head;
pub use linear::{LinOp, Linear, XSrc};
pub use norm::Norm;
pub use swiglu::SwiGlu;
pub use tape::{Composer, Kind, ResF32, SlotId, SlotInfo, TapeReader,
               TapeWriter};

/// Parameter registry used while composing a model: mints manifest
/// parameter indices in layout order.
#[derive(Default)]
pub struct ParamReg {
    /// Parameter layout in manifest order.
    pub infos: Vec<ParamInfo>,
}

impl ParamReg {
    /// An empty registry.
    pub fn new() -> ParamReg {
        ParamReg::default()
    }

    /// Register a parameter; returns its manifest index.
    pub fn add(&mut self, name: String, shape: Vec<usize>,
               trainable: bool) -> usize {
        self.infos.push(ParamInfo { name, shape, trainable });
        self.infos.len() - 1
    }
}

/// Per-layer wall-clock accumulator (used by the hotpath bench's
/// per-layer section; populated only when a profiler is attached to the
/// context, so the train path pays nothing).
#[derive(Default)]
pub struct Profiler {
    entries: Vec<(&'static str, f64, u64)>,
}

impl Profiler {
    /// An empty profiler.
    pub fn new() -> Profiler {
        Profiler::default()
    }

    /// Accumulate `ns` nanoseconds against `name`.
    pub fn add(&mut self, name: &'static str, ns: f64) {
        match self.entries.iter_mut().find(|(n, _, _)| *n == name) {
            Some((_, t, c)) => {
                *t += ns;
                *c += 1;
            }
            None => self.entries.push((name, ns, 1)),
        }
    }

    /// `(layer name, total ns, calls)` rows in first-seen order.
    pub fn rows(&self) -> &[(&'static str, f64, u64)] {
        &self.entries
    }
}

/// Forward-pass context threaded through the layer stack. `h` is the
/// running activation (`[rows, cols]` row-major, cols layer-defined);
/// [`Embed`] initializes it from `x`, [`Head`] consumes it into
/// `loss`/`metric`.
pub struct FwdCtx<'a> {
    /// Model parameters, manifest order (flat slice or shared-base +
    /// trainable split — layers index both identically).
    pub params: Params<'a>,
    /// Step-scoped buffer arena (all activations come from here).
    pub arena: &'a mut Arena,
    /// Input batch.
    pub x: &'a Tensor,
    /// Target batch.
    pub y: &'a Tensor,
    /// Running activation (empty before [`Embed`] / after [`Head`]).
    pub h: Vec<f32>,
    /// Loss, set by [`Head`].
    pub loss: f32,
    /// Task metric, set by [`Head`].
    pub metric: f32,
    /// Optional per-layer latency sink (bench only).
    pub profiler: Option<&'a mut Profiler>,
}

impl FwdCtx<'_> {
    /// Replace the running activation, returning the old buffer to the
    /// arena.
    pub fn set_h(&mut self, new: Vec<f32>) {
        let old = std::mem::replace(&mut self.h, new);
        self.arena.put_f32(old);
    }
}

/// Backward-pass context. `dh` is the running gradient w.r.t. the
/// activation [`FwdCtx::h`] carried at the same point of the stack;
/// [`Head`] initializes it from the loss, [`Embed`] consumes it into
/// the embedding gradients.
pub struct BwdCtx<'a> {
    /// Model parameters, manifest order (flat slice or shared-base +
    /// trainable split — layers index both identically).
    pub params: Params<'a>,
    /// Parameter layout (trainability gates gradient work).
    pub infos: &'a [ParamInfo],
    /// Step-scoped buffer arena.
    pub arena: &'a mut Arena,
    /// Input batch.
    pub x: &'a Tensor,
    /// Target batch.
    pub y: &'a Tensor,
    /// Running gradient (empty before [`Head`] / after [`Embed`]).
    pub dh: Vec<f32>,
    /// Gradient staging slots, one per parameter (manifest order).
    pub grads: &'a mut [Option<Vec<f32>>],
    /// Optional per-layer latency sink (bench only).
    pub profiler: Option<&'a mut Profiler>,
}

impl BwdCtx<'_> {
    /// Replace the running gradient, returning the old buffer to the
    /// arena.
    pub fn set_dh(&mut self, new: Vec<f32>) {
        let old = std::mem::replace(&mut self.dh, new);
        self.arena.put_f32(old);
    }

    /// Accumulate gradient buffer `g` into the staging slot for
    /// parameter `idx` (dropped to the arena when the parameter is
    /// frozen).
    pub fn acc(&mut self, idx: usize, g: Vec<f32>) {
        if !self.infos[idx].trainable {
            self.arena.put_f32(g);
            return;
        }
        match &mut self.grads[idx] {
            Some(a) => {
                super::kernels::add_inplace(a, &g);
                self.arena.put_f32(g);
            }
            slot @ None => *slot = Some(g),
        }
    }
}

/// One fused session's forward state in the cross-tenant `_many` walk:
/// the per-session pieces of [`FwdCtx`] (params view, batch, running
/// activation, tape) — everything except the arena, which the walk
/// shares across lanes.
pub struct FwdLane<'a> {
    /// The session's parameter view (split view onto the shared base).
    pub params: Params<'a>,
    /// Input batch.
    pub x: &'a Tensor,
    /// Target batch.
    pub y: &'a Tensor,
    /// Running activation.
    pub h: Vec<f32>,
    /// Loss, set by the head.
    pub loss: f32,
    /// Task metric, set by the head.
    pub metric: f32,
    /// The session's private residual tape.
    pub tape: TapeWriter<'a>,
}

/// One fused session's backward state (see [`FwdLane`]).
pub struct BwdLane<'a> {
    /// The session's parameter view.
    pub params: Params<'a>,
    /// Parameter layout (trainability gates gradient work).
    pub infos: &'a [ParamInfo],
    /// Input batch.
    pub x: &'a Tensor,
    /// Target batch.
    pub y: &'a Tensor,
    /// Running gradient.
    pub dh: Vec<f32>,
    /// Gradient staging slots, one per parameter (manifest order).
    pub grads: Vec<Option<Vec<f32>>>,
    /// The session's private tape reader.
    pub tape: TapeReader<'a>,
}

/// The generic per-lane forward walk: run `layer.fwd` once per lane
/// with a context assembled from the lane's state. This is both the
/// [`Layer::fwd_many`] default body and the fallback layers with a
/// fused override use when fusion preconditions fail. Bit-identity per
/// lane is by construction — the exact serial `fwd` runs on the exact
/// serial state; lanes differ from N serial calls only in arena buffer
/// interleaving, which is pooling, not arithmetic. Profiling is off in
/// lane mode.
pub fn fwd_each<L: Layer + ?Sized>(layer: &L, arena: &mut Arena,
                                   lanes: &mut [FwdLane<'_>])
                                   -> Result<()> {
    for lane in lanes.iter_mut() {
        let mut ctx = FwdCtx {
            params: lane.params,
            arena: &mut *arena,
            x: lane.x,
            y: lane.y,
            h: std::mem::take(&mut lane.h),
            loss: lane.loss,
            metric: lane.metric,
            profiler: None,
        };
        let res = layer.fwd(&mut ctx, &mut lane.tape);
        lane.h = std::mem::take(&mut ctx.h);
        lane.loss = ctx.loss;
        lane.metric = ctx.metric;
        res?;
    }
    Ok(())
}

/// The generic per-lane backward walk (see [`fwd_each`]).
pub fn bwd_each<L: Layer + ?Sized>(layer: &L, arena: &mut Arena,
                                   lanes: &mut [BwdLane<'_>])
                                   -> Result<()> {
    for lane in lanes.iter_mut() {
        let mut ctx = BwdCtx {
            params: lane.params,
            infos: lane.infos,
            arena: &mut *arena,
            x: lane.x,
            y: lane.y,
            dh: std::mem::take(&mut lane.dh),
            grads: lane.grads.as_mut_slice(),
            profiler: None,
        };
        let res = layer.bwd(&mut ctx, &mut lane.tape);
        lane.dh = std::mem::take(&mut ctx.dh);
        res?;
    }
    Ok(())
}

/// One composable model stage. Implementations push, in `fwd`, exactly
/// the slots they minted at construction, in mint order — and pop them
/// in reverse in `bwd`. The tape cursors verify both.
pub trait Layer {
    /// Stable display name (profiling, errors).
    fn name(&self) -> &'static str;

    /// Whether this is a leaf layer (profiled individually) rather than
    /// a combinator whose children profile themselves.
    fn is_leaf(&self) -> bool {
        true
    }

    /// Forward: transform `ctx.h`, push declared residuals.
    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()>;

    /// Backward: transform `ctx.dh`, pop declared residuals in reverse,
    /// accumulate parameter gradients via [`BwdCtx::acc`].
    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()>;

    /// Forward over N fused session lanes. The default runs the serial
    /// `fwd` once per lane ([`fwd_each`]) — always bit-identical to N
    /// serial calls. Combinators override it to recurse lane-wise
    /// (keeping all lanes at the same layer), and [`Linear`] overrides
    /// it to sweep every lane's activation block through one packed
    /// frozen-weight panel per KC block.
    fn fwd_many(&self, arena: &mut Arena,
                lanes: &mut [FwdLane<'_>]) -> Result<()> {
        fwd_each(self, arena, lanes)
    }

    /// Backward over N fused session lanes (see [`Layer::fwd_many`]).
    fn bwd_many(&self, arena: &mut Arena,
                lanes: &mut [BwdLane<'_>]) -> Result<()> {
        bwd_each(self, arena, lanes)
    }
}

/// Sequential composition; `bwd` walks the children in reverse.
pub struct Seq {
    /// Child layers, forward order.
    pub layers: Vec<Box<dyn Layer>>,
}

impl Seq {
    /// Compose `layers` sequentially.
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Seq {
        Seq { layers }
    }
}

fn timed_fwd(l: &dyn Layer, ctx: &mut FwdCtx,
             tape: &mut TapeWriter) -> Result<()> {
    if ctx.profiler.is_some() && l.is_leaf() {
        let t0 = Instant::now();
        l.fwd(ctx, tape)?;
        let ns = t0.elapsed().as_nanos() as f64;
        if let Some(p) = ctx.profiler.as_deref_mut() {
            p.add(l.name(), ns);
        }
        Ok(())
    } else {
        l.fwd(ctx, tape)
    }
}

fn timed_bwd(l: &dyn Layer, ctx: &mut BwdCtx,
             tape: &mut TapeReader) -> Result<()> {
    if ctx.profiler.is_some() && l.is_leaf() {
        let t0 = Instant::now();
        l.bwd(ctx, tape)?;
        let ns = t0.elapsed().as_nanos() as f64;
        if let Some(p) = ctx.profiler.as_deref_mut() {
            p.add(l.name(), ns);
        }
        Ok(())
    } else {
        l.bwd(ctx, tape)
    }
}

impl Layer for Seq {
    fn name(&self) -> &'static str {
        "Seq"
    }

    fn is_leaf(&self) -> bool {
        false
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        for l in &self.layers {
            timed_fwd(l.as_ref(), ctx, tape)?;
        }
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        for l in self.layers.iter().rev() {
            timed_bwd(l.as_ref(), ctx, tape)?;
        }
        Ok(())
    }

    // Layer-major recursion: every lane advances through child `l`
    // before any lane sees child `l+1`, which is what lets a fused
    // leaf see all N activation blocks at once.
    fn fwd_many(&self, arena: &mut Arena,
                lanes: &mut [FwdLane<'_>]) -> Result<()> {
        for l in &self.layers {
            l.fwd_many(arena, lanes)?;
        }
        Ok(())
    }

    fn bwd_many(&self, arena: &mut Arena,
                lanes: &mut [BwdLane<'_>]) -> Result<()> {
        for l in self.layers.iter().rev() {
            l.bwd_many(arena, lanes)?;
        }
        Ok(())
    }
}

/// Pre-norm residual branch: `h ← h + inner(h)`. The backward pass adds
/// the skip gradient back after the branch backward — exactly the
/// decoupled form the old monolithic `block_fwd`/`block_bwd` hard-coded
/// twice per block.
pub struct Residual {
    inner: Seq,
}

impl Residual {
    /// Wrap `inner` in a skip connection.
    pub fn new(inner: Seq) -> Residual {
        Residual { inner }
    }
}

impl Layer for Residual {
    fn name(&self) -> &'static str {
        "Residual"
    }

    fn is_leaf(&self) -> bool {
        false
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        let mut keep = ctx.arena.take_f32(ctx.h.len());
        keep.copy_from_slice(&ctx.h);
        self.inner.fwd(ctx, tape)?;
        super::kernels::add_inplace(&mut ctx.h, &keep);
        ctx.arena.put_f32(keep);
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        let mut dkeep = ctx.arena.take_f32(ctx.dh.len());
        dkeep.copy_from_slice(&ctx.dh);
        self.inner.bwd(ctx, tape)?;
        super::kernels::add_inplace(&mut ctx.dh, &dkeep);
        ctx.arena.put_f32(dkeep);
        Ok(())
    }

    // Per-lane skip saves around a lane-wise branch recursion — the
    // save/add arithmetic per lane is exactly the serial one.
    fn fwd_many(&self, arena: &mut Arena,
                lanes: &mut [FwdLane<'_>]) -> Result<()> {
        let mut keeps = Vec::with_capacity(lanes.len());
        for lane in lanes.iter() {
            let mut keep = arena.take_f32(lane.h.len());
            keep.copy_from_slice(&lane.h);
            keeps.push(keep);
        }
        let res = self.inner.fwd_many(arena, lanes);
        for (lane, keep) in lanes.iter_mut().zip(keeps) {
            if res.is_ok() {
                super::kernels::add_inplace(&mut lane.h, &keep);
            }
            arena.put_f32(keep);
        }
        res
    }

    fn bwd_many(&self, arena: &mut Arena,
                lanes: &mut [BwdLane<'_>]) -> Result<()> {
        let mut dkeeps = Vec::with_capacity(lanes.len());
        for lane in lanes.iter() {
            let mut dkeep = arena.take_f32(lane.dh.len());
            dkeep.copy_from_slice(&lane.dh);
            dkeeps.push(dkeep);
        }
        let res = self.inner.bwd_many(arena, lanes);
        for (lane, dkeep) in lanes.iter_mut().zip(dkeeps) {
            if res.is_ok() {
                super::kernels::add_inplace(&mut lane.dh, &dkeep);
            }
            arena.put_f32(dkeep);
        }
        res
    }
}
