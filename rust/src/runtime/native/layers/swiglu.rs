//! SwiGLU MLP layer (LLaMA-style gated feed-forward):
//! `y = W₃ᵀ·(h(W₁ᵀx) ⊙ W₂ᵀx)` with `h` the configured activation
//! (SiLU in the real architecture; the gate reuses the full
//! [`ActResidual`] policy, so ReSiLU2's 2-bit codes work here too).
//!
//! Residuals, in push order: the shared input `x` (saved once for
//! W₁/W₂, or shared with an MS norm's x̂), the two LoRA `u`s, the gate
//! activation residual, both gate-multiply operands (`s = h(u₁)` and
//! `u₃` — the paper's Figure 6 "+2·R·M" term), and the down
//! projection's input `p = s ⊙ u₃`. Module names follow the memmodel's
//! llama block (`fc1` = gate, `fc2` = up, `fc3` = down), which is what
//! lets the analytical cross-check match byte-for-byte.

use anyhow::Result;

use super::super::kernels::{add_inplace, mul_into};
use super::super::model::NetCfg;
use super::activation::ActResidual;
use super::linear::{need_x, LinOp};
use super::tape::{Composer, Kind, SlotId, TapeReader, TapeWriter};
use super::{BwdCtx, FwdCtx, Layer, ParamReg};

/// Gated MLP over a `[B·N, C]` running activation.
pub struct SwiGlu {
    gate: LinOp,
    up: LinOp,
    down: LinOp,
    act: ActResidual,
    s_slot: SlotId,
    u3_slot: SlotId,
    x_slot: Option<SlotId>,
    rows: usize,
    m: usize,
}

impl SwiGlu {
    /// Build the gated MLP for module path `mn` (e.g. `block0.mlp`).
    /// `shared_x` is the MS norm's x̂ slot, when one exists.
    pub fn new(cfg: &NetCfg, reg: &mut ParamReg, comp: &mut Composer,
               mn: &str, lead: &[usize],
               shared_x: Option<SlotId>) -> SwiGlu {
        let c = cfg.dim;
        let m = cfg.hidden();
        let needed = need_x(cfg, "fc1") || need_x(cfg, "fc2");
        let mut xshape = lead.to_vec();
        xshape.push(c);
        let (x_slot, x_ext) = match shared_x {
            Some(s) => (None, Some(s)),
            None if needed => {
                let s = comp.slot_f32(&format!("{mn}.fc1"),
                                      Kind::LinearInput, &xshape);
                (Some(s), Some(s))
            }
            None => (None, None),
        };
        let gate = LinOp::new(cfg, reg, comp, &format!("{mn}.fc1"),
                              "fc1", c, m, lead, x_ext);
        let up = LinOp::new(cfg, reg, comp, &format!("{mn}.fc2"), "fc2",
                            c, m, lead, x_ext);
        let act =
            ActResidual::mint(cfg, comp, &format!("{mn}.act"), lead, m);
        let mut mshape = lead.to_vec();
        mshape.push(m);
        let s_slot = comp.slot_f32(mn, Kind::GateOperand, &mshape);
        let u3_slot = comp.slot_f32(mn, Kind::GateOperand, &mshape);
        let down = LinOp::new(cfg, reg, comp, &format!("{mn}.fc3"),
                              "fc3", m, c, lead, None);
        SwiGlu {
            gate,
            up,
            down,
            act,
            s_slot,
            u3_slot,
            x_slot,
            rows: lead.iter().product(),
            m,
        }
    }
}

impl Layer for SwiGlu {
    fn name(&self) -> &'static str {
        "SwiGlu"
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        let n = self.rows * self.m;
        if let Some(slot) = self.x_slot {
            tape.push_f32(ctx.arena, slot, &ctx.h)?;
        }
        let u1 =
            self.gate.fwd(ctx.arena, ctx.params, tape, &ctx.h, self.rows)?;
        let u3 =
            self.up.fwd(ctx.arena, ctx.params, tape, &ctx.h, self.rows)?;
        let mut s = ctx.arena.take_f32(n);
        self.act.fwd_into(&mut s, &u1);
        self.act.push(ctx.arena, tape, &u1)?;
        tape.push_f32(ctx.arena, self.s_slot, &s)?;
        tape.push_f32(ctx.arena, self.u3_slot, &u3)?;
        ctx.arena.put_f32(u1);
        let mut p = ctx.arena.take_f32(n);
        mul_into(&mut p, &s, &u3);
        ctx.arena.put_f32(s);
        ctx.arena.put_f32(u3);
        let y =
            self.down.fwd(ctx.arena, ctx.params, tape, &p, self.rows)?;
        ctx.arena.put_f32(p);
        ctx.set_h(y);
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        let n = self.rows * self.m;
        let dy = std::mem::take(&mut ctx.dh);
        let dp = self.down.bwd(ctx, tape, &dy, self.rows)?;
        ctx.arena.put_f32(dy);
        let u3 = tape.pop(self.u3_slot)?;
        let s = tape.pop(self.s_slot)?;
        let saved = self.act.pop(ctx.arena, tape)?;
        // product rule: ds = dp ⊙ u₃, du₃ = dp ⊙ s, du₁ = ds ∘ h'(u₁)
        let mut ds = ctx.arena.take_f32(n);
        mul_into(&mut ds, &dp, u3.as_f32());
        let mut du3 = ctx.arena.take_f32(n);
        mul_into(&mut du3, &dp, s.as_f32());
        ctx.arena.put_f32(dp);
        let mut du1 = ctx.arena.take_f32(n);
        self.act.bwd_into(&mut du1, &saved, &ds);
        saved.release(ctx.arena);
        ctx.arena.put_f32(ds);
        // reverse push order: up's slots unwind before gate's
        let mut dx = self.up.bwd(ctx, tape, &du3, self.rows)?;
        ctx.arena.put_f32(du3);
        let dgx = self.gate.bwd(ctx, tape, &du1, self.rows)?;
        ctx.arena.put_f32(du1);
        add_inplace(&mut dx, &dgx);
        ctx.arena.put_f32(dgx);
        if let Some(slot) = self.x_slot {
            tape.pop(slot)?;
        }
        ctx.dh = dx;
        Ok(())
    }
}
