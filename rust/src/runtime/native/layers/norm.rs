//! Normalization layer: LayerNorm / RMSNorm, in plain (affine) or
//! memory-sharing form. The MS variants have no affine of their own —
//! the checkpoint merge (eq. 17) folds it into the following linears —
//! so the single saved x̂ serves both the norm backward *and* those
//! linears' input residual: the layer exposes its x̂ slot via
//! [`Norm::shared_slot`] and consumers wire it in as
//! [`XSrc::Ext`](super::XSrc) at build time.

use anyhow::Result;

use super::super::kernels::{add_bias, colsum_into, norm_bwd_into,
                            norm_fwd_into};
use super::super::model::NetCfg;
use super::tape::{Composer, Kind, SlotId, TapeReader, TapeWriter};
use super::{BwdCtx, FwdCtx, Layer, ParamReg};

/// LN / RMS / MS-LN / MS-RMS normalization over the running activation.
pub struct Norm {
    g: Option<usize>,
    b: Option<usize>,
    rms: bool,
    ms: bool,
    c: usize,
    rows: usize,
    xhat_slot: SlotId,
    stat_slot: SlotId,
}

impl Norm {
    /// Register affine parameters (plain variants only) and mint the
    /// x̂ + stat slots.
    pub fn new(cfg: &NetCfg, reg: &mut ParamReg, comp: &mut Composer,
               name: &str, lead: &[usize]) -> Norm {
        let c = cfg.dim;
        let full = cfg.tuning_full();
        let (g, b) = if cfg.has_affine() {
            let g = reg.add(format!("{name}.w"), vec![c], full);
            let b = if cfg.is_rms() {
                None
            } else {
                Some(reg.add(format!("{name}.b"), vec![c], full))
            };
            (Some(g), b)
        } else {
            (None, None)
        };
        let kind = if cfg.is_ms() {
            Kind::NormShared
        } else {
            Kind::NormInput
        };
        let mut xshape = lead.to_vec();
        xshape.push(c);
        let xhat_slot = comp.slot_f32(name, kind, &xshape);
        let stat_slot = comp.slot_f32(name, Kind::NormStat, lead);
        Norm {
            g,
            b,
            rms: cfg.is_rms(),
            ms: cfg.is_ms(),
            c,
            rows: lead.iter().product(),
            xhat_slot,
            stat_slot,
        }
    }

    /// The x̂ slot, when it is shareable with following linears (MS
    /// variants only).
    pub fn shared_slot(&self) -> Option<SlotId> {
        if self.ms { Some(self.xhat_slot) } else { None }
    }
}

impl Layer for Norm {
    fn name(&self) -> &'static str {
        "Norm"
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        let (rows, c) = (self.rows, self.c);
        let mut xhat = ctx.arena.take_f32(rows * c);
        let mut stat = ctx.arena.take_f32(rows);
        norm_fwd_into(&mut xhat, &mut stat, &ctx.h, rows, c, self.rms);
        tape.push_f32(ctx.arena, self.xhat_slot, &xhat)?;
        tape.push_f32(ctx.arena, self.stat_slot, &stat)?;
        ctx.arena.put_f32(stat);
        if let Some(gi) = self.g {
            let g = ctx.params[gi].as_f32();
            let mut y = ctx.arena.take_f32(rows * c);
            for (yrow, xrow) in y.chunks_mut(c).zip(xhat.chunks(c)) {
                for ((o, &xh), &gv) in
                    yrow.iter_mut().zip(xrow).zip(g)
                {
                    *o = xh * gv;
                }
            }
            if let Some(bi) = self.b {
                add_bias(&mut y, ctx.params[bi].as_f32());
            }
            ctx.arena.put_f32(xhat);
            ctx.set_h(y);
        } else {
            ctx.set_h(xhat);
        }
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        let (rows, c) = (self.rows, self.c);
        let stat = tape.pop(self.stat_slot)?;
        // under `_mesa` the saved x̂ is int8; pop_f32 dequantizes it
        let xhat = tape.pop_f32(ctx.arena, self.xhat_slot)?;
        let dy = std::mem::take(&mut ctx.dh);
        let mut dx = ctx.arena.take_f32(rows * c);
        if let Some(gi) = self.g {
            let mut dg = ctx.arena.take_f32_zeroed(c);
            for (dyrow, xrow) in dy.chunks(c).zip(xhat.as_f32().chunks(c))
            {
                for ((o, &d), &xh) in dg.iter_mut().zip(dyrow).zip(xrow)
                {
                    *o += d * xh;
                }
            }
            ctx.acc(gi, dg);
            if let Some(bi) = self.b {
                let mut db = ctx.arena.take_f32(c);
                colsum_into(&mut db, &dy, rows, c);
                ctx.acc(bi, db);
            }
            let g = ctx.params[gi].as_f32();
            let mut dyh = ctx.arena.take_f32(dy.len());
            for (orow, dyrow) in dyh.chunks_mut(c).zip(dy.chunks(c)) {
                for ((o, &d), &gv) in
                    orow.iter_mut().zip(dyrow).zip(g)
                {
                    *o = d * gv;
                }
            }
            norm_bwd_into(&mut dx, &dyh, xhat.as_f32(), stat.as_f32(),
                          rows, c, self.rms);
            ctx.arena.put_f32(dyh);
        } else {
            norm_bwd_into(&mut dx, &dy, xhat.as_f32(), stat.as_f32(),
                          rows, c, self.rms);
        }
        xhat.release(ctx.arena);
        ctx.arena.put_f32(dy);
        ctx.dh = dx;
        Ok(())
    }
}
