//! Elementwise activation layer. The forward is always exact; variants
//! differ only in what they save for the backward:
//!
//! * `Gelu`/`Silu` — full-precision pre-activation (`act_full`), exact
//!   backward.
//! * `ReGelu2`/`ReSilu2` — 2-bit segment codes (`act_codes`, Prop 4.3:
//!   the backward slope is one of 4 values), approximate backward at
//!   16× less residual memory.
//! * `Relu` — 1-bit sign codes (`act_codes`): ReLU's derivative is
//!   exactly 0/1, so the packed backward is *exact* at 32× less
//!   residual memory.
//!
//! The save/restore policy is factored into [`ActResidual`] so that
//! [`SwiGlu`](super::SwiGlu), which applies the activation to its gate
//! branch rather than to the running activation, shares it verbatim.

use anyhow::Result;

use super::super::arena::Arena;
use super::super::kernels::{act_bwd_exact_into, act_fwd_into,
                            relu_fwd_into};
use super::super::model::{Act, NetCfg};
use super::tape::{Composer, Kind, ResF32, SlotId, TapeReader,
                  TapeWriter};
use super::{BwdCtx, FwdCtx, Layer};
use crate::coeffs::funcs::ReluComb;
use crate::packing;
use crate::runtime::tensor::{DType, Tensor};

/// How an [`Act`] saves its backward residual.
enum Save {
    /// Full-precision pre-activation.
    Full,
    /// 2-bit segment codes against the combination's thresholds.
    Codes2(&'static ReluComb),
    /// 1-bit sign codes (ReLU).
    Signs,
}

fn save_policy(act: Act) -> Save {
    match act {
        Act::Gelu | Act::Silu => Save::Full,
        Act::ReGelu2 | Act::ReSilu2 => Save::Codes2(act.comb()),
        Act::Relu => Save::Signs,
    }
}

/// A popped activation residual: the full-precision save as an f32
/// view (dequantized from int8 under `_mesa`), or a packed code plane.
pub(crate) enum ActSaved<'a> {
    /// Full-precision pre-activation (possibly dequantized).
    Full(ResF32<'a>),
    /// Packed 2-bit segment / 1-bit sign codes.
    Packed(&'a Tensor),
}

impl ActSaved<'_> {
    /// Hand any owned dequantized buffer back to the arena.
    pub(crate) fn release(self, arena: &mut Arena) {
        if let ActSaved::Full(v) = self {
            v.release(arena);
        }
    }
}

/// The activation residual contract: one tape slot minted at build,
/// pushed from the pre-activation in fwd, applied to an upstream
/// gradient in bwd.
pub(crate) struct ActResidual {
    act: Act,
    slot: SlotId,
    n: usize,
}

impl ActResidual {
    /// Mint the residual slot for `cfg.act` over a `lead × m` tensor.
    /// The full-precision save goes through the mesa-aware `slot_f32`,
    /// so under `_mesa` it becomes an int8 group slot (Mesa-GELU /
    /// Mesa-SiLU); the packed code planes are already sub-byte and
    /// never quantize.
    pub(crate) fn mint(cfg: &NetCfg, comp: &mut Composer, module: &str,
                       lead: &[usize], m: usize) -> ActResidual {
        let mut shape = lead.to_vec();
        let slot = match save_policy(cfg.act) {
            Save::Full => {
                shape.push(m);
                comp.slot_f32(module, Kind::ActFull, &shape)
            }
            Save::Codes2(_) => {
                shape.push(m / 4);
                comp.slot(module, Kind::ActCodes, &shape, DType::U8, 2.0)
            }
            Save::Signs => {
                shape.push(m / 8);
                comp.slot(module, Kind::ActCodes, &shape, DType::U8, 1.0)
            }
        };
        ActResidual {
            act: cfg.act,
            slot,
            n: lead.iter().product::<usize>() * m,
        }
    }

    /// Exact forward `y = h(u)` into `out`.
    pub(crate) fn fwd_into(&self, out: &mut [f32], u: &[f32]) {
        match self.act {
            Act::Relu => relu_fwd_into(out, u),
            _ => act_fwd_into(out, u, self.act.is_gelu()),
        }
    }

    /// Push the backward residual derived from the pre-activation `u`.
    pub(crate) fn push(&self, arena: &mut Arena, tape: &mut TapeWriter,
                       u: &[f32]) -> Result<()> {
        match save_policy(self.act) {
            Save::Full => tape.push_f32(arena, self.slot, u),
            Save::Codes2(comb) => {
                // fused bucketize+pack straight into the residual
                // payload: no intermediate code vector
                let mut codes = arena.take_u8(self.n / 4);
                packing::encode2_into(u, comb.c, &mut codes);
                tape.push_u8(self.slot, codes)
            }
            Save::Signs => {
                let mut bits = arena.take_u8(self.n / 8);
                packing::encode1_into(u, &mut bits);
                tape.push_u8(self.slot, bits)
            }
        }
    }

    /// Pop the residual (dequantizing a `_mesa` full save).
    pub(crate) fn pop<'a>(&self, arena: &mut Arena,
                          tape: &mut TapeReader<'a>)
                          -> Result<ActSaved<'a>> {
        match save_policy(self.act) {
            Save::Full => {
                Ok(ActSaved::Full(tape.pop_f32(arena, self.slot)?))
            }
            _ => Ok(ActSaved::Packed(tape.pop(self.slot)?)),
        }
    }

    /// `du = dy ∘ h'(u)` into `du`, from the popped residual.
    pub(crate) fn bwd_into(&self, du: &mut [f32], saved: &ActSaved,
                           dy: &[f32]) {
        match (save_policy(self.act), saved) {
            (Save::Full, ActSaved::Full(u)) => {
                act_bwd_exact_into(du, u.as_f32(), dy,
                                   self.act.is_gelu());
            }
            (Save::Codes2(comb), ActSaved::Packed(t)) => {
                packing::apply_slopes_into(du, &t.data, dy,
                                           comb.slopes());
            }
            (Save::Signs, ActSaved::Packed(t)) => {
                packing::apply_signs_into(du, &t.data, dy);
            }
            _ => unreachable!("activation save/policy mismatch"),
        }
    }
}

/// Activation layer over a `[rows, m]` running activation.
pub struct Activation {
    res: ActResidual,
    n: usize,
}

impl Activation {
    /// Mint the residual slot for activation `cfg.act` applied to a
    /// `lead × m` tensor produced by `module`.
    pub fn new(cfg: &NetCfg, comp: &mut Composer, module: &str,
               lead: &[usize], m: usize) -> Activation {
        Activation {
            res: ActResidual::mint(cfg, comp, module, lead, m),
            n: lead.iter().product::<usize>() * m,
        }
    }
}

impl Layer for Activation {
    fn name(&self) -> &'static str {
        "Activation"
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        let u = std::mem::take(&mut ctx.h);
        let mut y = ctx.arena.take_f32(self.n);
        self.res.fwd_into(&mut y, &u);
        self.res.push(ctx.arena, tape, &u)?;
        ctx.arena.put_f32(u);
        ctx.h = y;
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        let saved = self.res.pop(ctx.arena, tape)?;
        let dy = std::mem::take(&mut ctx.dh);
        let mut du = ctx.arena.take_f32(self.n);
        self.res.bwd_into(&mut du, &saved, &dy);
        saved.release(ctx.arena);
        ctx.arena.put_f32(dy);
        ctx.dh = du;
        Ok(())
    }
}
