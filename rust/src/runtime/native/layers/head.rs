//! Task head + loss: mean-pool → classifier (ViT/RoBERTa) or per-token
//! LM head (LLaMA), followed by softmax cross-entropy. `fwd` consumes
//! the running activation into `(loss, metric)`; `bwd` seeds the
//! gradient chain from the saved logits. Sits after the final [`Norm`]
//! layer in the composition.
//!
//! [`Norm`]: super::Norm

use anyhow::Result;

use super::super::kernels::{add_inplace, softmax_ce,
                            softmax_ce_grad_into};
use super::super::model::{Arch, NetCfg};
use super::linear::{LinOp, XSrc};
use super::tape::{Composer, Kind, SlotId, TapeReader, TapeWriter};
use super::{BwdCtx, FwdCtx, Layer, ParamReg};

/// Head layer: pooling (non-LLaMA), `head.fc`, and the CE loss.
pub struct Head {
    lin: LinOp,
    input_slot: Option<SlotId>,
    logits_slot: SlotId,
    per_token: bool,
    bsz: usize,
    n: usize,
    c: usize,
    k: usize,
}

impl Head {
    /// Register `head.fc` and mint the head-input/logits slots.
    pub fn new(cfg: &NetCfg, reg: &mut ParamReg,
               comp: &mut Composer) -> Head {
        let (bsz, n, c) = (cfg.batch, cfg.n_tokens, cfg.dim);
        let per_token = cfg.arch == Arch::Llama;
        let k = if per_token { cfg.vocab } else { cfg.n_classes };
        let trainable = cfg.head_trainable();
        let (input_slot, x_src, logits_shape) = if per_token {
            let slot = if trainable {
                Some(comp.slot_f32("head.fc", Kind::HeadInput,
                                   &[bsz, n, c]))
            } else {
                None
            };
            (slot, slot.map_or(XSrc::None, XSrc::Ext), vec![bsz, n, k])
        } else {
            let slot =
                comp.slot_f32("head.fc", Kind::HeadInput, &[bsz, c]);
            (Some(slot), XSrc::Ext(slot), vec![bsz, k])
        };
        let lin = LinOp::new_plain(reg, "head.fc", c, k, trainable,
                                   cfg.use_bias(), x_src);
        let logits_slot =
            comp.slot_f32("head", Kind::Logits, &logits_shape);
        Head { lin, input_slot, logits_slot, per_token, bsz, n, c, k }
    }
}

impl Layer for Head {
    fn name(&self) -> &'static str {
        "Head"
    }

    fn fwd(&self, ctx: &mut FwdCtx, tape: &mut TapeWriter) -> Result<()> {
        let (bsz, n, c) = (self.bsz, self.n, self.c);
        let (loss, metric) = if self.per_token {
            let rows = bsz * n;
            if let Some(slot) = self.input_slot {
                tape.push_f32(ctx.arena, slot, &ctx.h)?;
            }
            let z =
                self.lin.fwd(ctx.arena, ctx.params, tape, &ctx.h, rows)?;
            let out = softmax_ce(&z, rows, self.k, ctx.y.as_i32());
            tape.push_f32(ctx.arena, self.logits_slot, &z)?;
            ctx.arena.put_f32(z);
            out
        } else {
            let mut pooled = ctx.arena.take_f32_zeroed(bsz * c);
            for b in 0..bsz {
                let prow = &mut pooled[b * c..(b + 1) * c];
                for i in 0..n {
                    let hrow =
                        &ctx.h[(b * n + i) * c..(b * n + i + 1) * c];
                    add_inplace(prow, hrow);
                }
                for v in prow.iter_mut() {
                    *v /= n as f32;
                }
            }
            tape.push_f32(ctx.arena, self.input_slot.unwrap(), &pooled)?;
            let z =
                self.lin.fwd(ctx.arena, ctx.params, tape, &pooled, bsz)?;
            ctx.arena.put_f32(pooled);
            let out = softmax_ce(&z, bsz, self.k, ctx.y.as_i32());
            tape.push_f32(ctx.arena, self.logits_slot, &z)?;
            ctx.arena.put_f32(z);
            out
        };
        ctx.loss = loss;
        ctx.metric = metric;
        ctx.set_h(Vec::new());
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, tape: &mut TapeReader) -> Result<()> {
        let (bsz, n, c) = (self.bsz, self.n, self.c);
        let z = tape.pop(self.logits_slot)?;
        let dhn = if self.per_token {
            let rows = bsz * n;
            let mut dz = ctx.arena.take_f32(rows * self.k);
            softmax_ce_grad_into(&mut dz, z.as_f32(), rows, self.k,
                                 ctx.y.as_i32());
            let d = self.lin.bwd(ctx, tape, &dz, rows)?;
            ctx.arena.put_f32(dz);
            if let Some(slot) = self.input_slot {
                tape.pop(slot)?;
            }
            d
        } else {
            let mut dz = ctx.arena.take_f32(bsz * self.k);
            softmax_ce_grad_into(&mut dz, z.as_f32(), bsz, self.k,
                                 ctx.y.as_i32());
            let dpooled = self.lin.bwd(ctx, tape, &dz, bsz)?;
            ctx.arena.put_f32(dz);
            tape.pop(self.input_slot.unwrap())?;
            let mut dhn = ctx.arena.take_f32(bsz * n * c);
            let inv = 1.0 / n as f32;
            for b in 0..bsz {
                let src = &dpooled[b * c..(b + 1) * c];
                for i in 0..n {
                    let dst =
                        &mut dhn[(b * n + i) * c..(b * n + i + 1) * c];
                    for (d, &s) in dst.iter_mut().zip(src) {
                        *d = s * inv;
                    }
                }
            }
            ctx.arena.put_f32(dpooled);
            dhn
        };
        ctx.set_dh(dhn);
        Ok(())
    }
}
