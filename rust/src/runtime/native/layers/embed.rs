//! Embedding layer: patch projection (ViT) or token-embedding gather
//! (LLaMA/RoBERTa), plus learned absolute positions — except under
//! RoPE, where positions are rotary inside [`Attention`](super::
//! Attention) and no position table exists. Saves nothing on the tape:
//! the weight gradients only need the batch input, which the trainer
//! still owns in bwd.

use anyhow::{ensure, Result};

use super::super::kernels::{add_inplace, colsum_into, matmul_nt_into,
                            matmul_tn_into};
use super::super::model::{Arch, NetCfg};
use super::tape::{TapeReader, TapeWriter};
use super::{BwdCtx, FwdCtx, Layer, ParamReg};

enum Table {
    /// ViT: `embed.proj.{W,b}` over `[B,N,P]` patches.
    Patch { w: usize, b: usize, patch_dim: usize },
    /// Token gather from `embed.tok.E`.
    Token { e: usize, vocab: usize },
}

/// Input embedding over the batch `x`.
pub struct Embed {
    table: Table,
    pos: Option<usize>,
    c: usize,
    rows: usize,
    n: usize,
}

impl Embed {
    /// Register the embedding parameters (manifest order: table, then
    /// the position table unless RoPE replaces it).
    pub fn new(cfg: &NetCfg, reg: &mut ParamReg) -> Embed {
        let c = cfg.dim;
        let full = cfg.tuning_full();
        let table = match cfg.arch {
            Arch::Vit => Table::Patch {
                w: reg.add("embed.proj.W".into(),
                           vec![c, cfg.patch_dim], full),
                b: reg.add("embed.proj.b".into(), vec![c], full),
                patch_dim: cfg.patch_dim,
            },
            _ => Table::Token {
                e: reg.add("embed.tok.E".into(), vec![cfg.vocab, c],
                           full),
                vocab: cfg.vocab,
            },
        };
        let pos = if cfg.rope() {
            None
        } else {
            Some(reg.add("embed.pos".into(), vec![cfg.n_tokens, c], full))
        };
        Embed {
            table,
            pos,
            c,
            rows: cfg.batch * cfg.n_tokens,
            n: cfg.n_tokens,
        }
    }
}

impl Layer for Embed {
    fn name(&self) -> &'static str {
        "Embed"
    }

    fn fwd(&self, ctx: &mut FwdCtx, _tape: &mut TapeWriter) -> Result<()> {
        let (rows, c) = (self.rows, self.c);
        let mut h = ctx.arena.take_f32(rows * c);
        match &self.table {
            Table::Patch { w, b, patch_dim } => {
                matmul_nt_into(&mut h, ctx.x.as_f32(),
                               ctx.params[*w].as_f32(), rows, *patch_dim,
                               c);
                super::super::kernels::add_bias(
                    &mut h, ctx.params[*b].as_f32());
            }
            Table::Token { e, vocab } => {
                let emb = ctx.params[*e].as_f32();
                for (r, &t) in ctx.x.as_i32().iter().enumerate() {
                    ensure!((t as usize) < *vocab,
                            "token {t} out of range");
                    let t = t as usize;
                    h[r * c..(r + 1) * c]
                        .copy_from_slice(&emb[t * c..(t + 1) * c]);
                }
            }
        }
        if let Some(pi) = self.pos {
            let pos = ctx.params[pi].as_f32();
            let n = self.n;
            for r in 0..rows {
                let prow = &pos[(r % n) * c..(r % n + 1) * c];
                add_inplace(&mut h[r * c..(r + 1) * c], prow);
            }
        }
        ctx.set_h(h);
        Ok(())
    }

    fn bwd(&self, ctx: &mut BwdCtx, _tape: &mut TapeReader) -> Result<()> {
        let (rows, c) = (self.rows, self.c);
        let dh = std::mem::take(&mut ctx.dh);
        match &self.table {
            Table::Patch { w, b, patch_dim } => {
                if ctx.infos[*w].trainable {
                    let mut dw = ctx.arena.take_f32(c * patch_dim);
                    matmul_tn_into(&mut dw, &dh, ctx.x.as_f32(), c, rows,
                                   *patch_dim);
                    ctx.acc(*w, dw);
                    let mut db = ctx.arena.take_f32(c);
                    colsum_into(&mut db, &dh, rows, c);
                    ctx.acc(*b, db);
                }
            }
            Table::Token { e, vocab } => {
                if ctx.infos[*e].trainable {
                    let mut de = ctx.arena.take_f32_zeroed(vocab * c);
                    for (r, &t) in ctx.x.as_i32().iter().enumerate() {
                        let t = t as usize;
                        add_inplace(&mut de[t * c..(t + 1) * c],
                                    &dh[r * c..(r + 1) * c]);
                    }
                    ctx.acc(*e, de);
                }
            }
        }
        if let Some(pi) = self.pos {
            if ctx.infos[pi].trainable {
                let mut dpos = ctx.arena.take_f32_zeroed(self.n * c);
                for r in 0..rows {
                    let i = r % self.n;
                    add_inplace(&mut dpos[i * c..(i + 1) * c],
                                &dh[r * c..(r + 1) * c]);
                }
                ctx.acc(pi, dpos);
            }
        }
        ctx.arena.put_f32(dh);
        Ok(())
    }
}
